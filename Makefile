.PHONY: artifacts verify test build bench

# Regenerate the host-artifact manifest + stamp files (committed, so this
# is only needed after changing model configs or entry contracts).
artifacts:
	cd python && python3 -m compile.gen_host_artifacts --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

# Tier-1 verify + perf check: tests under FASP_THREADS=1 and the default
# threaded backend (writes BENCH_prune_time.json + BENCH_host_threads.json).
verify:
	./verify.sh

bench:
	cargo bench
