#!/usr/bin/env bash
# Tier-1 verification + perf check for CI and pre-merge runs:
#   1. release build
#   2. full test suite (quiet), twice: FASP_THREADS=1 pins the serial
#      HostBackend; the default run exercises ThreadedHostBackend at the
#      machine's width. Outputs are bit-identical by contract
#      (test_backend.rs), so both runs must pass identically.
#   3. bench_prune_time in check mode — a shrunk matrix that writes
#      BENCH_prune_time.json (method mean times + the repack stage's
#      fraction of prune wall-time) so perf regressions in the pruning
#      or compact-repack paths show up as a diffable artifact.
#   4. bench_hot_paths in check mode — writes BENCH_host_threads.json
#      (single vs threaded host_exec fwd latency + bitwise identity) so
#      backend-parallelism regressions are diffable too.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (FASP_THREADS=1, serial reference backend) =="
FASP_THREADS=1 cargo test -q

echo "== cargo test -q (default threaded backend) =="
cargo test -q

echo "== bench_prune_time (check mode) =="
FASP_BENCH_CHECK=1 cargo bench --bench bench_prune_time

echo "== bench_hot_paths (check mode) =="
FASP_BENCH_CHECK=1 cargo bench --bench bench_hot_paths

echo "== verify OK =="
[ -f BENCH_prune_time.json ] && echo "perf record: BENCH_prune_time.json"
[ -f BENCH_host_threads.json ] && echo "perf record: BENCH_host_threads.json"
