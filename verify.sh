#!/usr/bin/env bash
# Tier-1 verification + perf check for CI and pre-merge runs:
#   1. release build
#   2. `fasp lint` — the determinism & robustness static-analysis pass
#      (rust/src/analysis/): HashMap/HashSet, unordered float
#      reductions, wall-clock/ptr-derived values, unsafe-without-SAFETY,
#      panics in request paths and hand-rolled threading are all gated
#      against the justified allowlist in rust/lint_allow.toml. Any
#      non-allowlisted finding (or stale allowlist entry) fails verify
#      before the test matrix even starts. Writes LINT_REPORT.json.
#   3. full test suite (quiet), three times, crossing the matrix axes:
#      - FASP_THREADS=1 + FASP_EXPORT=monolithic pins the serial
#        HostBackend and the classic one-file compact export;
#      - the default (threaded) run sets FASP_EXPORT=sharded so the
#        env-sensitive export paths (save_compact_auto, `fasp compact`)
#        exercise the sharded store;
#      - FASP_QUANT=int8 re-runs the threaded+sharded leg with the
#        quantized packed-panel dtype armed at every CLI boundary; the
#        library pins its own dtypes (Session::pack is always f32), so
#        all bitwise contracts must hold identically under this env.
#      Outputs are bit-identical by contract across all axes
#      (test_backend.rs for threads, test_store.rs for storage,
#      test_pack.rs for the quantized panels), so all runs must pass
#      identically.
#   4. bench_prune_time in check mode — a shrunk matrix that writes
#      BENCH_prune_time.json (method mean times + the repack stage's
#      fraction of prune wall-time) so perf regressions in the pruning
#      or compact-repack paths show up as a diffable artifact.
#   5. bench_hot_paths in check mode — re-runs the lint gate, then
#      writes BENCH_host_threads.json
#      (single vs threaded host_exec fwd latency + bitwise identity),
#      BENCH_shard_stream.json (shard load time, streamed vs monolithic
#      fwd latency, peak-resident-weights estimate), BENCH_decode.json
#      (KV-cached decode latency dense vs compact + the naive re-forward
#      baseline + resident KV bytes), BENCH_pack.json (packed
#      operator plan vs the legacy per-call-transpose path: forward /
#      prefill / per-token decode / streamed fwd, asserting packed
#      strictly beats unpacked, bit-identical outputs, and ZERO
#      pack/transpose operations inside the packed decode loop) and
#      BENCH_serve.json (continuous-batching serve engine vs N
#      sequential generates at 8/64/256 concurrent sessions: tokens/sec,
#      p50/p99 per-token latency, arena page residency — asserting
#      batched strictly beats sequential with bit-identical per-session
#      outputs)
#      and BENCH_spec.json (speculative decoding with FASP-pruned
#      drafts at 30/50/70% sparsity: tokens/sec vs target-only decode,
#      acceptance rate vs draft sparsity, draft KV bytes — asserting
#      greedy bit-identity at every point and a strict tokens/sec win at
#      s=50) so backend-parallelism, shard-streaming, decode, packing,
#      serve-scheduler and speculative-decode regressions are diffable
#      too.
#   6. a `fasp generate` smoke (deterministic --init weights) under both
#      FASP_THREADS=1 and the default threaded backend — the CLI decode
#      path must run end to end on both backends — plus an
#      FASP_QUANT=int8 leg of the same smoke on both backends and an
#      int8 `fasp serve --check` (the serve replay check compares two
#      runs of the same quantized plan, so bit-identity holds at int8
#      exactly as at f32).
#   7. a `fasp generate --draft --check` smoke under both backends: a
#      draft compact model is synthesized on the fly, decodes
#      speculatively, and the greedy output is asserted bit-identical
#      to target-only generate.
#   8. a `fasp serve --check` smoke under both backends: the serve
#      engine drives a self-generated session load end to end and
#      re-verifies every session bit-identical to sequential generate.
#   9. a `fasp chaos --check` smoke under both backends: the same serve
#      load runs fault-free for a census, then twice under one seeded
#      fault plan (pool-worker panics + KV-arena exhaustion) plus a
#      shard-store corruption/truncation probe — asserting survivors
#      bit-identical to the fault-free run, bit-identical replay, zero
#      leaked arena pages, one-shot corruption absorbed by the bounded
#      re-read and persistent truncation surfacing as a proper error.
#      Writes BENCH_chaos.json.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== fasp lint (static analysis gate) =="
cargo run --release --quiet -- lint

echo "== cargo test -q (FASP_THREADS=1, serial backend; monolithic export) =="
FASP_THREADS=1 FASP_EXPORT=monolithic cargo test -q

echo "== cargo test -q (default threaded backend; sharded export) =="
FASP_EXPORT=sharded cargo test -q

echo "== cargo test -q (FASP_QUANT=int8; threaded; sharded export) =="
FASP_QUANT=int8 FASP_EXPORT=sharded cargo test -q

echo "== fasp generate smoke (FASP_THREADS=1, serial backend) =="
FASP_THREADS=1 cargo run --release --quiet -- generate \
  --model llama_tiny --init --prompt-len 8 --max-new 8 --fast

echo "== fasp generate smoke (default threaded backend) =="
cargo run --release --quiet -- generate \
  --model llama_tiny --init --prompt-len 8 --max-new 8 --fast

echo "== fasp generate smoke (FASP_QUANT=int8, serial backend) =="
FASP_QUANT=int8 FASP_THREADS=1 cargo run --release --quiet -- generate \
  --model llama_tiny --init --prompt-len 8 --max-new 8 --fast

echo "== fasp generate smoke (FASP_QUANT=int8, threaded backend) =="
FASP_QUANT=int8 cargo run --release --quiet -- generate \
  --model llama_tiny --init --prompt-len 8 --max-new 8 --fast

echo "== fasp generate --draft smoke (FASP_THREADS=1, serial backend) =="
FASP_THREADS=1 cargo run --release --quiet -- generate \
  --model llama_tiny --init --prompt-len 8 --max-new 8 \
  --draft llama_tiny_spec_draft --draft-sparsity 0.5 --draft-k 4 --check --fast

echo "== fasp generate --draft smoke (default threaded backend) =="
cargo run --release --quiet -- generate \
  --model llama_tiny --init --prompt-len 8 --max-new 8 \
  --draft llama_tiny_spec_draft --draft-sparsity 0.5 --draft-k 4 --check --fast

echo "== fasp serve smoke (FASP_THREADS=1, serial backend) =="
FASP_THREADS=1 cargo run --release --quiet -- serve \
  --model llama_tiny --init --sessions 6 --prompt-len 8 --max-new 6 --check --fast

echo "== fasp serve smoke (default threaded backend) =="
cargo run --release --quiet -- serve \
  --model llama_tiny --init --sessions 6 --prompt-len 8 --max-new 6 --check --fast

echo "== fasp serve smoke (FASP_QUANT=int8, threaded backend) =="
FASP_QUANT=int8 cargo run --release --quiet -- serve \
  --model llama_tiny --init --sessions 6 --prompt-len 8 --max-new 6 --check --fast

echo "== fasp chaos smoke (FASP_THREADS=1, serial backend) =="
FASP_THREADS=1 cargo run --release --quiet -- chaos \
  --model llama_tiny --init --sessions 6 --prompt-len 8 --max-new 6 --check --fast

echo "== fasp chaos smoke (default threaded backend) =="
cargo run --release --quiet -- chaos \
  --model llama_tiny --init --sessions 6 --prompt-len 8 --max-new 6 --check --fast

echo "== bench_prune_time (check mode) =="
FASP_BENCH_CHECK=1 cargo bench --bench bench_prune_time

echo "== bench_hot_paths (check mode) =="
FASP_BENCH_CHECK=1 cargo bench --bench bench_hot_paths

echo "== verify OK =="
[ -f LINT_REPORT.json ] && echo "lint record: LINT_REPORT.json"
[ -f BENCH_prune_time.json ] && echo "perf record: BENCH_prune_time.json"
[ -f BENCH_host_threads.json ] && echo "perf record: BENCH_host_threads.json"
[ -f BENCH_shard_stream.json ] && echo "perf record: BENCH_shard_stream.json"
[ -f BENCH_decode.json ] && echo "perf record: BENCH_decode.json"
[ -f BENCH_pack.json ] && echo "perf record: BENCH_pack.json"
[ -f BENCH_serve.json ] && echo "perf record: BENCH_serve.json"
[ -f BENCH_spec.json ] && echo "perf record: BENCH_spec.json"
[ -f BENCH_chaos.json ] && echo "perf record: BENCH_chaos.json"
