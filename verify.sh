#!/usr/bin/env bash
# Tier-1 verification + perf check for CI and pre-merge runs:
#   1. release build
#   2. full test suite (quiet)
#   3. bench_prune_time in check mode — a shrunk matrix that writes
#      BENCH_prune_time.json (method mean times + the repack stage's
#      fraction of prune wall-time) so perf regressions in the pruning
#      or compact-repack paths show up as a diffable artifact.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== bench_prune_time (check mode) =="
FASP_BENCH_CHECK=1 cargo bench --bench bench_prune_time

echo "== verify OK =="
[ -f BENCH_prune_time.json ] && echo "perf record: BENCH_prune_time.json"
