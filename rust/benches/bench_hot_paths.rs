//! Bench: L3 hot paths in isolation — restoration solve (Cholesky vs
//! ADMM, the §3.3 comparison), host matmul, Wanda metric (host vs Pallas
//! artifact), and the threaded-vs-single host_exec comparison (the
//! backend-parallelism receipt). Drives the §Perf iteration log in
//! EXPERIMENTS.md.
//!
//! `FASP_BENCH_CHECK=1` shrinks the matrix AND writes
//! `BENCH_host_threads.json` (single/threaded fwd latency + bitwise
//! identity), `BENCH_shard_stream.json` (shard load time, streamed
//! vs monolithic fwd latency, peak-resident-weights estimate) and
//! `BENCH_decode.json` (KV-cached decode: prefill + per-token latency
//! dense vs OV-sliced compact, the naive re-forward baseline, resident
//! KV bytes), `BENCH_serve.json` (continuous-batching serve engine
//! vs N sequential generates at 8/64/256 concurrent sessions:
//! tokens/sec, p50/p99 per-token latency, arena page residency,
//! bitwise identity) and `BENCH_spec.json` (speculative decoding with
//! FASP compact drafts at s∈{30,50,70}: tokens/sec vs target-only,
//! acceptance rate per draft sparsity, draft+target KV bytes, greedy
//! bit-identity) so CI can diff backend-parallelism, shard-streaming,
//! decode-path, serve-scheduler and speculative-decode regressions.

use fasp::bench_support::Bencher;
use fasp::data::{Corpus, Dataset};
use fasp::eval::speed::compare_backends;
use fasp::linalg::admm_restore;
use fasp::model::Weights;
use fasp::prune::metric::{wanda_scores_host, KernelMetric};
use fasp::prune::restore::restore_columns;
use fasp::runtime::{HostBackend, Manifest, Session, ThreadedHostBackend};
use fasp::tensor::matmul::{matmul, matmul_at, matmul_bt};
use fasp::tensor::pack::PackedMat;
use fasp::tensor::Tensor;
use fasp::util::json::Json;
use fasp::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let check = std::env::var("FASP_BENCH_CHECK").is_ok();
    let mut b = Bencher::default();
    if check {
        b.min_samples = 3;
        b.budget_s = 0.5;
    }
    let mut rng = Rng::new(1);

    // ---- static-analysis gate: the crate must lint clean --------------
    // Runs first (cheap, pure CPU) so a determinism/robustness
    // regression fails the bench before any timing work; check mode
    // also writes LINT_REPORT.json so the gate is diffable like the
    // other receipts.
    {
        let run = fasp::analysis::lint_repo(&fasp::repo_root())
            .expect("fasp lint failed to run over the crate");
        if check {
            std::fs::write(
                fasp::repo_root().join("LINT_REPORT.json"),
                run.report_json().pretty(),
            )
            .expect("write LINT_REPORT.json");
        }
        assert!(
            run.is_clean(),
            "static analysis regressed:\n{}",
            run.render_table()
        );
        println!(
            "lint: clean ({} files, {} allowed suppressions)",
            run.files_scanned,
            run.allowed.len()
        );
    }

    // ---- restoration: closed form vs ADMM at the real shapes ----------
    for &(m, n) in &[(128usize, 512usize), (256, 1024)] {
        let w = Tensor::randn(&[m, n], 1.0, &mut rng);
        let x = Tensor::randn(&[512, n], 1.0, &mut rng);
        let g = matmul_at(&x, &x);
        let kept: Vec<bool> = (0..n).map(|j| j % 5 != 0).collect();
        b.bench(&format!("restore/closed_form {m}x{n}"), || {
            let _ = restore_columns(&w, &g, &kept, 1e-2).unwrap();
        });
        let mut greg: Vec<f64> = g.data.iter().map(|&v| v as f64).collect();
        for i in 0..n {
            greg[i * n + i] += 1.0;
        }
        let admm_iters = if check { 8 } else { 32 };
        b.bench(&format!("restore/admm_{admm_iters}it {m}x{n}"), || {
            let _ = admm_restore(&w, &greg, &kept, 100.0, admm_iters).unwrap();
        });
    }

    // ---- metric: host vs Pallas artifact --------------------------------
    let w = Tensor::randn(&[256, 1024], 1.0, &mut rng);
    let xnorm: Vec<f32> = (0..1024).map(|i| 0.1 + i as f32 * 1e-3).collect();
    b.bench("metric/wanda_host 256x1024", || {
        let _ = wanda_scores_host(&w, &xnorm);
    });
    if let Ok(manifest) = Manifest::load(&fasp::artifacts_dir()) {
        let km = KernelMetric::new(&manifest);
        b.bench("metric/wanda_pallas 256x1024", || {
            let _ = km.wanda_scores(&w, &xnorm).unwrap();
        });
    }

    // ---- host matmuls at restoration shapes -----------------------------
    let a = Tensor::randn(&[256, 1024], 1.0, &mut rng);
    let g = Tensor::randn(&[1024, 1024], 1.0, &mut rng);
    b.bench("matmul/256x1024x1024 (W*G)", || {
        let _ = matmul(&a, &g);
    });
    let x = Tensor::randn(&[512, 256], 1.0, &mut rng);
    let wt = Tensor::randn(&[1024, 256], 1.0, &mut rng);
    b.bench("matmul_bt/512x256->1024 (linear, per-call transpose)", || {
        let _ = matmul_bt(&x, &wt);
    });
    let packed = PackedMat::pack_bt(&wt);
    b.bench("matmul_packed/512x256->1024 (linear, pre-packed)", || {
        let _ = fasp::tensor::pack::matmul_packed(&x, &packed);
    });
    let xrow = Tensor::randn(&[1, 256], 1.0, &mut rng);
    b.bench("matvec_bt/1x256->1024 (decode fallback)", || {
        let _ = matmul_bt(&xrow, &wt);
    });
    b.bench("matvec_packed/1x256->1024 (decode hot path)", || {
        let _ = fasp::tensor::pack::matmul_packed(&xrow, &packed);
    });
    let y512 = Tensor::randn(&[512, 1024], 1.0, &mut rng);
    b.bench("matmul_at/512x256,512x1024 (transpose-free dW)", || {
        let _ = matmul_at(&x, &y512);
    });

    // ---- host_exec: single-threaded vs thread-pooled backend ------------
    if let Ok(manifest) = Manifest::load(&fasp::artifacts_dir()) {
        let model = "llama_small";
        let threads = fasp::util::pool::default_threads().max(4);
        let spec = manifest.model(model).expect("llama_small in manifest").clone();
        let wts = Weights::init(&spec, 5);
        let ds = Dataset::new(Corpus::new(spec.vocab, 2), spec.batch, spec.seq, 2);
        let batch = ds.train_batch(0);

        let single =
            Session::with_backend(&manifest, model, Arc::new(HostBackend::new())).unwrap();
        let sp = single.pack(&wts.packed).unwrap();
        b.bench(&format!("host_exec/{model} fwd_loss x1"), || {
            let _ = single.fwd_loss(&sp, &batch.tokens, &batch.targets).unwrap();
        });
        let threaded = Session::with_backend(
            &manifest,
            model,
            Arc::new(ThreadedHostBackend::new(threads)),
        )
        .unwrap();
        let tp = threaded.pack(&wts.packed).unwrap();
        b.bench(&format!("host_exec/{model} fwd_loss x{threads}"), || {
            let _ = threaded.fwd_loss(&tp, &batch.tokens, &batch.targets).unwrap();
        });

        let reps = if check { 5 } else { 20 };
        let cmp = compare_backends(&manifest, model, &wts, reps, threads).unwrap();
        println!(
            "\nhost_exec {model}: single {:.3}ms vs threaded(x{}) {:.3}ms → {:.2}x, \
             outputs bit-identical: {}",
            cmp.single_ms, cmp.threads, cmp.threaded_ms, cmp.speedup, cmp.identical
        );
        assert!(cmp.identical, "backend outputs diverged — determinism broken");

        // machine-readable record for regression diffing (check mode only)
        if check {
            let record = Json::obj(vec![
                ("bench", Json::Str("host_threads".into())),
                ("model", Json::Str(model.into())),
                ("threads", Json::Num(cmp.threads as f64)),
                ("single_ms", Json::Num(cmp.single_ms)),
                ("threaded_ms", Json::Num(cmp.threaded_ms)),
                ("speedup", Json::Num(cmp.speedup)),
                ("identical", Json::Bool(cmp.identical)),
            ]);
            let path = fasp::repo_root().join("BENCH_host_threads.json");
            std::fs::write(&path, record.pretty()).unwrap();
            println!("record → {}", path.display());
        }
    }

    // ---- sharded store: stream-load vs monolithic compact ----------------
    // Export a compact model sharded, then compare the monolithic
    // (assemble-everything) path against the layer-streaming path: shard
    // load time, fwd latency, and the peak-resident-weights estimate.
    if let Ok(mut manifest) = Manifest::load(&fasp::artifacts_dir()) {
        let model = "llama_small";
        let spec = manifest.model(model).expect("llama_small in manifest").clone();
        let w = Weights::init(&spec, 9);
        let mut mask = fasp::model::PruneMask::full(&spec);
        for l in 0..spec.n_layers {
            for j in 0..spec.d_ff / 4 {
                mask.layers[l].ffn[(j * 3 + l) % spec.d_ff] = false;
            }
        }
        let cm =
            fasp::model::compact::compact_from_mask(&w, &mask, "bench_shard").unwrap();
        let dir = std::env::temp_dir().join("fasp_bench_shard");
        let _ = std::fs::remove_dir_all(&dir);
        let jp = fasp::model::compact::save_compact_sharded(&dir, &cm).unwrap();
        manifest.register_compact(&jp).unwrap();
        let store = manifest.compact_store("bench_shard").unwrap();
        let reps = if check { 5 } else { 20 };
        let cmp = fasp::eval::speed::compare_stream_eval(
            &manifest,
            "bench_shard",
            &store,
            reps,
        )
        .unwrap();
        assert!(cmp.identical, "streamed outputs diverged — store broken");
        println!(
            "\nshard_stream {model}: assemble {:.3}ms, fwd mono {:.3}ms vs \
             streamed {:.3}ms; peak resident {:.2}MB / model {:.2}MB \
             ({:.0}%), {} shards, mean shard load {:.3}ms",
            cmp.assemble_ms,
            cmp.mono_ms,
            cmp.stream_ms,
            cmp.peak_resident_bytes as f64 / 1e6,
            cmp.model_bytes as f64 / 1e6,
            100.0 * cmp.peak_resident_bytes as f64 / cmp.model_bytes.max(1) as f64,
            cmp.shards,
            cmp.shard_load_ms
        );
        if check {
            let record = Json::obj(vec![
                ("bench", Json::Str("shard_stream".into())),
                ("model", Json::Str(model.into())),
                ("assemble_ms", Json::Num(cmp.assemble_ms)),
                ("mono_fwd_ms", Json::Num(cmp.mono_ms)),
                ("stream_fwd_ms", Json::Num(cmp.stream_ms)),
                ("shard_load_ms", Json::Num(cmp.shard_load_ms)),
                ("shards", Json::Num(cmp.shards as f64)),
                ("peak_resident_bytes", Json::Num(cmp.peak_resident_bytes as f64)),
                ("model_bytes", Json::Num(cmp.model_bytes as f64)),
                (
                    "resident_frac",
                    Json::Num(
                        cmp.peak_resident_bytes as f64 / cmp.model_bytes.max(1) as f64,
                    ),
                ),
                ("identical", Json::Bool(cmp.identical)),
            ]);
            let path = fasp::repo_root().join("BENCH_shard_stream.json");
            std::fs::write(&path, record.pretty()).unwrap();
            println!("record → {}", path.display());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- KV-cached decode: dense vs compact, cached vs re-forward --------
    // Export a compact model with BOTH FFN and OV slicing (OV is what
    // shrinks the value cache), then compare autoregressive decode:
    // prefill + per-token latency dense vs compact, the naive O(prefix²)
    // re-forward baseline, and the resident KV bytes of each cache.
    if let Ok(mut manifest) = Manifest::load(&fasp::artifacts_dir()) {
        let model = "llama_small";
        let spec = manifest.model(model).expect("llama_small in manifest").clone();
        let w = Weights::init(&spec, 13);
        let dh = spec.head_dim();
        let mut mask = fasp::model::PruneMask::full(&spec);
        for l in 0..spec.n_layers {
            for j in 0..spec.d_ff / 4 {
                mask.layers[l].ffn[(j * 3 + l) % spec.d_ff] = false;
            }
            // slice a quarter of every head's value dims — the KV-cache
            // shrink FASP's OV pruning promises
            for hi in 0..spec.n_heads {
                for j in 0..dh / 4 {
                    mask.layers[l].ov[hi * dh + (j * 3 + l) % dh] = false;
                }
            }
        }
        let cm =
            fasp::model::compact::compact_from_mask(&w, &mask, "bench_decode").unwrap();
        let dir = std::env::temp_dir().join("fasp_bench_decode");
        let _ = std::fs::remove_dir_all(&dir);
        let jp = fasp::model::compact::save_compact(&dir, &cm).unwrap();
        manifest.register_compact(&jp).unwrap();
        let cw = manifest.compact_weights("bench_decode").unwrap();

        let (prompt_len, max_new) = (32usize, if check { 8 } else { 16 });
        let reps = if check { 3 } else { 10 };
        let cmp = fasp::eval::speed::compare_decode(
            &manifest,
            model,
            &w,
            "bench_decode",
            &cw,
            prompt_len,
            max_new,
            reps,
        )
        .unwrap();
        assert!(
            cmp.identical,
            "cached decode tokens diverged from the full re-forward — decode broken"
        );
        assert!(
            cmp.compact_kv_bytes < cmp.dense_kv_bytes,
            "OV-sliced KV cache ({}) not below dense ({})",
            cmp.compact_kv_bytes,
            cmp.dense_kv_bytes
        );
        println!(
            "\ndecode {model}: prefill dense {:.3}ms vs compact {:.3}ms; per-token \
             dense {:.3}ms vs compact {:.3}ms ({:.2}x); re-forward baseline \
             {:.3}ms/tok ({:.2}x vs cached); kv dense {:.2}KB vs compact \
             {:.2}KB; cached ≡ re-forward: {}",
            cmp.dense_prefill_ms,
            cmp.compact_prefill_ms,
            cmp.dense_per_token_ms,
            cmp.compact_per_token_ms,
            cmp.per_token_speedup,
            cmp.dense_reforward_per_token_ms,
            cmp.cache_speedup,
            cmp.dense_kv_bytes as f64 / 1e3,
            cmp.compact_kv_bytes as f64 / 1e3,
            cmp.identical
        );
        if check {
            let record = Json::obj(vec![
                ("bench", Json::Str("decode".into())),
                ("model", Json::Str(model.into())),
                ("prompt_len", Json::Num(cmp.prompt_len as f64)),
                ("decode_steps", Json::Num(cmp.steps as f64)),
                ("dense_prefill_ms", Json::Num(cmp.dense_prefill_ms)),
                ("compact_prefill_ms", Json::Num(cmp.compact_prefill_ms)),
                ("dense_per_token_ms", Json::Num(cmp.dense_per_token_ms)),
                ("compact_per_token_ms", Json::Num(cmp.compact_per_token_ms)),
                (
                    "dense_reforward_per_token_ms",
                    Json::Num(cmp.dense_reforward_per_token_ms),
                ),
                ("per_token_speedup", Json::Num(cmp.per_token_speedup)),
                ("cache_speedup", Json::Num(cmp.cache_speedup)),
                ("dense_kv_bytes", Json::Num(cmp.dense_kv_bytes as f64)),
                ("compact_kv_bytes", Json::Num(cmp.compact_kv_bytes as f64)),
                ("identical", Json::Bool(cmp.identical)),
            ]);
            let path = fasp::repo_root().join("BENCH_decode.json");
            std::fs::write(&path, record.pretty()).unwrap();
            println!("record → {}", path.display());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- packed operator plan: packed vs unpacked everything -------------
    // The pre-packed weight plan (Session::pack) against the legacy
    // per-call copy + transpose path: full forward, prefill, per-token
    // decode, and the streamed forward (an s=0 sharded export whose
    // shards pack on the prefetch thread). Bit-identity is asserted, and
    // the pack/transpose counters prove the packed decode loop performs
    // zero pack work after the session is built.
    if let Ok(mut manifest) = Manifest::load(&fasp::artifacts_dir()) {
        let model = "llama_small";
        let spec = manifest.model(model).expect("llama_small in manifest").clone();
        let w = Weights::init(&spec, 29);

        // s=0 sharded export of the same weights for the streamed row
        let mask = fasp::model::PruneMask::full(&spec);
        let cm = fasp::model::compact::compact_from_mask(&w, &mask, "bench_pack").unwrap();
        let dir = std::env::temp_dir().join("fasp_bench_pack");
        let _ = std::fs::remove_dir_all(&dir);
        let jp = fasp::model::compact::save_compact_sharded(&dir, &cm).unwrap();
        manifest.register_compact(&jp).unwrap();
        let store = manifest.compact_store("bench_pack").unwrap();

        let (prompt_len, max_new) = (32usize, if check { 8 } else { 16 });
        let reps = if check { 3 } else { 10 };
        let cmp = fasp::eval::speed::compare_packed(
            &manifest,
            model,
            &w,
            Some(&store),
            prompt_len,
            max_new,
            reps,
        )
        .unwrap();
        assert!(
            cmp.identical,
            "packed outputs diverged from unpacked — the lane-kernel bit \
             contract is broken"
        );
        assert_eq!(
            cmp.decode_pack_ops, 0,
            "the packed decode loop performed {} pack constructions — \
             packing must happen exactly once, at Session::pack",
            cmp.decode_pack_ops
        );
        assert_eq!(
            cmp.decode_bt_transposes, 0,
            "the packed decode loop took {} weight-transpose copies — no \
             per-token transpose work is allowed after session build",
            cmp.decode_bt_transposes
        );
        assert!(
            cmp.int8_deterministic,
            "int8 greedy decode diverged across replay or pool widths — \
             the quantized lane kernel must stay deterministic"
        );
        println!(
            "\npack {model} (x{} workers): plan {:.3}ms / {:.2}MB / {} weights; \
             fwd unpacked {:.3}ms vs packed {:.3}ms ({:.2}x); prefill \
             {:.3} → {:.3}ms; per-token {:.3} → {:.3}ms ({:.2}x); streamed \
             fwd {:.3}ms; decode packs {} / transposes {}; packed ≡ \
             unpacked: {}",
            cmp.threads,
            cmp.pack_build_ms,
            cmp.pack_bytes as f64 / 1e6,
            cmp.packed_weights,
            cmp.unpacked_fwd_ms,
            cmp.packed_fwd_ms,
            cmp.fwd_speedup,
            cmp.unpacked_prefill_ms,
            cmp.packed_prefill_ms,
            cmp.unpacked_per_token_ms,
            cmp.packed_per_token_ms,
            cmp.per_token_speedup,
            cmp.streamed_fwd_ms,
            cmp.decode_pack_ops,
            cmp.decode_bt_transposes,
            cmp.identical
        );
        println!(
            "pack int8 {model}: plan {:.3}ms / {:.2}MB ({:.2}x of f32); fwd \
             {:.3}ms; prefill {:.3}ms; per-token {:.3}ms ({:.2}x of f32 \
             packed); nll delta {:+.3e}; deterministic: {}",
            cmp.int8_pack_build_ms,
            cmp.int8_pack_bytes as f64 / 1e6,
            cmp.int8_pack_bytes as f64 / cmp.pack_bytes.max(1) as f64,
            cmp.int8_fwd_ms,
            cmp.int8_prefill_ms,
            cmp.int8_per_token_ms,
            cmp.int8_per_token_ms / cmp.packed_per_token_ms.max(1e-12),
            cmp.int8_nll_delta,
            cmp.int8_deterministic
        );
        if check {
            // the packed paths must strictly beat the per-call-transpose
            // baseline — the whole point of the persistent plan
            assert!(
                cmp.packed_fwd_ms < cmp.unpacked_fwd_ms,
                "packed forward {:.3}ms !< unpacked {:.3}ms",
                cmp.packed_fwd_ms,
                cmp.unpacked_fwd_ms
            );
            assert!(
                cmp.packed_per_token_ms < cmp.unpacked_per_token_ms,
                "packed per-token decode {:.3}ms !< unpacked {:.3}ms",
                cmp.packed_per_token_ms,
                cmp.unpacked_per_token_ms
            );
            // int8 receipts: the quantized plan must roughly halve (in
            // fact quarter) the resident pack bytes and must not regress
            // per-token decode past the f32 packed path
            assert!(
                cmp.int8_pack_bytes as f64 <= 0.55 * cmp.pack_bytes as f64,
                "int8 pack bytes {} !<= 0.55x f32 pack bytes {}",
                cmp.int8_pack_bytes,
                cmp.pack_bytes
            );
            assert!(
                cmp.int8_per_token_ms <= 1.0 * cmp.packed_per_token_ms,
                "int8 per-token decode {:.3}ms regressed past f32 packed \
                 {:.3}ms — dequant must stay in-register on the hot path",
                cmp.int8_per_token_ms,
                cmp.packed_per_token_ms
            );
            let record = Json::obj(vec![
                ("bench", Json::Str("pack".into())),
                ("model", Json::Str(model.into())),
                ("threads", Json::Num(cmp.threads as f64)),
                ("pack_build_ms", Json::Num(cmp.pack_build_ms)),
                ("pack_bytes", Json::Num(cmp.pack_bytes as f64)),
                ("packed_weights", Json::Num(cmp.packed_weights as f64)),
                ("unpacked_fwd_ms", Json::Num(cmp.unpacked_fwd_ms)),
                ("packed_fwd_ms", Json::Num(cmp.packed_fwd_ms)),
                ("fwd_speedup", Json::Num(cmp.fwd_speedup)),
                ("unpacked_prefill_ms", Json::Num(cmp.unpacked_prefill_ms)),
                ("packed_prefill_ms", Json::Num(cmp.packed_prefill_ms)),
                ("unpacked_per_token_ms", Json::Num(cmp.unpacked_per_token_ms)),
                ("packed_per_token_ms", Json::Num(cmp.packed_per_token_ms)),
                ("per_token_speedup", Json::Num(cmp.per_token_speedup)),
                ("streamed_fwd_ms", Json::Num(cmp.streamed_fwd_ms)),
                ("decode_pack_ops", Json::Num(cmp.decode_pack_ops as f64)),
                (
                    "decode_bt_transposes",
                    Json::Num(cmp.decode_bt_transposes as f64),
                ),
                ("identical", Json::Bool(cmp.identical)),
                ("int8_pack_build_ms", Json::Num(cmp.int8_pack_build_ms)),
                ("int8_pack_bytes", Json::Num(cmp.int8_pack_bytes as f64)),
                (
                    "int8_bytes_ratio",
                    Json::Num(
                        cmp.int8_pack_bytes as f64 / cmp.pack_bytes.max(1) as f64,
                    ),
                ),
                ("int8_fwd_ms", Json::Num(cmp.int8_fwd_ms)),
                ("int8_prefill_ms", Json::Num(cmp.int8_prefill_ms)),
                ("int8_per_token_ms", Json::Num(cmp.int8_per_token_ms)),
                ("int8_nll_delta", Json::Num(cmp.int8_nll_delta)),
                ("int8_deterministic", Json::Bool(cmp.int8_deterministic)),
            ]);
            let path = fasp::repo_root().join("BENCH_pack.json");
            std::fs::write(&path, record.pretty()).unwrap();
            println!("record → {}", path.display());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- continuous-batching serve: batched vs N sequential generates ----
    // The serve engine (admission queue + paged KV arena + prefix cache)
    // driving 8/64/256 concurrent sessions over one shared packed plan,
    // against the same requests run one-at-a-time through generate.
    // Bit-identity is asserted per session, and batched throughput must
    // strictly beat sequential at every point — a batched tick reads
    // each packed weight panel once for all lanes.
    if let Ok(manifest) = Manifest::load(&fasp::artifacts_dir()) {
        let model = "llama_small";
        let spec = manifest.model(model).expect("llama_small in manifest").clone();
        let w = Weights::init(&spec, 31);
        let (prompt_len, max_new) = (16usize, if check { 6 } else { 12 });
        let (page, max_batch) = (16usize, 16usize);
        let mut points = Vec::new();
        for &sessions in &[8usize, 64, 256] {
            let uniq = sessions / 2 + sessions % 2;
            let pages_per = (prompt_len + max_new - 1 + page - 1) / page;
            let n_pages =
                (max_batch * pages_per + uniq * (prompt_len / page) + pages_per) * 5 / 4 + 1;
            let cfg = fasp::serve::ServeConfig {
                page,
                n_pages,
                max_batch,
                prefix_cache: true,
                prefill_chunk: 4,
                ..Default::default()
            };
            let cmp = fasp::eval::speed::compare_serve(
                &manifest, model, &w, sessions, prompt_len, max_new, &cfg,
            )
            .unwrap();
            assert!(
                cmp.identical,
                "serve outputs diverged from sequential generate at {sessions} \
                 sessions — the scheduler bit-identity contract is broken"
            );
            assert!(
                cmp.batched_tokens_per_s > cmp.sequential_tokens_per_s,
                "batched serve ({:.0} tok/s) not above {sessions} sequential \
                 generates ({:.0} tok/s)",
                cmp.batched_tokens_per_s,
                cmp.sequential_tokens_per_s
            );
            println!(
                "\nserve {model} x{sessions}: batched {:.0} tok/s vs sequential \
                 {:.0} tok/s ({:.2}x); p50 {:.3}ms / p99 {:.3}ms per token; \
                 {} ticks, max batch {}, {} prefix hits, peak {} / {} pages \
                 ({:.2}MB arena); bit-identical: {}",
                cmp.batched_tokens_per_s,
                cmp.sequential_tokens_per_s,
                cmp.throughput_speedup,
                cmp.p50_token_ms,
                cmp.p99_token_ms,
                cmp.ticks,
                cmp.max_batch_seen,
                cmp.prefix_hits,
                cmp.peak_pages,
                n_pages,
                cmp.kv_bytes as f64 / 1e6,
                cmp.identical
            );
            points.push(Json::obj(vec![
                ("sessions", Json::Num(sessions as f64)),
                ("batched_tokens_per_s", Json::Num(cmp.batched_tokens_per_s)),
                (
                    "sequential_tokens_per_s",
                    Json::Num(cmp.sequential_tokens_per_s),
                ),
                ("throughput_speedup", Json::Num(cmp.throughput_speedup)),
                ("p50_token_ms", Json::Num(cmp.p50_token_ms)),
                ("p99_token_ms", Json::Num(cmp.p99_token_ms)),
                ("ticks", Json::Num(cmp.ticks as f64)),
                ("max_batch_seen", Json::Num(cmp.max_batch_seen as f64)),
                ("prefix_hits", Json::Num(cmp.prefix_hits as f64)),
                ("peak_pages", Json::Num(cmp.peak_pages as f64)),
                ("n_pages", Json::Num(n_pages as f64)),
                ("kv_bytes", Json::Num(cmp.kv_bytes as f64)),
                ("identical", Json::Bool(cmp.identical)),
            ]));
        }
        if check {
            let record = Json::obj(vec![
                ("bench", Json::Str("serve".into())),
                ("model", Json::Str(model.into())),
                ("prompt_len", Json::Num(prompt_len as f64)),
                ("max_new", Json::Num(max_new as f64)),
                ("page", Json::Num(page as f64)),
                ("max_batch", Json::Num(max_batch as f64)),
                ("points", Json::Arr(points)),
            ]);
            let path = fasp::repo_root().join("BENCH_serve.json");
            std::fs::write(&path, record.pretty()).unwrap();
            println!("record → {}", path.display());
        }
    }

    // ---- speculative decoding: FASP compact drafts vs target-only --------
    // The paper's compression artifact as a *lossless speedup* of its
    // dense parent: compact exports at s∈{30,50,70} draft tokens, the
    // target verifies every proposal (plus one bonus) in ONE chunked
    // forward. The target's weights attenuate the to-be-pruned tail
    // units (x1e-3, the s=70 union) so the sliced drafts stay faithful
    // — acceptance then tracks draft sparsity the way a FASP-pruned
    // draft of a *trained* model would, instead of the ~1/vocab argmax
    // agreement two unrelated random inits give. Greedy bit-identity is
    // asserted per point regardless of acceptance (losslessness is
    // structural, not statistical).
    if let Ok(mut manifest) = Manifest::load(&fasp::artifacts_dir()) {
        let model = "llama_small";
        let spec = manifest.model(model).expect("llama_small in manifest").clone();
        let mut w = Weights::init(&spec, 37);
        let dh = spec.head_dim();
        let ov = spec.n_heads * dh;
        let (f70, v70) = ((spec.d_ff * 7) / 10, (dh * 7) / 10);
        for l in 0..spec.n_layers {
            let mut wd = w.get_l(l, "w_down").unwrap(); // [d, d_ff]
            for r in 0..spec.d_model {
                for j in 0..f70 {
                    wd.data[r * spec.d_ff + spec.d_ff - 1 - j] *= 1e-3;
                }
            }
            w.set_l(l, "w_down", &wd).unwrap();
            let mut wo = w.get_l(l, "wo").unwrap(); // [d, ov]
            for r in 0..spec.d_model {
                for hi in 0..spec.n_heads {
                    for j in 0..v70 {
                        wo.data[r * ov + hi * dh + dh - 1 - j] *= 1e-3;
                    }
                }
            }
            w.set_l(l, "wo", &wo).unwrap();
        }

        // nested tail-slice masks: the s=30 pruned set ⊂ s=50 ⊂ s=70,
        // all inside the attenuated union
        let dir = std::env::temp_dir().join("fasp_bench_spec");
        let _ = std::fs::remove_dir_all(&dir);
        let mut drafts: Vec<(f64, String, Weights)> = Vec::new();
        for &pct in &[30usize, 50, 70] {
            let (fc, vc) = ((spec.d_ff * pct) / 100, (dh * pct) / 100);
            let mut mask = fasp::model::PruneMask::full(&spec);
            for l in 0..spec.n_layers {
                for j in 0..fc {
                    mask.layers[l].ffn[spec.d_ff - 1 - j] = false;
                }
                for hi in 0..spec.n_heads {
                    for j in 0..vc {
                        mask.layers[l].ov[hi * dh + dh - 1 - j] = false;
                    }
                }
            }
            let name = format!("bench_spec_s{pct}");
            let cm = fasp::model::compact::compact_from_mask(&w, &mask, &name).unwrap();
            let jp = fasp::model::compact::save_compact(&dir.join(&name), &cm).unwrap();
            manifest.register_compact(&jp).unwrap();
            let cw = manifest.compact_weights(&name).unwrap();
            drafts.push((pct as f64 / 100.0, name, cw));
        }
        let refs: Vec<(f64, &str, &Weights)> =
            drafts.iter().map(|(s, n, cw)| (*s, n.as_str(), cw)).collect();

        let (prompt_len, max_new) = (8usize, if check { 40 } else { 64 });
        let draft_k = 8usize;
        let reps = if check { 3 } else { 10 };
        let cmp = fasp::eval::speed::compare_speculative(
            &manifest, model, &w, &refs, prompt_len, max_new, draft_k, reps,
        )
        .unwrap();
        println!(
            "\nspec {model}: target-only {:.0} tok/s (kv {:.2}KB), draft-k {draft_k}",
            cmp.target_tokens_per_s,
            cmp.target_kv_bytes as f64 / 1e3
        );
        let mut points = Vec::new();
        for p in &cmp.points {
            assert!(
                p.greedy_identical,
                "speculative greedy tokens diverged from target-only generate \
                 at s={:.0}% — the losslessness contract is broken",
                p.sparsity * 100.0
            );
            println!(
                "  s={:.0}%: {:.0} tok/s ({:.2}x), acceptance {:.2} \
                 ({}/{} proposals), {} chunks + {} draft steps, draft kv \
                 {:.2}KB; bit-identical: {}",
                p.sparsity * 100.0,
                p.spec_tokens_per_s,
                p.speedup,
                p.acceptance,
                p.accepted,
                p.proposed,
                p.chunks,
                p.draft_steps,
                p.draft_kv_bytes as f64 / 1e3,
                p.greedy_identical
            );
            points.push(Json::obj(vec![
                ("sparsity", Json::Num(p.sparsity)),
                ("draft_model", Json::Str(p.draft_model.clone())),
                ("acceptance", Json::Num(p.acceptance)),
                ("proposed", Json::Num(p.proposed as f64)),
                ("accepted", Json::Num(p.accepted as f64)),
                ("chunks", Json::Num(p.chunks as f64)),
                ("draft_steps", Json::Num(p.draft_steps as f64)),
                ("spec_tokens_per_s", Json::Num(p.spec_tokens_per_s)),
                ("speedup", Json::Num(p.speedup)),
                ("draft_kv_bytes", Json::Num(p.draft_kv_bytes as f64)),
                ("greedy_identical", Json::Bool(p.greedy_identical)),
            ]));
        }
        if check {
            // the headline receipt: at s=50 the speculative path must
            // strictly beat target-only decode in tokens/sec
            let s50 = cmp
                .points
                .iter()
                .find(|p| (p.sparsity - 0.5).abs() < 1e-9)
                .expect("s=50 point in the sweep");
            assert!(
                s50.spec_tokens_per_s > cmp.target_tokens_per_s,
                "speculative decode at s=50 ({:.0} tok/s) not above \
                 target-only ({:.0} tok/s)",
                s50.spec_tokens_per_s,
                cmp.target_tokens_per_s
            );
            let record = Json::obj(vec![
                ("bench", Json::Str("spec".into())),
                ("model", Json::Str(model.into())),
                ("prompt_len", Json::Num(cmp.prompt_len as f64)),
                ("max_new", Json::Num(cmp.max_new as f64)),
                ("draft_k", Json::Num(cmp.draft_k as f64)),
                ("target_tokens_per_s", Json::Num(cmp.target_tokens_per_s)),
                ("target_kv_bytes", Json::Num(cmp.target_kv_bytes as f64)),
                ("points", Json::Arr(points)),
            ]);
            let path = fasp::repo_root().join("BENCH_spec.json");
            std::fs::write(&path, record.pretty()).unwrap();
            println!("record → {}", path.display());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
