//! Bench: L3 hot paths in isolation — restoration solve (Cholesky vs
//! ADMM, the §3.3 comparison), host matmul, Wanda metric (host vs Pallas
//! artifact). Drives the §Perf iteration log in EXPERIMENTS.md.

use fasp::bench_support::Bencher;
use fasp::linalg::admm_restore;
use fasp::prune::metric::{wanda_scores_host, KernelMetric};
use fasp::prune::restore::restore_columns;
use fasp::runtime::Manifest;
use fasp::tensor::matmul::{matmul, matmul_bt};
use fasp::tensor::Tensor;
use fasp::util::rng::Rng;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Rng::new(1);

    // ---- restoration: closed form vs ADMM at the real shapes ----------
    for &(m, n) in &[(128usize, 512usize), (256, 1024)] {
        let w = Tensor::randn(&[m, n], 1.0, &mut rng);
        let x = Tensor::randn(&[512, n], 1.0, &mut rng);
        let g = matmul(&x.t(), &x);
        let kept: Vec<bool> = (0..n).map(|j| j % 5 != 0).collect();
        b.bench(&format!("restore/closed_form {m}x{n}"), || {
            let _ = restore_columns(&w, &g, &kept, 1e-2).unwrap();
        });
        let mut greg: Vec<f64> = g.data.iter().map(|&v| v as f64).collect();
        for i in 0..n {
            greg[i * n + i] += 1.0;
        }
        b.bench(&format!("restore/admm_32it {m}x{n}"), || {
            let _ = admm_restore(&w, &greg, &kept, 100.0, 32).unwrap();
        });
    }

    // ---- metric: host vs Pallas artifact --------------------------------
    let w = Tensor::randn(&[256, 1024], 1.0, &mut rng);
    let xnorm: Vec<f32> = (0..1024).map(|i| 0.1 + i as f32 * 1e-3).collect();
    b.bench("metric/wanda_host 256x1024", || {
        let _ = wanda_scores_host(&w, &xnorm);
    });
    if let Ok(manifest) = Manifest::load(&fasp::artifacts_dir()) {
        let km = KernelMetric::new(&manifest);
        b.bench("metric/wanda_pallas 256x1024", || {
            let _ = km.wanda_scores(&w, &xnorm).unwrap();
        });
    }

    // ---- host matmuls at restoration shapes -----------------------------
    let a = Tensor::randn(&[256, 1024], 1.0, &mut rng);
    let g = Tensor::randn(&[1024, 1024], 1.0, &mut rng);
    b.bench("matmul/256x1024x1024 (W*G)", || {
        let _ = matmul(&a, &g);
    });
    let x = Tensor::randn(&[512, 256], 1.0, &mut rng);
    let wt = Tensor::randn(&[1024, 256], 1.0, &mut rng);
    b.bench("matmul_bt/512x256->1024 (linear)", || {
        let _ = matmul_bt(&x, &wt);
    });
}
