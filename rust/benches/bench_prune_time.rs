//! Bench: Table 4 — pruning wall-time by method × model size, plus the
//! compact-export `repack` stage.
//! `cargo bench --bench bench_prune_time` (set FASP_BENCH_FAST=1 to
//! shrink; FASP_BENCH_CHECK=1 runs the fast matrix AND writes
//! BENCH_prune_time.json so CI can diff repack/prune regressions).
//! The paper's claim is the ordering FASP ≈ FLAP ≪ SliceGPT ≪
//! NASLLM/LLM-Pruner; the repack stage must stay a small fraction of the
//! prune time.

use fasp::bench_support::{fmt_s, Bencher};
use fasp::data::{Corpus, Dataset};
use fasp::model::Weights;
use fasp::prune::{prune, prune_compact, Method, PruneOpts};
use fasp::runtime::{Manifest, Session};
use fasp::util::json::Json;

fn main() {
    let manifest = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    let check = std::env::var("FASP_BENCH_CHECK").is_ok();
    let fast = check || std::env::var("FASP_BENCH_FAST").is_ok();
    let models: &[&str] = if fast { &["llama_tiny"] } else { &["llama_tiny", "llama_small"] };
    let mut b = Bencher::default();
    if check {
        b.min_samples = 3;
        b.budget_s = 0.5;
    }

    println!("# Table 4 analog — pruning time (20% sparsity)\n");
    let mut repack_frac = 0.0f64;
    for model in models {
        let session = Session::new(&manifest, model).unwrap();
        let spec = session.spec.clone();
        let ds = Dataset::new(Corpus::new(spec.vocab, 3), spec.batch, spec.seq, 4);
        let weights = Weights::init(&spec, 7);
        for method in Method::all() {
            let mut opts = PruneOpts::new(method, 0.20);
            opts.calib_batches = 2;
            opts.admm_iters = if fast { 8 } else { 32 };
            b.bench(&format!("{model}/{:?}", method), || {
                let _ = prune(&session, &weights, &ds, &opts).unwrap();
            });
        }
        // the repack stage in isolation: prune once, bench only the
        // physical slicing (the metric the BENCH record guards)
        let mut opts = PruneOpts::new(Method::Fasp, 0.20);
        opts.calib_batches = 2;
        let out = prune_compact(&session, &weights, &ds, &opts, "bench_repack").unwrap();
        repack_frac = out.report.phase("repack") / out.report.total_s.max(1e-9);
        let (pruned, mask) = (out.pruned, out.mask);
        b.bench(&format!("{model}/repack"), || {
            let _ = fasp::model::compact::compact_from_mask(&pruned, &mask, "bench_repack")
                .unwrap();
        });
    }

    println!("\n## summary (mean seconds)\n");
    for r in &b.results {
        println!("{:<40} {}", r.name, fmt_s(r.mean_s()));
    }
    println!("\nrepack fraction of last prune+repack run: {:.1}%", repack_frac * 100.0);

    // machine-readable record for regression diffing (check mode only, so
    // ad-hoc bench runs don't overwrite the CI record)
    if check {
        let record = Json::obj(vec![
            ("bench", Json::Str("prune_time".into())),
            ("fast", Json::Bool(fast)),
            ("repack_fraction", Json::Num(repack_frac)),
            (
                "mean_s",
                Json::Obj(
                    b.results
                        .iter()
                        .map(|r| (r.name.clone(), Json::Num(r.mean_s())))
                        .collect(),
                ),
            ),
        ]);
        let path = fasp::repo_root().join("BENCH_prune_time.json");
        std::fs::write(&path, record.pretty()).unwrap();
        println!("record → {}", path.display());
    }
}
