//! Bench: Table 4 — pruning wall-time by method × model size.
//! `cargo bench --bench bench_prune_time` (set FASP_BENCH_FAST=1 to
//! shrink). Reports per-method mean time on llama_{tiny,small} plus the
//! phase breakdown; the paper's claim is the ordering FASP ≈ FLAP ≪
//! SliceGPT ≪ NASLLM/LLM-Pruner.

use fasp::bench_support::{fmt_s, Bencher};
use fasp::data::{Corpus, Dataset};
use fasp::model::Weights;
use fasp::prune::{prune, Method, PruneOpts};
use fasp::runtime::{Manifest, ModelEngine};

fn main() {
    let manifest = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    let fast = std::env::var("FASP_BENCH_FAST").is_ok();
    let models: &[&str] = if fast { &["llama_tiny"] } else { &["llama_tiny", "llama_small"] };
    let mut b = Bencher::default();

    println!("# Table 4 analog — pruning time (20% sparsity)\n");
    for model in models {
        let engine = ModelEngine::new(&manifest, model).unwrap();
        let spec = engine.spec.clone();
        let ds = Dataset::new(Corpus::new(spec.vocab, 3), spec.batch, spec.seq, 4);
        let weights = Weights::init(&spec, 7);
        for method in Method::all() {
            let mut opts = PruneOpts::new(method, 0.20);
            opts.calib_batches = 2;
            opts.admm_iters = if fast { 8 } else { 32 };
            b.bench(&format!("{model}/{:?}", method), || {
                let _ = prune(&engine, &weights, &ds, &opts).unwrap();
            });
        }
    }

    println!("\n## summary (mean seconds)\n");
    for r in &b.results {
        println!("{:<40} {}", r.name, fmt_s(r.mean_s()));
    }
}
