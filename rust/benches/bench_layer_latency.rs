//! Bench: the structured-speedup claim — physically sliced decoder-layer
//! artifacts at sparsity 0–50%, end-to-end PJRT latency. Structured
//! pruning must yield real latency wins with no special hardware.

use fasp::eval::speed::layer_latency_sweep;
use fasp::runtime::Manifest;

fn main() {
    let manifest = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    let fast = std::env::var("FASP_BENCH_FAST").is_ok();
    let reps = if fast { 5 } else { 30 };
    let points = layer_latency_sweep(&manifest, reps).unwrap();
    println!("# Sliced decoder-layer latency (llama_small block)\n");
    println!(
        "{:<10} {:>8} {:>8} {:>12} {:>9}",
        "sparsity", "d_ff", "ov dims", "latency", "speedup"
    );
    for p in &points {
        println!(
            "{:<10} {:>8} {:>8} {:>10.3}ms {:>8.2}x",
            format!("{:.0}%", p.sparsity * 100.0),
            p.f_s,
            p.dk_s,
            p.mean_ms,
            p.speedup
        );
    }
    let last = points.last().unwrap();
    println!(
        "\n50% structured sparsity → {:.2}x layer speedup on CPU PJRT",
        last.speedup
    );
}
