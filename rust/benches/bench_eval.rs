//! Bench: evaluation-path throughput — fwd_loss tokens/sec per model,
//! capture cost per calibration batch, train_step time. These are the
//! denominators of every experiment's wall-time.

use fasp::bench_support::Bencher;
use fasp::data::{Corpus, Dataset};
use fasp::model::Weights;
use fasp::runtime::{Manifest, Session};

fn main() {
    let manifest = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    let fast = std::env::var("FASP_BENCH_FAST").is_ok();
    let models: &[&str] = if fast {
        &["llama_tiny"]
    } else {
        &["opt_tiny", "llama_tiny", "llama_small", "llama_medium"]
    };
    let mut b = Bencher::default();

    for model in models {
        let session = Session::new(&manifest, model).unwrap();
        let spec = session.spec.clone();
        let w = Weights::init(&spec, 5);
        let ds = Dataset::new(Corpus::new(spec.vocab, 2), spec.batch, spec.seq, 2);
        let batch = ds.train_batch(0);
        let tokens = spec.batch * spec.seq;
        let params = session.pack(&w.packed).unwrap();

        b.bench(&format!("{model}/fwd_loss"), || {
            let _ = session.fwd_loss(&params, &batch.tokens, &batch.targets).unwrap();
        });
        println!("  -> {:.0} tokens/s", b.last_throughput(tokens));

        b.bench(&format!("{model}/capture"), || {
            let _ = session.capture(&params, &[batch.tokens.clone()]).unwrap();
        });

        let mut state = session.init_train(&w.packed).unwrap();
        b.bench(&format!("{model}/train_step"), || {
            let _ = session
                .train_step(&mut state, &batch.tokens, &batch.targets, 1.0, 1e-3)
                .unwrap();
        });
        println!("  -> {:.0} tokens/s (train)", b.last_throughput(tokens));
    }
}
