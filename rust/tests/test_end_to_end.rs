//! End-to-end smoke at test scale: train → prune → eval → zero-shot on
//! the tiny model through the full three-layer stack, asserting the
//! paper's qualitative ordering where it is robust. Requires
//! `make artifacts`. (The full-size driver is
//! `examples/train_prune_eval.rs`.)

use fasp::data::tasks::{TaskKind, TaskSuite};
use fasp::data::{Corpus, Dataset};
use fasp::eval::{eval_suite, perplexity};
use fasp::prune::{prune, Method, PruneOpts};
use fasp::runtime::{Manifest, Session};
use fasp::train::{train, TrainOpts};

#[test]
fn train_prune_eval_zero_shot_pipeline() {
    let model = "llama_tiny";
    let manifest = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    let session = Session::new(&manifest, model).unwrap();
    let spec = session.spec.clone();

    // ---- train briefly (enough to beat the random-model baseline) -----
    let opts = TrainOpts { steps: 120, lr: 8e-3, warmup: 10, log_every: 1000, seed: 1 };
    let corpus = Corpus::new(spec.vocab, 42 ^ spec.vocab as u64);
    let dataset = Dataset::new(corpus, spec.batch, spec.seq, opts.steps + 8);
    let (weights, report) = train(&manifest, model, &dataset, &opts).unwrap();
    let first = report.losses.first().copied().unwrap();
    let last = report.losses.last().copied().unwrap();
    assert!(last < first - 0.8, "training too weak: {first} → {last}");

    // ---- perplexity sanity: trained ≪ random-token ppl -----------------
    let eval_b = dataset.valid_batches(4);
    let dense_ppl = perplexity(&session, &weights, &eval_b).unwrap();
    assert!(
        dense_ppl < spec.vocab as f64 * 0.5,
        "dense ppl {dense_ppl} vs vocab {}",
        spec.vocab
    );

    // ---- prune 20% with FASP and magnitude -----------------------------
    let mut fasp_opts = PruneOpts::new(Method::Fasp, 0.20);
    fasp_opts.calib_batches = 3;
    let (w_fasp, mask, rep) = prune(&session, &weights, &dataset, &fasp_opts).unwrap();
    assert!((rep.achieved_sparsity - 0.20).abs() < 0.04);
    mask.validate(&spec).unwrap();

    let mut mag_opts = PruneOpts::new(Method::Magnitude, 0.20);
    mag_opts.calib_batches = 3;
    let (w_mag, _, _) = prune(&session, &weights, &dataset, &mag_opts).unwrap();

    let ppl_fasp = perplexity(&session, &w_fasp, &eval_b).unwrap();
    let ppl_mag = perplexity(&session, &w_mag, &eval_b).unwrap();
    assert!(ppl_fasp.is_finite() && ppl_mag.is_finite());
    // the paper's core ordering: restoration+metric beats magnitude
    assert!(
        ppl_fasp <= ppl_mag * 1.02,
        "FASP ({ppl_fasp:.3}) worse than magnitude ({ppl_mag:.3})"
    );
    // pruning shouldn't destroy the model at 20%
    assert!(
        ppl_fasp < dense_ppl * 3.0,
        "FASP 20% destroyed the model: {dense_ppl:.2} → {ppl_fasp:.2}"
    );

    // ---- zero-shot: trained model beats chance on the easy suite -------
    let suite = TaskSuite::generate(&dataset.corpus, TaskKind::ArcES, 60, 7);
    let dense_acc = eval_suite(&session, &weights, &suite).unwrap().accuracy;
    assert!(
        dense_acc > 35.0,
        "trained model near chance on ARC-e-s: {dense_acc:.1}%"
    );
    let fasp_acc = eval_suite(&session, &w_fasp, &suite).unwrap().accuracy;
    assert!(fasp_acc > 25.0, "pruned model collapsed: {fasp_acc:.1}%");
}
