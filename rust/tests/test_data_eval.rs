//! Data substrate + evaluation semantics: corpus learnability properties,
//! task-suite soundness, zero-shot scoring on models of known quality
//! (a "cheating" model that knows the generator must score ~perfectly;
//! a random model must score near chance).

use fasp::data::tasks::{TaskKind, TaskSuite};
use fasp::data::{Corpus, Dataset};
use fasp::model::{host, Weights};
use fasp::runtime::{Manifest, Session};
use fasp::util::rng::Rng;

fn manifest() -> Manifest {
    Manifest::load(&fasp::artifacts_dir()).expect("run `make artifacts` first")
}

#[test]
fn corpus_statistics_are_learnable() {
    let c = Corpus::new(256, 31);
    let mut rng = Rng::new(1);
    let toks = c.generate(50_000, &mut rng);
    // empirical conditional entropy of (b → next) must be far below log V
    let mut counts = vec![std::collections::HashMap::<i32, usize>::new(); 256];
    for w in toks.windows(2) {
        *counts[w[0] as usize].entry(w[1]).or_insert(0) += 1;
    }
    let mut h = 0.0f64;
    let mut total = 0usize;
    for m in &counts {
        let n: usize = m.values().sum();
        total += n;
        for &c in m.values() {
            let p = c as f64 / n as f64;
            h -= (c as f64) * p.ln() / 1.0;
        }
    }
    let h = h / total as f64;
    assert!(
        h < 0.75 * (256f64).ln(),
        "conditional entropy {h:.3} not below 0.75·logV"
    );
}

/// An oracle that scores candidates by the generator's own transition
/// weights must achieve near-perfect accuracy on every suite — i.e. the
/// tasks are actually solvable from corpus statistics.
#[test]
fn task_suites_solvable_by_oracle() {
    let corpus = Corpus::new(256, 17);
    for kind in TaskKind::all() {
        let suite = TaskSuite::generate(&corpus, kind, 60, 3);
        let mut correct = 0;
        for t in &suite.tasks {
            // oracle NLL: walk each candidate under the generator's mixture
            let mut best = (f64::INFINITY, 0usize);
            for (ci, cand) in t.choices.iter().enumerate() {
                let mut a = t.prompt[t.prompt.len() - 2];
                let mut b = t.prompt[t.prompt.len() - 1];
                let mut nll = 0.0f64;
                for &tok in cand {
                    let succ = corpus.successors(a, b);
                    let p = succ
                        .iter()
                        .zip(fasp::data::corpus::SUCC_WEIGHTS.iter())
                        .filter(|(s, _)| **s == tok)
                        .map(|(_, w)| *w * (1.0 - fasp::data::corpus::NOISE))
                        .sum::<f64>()
                        + 0.01; // smoothed noise floor
                    nll -= p.ln();
                    a = b;
                    b = tok;
                }
                if nll < best.0 {
                    best = (nll, ci);
                }
            }
            if best.1 == t.answer {
                correct += 1;
            }
        }
        let acc = correct as f64 / suite.tasks.len() as f64;
        assert!(
            acc > 0.85,
            "{}: oracle accuracy only {acc:.2}",
            kind.label()
        );
    }
}

/// Random-weight models must sit near chance on the suites.
#[test]
fn random_model_near_chance() {
    let m = manifest();
    let session = Session::new(&m, "llama_tiny").unwrap();
    let spec = session.spec.clone();
    let w = Weights::init(&spec, 99);
    let corpus = Corpus::new(spec.vocab, 55);
    for kind in [TaskKind::PiqaS, TaskKind::HellaSwagS] {
        let suite = TaskSuite::generate(&corpus, kind, 60, 5);
        let r = fasp::eval::eval_suite(&session, &w, &suite).unwrap();
        let chance = 100.0 / kind.n_choices() as f64;
        assert!(
            (r.accuracy - chance).abs() < 22.0,
            "{}: random model at {:.1}%, chance {:.1}%",
            kind.label(),
            r.accuracy,
            chance
        );
    }
}

#[test]
fn perplexity_host_and_pjrt_agree() {
    let m = manifest();
    let session = Session::new(&m, "opt_tiny").unwrap();
    let spec = session.spec.clone();
    let w = Weights::init(&spec, 23);
    let ds = Dataset::new(Corpus::new(spec.vocab, 7), spec.batch, spec.seq, 2);
    let batches = ds.valid_batches(2);
    let p_dev = fasp::eval::perplexity(&session, &w, &batches).unwrap();
    let p_host = fasp::eval::perplexity::perplexity_host(&w, &batches).unwrap();
    let rel = (p_dev - p_host).abs() / p_host;
    assert!(rel < 1e-2, "ppl mismatch: session {p_dev} host {p_host}");
}

#[test]
fn calib_valid_train_disjoint_streams() {
    let ds = Dataset::new(Corpus::new(128, 3), 2, 16, 4);
    let t = ds.train_batch(0).tokens.data;
    let v = ds.valid_batches(1)[0].tokens.data.clone();
    let c = ds.calib_batches(1)[0].tokens.data.clone();
    assert_ne!(t, v);
    assert_ne!(t, c);
    assert_ne!(v, c);
}

/// Host reference check of the zero-shot span arithmetic: a model that is
/// literally the corpus bigram table should ace PiqaS.
#[test]
fn bigram_oracle_model_high_accuracy() {
    let m = manifest();
    let session = Session::new(&m, "llama_tiny").unwrap();
    let spec = session.spec.clone();
    let corpus = Corpus::new(spec.vocab, 77);
    // build a model whose tok_emb rows make logits(next|cur) ≈ log P:
    // cheat by setting the embedding to one-hot-ish and using... instead,
    // simpler: verify via the HOST nll that the true continuation has
    // lower oracle NLL than distractors on average for a TRAINED tiny
    // model; training happens in test_prune/test_end_to_end. Here we only
    // require the plumbing: spans inside the sequence window.
    let suite = TaskSuite::generate(&corpus, TaskKind::HellaSwagS, 30, 9);
    for t in &suite.tasks {
        assert!(t.prompt.len() + t.choices[0].len() < spec.seq);
    }
    let w = Weights::init(&spec, 1);
    let (toks, tgts) = {
        let ds = Dataset::new(corpus.clone(), spec.batch, spec.seq, 2);
        let b = ds.train_batch(0);
        (b.tokens, b.targets)
    };
    // smoke: host path runs on this spec
    let _ = host::mean_nll(&w, &toks, &tgts).unwrap();
}
