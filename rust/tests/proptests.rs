//! Property-based tests over the pruning-math invariants, via the
//! in-repo quickcheck substrate (no artifacts needed).

use fasp::linalg::cholesky::cholesky;
use fasp::model::mask::{kept_indices, pruned_indices};
use fasp::prune::metric::{lowest_k, wanda_scores_host};
use fasp::prune::restore::{recon_objective, restore_columns};
use fasp::prune::structure::{plan, rope_pairs, units};
use fasp::runtime::manifest::ModelSpec;
use fasp::tensor::matmul::{matmul, matmul_at, matmul_bt};
use fasp::tensor::pack::{matmul_packed, PackedMat};
use fasp::tensor::ops::{
    col_abs_sum, gather_cols, gather_elems, gather_rows, scatter_cols, scatter_rows,
    zero_cols,
};
use fasp::tensor::Tensor;
use fasp::util::quickcheck::{forall, Gen};

fn rand_tensor(g: &mut Gen, r: usize, c: usize) -> Tensor {
    Tensor::new(
        vec![r, c],
        (0..r * c).map(|_| g.f32_in(-2.0..2.0)).collect(),
    )
}

/// Restoration optimality: for random (W, X, mask), the closed form never
/// loses to plain zeroing on the least-squares objective.
#[test]
fn prop_restore_at_least_as_good_as_zeroing() {
    forall(40, 101, |g| {
        let m = g.usize_in(1..10);
        let n = g.usize_in(2..24);
        let s = n + g.usize_in(1..40);
        let w = rand_tensor(g, m, n);
        let x = rand_tensor(g, s, n);
        let gram = matmul(&x.t(), &x);
        let mut kept = vec![true; n];
        let n_prune = g.usize_in(1..n.max(2));
        for _ in 0..n_prune {
            let j = g.usize_in(0..n);
            kept[j] = false;
        }
        if kept.iter().all(|&k| !k) {
            kept[0] = true;
        }
        let restored = match restore_columns(&w, &gram, &kept, 1e-6) {
            Ok(r) => r,
            Err(e) => return (false, format!("restore failed: {e}")),
        };
        let mut zeroed = w.clone();
        zero_cols(&mut zeroed, &pruned_indices(&kept));
        let o_r = recon_objective(&restored, &w, &gram);
        let o_z = recon_objective(&zeroed, &w, &gram);
        (
            o_r <= o_z + 1e-4 * o_z.abs().max(1.0),
            format!("restored {o_r} worse than zeroed {o_z} (m={m},n={n})"),
        )
    });
}

/// Restored pruned columns are exactly zero; kept support is preserved.
#[test]
fn prop_restore_support() {
    forall(40, 202, |g| {
        let m = g.usize_in(1..8);
        let n = g.usize_in(2..20);
        let s = n + 8;
        let w = rand_tensor(g, m, n);
        let x = rand_tensor(g, s, n);
        let gram = matmul(&x.t(), &x);
        let kept: Vec<bool> = (0..n).map(|_| g.bool()).collect();
        let kept = if kept.iter().all(|&k| !k) {
            let mut k2 = kept;
            k2[0] = true;
            k2
        } else {
            kept
        };
        let restored = restore_columns(&w, &gram, &kept, 1e-4).unwrap();
        for i in 0..m {
            for j in 0..n {
                if !kept[j] && restored.at2(i, j) != 0.0 {
                    return (false, format!("support violated at ({i},{j})"));
                }
            }
        }
        (true, String::new())
    });
}

/// Wanda scores scale linearly with the activation norms.
#[test]
fn prop_wanda_linear_in_xnorm() {
    forall(60, 303, |g| {
        let m = g.usize_in(1..12);
        let n = g.usize_in(1..16);
        let w = rand_tensor(g, m, n);
        let xn: Vec<f32> = (0..n).map(|_| g.f32_in(0.0..3.0)).collect();
        let c = g.f32_in(0.1..5.0);
        let s1 = wanda_scores_host(&w, &xn);
        let xn2: Vec<f32> = xn.iter().map(|v| v * c).collect();
        let s2 = wanda_scores_host(&w, &xn2);
        for j in 0..n {
            if (s2[j] - c * s1[j]).abs() > 1e-3 * s1[j].abs().max(1.0) {
                return (false, format!("nonlinear at {j}: {} vs {}", s2[j], c * s1[j]));
            }
        }
        (true, String::new())
    });
}

/// lowest_k actually returns the k smallest, and is a subset of 0..n.
#[test]
fn prop_lowest_k_correct() {
    forall(80, 404, |g| {
        let scores = g.vec_f32(1..64, -10.0..10.0);
        let k = g.usize_in(0..scores.len() + 1);
        let picked = lowest_k(&scores, k);
        if picked.len() != k.min(scores.len()) {
            return (false, "wrong count".into());
        }
        let max_picked = picked
            .iter()
            .map(|&i| scores[i])
            .fold(f32::NEG_INFINITY, f32::max);
        let unpicked_min = (0..scores.len())
            .filter(|i| !picked.contains(i))
            .map(|i| scores[i])
            .fold(f32::INFINITY, f32::min);
        (
            picked.is_empty() || max_picked <= unpicked_min + 1e-6,
            format!("picked max {max_picked} > unpicked min {unpicked_min}"),
        )
    });
}

/// gather→scatter of columns is the identity on the gathered set.
#[test]
fn prop_gather_scatter_roundtrip() {
    forall(60, 505, |g| {
        let r = g.usize_in(1..10);
        let c = g.usize_in(1..16);
        let t = rand_tensor(g, r, c);
        let cols: Vec<usize> = (0..c).filter(|_| g.bool()).collect();
        if cols.is_empty() {
            return (true, String::new());
        }
        let gathered = gather_cols(&t, &cols);
        let mut out = Tensor::zeros(&[r, c]);
        scatter_cols(&mut out, &cols, &gathered);
        for i in 0..r {
            for (ci, &j) in cols.iter().enumerate() {
                if out.at2(i, j) != gathered.at2(i, ci) {
                    return (false, format!("mismatch at ({i},{j})"));
                }
            }
        }
        (true, String::new())
    });
}

/// matmul_bt(A, B) == matmul(A, Bᵀ) for random shapes.
#[test]
fn prop_matmul_bt_equiv() {
    forall(40, 606, |g| {
        let m = g.usize_in(1..12);
        let k = g.usize_in(1..12);
        let n = g.usize_in(1..12);
        let a = rand_tensor(g, m, k);
        let b = rand_tensor(g, n, k);
        let c1 = matmul_bt(&a, &b);
        let c2 = matmul(&a, &b.t());
        let d = c1.max_abs_diff(&c2);
        (d < 1e-3, format!("diff {d} (m={m},k={k},n={n})"))
    });
}

/// Pack/unpack roundtrips bit-exactly in both orientations for random
/// shapes — a pack is a pure relayout.
#[test]
fn prop_pack_roundtrip() {
    forall(60, 611, |g| {
        let r = g.usize_in(1..16);
        let c = g.usize_in(1..16);
        let w = rand_tensor(g, r, c);
        let back = PackedMat::pack_bt(&w).unpack();
        if back.shape != w.shape
            || !back.data.iter().zip(&w.data).all(|(x, y)| x.to_bits() == y.to_bits())
        {
            return (false, format!("bt roundtrip drifted ({r}x{c})"));
        }
        let back = PackedMat::pack_ab(&w).unpack();
        let ok = back.shape == w.shape
            && back.data.iter().zip(&w.data).all(|(x, y)| x.to_bits() == y.to_bits());
        (ok, format!("ab roundtrip drifted ({r}x{c})"))
    });
}

/// matmul_packed over a packed weight is bit-identical to the unpacked
/// product in both orientations, including planted exact zeros (the
/// skip path) and m == 1 (the decode shape).
#[test]
fn prop_matmul_packed_equiv() {
    forall(60, 612, |g| {
        let m = g.usize_in(1..8);
        let k = g.usize_in(1..12);
        let n = g.usize_in(1..12);
        let mut a = rand_tensor(g, m, k);
        a.data[g.usize_in(0..m * k)] = 0.0;
        let w = rand_tensor(g, n, k);
        let c1 = matmul_packed(&a, &PackedMat::pack_bt(&w));
        let c2 = matmul_bt(&a, &w);
        if !c1.data.iter().zip(&c2.data).all(|(x, y)| x.to_bits() == y.to_bits()) {
            return (false, format!("bt packed diverged (m={m},k={k},n={n})"));
        }
        let b = rand_tensor(g, k, n);
        let c1 = matmul_packed(&a, &PackedMat::pack_ab(&b));
        let c2 = matmul(&a, &b);
        let ok = c1.data.iter().zip(&c2.data).all(|(x, y)| x.to_bits() == y.to_bits());
        (ok, format!("ab packed diverged (m={m},k={k},n={n})"))
    });
}

/// matmul_at(A, B) is bit-identical to matmul(Aᵀ, B) for random shapes
/// with planted zeros (the transpose-free Gram/backward contract).
#[test]
fn prop_matmul_at_equiv() {
    forall(60, 613, |g| {
        let r = g.usize_in(1..14);
        let m = g.usize_in(1..10);
        let n = g.usize_in(1..10);
        let mut a = rand_tensor(g, r, m);
        a.data[g.usize_in(0..r * m)] = 0.0;
        let b = rand_tensor(g, r, n);
        let c1 = matmul_at(&a, &b);
        let c2 = matmul(&a.t(), &b);
        let ok = c1.shape == c2.shape
            && c1.data.iter().zip(&c2.data).all(|(x, y)| x.to_bits() == y.to_bits());
        (ok, format!("matmul_at diverged (r={r},m={m},n={n})"))
    });
}

/// Cholesky solve residual ‖Ax − b‖ is small for random SPD systems.
#[test]
fn prop_cholesky_residual() {
    forall(40, 707, |g| {
        let n = g.usize_in(1..24);
        let s = n + 4;
        let x = rand_tensor(g, s, n);
        let gram = matmul(&x.t(), &x);
        let mut a: Vec<f64> = gram.data.iter().map(|&v| v as f64).collect();
        for i in 0..n {
            a[i * n + i] += 0.5;
        }
        let b: Vec<f64> = (0..n).map(|_| g.f32_in(-3.0..3.0) as f64).collect();
        let f = match cholesky(&a, n) {
            Ok(f) => f,
            Err(e) => return (false, format!("cholesky failed: {e}")),
        };
        let mut sol = b.clone();
        f.solve_in_place(&mut sol);
        let mut worst = 0.0f64;
        for i in 0..n {
            let mut ax = 0.0;
            for j in 0..n {
                ax += a[i * n + j] * sol[j];
            }
            worst = worst.max((ax - b[i]).abs());
        }
        (worst < 1e-6, format!("residual {worst} at n={n}"))
    });
}

/// Structure plan: achieved fraction equals target for any sparsity/fam.
#[test]
fn prop_plan_exact() {
    forall(60, 808, |g| {
        let d = 8 * g.usize_in(1..32);
        let h = 4;
        let f = d * g.usize_in(2..5);
        let spec = ModelSpec {
            name: "p".into(),
            family: if g.bool() { "opt" } else { "llama" }.into(),
            d_model: d,
            n_heads: h,
            n_layers: g.usize_in(1..8),
            d_ff: f,
            vocab: 64,
            seq: 16,
            batch: 2,
            params: vec![],
            layer_dims: vec![],
        };
        let target = g.f32_in(0.01..0.6) as f64;
        let p = plan(&spec, target, g.bool());
        let (ffn_c, ov_c, qk_c) = fasp::prune::structure::unit_costs(&spec);
        let removed = (p.ffn_ratio * f as f64 * ffn_c as f64
            + p.ov_ratio * d as f64 * ov_c as f64
            + p.qk_ratio * d as f64 * qk_c as f64)
            * spec.n_layers as f64;
        let frac = removed / fasp::model::mask::prunable_params(&spec) as f64;
        // ratios clamp at 1.0; below the clamp the plan must be exact
        let exact = p.ffn_ratio < 1.0 - 1e-12;
        (
            !exact || (frac - target).abs() < 1e-9,
            format!("target {target} achieved {frac}"),
        )
    });
}

/// RoPE pairs partition [0, d) for any valid (d, h) with even head dim.
#[test]
fn prop_rope_pairs_partition() {
    forall(60, 909, |g| {
        let h = g.usize_in(1..8);
        let dh = 2 * g.usize_in(1..16);
        let d = h * dh;
        let pairs = rope_pairs(d, h);
        let mut seen = vec![false; d];
        for (a, b) in &pairs {
            if *a >= d || *b >= d || seen[*a] || seen[*b] {
                return (false, format!("bad pair ({a},{b}) d={d}"));
            }
            seen[*a] = true;
            seen[*b] = true;
        }
        (seen.iter().all(|&s| s), format!("not a partition d={d} h={h}"))
    });
}

/// kept/pruned indices always partition the mask.
#[test]
fn prop_mask_partition() {
    forall(80, 1010, |g| {
        let n = g.usize_in(1..128);
        let mask: Vec<bool> = (0..n).map(|_| g.bool()).collect();
        let k = kept_indices(&mask);
        let p = pruned_indices(&mask);
        if k.len() + p.len() != n {
            return (false, "not a partition".into());
        }
        for &i in &k {
            if !mask[i] {
                return (false, "kept contains pruned".into());
            }
        }
        (true, String::new())
    });
}

/// units() never exceeds n and is monotone in the ratio.
#[test]
fn prop_units_monotone() {
    forall(80, 1111, |g| {
        let n = g.usize_in(1..2048);
        let r1 = g.f32_in(0.0..1.0) as f64;
        let r2 = (r1 + g.f32_in(0.0..0.5) as f64).min(1.0);
        let u1 = units(n, r1);
        let u2 = units(n, r2);
        (u1 <= u2 && u2 <= n, format!("n={n} r1={r1} r2={r2}"))
    });
}

/// gather_rows shape/content invariants + scatter_rows inverse.
#[test]
fn prop_gather_scatter_rows_roundtrip() {
    forall(60, 1212, |g| {
        let r = g.usize_in(1..12);
        let c = g.usize_in(1..16);
        let t = rand_tensor(g, r, c);
        let rows: Vec<usize> = (0..r).filter(|_| g.bool()).collect();
        let gathered = gather_rows(&t, &rows);
        if gathered.shape != vec![rows.len(), c] {
            return (false, format!("bad shape {:?}", gathered.shape));
        }
        for (k, &i) in rows.iter().enumerate() {
            for j in 0..c {
                if gathered.at2(k, j) != t.at2(i, j) {
                    return (false, format!("content mismatch at ({k},{j})"));
                }
            }
        }
        if rows.is_empty() {
            return (true, String::new());
        }
        let mut out = Tensor::zeros(&[r, c]);
        scatter_rows(&mut out, &rows, &gathered);
        for (k, &i) in rows.iter().enumerate() {
            for j in 0..c {
                if out.at2(i, j) != gathered.at2(k, j) {
                    return (false, format!("scatter mismatch at ({i},{j})"));
                }
            }
        }
        (true, String::new())
    });
}

/// gather_elems matches direct indexing and preserves order.
#[test]
fn prop_gather_elems_indexing() {
    forall(80, 1313, |g| {
        let n = g.usize_in(1..64);
        let data = g.vec_f32(n..n + 1, -5.0..5.0);
        let t = Tensor::new(vec![n], data);
        let idx: Vec<usize> = (0..n).filter(|_| g.bool()).collect();
        let out = gather_elems(&t, &idx);
        if out.shape != vec![idx.len()] {
            return (false, "bad shape".into());
        }
        for (k, &i) in idx.iter().enumerate() {
            if out.data[k] != t.data[i] {
                return (false, format!("mismatch at {k}"));
            }
        }
        (true, String::new())
    });
}

/// Gathers never introduce NaN/Inf: every output element is drawn
/// verbatim from the (finite) input.
#[test]
fn prop_gathers_introduce_no_nan() {
    forall(60, 1414, |g| {
        let r = g.usize_in(1..10);
        let c = g.usize_in(1..14);
        let t = rand_tensor(g, r, c);
        let cols: Vec<usize> = (0..c).filter(|_| g.bool()).collect();
        let rows: Vec<usize> = (0..r).filter(|_| g.bool()).collect();
        let gc = gather_cols(&t, &cols);
        let gr = gather_rows(&t, &rows);
        let ok = gc.data.iter().all(|x| x.is_finite())
            && gr.data.iter().all(|x| x.is_finite());
        (ok, "non-finite value out of a finite input".into())
    });
}
