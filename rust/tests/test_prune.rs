//! Integration: the full pruning pipeline (every method) on trained-ish
//! tiny models — the paper's qualitative claims at small scale:
//! restoration helps, coupling helps, Q/K pruning hurts, sparsity
//! accounting is honest. Requires `make artifacts`.

use fasp::data::{Corpus, Dataset};
use fasp::eval::perplexity;
use fasp::model::Weights;
use fasp::prune::{self, Method, PruneOpts};
use fasp::runtime::{Manifest, Session};

fn manifest() -> Manifest {
    Manifest::load(&fasp::artifacts_dir()).expect("run `make artifacts` first")
}

/// Train a quick llama_tiny once per process for the pruning tests.
fn quick_trained(m: &Manifest, model: &str, steps: usize) -> (Weights, Dataset) {
    let session = Session::new(m, model).unwrap();
    let spec = session.spec.clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 13), spec.batch, spec.seq, steps + 4);
    let init = Weights::init(&spec, 4242);
    let mut state = session.init_train(&init.packed).unwrap();
    for step in 0..steps {
        let b = ds.train_batch(step);
        session
            .train_step(&mut state, &b.tokens, &b.targets, (step + 1) as f32, 8e-3)
            .unwrap();
    }
    let packed = session.train_params(&state).unwrap();
    let mut w = Weights::zeros(&spec);
    w.packed = packed;
    (w, ds)
}

fn ppl(m: &Manifest, model: &str, w: &Weights, ds: &Dataset) -> f64 {
    let session = Session::new(m, model).unwrap();
    perplexity(&session, w, &ds.valid_batches(4)).unwrap()
}

#[test]
fn every_method_runs_and_reports_sparsity() {
    let m = manifest();
    let model = "llama_tiny";
    let (w, ds) = quick_trained(&m, model, 60);
    let session = Session::new(&m, model).unwrap();
    let dense_ppl = ppl(&m, model, &w, &ds);

    for method in Method::all() {
        let mut opts = PruneOpts::new(method, 0.20);
        opts.calib_batches = 2;
        opts.admm_iters = 12;
        let (pruned, mask, report) =
            prune::prune(&session, &w, &ds, &opts).unwrap_or_else(|e| {
                panic!("{method:?} failed: {e:#}")
            });
        // sparsity within tolerance of target (floor rounding loses a bit)
        assert!(
            (report.achieved_sparsity - 0.20).abs() < 0.05,
            "{method:?}: achieved {:.3}",
            report.achieved_sparsity
        );
        assert!(report.total_s > 0.0);
        mask.validate(&session.spec).unwrap();
        // pruned model still evaluates to something finite & sane
        let p = ppl(&m, model, &pruned, &ds);
        assert!(p.is_finite() && p > 1.0, "{method:?} ppl {p}");
        assert!(
            p < dense_ppl * 50.0,
            "{method:?} destroyed the model: dense {dense_ppl:.2} → {p:.2}"
        );
        // weights actually changed
        assert!(pruned.packed.max_abs_diff(&w.packed) > 1e-6, "{method:?}");
    }
}

#[test]
fn restoration_improves_over_plain_zeroing() {
    let m = manifest();
    let model = "llama_tiny";
    let (w, ds) = quick_trained(&m, model, 80);
    let session = Session::new(&m, model).unwrap();

    let mut with = PruneOpts::new(Method::Fasp, 0.30);
    with.calib_batches = 3;
    let mut without = with.clone();
    without.restore = false;

    let (wr, _, _) = prune::prune(&session, &w, &ds, &with).unwrap();
    let (wz, _, _) = prune::prune(&session, &w, &ds, &without).unwrap();
    let ppl_restored = ppl(&m, model, &wr, &ds);
    let ppl_zeroed = ppl(&m, model, &wz, &ds);
    assert!(
        ppl_restored < ppl_zeroed + 1e-9,
        "restoration did not help: {ppl_restored:.3} vs {ppl_zeroed:.3}"
    );
}

#[test]
fn qk_pruning_hurts_more_than_default() {
    let m = manifest();
    let model = "opt_tiny";
    let (w, ds) = quick_trained(&m, model, 80);
    let session = Session::new(&m, model).unwrap();

    let mut default = PruneOpts::new(Method::Fasp, 0.30);
    default.calib_batches = 3;
    let mut qk = default.clone();
    qk.prune_qk = true;

    let (wd, _, rd) = prune::prune(&session, &w, &ds, &default).unwrap();
    let (wq, _, rq) = prune::prune(&session, &w, &ds, &qk).unwrap();
    // equal global sparsity by construction
    assert!((rd.achieved_sparsity - rq.achieved_sparsity).abs() < 0.03);
    let ppl_default = ppl(&m, model, &wd, &ds);
    let ppl_qk = ppl(&m, model, &wq, &ds);
    assert!(
        ppl_default <= ppl_qk * 1.05,
        "Q/K pruning unexpectedly better: default {ppl_default:.3} vs qk {ppl_qk:.3}"
    );
}

#[test]
fn deeper_sparsity_monotonically_degrades() {
    let m = manifest();
    let model = "llama_tiny";
    let (w, ds) = quick_trained(&m, model, 80);
    let session = Session::new(&m, model).unwrap();
    let mut prev = ppl(&m, model, &w, &ds);
    for &s in &[0.1, 0.3, 0.5] {
        let mut opts = PruneOpts::new(Method::Fasp, s);
        opts.calib_batches = 2;
        let (pw, _, _) = prune::prune(&session, &w, &ds, &opts).unwrap();
        let p = ppl(&m, model, &pw, &ds);
        // allow small non-monotonicity at low sparsity (restoration noise)
        assert!(
            p > prev * 0.9,
            "ppl dropped hard with more sparsity: {prev:.3} → {p:.3} at s={s}"
        );
        prev = p;
    }
}

#[test]
fn sequential_mode_runs() {
    let m = manifest();
    let model = "llama_tiny";
    let (w, ds) = quick_trained(&m, model, 40);
    let session = Session::new(&m, model).unwrap();
    let mut opts = PruneOpts::new(Method::Fasp, 0.2);
    opts.calib_batches = 2;
    opts.sequential = true;
    let (pw, _, report) = prune::prune(&session, &w, &ds, &opts).unwrap();
    assert!(ppl(&m, model, &pw, &ds).is_finite());
    // sequential re-captures per layer → capture phase dominates
    assert!(report.phase("capture") > 0.0);
}

#[test]
fn flap_compensates_bias() {
    let m = manifest();
    let model = "llama_tiny";
    let (w, ds) = quick_trained(&m, model, 60);
    let session = Session::new(&m, model).unwrap();
    let mut opts = PruneOpts::new(Method::Flap, 0.3);
    opts.calib_batches = 2;
    let (pw, _, _) = prune::prune(&session, &w, &ds, &opts).unwrap();
    // the compensation biases must now be non-zero somewhere
    let mut nonzero = false;
    for l in 0..session.spec.n_layers {
        let b = pw.get_l(l, "b_down").unwrap();
        if b.data.iter().any(|&x| x != 0.0) {
            nonzero = true;
        }
    }
    assert!(nonzero, "FLAP did not write compensation biases");
}

/// Round trip through the pipeline's export stage: prune → repack →
/// compact forward parity with the masked model, repack wall-time
/// accounted, and a sparsity-0 export is bit-identical.
#[test]
fn compact_export_round_trip_from_pipeline() {
    let m = manifest();
    let model = "llama_tiny";
    let (w, ds) = quick_trained(&m, model, 40);
    let session = Session::new(&m, model).unwrap();

    let mut opts = PruneOpts::new(Method::Fasp, 0.2);
    opts.calib_batches = 2;
    let out = prune::prune_compact(&session, &w, &ds, &opts, "llama_tiny_pr").unwrap();
    assert!(out.report.phase("repack") > 0.0, "repack phase missing from report");
    assert!(out.compact.spec.n_params_elems() < session.spec.n_params_elems());

    let b = ds.train_batch(0);
    let (nll_masked, _) =
        fasp::model::host::forward_nll(&out.pruned, &b.tokens, &b.targets, false).unwrap();
    let (nll_compact, _) =
        fasp::model::host::forward_nll(&out.compact.weights, &b.tokens, &b.targets, false)
            .unwrap();
    let diff = nll_masked.max_abs_diff(&nll_compact);
    assert!(diff < 1e-5, "masked vs compact forward diff {diff}");

    // sparsity-0 export: identity
    let full = fasp::model::PruneMask::full(&session.spec);
    let cm0 = fasp::model::compact::compact_from_mask(&w, &full, "llama_tiny_id").unwrap();
    assert_eq!(cm0.weights.packed, w.packed, "sparsity-0 export not bit-identical");
}
