//! Packed operator plan contract: every entry and decode path over a
//! `Session::pack` plan (pre-packed linear weights + tied head) is
//! **bit-identical** to the unpacked per-call-transpose path, on both
//! backends and at pool widths {1, 2, 8} — packing is a latency
//! decision, never a numerics one. Plus: pack-cache coverage and
//! pool-width-independent pack bytes. The session-level tests require
//! `make artifacts`; the gradcol identity runs on toy specs.

use fasp::data::{Corpus, Dataset};
use fasp::model::compact::build_params;
use fasp::model::weights::linear_shorts;
use fasp::model::{host, host_grad, PackCache, Weights};
use fasp::runtime::manifest::LayerDims;
use fasp::runtime::{Backend, HostBackend, Manifest, ModelSpec, Session, ThreadedHostBackend};
use fasp::tensor::IntTensor;
use fasp::util::pool;
use fasp::util::rng::Rng;
use std::sync::Arc;

fn manifest() -> Manifest {
    Manifest::load(&fasp::artifacts_dir()).expect("run `make artifacts` first")
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Session entries run over the packed plan; the host reference runs
/// unpacked on the serial pool. Bitwise equality across {1, 2, 8}
/// workers is the packed≡unpacked contract for fwd, capture AND gradcol.
#[test]
fn packed_entries_bit_identical_to_unpacked_reference() {
    let m = manifest();
    for model in ["opt_tiny", "llama_tiny"] {
        let spec = m.model(model).unwrap().clone();
        let w = Weights::init(&spec, 71);
        let ds = Dataset::new(Corpus::new(spec.vocab, 7), spec.batch, spec.seq, 2);
        let b = ds.train_batch(0);

        // unpacked references, serial ambient pool, no session involved
        let (nll_ref, caps_ref) = {
            let _g = pool::enter(pool::serial());
            host::forward_nll(&w, &b.tokens, &b.targets, true).unwrap()
        };
        let grams_ref: Vec<_> = {
            let _g = pool::enter(pool::serial());
            caps_ref
                .iter()
                .map(|c| (host::host_gram(&c.ffn_h), host::host_gram(&c.attn_ctx)))
                .collect()
        };
        let scores_ref = {
            let _g = pool::enter(pool::serial());
            let (_, grad) = host_grad::loss_and_grad(&w, &b.tokens, &b.targets).unwrap();
            host_grad::taylor_scores(&w, &grad).unwrap()
        };

        for workers in [1usize, 2, 8] {
            let backend: Arc<dyn Backend> = if workers == 1 {
                Arc::new(HostBackend::new())
            } else {
                Arc::new(ThreadedHostBackend::new(workers))
            };
            let s = Session::with_backend(&m, model, backend).unwrap();
            let pp = s.pack(&w.packed).unwrap();
            assert!(pp.pack_count() > 0, "{model}: empty pack cache");
            assert!(pp.pack_bytes() > 0, "{model}: zero pack bytes");

            let o = s.fwd_loss(&pp, &b.tokens, &b.targets).unwrap();
            assert!(
                bits_eq(&o.tok_nll.data, &nll_ref.data),
                "{model} (w={workers}): packed fwd diverged from unpacked"
            );

            let stats = s.capture(&pp, &[b.tokens.clone()]).unwrap();
            for (l, (ls, (g_ffn, g_attn))) in
                stats.layers.iter().zip(&grams_ref).enumerate()
            {
                assert!(
                    bits_eq(&ls.g_ffn.data, &g_ffn.data),
                    "{model} (w={workers}) layer {l}: packed capture g_ffn diverged"
                );
                assert!(
                    bits_eq(&ls.g_attn.data, &g_attn.data),
                    "{model} (w={workers}) layer {l}: packed capture g_attn diverged"
                );
            }

            let g = s
                .gradcol(&pp, &[(b.tokens.clone(), b.targets.clone())])
                .unwrap();
            for (l, (a, (ffn_r, ov_r))) in g.iter().zip(&scores_ref).enumerate() {
                assert!(
                    bits_eq(&a.ffn, ffn_r),
                    "{model} (w={workers}) layer {l}: packed gradcol ffn diverged"
                );
                assert!(
                    bits_eq(&a.ov, ov_r),
                    "{model} (w={workers}) layer {l}: packed gradcol ov diverged"
                );
            }
        }
    }
}

/// The pack cache covers exactly the linear weights + the tied head,
/// and its bytes are pool-width-independent (pure relayout).
#[test]
fn pack_cache_coverage_and_pool_width_independent_bytes() {
    let m = manifest();
    let spec = m.model("llama_tiny").unwrap().clone();
    let w = Weights::init(&spec, 13);
    let shorts = linear_shorts(&spec.family);

    let serial = {
        let _g = pool::enter(pool::serial());
        PackCache::build(&w)
    };
    assert_eq!(
        serial.count(),
        spec.n_layers * shorts.len() + 1,
        "pack cache must hold every linear weight plus the tied head"
    );
    let head = serial.get("tok_emb").expect("tied head packed");
    assert_eq!(head.out_dim(), spec.vocab);
    assert_eq!(head.k_dim(), spec.d_model);

    for workers in [2usize, 8] {
        let pooled = {
            let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
            PackCache::build(&w)
        };
        assert_eq!(serial.bytes(), pooled.bytes(), "pack bytes at {workers} workers");
        assert_eq!(serial.count(), pooled.count());
        for l in 0..spec.n_layers {
            for short in shorts {
                let a = serial.get_l(l, short).unwrap();
                let b = pooled.get_l(l, short).unwrap();
                assert!(
                    bits_eq(a.data(), b.data()),
                    "layer {l} {short}: pack bytes diverged at {workers} workers"
                );
            }
        }
        assert!(bits_eq(
            serial.get("tok_emb").unwrap().data(),
            pooled.get("tok_emb").unwrap().data()
        ));
    }
}

/// Toy ragged spec (compact-style per-layer dims) for the manifest-free
/// gradcol identity.
fn toy_spec(family: &str) -> ModelSpec {
    let layer_dims = vec![
        LayerDims { d_ff: 20, d_ov: 10, head_splits: vec![6, 4] },
        LayerDims { d_ff: 12, d_ov: 5, head_splits: vec![5, 0] },
    ];
    let params = build_params(family, 16, 2, 48, 24, &layer_dims);
    ModelSpec {
        name: format!("pack_toy_{family}"),
        family: family.into(),
        d_model: 16,
        n_heads: 2,
        n_layers: 2,
        d_ff: 20,
        vocab: 48,
        seq: 24,
        batch: 2,
        params,
        layer_dims,
    }
}

/// Quantize → dequantize error-bound property: every element of an int8
/// panel (and of the flat shard quantizer) reconstructs within half a
/// scale step of its original, exact zeros reconstruct to exact zero,
/// `unpack()` agrees bitwise with elementwise dequant, and wide panels
/// land well under the 0.55× byte budget.
#[test]
fn int8_quantize_dequantize_error_bound_property() {
    use fasp::tensor::pack::{
        dequantize_flat_range, quantize_flat, PackedMat, Quant, Q8_GROUP,
    };
    let mut rng = Rng::new(0x51);
    for &(n, k) in &[(7usize, 64usize), (33, 150), (64, 256), (10, 1)] {
        // mixed magnitudes with sprinkled exact zeros and one zero lane
        let mut w: Vec<f32> = (0..n * k)
            .map(|_| (rng.below(2000) as f32 - 1000.0) / 97.0)
            .collect();
        for i in (0..w.len()).step_by(13) {
            w[i] = 0.0;
        }
        for v in w[..k].iter_mut() {
            *v = 0.0;
        }
        let pm = PackedMat::pack_bt_raw_q(&w, n, k, Quant::Int8);
        let (q, scales) = pm.q_data().expect("int8 payload");
        if k >= 64 {
            assert!(
                pm.bytes() as f64 <= 0.55 * (4 * n * k) as f64,
                "[{n}x{k}] int8 panel bytes {} !<= 0.55x f32 {}",
                pm.bytes(),
                4 * n * k
            );
        }
        let up = pm.unpack();
        for j in 0..n {
            for kk in 0..k {
                let orig = w[j * k + kk];
                let s = scales[(kk / Q8_GROUP) * n + j];
                let deq = q[kk * n + j] as f32 * s;
                assert!(
                    (orig - deq).abs() <= 0.5 * s + 1e-6,
                    "[{n}x{k}] ({j},{kk}): {orig} vs {deq} (scale {s})"
                );
                if orig == 0.0 {
                    assert_eq!(
                        deq.to_bits(),
                        0.0f32.to_bits(),
                        "[{n}x{k}] ({j},{kk}): exact zero must stay exact"
                    );
                }
                assert_eq!(
                    up.data[j * k + kk].to_bits(),
                    deq.to_bits(),
                    "[{n}x{k}] ({j},{kk}): unpack != elementwise dequant"
                );
            }
        }
        // the flat shard quantizer honors the same per-element bound
        let (fq, fs) = quantize_flat(&w, Q8_GROUP);
        let deq = dequantize_flat_range(&fq, &fs, Q8_GROUP, 0, w.len());
        for (i, (&x, &d)) in w.iter().zip(&deq).enumerate() {
            let s = fs[i / Q8_GROUP];
            assert!(
                (x - d).abs() <= 0.5 * s + 1e-6,
                "flat elem {i}: {x} vs {d} (scale {s})"
            );
        }
    }
}

/// `loss_and_grad` with and without a pack cache produce bit-identical
/// loss and gradients — the gradcol entry's packed forward is exact,
/// even on ragged (compact-style) specs with a fully sliced head.
#[test]
fn packed_gradcol_forward_matches_unpacked() {
    for family in ["opt", "llama"] {
        let spec = toy_spec(family);
        let w = Weights::init(&spec, 5);
        let packs = PackCache::build(&w);
        let mut rng = Rng::new(41);
        let n = 2 * 6;
        let toks = IntTensor::new(
            vec![2, 6],
            (0..n).map(|_| rng.below(spec.vocab) as i32).collect(),
        );
        let tgts = IntTensor::new(
            vec![2, 6],
            (0..n).map(|_| rng.below(spec.vocab) as i32).collect(),
        );
        let (l_u, g_u) = host_grad::loss_and_grad(&w, &toks, &tgts).unwrap();
        let (l_p, g_p) =
            host_grad::loss_and_grad_packed(&w, Some(&packs), &toks, &tgts).unwrap();
        assert_eq!(l_u.to_bits(), l_p.to_bits(), "{family}: packed loss diverged");
        assert!(bits_eq(&g_u.data, &g_p.data), "{family}: packed gradient diverged");
    }
}
