//! Integration: the runtime session entries must agree with the
//! independent host-side reference implementation — the spike-level
//! guarantee everything else rests on. Requires `make artifacts`.

use fasp::data::{Corpus, Dataset};
use fasp::model::{host, Weights};
use fasp::runtime::{Manifest, Session};
use fasp::tensor::Tensor;

fn manifest() -> Manifest {
    Manifest::load(&fasp::artifacts_dir()).expect("run `make artifacts` first")
}

#[test]
fn manifest_loads_and_knows_the_zoo() {
    let m = manifest();
    for name in fasp::model::zoo::all_models() {
        let spec = m.model(name).unwrap();
        assert_eq!(spec.d_model % spec.n_heads, 0);
        assert!(m.artifacts.contains_key(&format!("{name}_fwd_loss")));
        assert!(m.artifacts.contains_key(&format!("{name}_capture")));
        assert!(m.artifacts.contains_key(&format!("{name}_gradcol")));
        assert!(m.artifacts.contains_key(&format!("{name}_train_step")));
    }
    assert!(!m.capture_leaves.is_empty());
}

/// Session fwd_loss vs host forward — both families.
#[test]
fn fwd_loss_matches_host_reference() {
    for model in ["opt_tiny", "llama_tiny"] {
        let m = manifest();
        let session = Session::new(&m, model).unwrap();
        let spec = session.spec.clone();
        let weights = Weights::init(&spec, 7);
        let ds = Dataset::new(Corpus::new(spec.vocab, 3), spec.batch, spec.seq, 2);
        let b = ds.train_batch(0);

        let params = session.pack(&weights.packed).unwrap();
        let out = session.fwd_loss(&params, &b.tokens, &b.targets).unwrap();
        let host_nll = host::mean_nll(&weights, &b.tokens, &b.targets).unwrap();
        let diff = (out.mean_nll - host_nll).abs();
        assert!(
            diff < 2e-3 * host_nll.abs().max(1.0),
            "{model}: session {} vs host {host_nll}",
            out.mean_nll
        );
        // per-token consistency
        let (host_tok, _) = host::forward_nll(&weights, &b.tokens, &b.targets, false).unwrap();
        let max = out.tok_nll.max_abs_diff(&host_tok);
        assert!(max < 5e-2, "{model}: max tok nll diff {max}");
    }
}

/// The capture entry's Gram matrices equal host-recomputed X^T X.
#[test]
fn capture_grams_match_host_activations() {
    let m = manifest();
    let session = Session::new(&m, "opt_tiny").unwrap();
    let spec = session.spec.clone();
    let weights = Weights::init(&spec, 11);
    let ds = Dataset::new(Corpus::new(spec.vocab, 5), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);

    let params = session.pack(&weights.packed).unwrap();
    let stats = session.capture(&params, &[b.tokens.clone()]).unwrap();
    assert_eq!(stats.layers.len(), spec.n_layers);
    assert_eq!(stats.rows, spec.batch * spec.seq);

    let (_, caps) = host::forward_nll(&weights, &b.tokens, &b.targets, true).unwrap();
    for (l, cap) in caps.iter().enumerate() {
        let g_host = host::host_gram(&cap.ffn_h);
        let rel = stats.layers[l].g_ffn.rel_err(&g_host);
        assert!(rel < 2e-2, "layer {l} g_ffn rel err {rel}");
        let g_host = host::host_gram(&cap.attn_ctx);
        let rel = stats.layers[l].g_attn.rel_err(&g_host);
        assert!(rel < 2e-2, "layer {l} g_attn rel err {rel}");
        // mean vectors: column sums of the activations
        let (_, f) = cap.ffn_h.dims2();
        let mut sums = vec![0.0f32; f];
        for r in 0..cap.ffn_h.shape[0] {
            for (s, v) in sums.iter_mut().zip(cap.ffn_h.row(r)) {
                *s += v;
            }
        }
        let m_ffn = &stats.layers[l].m_ffn;
        let host_m = Tensor::new(vec![f], sums);
        assert!(m_ffn.rel_err(&host_m) < 2e-2, "layer {l} m_ffn");
    }
}

/// train_step reduces loss and the state round-trips opaquely.
#[test]
fn train_step_learns_on_tiny_model() {
    let m = manifest();
    let session = Session::new(&m, "llama_tiny").unwrap();
    let spec = session.spec.clone();
    let init = Weights::init(&spec, 42);
    let ds = Dataset::new(Corpus::new(spec.vocab, 9), spec.batch, spec.seq, 40);

    let mut state = session.init_train(&init.packed).unwrap();
    let mut first = None;
    let mut last = 0.0f32;
    for step in 0..60 {
        let b = ds.train_batch(step);
        let loss = session
            .train_step(&mut state, &b.tokens, &b.targets, (step + 1) as f32, 8e-3)
            .unwrap();
        first.get_or_insert(loss);
        last = loss;
        assert!(loss.is_finite(), "step {step} loss {loss}");
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.3,
        "training did not reduce loss: {first} → {last}"
    );
    // params extracted from the state differ from init (learning happened)
    let trained = session.train_params(&state).unwrap();
    let diff = trained.max_abs_diff(&init.packed);
    assert!(diff > 1e-3, "params unchanged after training");
}

/// gradcol returns finite, non-negative, correctly-shaped scores.
#[test]
fn gradcol_scores_shapes() {
    let m = manifest();
    let session = Session::new(&m, "llama_tiny").unwrap();
    let spec = session.spec.clone();
    let weights = Weights::init(&spec, 1);
    let ds = Dataset::new(Corpus::new(spec.vocab, 2), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);
    let params = session.pack(&weights.packed).unwrap();
    let scores = session
        .gradcol(&params, &[(b.tokens.clone(), b.targets.clone())])
        .unwrap();
    assert_eq!(scores.len(), spec.n_layers);
    for s in &scores {
        assert_eq!(s.ffn.len(), spec.d_ff);
        assert_eq!(s.ov.len(), spec.d_model);
        assert!(s.ffn.iter().all(|x| x.is_finite() && *x >= 0.0));
        assert!(s.ov.iter().all(|x| x.is_finite() && *x >= 0.0));
    }
}

/// Shape validation must reject wrong inputs loudly.
#[test]
fn wrong_shapes_rejected() {
    let m = manifest();
    let session = Session::new(&m, "opt_tiny").unwrap();
    let spec = session.spec.clone();
    let weights = Weights::init(&spec, 1);
    let params = session.pack(&weights.packed).unwrap();
    let bad = fasp::tensor::IntTensor::zeros(&[1, 3]); // wrong batch/seq
    let err = session.fwd_loss(&params, &bad, &bad);
    assert!(err.is_err());
    // wrong-length params rejected at pack time
    let short = Tensor::zeros(&[3]);
    assert!(session.pack(&short).is_err());
}

/// The Pallas wanda-metric artifact agrees with the host metric.
#[test]
fn wanda_kernel_artifact_matches_host() {
    let m = manifest();
    let km = fasp::prune::metric::KernelMetric::new(&m);
    let mut rng = fasp::util::rng::Rng::new(3);
    // (64, 256) exists as an artifact (opt_tiny fc2 shape)
    let w = Tensor::randn(&[64, 256], 1.0, &mut rng);
    let xnorm: Vec<f32> = (0..256).map(|i| (i as f32 * 0.01) + 0.1).collect();
    let got = km.wanda_scores(&w, &xnorm).unwrap();
    let want = fasp::prune::metric::wanda_scores_host(&w, &xnorm);
    for (g, w2) in got.iter().zip(&want) {
        assert!((g - w2).abs() < 1e-2 * w2.abs().max(1.0), "{g} vs {w2}");
    }
}

/// Masked evaluation exactness (DESIGN.md §5): zeroing a fc2 column and
/// its coupled fc1 row must not change the loss at all vs zeroing the
/// column alone.
#[test]
fn coupled_row_removal_is_free() {
    let m = manifest();
    let session = Session::new(&m, "opt_tiny").unwrap();
    let spec = session.spec.clone();
    let base = Weights::init(&spec, 21);
    let ds = Dataset::new(Corpus::new(spec.vocab, 8), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);

    // zero column 5 of fc2 in layer 0
    let mut w_col = base.clone();
    let mut fc2 = w_col.get_l(0, "fc2").unwrap();
    fasp::tensor::ops::zero_cols(&mut fc2, &[5]);
    w_col.set_l(0, "fc2", &fc2).unwrap();
    let p_col = session.pack(&w_col.packed).unwrap();
    let loss_col = session.fwd_loss(&p_col, &b.tokens, &b.targets).unwrap().mean_nll;

    // additionally zero the coupled fc1 row + bias element
    let mut w_both = w_col.clone();
    let mut fc1 = w_both.get_l(0, "fc1").unwrap();
    fasp::tensor::ops::zero_rows(&mut fc1, &[5]);
    w_both.set_l(0, "fc1", &fc1).unwrap();
    let mut b1 = w_both.get_l(0, "bfc1").unwrap();
    fasp::tensor::ops::zero_elems(&mut b1, &[5]);
    w_both.set_l(0, "bfc1", &b1).unwrap();
    let p_both = session.pack(&w_both.packed).unwrap();
    let loss_both = session.fwd_loss(&p_both, &b.tokens, &b.targets).unwrap().mean_nll;

    assert!(
        (loss_col - loss_both).abs() < 1e-6,
        "coupled removal changed loss: {loss_col} vs {loss_both}"
    );
}
