//! Serve-engine contract: the continuous-batching scheduler's output is
//! **bit-identical** to per-session sequential `generate` across batch
//! compositions, join/leave orders, page sizes and pool widths; the
//! paged KV arena reuses freed pages and accounts residency; a
//! prefix-cache hit produces the same bits as a cold prefill. Plus the
//! decode-path regression locks: non-finite logits can never be
//! sampled, oversized generations fail before any forward work, pool
//! worker panics carry their payload, and a failed shard publish leaves
//! no `*.tmp` debris.

use fasp::model::compact::{build_params, compact_from_mask};
use fasp::model::decode::{self, GenerateOpts, KvCache, Sampler};
use fasp::model::{PackedWeights, PruneMask, Weights};
use fasp::runtime::manifest::LayerDims;
use fasp::runtime::store::{shard_file, write_shards, ShardKind};
use fasp::runtime::ModelSpec;
use fasp::serve::{serve, ServeConfig, ServeRequest};
use fasp::tensor::IntTensor;
use fasp::util::pool;
use fasp::util::rng::Rng;
use std::sync::Arc;

/// Toy spec with ragged (compact-style) per-layer dims, including one
/// fully sliced head — the serve path must hold exactly where the OV
/// slicing bites (same shape family as `test_decode`'s toy).
fn toy_spec(family: &str) -> ModelSpec {
    let layer_dims = vec![
        LayerDims { d_ff: 20, d_ov: 10, head_splits: vec![6, 4] },
        LayerDims { d_ff: 12, d_ov: 5, head_splits: vec![5, 0] },
        LayerDims { d_ff: 16, d_ov: 16, head_splits: vec![8, 8] },
    ];
    let params = build_params(family, 16, 3, 48, 24, &layer_dims);
    ModelSpec {
        name: format!("serve_toy_{family}"),
        family: family.into(),
        d_model: 16,
        n_heads: 2,
        n_layers: 3,
        d_ff: 20,
        vocab: 48,
        seq: 24,
        batch: 2,
        params,
        layer_dims,
    }
}

/// A mixed load: staggered prompt lengths and generation lengths, both
/// samplers, one seed per session — and the last session repeating the
/// first session's prompt so the prefix cache has something to share.
fn toy_requests(spec: &ModelSpec, n: usize) -> Vec<ServeRequest> {
    let mut rng = Rng::new(0x10ad);
    let mut reqs = Vec::with_capacity(n);
    for i in 0..n {
        let t = 3 + i % 4;
        let prompt: Vec<i32> = (0..t).map(|_| rng.below(spec.vocab) as i32).collect();
        let sampler = if i % 2 == 0 {
            Sampler::Greedy
        } else {
            Sampler::TopK { k: 4, temperature: 0.9 }
        };
        reqs.push(ServeRequest {
            prompt,
            max_new: 2 + i % 3,
            sampler,
            seed: 1000 + i as u64,
            ..Default::default()
        });
    }
    if n >= 2 {
        reqs[n - 1].prompt = reqs[0].prompt.clone();
        reqs[n - 1].max_new = reqs[0].max_new;
    }
    reqs
}

/// Per-session sequential reference: one b=1 `generate_src` over the
/// same packed weights with the same prompt/sampler/seed.
fn sequential_reference(pw: &PackedWeights, reqs: &[ServeRequest]) -> Vec<Vec<i32>> {
    reqs.iter()
        .map(|r| {
            let prompt = IntTensor::new(vec![1, r.prompt.len()], r.prompt.clone());
            let opts = GenerateOpts { max_new: r.max_new, sampler: r.sampler, seed: r.seed };
            decode::generate_src(&mut pw.source(), &prompt, &opts)
                .unwrap()
                .tokens
                .data
        })
        .collect()
}

fn pages_for(positions: usize, page: usize) -> usize {
    (positions + page - 1) / page
}

// --------------------------------------------------- scheduler bit-identity

/// The hard receipt: serve ≡ sequential, bit for bit, on both families,
/// across page sizes, batch caps (1 = fully serialized admission,
/// mid = rolling join/leave, all = one big batch) and pool widths.
#[test]
fn serve_bit_identical_to_sequential_across_compositions() {
    for family in ["llama", "opt"] {
        let spec = toy_spec(family);
        let w = Weights::init(&spec, 77);
        let pw = PackedWeights::new(w);
        let reqs = toy_requests(&spec, 6);
        let expect = {
            let _g = pool::enter(pool::serial());
            sequential_reference(&pw, &reqs)
        };
        for (page, max_batch, workers, prefill_chunk) in [
            (1usize, 1usize, 1usize, 1usize),
            (1, 3, 1, 2),
            (3, 1, 1, 4),
            (3, 2, 1, 3),
            (3, 6, 1, 2),
            (8, 3, 1, 4),
            (3, 3, 4, 1),
            (8, 6, 4, 4),
        ] {
            let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
            let n_pages = 64;
            let cfg = ServeConfig {
                page,
                n_pages,
                max_batch,
                prefix_cache: true,
                prefill_chunk,
                ..Default::default()
            };
            let report = serve(&pw, &reqs, &cfg).unwrap();
            assert_eq!(report.outputs.len(), reqs.len());
            for (o, want) in report.outputs.iter().zip(&expect) {
                assert_eq!(
                    &o.tokens, want,
                    "{family} page={page} max_batch={max_batch} w={workers}: \
                     session {} diverged from sequential generate",
                    o.id
                );
            }
            assert_eq!(report.generated_tokens, reqs.iter().map(|r| r.max_new).sum::<usize>());
            assert!(report.max_batch_seen <= max_batch);
            // disabling the prefix cache must not change a single bit
            let cfg_cold = ServeConfig { prefix_cache: false, ..cfg };
            let cold = serve(&pw, &reqs, &cfg_cold).unwrap();
            for (o, want) in cold.outputs.iter().zip(&expect) {
                assert_eq!(
                    &o.tokens, want,
                    "{family} page={page} max_batch={max_batch} w={workers}: \
                     cold-cache session {} diverged",
                    o.id
                );
            }
            assert_eq!(cold.prefix_hits, 0);
        }
    }
}

/// The sampled stream must be a function of the session alone: the same
/// request produces the same tokens whether it runs solo or packed into
/// a batch of strangers (per-session rng streams, lane-independent rows).
#[test]
fn session_output_independent_of_batch_neighbors() {
    let spec = toy_spec("llama");
    let w = Weights::init(&spec, 11);
    let pw = PackedWeights::new(w);
    let reqs = toy_requests(&spec, 5);
    let solo: Vec<Vec<i32>> = reqs
        .iter()
        .map(|r| {
            let cfg = ServeConfig {
                page: 4,
                n_pages: 32,
                max_batch: 1,
                prefix_cache: false,
                prefill_chunk: 2,
                ..Default::default()
            };
            serve(&pw, std::slice::from_ref(r), &cfg).unwrap().outputs[0].tokens.clone()
        })
        .collect();
    let cfg = ServeConfig {
        page: 4,
        n_pages: 32,
        max_batch: 5,
        prefix_cache: false,
        prefill_chunk: 3,
        ..Default::default()
    };
    let batched = serve(&pw, &reqs, &cfg).unwrap();
    for (o, want) in batched.outputs.iter().zip(&solo) {
        assert_eq!(&o.tokens, want, "session {}: neighbors perturbed its output", o.id);
    }
}

// ----------------------------------------------------- prefix cache sharing

/// A prefix-cache hit must adopt full prompt-head pages (counted in the
/// report and the per-session output) and still produce the exact bits
/// of a cold prefill.
#[test]
fn prefix_hit_bit_identical_to_cold_prefill() {
    let spec = toy_spec("llama");
    let w = Weights::init(&spec, 23);
    let pw = PackedWeights::new(w);
    let mut rng = Rng::new(5);
    let prompt: Vec<i32> = (0..6).map(|_| rng.below(spec.vocab) as i32).collect();
    // identical prompts, serialized admission: the second session starts
    // only after the first finished inserting its prompt head
    let reqs: Vec<ServeRequest> = (0..3)
        .map(|i| ServeRequest {
            prompt: prompt.clone(),
            max_new: 3,
            sampler: Sampler::TopK { k: 3, temperature: 1.1 },
            seed: 40 + i as u64,
            ..Default::default()
        })
        .collect();
    let expect = sequential_reference(&pw, &reqs);
    let page = 2;
    let cfg = ServeConfig {
        page,
        n_pages: 32,
        max_batch: 1,
        prefix_cache: true,
        prefill_chunk: 2,
        ..Default::default()
    };
    let report = serve(&pw, &reqs, &cfg).unwrap();
    for (o, want) in report.outputs.iter().zip(&expect) {
        assert_eq!(&o.tokens, want, "session {}: prefix hit changed the bits", o.id);
    }
    // lookup is capped at t_prompt - 1 = 5 positions → 2 full pages
    assert_eq!(report.outputs[0].prefix_hit_positions, 0, "first session must be cold");
    for o in &report.outputs[1..] {
        assert_eq!(
            o.prefix_hit_positions,
            (prompt.len() - 1) / page * page,
            "session {} adopted the wrong share",
            o.id
        );
    }
    assert!(report.prefix_hits >= 2, "hits: {}", report.prefix_hits);
    assert!(report.prefix_insertions >= 1);
}

// ------------------------------------------------- arena residency + reuse

/// Retired sessions return their pages to the pool: a load far larger
/// than the batch cap must peak at the concurrent working set, not the
/// whole load, and every page must come home at teardown (the engine
/// debug-asserts that).
#[test]
fn arena_pages_are_reused_across_waves() {
    let spec = toy_spec("llama");
    let w = Weights::init(&spec, 31);
    let pw = PackedWeights::new(w);
    let reqs = toy_requests(&spec, 9);
    let page = 2;
    let max_batch = 2;
    let cfg = ServeConfig {
        page,
        n_pages: 48,
        max_batch,
        prefix_cache: false,
        prefill_chunk: 4,
        ..Default::default()
    };
    let report = serve(&pw, &reqs, &cfg).unwrap();
    let total: usize = reqs
        .iter()
        .map(|r| pages_for(r.prompt.len() + r.max_new - 1, page))
        .sum();
    let worst_concurrent = max_batch
        * reqs
            .iter()
            .map(|r| pages_for(r.prompt.len() + r.max_new - 1, page))
            .max()
            .unwrap();
    assert!(
        report.peak_pages <= worst_concurrent,
        "peak {} pages exceeds the {}-session working set bound {}",
        report.peak_pages,
        max_batch,
        worst_concurrent
    );
    assert!(
        report.peak_pages < total,
        "peak {} pages vs {} total — retired pages were never reused",
        report.peak_pages,
        total
    );
    assert_eq!(report.kv_bytes, report.page_bytes * cfg.n_pages);
}

/// Unservable requests are rejected up front with a proper error — no
/// forward work, no mid-generation arena panic.
#[test]
fn serve_rejects_unservable_requests_up_front() {
    let spec = toy_spec("llama");
    let w = Weights::init(&spec, 3);
    let pw = PackedWeights::new(w);
    let ok = ServeRequest {
        prompt: vec![1, 2, 3],
        max_new: 2,
        sampler: Sampler::Greedy,
        seed: 0,
        ..Default::default()
    };

    // needs more pages than the whole arena
    let big = ServeRequest {
        prompt: vec![1; 10],
        max_new: 10,
        sampler: Sampler::Greedy,
        seed: 0,
        ..Default::default()
    };
    let cfg = ServeConfig {
        page: 2,
        n_pages: 4,
        max_batch: 2,
        prefix_cache: true,
        prefill_chunk: 2,
        ..Default::default()
    };
    let err = serve(&pw, &[ok.clone(), big], &cfg).unwrap_err();
    assert!(
        format!("{err:#}").contains("rejected before any forward work"),
        "{err:#}"
    );

    // a zero prefill chunk is a config error, not an infinite stall
    let bad_cfg = ServeConfig { prefill_chunk: 0, ..cfg };
    let err = serve(&pw, std::slice::from_ref(&ok), &bad_cfg).unwrap_err();
    assert!(format!("{err:#}").contains("prefill_chunk"), "{err:#}");

    // empty prompt / zero generation / out-of-vocab token
    let cfg = ServeConfig {
        page: 4,
        n_pages: 32,
        max_batch: 2,
        prefix_cache: true,
        prefill_chunk: 1,
        ..Default::default()
    };
    let empty = ServeRequest { prompt: vec![], ..ok.clone() };
    assert!(format!("{:#}", serve(&pw, &[empty], &cfg).unwrap_err()).contains("empty prompt"));
    let zero = ServeRequest { max_new: 0, ..ok.clone() };
    assert!(format!("{:#}", serve(&pw, &[zero], &cfg).unwrap_err()).contains("max_new"));
    let bad = ServeRequest { prompt: vec![0, spec.vocab as i32], ..ok.clone() };
    assert!(format!("{:#}", serve(&pw, &[bad], &cfg).unwrap_err()).contains("vocab"));

    // OPT: generation must fit the learned positions
    let ospec = toy_spec("opt");
    let opw = PackedWeights::new(Weights::init(&ospec, 3));
    let long = ServeRequest {
        prompt: vec![1; ospec.seq],
        max_new: 2,
        sampler: Sampler::Greedy,
        seed: 0,
        ..Default::default()
    };
    let cfg = ServeConfig {
        page: 8,
        n_pages: 64,
        max_batch: 1,
        prefix_cache: false,
        prefill_chunk: 4,
        ..Default::default()
    };
    let err = serve(&opw, &[long], &cfg).unwrap_err();
    assert!(format!("{err:#}").contains("learned positions"), "{err:#}");

    // ...and a request that merely has to WAIT for pages is fine: the
    // arena fits one session at a time, the queue drains in waves
    let tight = ServeConfig {
        page: 2,
        n_pages: 2,
        max_batch: 4,
        prefix_cache: false,
        prefill_chunk: 3,
        ..Default::default()
    };
    let reqs = vec![ok.clone(), ok.clone(), ok];
    let expect = sequential_reference(&pw, &reqs);
    let report = serve(&pw, &reqs, &tight).unwrap();
    for (o, want) in report.outputs.iter().zip(&expect) {
        assert_eq!(&o.tokens, want, "starved admission changed session {}", o.id);
    }
    assert_eq!(report.max_batch_seen, 1, "2 pages can only host one session");
}

// -------------------------------------------- regression: KV overflow Err

/// An oversized generation against a caller-provided cache must return
/// a proper `Err` before any prefill work — the cache stays untouched.
#[test]
fn oversized_generation_errs_before_prefill() {
    let spec = toy_spec("llama");
    let w = Weights::init(&spec, 13);
    let pw = PackedWeights::new(w);
    let prompt = IntTensor::new(vec![1, 3], vec![1, 2, 3]);
    let mut cache = KvCache::for_spec(&spec, 1, 4).unwrap();

    // needs 3 + 4 - 1 = 6 cached positions, capacity is 4
    let opts = GenerateOpts { max_new: 4, sampler: Sampler::Greedy, seed: 0 };
    let err = decode::generate_with_cache_src(&mut pw.source(), &prompt, &opts, &mut cache)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rejected before prefill"), "{msg}");
    assert!(msg.contains("overflow"), "{msg}");
    assert_eq!(cache.len(), 0, "the failed call must not have touched the cache");

    // exactly at capacity (3 + 2 - 1 = 4) it runs — and matches the
    // exact-cache generate_src path bit for bit
    let opts = GenerateOpts { max_new: 2, sampler: Sampler::Greedy, seed: 0 };
    let g = decode::generate_with_cache_src(&mut pw.source(), &prompt, &opts, &mut cache)
        .unwrap();
    let g2 = decode::generate_src(&mut pw.source(), &prompt, &opts).unwrap();
    assert_eq!(g.tokens.data, g2.tokens.data);
    assert_eq!(g.generated, 2);
}

/// An empty prompt — zero tokens per sequence, or zero sequences — must
/// be a proper `Err` before any prefill work, on both entry points.
/// (Previously `[1, 0]` reached prefill and panicked inside embedding.)
#[test]
fn empty_prompt_rejected_before_prefill() {
    let spec = toy_spec("llama");
    let w = Weights::init(&spec, 13);
    let pw = PackedWeights::new(w);
    let opts = GenerateOpts { max_new: 2, sampler: Sampler::Greedy, seed: 0 };
    for shape in [vec![1usize, 0usize], vec![0, 3], vec![0, 0]] {
        let prompt = IntTensor::new(shape.clone(), vec![]);
        let err = decode::generate_src(&mut pw.source(), &prompt, &opts).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rejected before prefill"), "shape {shape:?}: {msg}");

        let mut cache = KvCache::for_spec(&spec, 1, 8).unwrap();
        let err =
            decode::generate_with_cache_src(&mut pw.source(), &prompt, &opts, &mut cache)
                .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rejected before prefill"), "shape {shape:?}: {msg}");
        assert_eq!(cache.len(), 0, "the rejected call must not touch the cache");
    }
}

// ------------------------------------------- regression: NaN-proof sampling

#[test]
fn sampling_skips_non_finite_logits() {
    let mut rng = Rng::new(9);
    let nan = f32::NAN;
    let inf = f32::INFINITY;

    // greedy: NaN/±inf can never win, even in first position
    let logits = [nan, 1.0, inf, 0.5, f32::NEG_INFINITY];
    assert_eq!(decode::sample_row(&logits, Sampler::Greedy, &mut rng), 1);
    assert_eq!(decode::sample_row(&[nan, 2.0, 1.0], Sampler::Greedy, &mut rng), 1);

    // top-k: non-finite entries sort strictly last — with k spanning
    // them, only the finite candidates are ever sampled
    for k in [2usize, 3, 5] {
        for _ in 0..64 {
            let pick = decode::sample_row(
                &logits,
                Sampler::TopK { k, temperature: 0.7 },
                &mut rng,
            );
            assert!(
                pick == 1 || pick == 3,
                "top-{k} sampled non-finite index {pick}"
            );
        }
    }

    // deterministic: with one finite logit, top-k is forced onto it
    let one = [nan, nan, 4.0, inf];
    for _ in 0..8 {
        assert_eq!(
            decode::sample_row(&one, Sampler::TopK { k: 4, temperature: 1.0 }, &mut rng),
            2
        );
    }
}

#[test]
#[should_panic(expected = "no finite logit")]
fn all_nan_greedy_panics_loudly() {
    let mut rng = Rng::new(1);
    decode::sample_row(&[f32::NAN, f32::NAN], Sampler::Greedy, &mut rng);
}

#[test]
#[should_panic(expected = "no finite logit")]
fn all_nan_topk_panics_loudly() {
    let mut rng = Rng::new(1);
    decode::sample_row(
        &[f32::NAN, f32::INFINITY, f32::NEG_INFINITY],
        Sampler::TopK { k: 2, temperature: 1.0 },
        &mut rng,
    );
}

// ------------------------------------------ regression: pool panic payload

/// A panic inside a spawned pool task must surface its original payload
/// on the calling thread, not `std::thread::scope`'s generic "a scoped
/// thread panicked".
#[test]
fn pool_worker_panics_carry_their_payload() {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {})); // keep test output clean
    let pool = pool::Pool::new(4);

    // map: some task (caller- or worker-side, scheduling decides) panics
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.map(16, |i| {
            if i == 7 {
                panic!("map payload 42");
            }
            i
        })
    }))
    .unwrap_err();
    let msg = err.downcast_ref::<&str>().copied().unwrap_or("<non-str payload>");
    assert!(msg.contains("map payload 42"), "lost map panic payload: {msg:?}");

    // run_rows1: row 0 lands on a SPAWNED worker (the calling thread
    // takes the last chunk), so this exercises the join/re-raise path
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut data = vec![0.0f32; 16 * 4];
        pool.run_rows1(&mut data, 4, |r0, _chunk| {
            if r0 == 0 {
                panic!("rows payload 7");
            }
        });
    }))
    .unwrap_err();
    let msg = err.downcast_ref::<&str>().copied().unwrap_or("<non-str payload>");
    assert!(msg.contains("rows payload 7"), "lost rows panic payload: {msg:?}");
    std::panic::set_hook(prev);
}

// --------------------------------------- regression: shard publish hygiene

/// A failed rename during shard publish must take its temp file with it
/// — no `*.tmp` debris next to live store content.
#[test]
fn failed_shard_publish_leaves_no_tmp_debris() {
    let spec = toy_spec("llama");
    let w = Weights::init(&spec, 3);
    let mask = PruneMask::full(&spec);
    let cm = compact_from_mask(&w, &mask, "serve_tmp_fail").unwrap();
    let dir = std::env::temp_dir().join("fasp_test_serve_tmpfail");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // block the embed shard's publish: a non-empty directory at the
    // destination name makes the rename fail after the temp write
    let blocker = dir.join(shard_file(&cm.spec.name, ShardKind::Embed));
    std::fs::create_dir_all(blocker.join("occupied")).unwrap();

    let err = write_shards(&dir, &cm).unwrap_err();
    assert!(format!("{err:#}").contains("publish"), "{err:#}");
    let tmp_left: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .flatten()
        .filter(|e| e.path().to_string_lossy().ends_with(".tmp"))
        .map(|e| e.path())
        .collect();
    assert!(tmp_left.is_empty(), "rename failure leaked temp files: {tmp_left:?}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Stale `*.tmp` debris from an older crashed publish is cleared by the
/// next successful write, and never shadows live shards.
#[test]
fn stale_tmp_debris_cleared_on_next_publish() {
    let spec = toy_spec("llama");
    let w = Weights::init(&spec, 4);
    let mask = PruneMask::full(&spec);
    let cm = compact_from_mask(&w, &mask, "serve_tmp_stale").unwrap();
    let dir = std::env::temp_dir().join("fasp_test_serve_tmpstale");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let debris = dir.join("serve_tmp_stale.layer000.ftns.tmp");
    std::fs::write(&debris, b"half-written junk").unwrap();

    let index = write_shards(&dir, &cm).unwrap();
    assert!(!debris.exists(), "stale temp file survived a successful publish");
    assert_eq!(index.shards.len(), 1 + spec.n_layers);
    for s in &index.shards {
        assert!(dir.join(&s.file).is_file(), "missing shard {}", s.file);
    }
    std::fs::remove_dir_all(&dir).ok();
}
