//! Failure injection: the system must fail loudly and legibly, never with
//! garbage numerics — corrupt manifests, truncated checkpoints, missing
//! artifacts, impossible pruning requests.

use fasp::model::Weights;
use fasp::runtime::{Manifest, Session};
use fasp::tensor::io::TensorFile;
use fasp::tensor::Tensor;
use std::io::Write;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fasp_fail_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_a_clear_error() {
    let d = tmpdir("nomanifest");
    let err = Manifest::load(&d).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn corrupt_manifest_json_rejected() {
    let d = tmpdir("badjson");
    std::fs::write(d.join("manifest.json"), "{ not json !!").unwrap();
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn manifest_missing_fields_rejected() {
    let d = tmpdir("missingfields");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"models": {"x": {"family": "opt"}}, "artifacts": {}}"#,
    )
    .unwrap();
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn unknown_model_and_artifact_errors() {
    let m = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    assert!(m.model("gpt5_huge").is_err());
    assert!(m.artifact("nonexistent_entry").is_err());
    assert!(Session::new(&m, "gpt5_huge").is_err());
}

#[test]
fn artifact_with_garbage_hlo_fails_at_load() {
    let m = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    // copy the manifest dir entry but point at a garbage file
    let d = tmpdir("badhlo");
    let manifest_text =
        std::fs::read_to_string(fasp::artifacts_dir().join("manifest.json")).unwrap();
    std::fs::write(d.join("manifest.json"), manifest_text).unwrap();
    // write garbage for one artifact the test will load
    let spec = m.artifact("wanda_metric_64x64").unwrap();
    let mut f = std::fs::File::create(d.join(&spec.file)).unwrap();
    writeln!(f, "this is not HLO").unwrap();
    let m2 = Manifest::load(&d).unwrap();
    let res = fasp::runtime::Artifact::load(&m2, "wanda_metric_64x64");
    assert!(res.is_err(), "garbage HLO must not load");
}

#[test]
fn truncated_checkpoint_rejected() {
    let m = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    let spec = m.model("opt_tiny").unwrap();
    let w = Weights::init(spec, 1);
    let path = std::env::temp_dir().join("fasp_fail_trunc.ftns");
    w.save(&path).unwrap();
    // truncate the file body
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(Weights::load(spec, &path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn checkpoint_for_wrong_model_rejected() {
    let m = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    let tiny = m.model("opt_tiny").unwrap();
    let small = m.model("opt_small").unwrap();
    let w = Weights::init(tiny, 1);
    let path = std::env::temp_dir().join("fasp_fail_wrongmodel.ftns");
    w.save(&path).unwrap();
    let err = match Weights::load(small, &path) {
        Err(e) => e,
        Ok(_) => panic!("wrong-model checkpoint accepted"),
    };
    assert!(format!("{err}").contains("checkpoint size"));
    std::fs::remove_file(&path).ok();
}

#[test]
fn tensorfile_wrong_magic_rejected() {
    let path = std::env::temp_dir().join("fasp_fail_magic.ftns");
    std::fs::write(&path, b"XXXX\x01\x00\x00\x00").unwrap();
    assert!(TensorFile::load(&path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn restoration_rejects_degenerate_gram() {
    // an indefinite "Gram" (can arise from corrupted stats) must error,
    // not return NaNs
    let w = Tensor::new(vec![1, 2], vec![1.0, 2.0]);
    let g = Tensor::new(vec![2, 2], vec![1.0, 2.0, 2.0, 1.0]); // indefinite
    let kept = vec![true, false];
    // delta too small to fix indefiniteness in the kept block? kept block
    // here is [1.0] which IS pd; craft a negative-diagonal case instead:
    let g_bad = Tensor::new(vec![2, 2], vec![-1.0, 0.0, 0.0, -1.0]);
    let res = fasp::prune::restore::restore_columns(&w, &g_bad, &kept, 1e-6);
    assert!(res.is_err(), "negative-definite gram accepted");
    let _ = g;
}

#[test]
fn sparsity_one_empties_groups_but_stays_finite() {
    let m = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    let session = Session::new(&m, "llama_tiny").unwrap();
    let spec = session.spec.clone();
    let w = Weights::init(&spec, 3);
    let ds = fasp::data::Dataset::new(
        fasp::data::Corpus::new(spec.vocab, 1),
        spec.batch,
        spec.seq,
        2,
    );
    let mut opts = fasp::prune::PruneOpts::new(fasp::prune::Method::Fasp, 0.99);
    opts.calib_batches = 1;
    // must not panic; ratios clamp at 1.0
    let (pw, _, rep) = fasp::prune::prune(&session, &w, &ds, &opts).unwrap();
    assert!(rep.achieved_sparsity <= 1.0);
    let out = session
        .fwd_loss(
            &session.pack(&pw.packed).unwrap(),
            &ds.train_batch(0).tokens,
            &ds.train_batch(0).targets,
        )
        .unwrap();
    assert!(out.mean_nll.is_finite());
}

#[test]
fn cli_rejects_unknown_method_and_command() {
    use fasp::cli::args::Args;
    let a = Args::parse(
        "prune --model x --method bogus"
            .split_whitespace()
            .map(str::to_string),
    )
    .unwrap();
    assert!(fasp::prune::Method::parse(a.get("method").unwrap()).is_none());
}

// ---- compact-artifact failure injection --------------------------------

/// Build a small valid compact artifact in `dir` and return its json path.
fn make_compact_artifact(dir: &std::path::Path, name: &str) -> std::path::PathBuf {
    let m = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    let spec = m.model("llama_tiny").unwrap().clone();
    let w = Weights::init(&spec, 3);
    let mut mask = fasp::model::PruneMask::full(&spec);
    for j in 0..16 {
        mask.layers[0].ffn[j] = false;
    }
    let cm = fasp::model::compact::compact_from_mask(&w, &mask, name).unwrap();
    fasp::model::compact::save_compact(dir, &cm).unwrap()
}

#[test]
fn truncated_compact_weights_rejected() {
    let d = tmpdir("compact_trunc");
    let jpath = make_compact_artifact(&d, "trunc_model");
    let wpath = d.join("trunc_model.ftns");
    let bytes = std::fs::read(&wpath).unwrap();
    std::fs::write(&wpath, &bytes[..bytes.len() / 3]).unwrap();
    let err = match fasp::model::compact::load_compact(&jpath) {
        Err(e) => e,
        Ok(_) => panic!("truncated compact weights accepted"),
    };
    let msg = format!("{err:#}");
    assert!(
        msg.contains("truncated") || msg.contains("corrupt"),
        "unhelpful truncation error: {msg}"
    );
}

#[test]
fn compact_dimension_mismatch_rejected() {
    let d = tmpdir("compact_dims");
    let jpath = make_compact_artifact(&d, "dims_model");
    // corrupt the spec: head_splits no longer sum to d_ov
    let text = std::fs::read_to_string(&jpath).unwrap();
    let bad = text.replacen("\"d_ov\": 64", "\"d_ov\": 63", 1);
    assert_ne!(bad, text, "fixture drifted: d_ov field not found");
    std::fs::write(&jpath, bad).unwrap();
    let err = match fasp::model::compact::load_compact(&jpath) {
        Err(e) => e,
        Ok(_) => panic!("dimension-mismatched compact spec accepted"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("dimension mismatch"), "unhelpful error: {msg}");
}

#[test]
fn compact_missing_weights_rejected_at_registration() {
    let d = tmpdir("compact_missing");
    let jpath = make_compact_artifact(&d, "missing_model");
    std::fs::remove_file(d.join("missing_model.ftns")).unwrap();
    let mut m = Manifest::load(&fasp::artifacts_dir()).expect("make artifacts");
    let err = match m.register_compact(&jpath) {
        Err(e) => e,
        Ok(_) => panic!("compact artifact with missing weights registered"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("missing"), "unhelpful error: {msg}");
    // and a manifest-dir scan with the same broken artifact fails loudly too
    std::fs::copy(
        fasp::artifacts_dir().join("manifest.json"),
        d.join("manifest.json"),
    )
    .unwrap();
    let cdir = d.join("compact");
    std::fs::create_dir_all(&cdir).unwrap();
    std::fs::rename(&jpath, cdir.join("missing_model.compact.json")).unwrap();
    assert!(Manifest::load(&d).is_err(), "scan accepted missing weights");
}
