//! Host reference model: internal consistency (no artifacts needed).
//! The PJRT cross-check lives in test_runtime; here we pin down host
//! model semantics on their own.

use fasp::data::{Corpus, Dataset};
use fasp::model::{host, Weights};
use fasp::runtime::manifest::ModelSpec;
use fasp::tensor::ops::{zero_cols, zero_elems, zero_rows};
use fasp::tensor::IntTensor;

fn spec(family: &str) -> ModelSpec {
    // self-contained spec (mirrors configs.py *_tiny but smaller seq)
    let d = 64;
    let f = 256;
    let v = 256;
    let mut params = vec![("tok_emb".to_string(), vec![v, d])];
    if family == "opt" {
        params.push(("pos_emb".into(), vec![16, d]));
    }
    for i in 0..2 {
        let p = format!("layers.{i}.");
        if family == "opt" {
            for (n, s) in [
                ("ln1_g", vec![d]), ("ln1_b", vec![d]),
                ("wq", vec![d, d]), ("bq", vec![d]),
                ("wk", vec![d, d]), ("bk", vec![d]),
                ("wv", vec![d, d]), ("bv", vec![d]),
                ("wo", vec![d, d]), ("bo", vec![d]),
                ("ln2_g", vec![d]), ("ln2_b", vec![d]),
                ("fc1", vec![f, d]), ("bfc1", vec![f]),
                ("fc2", vec![d, f]), ("bfc2", vec![d]),
            ] {
                params.push((format!("{p}{n}"), s));
            }
        } else {
            for (n, s) in [
                ("ln1_g", vec![d]),
                ("wq", vec![d, d]), ("wk", vec![d, d]),
                ("wv", vec![d, d]), ("wo", vec![d, d]), ("bo", vec![d]),
                ("ln2_g", vec![d]),
                ("w_gate", vec![f, d]), ("w_up", vec![f, d]),
                ("w_down", vec![d, f]), ("b_down", vec![d]),
            ] {
                params.push((format!("{p}{n}"), s));
            }
        }
    }
    params.push(("lnf_g".into(), vec![d]));
    if family == "opt" {
        params.push(("lnf_b".into(), vec![d]));
    }
    ModelSpec {
        name: format!("host_{family}"),
        family: family.into(),
        d_model: d,
        n_heads: 4,
        n_layers: 2,
        d_ff: f,
        vocab: v,
        seq: 16,
        batch: 2,
        params,
        layer_dims: vec![],
    }
}

fn batch(spec: &ModelSpec, seed: u64) -> (IntTensor, IntTensor) {
    let ds = Dataset::new(Corpus::new(spec.vocab, seed), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);
    (b.tokens, b.targets)
}

#[test]
fn random_model_nll_near_uniform() {
    for fam in ["opt", "llama"] {
        let s = spec(fam);
        let w = Weights::init(&s, 3);
        let (toks, tgts) = batch(&s, 1);
        let nll = host::mean_nll(&w, &toks, &tgts).unwrap();
        let uniform = (s.vocab as f32).ln();
        assert!(
            (nll - uniform).abs() < 0.5,
            "{fam}: random-init NLL {nll} vs log V {uniform}"
        );
    }
}

#[test]
fn causality_future_tokens_do_not_matter() {
    // changing tokens after position t must not change NLL at positions < t
    for fam in ["opt", "llama"] {
        let s = spec(fam);
        let w = Weights::init(&s, 5);
        let (toks, tgts) = batch(&s, 2);
        let (nll_a, _) = host::forward_nll(&w, &toks, &tgts, false).unwrap();
        let mut toks_b = toks.clone();
        let t = s.seq;
        // mutate the last 4 tokens of each row
        for b in 0..s.batch {
            for i in t - 4..t {
                toks_b.data[b * t + i] = (toks_b.data[b * t + i] + 7) % s.vocab as i32;
            }
        }
        let (nll_b, _) = host::forward_nll(&w, &toks_b, &tgts, false).unwrap();
        for b in 0..s.batch {
            for i in 0..t - 5 {
                let d = (nll_a.data[b * t + i] - nll_b.data[b * t + i]).abs();
                assert!(d < 1e-4, "{fam}: future leak at ({b},{i}): {d}");
            }
        }
    }
}

#[test]
fn coupled_zeroing_exactness_host() {
    // §3.1 exactness on the host model for BOTH families and BOTH groups
    for fam in ["opt", "llama"] {
        let s = spec(fam);
        let base = Weights::init(&s, 8);
        let (toks, tgts) = batch(&s, 3);

        // FFN group
        let later = if fam == "opt" { "fc2" } else { "w_down" };
        let mut w1 = base.clone();
        let mut t = w1.get_l(0, later).unwrap();
        zero_cols(&mut t, &[3, 17]);
        w1.set_l(0, later, &t).unwrap();
        let l1 = host::mean_nll(&w1, &toks, &tgts).unwrap();

        let mut w2 = w1.clone();
        if fam == "opt" {
            let mut fc1 = w2.get_l(0, "fc1").unwrap();
            zero_rows(&mut fc1, &[3, 17]);
            w2.set_l(0, "fc1", &fc1).unwrap();
            let mut b1 = w2.get_l(0, "bfc1").unwrap();
            zero_elems(&mut b1, &[3, 17]);
            w2.set_l(0, "bfc1", &b1).unwrap();
        } else {
            for n in ["w_gate", "w_up"] {
                let mut m = w2.get_l(0, n).unwrap();
                zero_rows(&mut m, &[3, 17]);
                w2.set_l(0, n, &m).unwrap();
            }
        }
        let l2 = host::mean_nll(&w2, &toks, &tgts).unwrap();
        assert!((l1 - l2).abs() < 1e-5, "{fam} ffn: {l1} vs {l2}");

        // OV group
        let mut w3 = base.clone();
        let mut wo = w3.get_l(1, "wo").unwrap();
        zero_cols(&mut wo, &[2, 9]);
        w3.set_l(1, "wo", &wo).unwrap();
        let l3 = host::mean_nll(&w3, &toks, &tgts).unwrap();
        let mut w4 = w3.clone();
        let mut wv = w4.get_l(1, "wv").unwrap();
        zero_rows(&mut wv, &[2, 9]);
        w4.set_l(1, "wv", &wv).unwrap();
        if fam == "opt" {
            let mut bv = w4.get_l(1, "bv").unwrap();
            zero_elems(&mut bv, &[2, 9]);
            w4.set_l(1, "bv", &bv).unwrap();
        }
        let l4 = host::mean_nll(&w4, &toks, &tgts).unwrap();
        assert!((l3 - l4).abs() < 1e-5, "{fam} ov: {l3} vs {l4}");
    }
}

#[test]
fn rope_pair_zeroing_exactness_llama() {
    // zeroing both members of a RoPE pair in wq/wk rows must equal the
    // effect of removing those q/k dims entirely: verified by comparing
    // against zeroing them + arbitrary perturbation of the removed rows
    // in the OTHER matrix (their contribution must be dead).
    let s = spec("llama");
    let base = Weights::init(&s, 12);
    let (toks, tgts) = batch(&s, 4);
    let pairs = fasp::prune::structure::rope_pairs(s.d_model, s.n_heads);
    let (a, b) = pairs[3];

    let mut w1 = base.clone();
    for n in ["wq", "wk"] {
        let mut m = w1.get_l(0, n).unwrap();
        zero_rows(&mut m, &[a, b]);
        w1.set_l(0, n, &m).unwrap();
    }
    let l1 = host::mean_nll(&w1, &toks, &tgts).unwrap();

    // perturb the zeroed wk rows' *columns* in wq — dead dims must stay dead
    let mut w2 = w1.clone();
    let mut wk = w2.get_l(0, "wk").unwrap();
    // fill the zeroed rows with garbage, then re-zero wq rows: attention
    // score contribution q_a k_a + q_b k_b must be 0 because q rows are 0.
    for &r in &[a, b] {
        for c in 0..s.d_model {
            *wk.at2_mut(r, c) = 123.0;
        }
    }
    w2.set_l(0, "wk", &wk).unwrap();
    let l2 = host::mean_nll(&w2, &toks, &tgts).unwrap();
    assert!((l1 - l2).abs() < 1e-4, "dead q/k dims leaked: {l1} vs {l2}");
}

#[test]
fn checkpoint_roundtrip() {
    let s = spec("llama");
    let w = Weights::init(&s, 77);
    let path = std::env::temp_dir().join("fasp_ckpt_test.ftns");
    w.save(&path).unwrap();
    let re = Weights::load(&s, &path).unwrap();
    assert_eq!(re.packed, w.packed);
    std::fs::remove_file(&path).ok();
}

#[test]
fn weights_get_set_roundtrip() {
    let s = spec("opt");
    let mut w = Weights::init(&s, 1);
    let mut t = w.get_l(0, "wq").unwrap();
    t.data[5] = 42.0;
    w.set_l(0, "wq", &t).unwrap();
    assert_eq!(w.get_l(0, "wq").unwrap().data[5], 42.0);
    // shape mismatch rejected
    let bad = fasp::tensor::Tensor::zeros(&[2, 2]);
    assert!(w.set_l(0, "wq", &bad).is_err());
    assert!(w.get("nonexistent").is_err());
}
