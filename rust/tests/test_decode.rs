//! Decode-path contract: KV-cached `decode_step` logits are
//! **bit-identical** to a full-prefix re-forward at every position, on
//! both families, at every pool width, from every weight source (dense,
//! compact, sharded streaming) — plus cache failure injection (overflow
//! past capacity, mismatched layer dims, batch mismatch) and sampling
//! determinism. The cross-source generation tests require `make
//! artifacts`; the core bit-identity tests run on toy specs.

use fasp::model::compact::{build_params, compact_from_mask, CompactModel};
use fasp::model::decode::{
    self, decode_step_src, full_logits, prefill_src, GenerateOpts, KvCache, Sampler,
};
use fasp::model::{DenseParams, PackedWeights, PruneMask, Weights};
use fasp::runtime::manifest::LayerDims;
use fasp::runtime::{HostBackend, Manifest, ModelSpec, Session, ThreadedHostBackend};
use fasp::tensor::{IntTensor, Tensor};
use fasp::util::pool;
use fasp::util::rng::Rng;
use std::sync::Arc;

fn bits_eq(a: &Tensor, b: &Tensor) -> bool {
    a.shape == b.shape
        && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Toy spec with ragged (compact-style) per-layer dims, including one
/// fully sliced head, so the decode path is exercised exactly where the
/// OV slicing bites.
fn toy_spec(family: &str) -> ModelSpec {
    let layer_dims = vec![
        LayerDims { d_ff: 20, d_ov: 10, head_splits: vec![6, 4] },
        LayerDims { d_ff: 12, d_ov: 5, head_splits: vec![5, 0] },
        LayerDims { d_ff: 16, d_ov: 16, head_splits: vec![8, 8] },
    ];
    let params = build_params(family, 16, 3, 48, 24, &layer_dims);
    ModelSpec {
        name: format!("decode_toy_{family}"),
        family: family.into(),
        d_model: 16,
        n_heads: 2,
        n_layers: 3,
        d_ff: 20,
        vocab: 48,
        seq: 24,
        batch: 2,
        params,
        layer_dims,
    }
}

fn random_prompt(b: usize, t: usize, vocab: usize, seed: u64) -> IntTensor {
    let mut rng = Rng::new(seed);
    IntTensor::new(
        vec![b, t],
        (0..b * t).map(|_| rng.below(vocab) as i32).collect(),
    )
}

/// Teacher-force a prompt through the cached path, comparing logits
/// against the cache-free full re-forward at every position.
fn assert_decode_matches_reforward(spec: &ModelSpec, workers: usize) {
    let w = Weights::init(spec, 21);
    let b = 2;
    let t_total = 10;
    let t0 = 4;
    let prompt = random_prompt(b, t_total, spec.vocab, 99);
    let _g = pool::enter(Arc::new(pool::Pool::new(workers)));

    let mut cache = KvCache::for_spec(spec, b, t_total).unwrap();
    let prefix = IntTensor::new(vec![b, t0], {
        let mut v = Vec::new();
        for bi in 0..b {
            v.extend_from_slice(&prompt.data[bi * t_total..bi * t_total + t0]);
        }
        v
    });
    let mut logits = prefill_src(&mut DenseParams(&w), &prefix, &mut cache).unwrap();
    assert_eq!(cache.len(), t0);
    for p in t0..t_total {
        // cached logits after consuming positions 0..p-1 must equal the
        // full re-forward over the same prefix, bit for bit
        let full_prefix = IntTensor::new(vec![b, p], {
            let mut v = Vec::new();
            for bi in 0..b {
                v.extend_from_slice(&prompt.data[bi * t_total..bi * t_total + p]);
            }
            v
        });
        let reforward = full_logits(&mut DenseParams(&w), &full_prefix).unwrap();
        assert!(
            bits_eq(&logits, &reforward),
            "{} (w={workers}): cached logits diverged from re-forward at \
             prefix {p}",
            spec.name
        );
        let step = IntTensor::new(vec![b, 1], {
            (0..b).map(|bi| prompt.data[bi * t_total + p]).collect()
        });
        logits = decode_step_src(&mut DenseParams(&w), &step, &mut cache).unwrap();
        assert_eq!(cache.len(), p + 1);
    }
    let reforward = full_logits(&mut DenseParams(&w), &prompt).unwrap();
    assert!(
        bits_eq(&logits, &reforward),
        "{} (w={workers}): final cached logits diverged",
        spec.name
    );
}

#[test]
fn decode_bitwise_matches_full_reforward_both_families() {
    for family in ["llama", "opt"] {
        let spec = toy_spec(family);
        for workers in [1usize, 4] {
            assert_decode_matches_reforward(&spec, workers);
        }
    }
}

#[test]
fn decode_bit_identical_across_pool_widths() {
    let spec = toy_spec("llama");
    let w = Weights::init(&spec, 5);
    let prompt = random_prompt(2, 6, spec.vocab, 3);
    let run = |workers: usize| -> (IntTensor, Tensor) {
        let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
        let gen = decode::generate_src(
            &mut DenseParams(&w),
            &prompt,
            &GenerateOpts { max_new: 6, sampler: Sampler::Greedy, seed: 0 },
        )
        .unwrap();
        let mut cache = KvCache::for_spec(&spec, 2, 6).unwrap();
        let logits = prefill_src(&mut DenseParams(&w), &prompt, &mut cache).unwrap();
        (gen.tokens, logits)
    };
    let (t1, l1) = run(1);
    for workers in [2usize, 4, 8] {
        let (t2, l2) = run(workers);
        assert_eq!(t1.data, t2.data, "tokens diverged at {workers} workers");
        assert!(bits_eq(&l1, &l2), "prefill logits diverged at {workers} workers");
    }
}

/// The packed operator plan decodes bit-identically to the unpacked
/// source at every position — prefill, steps and the re-forward all
/// agree across pool widths (the packed≡unpacked decode contract on the
/// ragged toy spec, where compact slicing actually bites).
#[test]
fn packed_decode_bit_identical_to_unpacked() {
    for family in ["llama", "opt"] {
        let spec = toy_spec(family);
        let w = Weights::init(&spec, 37);
        let prompt = random_prompt(2, 7, spec.vocab, 51);
        for workers in [1usize, 4] {
            let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
            let pw = PackedWeights::new(w.clone());

            let mut cache_p = KvCache::for_spec(&spec, 2, 9).unwrap();
            let mut cache_u = KvCache::for_spec(&spec, 2, 9).unwrap();
            let lp = prefill_src(&mut pw.source(), &prompt, &mut cache_p).unwrap();
            let lu = prefill_src(&mut DenseParams(&w), &prompt, &mut cache_u).unwrap();
            assert!(bits_eq(&lp, &lu), "{family} (w={workers}): packed prefill diverged");

            let step = IntTensor::new(vec![2, 1], vec![3, 5]);
            let sp = decode_step_src(&mut pw.source(), &step, &mut cache_p).unwrap();
            let su = decode_step_src(&mut DenseParams(&w), &step, &mut cache_u).unwrap();
            assert!(bits_eq(&sp, &su), "{family} (w={workers}): packed step diverged");

            // the cache-free full forward agrees too (packed full_logits)
            let fp = full_logits(&mut pw.source(), &prompt).unwrap();
            let fu = full_logits(&mut DenseParams(&w), &prompt).unwrap();
            assert!(bits_eq(&fp, &fu), "{family} (w={workers}): packed full_logits diverged");
        }
    }
}

#[test]
fn generation_appends_prompt_and_counts_phases() {
    let spec = toy_spec("opt");
    let w = Weights::init(&spec, 9);
    let prompt = random_prompt(3, 5, spec.vocab, 17);
    let gen = decode::generate_src(
        &mut DenseParams(&w),
        &prompt,
        &GenerateOpts { max_new: 4, sampler: Sampler::Greedy, seed: 0 },
    )
    .unwrap();
    assert_eq!(gen.tokens.shape, vec![3, 9]);
    assert_eq!(gen.prompt_len, 5);
    assert_eq!(gen.generated, 4);
    assert_eq!(gen.steps, 3, "last sampled token needs no forward");
    for bi in 0..3 {
        assert_eq!(
            &gen.tokens.data[bi * 9..bi * 9 + 5],
            &prompt.data[bi * 5..(bi + 1) * 5],
            "row {bi} prompt not preserved"
        );
        for &tok in &gen.tokens.data[bi * 9 + 5..(bi + 1) * 9] {
            assert!(tok >= 0 && (tok as usize) < spec.vocab);
        }
    }
    assert!(gen.kv_bytes > 0);
}

#[test]
fn topk_generation_is_seed_deterministic() {
    let spec = toy_spec("llama");
    let w = Weights::init(&spec, 31);
    let prompt = random_prompt(2, 4, spec.vocab, 8);
    let opts = GenerateOpts {
        max_new: 6,
        sampler: Sampler::TopK { k: 5, temperature: 0.8 },
        seed: 1234,
    };
    let a = decode::generate_src(&mut DenseParams(&w), &prompt, &opts).unwrap();
    let b = decode::generate_src(&mut DenseParams(&w), &prompt, &opts).unwrap();
    assert_eq!(a.tokens.data, b.tokens.data, "same seed must replay");
    // greedy == top-1 on the same logits
    let g = decode::generate_src(
        &mut DenseParams(&w),
        &prompt,
        &GenerateOpts { max_new: 6, sampler: Sampler::Greedy, seed: 0 },
    )
    .unwrap();
    let t1 = decode::generate_src(
        &mut DenseParams(&w),
        &prompt,
        &GenerateOpts {
            max_new: 6,
            sampler: Sampler::TopK { k: 1, temperature: 0.5 },
            seed: 777,
        },
    )
    .unwrap();
    assert_eq!(g.tokens.data, t1.tokens.data, "top-1 must equal greedy");
}

// ----------------------------------------------------------- failure modes

#[test]
fn cache_overflow_and_mismatch_are_loud() {
    let spec = toy_spec("llama");
    let w = Weights::init(&spec, 2);
    let b = 2;

    // prompt longer than capacity
    let mut cache = KvCache::for_spec(&spec, b, 4).unwrap();
    let long = random_prompt(b, 5, spec.vocab, 1);
    let err = prefill_src(&mut DenseParams(&w), &long, &mut cache).unwrap_err();
    assert!(format!("{err:#}").contains("overflow"), "{err:#}");

    // stepping past capacity
    let short = random_prompt(b, 4, spec.vocab, 2);
    prefill_src(&mut DenseParams(&w), &short, &mut cache).unwrap();
    let step = IntTensor::new(vec![b, 1], vec![1; b]);
    let err = decode_step_src(&mut DenseParams(&w), &step, &mut cache).unwrap_err();
    assert!(
        format!("{err:#}").contains("overflow"),
        "capacity exhaustion must be loud: {err:#}"
    );

    // prefill into a non-empty cache
    let err = prefill_src(&mut DenseParams(&w), &short, &mut cache).unwrap_err();
    assert!(format!("{err:#}").contains("empty cache"), "{err:#}");
    cache.clear();
    assert_eq!(cache.len(), 0);
    prefill_src(&mut DenseParams(&w), &short, &mut cache).unwrap();

    // cache built for a different spec (other per-layer dims)
    let other = {
        let mut s = toy_spec("llama");
        s.layer_dims[1] = LayerDims { d_ff: 12, d_ov: 4, head_splits: vec![2, 2] };
        s.params =
            build_params("llama", s.d_model, s.n_layers, s.vocab, s.seq, &s.layer_dims);
        s
    };
    let mut wrong = KvCache::for_spec(&other, b, 8).unwrap();
    let err = prefill_src(&mut DenseParams(&w), &short, &mut wrong).unwrap_err();
    assert!(
        format!("{err:#}").contains("mismatch"),
        "mismatched layer dims must be loud: {err:#}"
    );

    // batch mismatch
    let mut cache3 = KvCache::for_spec(&spec, 3, 8).unwrap();
    let err = prefill_src(&mut DenseParams(&w), &short, &mut cache3).unwrap_err();
    assert!(format!("{err:#}").contains("batch"), "{err:#}");

    // token id outside vocab
    let mut cache = KvCache::for_spec(&spec, b, 8).unwrap();
    let bad = IntTensor::new(vec![b, 2], vec![0, 1, 2, spec.vocab as i32]);
    let err = prefill_src(&mut DenseParams(&w), &bad, &mut cache).unwrap_err();
    assert!(format!("{err:#}").contains("vocab"), "{err:#}");
}

#[test]
fn opt_cache_capacity_bounded_by_learned_positions() {
    let spec = toy_spec("opt");
    let err = KvCache::for_spec(&spec, 1, spec.seq + 1).unwrap_err();
    assert!(format!("{err:#}").contains("position"), "{err:#}");
    KvCache::for_spec(&spec, 1, spec.seq).unwrap();
}

#[test]
fn kv_bytes_shrink_with_sliced_ov() {
    // same capacity: the toy spec (d_ov 10/5/16 of 16) must hold a
    // strictly smaller value cache than its dense-uniform counterpart
    let sliced = toy_spec("llama");
    let dense = {
        let mut s = toy_spec("llama");
        s.name = "decode_toy_dense".into();
        s.layer_dims = (0..s.n_layers)
            .map(|_| LayerDims { d_ff: 20, d_ov: 16, head_splits: vec![8, 8] })
            .collect();
        s.params =
            build_params("llama", s.d_model, s.n_layers, s.vocab, s.seq, &s.layer_dims);
        s
    };
    let cs = KvCache::for_spec(&sliced, 2, 12).unwrap();
    let cd = KvCache::for_spec(&dense, 2, 12).unwrap();
    assert!(
        cs.kv_bytes() < cd.kv_bytes(),
        "sliced kv {} !< dense kv {}",
        cs.kv_bytes(),
        cd.kv_bytes()
    );
    assert_eq!(cs.capacity(), 12);
    assert_eq!(cs.batch(), 2);
}

// ------------------------------------------ cross-backend / cross-source

fn manifest() -> Manifest {
    Manifest::load(&fasp::artifacts_dir()).expect("run `make artifacts` first")
}

/// Greedy generations must be identical across `HostBackend` /
/// `ThreadedHostBackend` and across the three weight sources: the dense
/// zoo model, its (bit-identical) sparsity-0 compact export, and the
/// sharded streaming store of that export.
#[test]
fn generate_identical_across_backends_and_sources() {
    let mut m = manifest();
    let model = "llama_tiny";
    let spec = m.model(model).unwrap().clone();
    let w = Weights::init(&spec, 7);

    // sparsity-0 compact export: packed bytes are bit-identical to the
    // dense weights (locked in by test_compact), sharded on disk
    let mask = PruneMask::full(&spec);
    let cm = compact_from_mask(&w, &mask, "decode_src_id").unwrap();
    let dir = std::env::temp_dir().join("fasp_test_decode_sources");
    let _ = std::fs::remove_dir_all(&dir);
    let jp = fasp::model::compact::save_compact_sharded(&dir, &cm).unwrap();
    m.register_compact(&jp).unwrap();
    let store = m.compact_store("decode_src_id").unwrap();
    let cw = m.compact_weights("decode_src_id").unwrap();
    assert_eq!(w.packed.data, cw.packed.data, "s=0 export must be bit-identical");

    let prompt = random_prompt(2, 6, spec.vocab, 42);
    let opts = GenerateOpts { max_new: 8, sampler: Sampler::Greedy, seed: 0 };

    let dense_single =
        Session::with_backend(&m, model, Arc::new(HostBackend::new())).unwrap();
    let dense_threaded =
        Session::with_backend(&m, model, Arc::new(ThreadedHostBackend::new(4))).unwrap();
    let compact_single =
        Session::with_backend(&m, "decode_src_id", Arc::new(HostBackend::new())).unwrap();
    let compact_threaded =
        Session::with_backend(&m, "decode_src_id", Arc::new(ThreadedHostBackend::new(4)))
            .unwrap();

    // decode runs over the session's packed operator plan (packed once
    // per session here); generations must still be identical to every
    // other source — the packed≡unpacked decode contract
    let base = dense_single
        .generate(&dense_single.pack(&w.packed).unwrap(), &prompt, &opts)
        .unwrap();
    let runs = [
        (
            "dense/threaded",
            dense_threaded
                .generate(&dense_threaded.pack(&w.packed).unwrap(), &prompt, &opts)
                .unwrap(),
        ),
        (
            "compact/host",
            compact_single
                .generate(&compact_single.pack(&cw.packed).unwrap(), &prompt, &opts)
                .unwrap(),
        ),
        (
            "compact/threaded",
            compact_threaded
                .generate(&compact_threaded.pack(&cw.packed).unwrap(), &prompt, &opts)
                .unwrap(),
        ),
        (
            "sharded/host",
            compact_single.generate_streamed(&store, &prompt, &opts).unwrap(),
        ),
        (
            "sharded/threaded",
            compact_threaded.generate_streamed(&store, &prompt, &opts).unwrap(),
        ),
    ];
    for (label, gen) in &runs {
        assert_eq!(
            base.tokens.data, gen.tokens.data,
            "{label}: greedy generation diverged from dense/host"
        );
    }
    // identical dims → identical cache footprint across sources
    for (label, gen) in &runs {
        assert_eq!(base.kv_bytes, gen.kv_bytes, "{label}: kv bytes");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Session-level decode entries validate their inputs (wrong-model
/// weights, wrong-vocab prompt) and agree with the host-level path.
#[test]
fn session_decode_contracts() {
    let m = manifest();
    let model = "llama_tiny";
    let session = Session::with_backend(&m, model, Arc::new(HostBackend::new())).unwrap();
    let spec = session.spec.clone();
    let w = Weights::init(&spec, 3);
    let prompt = random_prompt(1, 5, spec.vocab, 6);

    // session path (packed operator plan) == host path (unpacked
    // DenseParams), bit for bit — the packed≡unpacked decode receipt
    let pp = session.pack(&w.packed).unwrap();
    let mut cache = session.decode_cache(1, 8).unwrap();
    let s_logits = session.prefill(&pp, &prompt, &mut cache).unwrap();
    let mut cache_h = KvCache::for_spec(&spec, 1, 8).unwrap();
    let h_logits = prefill_src(&mut DenseParams(&w), &prompt, &mut cache_h).unwrap();
    assert!(bits_eq(&s_logits, &h_logits));
    let step = IntTensor::new(vec![1, 1], vec![1]);
    let s2 = session.decode_step(&pp, &step, &mut cache).unwrap();
    let h2 = decode_step_src(&mut DenseParams(&w), &step, &mut cache_h).unwrap();
    assert!(bits_eq(&s2, &h2));

    // wrong-model params rejected (packed on the other model's session)
    let other_session =
        Session::with_backend(&m, "opt_tiny", Arc::new(HostBackend::new())).unwrap();
    let other_spec = m.model("opt_tiny").unwrap().clone();
    let other_w = Weights::init(&other_spec, 3);
    let other_pp = other_session.pack(&other_w.packed).unwrap();
    let mut cache2 = session.decode_cache(1, 8).unwrap();
    assert!(session.prefill(&other_pp, &prompt, &mut cache2).is_err());

    // out-of-vocab prompt rejected before any compute
    let bad = IntTensor::new(vec![1, 2], vec![0, spec.vocab as i32]);
    let mut cache3 = session.decode_cache(1, 8).unwrap();
    assert!(session.prefill(&pp, &bad, &mut cache3).is_err());
}

/// A *sliced* (sparsity > 0) compact model decodes from a strictly
/// smaller KV cache than its dense base at the same capacity, and its
/// monolithic-vs-sharded generations still agree token for token.
#[test]
fn sliced_compact_decode_shrinks_kv_and_streams_identically() {
    let mut m = manifest();
    let model = "llama_tiny";
    let spec = m.model(model).unwrap().clone();
    let w = Weights::init(&spec, 19);
    let dh = spec.head_dim();
    let mut mask = PruneMask::full(&spec);
    for l in 0..spec.n_layers {
        for hi in 0..spec.n_heads {
            for j in 0..dh / 2 {
                mask.layers[l].ov[hi * dh + j * 2] = false;
            }
        }
        for j in 0..spec.d_ff / 3 {
            mask.layers[l].ffn[j * 3] = false;
        }
    }
    let cm: CompactModel = compact_from_mask(&w, &mask, "decode_sliced").unwrap();
    let dir = std::env::temp_dir().join("fasp_test_decode_sliced");
    let _ = std::fs::remove_dir_all(&dir);
    let jp = fasp::model::compact::save_compact_sharded(&dir, &cm).unwrap();
    m.register_compact(&jp).unwrap();
    let cw = m.compact_weights("decode_sliced").unwrap();
    let store = m.compact_store("decode_sliced").unwrap();

    let prompt = random_prompt(2, 6, spec.vocab, 23);
    let opts = GenerateOpts { max_new: 5, sampler: Sampler::Greedy, seed: 0 };
    let ds = Session::with_backend(&m, model, Arc::new(HostBackend::new())).unwrap();
    let cs =
        Session::with_backend(&m, "decode_sliced", Arc::new(HostBackend::new())).unwrap();
    let dense_gen = ds.generate(&ds.pack(&w.packed).unwrap(), &prompt, &opts).unwrap();
    let compact_gen = cs.generate(&cs.pack(&cw.packed).unwrap(), &prompt, &opts).unwrap();
    let streamed_gen = cs.generate_streamed(&store, &prompt, &opts).unwrap();
    assert!(
        compact_gen.kv_bytes < dense_gen.kv_bytes,
        "sliced compact kv {} !< dense kv {}",
        compact_gen.kv_bytes,
        dense_gen.kv_bytes
    );
    assert_eq!(
        compact_gen.tokens.data, streamed_gen.tokens.data,
        "sliced compact: resident vs streamed generations diverged"
    );
    // decoded tokens stay in-vocab even on the sliced model
    for &t in &compact_gen.tokens.data {
        assert!(t >= 0 && (t as usize) < spec.vocab);
    }
    std::fs::remove_dir_all(&dir).ok();
}
