//! Integration over the pure-host substrates (no artifacts needed):
//! linalg vs tensor ops, JSON round-trips of realistic payloads, the
//! bench harness, masks and structure planning.

use fasp::linalg::{admm_restore, jacobi_eigh, solve_posdef};
use fasp::model::mask::{kept_indices, pruned_indices, prunable_params, PruneMask};
use fasp::prune::restore::{recon_objective, restore_columns};
use fasp::prune::structure::{plan, unit_costs};
use fasp::runtime::manifest::ModelSpec;
use fasp::tensor::matmul::matmul;
use fasp::tensor::Tensor;
use fasp::util::json::Json;
use fasp::util::rng::Rng;

fn toy_spec(family: &str) -> ModelSpec {
    ModelSpec {
        name: format!("{family}_toy"),
        family: family.into(),
        d_model: 32,
        n_heads: 4,
        n_layers: 2,
        d_ff: 64,
        vocab: 64,
        seq: 16,
        batch: 2,
        params: vec![],
            layer_dims: vec![],
    }
}

#[test]
fn restoration_is_optimal_among_candidates() {
    // the closed-form solution must beat any perturbed candidate
    let mut rng = Rng::new(5);
    let (m, n, s) = (6, 12, 48);
    let w = Tensor::randn(&[m, n], 1.0, &mut rng);
    let x = Tensor::randn(&[s, n], 1.0, &mut rng);
    let g = matmul(&x.t(), &x);
    let kept: Vec<bool> = (0..n).map(|j| j % 3 != 1).collect();
    let opt = restore_columns(&w, &g, &kept, 1e-8).unwrap();
    let base = recon_objective(&opt, &w, &g);
    for trial in 0..10 {
        let mut cand = opt.clone();
        let mut r2 = Rng::new(100 + trial);
        for v in cand.data.iter_mut() {
            *v += (r2.f32() - 0.5) * 0.05;
        }
        // keep the support constraint
        for i in 0..m {
            for j in 0..n {
                if !kept[j] {
                    *cand.at2_mut(i, j) = 0.0;
                }
            }
        }
        let c = recon_objective(&cand, &w, &g);
        assert!(c >= base - 1e-6, "perturbation beat the optimum: {c} < {base}");
    }
}

#[test]
fn admm_matches_closed_form_given_iterations() {
    let mut rng = Rng::new(7);
    let (m, n, s) = (4, 10, 60);
    let w = Tensor::randn(&[m, n], 1.0, &mut rng);
    let x = Tensor::randn(&[s, n], 1.0, &mut rng);
    let g32 = matmul(&x.t(), &x);
    let g: Vec<f64> = g32.data.iter().map(|&v| v as f64).collect();
    let kept: Vec<bool> = (0..n).map(|j| j != 0 && j != 5).collect();
    let mut greg = g.clone();
    for i in 0..n {
        greg[i * n + i] += 1e-6;
    }
    let (w_admm, _) = admm_restore(&w, &greg, &kept, 50.0, 500).unwrap();
    let w_cf = restore_columns(&w, &g32, &kept, 1e-9).unwrap();
    let diff = w_admm.max_abs_diff(&w_cf);
    assert!(diff < 5e-2, "ADMM far from closed form: {diff}");
    // and closed form is never worse on the objective
    let o_admm = recon_objective(&w_admm, &w, &g32);
    let o_cf = recon_objective(&w_cf, &w, &g32);
    assert!(o_cf <= o_admm + 1e-6, "{o_cf} vs {o_admm}");
}

#[test]
fn eigh_solves_match() {
    // A x = b solved via eigendecomposition must match cholesky solve
    let mut rng = Rng::new(9);
    let n = 16;
    let x = Tensor::randn(&[40, n], 1.0, &mut rng);
    let g32 = matmul(&x.t(), &x);
    let mut a: Vec<f64> = g32.data.iter().map(|&v| v as f64).collect();
    for i in 0..n {
        a[i * n + i] += 1.0;
    }
    let b: Vec<f64> = (0..n).map(|i| i as f64 * 0.3 - 1.0).collect();
    let x_chol = solve_posdef(&a, n, &b).unwrap();
    let (w, v) = jacobi_eigh(&a, n);
    // x = Σ_k (v_k·b / λ_k) v_k
    let mut x_eig = vec![0.0f64; n];
    for k in 0..n {
        let vk = &v[k * n..(k + 1) * n];
        let coef: f64 = vk.iter().zip(&b).map(|(a, b)| a * b).sum::<f64>() / w[k];
        for i in 0..n {
            x_eig[i] += coef * vk[i];
        }
    }
    for i in 0..n {
        assert!((x_chol[i] - x_eig[i]).abs() < 1e-7, "i={i}");
    }
}

#[test]
fn json_handles_experiment_payloads() {
    let payload = Json::obj(vec![
        ("model", Json::Str("llama_small".into())),
        ("ppl", Json::Num(12.345678)),
        ("curve", Json::arr_f64(&[1.0, 0.5, 0.25])),
        (
            "phases",
            Json::obj(vec![("capture", Json::Num(0.12)), ("solve", Json::Num(0.03))]),
        ),
        ("notes", Json::Str("line1\nline2 \"quoted\"".into())),
    ]);
    let text = payload.pretty();
    let re = Json::parse(&text).unwrap();
    assert_eq!(re, payload);
    assert_eq!(re.get("phases").get("solve").as_f64().unwrap(), 0.03);
}

#[test]
fn mask_accounting_consistent_with_unit_costs() {
    for fam in ["opt", "llama"] {
        let spec = toy_spec(fam);
        let mut mask = PruneMask::full(&spec);
        // prune 8 ffn units in layer 0, 4 ov dims in layer 1
        for j in 0..8 {
            mask.layers[0].ffn[j] = false;
        }
        for j in 0..4 {
            mask.layers[1].ov[j] = false;
        }
        let (ffn_c, ov_c, _) = unit_costs(&spec);
        assert_eq!(mask.params_removed(&spec), 8 * ffn_c + 4 * ov_c, "{fam}");
        assert!(mask.sparsity(&spec) > 0.0);
        assert!(mask.sparsity(&spec) < 1.0);
        mask.validate(&spec).unwrap();
    }
}

#[test]
fn plan_respects_pool_size() {
    for fam in ["opt", "llama"] {
        let spec = toy_spec(fam);
        let p = plan(&spec, 0.25, false);
        // removing the planned units must match 25% of the pool
        let (ffn_c, ov_c, _) = unit_costs(&spec);
        let removed = (p.ffn_ratio * spec.d_ff as f64 * ffn_c as f64
            + p.ov_ratio * spec.d_model as f64 * ov_c as f64)
            * spec.n_layers as f64;
        let frac = removed / prunable_params(&spec) as f64;
        assert!((frac - 0.25).abs() < 1e-9, "{fam}: {frac}");
    }
}

#[test]
fn kept_pruned_partition() {
    let mask = vec![true, false, true, false, false];
    let k = kept_indices(&mask);
    let p = pruned_indices(&mask);
    assert_eq!(k, vec![0, 2]);
    assert_eq!(p, vec![1, 3, 4]);
    assert_eq!(k.len() + p.len(), mask.len());
}

#[test]
fn bench_harness_runs() {
    let mut b = fasp::bench_support::Bencher {
        min_samples: 3,
        budget_s: 0.05,
        results: vec![],
    };
    let mut acc = 0u64;
    b.bench("spin", || {
        for i in 0..1000u64 {
            acc = acc.wrapping_add(i);
        }
        std::hint::black_box(acc);
    });
    assert!(b.results[0].mean_s() >= 0.0);
    assert!(b.last_throughput(1000) > 0.0);
}
