//! Compact-export test matrix (see rust/tests/README.md):
//! the physically sliced model must be a faithful, loadable, *faster*
//! stand-in for the masked dense model.
//!
//! * round-trip: compact → save → manifest register → session load →
//!   forward/perplexity parity with the masked model (±1e-3);
//! * property: random masks → compact forward equals masked forward to
//!   1e-5 (both families);
//! * identity: sparsity-0 export is bit-identical;
//! * speed: compact latency strictly below dense at sparsity ≥ 0.3.

use fasp::data::{Corpus, Dataset};
use fasp::eval::perplexity;
use fasp::model::{compact, host, Weights};
use fasp::prune::{self, Method, PruneOpts};
use fasp::runtime::manifest::LayerDims;
use fasp::runtime::{Manifest, ModelSpec, Session};
use fasp::tensor::ops::{zero_cols, zero_elems, zero_rows};
use fasp::util::quickcheck::{forall, Gen};

fn manifest() -> Manifest {
    Manifest::load(&fasp::artifacts_dir()).expect("run `make artifacts` first")
}

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fasp_compact_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A free-standing tiny spec (no manifest needed) for property tests.
fn tiny_spec(family: &str) -> ModelSpec {
    let (d, f, v) = (32usize, 64usize, 64usize);
    let dims: Vec<LayerDims> = (0..2)
        .map(|_| LayerDims { d_ff: f, d_ov: d, head_splits: vec![d / 4; 4] })
        .collect();
    let params = compact::build_params(family, d, 2, v, 8, &dims);
    ModelSpec {
        name: format!("tiny_{family}"),
        family: family.into(),
        d_model: d,
        n_heads: 4,
        n_layers: 2,
        d_ff: f,
        vocab: v,
        seq: 8,
        batch: 2,
        params,
        layer_dims: dims,
    }
}

/// Apply a mask to dense weights exactly like the pruning pipeline does
/// (zero later-layer columns + coupled earlier rows/bias elements).
fn apply_mask(w: &mut Weights, mask: &fasp::model::PruneMask) {
    let is_opt = w.spec.family == "opt";
    let later = if is_opt { "fc2" } else { "w_down" };
    for (l, lm) in mask.layers.iter().enumerate() {
        let ffn_pruned = fasp::model::mask::pruned_indices(&lm.ffn);
        let ov_pruned = fasp::model::mask::pruned_indices(&lm.ov);
        if !ffn_pruned.is_empty() {
            let mut t = w.get_l(l, later).unwrap();
            zero_cols(&mut t, &ffn_pruned);
            w.set_l(l, later, &t).unwrap();
            if is_opt {
                let mut fc1 = w.get_l(l, "fc1").unwrap();
                zero_rows(&mut fc1, &ffn_pruned);
                w.set_l(l, "fc1", &fc1).unwrap();
                let mut b1 = w.get_l(l, "bfc1").unwrap();
                zero_elems(&mut b1, &ffn_pruned);
                w.set_l(l, "bfc1", &b1).unwrap();
            } else {
                for name in ["w_gate", "w_up"] {
                    let mut m = w.get_l(l, name).unwrap();
                    zero_rows(&mut m, &ffn_pruned);
                    w.set_l(l, name, &m).unwrap();
                }
            }
        }
        if !ov_pruned.is_empty() {
            let mut wo = w.get_l(l, "wo").unwrap();
            zero_cols(&mut wo, &ov_pruned);
            w.set_l(l, "wo", &wo).unwrap();
            let mut wv = w.get_l(l, "wv").unwrap();
            zero_rows(&mut wv, &ov_pruned);
            w.set_l(l, "wv", &wv).unwrap();
            if is_opt {
                let mut bv = w.get_l(l, "bv").unwrap();
                zero_elems(&mut bv, &ov_pruned);
                w.set_l(l, "bv", &bv).unwrap();
            }
        }
    }
}

/// Property: for random masks, the compact forward equals the masked
/// dense forward to 1e-5 — both families, including uneven head splits.
#[test]
fn prop_random_masks_compact_equals_masked() {
    for fam in ["opt", "llama"] {
        let spec = tiny_spec(fam);
        forall(10, 777, |g: &mut Gen| {
            let seed = g.rng.next_u64();
            let dense = Weights::init(&spec, seed);
            let mut mask = fasp::model::PruneMask::full(&spec);
            for lm in mask.layers.iter_mut() {
                for b in lm.ffn.iter_mut() {
                    *b = g.f32_in(0.0..1.0) < 0.7;
                }
                for b in lm.ov.iter_mut() {
                    *b = g.f32_in(0.0..1.0) < 0.7;
                }
                if lm.ffn.iter().all(|&k| !k) {
                    lm.ffn[0] = true;
                }
                if lm.ov.iter().all(|&k| !k) {
                    lm.ov[0] = true;
                }
            }
            let mut masked = dense.clone();
            apply_mask(&mut masked, &mask);
            let cm = match compact::compact_from_mask(&masked, &mask, "prop_c") {
                Ok(c) => c,
                Err(e) => return (false, format!("export failed: {e:#}")),
            };
            let ds = Dataset::new(Corpus::new(spec.vocab, seed ^ 1), spec.batch, spec.seq, 2);
            let b = ds.train_batch(0);
            let (nll_m, _) = host::forward_nll(&masked, &b.tokens, &b.targets, false).unwrap();
            let (nll_c, _) = host::forward_nll(&cm.weights, &b.tokens, &b.targets, false).unwrap();
            let diff = nll_m.max_abs_diff(&nll_c);
            (diff < 1e-5, format!("{fam}: masked vs compact nll diff {diff}"))
        });
    }
}

#[test]
fn zero_sparsity_export_is_bit_identical() {
    let m = manifest();
    let spec = m.model("llama_tiny").unwrap().clone();
    let w = Weights::init(&spec, 42);
    let mask = fasp::model::PruneMask::full(&spec);
    let cm = compact::compact_from_mask(&w, &mask, "llama_tiny_id").unwrap();
    assert_eq!(cm.weights.packed, w.packed, "sparsity-0 export must be bit-identical");
    assert_eq!(cm.spec.params, spec.params);
    assert!(cm.spec.is_uniform());
}

/// Full round trip at test scale: train a little, prune with FASP,
/// repack, save, re-register in the manifest, run through a Session —
/// perplexity must match the masked model within 1e-3.
#[test]
fn compact_round_trip_matches_masked_perplexity() {
    let m = manifest();
    let model = "llama_tiny";
    let session = Session::new(&m, model).unwrap();
    let spec = session.spec.clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 99), spec.batch, spec.seq, 44);

    // brief training so pruning acts on structured weights
    let init = Weights::init(&spec, 7);
    let mut state = session.init_train(&init.packed).unwrap();
    for step in 0..40 {
        let b = ds.train_batch(step);
        session
            .train_step(&mut state, &b.tokens, &b.targets, (step + 1) as f32, 8e-3)
            .unwrap();
    }
    let mut trained = Weights::zeros(&spec);
    trained.packed = session.train_params(&state).unwrap();

    let mut opts = PruneOpts::new(Method::Fasp, 0.3);
    opts.calib_batches = 2;
    let out = prune::prune_compact(&session, &trained, &ds, &opts, "llama_tiny_rt").unwrap();
    assert!(out.report.phase("repack") > 0.0, "repack phase not accounted");
    assert!(
        out.compact.spec.n_params_elems() < spec.n_params_elems(),
        "compact model did not shrink"
    );

    // save + register + reload through a second manifest instance
    let dir = tmpdir("roundtrip");
    let jpath = compact::save_compact(&dir, &out.compact).unwrap();
    let mut m2 = manifest();
    let name = m2.register_compact(&jpath).unwrap();
    assert_eq!(name, "llama_tiny_rt");
    let cw = m2.compact_weights(&name).unwrap();
    assert_eq!(cw.packed, out.compact.weights.packed);

    let ce = Session::new(&m2, &name).unwrap();
    let eval_b = ds.valid_batches(3);
    let ppl_masked = perplexity(&session, &out.pruned, &eval_b).unwrap();
    let ppl_compact = perplexity(&ce, &cw, &eval_b).unwrap();
    assert!(
        (ppl_masked - ppl_compact).abs() < 1e-3 * ppl_masked.max(1.0),
        "masked ppl {ppl_masked} vs compact ppl {ppl_compact}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The compact model must be strictly faster than the dense model at
/// sparsity ≥ 0.3 (the structured-speedup receipt).
#[test]
fn compact_latency_strictly_below_dense_at_30pct() {
    let mut m = manifest();
    let model = "llama_small";
    let session = Session::new(&m, model).unwrap();
    let spec = session.spec.clone();
    let w = Weights::init(&spec, 5);
    let ds = Dataset::new(Corpus::new(spec.vocab, 5), spec.batch, spec.seq, 2);

    let mut opts = PruneOpts::new(Method::Magnitude, 0.35);
    opts.calib_batches = 1;
    let out = prune::prune_compact(&session, &w, &ds, &opts, "llama_small_fast").unwrap();

    let dir = tmpdir("latency");
    let jpath = compact::save_compact(&dir, &out.compact).unwrap();
    let name = m.register_compact(&jpath).unwrap();
    let cw = m.compact_weights(&name).unwrap();

    let cmp = fasp::eval::speed::compare_dense_compact(&m, model, &w, &name, &cw, 8).unwrap();
    assert!(
        cmp.compact_ms < cmp.dense_ms,
        "compact ({:.3}ms) not faster than dense ({:.3}ms)",
        cmp.compact_ms,
        cmp.dense_ms
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Compact specs survive the manifest scan path too: drop the artifact
/// into a manifest dir's compact/ subdir and Manifest::load finds it.
#[test]
fn manifest_scan_discovers_compact_artifacts() {
    let m = manifest();
    let spec = m.model("opt_tiny").unwrap().clone();
    let w = Weights::init(&spec, 11);
    let mut mask = fasp::model::PruneMask::full(&spec);
    for j in 0..32 {
        mask.layers[0].ffn[j] = false;
    }
    let mut masked = w.clone();
    apply_mask(&mut masked, &mask);
    let cm = compact::compact_from_mask(&masked, &mask, "opt_tiny_scan").unwrap();

    // a private manifest dir: copy manifest.json + stamp files refs stay
    let d = tmpdir("scan");
    std::fs::copy(
        fasp::artifacts_dir().join("manifest.json"),
        d.join("manifest.json"),
    )
    .unwrap();
    compact::save_compact(&d.join("compact"), &cm).unwrap();
    let m2 = Manifest::load(&d).unwrap();
    assert!(m2.models.contains_key("opt_tiny_scan"));
    assert!(m2.compact.contains_key("opt_tiny_scan"));
    assert!(m2.artifacts.contains_key("opt_tiny_scan_fwd_loss"));
    let spec2 = m2.model("opt_tiny_scan").unwrap();
    assert_eq!(spec2.d_ff_l(0), spec.d_ff - 32);
    assert_eq!(spec2.d_ff_l(1), spec.d_ff);
    assert!(!spec2.is_uniform());

    // and a session can run it from the scanned manifest
    let cw = m2.compact_weights("opt_tiny_scan").unwrap();
    let ce = Session::new(&m2, "opt_tiny_scan").unwrap();
    let ds = Dataset::new(Corpus::new(spec.vocab, 2), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);
    let out = ce.fwd_loss(&ce.pack(&cw.packed).unwrap(), &b.tokens, &b.targets).unwrap();
    assert!(out.mean_nll.is_finite());
    std::fs::remove_dir_all(&d).ok();
}
