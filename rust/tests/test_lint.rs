//! The static-analysis gate, as a test: `fasp lint` must run clean
//! over the real crate with the checked-in allowlist. This is the
//! same check `verify.sh` runs via the CLI — having it in the test
//! matrix means a plain `cargo test` also refuses lint regressions.
//!
//! Rule-level behavior (each rule fires on seeded violations, stays
//! silent on clean code) is covered by the fixture self-tests inside
//! `rust/src/analysis/`; this file exercises the end-to-end pass:
//! crate walk → lex → rules → allowlist → report.

use fasp::analysis;

#[test]
fn crate_lints_clean_with_checked_in_allowlist() {
    let run = analysis::lint_repo(&fasp::repo_root()).unwrap();
    assert!(
        run.files_scanned > 40,
        "suspiciously few files scanned ({}) — wrong root?",
        run.files_scanned
    );
    assert!(
        run.violations.is_empty(),
        "lint violations crept in:\n{}",
        run.render_table()
    );
    assert!(
        run.stale.is_empty(),
        "stale allowlist entries (remove them from rust/lint_allow.toml):\n{}",
        run.render_table()
    );
    assert!(run.is_clean());
    // the allowlist is in active use — suppressions exist and are all
    // consumed (every entry justified AND load-bearing)
    assert!(!run.entries.is_empty(), "expected a non-empty allowlist");
    assert!(!run.allowed.is_empty(), "expected absorbed suppressions");
}

#[test]
fn report_json_is_parseable_and_consistent() {
    use fasp::util::json::Json;
    let run = analysis::lint_repo(&fasp::repo_root()).unwrap();
    let txt = run.report_json().pretty();
    let parsed = Json::parse(&txt).expect("LINT_REPORT.json round-trips");
    match &parsed {
        Json::Obj(o) => {
            assert_eq!(o.get("clean"), Some(&Json::Bool(true)));
            assert_eq!(o.get("total_violations"), Some(&Json::Num(0.0)));
            match o.get("rules") {
                Some(Json::Arr(rules)) => assert_eq!(rules.len(), 6, "D1-D3, U1, R1, P1"),
                other => panic!("rules not an array: {other:?}"),
            }
        }
        other => panic!("report not an object: {other:?}"),
    }
}

/// A seeded violation in a synthetic tree is caught end-to-end, and a
/// stale allowlist entry fails the run even with zero violations.
#[test]
fn seeded_violation_and_stale_entry_fail_the_gate() {
    let dir = std::env::temp_dir().join("fasp_lint_seeded");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("rust/src")).unwrap();
    std::fs::write(
        dir.join("rust/src/lib.rs"),
        "use std::collections::HashMap;\npub fn f() -> HashMap<u32, u32> { HashMap::new() }\n",
    )
    .unwrap();

    // no allowlist: the seeded D1 violation must surface
    let run = analysis::lint_repo(&dir).unwrap();
    assert!(!run.is_clean());
    assert_eq!(run.violations.len(), 2); // use line + fn line
    assert!(run.violations.iter().all(|v| v.rule == "D1"));
    assert_eq!(run.violations[0].rel, "src/lib.rs");

    // a covering allowlist entry absorbs it...
    std::fs::write(
        dir.join("rust/lint_allow.toml"),
        "[[allow]]\nrule = \"D1\"\nfile = \"src/lib.rs\"\nwhy = \"seeded fixture for the end-to-end lint test\"\n",
    )
    .unwrap();
    let run2 = analysis::lint_repo(&dir).unwrap();
    assert!(run2.is_clean(), "{}", run2.render_table());
    assert_eq!(run2.allowed.len(), 2);

    // ...but an entry matching nothing is stale and fails the gate
    std::fs::write(
        dir.join("rust/src/lib.rs"),
        "pub fn f() -> u32 { 7 }\n",
    )
    .unwrap();
    let run3 = analysis::lint_repo(&dir).unwrap();
    assert!(run3.violations.is_empty());
    assert_eq!(run3.stale.len(), 1);
    assert!(!run3.is_clean());

    std::fs::remove_dir_all(&dir).ok();
}
