//! Fault-injection & graceful-degradation contract: a seeded
//! [`FaultPlan`] fires on exact event counters (never wall clock), so
//! every injected failure replays bit-identically; the serve engine
//! degrades per-session — a faulted session comes back as a failed
//! [`ServeOutput`] while survivors stay bit-identical to the fault-free
//! run — and always drains with zero leaked arena pages; an injected
//! prefetch-thread failure in the shard store surfaces as a proper
//! `Err` and `rewind()` recovers; the KV arena's accounting stays exact
//! through injected exhaustion across page sizes and pool widths.

use fasp::eval::speed::chaos_shard_probe;
use fasp::fault::{self, FaultPlan, Site};
use fasp::model::compact::{build_params, compact_from_mask};
use fasp::model::decode::Sampler;
use fasp::model::weights::ParamSource;
use fasp::model::{KvArena, PagedKv, PackedWeights, PruneMask, Weights};
use fasp::runtime::manifest::LayerDims;
use fasp::runtime::store::{write_shards, ShardedWeights, StreamingParams};
use fasp::runtime::ModelSpec;
use fasp::serve::{serve, ServeConfig, ServeOutput, ServeRequest};
use fasp::util::pool;
use fasp::util::rng::Rng;
use std::sync::Arc;

/// Same ragged toy as `test_serve` — small enough that nothing crosses
/// the pool's parallel threshold, so its serve runs see zero pool
/// events and fault census stays pool-free at every worker count.
fn toy_spec() -> ModelSpec {
    let layer_dims = vec![
        LayerDims { d_ff: 20, d_ov: 10, head_splits: vec![6, 4] },
        LayerDims { d_ff: 12, d_ov: 5, head_splits: vec![5, 0] },
        LayerDims { d_ff: 16, d_ov: 16, head_splits: vec![8, 8] },
    ];
    let params = build_params("llama", 16, 3, 48, 24, &layer_dims);
    ModelSpec {
        name: "chaos_toy".into(),
        family: "llama".into(),
        d_model: 16,
        n_heads: 2,
        n_layers: 3,
        d_ff: 20,
        vocab: 48,
        seq: 24,
        batch: 2,
        params,
        layer_dims,
    }
}

/// A spec whose head-logits matmul crosses [`pool`]'s parallel
/// threshold exactly when 4 lanes step together (4 · 2048 · 128 = 2^20
/// flops), so pool fan-out events fire on the serve path and nowhere
/// else — the smallest shape where pool faults are reachable.
fn big_vocab_spec() -> ModelSpec {
    let layer_dims = vec![LayerDims { d_ff: 64, d_ov: 128, head_splits: vec![64, 64] }];
    let params = build_params("llama", 128, 1, 2048, 32, &layer_dims);
    ModelSpec {
        name: "chaos_big_vocab".into(),
        family: "llama".into(),
        d_model: 128,
        n_heads: 2,
        n_layers: 1,
        d_ff: 64,
        vocab: 2048,
        seq: 32,
        batch: 2,
        params,
        layer_dims,
    }
}

/// Staggered mixed load (same shape as `test_serve::toy_requests`).
fn toy_requests(spec: &ModelSpec, n: usize) -> Vec<ServeRequest> {
    let mut rng = Rng::new(0x10ad);
    let mut reqs = Vec::with_capacity(n);
    for i in 0..n {
        let t = 3 + i % 4;
        let prompt: Vec<i32> = (0..t).map(|_| rng.below(spec.vocab) as i32).collect();
        let sampler = if i % 2 == 0 {
            Sampler::Greedy
        } else {
            Sampler::TopK { k: 4, temperature: 0.9 }
        };
        reqs.push(ServeRequest {
            prompt,
            max_new: 2 + i % 3,
            sampler,
            seed: 1000 + i as u64,
            ..Default::default()
        });
    }
    reqs
}

/// A lockstep load: every session has the same prompt length and
/// generation budget, so all of them prefill, step and retire on the
/// same ticks — the batched step always runs with `n` lanes.
fn aligned_requests(spec: &ModelSpec, n: usize) -> Vec<ServeRequest> {
    let mut rng = Rng::new(0xa11e);
    (0..n)
        .map(|i| ServeRequest {
            prompt: (0..6).map(|_| rng.below(spec.vocab) as i32).collect(),
            max_new: 4,
            sampler: Sampler::Greedy,
            seed: 2000 + i as u64,
            ..Default::default()
        })
        .collect()
}

fn toy_cfg() -> ServeConfig {
    ServeConfig {
        page: 3,
        n_pages: 64,
        max_batch: 3,
        prefix_cache: false,
        prefill_chunk: 2,
        ..Default::default()
    }
}

fn big_cfg() -> ServeConfig {
    ServeConfig {
        page: 4,
        n_pages: 64,
        max_batch: 8,
        prefix_cache: false,
        prefill_chunk: 4,
        ..Default::default()
    }
}

/// Run `f` with the panic hook silenced (injected pool-worker panics
/// are caught by the engine, but the default hook would still spew
/// backtraces into the test output).
fn quiet_panics<T>(f: impl FnOnce() -> T) -> T {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let out = f();
    std::panic::set_hook(prev);
    out
}

fn errors(outputs: &[ServeOutput]) -> Vec<&ServeOutput> {
    outputs.iter().filter(|o| o.error.is_some()).collect()
}

// ------------------------------------------------------ plan determinism

/// Synthesized plans are a pure function of (seed, event counts) and
/// round-trip through the textual grammar unchanged.
#[test]
fn synth_plan_is_seed_deterministic_and_round_trips() {
    let a = fault::synth_serve_plan(7, 40, 9, 2);
    let b = fault::synth_serve_plan(7, 40, 9, 2);
    assert_eq!(a, b, "same seed + census must synthesize the same plan");
    assert_eq!(a.specs.len(), 3, "one arena exhaust + two pool panics");
    assert_eq!(a.specs[0].site, Site::Arena);
    assert!(1 <= a.specs[0].nth && a.specs[0].nth <= 9);
    for s in &a.specs[1..] {
        assert_eq!(s.site, Site::Pool);
        assert!(1 <= s.nth && s.nth <= 40);
    }
    let back = FaultPlan::parse(&a.render()).unwrap();
    assert_eq!(back, a, "parse(render(p)) != p");

    // no pool events observed -> no pool faults synthesized
    let dry = fault::synth_serve_plan(7, 0, 9, 2);
    assert!(dry.specs.iter().all(|s| s.site != Site::Pool));
}

// ----------------------------------- arena exhaustion: one session fails

/// A single-shot injected arena exhaustion retires exactly one session
/// with a failed output; every survivor is bit-identical to the
/// fault-free run, nothing leaks, and an identical plan replays to
/// identical bits and an identical fault trace.
#[test]
fn one_shot_arena_exhaust_fails_exactly_one_session() {
    let spec = toy_spec();
    let pw = PackedWeights::new(Weights::init(&spec, 77));
    let reqs = toy_requests(&spec, 6);
    let cfg = toy_cfg();
    let _g = pool::enter(pool::serial());

    // fault-free census + baseline bits
    let (clean, arena_events) = {
        let scope = fault::install(&FaultPlan::default());
        let rep = serve(&pw, &reqs, &cfg).unwrap();
        (rep, scope.report().events_at(Site::Arena))
    };
    assert!(arena_events >= 1, "toy serve load must grow the arena at least once");
    assert_eq!(clean.failed_sessions, 0);
    assert_eq!(clean.leaked_pages, 0);

    let plan = FaultPlan::parse(&format!("arena@{}=exhaust", arena_events / 2 + 1)).unwrap();
    let run = |plan: &FaultPlan| {
        let scope = fault::install(plan);
        let rep = serve(&pw, &reqs, &cfg).unwrap();
        (rep, scope.report())
    };
    let (chaos, fr1) = run(&plan);
    let (replay, fr2) = run(&plan);

    assert_eq!(fr1.total_injected(), 1);
    let failed = errors(&chaos.outputs);
    assert_eq!(failed.len(), 1, "one-shot exhaust must fail exactly one session");
    assert_eq!(chaos.failed_sessions, 1);
    let msg = failed[0].error.as_deref().unwrap();
    assert!(msg.contains("injected fault"), "unexpected failure reason: {msg}");
    for (c, cl) in chaos.outputs.iter().zip(&clean.outputs) {
        if c.error.is_none() {
            assert_eq!(c.tokens, cl.tokens, "survivor {} diverged from fault-free run", c.id);
        }
    }
    assert_eq!(chaos.leaked_pages, 0, "failed session leaked arena pages");

    // replay identity: same bits, same counters, same trace
    assert_eq!(fr1, fr2, "fault reports diverged across replay");
    for (a, b) in chaos.outputs.iter().zip(&replay.outputs) {
        assert_eq!((a.id, &a.tokens, &a.error), (b.id, &b.tokens, &b.error));
    }
    assert_eq!(chaos.failed_sessions, replay.failed_sessions);
    assert_eq!(chaos.tick_retries, replay.tick_retries);
}

// ------------------------------------------- pool panics: absorb / drain

/// A single-shot pool-worker panic is absorbed by the bounded tick
/// retry: the faulted tick rolls back and reruns, every session
/// finishes with bits identical to the fault-free run, and the retry
/// counter is the only trace the fault ever happened.
#[test]
fn one_shot_pool_panic_is_absorbed_bit_identically() {
    quiet_panics(|| {
        let spec = big_vocab_spec();
        let pw = PackedWeights::new(Weights::init(&spec, 77));
        let reqs = aligned_requests(&spec, 4);
        let cfg = big_cfg();
        let _g = pool::enter(Arc::new(pool::Pool::new(4)));

        let (clean, pool_events) = {
            let scope = fault::install(&FaultPlan::default());
            let rep = serve(&pw, &reqs, &cfg).unwrap();
            (rep, scope.report().events_at(Site::Pool))
        };
        assert!(pool_events >= 1, "4-lane big-vocab steps must fan out on the pool");
        assert_eq!(clean.failed_sessions, 0);

        let plan = FaultPlan::parse(&format!("pool@{}=panic", pool_events / 2 + 1)).unwrap();
        let scope = fault::install(&plan);
        let chaos = serve(&pw, &reqs, &cfg).unwrap();
        assert_eq!(scope.report().injected_at(Site::Pool), 1);
        drop(scope);

        assert!(chaos.tick_retries >= 1, "absorbed fault must show up in the retry counter");
        assert_eq!(chaos.failed_sessions, 0, "one-shot panic must not fail any session");
        for (c, cl) in chaos.outputs.iter().zip(&clean.outputs) {
            assert!(c.error.is_none());
            assert_eq!(c.tokens, cl.tokens, "session {} diverged after absorbed panic", c.id);
        }
        assert_eq!(chaos.leaked_pages, 0);
    });
}

/// A persistent pool panic exhausts the bounded retries: every stepping
/// session is retired with a failed output carrying the panic payload —
/// but the engine itself returns `Ok` and drains every arena page.
#[test]
fn persistent_pool_panic_fails_sessions_not_the_engine() {
    quiet_panics(|| {
        let spec = big_vocab_spec();
        let pw = PackedWeights::new(Weights::init(&spec, 77));
        let reqs = aligned_requests(&spec, 4);
        let cfg = big_cfg();
        let _g = pool::enter(Arc::new(pool::Pool::new(4)));

        let _scope = fault::install(&FaultPlan::parse("pool@1=panic*always").unwrap());
        let report = serve(&pw, &reqs, &cfg).unwrap();
        assert_eq!(report.failed_sessions, reqs.len(), "every lockstep session steps, so all fail");
        for o in &report.outputs {
            let msg = o.error.as_deref().expect("session should have failed");
            assert!(msg.contains("tick fault"), "unexpected reason: {msg}");
            assert!(msg.contains("pool worker panic"), "lost panic payload: {msg}");
            assert_eq!(o.generated, 0, "first step already faults — nothing generated");
        }
        assert_eq!(report.leaked_pages, 0, "drain after persistent faults leaked pages");
    });
}

// ----------------------------------------- admission shedding & deadlines

/// Arrivals beyond `queue_cap` are shed from the back of the queue
/// before any forward work: highest ids come back as failed outputs
/// with zero tokens generated, admitted sessions are bit-identical to
/// the uncapped run.
#[test]
fn bounded_admission_queue_sheds_from_the_back() {
    let spec = toy_spec();
    let pw = PackedWeights::new(Weights::init(&spec, 77));
    let reqs = toy_requests(&spec, 6);
    let _g = pool::enter(pool::serial());

    let clean = serve(&pw, &reqs, &toy_cfg()).unwrap();
    let cfg = ServeConfig { queue_cap: 4, ..toy_cfg() };
    let capped = serve(&pw, &reqs, &cfg).unwrap();

    assert_eq!(capped.shed_sessions, 2);
    assert_eq!(capped.failed_sessions, 2, "shed sessions count as failed");
    for o in &capped.outputs {
        if o.id >= 4 {
            let msg = o.error.as_deref().expect("over-cap arrival should be shed");
            assert!(msg.contains("shed"), "unexpected shed reason: {msg}");
            assert_eq!(o.generated, 0, "shed before any forward work");
        } else {
            assert!(o.error.is_none());
            assert_eq!(o.tokens, clean.outputs[o.id].tokens, "admitted session {} diverged", o.id);
        }
    }
    assert_eq!(capped.leaked_pages, 0);
}

/// Tick-counted deadlines retire only the late session: a zero-tick
/// deadline fails before any forward work, a small one fails with a
/// partial generation, and sessions without deadlines are untouched.
#[test]
fn tick_deadlines_retire_only_the_late_session() {
    let spec = toy_spec();
    let pw = PackedWeights::new(Weights::init(&spec, 77));
    let mut reqs = toy_requests(&spec, 3);
    let _g = pool::enter(pool::serial());
    let clean = serve(&pw, &reqs, &toy_cfg()).unwrap();

    // zero budget: retired at the very first deadline sweep
    reqs[1].deadline_ticks = 0;
    let report = serve(&pw, &reqs, &toy_cfg()).unwrap();
    assert_eq!(report.deadline_failures, 1);
    let late = &report.outputs[1];
    let msg = late.error.as_deref().expect("deadline 0 must fail");
    assert!(msg.contains("deadline exceeded"), "unexpected reason: {msg}");
    assert_eq!(late.generated, 0);
    for id in [0usize, 2] {
        assert!(report.outputs[id].error.is_none());
        assert_eq!(report.outputs[id].tokens, clean.outputs[id].tokens);
    }

    // a 2-tick budget on a session that needs many more: partial output
    reqs[1] = ServeRequest {
        prompt: reqs[0].prompt.clone(),
        max_new: 6,
        sampler: Sampler::Greedy,
        seed: 9,
        deadline_ticks: 2,
    };
    let report = serve(&pw, &reqs, &toy_cfg()).unwrap();
    let late = &report.outputs[1];
    assert!(late.error.as_deref().unwrap_or("").contains("deadline exceeded"));
    assert!(late.generated < 6, "2 ticks cannot produce 6 tokens");
    assert_eq!(report.leaked_pages, 0);
}

// ------------------------------------------- leak-freedom (satellite 3)

/// Whatever mix of faults hits mid-generation, the drained engine owns
/// zero arena pages afterwards — across page sizes and pool widths.
#[test]
fn faulted_drains_leak_no_pages_across_page_sizes_and_widths() {
    quiet_panics(|| {
        // serial width: arena faults only (toy load never crosses the
        // pool threshold)
        let spec = toy_spec();
        let pw = PackedWeights::new(Weights::init(&spec, 77));
        let reqs = toy_requests(&spec, 6);
        for page in [1usize, 2, 4, 8] {
            let _g = pool::enter(pool::serial());
            let cfg = ServeConfig { page, ..toy_cfg() };
            let _scope = fault::install(&FaultPlan::parse("arena@3=exhaust*always").unwrap());
            let report = serve(&pw, &reqs, &cfg).unwrap();
            assert!(report.failed_sessions >= 1, "page={page}: persistent exhaust must bite");
            assert_eq!(report.leaked_pages, 0, "page={page}: drain leaked pages");
            assert_eq!(report.outputs.len(), reqs.len());
        }

        // parallel width: arena exhaust + persistent pool panic together
        let spec = big_vocab_spec();
        let pw = PackedWeights::new(Weights::init(&spec, 77));
        let reqs = aligned_requests(&spec, 5);
        for page in [1usize, 4] {
            let _g = pool::enter(Arc::new(pool::Pool::new(4)));
            let cfg = ServeConfig { page, ..big_cfg() };
            let _scope =
                fault::install(&FaultPlan::parse("arena@2=exhaust,pool@2=panic*always").unwrap());
            let report = serve(&pw, &reqs, &cfg).unwrap();
            assert!(report.failed_sessions >= 1, "page={page}: faults must bite");
            assert_eq!(report.leaked_pages, 0, "page={page}: drain leaked pages");
        }
    });
}

/// Arena-level accounting through an injected exhaustion: the failed
/// grow takes nothing, prior pages stay owned, and releasing every
/// session returns the pool to exactly full — for every page size.
#[test]
fn arena_accounting_is_exact_through_injected_exhaustion() {
    let spec = toy_spec();
    for page in [1usize, 2, 4, 8] {
        let mut arena = KvArena::for_spec(&spec, 16, page).unwrap();
        let mut a = PagedKv::new();
        let mut b = PagedKv::new();
        arena.grow(&mut a, 2 * page + 1).unwrap(); // 3 pages
        arena.grow(&mut b, page).unwrap(); // 1 page
        assert_eq!(arena.used_pages(), 4);
        {
            let _scope = fault::install(&FaultPlan::parse("arena@1=exhaust*always").unwrap());
            // within already-granted capacity: no allocation, no fault
            arena.grow(&mut a, 2 * page).unwrap();
            // allocating grow: injected exhaustion, b keeps its page
            assert!(arena.grow(&mut b, 3 * page).is_err(), "page={page}");
        }
        assert_eq!(arena.used_pages(), 4, "page={page}: failed grow changed ownership");
        arena.release(&mut a);
        arena.release(&mut b);
        assert_eq!(arena.used_pages(), 0, "page={page}");
        assert_eq!(arena.free_pages(), arena.n_pages(), "page={page}: pool not whole again");
    }
}

// --------------------------------- streaming prefetch fault (satellite 1)

/// An injected corruption on the prefetch thread surfaces as a proper
/// `Err` on the next layer access (never a hang or abort), and
/// `rewind()` recovers the stream: the post-recovery pass hands back
/// the exact bytes of a fault-free pass.
#[test]
fn prefetch_fault_surfaces_as_err_and_rewind_recovers() {
    let spec = toy_spec();
    let w = Weights::init(&spec, 77);
    let cm = compact_from_mask(&w, &PruneMask::full(&spec), "chaos_stream_toy").unwrap();
    let dir = std::env::temp_dir().join(format!("fasp_test_chaos_stream_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let index = write_shards(&dir, &cm).unwrap();
    let store = ShardedWeights::open(cm.spec.clone(), dir.clone(), index).unwrap();

    // fault-free baseline bytes, one tensor per layer
    let baseline: Vec<Vec<f32>> = {
        let mut src = StreamingParams::new(&store, 1).unwrap();
        (0..spec.n_layers)
            .map(|l| {
                let t = src.get_l(l, "wo").unwrap();
                src.layer_done(l).unwrap();
                t.data
            })
            .collect()
    };

    let mut src = StreamingParams::new(&store, 1).unwrap();
    {
        // layer 0's prefetch was spawned at construction, before the
        // scope existed — it reads clean. The layer-1 prefetch spawned
        // while consuming layer 0 inherits the armed plan and corrupts.
        let _scope = fault::install(&FaultPlan::parse("shard@1=corrupt*always").unwrap());
        let t0 = src.get_l(0, "wo").unwrap();
        assert_eq!(t0.data, baseline[0]);
        src.layer_done(0).unwrap();
        let err = src.get_l(1, "wo").expect_err("corrupted prefetch must surface as Err");
        let msg = format!("{err:#}");
        assert!(msg.contains("checksum"), "expected a checksum failure, got: {msg}");
    }

    // scope dropped: rewind respawns prefetch under a clean plan
    src.rewind().unwrap();
    for (l, want) in baseline.iter().enumerate() {
        let t = src.get_l(l, "wo").unwrap();
        assert_eq!(&t.data, want, "layer {l} bytes changed across fault + rewind");
        src.layer_done(l).unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------- shard-store probe

/// The `fasp chaos` shard probe holds at test scale: a one-shot
/// checksum corruption is absorbed by the bounded re-read while a
/// persistent truncation surfaces as a per-call `Err`.
#[test]
fn shard_probe_absorbs_one_shot_and_errs_on_persistent() {
    let spec = toy_spec();
    let w = Weights::init(&spec, 77);
    let dir = std::env::temp_dir().join(format!("fasp_test_chaos_probe_{}", std::process::id()));
    let probe = chaos_shard_probe(&w, &dir);
    std::fs::remove_dir_all(&dir).ok();
    let probe = probe.unwrap();
    assert_eq!(probe.shard_events, 1 + spec.n_layers as u64, "embed + one event per layer");
    assert!(probe.absorbed_ok, "one-shot corruption must be absorbed by the re-read");
    assert!(probe.retries_absorbed >= 1, "absorbed pass must show the retry");
    assert!(probe.fatal_is_err, "persistent truncation must surface as Err");
}
