//! Backend determinism contract: `ThreadedHostBackend` (FASP_THREADS-style
//! pools, here pinned to 4 workers) must produce **bit-identical**
//! `fwd_loss` / `capture` / `gradcol` / `train_step` outputs and identical
//! prune masks vs the single-threaded `HostBackend` reference. The
//! parallel fan-outs use fixed reduction orders and no atomic
//! accumulation, so this is equality of f32 bit patterns, not tolerance.
//! Requires `make artifacts`.

use fasp::data::{Corpus, Dataset};
use fasp::model::Weights;
use fasp::prune::{self, Method, PruneOpts};
use fasp::runtime::{Backend, HostBackend, Manifest, Session, ThreadedHostBackend};
use std::sync::Arc;

const THREADS: usize = 4;

fn manifest() -> Manifest {
    Manifest::load(&fasp::artifacts_dir()).expect("run `make artifacts` first")
}

fn sessions<'m>(m: &'m Manifest, model: &str) -> (Session<'m>, Session<'m>) {
    let single = Session::with_backend(m, model, Arc::new(HostBackend::new())).unwrap();
    let threaded =
        Session::with_backend(m, model, Arc::new(ThreadedHostBackend::new(THREADS))).unwrap();
    (single, threaded)
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn fwd_loss_bit_identical_across_backends() {
    let m = manifest();
    for model in ["opt_tiny", "llama_tiny", "llama_small"] {
        let (single, threaded) = sessions(&m, model);
        assert_eq!(single.backend().name(), "host");
        assert_eq!(single.backend().threads(), 1);
        assert_eq!(threaded.backend().name(), "threaded-host");
        assert_eq!(threaded.backend().threads(), THREADS);
        let spec = single.spec.clone();
        let w = Weights::init(&spec, 7);
        let ds = Dataset::new(Corpus::new(spec.vocab, 3), spec.batch, spec.seq, 2);
        let b = ds.train_batch(0);

        let o1 = single.fwd_loss(&single.pack(&w.packed).unwrap(), &b.tokens, &b.targets).unwrap();
        let o2 =
            threaded.fwd_loss(&threaded.pack(&w.packed).unwrap(), &b.tokens, &b.targets).unwrap();
        assert_eq!(
            o1.mean_nll.to_bits(),
            o2.mean_nll.to_bits(),
            "{model}: mean nll diverged"
        );
        assert!(bits_eq(&o1.seq_nll, &o2.seq_nll), "{model}: seq nll diverged");
        assert!(
            bits_eq(&o1.tok_nll.data, &o2.tok_nll.data),
            "{model}: token nll diverged"
        );
    }
}

#[test]
fn capture_and_gradcol_bit_identical_across_backends() {
    let m = manifest();
    let (single, threaded) = sessions(&m, "llama_tiny");
    let spec = single.spec.clone();
    let w = Weights::init(&spec, 11);
    let ds = Dataset::new(Corpus::new(spec.vocab, 5), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);

    let s1 = single
        .capture(&single.pack(&w.packed).unwrap(), &[b.tokens.clone()])
        .unwrap();
    let s2 = threaded
        .capture(&threaded.pack(&w.packed).unwrap(), &[b.tokens.clone()])
        .unwrap();
    assert_eq!(s1.rows, s2.rows);
    for (l, (a, c)) in s1.layers.iter().zip(&s2.layers).enumerate() {
        assert!(bits_eq(&a.g_ln1.data, &c.g_ln1.data), "layer {l} g_ln1");
        assert!(bits_eq(&a.g_ln2.data, &c.g_ln2.data), "layer {l} g_ln2");
        assert!(bits_eq(&a.g_attn.data, &c.g_attn.data), "layer {l} g_attn");
        assert!(bits_eq(&a.g_ffn.data, &c.g_ffn.data), "layer {l} g_ffn");
        assert!(bits_eq(&a.m_ffn.data, &c.m_ffn.data), "layer {l} m_ffn");
    }

    let batches = vec![(b.tokens.clone(), b.targets.clone())];
    let g1 = single.gradcol(&single.pack(&w.packed).unwrap(), &batches).unwrap();
    let g2 = threaded.gradcol(&threaded.pack(&w.packed).unwrap(), &batches).unwrap();
    for (l, (a, c)) in g1.iter().zip(&g2).enumerate() {
        assert!(bits_eq(&a.ffn, &c.ffn), "layer {l} ffn taylor scores diverged");
        assert!(bits_eq(&a.ov, &c.ov), "layer {l} ov taylor scores diverged");
    }
}

#[test]
fn train_step_bit_identical_across_backends() {
    let m = manifest();
    let (single, threaded) = sessions(&m, "llama_tiny");
    let spec = single.spec.clone();
    let init = Weights::init(&spec, 42);
    let ds = Dataset::new(Corpus::new(spec.vocab, 9), spec.batch, spec.seq, 8);

    let mut st1 = single.init_train(&init.packed).unwrap();
    let mut st2 = threaded.init_train(&init.packed).unwrap();
    for step in 0..3 {
        let b = ds.train_batch(step);
        let l1 = single
            .train_step(&mut st1, &b.tokens, &b.targets, (step + 1) as f32, 8e-3)
            .unwrap();
        let l2 = threaded
            .train_step(&mut st2, &b.tokens, &b.targets, (step + 1) as f32, 8e-3)
            .unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits(), "step {step}: loss diverged");
    }
    let p1 = single.train_params(&st1).unwrap();
    let p2 = threaded.train_params(&st2).unwrap();
    assert!(bits_eq(&p1.data, &p2.data), "trained params diverged");
}

/// The full pipeline: identical prune masks AND identical pruned weights
/// under both backends (capture → metric → select → restore all run on
/// pool-width-independent arithmetic).
#[test]
fn prune_masks_identical_across_backends() {
    let m = manifest();
    let (single, threaded) = sessions(&m, "llama_tiny");
    let spec = single.spec.clone();
    let w = Weights::init(&spec, 21);
    let ds = Dataset::new(Corpus::new(spec.vocab, 13), spec.batch, spec.seq, 4);

    let mut opts = PruneOpts::new(Method::Fasp, 0.3);
    opts.calib_batches = 2;
    let (w1, m1, _) = prune::prune(&single, &w, &ds, &opts).unwrap();
    let (w2, m2, _) = prune::prune(&threaded, &w, &ds, &opts).unwrap();
    for (l, (a, b)) in m1.layers.iter().zip(&m2.layers).enumerate() {
        assert_eq!(a.ffn, b.ffn, "layer {l}: ffn masks diverged");
        assert_eq!(a.ov, b.ov, "layer {l}: ov masks diverged");
        assert_eq!(a.qk, b.qk, "layer {l}: qk masks diverged");
    }
    assert!(bits_eq(&w1.packed.data, &w2.packed.data), "pruned weights diverged");
}

/// Compact repack on a wide pool equals the serial repack bit-for-bit
/// (gathers are pure copies).
#[test]
fn compact_repack_identical_across_pool_widths() {
    use fasp::util::pool;
    let m = manifest();
    let spec = m.model("llama_tiny").unwrap().clone();
    let w = Weights::init(&spec, 5);
    let mut mask = fasp::model::PruneMask::full(&spec);
    for j in 0..16 {
        mask.layers[0].ffn[j] = false;
        mask.layers[1].ov[j % spec.d_model] = false;
    }
    let serial = {
        let _g = pool::enter(pool::serial());
        fasp::model::compact::compact_from_mask(&w, &mask, "bk_serial").unwrap()
    };
    let pooled = {
        let _g = pool::enter(Arc::new(pool::Pool::new(THREADS)));
        fasp::model::compact::compact_from_mask(&w, &mask, "bk_pooled").unwrap()
    };
    assert_eq!(serial.spec.layer_dims, pooled.spec.layer_dims);
    assert!(
        bits_eq(&serial.weights.packed.data, &pooled.weights.packed.data),
        "repacked weights diverged across pool widths"
    );
}

/// Sharded export bytes are pool-width-independent: serializing +
/// checksumming shards on a wide pool produces byte-identical files and
/// an identical index vs the serial pool.
#[test]
fn sharded_export_bytes_identical_across_pool_widths() {
    use fasp::util::pool;
    let m = manifest();
    let spec = m.model("llama_tiny").unwrap().clone();
    let w = Weights::init(&spec, 17);
    let mut mask = fasp::model::PruneMask::full(&spec);
    for j in 0..24 {
        mask.layers[0].ffn[j] = false;
        mask.layers[1].ov[j % spec.d_model] = false;
    }
    let cm = fasp::model::compact::compact_from_mask(&w, &mask, "bk_shard").unwrap();
    let d1 = std::env::temp_dir().join("fasp_bk_shard_serial");
    let d2 = std::env::temp_dir().join("fasp_bk_shard_pooled");
    for d in [&d1, &d2] {
        let _ = std::fs::remove_dir_all(d);
    }
    let idx1 = {
        let _g = pool::enter(pool::serial());
        fasp::runtime::store::write_shards(&d1, &cm).unwrap()
    };
    let idx2 = {
        let _g = pool::enter(Arc::new(pool::Pool::new(THREADS)));
        fasp::runtime::store::write_shards(&d2, &cm).unwrap()
    };
    assert_eq!(idx1, idx2, "shard indices (incl. checksums) diverged");
    for s in &idx1.shards {
        let b1 = std::fs::read(d1.join(&s.file)).unwrap();
        let b2 = std::fs::read(d2.join(&s.file)).unwrap();
        assert_eq!(b1, b2, "shard {} bytes diverged across pool widths", s.file);
    }
    for d in [&d1, &d2] {
        std::fs::remove_dir_all(d).ok();
    }
}

/// Pack-cache bytes are pool-width-independent: building the packed
/// operator plan on a wide pool produces bit-identical panels (and the
/// same byte total) as the serial build — packing is a pure relayout.
#[test]
fn pack_cache_bytes_identical_across_pool_widths() {
    use fasp::model::weights::linear_shorts;
    use fasp::model::PackCache;
    use fasp::util::pool;
    let m = manifest();
    let spec = m.model("llama_tiny").unwrap().clone();
    let w = Weights::init(&spec, 23);
    let serial = {
        let _g = pool::enter(pool::serial());
        PackCache::build(&w)
    };
    let pooled = {
        let _g = pool::enter(Arc::new(pool::Pool::new(THREADS)));
        PackCache::build(&w)
    };
    assert_eq!(serial.bytes(), pooled.bytes(), "pack bytes diverged across widths");
    assert_eq!(serial.count(), pooled.count());
    for l in 0..spec.n_layers {
        for short in linear_shorts(&spec.family) {
            let a = serial.get_l(l, short).unwrap();
            let b = pooled.get_l(l, short).unwrap();
            assert!(
                bits_eq(a.data(), b.data()),
                "layer {l} {short}: packed panel diverged across pool widths"
            );
        }
    }
}

/// The speed harness agrees: outputs identical, timing fields sane.
#[test]
fn compare_backends_reports_identity() {
    let m = manifest();
    let spec = m.model("llama_small").unwrap().clone();
    let w = Weights::init(&spec, 3);
    let cmp = fasp::eval::speed::compare_backends(&m, "llama_small", &w, 3, THREADS).unwrap();
    assert!(cmp.identical, "backend outputs diverged");
    assert_eq!(cmp.threads, THREADS);
    assert!(cmp.single_ms > 0.0 && cmp.threaded_ms > 0.0);
}

/// Int8 pack bytes are pool-width-independent: quantized panels (q
/// bytes AND per-group scales) built on a wide pool are bit-identical
/// to the serial build — quantization is per-lane-group arithmetic over
/// a fixed index partition, never a reduction race.
#[test]
fn int8_pack_cache_bytes_identical_across_pool_widths() {
    use fasp::model::weights::linear_shorts;
    use fasp::model::PackCache;
    use fasp::tensor::Quant;
    use fasp::util::pool;
    let m = manifest();
    let spec = m.model("llama_tiny").unwrap().clone();
    let w = Weights::init(&spec, 33);
    let serial = {
        let _g = pool::enter(pool::serial());
        PackCache::build_q(&w, Quant::Int8)
    };
    assert_eq!(serial.quant(), Quant::Int8);
    let f32_cache = {
        let _g = pool::enter(pool::serial());
        PackCache::build(&w)
    };
    assert!(
        (serial.bytes() as f64) <= 0.55 * f32_cache.bytes() as f64,
        "int8 cache {} !<= 0.55x f32 cache {}",
        serial.bytes(),
        f32_cache.bytes()
    );
    for workers in [2usize, 8] {
        let pooled = {
            let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
            PackCache::build_q(&w, Quant::Int8)
        };
        assert_eq!(serial.bytes(), pooled.bytes(), "int8 pack bytes at {workers} workers");
        assert_eq!(serial.count(), pooled.count());
        for l in 0..spec.n_layers {
            for short in linear_shorts(&spec.family) {
                let a = serial.get_l(l, short).unwrap();
                let b = pooled.get_l(l, short).unwrap();
                let (aq, asc) = a.q_data().expect("serial panel not int8");
                let (bq, bsc) = b.q_data().expect("pooled panel not int8");
                assert_eq!(aq, bq, "layer {l} {short}: q bytes diverged at {workers} workers");
                assert!(
                    bits_eq(asc, bsc),
                    "layer {l} {short}: scales diverged at {workers} workers"
                );
            }
        }
        let a = serial.get("tok_emb").unwrap();
        let b = pooled.get("tok_emb").unwrap();
        let (aq, asc) = a.q_data().unwrap();
        let (bq, bsc) = b.q_data().unwrap();
        assert_eq!(aq, bq, "head q bytes diverged at {workers} workers");
        assert!(bits_eq(asc, bsc), "head scales diverged at {workers} workers");
    }
}

/// Int8 greedy decode is deterministic: generation over a quantized
/// plan is bit-identical across pool widths AND under `FASP_POOL_JITTER`
/// schedule perturbation — the dequant-in-register kernels keep the
/// canonical ascending-k one-accumulator-per-lane order, so int8
/// inherits the exact determinism contract of f32. (Int8 vs *f32*
/// values differ by the bounded quantization error; int8 vs int8 never
/// differs.)
#[test]
fn int8_generate_bit_identical_across_pool_widths_and_jitter() {
    use fasp::model::decode::{GenerateOpts, Sampler};
    use fasp::tensor::{IntTensor, Quant};

    let m = manifest();
    let (single, threaded) = sessions(&m, "llama_tiny");
    let spec = single.spec.clone();
    let w = Weights::init(&spec, 37);
    let prompt = IntTensor::new(
        vec![2, 5],
        (0..10).map(|i| (i * 11 + 2) % spec.vocab as i32).collect(),
    );
    let opts = GenerateOpts { max_new: 6, sampler: Sampler::Greedy, seed: 0 };

    let p1 = single.pack_as(&w.packed, Quant::Int8).unwrap();
    let p2 = threaded.pack_as(&w.packed, Quant::Int8).unwrap();
    assert_eq!(p1.quant(), Quant::Int8);
    let pf = single.pack(&w.packed).unwrap();
    assert!(
        (p1.pack_bytes() as f64) <= 0.55 * pf.pack_bytes() as f64,
        "int8 plan {} !<= 0.55x f32 plan {}",
        p1.pack_bytes(),
        pf.pack_bytes()
    );

    let g1 = single.generate(&p1, &prompt, &opts).unwrap();
    let g2 = threaded.generate(&p2, &prompt, &opts).unwrap();
    assert_eq!(g1.generated, 6, "int8 generation truncated");
    assert_eq!(
        g1.tokens.data, g2.tokens.data,
        "int8 decode diverged across pool widths 1 vs {THREADS}"
    );

    let wide =
        Session::with_backend(&m, "llama_tiny", Arc::new(ThreadedHostBackend::new(8))).unwrap();
    let p8 = wide.pack_as(&w.packed, Quant::Int8).unwrap();
    let g8 = wide.generate(&p8, &prompt, &opts).unwrap();
    assert_eq!(g1.tokens.data, g8.tokens.data, "int8 decode diverged at 8 workers");

    std::env::set_var("FASP_POOL_JITTER", "400");
    for i in 0..3 {
        let gj = threaded.generate(&p2, &prompt, &opts).unwrap();
        assert_eq!(
            g1.tokens.data, gj.tokens.data,
            "jitter run {i}: int8 decode diverged"
        );
    }
    std::env::remove_var("FASP_POOL_JITTER");
}

/// Schedule perturbation: `FASP_POOL_JITTER` delays every spawned pool
/// worker by a pseudorandom start offset, shuffling fan-out
/// interleavings — the dynamic complement to the `fasp lint` static
/// pass. Outputs must stay bit-identical, because determinism comes
/// from the fixed partition/reduction arithmetic, never from timing.
/// (Setting the env var is safe alongside concurrently running tests:
/// the knob can only slow workers down, not change any result — which
/// is exactly what this test proves.)
#[test]
fn outputs_bit_identical_under_pool_jitter() {
    use fasp::model::decode::{GenerateOpts, Sampler};
    use fasp::tensor::IntTensor;

    let m = manifest();
    let (_, threaded) = sessions(&m, "llama_tiny");
    let spec = threaded.spec.clone();
    let w = Weights::init(&spec, 29);
    let ds = Dataset::new(Corpus::new(spec.vocab, 31), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);
    let pack = threaded.pack(&w.packed).unwrap();
    let prompt = IntTensor::new(
        vec![2, 5],
        (0..10).map(|i| (i * 7 + 3) % spec.vocab as i32).collect(),
    );
    let gen_opts = GenerateOpts { max_new: 6, sampler: Sampler::Greedy, seed: 0 };

    let run = |label: &str| {
        let fwd = threaded.fwd_loss(&pack, &b.tokens, &b.targets).unwrap();
        let cap = threaded.capture(&pack, &[b.tokens.clone()]).unwrap();
        let grads = threaded
            .gradcol(&pack, &[(b.tokens.clone(), b.targets.clone())])
            .unwrap();
        let gen = threaded.generate(&pack, &prompt, &gen_opts).unwrap();
        assert_eq!(gen.generated, 6, "{label}: generation truncated");
        (fwd, cap, grads, gen)
    };

    let (fwd0, cap0, grads0, gen0) = run("baseline");
    std::env::set_var("FASP_POOL_JITTER", "400");
    let jittered: Vec<_> = (0..3).map(|i| run(&format!("jitter run {i}"))).collect();
    std::env::remove_var("FASP_POOL_JITTER");

    for (i, (fwd, cap, grads, gen)) in jittered.iter().enumerate() {
        assert_eq!(
            fwd0.mean_nll.to_bits(),
            fwd.mean_nll.to_bits(),
            "jitter run {i}: fwd mean nll diverged"
        );
        assert!(
            bits_eq(&fwd0.tok_nll.data, &fwd.tok_nll.data),
            "jitter run {i}: token nll diverged"
        );
        for (l, (a, c)) in cap0.layers.iter().zip(&cap.layers).enumerate() {
            assert!(bits_eq(&a.g_attn.data, &c.g_attn.data), "run {i} layer {l} g_attn");
            assert!(bits_eq(&a.g_ffn.data, &c.g_ffn.data), "run {i} layer {l} g_ffn");
        }
        for (l, (a, c)) in grads0.iter().zip(grads).enumerate() {
            assert!(bits_eq(&a.ffn, &c.ffn), "run {i} layer {l}: ffn scores diverged");
            assert!(bits_eq(&a.ov, &c.ov), "run {i} layer {l}: ov scores diverged");
        }
        assert_eq!(
            gen0.tokens.data, gen.tokens.data,
            "jitter run {i}: generated tokens diverged"
        );
    }
}
