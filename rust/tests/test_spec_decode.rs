//! Speculative-decoding contract: greedy speculative generation is
//! **bit-identical** to target-only `generate` — for every draft
//! (faithful s=0 clone, genuinely sliced compacts, even a draft built
//! from unrelated weights), every `draft_k`, both families, at every
//! pool width — because each committed token is a target argmax and the
//! chunked verification forward is bitwise the chunk≡steps contract.
//! Plus: the sampled path is seed-deterministic, an s=0 draft is always
//! accepted, mismatched drafts and malformed requests are proper
//! `Err`s, `decode_chunk_src` ≡ sequential `decode_step_src` bitwise,
//! and `KvCache::truncate` rolls back to a state bit-identical to
//! never having decoded past it.

use fasp::model::compact::{build_params, compact_from_mask};
use fasp::model::decode::{
    self, decode_chunk_src, decode_step_src, prefill_src, GenerateOpts, KvCache, Sampler,
};
use fasp::model::spec_decode::{generate_speculative_src, SpecOpts};
use fasp::model::{DenseParams, PruneMask, Weights};
use fasp::runtime::manifest::LayerDims;
use fasp::runtime::ModelSpec;
use fasp::tensor::{IntTensor, Tensor};
use fasp::util::pool;
use fasp::util::rng::Rng;
use std::sync::Arc;

fn bits_eq(a: &Tensor, b: &Tensor) -> bool {
    a.shape == b.shape
        && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn row_bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Ragged (compact-style) toy spec with one fully sliced head — the
/// chunked verification forward must hold exactly where the OV slicing
/// bites (same shape family as `test_decode`'s toy).
fn toy_spec(family: &str) -> ModelSpec {
    let layer_dims = vec![
        LayerDims { d_ff: 20, d_ov: 10, head_splits: vec![6, 4] },
        LayerDims { d_ff: 12, d_ov: 5, head_splits: vec![5, 0] },
        LayerDims { d_ff: 16, d_ov: 16, head_splits: vec![8, 8] },
    ];
    let params = build_params(family, 16, 3, 48, 24, &layer_dims);
    ModelSpec {
        name: format!("spec_toy_{family}"),
        family: family.into(),
        d_model: 16,
        n_heads: 2,
        n_layers: 3,
        d_ff: 20,
        vocab: 48,
        seq: 24,
        batch: 2,
        params,
        layer_dims,
    }
}

/// Dense-uniform toy spec — the shape `compact_from_mask` prunes from.
fn uniform_spec(family: &str, name: &str, vocab: usize) -> ModelSpec {
    let layer_dims: Vec<LayerDims> = (0..3)
        .map(|_| LayerDims { d_ff: 20, d_ov: 16, head_splits: vec![8, 8] })
        .collect();
    let params = build_params(family, 16, 3, vocab, 24, &layer_dims);
    ModelSpec {
        name: name.into(),
        family: family.into(),
        d_model: 16,
        n_heads: 2,
        n_layers: 3,
        d_ff: 20,
        vocab,
        seq: 24,
        batch: 2,
        params,
        layer_dims,
    }
}

/// Compact draft pruning the TAIL `pct`% of FFN units and per-head OV
/// dims — the same collision-free slices the bench uses.
fn tail_draft(base: &Weights, pct: usize, name: &str) -> Weights {
    let spec = &base.spec;
    let dh = spec.head_dim();
    let mut mask = PruneMask::full(spec);
    let fc = spec.d_ff * pct / 100;
    let vc = dh * pct / 100;
    for l in 0..spec.n_layers {
        for j in 0..fc {
            mask.layers[l].ffn[spec.d_ff - 1 - j] = false;
        }
        for hi in 0..spec.n_heads {
            for j in 0..vc {
                mask.layers[l].ov[hi * dh + dh - 1 - j] = false;
            }
        }
    }
    compact_from_mask(base, &mask, name).unwrap().weights
}

fn random_prompt(b: usize, t: usize, vocab: usize, seed: u64) -> IntTensor {
    let mut rng = Rng::new(seed);
    IntTensor::new(vec![b, t], (0..b * t).map(|_| rng.below(vocab) as i32).collect())
}

// -------------------------------------------------- greedy losslessness

/// The hard receipt: greedy speculative ≡ target-only `generate`, token
/// for token at every position, across draft sparsities (a faithful
/// tail-sliced family and a draft from UNRELATED weights — acceptance
/// near zero, identity must still hold), k ∈ {1, 2, 4, 8}, both
/// families, pool widths 1 and 4.
#[test]
fn greedy_speculative_bit_identical_to_generate() {
    for family in ["llama", "opt"] {
        let tspec = toy_spec(family);
        let tw = Weights::init(&tspec, 21);
        // drafts share only the token space with the ragged target
        let base = Weights::init(&uniform_spec(family, "spec_draft_base", tspec.vocab), 77);
        let stranger = Weights::init(&uniform_spec(family, "spec_draft_odd", tspec.vocab), 5);
        let drafts = [
            ("s30", tail_draft(&base, 30, "spec_d30")),
            ("s50", tail_draft(&base, 50, "spec_d50")),
            ("unrelated", stranger),
        ];
        let prompt = random_prompt(1, 5, tspec.vocab, 42);
        let opts = GenerateOpts { max_new: 12, sampler: Sampler::Greedy, seed: 0 };
        for workers in [1usize, 4] {
            let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
            let want = decode::generate_src(&mut DenseParams(&tw), &prompt, &opts).unwrap();
            for (label, dw) in &drafts {
                for k in [1usize, 2, 4, 8] {
                    let sopts = SpecOpts {
                        max_new: 12,
                        draft_k: k,
                        sampler: Sampler::Greedy,
                        seed: 0,
                    };
                    let g = generate_speculative_src(
                        &mut DenseParams(&tw),
                        &mut DenseParams(dw),
                        &prompt,
                        &sopts,
                    )
                    .unwrap();
                    assert_eq!(
                        g.tokens.data, want.tokens.data,
                        "{family} draft={label} k={k} w={workers}: speculative \
                         greedy diverged from target-only generate"
                    );
                    assert_eq!(g.tokens.shape, vec![1, 17]);
                    assert_eq!(g.prompt_len, 5);
                    assert_eq!(g.generated, 12);
                    assert!(g.accepted <= g.proposed, "accounting: {label} k={k}");
                    assert!(g.chunks >= 1);
                }
            }
        }
    }
}

/// A sparsity-0 draft is the target bit for bit — every proposal passes
/// the argmax check, acceptance is exactly 1.0, and the OV-sliced
/// drafts hold strictly smaller caches at the same capacity.
#[test]
fn zero_sparsity_draft_accepts_everything() {
    let spec = uniform_spec("llama", "spec_s0_base", 48);
    let w = Weights::init(&spec, 13);
    let clone = tail_draft(&w, 0, "spec_s0");
    assert_eq!(w.packed.data, clone.packed.data, "s=0 export must be bit-identical");
    let prompt = random_prompt(1, 5, spec.vocab, 3);
    let opts = SpecOpts { max_new: 12, draft_k: 4, sampler: Sampler::Greedy, seed: 0 };
    let g = generate_speculative_src(
        &mut DenseParams(&w),
        &mut DenseParams(&clone),
        &prompt,
        &opts,
    )
    .unwrap();
    assert!(g.proposed > 0);
    assert_eq!(g.accepted, g.proposed, "a faithful draft can never be rejected");
    assert_eq!(g.acceptance_rate(), 1.0);
    let want = decode::generate_src(
        &mut DenseParams(&w),
        &prompt,
        &GenerateOpts { max_new: 12, sampler: Sampler::Greedy, seed: 0 },
    )
    .unwrap();
    assert_eq!(g.tokens.data, want.tokens.data);
    assert_eq!(
        g.target_kv_bytes, g.draft_kv_bytes,
        "s=0 keeps the full OV dims — equal caches"
    );

    // a 50%-OV-sliced draft of the same base caches strictly less
    let half = tail_draft(&w, 50, "spec_s50_kv");
    let g2 = generate_speculative_src(
        &mut DenseParams(&w),
        &mut DenseParams(&half),
        &prompt,
        &opts,
    )
    .unwrap();
    assert!(
        g2.draft_kv_bytes < g2.target_kv_bytes,
        "sliced draft kv {} !< target kv {}",
        g2.draft_kv_bytes,
        g2.target_kv_bytes
    );
    assert_eq!(g2.tokens.data, want.tokens.data, "sliced draft still lossless");
}

// ------------------------------------------------------- sampled path

/// The sampled (top-k) path replays bit-for-bit under the same seed,
/// and every committed token stays in-vocab.
#[test]
fn sampled_speculative_is_seed_deterministic() {
    let spec = uniform_spec("llama", "spec_topk_base", 48);
    let w = Weights::init(&spec, 31);
    let draft = tail_draft(&w, 50, "spec_topk_d50");
    let prompt = random_prompt(1, 4, spec.vocab, 8);
    let opts = SpecOpts {
        max_new: 10,
        draft_k: 3,
        sampler: Sampler::TopK { k: 5, temperature: 0.8 },
        seed: 1234,
    };
    let a = generate_speculative_src(
        &mut DenseParams(&w),
        &mut DenseParams(&draft),
        &prompt,
        &opts,
    )
    .unwrap();
    let b = generate_speculative_src(
        &mut DenseParams(&w),
        &mut DenseParams(&draft),
        &prompt,
        &opts,
    )
    .unwrap();
    assert_eq!(a.tokens.data, b.tokens.data, "same seed must replay");
    assert_eq!(a.accepted, b.accepted);
    assert_eq!(a.chunks, b.chunks);
    for &t in &a.tokens.data {
        assert!(t >= 0 && (t as usize) < spec.vocab, "out-of-vocab token {t}");
    }
}

// ------------------------------------------------------ failure modes

/// Drafts that cannot speak for the target, and malformed requests, are
/// proper `Err`s before any forward work.
#[test]
fn mismatched_or_malformed_requests_are_rejected() {
    let tspec = toy_spec("llama");
    let tw = Weights::init(&tspec, 2);
    let prompt = random_prompt(1, 4, tspec.vocab, 1);
    let opts = SpecOpts::default();

    // draft with a different vocab can never share the token space
    let other = Weights::init(&uniform_spec("llama", "spec_v32", 32), 3);
    let err = generate_speculative_src(
        &mut DenseParams(&tw),
        &mut DenseParams(&other),
        &prompt,
        &opts,
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("token space"), "{err:#}");

    let good = Weights::init(&uniform_spec("llama", "spec_v48", 48), 3);

    // batched prompts would serialize on the slowest lane — rejected
    let wide = random_prompt(2, 4, tspec.vocab, 1);
    let err = generate_speculative_src(
        &mut DenseParams(&tw),
        &mut DenseParams(&good),
        &wide,
        &opts,
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("one sequence"), "{err:#}");

    // empty prompt rejected before prefill (shared generate validation)
    let empty = IntTensor::new(vec![1, 0], vec![]);
    let err = generate_speculative_src(
        &mut DenseParams(&tw),
        &mut DenseParams(&good),
        &empty,
        &opts,
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("rejected before prefill"), "{err:#}");

    // degenerate knobs
    for (max_new, draft_k) in [(0usize, 4usize), (8, 0)] {
        let bad = SpecOpts { max_new, draft_k, ..SpecOpts::default() };
        assert!(
            generate_speculative_src(
                &mut DenseParams(&tw),
                &mut DenseParams(&good),
                &prompt,
                &bad,
            )
            .is_err(),
            "max_new={max_new} draft_k={draft_k} must be rejected"
        );
    }
}

// --------------------------------------------- chunk ≡ steps (bitwise)

/// `decode_chunk_src` is bitwise the sequential `decode_step_src` path:
/// a chunk of one reproduces a single step exactly, and every row of a
/// multi-token chunk equals the corresponding step's logits — on both
/// families, on the ragged toy where the OV slicing bites.
#[test]
fn chunk_logits_bitwise_match_sequential_steps() {
    for family in ["llama", "opt"] {
        let spec = toy_spec(family);
        let w = Weights::init(&spec, 9);
        let prompt = random_prompt(1, 4, spec.vocab, 17);
        let seq: Vec<i32> = random_prompt(1, 6, spec.vocab, 29).data;

        let mut c_step = KvCache::for_spec(&spec, 1, 10).unwrap();
        let mut c_chunk = KvCache::for_spec(&spec, 1, 10).unwrap();
        prefill_src(&mut DenseParams(&w), &prompt, &mut c_step).unwrap();
        prefill_src(&mut DenseParams(&w), &prompt, &mut c_chunk).unwrap();

        // chunk of 1 ≡ decode_step, repeated
        for &tok in &seq[..2] {
            let t = IntTensor::new(vec![1, 1], vec![tok]);
            let ls = decode_step_src(&mut DenseParams(&w), &t, &mut c_step).unwrap();
            let lc = decode_chunk_src(&mut DenseParams(&w), &t, &mut c_chunk).unwrap();
            assert!(
                row_bits_eq(ls.row(0), lc.row(0)),
                "{family}: chunk-of-1 diverged from decode_step"
            );
            assert_eq!(c_step.len(), c_chunk.len());
        }

        // one 4-token chunk ≡ four steps, row by row
        let tail = &seq[2..6];
        let mut step_logits: Vec<Tensor> = Vec::new();
        for &tok in tail {
            let t = IntTensor::new(vec![1, 1], vec![tok]);
            step_logits.push(decode_step_src(&mut DenseParams(&w), &t, &mut c_step).unwrap());
        }
        let chunk = IntTensor::new(vec![1, 4], tail.to_vec());
        let lc = decode_chunk_src(&mut DenseParams(&w), &chunk, &mut c_chunk).unwrap();
        assert_eq!(c_chunk.len(), c_step.len());
        for (r, ls) in step_logits.iter().enumerate() {
            assert!(
                row_bits_eq(ls.row(0), lc.row(r)),
                "{family}: chunk row {r} diverged from its sequential step"
            );
        }

        // chunk overflow past capacity is loud and leaves no residue
        let over = IntTensor::new(vec![1, 1], vec![seq[0]]);
        let len_before = c_chunk.len();
        let err = decode_chunk_src(&mut DenseParams(&w), &over, &mut c_chunk).unwrap_err();
        assert!(format!("{err:#}").contains("overflow"), "{err:#}");
        assert_eq!(c_chunk.len(), len_before);
    }
}

// ------------------------------------------------- truncate (property)

/// Rolling a cache back with `truncate(p)` and re-decoding is
/// bit-identical to never having decoded past `p` — at several rollback
/// points, both families, pool widths 1 and 4; rolling *forward* is a
/// proper `Err` that leaves the cache untouched.
#[test]
fn truncate_then_redecode_is_bit_identical() {
    for family in ["llama", "opt"] {
        let spec = toy_spec(family);
        let w = Weights::init(&spec, 23);
        let t0 = 4;
        let t_total = 12;
        let prompt = random_prompt(1, t0, spec.vocab, 7);
        let seq: Vec<i32> = random_prompt(1, t_total - t0, spec.vocab, 11).data;
        for workers in [1usize, 4] {
            let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
            let mut cache = KvCache::for_spec(&spec, 1, t_total).unwrap();
            prefill_src(&mut DenseParams(&w), &prompt, &mut cache).unwrap();
            // logits[i] = step logits after feeding seq[i] (cache len t0+i+1)
            let mut logits: Vec<Tensor> = Vec::new();
            for &tok in &seq {
                let t = IntTensor::new(vec![1, 1], vec![tok]);
                logits.push(decode_step_src(&mut DenseParams(&w), &t, &mut cache).unwrap());
            }
            assert_eq!(cache.len(), t_total);

            for p in [t0, t0 + 3, t_total - 1] {
                cache.truncate(p).unwrap();
                assert_eq!(cache.len(), p);
                for (i, &tok) in seq.iter().enumerate().skip(p - t0) {
                    let t = IntTensor::new(vec![1, 1], vec![tok]);
                    let l =
                        decode_step_src(&mut DenseParams(&w), &t, &mut cache).unwrap();
                    assert!(
                        bits_eq(&l, &logits[i]),
                        "{family} (w={workers}): re-decode after truncate({p}) \
                         diverged at step {i}"
                    );
                }
                assert_eq!(cache.len(), t_total);
            }

            // truncate can only roll back, never extend
            let err = cache.truncate(t_total + 1).unwrap_err();
            assert!(format!("{err:#}").contains("roll back"), "{err:#}");
            assert_eq!(cache.len(), t_total, "failed truncate must not move the cache");

            // truncate(0) resets far enough for a fresh prefill
            cache.truncate(0).unwrap();
            let l0 = prefill_src(&mut DenseParams(&w), &prompt, &mut cache).unwrap();
            let mut fresh = KvCache::for_spec(&spec, 1, t_total).unwrap();
            let lf = prefill_src(&mut DenseParams(&w), &prompt, &mut fresh).unwrap();
            assert!(
                bits_eq(&l0, &lf),
                "{family} (w={workers}): prefill after truncate(0) diverged"
            );
        }
    }
}
