//! Sharded compact store test matrix (see rust/tests/README.md): a
//! sharded export must be a bit-faithful, stream-loadable twin of the
//! monolithic compact artifact.
//!
//! * equivalence: sharded↔monolithic bit-identical weights, `fwd_loss`
//!   and perplexity (f64 bit equality) after a save → register → load
//!   round trip;
//! * residency: streaming eval never materializes more than the
//!   embed/head shard + one layer shard (+ the backend's prefetch
//!   buffer) — strictly less than the whole model;
//! * failure injection: truncated shard, corrupt shard (checksum
//!   mismatch), missing shard file, shard-index/layer-count mismatch,
//!   duplicate compact names;
//! * compact-aware kernel metrics: registration synthesizes
//!   `wanda_metric_{m}x{n}` entries for the sliced shapes.

use fasp::data::{Corpus, Dataset};
use fasp::eval::{perplexity, perplexity_streamed};
use fasp::model::{compact, CompactModel, Weights};
use fasp::prune::metric::{wanda_scores_host, KernelMetric};
use fasp::runtime::{HostBackend, Manifest, Session, ThreadedHostBackend};
use fasp::tensor::Tensor;
use fasp::util::json::Json;
use std::path::PathBuf;
use std::sync::Arc;

fn manifest() -> Manifest {
    Manifest::load(&fasp::artifacts_dir()).expect("run `make artifacts` first")
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fasp_store_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Compact model from `model` with a mixed FFN+OV mask.
fn make_compact(model: &str, name: &str, seed: u64) -> CompactModel {
    let m = manifest();
    let spec = m.model(model).unwrap().clone();
    let w = Weights::init(&spec, seed);
    let mut mask = fasp::model::PruneMask::full(&spec);
    for l in 0..spec.n_layers {
        for j in 0..spec.d_ff / 4 {
            mask.layers[l].ffn[(j * 3 + l) % spec.d_ff] = false;
        }
        for j in 0..spec.d_model / 8 {
            mask.layers[l].ov[(j * 5 + l) % spec.d_model] = false;
        }
    }
    compact::compact_from_mask(&w, &mask, name).unwrap()
}

#[test]
fn sharded_equals_monolithic_bit_identical_weights_fwd_and_ppl() {
    let name = "ls_store_eq";
    let cm = make_compact("llama_small", name, 5);
    let dmono = tmpdir("eq_mono");
    let dshard = tmpdir("eq_shard");
    let jp_m = compact::save_compact(&dmono, &cm).unwrap();
    let jp_s = compact::save_compact_sharded(&dshard, &cm).unwrap();

    let mut m1 = manifest();
    m1.register_compact(&jp_m).unwrap();
    let mut m2 = manifest();
    m2.register_compact(&jp_s).unwrap();

    // bit-identical packed weights after the round trip, both storages
    let w_mono = m1.compact_weights(name).unwrap();
    let w_shard = m2.compact_weights(name).unwrap();
    assert!(
        bits_eq(&w_mono.packed.data, &w_shard.packed.data),
        "sharded assembly diverged from the monolithic weights"
    );
    assert!(bits_eq(&w_mono.packed.data, &cm.weights.packed.data));

    let s1 = Session::new(&m1, name).unwrap();
    let s2 = Session::new(&m2, name).unwrap();
    let spec = s1.spec.clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 7), spec.batch, spec.seq, 6);

    // fwd_loss: monolithic entry vs streaming store, bitwise
    let b = ds.train_batch(0);
    let store = m2.compact_store(name).unwrap();
    let o1 = s1
        .fwd_loss(&s1.pack(&w_mono.packed).unwrap(), &b.tokens, &b.targets)
        .unwrap();
    let o2 = s2.fwd_loss_streamed(&store, &b.tokens, &b.targets).unwrap();
    assert_eq!(o1.mean_nll.to_bits(), o2.mean_nll.to_bits(), "mean nll diverged");
    assert!(bits_eq(&o1.seq_nll, &o2.seq_nll), "seq nll diverged");
    assert!(bits_eq(&o1.tok_nll.data, &o2.tok_nll.data), "token nll diverged");

    // perplexity: f64 bit equality across the two load paths
    let eval_b = ds.valid_batches(3);
    let ppl_mono = perplexity(&s1, &w_mono, &eval_b).unwrap();
    let ppl_stream = perplexity_streamed(&s2, &store, &eval_b).unwrap();
    assert_eq!(
        ppl_mono.to_bits(),
        ppl_stream.to_bits(),
        "streamed ppl {ppl_stream} != monolithic ppl {ppl_mono}"
    );

    std::fs::remove_dir_all(&dmono).ok();
    std::fs::remove_dir_all(&dshard).ok();
}

/// The streaming path's receipt: peak resident weights stay at the
/// embed/head shard + one layer (+ prefetch buffer), strictly below the
/// whole model — on both the serial (prefetch 0) and threaded
/// (prefetch 1) backends, with bit-identical outputs.
#[test]
fn streaming_peak_residency_is_one_layer_plus_prefetch() {
    let name = "ls_store_resident";
    let cm = make_compact("llama_small", name, 11);
    let d = tmpdir("resident");
    let jp = compact::save_compact_sharded(&d, &cm).unwrap();
    let mut m = manifest();
    m.register_compact(&jp).unwrap();
    let store = m.compact_store(name).unwrap();
    let spec = m.model(name).unwrap().clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 3), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);

    let single = Session::with_backend(&m, name, Arc::new(HostBackend::new())).unwrap();
    store.reset_stats();
    let o1 = single.fwd_loss_streamed(&store, &b.tokens, &b.targets).unwrap();
    let snap1 = store.stats();
    assert_eq!(snap1.resident_bytes, 0, "shards leaked after the forward");
    assert!(
        snap1.peak_resident_bytes <= store.embed_bytes() + store.max_layer_bytes(),
        "serial backend (prefetch 0): peak {} > embed {} + one layer {}",
        snap1.peak_resident_bytes,
        store.embed_bytes(),
        store.max_layer_bytes()
    );

    let threaded =
        Session::with_backend(&m, name, Arc::new(ThreadedHostBackend::new(4))).unwrap();
    store.reset_stats();
    let o2 = threaded.fwd_loss_streamed(&store, &b.tokens, &b.targets).unwrap();
    let snap2 = store.stats();
    assert_eq!(snap2.resident_bytes, 0);
    assert!(
        snap2.peak_resident_bytes
            <= store.embed_bytes() + 2 * store.max_layer_bytes(),
        "threaded backend (prefetch 1): peak {} > embed + 2 layers",
        snap2.peak_resident_bytes
    );
    assert!(
        snap2.peak_resident_bytes < store.total_param_bytes(),
        "streaming never beat full residency: peak {} vs model {}",
        snap2.peak_resident_bytes,
        store.total_param_bytes()
    );

    // prefetch depth changes wall-time only, never numerics
    assert_eq!(o1.mean_nll.to_bits(), o2.mean_nll.to_bits());
    assert!(bits_eq(&o1.tok_nll.data, &o2.tok_nll.data));
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn capture_streamed_matches_monolithic_capture_bitwise() {
    let name = "lt_store_capture";
    let cm = make_compact("llama_tiny", name, 13);
    let d = tmpdir("capture");
    let jp = compact::save_compact_sharded(&d, &cm).unwrap();
    let mut m = manifest();
    m.register_compact(&jp).unwrap();
    let store = m.compact_store(name).unwrap();
    let session = Session::new(&m, name).unwrap();
    let spec = session.spec.clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 17), spec.batch, spec.seq, 3);
    let batches: Vec<_> = (0..2).map(|i| ds.train_batch(i).tokens).collect();

    let w = m.compact_weights(name).unwrap();
    let mono = session.capture(&session.pack(&w.packed).unwrap(), &batches).unwrap();
    let streamed = session.capture_streamed(&store, &batches).unwrap();
    assert_eq!(mono.rows, streamed.rows);
    for (l, (a, b)) in mono.layers.iter().zip(&streamed.layers).enumerate() {
        assert!(bits_eq(&a.g_ln1.data, &b.g_ln1.data), "layer {l} g_ln1");
        assert!(bits_eq(&a.g_ln2.data, &b.g_ln2.data), "layer {l} g_ln2");
        assert!(bits_eq(&a.g_attn.data, &b.g_attn.data), "layer {l} g_attn");
        assert!(bits_eq(&a.g_ffn.data, &b.g_ffn.data), "layer {l} g_ffn");
        assert!(bits_eq(&a.m_ln1.data, &b.m_ln1.data), "layer {l} m_ln1");
        assert!(bits_eq(&a.m_ln2.data, &b.m_ln2.data), "layer {l} m_ln2");
        assert!(bits_eq(&a.m_attn.data, &b.m_attn.data), "layer {l} m_attn");
        assert!(bits_eq(&a.m_ffn.data, &b.m_ffn.data), "layer {l} m_ffn");
    }
    std::fs::remove_dir_all(&d).ok();
}

// ---- failure injection --------------------------------------------------

fn make_sharded_artifact(dir: &std::path::Path, name: &str) -> PathBuf {
    let cm = make_compact("llama_tiny", name, 3);
    compact::save_compact_sharded(dir, &cm).unwrap()
}

#[test]
fn truncated_shard_rejected_by_checksum() {
    let d = tmpdir("trunc");
    let jp = make_sharded_artifact(&d, "trunc_shard");
    let spath = d.join("trunc_shard.layer000.ftns");
    let bytes = std::fs::read(&spath).unwrap();
    std::fs::write(&spath, &bytes[..bytes.len() / 2]).unwrap();
    let mut m = manifest();
    m.register_compact(&jp).unwrap(); // file still exists; load must fail
    let err = match m.compact_weights("trunc_shard") {
        Err(e) => e,
        Ok(_) => panic!("truncated shard accepted"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("checksum mismatch"), "unhelpful error: {msg}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn corrupt_shard_byte_rejected_by_checksum() {
    let d = tmpdir("corrupt");
    let jp = make_sharded_artifact(&d, "corrupt_shard");
    let spath = d.join("corrupt_shard.layer001.ftns");
    let mut bytes = std::fs::read(&spath).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40; // same length, different payload
    std::fs::write(&spath, &bytes).unwrap();
    let mut m = manifest();
    m.register_compact(&jp).unwrap();
    let err = m.compact_weights("corrupt_shard").unwrap_err();
    assert!(
        format!("{err:#}").contains("checksum mismatch"),
        "{err:#}"
    );
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn missing_shard_file_rejected_at_registration() {
    let d = tmpdir("missing");
    let jp = make_sharded_artifact(&d, "missing_shard");
    std::fs::remove_file(d.join("missing_shard.layer001.ftns")).unwrap();
    let mut m = manifest();
    let err = match m.register_compact(&jp) {
        Err(e) => e,
        Ok(_) => panic!("artifact with a missing shard registered"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("missing shard file"), "unhelpful error: {msg}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn shard_index_layer_count_mismatch_rejected() {
    let d = tmpdir("idx");
    let jp = make_sharded_artifact(&d, "idx_shard");
    // drop the last shard entry from the index (json stays well-formed)
    let j = Json::parse(&std::fs::read_to_string(&jp).unwrap()).unwrap();
    let mut obj = j.as_obj().unwrap().clone();
    let shards = obj["shards"].as_arr().unwrap().to_vec();
    obj.insert(
        "shards".to_string(),
        Json::Arr(shards[..shards.len() - 1].to_vec()),
    );
    std::fs::write(&jp, Json::Obj(obj).pretty()).unwrap();
    let mut m = manifest();
    let err = m.register_compact(&jp).unwrap_err();
    assert!(
        format!("{err:#}").contains("index/layer-count mismatch"),
        "{err:#}"
    );
    std::fs::remove_dir_all(&d).ok();
}

/// Two descriptors declaring the same model name must fail the manifest
/// scan loudly instead of silently overwriting each other (the
/// `register_compact` duplicate-name fix).
#[test]
fn duplicate_compact_names_rejected_at_scan() {
    let d = tmpdir("dup");
    std::fs::copy(
        fasp::artifacts_dir().join("manifest.json"),
        d.join("manifest.json"),
    )
    .unwrap();
    let cdir = d.join("compact");
    let cm = make_compact("llama_tiny", "dup_model", 9);
    compact::save_compact(&cdir, &cm).unwrap();
    // a second descriptor file declaring the same name
    std::fs::copy(
        cdir.join("dup_model.compact.json"),
        cdir.join("zz_dup.compact.json"),
    )
    .unwrap();
    let err = match Manifest::load(&d) {
        Err(e) => e,
        Ok(_) => panic!("duplicate compact names accepted"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("multiple descriptors"), "unhelpful error: {msg}");
    std::fs::remove_dir_all(&d).ok();
}

// ---- compact-aware kernel metrics ---------------------------------------

/// Registering a compact model synthesizes `wanda_metric_{m}x{n}`
/// entries for its sliced shapes, and the kernel path computes the same
/// scores as the host metric — no more once-per-shape fallback warning
/// for freshly exported models.
#[test]
fn compact_registration_synthesizes_metric_entries() {
    let name = "lt_store_metric";
    let cm = make_compact("llama_tiny", name, 21);
    let d = tmpdir("metric");
    let jp = compact::save_compact_sharded(&d, &cm).unwrap();
    let mut m = manifest();
    m.register_compact(&jp).unwrap();
    let spec = m.model(name).unwrap().clone();
    let dm = spec.d_model;
    for l in 0..spec.n_layers {
        for n in [spec.d_ff_l(l), spec.d_ov_l(l)] {
            // both orientations: pipeline scores [d, n], the wanda_struct
            // baseline scores the transposed [n, d] operators
            for key in [
                format!("wanda_metric_{dm}x{n}"),
                format!("wanda_metric_{n}x{dm}"),
            ] {
                assert!(m.artifacts.contains_key(&key), "no synthesized {key} entry");
            }
        }
    }
    // the sliced FFN shape is not a dense zoo shape, so it must have been
    // synthesized here — and it must agree with the host metric exactly
    let f0 = spec.d_ff_l(0);
    assert!(f0 < spec.d_ff, "mask did not slice layer 0");
    let mut rng = fasp::util::rng::Rng::new(2);
    let w = Tensor::randn(&[dm, f0], 1.0, &mut rng);
    let xnorm: Vec<f32> = (0..f0).map(|i| 0.2 + i as f32 * 1e-3).collect();
    let km = KernelMetric::new(&m);
    let scores = km.wanda_scores(&w, &xnorm).unwrap();
    assert!(bits_eq(&scores, &wanda_scores_host(&w, &xnorm)));
    std::fs::remove_dir_all(&d).ok();
}

// ---- int8 quantized shards -----------------------------------------------

/// Int8 sharded export round trip: the index records the dtype per
/// shard, layer payloads shrink to ~0.27× of f32 (int8 q bytes +
/// per-group f32 scales + FQ8S header), the embed/head shard stays
/// exact f32, every assembled (dequantized) weight lands within half a
/// scale step of its original, and streamed evaluation over the
/// quantized store is bit-identical across pool widths.
#[test]
fn int8_shard_roundtrip_dtype_payload_and_error_bound() {
    use fasp::runtime::store::ShardKind;
    use fasp::tensor::pack::{Quant, Q8_GROUP};
    let name = "lt_store_int8";
    let cm = make_compact("llama_tiny", name, 19);
    let d = tmpdir("int8_rt");
    let jp = compact::save_compact_sharded_q(&d, &cm, Quant::Int8).unwrap();
    let mut m = manifest();
    m.register_compact(&jp).unwrap();
    let store = m.compact_store(name).unwrap();
    assert_eq!(store.quant(), Quant::Int8);
    for s in &store.index().shards {
        match s.kind {
            ShardKind::Embed => {
                assert_eq!(s.dtype, Quant::F32, "embed shard must stay f32");
                assert_eq!(s.payload_bytes(), s.elems * 4);
            }
            ShardKind::Layer(_) => {
                assert_eq!(s.dtype, Quant::Int8, "{}: layer shard not int8", s.file);
                let groups = (s.elems + Q8_GROUP - 1) / Q8_GROUP;
                assert_eq!(s.payload_bytes(), 16 + s.elems + 4 * groups);
                assert!(
                    (s.payload_bytes() as f64) < 0.30 * (s.elems * 4) as f64,
                    "{}: int8 payload {} not ~quarter of f32 {}",
                    s.file,
                    s.payload_bytes(),
                    s.elems * 4
                );
            }
        }
    }
    assert!(
        store.total_payload_bytes() < store.total_param_bytes(),
        "quantized store does not stream fewer bytes than f32"
    );
    assert!(store.max_layer_payload_bytes() < store.max_layer_bytes());

    // assembled weights dequantize within half a scale step of the
    // originals (every group scale is <= global amax / 127), and exact
    // zeros survive exactly
    let re = m.compact_weights(name).unwrap();
    let orig = &cm.weights.packed.data;
    assert_eq!(re.packed.data.len(), orig.len());
    let amax = orig.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let bound = 0.5 * amax / 127.0 + 1e-6;
    for (i, (&x, &y)) in orig.iter().zip(&re.packed.data).enumerate() {
        assert!(
            (x - y).abs() <= bound,
            "elem {i}: {x} vs dequantized {y} exceeds bound {bound}"
        );
        if x == 0.0 {
            assert_eq!(y.to_bits(), 0.0f32.to_bits(), "elem {i}: exact zero must survive");
        }
    }

    // streamed ppl over the int8 store: finite, and f64-bit-identical
    // across pool widths / prefetch depths
    let spec = m.model(name).unwrap().clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 23), spec.batch, spec.seq, 4);
    let eval_b = ds.valid_batches(2);
    let s1 = Session::with_backend(&m, name, Arc::new(HostBackend::new())).unwrap();
    let s2 = Session::with_backend(&m, name, Arc::new(ThreadedHostBackend::new(4))).unwrap();
    let ppl1 = perplexity_streamed(&s1, &store, &eval_b).unwrap();
    let ppl2 = perplexity_streamed(&s2, &store, &eval_b).unwrap();
    assert!(ppl1.is_finite() && ppl1 > 0.0, "int8 streamed ppl not finite: {ppl1}");
    assert_eq!(
        ppl1.to_bits(),
        ppl2.to_bits(),
        "int8 streamed ppl diverged across pool widths: {ppl1} vs {ppl2}"
    );
    std::fs::remove_dir_all(&d).ok();
}

/// Streamed int8 decode: `generate_streamed` over a quantized store is
/// bit-identical across pool widths / prefetch depths and across
/// replays — the prefetch thread quantizes panels with the same
/// fixed-partition arithmetic as the synchronous path.
#[test]
fn int8_streamed_decode_bit_identical_across_pool_widths() {
    use fasp::model::decode::{GenerateOpts, Sampler};
    use fasp::tensor::pack::Quant;
    use fasp::tensor::IntTensor;
    let name = "lt_store_int8_gen";
    let cm = make_compact("llama_tiny", name, 27);
    let d = tmpdir("int8_gen");
    let jp = compact::save_compact_sharded_q(&d, &cm, Quant::Int8).unwrap();
    let mut m = manifest();
    m.register_compact(&jp).unwrap();
    let store = m.compact_store(name).unwrap();
    let spec = m.model(name).unwrap().clone();
    let prompt = IntTensor::new(
        vec![2, 4],
        (0..8).map(|i| (i * 5 + 1) % spec.vocab as i32).collect(),
    );
    let opts = GenerateOpts { max_new: 5, sampler: Sampler::Greedy, seed: 0 };
    let single = Session::with_backend(&m, name, Arc::new(HostBackend::new())).unwrap();
    let threaded =
        Session::with_backend(&m, name, Arc::new(ThreadedHostBackend::new(4))).unwrap();
    let g1 = single.generate_streamed(&store, &prompt, &opts).unwrap();
    let g2 = threaded.generate_streamed(&store, &prompt, &opts).unwrap();
    let g3 = threaded.generate_streamed(&store, &prompt, &opts).unwrap();
    assert_eq!(g1.generated, 5, "int8 streamed generation truncated");
    assert_eq!(
        g1.tokens.data, g2.tokens.data,
        "int8 streamed decode diverged across pool widths"
    );
    assert_eq!(g2.tokens.data, g3.tokens.data, "int8 streamed decode replay diverged");
    std::fs::remove_dir_all(&d).ok();
}

/// Int8 shard integrity: checksums cover the written (quantized) bytes,
/// so a flipped byte or a truncation in an FQ8S layer shard is rejected
/// exactly like an f32 shard.
#[test]
fn corrupt_and_truncated_int8_shards_rejected() {
    use fasp::tensor::pack::Quant;
    let name = "int8_corrupt";
    let d = tmpdir("int8_fail");
    let cm = make_compact("llama_tiny", name, 3);
    let jp = compact::save_compact_sharded_q(&d, &cm, Quant::Int8).unwrap();
    let spath = d.join(format!("{name}.layer001.ftns"));
    let orig = std::fs::read(&spath).unwrap();

    // flipped byte: same length, different payload
    let mut bytes = orig.clone();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&spath, &bytes).unwrap();
    let mut m = manifest();
    m.register_compact(&jp).unwrap();
    let err = m.compact_weights(name).unwrap_err();
    assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");

    // truncation: half the file
    std::fs::write(&spath, &orig[..orig.len() / 2]).unwrap();
    let mut m2 = manifest();
    m2.register_compact(&jp).unwrap();
    let err2 = m2.compact_weights(name).unwrap_err();
    assert!(format!("{err2:#}").contains("checksum mismatch"), "{err2:#}");
    std::fs::remove_dir_all(&d).ok();
}

/// Old-format compat: an f32 shard index written before the dtype field
/// existed (no "dtype" key on any shard entry) must load as `F32` with
/// bit-identical weights — the quantization change cannot orphan
/// existing sharded artifacts.
#[test]
fn legacy_shard_index_without_dtype_loads_as_f32() {
    use fasp::tensor::pack::Quant;
    let name = "legacy_dtype";
    let d = tmpdir("legacy_dtype");
    let cm = make_compact("llama_tiny", name, 29);
    let jp = compact::save_compact_sharded(&d, &cm).unwrap();
    // strip the dtype field from every shard entry, as an old writer
    // would have produced
    let j = Json::parse(&std::fs::read_to_string(&jp).unwrap()).unwrap();
    let mut obj = j.as_obj().unwrap().clone();
    let shards: Vec<Json> = obj["shards"]
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| {
            let mut so = s.as_obj().unwrap().clone();
            assert!(so.remove("dtype").is_some(), "new index should carry dtype");
            Json::Obj(so)
        })
        .collect();
    obj.insert("shards".to_string(), Json::Arr(shards));
    std::fs::write(&jp, Json::Obj(obj).pretty()).unwrap();

    let mut m = manifest();
    m.register_compact(&jp).unwrap();
    let store = m.compact_store(name).unwrap();
    assert_eq!(store.quant(), Quant::F32, "legacy index must default to f32");
    let w = m.compact_weights(name).unwrap();
    assert!(
        bits_eq(&w.packed.data, &cm.weights.packed.data),
        "legacy f32 round trip diverged"
    );
    std::fs::remove_dir_all(&d).ok();
}

// ---- export-mode env axis ------------------------------------------------

/// `verify.sh` runs the tier-1 suite under both `FASP_EXPORT=monolithic`
/// and `FASP_EXPORT=sharded`; this round trip follows the ambient mode
/// through `save_compact_auto`, so both storage paths get end-to-end
/// coverage from the same test.
#[test]
fn auto_export_roundtrip_in_ambient_mode() {
    let name = "lt_store_auto";
    let cm = make_compact("llama_tiny", name, 8);
    let d = tmpdir("auto");
    let jp = compact::save_compact_auto(&d, &cm).unwrap();
    let re = compact::load_compact(&jp).unwrap();
    assert!(bits_eq(&re.weights.packed.data, &cm.weights.packed.data));
    let mut m = manifest();
    let registered = m.register_compact(&jp).unwrap();
    assert_eq!(registered, name);
    let lw = m.compact_weights(name).unwrap();
    assert!(bits_eq(&lw.packed.data, &cm.weights.packed.data));
    std::fs::remove_dir_all(&d).ok();
}
