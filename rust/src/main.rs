//! `fasp` CLI entrypoint — see `fasp help`.

fn main() {
    if let Err(e) = fasp::cli::run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
