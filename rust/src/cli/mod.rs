//! Hand-rolled CLI (clap is not in the offline vendor set): a small
//! positional/flag parser plus the subcommand implementations.

pub mod args;
pub mod commands;

pub use args::Args;

use crate::Result;

pub const USAGE: &str = "\
fasp — Fast and Accurate Structured Pruning (paper reproduction)

USAGE: fasp <COMMAND> [OPTIONS]

COMMANDS:
  info                         manifest + zoo summary
  train      --model M         train (or re-train) a zoo model
  eval       --model M         perplexity of the (trained) model
  prune      --model M --method X --sparsity S   prune + evaluate
  compact    --model M --sparsity S  prune, physically repack and save a
                               compact model artifact; evaluates ppl
                               parity and dense-vs-compact latency
  shard      --model M --sparsity S  like compact, but saves a SHARDED
                               export (one .ftns per layer + embed shard,
                               checksummed index) and verifies streaming
                               load: bit-identical ppl at O(one layer)
                               peak resident weights
  generate   --model M         KV-cached autoregressive generation from a
                               corpus prompt (zoo or compact model):
                               prefill + per-token decode timings and the
                               resident KV-cache bytes
  serve      --model M         continuous-batching serve engine, driven by
                               a self-generated session load: admission
                               queue + paged KV arena + prefix cache over
                               one shared packed plan; reports tokens/sec,
                               p50/p99 per-token latency, page residency
                               and (with --check) verifies every session
                               is bit-identical to sequential generate
  chaos      --model M         deterministic fault-injection drill over the
                               serve engine: a fault-free baseline, then the
                               same load twice under one seeded fault plan
                               (worker panics, KV-arena exhaustion) plus a
                               shard-store probe (checksum corruption,
                               truncation); reports faults absorbed vs fatal,
                               shed/retry counters and throughput under
                               faults, writes BENCH_chaos.json, and (with
                               --check) asserts survivors bit-identical to
                               the fault-free run, bit-identical replay and
                               zero leaked arena pages
  zeroshot   --model M [--method X --sparsity S] zero-shot suites
  tables     --id table1|...|fig4|all            regenerate paper tables
  latency                      sliced decoder-layer latency sweep
  lint                         determinism & robustness static analysis
                               over rust/src (rules D1-D3, U1, R1, P1;
                               suppressions in rust/lint_allow.toml);
                               writes LINT_REPORT.json, exits non-zero
                               on any non-allowlisted violation
  help                         this message

COMMON OPTIONS:
  --fast                 shrink eval/calibration budgets
  --steps N              override training steps (train)
  --method NAME          fasp|wanda|magnitude|flap|slicegpt|llm_pruner|nasllm
  --sparsity F           target sparsity in [0,1) (default 0.2)
  --calib N              calibration batches (default 8)
  --eval-batches N       perplexity batches (default 12)
  --no-restore           disable FASP restoration (ablation)
  --export-compact       (prune) also save a compact artifact of the mask
                         (storage per FASP_EXPORT, default monolithic)
  --export-sharded       (prune) like --export-compact, but always sharded
  --name NAME            compact artifact name (default <model>_<method>_sNN)
  --prune-qk             also prune W_Q/W_K rows (Table 6 ablation)
  --prompt-len N         (generate) corpus prompt tokens (default 16)
  --max-new N            (generate) tokens to generate (default 32)
  --batch N              (generate) sequences decoded in lockstep (default 1)
  --top-k K              (generate) top-k sampling; 0 = greedy (default 0)
  --temperature F        (generate) top-k softmax temperature (default 1.0)
  --draft NAME           (generate) also decode speculatively: NAME (a
                         registered compact model, or a fresh on-the-fly
                         compact export of the target at --draft-sparsity)
                         proposes tokens, the target verifies them in one
                         chunked forward; greedy output is bit-identical
                         to target-only generate
  --draft-k K            (generate) draft proposals per round (default 4)
  --draft-sparsity F     (generate) sparsity of a synthesized draft in
                         [0,1) (default 0.5; only when NAME is unregistered)
  --init                 (generate/serve) fresh deterministic weights —
                         skip checkpoint/training (decode smoke tests)
  --sessions N           (serve) concurrent decode sessions (default 8);
                         the second half repeat the first half's prompts
                         to exercise the prefix cache
  --page N               (serve) positions per KV arena page (default 16)
  --pages N              (serve) arena pool size in pages (default: sized
                         to the load with ~25% slack)
  --max-batch N          (serve) max sessions per batched tick (default 8)
  --no-prefix-cache      (serve) disable prompt-head sharing
  --prefill-chunk N      (serve) prompt tokens a prefilling session may
                         consume per tick via one chunked forward
                         (default 4; 1 = token-per-tick; outputs are
                         bit-identical at any value)
  --check                (serve) replay and assert bit-identity: serve
                         sessions against sequential generate, and
                         (generate --draft) speculative greedy tokens
                         against target-only generate; (chaos) assert the
                         full graceful-degradation contract
  --plan SPEC            (chaos) explicit fault plan, e.g.
                         'pool@2=panic,arena@1=exhaust*always'
                         (site@nth=kind[:arg][*count]; overrides both the
                         FASP_FAULTS env var and seeded synthesis)
  --faults N             (chaos) pool-panic faults to synthesize when no
                         explicit plan is given (default 2)
  --queue-cap N          (chaos) admission-queue bound; arrivals beyond it
                         are deterministically shed from the back
                         (default sessions-1: sheds exactly one)
  --tick-retries N       (chaos) bounded retries for a faulted scheduler
                         tick before the affected sessions are retired
                         (default 2)
  --stream               (generate) decode a sharded compact model from
                         its shard store (layer-streaming weights)
  --sequential           re-capture activations after each pruned layer
  --json PATH            (lint/chaos) write the JSON report somewhere else
  --report               persist a JSON run record under results/reports/
  --out PATH             save the pruned weights as a checkpoint
  --seed N               experiment seed (default 42)

ENVIRONMENT:
  FASP_THREADS=N         host-backend worker count (1 = single-threaded
                         reference backend; default: cores, capped at 8;
                         outputs are bit-identical at every width)
  FASP_EXPORT=MODE       default compact export storage: 'monolithic'
                         (one packed .ftns, default) or 'sharded' (one
                         .ftns per layer, stream-loadable); exported
                         weights are bit-identical either way
  FASP_FAULTS=PLAN       arm a fault plan for any command (grammar as
                         --plan); faults fire on exact event counters
                         (the Nth shard read / pool fan-out / arena
                         grow), never on wall clock, so every injected
                         failure replays bit-identically

Artifacts must exist (`make artifacts`). Checkpoints are cached under
checkpoints/ and reused across runs.
";

pub fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.command.as_deref() {
        Some("info") => commands::info(&args),
        Some("train") => commands::train(&args),
        Some("eval") => commands::eval(&args),
        Some("prune") => commands::prune(&args),
        Some("compact") => commands::compact(&args),
        Some("shard") => commands::shard(&args),
        Some("generate") => commands::generate(&args),
        Some("serve") => commands::serve(&args),
        Some("chaos") => commands::chaos(&args),
        Some("zeroshot") => commands::zeroshot(&args),
        Some("tables") => commands::tables(&args),
        Some("latency") => commands::latency(&args),
        Some("lint") => commands::lint(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            anyhow::bail!("unknown command '{other}'\n\n{USAGE}")
        }
    }
}
