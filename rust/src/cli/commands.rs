//! Subcommand implementations.

use super::args::Args;
use crate::bench_support::table::Table;
use crate::data::tasks::{TaskKind, TaskSuite};
use crate::data::{Corpus, Dataset};
use crate::eval::{eval_suite, perplexity};
use crate::experiments::common::ExpCtx;
use crate::model::zoo;
use crate::prune::{Method, PruneOpts};
use crate::runtime::{Backend, Manifest, Session};
use crate::util::timer::fmt_duration;
use crate::Result;
use std::time::Duration;

fn manifest() -> Result<Manifest> {
    Manifest::load(&crate::artifacts_dir())
}

fn ctx_from(args: &Args) -> Result<ExpCtx> {
    let mut ctx = ExpCtx::new(manifest()?, args.has("fast"));
    ctx.eval_batches = args.get_usize("eval-batches", ctx.eval_batches)?;
    ctx.calib_batches = args.get_usize("calib", ctx.calib_batches)?;
    ctx.seed = args.get_usize("seed", ctx.seed as usize)? as u64;
    Ok(ctx)
}

fn model_arg(args: &Args) -> Result<String> {
    args.get("model")
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("--model is required (one of {:?})", zoo::all_models()))
}

fn method_arg(args: &Args) -> Result<Method> {
    let name = args.get_or("method", "fasp");
    Method::parse(&name).ok_or_else(|| anyhow::anyhow!("unknown --method '{name}'"))
}

pub fn info(_args: &Args) -> Result<()> {
    let m = manifest()?;
    let mut t = Table::new(
        "Model zoo",
        &["model", "paper analog", "d", "heads", "layers", "d_ff", "vocab", "params", "ckpt"],
    );
    for (name, spec) in &m.models {
        t.row(vec![
            name.clone(),
            zoo::paper_label(name).to_string(),
            spec.d_model.to_string(),
            spec.n_heads.to_string(),
            spec.n_layers.to_string(),
            spec.d_ff.to_string(),
            spec.vocab.to_string(),
            format!("{:.2}M", spec.n_params_elems() as f64 / 1e6),
            if zoo::checkpoint_path(name).exists() { "yes" } else { "no" }.into(),
        ]);
    }
    t.print();
    println!(
        "{} artifacts in {}",
        m.artifacts.len(),
        m.dir.display()
    );
    let backend = crate::runtime::default_backend();
    println!(
        "host backend: {} ({} thread{}; set FASP_THREADS to resize)",
        backend.name(),
        backend.threads(),
        if backend.threads() == 1 { "" } else { "s" }
    );
    Ok(())
}

pub fn train(args: &Args) -> Result<()> {
    let m = manifest()?;
    let model = model_arg(args)?;
    let spec = m.model(&model)?;
    let mut opts = crate::train::TrainOpts::for_model(&model);
    opts.steps = args.get_usize("steps", opts.steps)?;
    opts.lr = args.get_f64("lr", opts.lr as f64)? as f32;
    let corpus = Corpus::new(spec.vocab, 42 ^ spec.vocab as u64);
    let dataset = Dataset::new(corpus, spec.batch, spec.seq, opts.steps + 8);
    let (w, report) = crate::train::train(&m, &model, &dataset, &opts)?;
    let path = zoo::checkpoint_path(&model);
    w.save(&path)?;
    println!(
        "trained {model}: {} steps, final loss {:.4}, {} → {}",
        report.steps,
        report.losses.last().copied().unwrap_or(f32::NAN),
        fmt_duration(Duration::from_secs_f64(report.wall_s)),
        path.display()
    );
    Ok(())
}

pub fn eval(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let model = model_arg(args)?;
    let p = ctx.prepared(&model)?;
    let ppl = p.dense_ppl(&ctx)?;
    println!("{model}: perplexity {ppl:.3} over {} batches", ctx.eval_batches);
    Ok(())
}

pub fn prune(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let model = model_arg(args)?;
    let method = method_arg(args)?;
    let sparsity = args.get_f64("sparsity", 0.2)?;
    let p = ctx.prepared(&model)?;

    let mut opts = PruneOpts::new(method, sparsity);
    opts.calib_batches = ctx.calib_batches;
    if args.has("no-restore") {
        opts.restore = false;
    }
    opts.prune_qk = args.has("prune-qk");
    opts.sequential = args.has("sequential");

    let dense = p.dense_ppl(&ctx)?;
    let (w, mask, report) = p.prune_with(&opts)?;
    let ppl = p.ppl_of(&ctx, &w)?;
    println!(
        "{model} {}: target {:.0}% achieved {:.1}% ({} params removed)",
        method.label(),
        sparsity * 100.0,
        report.achieved_sparsity * 100.0,
        report.params_removed
    );
    println!("perplexity: dense {dense:.3} → pruned {ppl:.3}");
    println!(
        "time: total {} | {}",
        fmt_duration(Duration::from_secs_f64(report.total_s)),
        report
            .phase_s
            .iter()
            .map(|(n, s)| format!("{n} {:.2}s", s))
            .collect::<Vec<_>>()
            .join(" | ")
    );
    if let Some(out) = args.get("out") {
        w.save(std::path::Path::new(out))?;
        println!("pruned weights → {out}");
    }
    if args.has("export-compact") || args.has("export-sharded") {
        let default_name = compact_name(&model, method, sparsity);
        let name = args.get_or("name", &default_name);
        anyhow::ensure!(
            !ctx.manifest.models.contains_key(&name)
                || ctx.manifest.compact.contains_key(&name),
            "--name '{name}' collides with an existing model; pick another"
        );
        let cm = crate::model::compact::compact_from_mask(&w, &mask, &name)?;
        let dir = crate::artifacts_dir().join("compact");
        // --export-sharded forces shards; --export-compact follows
        // FASP_EXPORT (default monolithic)
        let sharded = args.has("export-sharded")
            || crate::model::compact::ExportMode::from_env()
                == crate::model::compact::ExportMode::Sharded;
        let jp = if sharded {
            crate::model::compact::save_compact_sharded(&dir, &cm)?
        } else {
            crate::model::compact::save_compact(&dir, &cm)?
        };
        println!(
            "compact artifact ({}) → {} ({} → {} params)",
            if sharded { "sharded" } else { "monolithic" },
            jp.display(),
            w.spec.n_params_elems(),
            cm.spec.n_params_elems()
        );
    }
    if args.has("report") {
        let rec = crate::prune::report::RunRecord {
            model: model.clone(),
            report,
            dense_ppl: Some(dense),
            pruned_ppl: Some(ppl),
            zero_shot_mean: None,
        };
        println!("report → {}", rec.save()?.display());
    }
    Ok(())
}

fn compact_name(model: &str, method: Method, sparsity: f64) -> String {
    format!(
        "{model}_{}_s{:02.0}",
        format!("{method:?}").to_lowercase(),
        sparsity * 100.0
    )
}

/// Shared `fasp compact` / `fasp shard` preamble: resolve method,
/// sparsity and the collision-checked artifact name from the flags,
/// reject `--prune-qk` (unsupported by compact export), then prune +
/// repack. Returns `(name, method, sparsity, prepared, outcome)`.
fn prune_compact_from_args<'c>(
    args: &Args,
    ctx: &'c ExpCtx,
    model: &str,
) -> Result<(
    String,
    Method,
    f64,
    crate::experiments::common::Prepared<'c>,
    crate::prune::CompactOutcome,
)> {
    let method = method_arg(args)?;
    let sparsity = args.get_f64("sparsity", 0.3)?;
    let default_name = compact_name(model, method, sparsity);
    let name = args.get_or("name", &default_name);
    anyhow::ensure!(
        !ctx.manifest.models.contains_key(&name)
            || ctx.manifest.compact.contains_key(&name),
        "--name '{name}' collides with an existing model; pick another"
    );
    anyhow::ensure!(
        !args.has("prune-qk"),
        "compact export does not support --prune-qk (Q/K rows stay dense \
         under FASP §3.1); run `fasp prune --prune-qk` for the ablation"
    );
    let p = ctx.prepared(model)?;
    let mut opts = PruneOpts::new(method, sparsity);
    opts.calib_batches = ctx.calib_batches;
    if args.has("no-restore") {
        opts.restore = false;
    }
    opts.sequential = args.has("sequential");
    let out = crate::prune::prune_compact(&p.session, &p.weights, &p.dataset, &opts, &name)?;
    Ok((name, method, sparsity, p, out))
}

/// `fasp compact`: prune + physically repack + save the compact artifact,
/// then evaluate it end to end (perplexity parity with the masked model,
/// dense-vs-compact latency).
pub fn compact(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let model = model_arg(args)?;
    let reps = args.get_usize("reps", 10)?;
    let (name, method, sparsity, p, out) = prune_compact_from_args(args, &ctx, &model)?;
    // honors FASP_EXPORT (monolithic default / sharded)
    let jpath = crate::model::compact::save_compact_auto(
        &crate::artifacts_dir().join("compact"),
        &out.compact,
    )?;
    println!(
        "compact artifact → {} ({} → {} params, repack {:.3}s)",
        jpath.display(),
        p.weights.spec.n_params_elems(),
        out.compact.spec.n_params_elems(),
        out.report.phase("repack")
    );

    // fresh manifest load picks up the exported artifact
    let m2 = manifest()?;
    let cw = m2.compact_weights(&name)?;
    let ce = Session::new(&m2, &name)?;
    let eval_b = p.dataset.valid_batches(ctx.eval_batches);
    let ppl_dense = p.dense_ppl(&ctx)?;
    let ppl_masked = p.ppl_of(&ctx, &out.pruned)?;
    let ppl_compact = perplexity(&ce, &cw, &eval_b)?;
    let cmp = crate::eval::speed::compare_dense_compact(
        &m2, &model, &p.weights, &name, &cw, reps,
    )?;

    let mut t = Table::new(
        &format!("Compact export — {model} @ {:.0}% ({})", sparsity * 100.0, method.label()),
        &["variant", "ppl", "latency"],
    );
    t.row(vec![
        "dense".into(),
        format!("{ppl_dense:.3}"),
        format!("{:.3}ms", cmp.dense_ms),
    ]);
    t.row(vec!["masked".into(), format!("{ppl_masked:.3}"), "—".into()]);
    t.row(vec![
        "compact".into(),
        format!("{ppl_compact:.3}"),
        format!("{:.3}ms ({:.2}x)", cmp.compact_ms, cmp.speedup),
    ]);
    t.print();
    Ok(())
}

/// `fasp shard`: prune + physically repack + save a **sharded** compact
/// artifact (one `.ftns` per layer + embed/head shard, checksummed
/// index), then verify the streaming store end to end: perplexity over
/// the layer-streaming loader must be bit-identical to the monolithic
/// (assembled) compact path, with peak resident weights of O(one layer
/// + prefetch) instead of O(model).
pub fn shard(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let model = model_arg(args)?;
    let reps = args.get_usize("reps", 10)?;
    let (name, method, sparsity, p, out) = prune_compact_from_args(args, &ctx, &model)?;
    // FASP_QUANT=int8 exports quantized layer shards; the CLI boundary
    // is the only place the env is read — library callers pick the
    // dtype explicitly
    let quant = crate::tensor::pack::Quant::from_env();
    let jpath = crate::model::compact::save_compact_sharded_q(
        &crate::artifacts_dir().join("compact"),
        &out.compact,
        quant,
    )?;
    println!(
        "sharded compact artifact → {} ({} layers + embed shard, dtype {}, \
         {} → {} params, repack {:.3}s)",
        jpath.display(),
        out.compact.spec.n_layers,
        quant.label(),
        p.weights.spec.n_params_elems(),
        out.compact.spec.n_params_elems(),
        out.report.phase("repack")
    );

    // fresh manifest load picks up the sharded artifact
    let m2 = manifest()?;
    let store = m2.compact_store(&name)?;
    let ce = Session::new(&m2, &name)?;
    let cmp = crate::eval::speed::compare_stream_eval(&m2, &name, &store, reps)?;
    // bit-identity is the f32 contract; an int8 store serves quantized
    // panels, so its receipt is the bounded ppl delta reported below
    if quant == crate::tensor::pack::Quant::F32 {
        anyhow::ensure!(
            cmp.identical,
            "streamed fwd_loss diverged from the monolithic compact path"
        );
    }

    let eval_b = p.dataset.valid_batches(ctx.eval_batches);
    let cw = m2.compact_weights(&name)?;
    let ppl_mono = perplexity(&ce, &cw, &eval_b)?;
    store.reset_stats();
    let ppl_stream = crate::eval::perplexity_streamed(&ce, &store, &eval_b)?;
    if quant == crate::tensor::pack::Quant::F32 {
        anyhow::ensure!(
            ppl_mono.to_bits() == ppl_stream.to_bits(),
            "streamed ppl {ppl_stream} != monolithic ppl {ppl_mono}"
        );
    } else {
        println!(
            "int8 streamed ppl {ppl_stream:.4} vs assembled-f32 ppl \
             {ppl_mono:.4} (delta {:+.4})",
            ppl_stream - ppl_mono
        );
    }
    let snap = store.stats();

    let mb = |bytes: usize| format!("{:.2}MB", bytes as f64 / 1e6);
    let mut t = Table::new(
        &format!(
            "Sharded export — {model} @ {:.0}% ({})",
            sparsity * 100.0,
            method.label()
        ),
        &["path", "ppl", "fwd latency", "resident weights"],
    );
    t.row(vec![
        "monolithic".into(),
        format!("{ppl_mono:.3}"),
        format!("{:.3}ms", cmp.mono_ms),
        format!("{} (assemble {:.2}ms)", mb(cmp.model_bytes), cmp.assemble_ms),
    ]);
    t.row(vec![
        "streamed".into(),
        format!("{ppl_stream:.3}"),
        format!("{:.3}ms", cmp.stream_ms),
        format!(
            "peak {} ({:.0}% of model)",
            mb(snap.peak_resident_bytes),
            100.0 * snap.peak_resident_bytes as f64 / cmp.model_bytes.max(1) as f64
        ),
    ]);
    t.print();
    println!(
        "store dtype {}: stream payload {} of {} f32 ({:.0}%), max layer \
         shard {}",
        snap.quant.label(),
        mb(store.total_payload_bytes()),
        mb(store.total_param_bytes()),
        100.0 * store.total_payload_bytes() as f64
            / store.total_param_bytes().max(1) as f64,
        mb(store.max_layer_payload_bytes()),
    );
    println!(
        "{} shards, mean shard load {:.3}ms; outputs bit-identical: {}",
        cmp.shards, cmp.shard_load_ms, cmp.identical
    );
    Ok(())
}

/// `fasp generate`: batched KV-cached autoregressive generation from a
/// corpus prompt — greedy by default, seeded top-k with `--top-k`.
/// Works on zoo models (checkpoint-trained, or `--init` fresh weights)
/// and on registered compact models; `--stream` decodes a *sharded*
/// compact model straight from its shard store.
pub fn generate(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let model = model_arg(args)?;
    let batch = args.get_usize("batch", 1)?;
    let prompt_len = args.get_usize("prompt-len", 16)?;
    let max_new = args.get_usize("max-new", 32)?;
    let top_k = args.get_usize("top-k", 0)?;
    let temperature = args.get_f64("temperature", 1.0)? as f32;
    let m = &ctx.manifest;

    // weight source: --stream never assembles the monolithic weights —
    // the whole point of decoding from the shard store is O(one layer)
    // weight residency
    enum Src {
        Resident(crate::model::Weights),
        Streamed(crate::runtime::ShardedWeights),
    }
    let (session, src) = if args.has("stream") {
        (Session::new(m, &model)?, Src::Streamed(m.compact_store(&model)?))
    } else if m.compact.contains_key(&model) {
        (Session::new(m, &model)?, Src::Resident(m.compact_weights(&model)?))
    } else if args.has("init") {
        // deterministic fresh weights: the decode-path smoke needs no
        // checkpoint or training run
        let session = Session::new(m, &model)?;
        let w = crate::model::Weights::init(&session.spec, ctx.seed);
        (session, Src::Resident(w))
    } else {
        let p = ctx.prepared(&model)?;
        (p.session, Src::Resident(p.weights))
    };
    let spec = session.spec.clone();
    anyhow::ensure!(
        spec.family != "opt" || prompt_len + max_new <= spec.seq + 1,
        "OPT position embeddings cover {} positions; shrink --prompt-len/--max-new",
        spec.seq
    );

    let corpus = Corpus::new(spec.vocab, ctx.seed ^ spec.vocab as u64);
    let prompt = Dataset::new(corpus, batch, prompt_len, 2).valid_batches(1)[0]
        .tokens
        .clone();
    let sampler = if top_k == 0 {
        crate::model::Sampler::Greedy
    } else {
        crate::model::Sampler::TopK { k: top_k, temperature }
    };
    let opts = crate::model::GenerateOpts { max_new, sampler, seed: ctx.seed };

    // FASP_QUANT=int8 decodes over quantized panels (a streamed store
    // carries its own dtype from export time)
    let quant = crate::tensor::pack::Quant::from_env();
    let gen = match &src {
        // pack once (the persistent operator plan); the decode loop then
        // runs with zero per-token transpose/pack work
        Src::Resident(w) => {
            session.generate(&session.pack_as(&w.packed, quant)?, &prompt, &opts)?
        }
        Src::Streamed(store) => session.generate_streamed(store, &prompt, &opts)?,
    };

    let row0 = gen.tokens.data[..gen.prompt_len + gen.generated].to_vec();
    let fmt_ids = |ids: &[i32]| {
        ids.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ")
    };
    println!("prompt    [{}]", fmt_ids(&row0[..gen.prompt_len]));
    println!("generated [{}]", fmt_ids(&row0[gen.prompt_len..]));

    let mut t = Table::new(
        &format!(
            "Decode — {model} ({}), batch {batch}, {} sampling",
            session.backend().name(),
            if top_k == 0 { "greedy".to_string() } else { format!("top-{top_k}") }
        ),
        &["phase", "wall", "per token", "throughput"],
    );
    t.row(vec![
        format!("prefill x{prompt_len}"),
        format!("{:.3}ms", gen.prefill_s * 1e3),
        format!("{:.3}ms", gen.prefill_s * 1e3 / prompt_len.max(1) as f64),
        format!(
            "{:.0} tok/s",
            batch as f64 * prompt_len as f64 / gen.prefill_s.max(1e-12)
        ),
    ]);
    t.row(vec![
        format!("decode x{}", gen.steps),
        format!("{:.3}ms", gen.decode_s * 1e3),
        format!("{:.3}ms", gen.per_token_s() * 1e3),
        format!(
            "{:.0} tok/s",
            batch as f64 * gen.steps as f64 / gen.decode_s.max(1e-12)
        ),
    ]);
    t.print();
    println!(
        "kv cache: {:.2}KB resident ({} positions x {} layers{})",
        gen.kv_bytes as f64 / 1e3,
        prompt_len + max_new - 1,
        spec.n_layers,
        if spec.is_uniform() { "" } else { ", OV-sliced" }
    );

    // ---- speculative decoding against a FASP-pruned draft --------------
    // `--draft NAME` runs the same generation again speculatively: the
    // draft proposes --draft-k tokens per round, the target verifies
    // them in one chunked forward. If NAME is not a registered compact
    // model, a compact draft is synthesized on the fly from the target
    // weights at --draft-sparsity (the no-checkpoint smoke path, like
    // --init itself). `--check` asserts greedy bit-identity with the
    // target-only generation above.
    if let Some(draft_name) = args.get("draft") {
        let draft_k = args.get_usize("draft-k", 4)?;
        anyhow::ensure!(
            batch == 1,
            "--draft decodes a single sequence; drop --batch {batch}"
        );
        anyhow::ensure!(
            !args.has("stream"),
            "--draft needs resident target weights; drop --stream"
        );
        let Src::Resident(w) = &src else {
            anyhow::bail!("--draft needs resident target weights")
        };

        // a second manifest load: the draft may need registering, and
        // `session` immutably borrows the primary manifest
        let mut m2 = manifest()?;
        let mut tmp_dir = None;
        if !m2.compact.contains_key(draft_name) {
            let s = args.get_f64("draft-sparsity", 0.5)?;
            anyhow::ensure!(
                (0.0..1.0).contains(&s),
                "--draft-sparsity wants a fraction in [0, 1), got {s}"
            );
            let dh = spec.head_dim();
            let f_cut = (spec.d_ff as f64 * s) as usize;
            let v_cut = (dh as f64 * s) as usize;
            let mut mask = crate::model::PruneMask::full(&spec);
            for l in 0..spec.n_layers {
                // collision-free tail slices: exactly f_cut FFN units and
                // v_cut value dims per head pruned in every layer
                for j in 0..f_cut {
                    mask.layers[l].ffn[spec.d_ff - 1 - j] = false;
                }
                for hi in 0..spec.n_heads {
                    for j in 0..v_cut {
                        mask.layers[l].ov[hi * dh + dh - 1 - j] = false;
                    }
                }
            }
            let cm = crate::model::compact::compact_from_mask(w, &mask, draft_name)?;
            let dir = std::env::temp_dir().join(format!("fasp_draft_{draft_name}"));
            let _ = std::fs::remove_dir_all(&dir);
            let jp = crate::model::compact::save_compact(&dir, &cm)?;
            m2.register_compact(&jp)?;
            tmp_dir = Some(dir);
            println!(
                "\ndraft '{draft_name}': synthesized compact export at \
                 {:.0}% sparsity ({} FFN + {}/head OV units sliced per layer)",
                s * 100.0,
                f_cut,
                v_cut
            );
        }
        let draft_sess = Session::new(&m2, draft_name)?;
        let draft_w = m2.compact_weights(draft_name)?;

        let sopts = crate::model::SpecOpts { max_new, draft_k, sampler, seed: ctx.seed };
        // same dtype for target + draft: the --check bit-identity below
        // compares two runs of the same quantized plan, so it holds for
        // int8 exactly as for f32
        let tparams = session.pack_as(&w.packed, quant)?;
        let dparams = draft_sess.pack_as(&draft_w.packed, quant)?;
        let g = session.generate_speculative(&tparams, &dparams, &prompt, &sopts)?;

        let srow = g.tokens.data[g.prompt_len..].to_vec();
        println!("speculative [{}]", fmt_ids(&srow));
        println!(
            "speculative: draft-k {draft_k}, acceptance {:.2} ({} of {} \
             proposals), {} target chunks + {} draft steps for {} tokens; \
             kv target {:.2}KB + draft {:.2}KB",
            g.acceptance_rate(),
            g.accepted,
            g.proposed,
            g.chunks,
            g.draft_steps,
            g.generated,
            g.target_kv_bytes as f64 / 1e3,
            g.draft_kv_bytes as f64 / 1e3
        );
        if args.has("check") {
            anyhow::ensure!(
                top_k == 0,
                "--check asserts greedy bit-identity; drop --top-k"
            );
            anyhow::ensure!(
                g.tokens.data == gen.tokens.data,
                "speculative greedy tokens diverged from target-only generate \
                 — the losslessness contract is broken"
            );
            println!(
                "check: speculative ≡ target-only generate, bit-identical \
                 ({} tokens)",
                g.tokens.data.len()
            );
        }
        if let Some(dir) = tmp_dir {
            std::fs::remove_dir_all(&dir).ok();
        }
    }
    Ok(())
}

/// Self-driving load harness for the continuous-batching serve engine:
/// builds N sessions from corpus prompts (the second half repeating the
/// first half's prompts so the prefix cache has heads to share), drives
/// them to completion over one shared packed plan, and reports the
/// throughput / latency / residency receipts. `--check` replays every
/// session through the sequential [`Session::generate`] path and
/// asserts bit-identical tokens — the scheduler's core contract.
pub fn serve(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let model = model_arg(args)?;
    let sessions = args.get_usize("sessions", 8)?;
    let prompt_len = args.get_usize("prompt-len", 16)?;
    let max_new = args.get_usize("max-new", 8)?;
    let top_k = args.get_usize("top-k", 0)?;
    let temperature = args.get_f64("temperature", 1.0)? as f32;
    let page = args.get_usize("page", 16)?;
    let max_batch = args.get_usize("max-batch", 8)?;
    let m = &ctx.manifest;
    anyhow::ensure!(sessions >= 1, "serve wants --sessions >= 1");

    let (session, w) = if m.compact.contains_key(&model) {
        (Session::new(m, &model)?, m.compact_weights(&model)?)
    } else if args.has("init") {
        // deterministic fresh weights: the serve smoke needs no
        // checkpoint or training run
        let session = Session::new(m, &model)?;
        let w = crate::model::Weights::init(&session.spec, ctx.seed);
        (session, w)
    } else {
        let p = ctx.prepared(&model)?;
        (p.session, p.weights)
    };
    let spec = session.spec.clone();
    anyhow::ensure!(
        spec.family != "opt" || prompt_len + max_new <= spec.seq + 1,
        "OPT position embeddings cover {} positions; shrink --prompt-len/--max-new",
        spec.seq
    );

    // self-generated load: ceil(sessions/2) distinct corpus prompts,
    // repeated across the second half, one sampling seed per session
    let corpus = Corpus::new(spec.vocab, ctx.seed ^ spec.vocab as u64);
    let uniq = sessions / 2 + sessions % 2;
    let toks = Dataset::new(corpus, uniq, prompt_len, 2).valid_batches(1)[0]
        .tokens
        .clone();
    let sampler = if top_k == 0 {
        crate::model::Sampler::Greedy
    } else {
        crate::model::Sampler::TopK { k: top_k, temperature }
    };
    let mut requests = Vec::with_capacity(sessions);
    for i in 0..sessions {
        let row = i % uniq;
        requests.push(crate::serve::ServeRequest {
            prompt: toks.data[row * prompt_len..(row + 1) * prompt_len].to_vec(),
            max_new,
            sampler,
            seed: ctx.seed ^ i as u64,
            ..Default::default()
        });
    }

    // arena sizing: worst-case pages for a full batch + the prefix
    // cache's pinned heads, with ~25% slack (override via --pages)
    let pages_per = (prompt_len + max_new - 1 + page - 1) / page;
    let auto = max_batch.min(sessions) * pages_per + uniq * (prompt_len / page) + pages_per;
    let n_pages = args.get_usize("pages", auto * 5 / 4 + 1)?;
    let cfg = crate::serve::ServeConfig {
        page,
        n_pages,
        max_batch,
        prefix_cache: !args.has("no-prefix-cache"),
        prefill_chunk: args.get_usize("prefill-chunk", 4)?,
        ..Default::default()
    };

    // pack once — every session decodes over this one shared plan;
    // FASP_QUANT=int8 serves quantized panels, and the --check replay
    // below compares against a sequential generate over the *same*
    // plan, so bit-identity holds at either dtype
    let packed = session.pack_as(&w.packed, crate::tensor::pack::Quant::from_env())?;
    let report = session.serve(&packed, &requests, &cfg)?;

    if args.has("check") {
        for (r, o) in requests.iter().zip(&report.outputs) {
            let prompt =
                crate::tensor::IntTensor::new(vec![1, r.prompt.len()], r.prompt.clone());
            let opts = crate::model::GenerateOpts {
                max_new: r.max_new,
                sampler: r.sampler,
                seed: r.seed,
            };
            let g = session.generate(&packed, &prompt, &opts)?;
            anyhow::ensure!(
                o.error.is_none(),
                "serve session {} failed with no faults armed: {:?}",
                o.id,
                o.error
            );
            anyhow::ensure!(
                g.tokens.data == o.tokens,
                "serve output for session {} diverged from sequential generate",
                o.id
            );
        }
        println!("check: {sessions} sessions bit-identical to sequential generate");
    }

    let mut t = Table::new(
        &format!(
            "Serve — {model} ({}), {sessions} sessions, {} sampling",
            session.backend().name(),
            if top_k == 0 { "greedy".to_string() } else { format!("top-{top_k}") }
        ),
        &["metric", "value"],
    );
    t.row(vec!["scheduler ticks".into(), report.ticks.to_string()]);
    t.row(vec![
        "generated tokens".into(),
        format!("{} ({} per session)", report.generated_tokens, max_new),
    ]);
    t.row(vec![
        "throughput".into(),
        format!("{:.0} tok/s", report.tokens_per_s),
    ]);
    t.row(vec![
        "per-token latency".into(),
        format!(
            "p50 {:.3}ms / p99 {:.3}ms",
            report.p50_token_s * 1e3,
            report.p99_token_s * 1e3
        ),
    ]);
    t.row(vec!["max batch seen".into(), report.max_batch_seen.to_string()]);
    t.row(vec![
        "prefix cache".into(),
        format!(
            "{} hits / {} misses / {} pinned heads / {} evictions",
            report.prefix_hits, report.prefix_misses, report.prefix_insertions,
            report.prefix_evictions
        ),
    ]);
    t.row(vec![
        "kv arena".into(),
        format!(
            "{n_pages} pages x {page} pos ({:.2}KB/page), peak {} resident",
            report.page_bytes as f64 / 1e3,
            report.peak_pages
        ),
    ]);
    t.print();
    Ok(())
}

/// `fasp chaos` — the graceful-degradation receipt. Drives the serve
/// engine through a fault-free baseline plus two identically-seeded
/// fault-plan runs (chaos + replay), probes the sharded weight store
/// under injected corruption, prints the absorbed/fatal/shed/retry
/// counters and writes `BENCH_chaos.json`. With `--check` it fails
/// unless every surviving session is bit-identical to the fault-free
/// run, the replay reproduces the identical fault trace and outputs,
/// zero arena pages leak, a one-shot shard corruption is absorbed by
/// the bounded re-read and a persistent truncation surfaces as `Err`.
///
/// The plan comes from `--plan`, else the `FASP_FAULTS` env var, else
/// it is synthesized from `--seed` against the clean run's event
/// census (pool fan-outs are width-dependent, so synthesis — not a
/// fixed plan — is what keeps the smoke meaningful at FASP_THREADS=1).
pub fn chaos(args: &Args) -> Result<()> {
    use crate::util::json::Json;
    let ctx = ctx_from(args)?;
    let model = model_arg(args)?;
    let sessions = args.get_usize("sessions", 6)?;
    let prompt_len = args.get_usize("prompt-len", 8)?;
    let max_new = args.get_usize("max-new", 6)?;
    let page = args.get_usize("page", 4)?;
    let max_batch = args.get_usize("max-batch", 4)?;
    let n_pool = args.get_usize("faults", 2)?;
    let m = &ctx.manifest;
    anyhow::ensure!(sessions >= 2, "chaos wants --sessions >= 2 (survivors + victims)");

    let (session, w) = if m.compact.contains_key(&model) {
        (Session::new(m, &model)?, m.compact_weights(&model)?)
    } else if args.has("init") {
        let session = Session::new(m, &model)?;
        let w = crate::model::Weights::init(&session.spec, ctx.seed);
        (session, w)
    } else {
        let p = ctx.prepared(&model)?;
        (p.session, p.weights)
    };
    let spec = session.spec.clone();
    anyhow::ensure!(
        spec.family != "opt" || prompt_len + max_new <= spec.seq + 1,
        "OPT position embeddings cover {} positions; shrink --prompt-len/--max-new",
        spec.seq
    );

    // explicit plan > FASP_FAULTS env > seeded synthesis in compare_chaos
    let plan_override = match args.get("plan") {
        Some(s) => Some(crate::fault::FaultPlan::parse(s)?),
        None => crate::fault::FaultPlan::from_env()?,
    };

    // arena sizing as in `serve`; a bounded admission queue that sheds
    // exactly one session is part of the receipt (deterministic in the
    // clean and chaos runs alike, so survivors still compare equal)
    let uniq = sessions / 2 + sessions % 2;
    let pages_per = (prompt_len + max_new - 1 + page - 1) / page;
    let auto = max_batch.min(sessions) * pages_per + uniq * (prompt_len / page) + pages_per;
    let cfg = crate::serve::ServeConfig {
        page,
        n_pages: args.get_usize("pages", auto * 5 / 4 + 1)?,
        max_batch,
        prefix_cache: !args.has("no-prefix-cache"),
        prefill_chunk: args.get_usize("prefill-chunk", 4)?,
        queue_cap: args.get_usize("queue-cap", sessions - 1)?,
        tick_retries: args.get_usize("tick-retries", 2)?,
    };

    let cmp = crate::eval::speed::compare_chaos(
        m,
        &model,
        &w,
        sessions,
        prompt_len,
        max_new,
        &cfg,
        plan_override.as_ref(),
        n_pool,
        ctx.seed,
    )?;

    // shard-store half of the receipt, in a throwaway staging dir
    let stage = std::env::temp_dir().join(format!("fasp_chaos_{}", ctx.seed));
    let probe = crate::eval::speed::chaos_shard_probe(&w, &stage);
    std::fs::remove_dir_all(&stage).ok();
    let probe = probe?;

    let injected = cmp.injected_pool + cmp.injected_arena + 1; // +1: shard corrupt probe
    let mut t = Table::new(
        &format!(
            "Chaos — {model} ({}), {sessions} sessions under plan \"{}\"",
            session.backend().name(),
            cmp.plan
        ),
        &["metric", "value"],
    );
    t.row(vec![
        "event census (clean)".into(),
        format!(
            "{} pool fan-outs / {} arena grows / {} shard reads",
            cmp.pool_events, cmp.arena_events, probe.shard_events
        ),
    ]);
    t.row(vec![
        "faults injected".into(),
        format!("{injected} ({} pool, {} arena, 1 shard)", cmp.injected_pool, cmp.injected_arena),
    ]);
    t.row(vec![
        "sessions".into(),
        format!(
            "{} survived / {} failed ({} shed, {} deadline)",
            cmp.survivors, cmp.failed_sessions, cmp.shed_sessions, cmp.deadline_failures
        ),
    ]);
    t.row(vec![
        "tick retries".into(),
        format!("{} (shard re-reads: {})", cmp.tick_retries, probe.retries_absorbed),
    ]);
    t.row(vec![
        "throughput".into(),
        format!(
            "{:.0} tok/s under faults vs {:.0} clean ({:.2}x)",
            cmp.chaos_tokens_per_s, cmp.clean_tokens_per_s, cmp.throughput_ratio
        ),
    ]);
    t.row(vec![
        "survivors bit-identical".into(),
        cmp.survivors_identical.to_string(),
    ]);
    t.row(vec!["replay bit-identical".into(), cmp.replay_identical.to_string()]);
    t.row(vec!["leaked arena pages".into(), cmp.leaked_pages.to_string()]);
    t.row(vec![
        "shard probe".into(),
        format!(
            "one-shot corrupt absorbed: {} / persistent truncate is Err: {}",
            probe.absorbed_ok, probe.fatal_is_err
        ),
    ]);
    if !cmp.trace.is_empty() {
        t.row(vec!["fault trace".into(), cmp.trace.join(", ")]);
    }
    t.print();

    let record = Json::obj(vec![
        ("bench", Json::Str("chaos".into())),
        ("model", Json::Str(model.clone())),
        ("seed", Json::Num(ctx.seed as f64)),
        ("plan", Json::Str(cmp.plan.clone())),
        ("sessions", Json::Num(sessions as f64)),
        ("pool_events", Json::Num(cmp.pool_events as f64)),
        ("arena_events", Json::Num(cmp.arena_events as f64)),
        ("shard_events", Json::Num(probe.shard_events as f64)),
        ("injected_pool", Json::Num(cmp.injected_pool as f64)),
        ("injected_arena", Json::Num(cmp.injected_arena as f64)),
        ("survivors", Json::Num(cmp.survivors as f64)),
        ("failed_sessions", Json::Num(cmp.failed_sessions as f64)),
        ("shed_sessions", Json::Num(cmp.shed_sessions as f64)),
        ("deadline_failures", Json::Num(cmp.deadline_failures as f64)),
        ("tick_retries", Json::Num(cmp.tick_retries as f64)),
        ("shard_retries", Json::Num(probe.retries_absorbed as f64)),
        ("clean_tokens_per_s", Json::Num(cmp.clean_tokens_per_s)),
        ("chaos_tokens_per_s", Json::Num(cmp.chaos_tokens_per_s)),
        ("throughput_ratio", Json::Num(cmp.throughput_ratio)),
        ("survivors_identical", Json::Bool(cmp.survivors_identical)),
        ("replay_identical", Json::Bool(cmp.replay_identical)),
        ("leaked_pages", Json::Num(cmp.leaked_pages as f64)),
        ("shard_absorbed_ok", Json::Bool(probe.absorbed_ok)),
        ("shard_fatal_is_err", Json::Bool(probe.fatal_is_err)),
        (
            "trace",
            Json::Arr(cmp.trace.iter().map(|s| Json::Str(s.clone())).collect()),
        ),
    ]);
    let path = match args.get("json") {
        Some(p) => std::path::PathBuf::from(p),
        None => crate::repo_root().join("BENCH_chaos.json"),
    };
    std::fs::write(&path, record.pretty())
        .map_err(|e| anyhow::anyhow!("fasp chaos: write {}: {e}", path.display()))?;
    println!("record -> {}", path.display());

    if args.has("check") {
        anyhow::ensure!(
            cmp.survivors_identical,
            "chaos check failed: a surviving session diverged from its fault-free run"
        );
        anyhow::ensure!(
            cmp.replay_identical,
            "chaos check failed: replaying the identical plan did not reproduce the \
             identical fault trace and outputs"
        );
        anyhow::ensure!(
            cmp.leaked_pages == 0,
            "chaos check failed: {} arena page(s) leaked after drain",
            cmp.leaked_pages
        );
        anyhow::ensure!(
            probe.absorbed_ok,
            "chaos check failed: one-shot shard corruption was not absorbed by the \
             bounded re-read"
        );
        anyhow::ensure!(
            probe.fatal_is_err,
            "chaos check failed: persistent shard truncation did not surface as Err"
        );
        println!(
            "check: {} survivor(s) bit-identical, replay bit-identical, 0 leaked \
             pages, shard faults degrade gracefully",
            cmp.survivors
        );
    }
    Ok(())
}

pub fn zeroshot(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let model = model_arg(args)?;
    let p = ctx.prepared(&model)?;
    let sparsity = args.get_f64("sparsity", 0.0)?;
    let w = if sparsity > 0.0 {
        let method = method_arg(args)?;
        p.prune_only(&ctx, method, sparsity)?.0
    } else {
        p.weights.clone()
    };
    let mut t = Table::new(
        &format!("Zero-shot accuracy — {model} at {:.0}% sparsity", sparsity * 100.0),
        &["suite", "accuracy %", "n"],
    );
    let mut total = 0.0;
    let kinds = TaskKind::all();
    for kind in kinds {
        let suite = TaskSuite::generate(&p.dataset.corpus, kind, ctx.tasks_per_suite, ctx.seed);
        let r = eval_suite(&p.session, &w, &suite)?;
        total += r.accuracy;
        t.row(vec![r.kind.to_string(), format!("{:.2}", r.accuracy), r.n.to_string()]);
    }
    t.row(vec![
        "Mean".into(),
        format!("{:.2}", total / kinds.len() as f64),
        "".into(),
    ]);
    t.print();
    Ok(())
}

pub fn tables(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let id = args.get_or("id", "all");
    crate::experiments::run_by_id(&ctx, &id)
}

pub fn latency(args: &Args) -> Result<()> {
    let m = manifest()?;
    let reps = args.get_usize("reps", 20)?;
    let points = crate::eval::speed::layer_latency_sweep(&m, reps)?;
    let mut t = Table::new(
        "Sliced decoder-layer latency (structured speedup)",
        &["sparsity", "d_ff kept", "ov kept", "latency", "speedup"],
    );
    for p in points {
        t.row(vec![
            format!("{:.0}%", p.sparsity * 100.0),
            p.f_s.to_string(),
            p.dk_s.to_string(),
            format!("{:.3}ms", p.mean_ms),
            format!("{:.2}x", p.speedup),
        ]);
    }
    t.print();
    Ok(())
}

/// `fasp lint` — run the determinism & robustness static-analysis
/// pass over `rust/src`, print the rule table, write
/// `LINT_REPORT.json`, and fail on any non-allowlisted violation or
/// stale allowlist entry (see [`crate::analysis`]).
pub fn lint(args: &Args) -> Result<()> {
    let root = crate::repo_root();
    let run = crate::analysis::lint_repo(&root)?;
    print!("{}", run.render_table());
    let json_path = match args.get("json") {
        Some(p) => std::path::PathBuf::from(p),
        None => root.join("LINT_REPORT.json"),
    };
    std::fs::write(&json_path, run.report_json().pretty())
        .map_err(|e| anyhow::anyhow!("fasp lint: write {}: {e}", json_path.display()))?;
    println!("report -> {}", json_path.display());
    if !run.is_clean() {
        anyhow::bail!(
            "fasp lint failed: {} violation(s), {} stale allowlist entr(y/ies) — \
             fix the code or add a justified entry to rust/lint_allow.toml",
            run.violations.len(),
            run.stale.len()
        );
    }
    Ok(())
}

pub fn eval_ppl_of(
    manifest: &Manifest,
    model: &str,
    weights: &crate::model::Weights,
    batches: usize,
) -> Result<f64> {
    let session = Session::new(manifest, model)?;
    let spec = session.spec.clone();
    let corpus = Corpus::new(spec.vocab, 42 ^ spec.vocab as u64);
    let dataset = Dataset::new(corpus, spec.batch, spec.seq, 8);
    perplexity(&session, weights, &dataset.valid_batches(batches))
}
