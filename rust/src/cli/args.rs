//! Flag parser: `command --key value --bool-flag`.

use crate::Result;
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub bools: Vec<String>,
}

impl Args {
    pub fn parse<I: Iterator<Item = String>>(mut it: I) -> Result<Args> {
        let mut args = Args::default();
        let mut pending: Option<String> = None;
        if let Some(first) = it.next() {
            if first.starts_with("--") {
                pending = Some(first.trim_start_matches('-').to_string());
            } else {
                args.command = Some(first);
            }
        }
        for tok in it {
            if let Some(key) = pending.take() {
                if tok.starts_with("--") {
                    args.bools.push(key);
                    pending = Some(tok.trim_start_matches('-').to_string());
                } else {
                    args.flags.insert(key, tok);
                }
            } else if tok.starts_with("--") {
                pending = Some(tok.trim_start_matches('-').to_string());
            } else {
                anyhow::bail!("unexpected positional argument '{tok}'");
            }
        }
        if let Some(key) = pending {
            args.bools.push(key);
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} wants a number, got '{v}'")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} wants an integer, got '{v}'")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn parses_command_flags_bools() {
        let a = parse("prune --model llama_small --sparsity 0.2 --fast");
        assert_eq!(a.command.as_deref(), Some("prune"));
        assert_eq!(a.get("model"), Some("llama_small"));
        assert_eq!(a.get_f64("sparsity", 0.0).unwrap(), 0.2);
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
    }

    #[test]
    fn bool_before_kv() {
        let a = parse("eval --fast --model x");
        assert!(a.has("fast"));
        assert_eq!(a.get("model"), Some("x"));
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(
            "eval stray".split_whitespace().map(str::to_string)
        )
        .is_err());
    }
}
