//! The FASP pruning structure (paper §3.1): coupled column/row groups and
//! the sparsity rebalancing that compensates for skipping Q/K.
//!
//! Coupled groups per decoder layer:
//!
//! | group | later layer (columns) | earlier layer (rows, removed free) |
//! |-------|------------------------|------------------------------------|
//! | FFN   | `fc2` / `w_down`       | `fc1`(+bias) / `w_gate`+`w_up`     |
//! | OV    | `wo`                   | `wv`(+bias)                        |
//! | QK    | — (rows of both `wq` and `wk`, through QKᵀ; skipped by      |
//! |       |   default per Table 6, RoPE-pair-aware for LLaMA)           |

use crate::model::mask::prunable_params;
use crate::runtime::manifest::ModelSpec;

/// How many structures to remove per layer for each group.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GroupPlan {
    /// fraction of FFN hidden units to prune
    pub ffn_ratio: f64,
    /// fraction of OV context dims to prune
    pub ov_ratio: f64,
    /// fraction of Q/K rows to prune (0 unless the Table 6 ablation)
    pub qk_ratio: f64,
}

/// Parameters removed when one unit of each group is pruned (counting the
/// coupled row(s) and bias element(s) — the "free" removals of §3.1).
pub fn unit_costs(spec: &ModelSpec) -> (usize, usize, usize) {
    let d = spec.d_model;
    if spec.family == "opt" {
        // FFN: fc2 col (d) + fc1 row (d) + fc1 bias (1)
        // OV:  wo col (d) + wv row (d) + wv bias (1)
        // QK:  wq row (d) + bias + wk row (d) + bias
        (2 * d + 1, 2 * d + 1, 2 * d + 2)
    } else {
        (3 * d, 2 * d, 2 * d)
    }
}

/// Compute per-group ratios achieving global `sparsity` over the
/// prunable pool (paper: "we increase the sparsity level of the other
/// layers uniformly to satisfy the overall sparsity requirements").
/// Per-layer dims (compact models) are summed, so the same uniform ratio
/// stays exact for heterogeneous layers.
pub fn plan(spec: &ModelSpec, sparsity: f64, prune_qk: bool) -> GroupPlan {
    let (ffn_c, ov_c, qk_c) = unit_costs(spec);
    let d = spec.d_model as f64;
    let pool = prunable_params(spec) as f64;
    let mut removable = 0.0f64;
    for l in 0..spec.n_layers {
        removable += spec.d_ff_l(l) as f64 * ffn_c as f64
            + spec.d_ov_l(l) as f64 * ov_c as f64
            + if prune_qk { d * qk_c as f64 } else { 0.0 };
    }
    let r = (sparsity * pool / removable).clamp(0.0, 1.0);
    GroupPlan {
        ffn_ratio: r,
        ov_ratio: r,
        qk_ratio: if prune_qk { r } else { 0.0 },
    }
}

/// Units to prune given a ratio (floor — never exceed the target).
pub fn units(n: usize, ratio: f64) -> usize {
    ((n as f64 * ratio).floor() as usize).min(n)
}

/// RoPE pairs (LLaMA): Q/K rows must be pruned in (j, j+half) pairs
/// within each head so the rotation stays closed (DESIGN.md §5). Returns
/// the index pairs for one model dim `d` with `h` heads.
pub fn rope_pairs(d: usize, h: usize) -> Vec<(usize, usize)> {
    let dh = d / h;
    let half = dh / 2;
    let mut pairs = Vec::with_capacity(d / 2);
    for head in 0..h {
        let base = head * dh;
        for k in 0..half {
            pairs.push((base + k, base + half + k));
        }
    }
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ModelSpec;

    fn spec(family: &str) -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            family: family.into(),
            d_model: 128,
            n_heads: 4,
            n_layers: 4,
            d_ff: 512,
            vocab: 512,
            seq: 64,
            batch: 8,
            params: vec![],
            layer_dims: vec![],
        }
    }

    #[test]
    fn plan_hits_target_sparsity() {
        for fam in ["opt", "llama"] {
            let s = spec(fam);
            for &target in &[0.1, 0.2, 0.3, 0.5] {
                let p = plan(&s, target, false);
                let (ffn_c, ov_c, _) = unit_costs(&s);
                let removed = p.ffn_ratio * s.d_ff as f64 * ffn_c as f64
                    + p.ov_ratio * s.d_model as f64 * ov_c as f64;
                let achieved =
                    removed * s.n_layers as f64 / prunable_params(&s) as f64;
                assert!(
                    (achieved - target).abs() < 1e-9,
                    "{fam} target {target} achieved {achieved}"
                );
            }
        }
    }

    #[test]
    fn qk_pruning_lowers_other_ratios() {
        let s = spec("llama");
        let with = plan(&s, 0.3, true);
        let without = plan(&s, 0.3, false);
        assert!(with.ffn_ratio < without.ffn_ratio);
        assert!(with.qk_ratio > 0.0);
        assert_eq!(without.qk_ratio, 0.0);
    }

    #[test]
    fn rope_pairs_cover_all_dims_once() {
        let pairs = rope_pairs(32, 4);
        assert_eq!(pairs.len(), 16);
        let mut seen = vec![false; 32];
        for (a, b) in pairs {
            assert!(!seen[a] && !seen[b]);
            seen[a] = true;
            seen[b] = true;
            // both in the same head, half apart
            assert_eq!(a / 8, b / 8);
            assert_eq!(b - a, 4);
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn units_floor() {
        assert_eq!(units(512, 0.1), 51);
        assert_eq!(units(512, 0.0), 0);
        assert_eq!(units(512, 1.0), 512);
    }
}
