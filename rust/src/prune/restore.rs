//! Weight restoration (paper §3.3).
//!
//! After choosing kept columns `M` for a down/out projection `W` [m,n]
//! with input Gram `G = X Xᵀ` [n,n], the optimal update solves
//!
//! ```text
//! min_{W*_{:,M}} ½ ‖W*_{:,M} X_{M,:} − W X‖²_F
//! ⇒ W*_{:,M} = (W G)_{:,M} (G_{M,M} + δ̂ I)⁻¹        (Eq. 8)
//! ```
//!
//! where `δ̂ = delta · mean(diag G)` scales the ridge to the data. Each
//! output row is an independent RHS of the same SPD system, so one
//! Cholesky factorization + m triangular solves suffice — exactly the
//! efficiency argument the paper makes against ADMM.
//!
//! Masked-evaluation equivalence (DESIGN.md §5): returning the full [m,n]
//! matrix with pruned columns zeroed makes the dense masked forward
//! numerically identical to the sliced forward.

use crate::linalg::cholesky::cholesky;
use crate::model::mask::kept_indices;
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;
use anyhow::{Context, Result};

/// Closed-form restoration. `g` is the f32 Gram sums from capture.
/// Returns the restored weight (pruned columns exactly zero).
pub fn restore_columns(
    w: &Tensor,
    g: &Tensor,
    kept: &[bool],
    delta: f64,
) -> Result<Tensor> {
    let (m, n) = w.dims2();
    let (gn, gm) = g.dims2();
    anyhow::ensure!(gn == n && gm == n, "gram shape {:?} vs weight {:?}", g.shape, w.shape);
    anyhow::ensure!(kept.len() == n, "mask length");
    let kept_idx = kept_indices(kept);
    let kn = kept_idx.len();
    if kn == n {
        return Ok(w.clone()); // nothing pruned
    }
    if kn == 0 {
        return Ok(Tensor::zeros(&[m, n]));
    }

    // ridge scaled by the mean Gram diagonal
    let mean_diag: f64 =
        (0..n).map(|i| g.at2(i, i) as f64).sum::<f64>() / n as f64;
    let ridge = delta * mean_diag.max(1e-30);

    // G_MM in f64 + ridge
    let mut gkk = vec![0.0f64; kn * kn];
    for (a, &ia) in kept_idx.iter().enumerate() {
        for (b, &ib) in kept_idx.iter().enumerate() {
            gkk[a * kn + b] = g.at2(ia, ib) as f64;
        }
        gkk[a * kn + a] += ridge;
    }
    let factor = cholesky(&gkk, kn).context("restoration Gram not PD")?;

    // B = W · G (f32 blocked matmul), then gather kept columns per row.
    let b = matmul(w, g);
    let mut out = Tensor::zeros(&[m, n]);
    let mut rhs = vec![0.0f64; kn];
    for i in 0..m {
        let brow = b.row(i);
        for (a, &ja) in kept_idx.iter().enumerate() {
            rhs[a] = brow[ja] as f64;
        }
        factor.solve_in_place(&mut rhs);
        let orow = out.row_mut(i);
        for (a, &ja) in kept_idx.iter().enumerate() {
            orow[ja] = rhs[a] as f32;
        }
    }
    Ok(out)
}

/// FLAP bias compensation: `Δb = W_:,pruned · mean(X_pruned)` — the
/// expected output of the removed units is folded into the layer bias.
pub fn bias_compensation(
    w: &Tensor,
    mean_sum: &[f32],
    rows: usize,
    kept: &[bool],
) -> Vec<f32> {
    let (m, n) = w.dims2();
    assert_eq!(mean_sum.len(), n);
    let inv = 1.0 / rows.max(1) as f32;
    let mut delta = vec![0.0f32; m];
    for j in 0..n {
        if kept[j] {
            continue;
        }
        let mx = mean_sum[j] * inv;
        if mx == 0.0 {
            continue;
        }
        for (i, d) in delta.iter_mut().enumerate() {
            *d += w.at2(i, j) * mx;
        }
    }
    delta
}

/// Reconstruction error ‖W' G W'ᵀ − ...‖ proxy used in tests: the exact
/// least-squares objective ½‖(W' − W) X‖² expressed through the Gram:
/// `tr((W'−W) G (W'−W)ᵀ)`.
pub fn recon_objective(w_new: &Tensor, w_old: &Tensor, g: &Tensor) -> f64 {
    let (m, n) = w_old.dims2();
    let mut total = 0.0f64;
    // D = W' − W; total = Σ_i d_i G d_iᵀ
    let mut d = vec![0.0f32; n];
    let mut gd = vec![0.0f64; n];
    for i in 0..m {
        for j in 0..n {
            d[j] = w_new.at2(i, j) - w_old.at2(i, j);
        }
        for j in 0..n {
            let mut s = 0.0f64;
            for k in 0..n {
                s += g.at2(j, k) as f64 * d[k] as f64;
            }
            gd[j] = s;
        }
        for j in 0..n {
            total += d[j] as f64 * gd[j];
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn make_gram(x: &Tensor) -> Tensor {
        // G = Xᵀ X for X [s, n] — the transpose-free kernel
        crate::tensor::matmul::matmul_at(x, x)
    }

    #[test]
    fn restoration_beats_plain_zeroing() {
        let mut rng = Rng::new(0);
        let (m, n, s) = (8, 16, 64);
        let w = Tensor::randn(&[m, n], 1.0, &mut rng);
        let x = Tensor::randn(&[s, n], 1.0, &mut rng);
        let g = make_gram(&x);
        let kept: Vec<bool> = (0..n).map(|j| j % 4 != 0).collect();

        let restored = restore_columns(&w, &g, &kept, 1e-6).unwrap();
        let mut zeroed = w.clone();
        crate::tensor::ops::zero_cols(
            &mut zeroed,
            &crate::model::mask::pruned_indices(&kept),
        );
        let err_restored = recon_objective(&restored, &w, &g);
        let err_zeroed = recon_objective(&zeroed, &w, &g);
        assert!(
            err_restored < err_zeroed * 0.9,
            "restored {err_restored} vs zeroed {err_zeroed}"
        );
        // pruned columns exactly zero
        for i in 0..m {
            for j in 0..n {
                if !kept[j] {
                    assert_eq!(restored.at2(i, j), 0.0);
                }
            }
        }
    }

    /// KKT check: at the optimum, the residual (W* − W) G must vanish on
    /// the kept columns (up to the ridge term).
    #[test]
    fn normal_equation_stationarity() {
        let mut rng = Rng::new(1);
        let (m, n, s) = (4, 10, 80);
        let w = Tensor::randn(&[m, n], 1.0, &mut rng);
        let x = Tensor::randn(&[s, n], 1.0, &mut rng);
        let g = make_gram(&x);
        let kept: Vec<bool> = (0..n).map(|j| j != 2 && j != 7).collect();
        let restored = restore_columns(&w, &g, &kept, 1e-10).unwrap();
        // residual R = (W* − W) G ; R[:, kept] ≈ 0
        let mut diff = restored.clone();
        for (dv, wv) in diff.data.iter_mut().zip(&w.data) {
            *dv -= wv;
        }
        let r = matmul(&diff, &g);
        let scale = crate::tensor::ops::fro_norm(&r).max(1e-12);
        for i in 0..m {
            for j in 0..n {
                if kept[j] {
                    assert!(
                        r.at2(i, j).abs() / scale < 1e-3,
                        "KKT violated at ({i},{j}): {}",
                        r.at2(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn bias_compensation_formula() {
        let w = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        // mean over 2 rows: X means = [0.5, 1.0, 2.0]
        let mean_sum = vec![1.0, 2.0, 4.0];
        let kept = vec![true, false, true];
        let d = bias_compensation(&w, &mean_sum, 2, &kept);
        assert_eq!(d, vec![2.0 * 1.0, 5.0 * 1.0]);
    }
}
