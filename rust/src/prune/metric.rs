//! Pruning metrics (paper §3.2 plus baselines).
//!
//! The FASP metric scores column `j` of the later matrix `W` by
//! `‖W_:,j‖₁ · ‖X_j‖₂` — the column sum of Wanda's elementwise scores.
//! The preferred implementation routes through the AOT Pallas kernel
//! (`wanda_metric_{m}x{n}` artifact, L1 on the pruning path); the host
//! fallback computes the same number and cross-checks it in tests.

use crate::runtime::executable::{Artifact, In};
use crate::runtime::Manifest;
use crate::tensor::ops::{col_abs_sum, col_sq_sum};
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Host Wanda-column scores: `score[j] = ||W_:,j||_1 * xnorm[j]`.
pub fn wanda_scores_host(w: &Tensor, xnorm: &[f32]) -> Vec<f32> {
    col_abs_sum(w)
        .iter()
        .zip(xnorm)
        .map(|(c, x)| c * x)
        .collect()
}

/// Magnitude-only column scores: `||W_:,j||_1`.
pub fn magnitude_scores(w: &Tensor) -> Vec<f32> {
    col_abs_sum(w)
}

/// FLAP-style fluctuation scores: `Var(X_j) · ||W_:,j||²` where the
/// variance comes from the capture sums (`Var = Σx²/N − (Σx/N)²`).
pub fn flap_scores(w: &Tensor, g_diag: &[f32], mean_sum: &[f32], rows: usize) -> Vec<f32> {
    let n = rows as f32;
    col_sq_sum(w)
        .iter()
        .enumerate()
        .map(|(j, wsq)| {
            let ex2 = g_diag[j] / n;
            let ex = mean_sum[j] / n;
            let var = (ex2 - ex * ex).max(0.0);
            var * wsq
        })
        .collect()
}

/// Scores via the Pallas kernel artifact, falling back to the host
/// implementation when the shape has no artifact. Artifacts are compiled
/// once per shape and cached process-wide.
pub struct KernelMetric<'m> {
    manifest: &'m Manifest,
    // BTreeMap, not HashMap: the cache is keyed by artifact name and
    // only ever probed per key (iteration order can't leak into
    // results today), but the D1 lint holds the whole crate to ordered
    // containers so no future `.iter()` can introduce order dependence.
    cache: Mutex<BTreeMap<String, Option<&'static Artifact>>>,
}

impl<'m> KernelMetric<'m> {
    pub fn new(manifest: &'m Manifest) -> Self {
        KernelMetric { manifest, cache: Mutex::new(BTreeMap::new()) }
    }

    pub fn wanda_scores(&self, w: &Tensor, xnorm: &[f32]) -> Result<Vec<f32>> {
        let (m, n) = w.dims2();
        let name = format!("wanda_metric_{m}x{n}");
        let mut cache = self.cache.lock().unwrap();
        let entry = cache.entry(name.clone()).or_insert_with(|| {
            if self.manifest.artifacts.contains_key(&name) {
                match Artifact::load(self.manifest, &name) {
                    // leak: artifacts live for the process; tiny and few
                    Ok(a) => Some(Box::leak(Box::new(a)) as &'static Artifact),
                    Err(e) => {
                        crate::warn!("kernel metric {name} failed to load: {e}");
                        None
                    }
                }
            } else {
                // compact (per-layer-sliced) shapes have no pre-built
                // kernel artifact — say so once per shape instead of
                // silently degrading (ROADMAP: compact-aware metrics)
                crate::warn!(
                    "no '{name}' kernel artifact for shape {m}x{n} (compact \
                     re-pruning?); using the shape-generic host Wanda metric"
                );
                None
            }
        });
        if let Some(art) = entry {
            let xn = Tensor::new(vec![n], xnorm.to_vec());
            let out = art.call_tensors(&[In::F(w), In::F(&xn)])?;
            Ok(out[0].data.clone())
        } else {
            Ok(wanda_scores_host(w, xnorm))
        }
    }
}

/// Pick the `k` smallest-score indices (the pruned set).
pub fn lowest_k(scores: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[a]
            .partial_cmp(&scores[b])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx
}

/// Global adaptive selection (FLAP): z-normalize scores per layer, rank
/// globally, prune the lowest `total_units`. Returns per-layer pruned
/// index lists.
pub fn global_lowest(per_layer: &[Vec<f32>], total_units: usize) -> Vec<Vec<usize>> {
    let mut pool: Vec<(f32, usize, usize)> = Vec::new(); // (z, layer, idx)
    for (l, scores) in per_layer.iter().enumerate() {
        let m = scores.iter().sum::<f32>() / scores.len().max(1) as f32;
        let var = scores.iter().map(|s| (s - m) * (s - m)).sum::<f32>()
            / scores.len().max(1) as f32;
        let sd = var.sqrt().max(1e-12);
        for (j, &s) in scores.iter().enumerate() {
            pool.push(((s - m) / sd, l, j));
        }
    }
    pool.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut out = vec![Vec::new(); per_layer.len()];
    for &(_, l, j) in pool.iter().take(total_units) {
        out[l].push(j);
    }
    // guard: never empty a whole layer (keep at least 4 units)
    for (l, pruned) in out.iter_mut().enumerate() {
        let n = per_layer[l].len();
        if pruned.len() + 4 > n {
            pruned.sort();
            pruned.truncate(n.saturating_sub(4));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wanda_host_formula() {
        let w = Tensor::new(vec![2, 3], vec![1., -2., 3., -4., 5., -6.]);
        let s = wanda_scores_host(&w, &[1.0, 0.5, 2.0]);
        assert_eq!(s, vec![5.0, 3.5, 18.0]);
    }

    #[test]
    fn lowest_k_orders() {
        let s = vec![5.0, 1.0, 3.0, 0.5];
        assert_eq!(lowest_k(&s, 2), vec![3, 1]);
        assert_eq!(lowest_k(&s, 0), Vec::<usize>::new());
    }

    #[test]
    fn flap_variance() {
        // X col with rows [1, 3]: Σx=4, Σx²=10, N=2 → var = 5 - 4 = 1
        let w = Tensor::new(vec![1, 1], vec![2.0]);
        let s = flap_scores(&w, &[10.0], &[4.0], 2);
        assert!((s[0] - 4.0).abs() < 1e-6); // var 1 * ||w||² 4
    }

    #[test]
    fn global_budget_respected() {
        let per_layer = vec![
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0, 60.0, 70.0, 80.0],
        ];
        let pruned = global_lowest(&per_layer, 6);
        let total: usize = pruned.iter().map(|p| p.len()).sum();
        assert_eq!(total, 6);
        // z-normalized: both layers should lose some units
        assert!(!pruned[0].is_empty() && !pruned[1].is_empty());
    }
}
