//! SliceGPT-like baseline: PCA rotation + slicing.
//!
//! Faithful-to-mechanism simplification of SliceGPT (Ashkboos et al.
//! 2024) for the masked-evaluation setting (DESIGN.md §1):
//!
//! * **OV pair (exact)** — the attention output is linear in V per head,
//!   so a per-head orthogonal rotation `Q_h` (eigenvectors of that head's
//!   block of the context Gram) commutes with attention:
//!   `wv_h ← Q_hᵀ wv_h`, `wo_h ← wo_h Q_h`. Slicing the lowest-variance
//!   rotated directions is then PCA-optimal for that head.
//! * **FFN hidden units (metric only)** — rotations do not commute with
//!   the ReLU/SwiGLU nonlinearity, so (like SliceGPT's reliance on
//!   activations alone, which the paper critiques) units are ranked by
//!   their activation energy `E‖X_j‖²  = diag(G_ffn)` and sliced without
//!   restoration.
//!
//! The eigendecompositions (host Jacobi, f64) dominate the method's
//! pruning time, reproducing Table 4's cost ordering.

use crate::data::Dataset;
use crate::linalg::jacobi_eigh;
use crate::model::mask::PruneMask;
use crate::model::Weights;
use crate::prune::metric::lowest_k;
use crate::prune::structure::{plan, units};
use crate::prune::types::{PruneOpts, PruneReport};
use crate::runtime::Session;
use crate::tensor::ops::{zero_cols, zero_elems, zero_rows};
use crate::tensor::Tensor;
use crate::util::timer::Stopwatch;
use anyhow::Result;

pub fn prune_slicegpt(
    session: &Session,
    weights: &Weights,
    dataset: &Dataset,
    opts: &PruneOpts,
) -> Result<(Weights, PruneMask, PruneReport)> {
    let spec = session.spec.clone();
    // the per-head rotation assumes every head owns a full dh-block of
    // the context Gram — only true for uniform (non-compact) specs
    anyhow::ensure!(
        spec.is_uniform(),
        "SliceGPT-like baseline requires a uniform (non-compact) model spec"
    );
    let mut w = weights.clone();
    let mut mask = PruneMask::full(&spec);
    let mut sw = Stopwatch::start();

    let calib = dataset.calib_batches(opts.calib_batches);
    let calib_tokens: Vec<_> = calib.iter().map(|b| b.tokens.clone()).collect();
    let stats = session.capture(&session.pack(&w.packed)?, &calib_tokens)?;
    sw.split("capture");

    let group_plan = plan(&spec, opts.sparsity, false);
    let d = spec.d_model;
    let h = spec.n_heads;
    let dh = spec.head_dim();

    for l in 0..spec.n_layers {
        // ---- OV pair: per-head PCA rotation + slice -----------------------
        let mut wv = w.get_l(l, "wv")?;
        let mut wo = w.get_l(l, "wo")?;
        let k_ov = units(d, group_plan.ov_ratio);
        // distribute sliced dims evenly across heads
        let per_head = k_ov / h;
        let mut pruned_ov: Vec<usize> = Vec::with_capacity(per_head * h);
        for head in 0..h {
            let base = head * dh;
            // head block of the context Gram, f64
            let mut gb = vec![0.0f64; dh * dh];
            for a in 0..dh {
                for b in 0..dh {
                    gb[a * dh + b] =
                        stats.layers[l].g_attn.at2(base + a, base + b) as f64;
                }
            }
            let (_evals, evecs) = jacobi_eigh(&gb, dh); // ascending
            sw.split("pca");
            // rotate: wv_h ← Qᵀ wv_h (rows), wo_h ← wo_h Q (cols);
            // eigenvector k is evecs[k*dh..(k+1)*dh]; ascending order means
            // the FIRST per_head rotated dims carry the least variance.
            rotate_rows(&mut wv, base, dh, &evecs);
            rotate_cols(&mut wo, base, dh, &evecs);
            for k in 0..per_head {
                pruned_ov.push(base + k);
            }
        }
        sw.split("rotate");
        zero_rows(&mut wv, &pruned_ov);
        zero_cols(&mut wo, &pruned_ov);
        w.set_l(l, "wv", &wv)?;
        w.set_l(l, "wo", &wo)?;
        if spec.family == "opt" {
            // V bias lives in the rotated basis too: rotate then zero
            let mut bv = w.get_l(l, "bv")?;
            for head in 0..h {
                let base = head * dh;
                let mut gb = vec![0.0f64; dh * dh];
                for a in 0..dh {
                    for b in 0..dh {
                        gb[a * dh + b] =
                            stats.layers[l].g_attn.at2(base + a, base + b) as f64;
                    }
                }
                let (_e, evecs) = jacobi_eigh(&gb, dh);
                let old: Vec<f32> = (0..dh).map(|i| bv.data[base + i]).collect();
                for k in 0..dh {
                    let mut s = 0.0f64;
                    for i in 0..dh {
                        s += evecs[k * dh + i] * old[i] as f64;
                    }
                    bv.data[base + k] = s as f32;
                }
            }
            zero_elems(&mut bv, &pruned_ov);
            w.set_l(l, "bv", &bv)?;
        }
        for &j in &pruned_ov {
            mask.layers[l].ov[j] = false;
        }
        sw.split("apply");

        // ---- FFN: activation-energy slice (no restoration) ----------------
        let energies: Vec<f32> =
            (0..spec.d_ff).map(|i| stats.layers[l].g_ffn.at2(i, i)).collect();
        let k_ffn = units(spec.d_ff, group_plan.ffn_ratio);
        let pruned_ffn = lowest_k(&energies, k_ffn);
        sw.split("metric");
        let later = if spec.family == "opt" { "fc2" } else { "w_down" };
        let mut w_later = w.get_l(l, later)?;
        zero_cols(&mut w_later, &pruned_ffn);
        w.set_l(l, later, &w_later)?;
        if spec.family == "opt" {
            let mut fc1 = w.get_l(l, "fc1")?;
            zero_rows(&mut fc1, &pruned_ffn);
            w.set_l(l, "fc1", &fc1)?;
            let mut b1 = w.get_l(l, "bfc1")?;
            zero_elems(&mut b1, &pruned_ffn);
            w.set_l(l, "bfc1", &b1)?;
        } else {
            for name in ["w_gate", "w_up"] {
                let mut m = w.get_l(l, name)?;
                zero_rows(&mut m, &pruned_ffn);
                w.set_l(l, name, &m)?;
            }
        }
        for &j in &pruned_ffn {
            mask.layers[l].ffn[j] = false;
        }
        sw.split("apply");
    }

    mask.validate(&spec)?;
    let report = PruneReport {
        method: opts.method,
        target_sparsity: opts.sparsity,
        achieved_sparsity: mask.sparsity(&spec),
        params_removed: mask.params_removed(&spec),
        phase_s: sw
            .splits
            .iter()
            .map(|(n, d)| (n.clone(), d.as_secs_f64()))
            .collect(),
        total_s: sw.total().as_secs_f64(),
    };
    Ok((w, mask, report))
}

/// rows base..base+dh of `m` ← Qᵀ · rows  (Q rows = eigenvectors).
fn rotate_rows(m: &mut Tensor, base: usize, dh: usize, evecs: &[f64]) {
    let (_r, c) = m.dims2();
    let mut block: Vec<f32> = Vec::with_capacity(dh * c);
    for i in 0..dh {
        block.extend_from_slice(m.row(base + i));
    }
    for k in 0..dh {
        let out = m.row_mut(base + k);
        for j in 0..c {
            let mut s = 0.0f64;
            for i in 0..dh {
                s += evecs[k * dh + i] * block[i * c + j] as f64;
            }
            out[j] = s as f32;
        }
    }
}

/// cols base..base+dh of `m` ← cols · Q  (so new col k = Σ_i old_i Q_ik,
/// with Q_ik = evecs[k*dh + i]).
fn rotate_cols(m: &mut Tensor, base: usize, dh: usize, evecs: &[f64]) {
    let (r, c) = m.dims2();
    let mut block = vec![0.0f32; r * dh];
    for i in 0..r {
        for j in 0..dh {
            block[i * dh + j] = m.data[i * c + base + j];
        }
    }
    for i in 0..r {
        for k in 0..dh {
            let mut s = 0.0f64;
            for j in 0..dh {
                s += block[i * dh + j] as f64 * evecs[k * dh + j];
            }
            m.data[i * c + base + k] = s as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Rotating rows of wv by Qᵀ and cols of wo by Q must leave the
    /// product wo · wv unchanged (the forward pass is invariant).
    #[test]
    fn rotation_preserves_product() {
        let mut rng = Rng::new(0);
        let dh = 8;
        let d = 16;
        let mut wv = Tensor::randn(&[d, d], 1.0, &mut rng);
        let mut wo = Tensor::randn(&[d, d], 1.0, &mut rng);
        let before = crate::tensor::matmul::matmul(&wo, &wv);
        // random symmetric → eigenvectors are a valid orthogonal basis
        let mut sym = vec![0.0f64; dh * dh];
        for i in 0..dh {
            for j in 0..=i {
                let v = rng.normal();
                sym[i * dh + j] = v;
                sym[j * dh + i] = v;
            }
        }
        let (_e, q) = jacobi_eigh(&sym, dh);
        rotate_rows(&mut wv, 0, dh, &q);
        rotate_cols(&mut wo, 0, dh, &q);
        let after = crate::tensor::matmul::matmul(&wo, &wv);
        assert!(
            before.max_abs_diff(&after) < 1e-3,
            "product changed by {}",
            before.max_abs_diff(&after)
        );
    }
}
