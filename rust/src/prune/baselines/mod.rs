//! Baseline pruning methods with their own pipelines (the
//! structure-sharing baselines — magnitude, FLAP, LLM-Pruner-like,
//! NASLLM-ADMM — live inside [`super::pipeline`]):
//!
//! * [`wanda_struct`] — Table 5's "Wanda" row: per-operator column
//!   pruning, evenly distributed sparsity, optimal update, no coupling.
//! * [`slicegpt`] — SliceGPT-like PCA slicing: exact per-head rotation of
//!   the OV pair, activation-energy metric on FFN hidden units.

pub mod slicegpt;
pub mod wanda_struct;
