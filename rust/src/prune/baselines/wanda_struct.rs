//! Table 5 ablation baseline ("Wanda" row): prune every operator's input
//! columns independently with evenly distributed sparsity — Wanda column
//! selection + the optimal update — but WITHOUT FASP's coupled structure
//! (no free row removals, no Q/K skipping/rebalancing).
//!
//! The point of the ablation: at equal *parameter* sparsity, spending the
//! budget on uncoupled per-operator columns wrecks more of the network
//! than FASP's coupled removals, because (a) zeroed input columns of
//! q/k/v/fc1 delete information that IS still used downstream, and (b) no
//! rows come off for free.

use crate::data::Dataset;
use crate::model::mask::PruneMask;
use crate::model::Weights;
use crate::prune::metric::{lowest_k, KernelMetric};
use crate::prune::restore::restore_columns;
use crate::prune::types::{PruneOpts, PruneReport};
use crate::runtime::Session;
use crate::tensor::ops::zero_cols;
use crate::tensor::Tensor;
use crate::util::timer::Stopwatch;
use anyhow::Result;

pub fn prune_wanda_struct(
    session: &Session,
    weights: &Weights,
    dataset: &Dataset,
    opts: &PruneOpts,
) -> Result<(Weights, PruneMask, PruneReport)> {
    let spec = session.spec.clone();
    let mut w = weights.clone();
    let mut sw = Stopwatch::start();

    let calib = dataset.calib_batches(opts.calib_batches);
    let calib_tokens: Vec<_> = calib.iter().map(|b| b.tokens.clone()).collect();
    let stats = session.capture(&session.pack(&w.packed)?, &calib_tokens)?;
    sw.split("capture");

    let metric = KernelMetric::new(session.manifest);
    let mut removed = 0usize;
    // (operator names, which Gram supplies its input activations)
    let ops_per_layer: Vec<(&str, GramKind)> = if spec.family == "opt" {
        vec![
            ("wq", GramKind::Ln1),
            ("wk", GramKind::Ln1),
            ("wv", GramKind::Ln1),
            ("wo", GramKind::Attn),
            ("fc1", GramKind::Ln2),
            ("fc2", GramKind::Ffn),
        ]
    } else {
        vec![
            ("wq", GramKind::Ln1),
            ("wk", GramKind::Ln1),
            ("wv", GramKind::Ln1),
            ("wo", GramKind::Attn),
            ("w_gate", GramKind::Ln2),
            ("w_up", GramKind::Ln2),
            ("w_down", GramKind::Ffn),
        ]
    };

    for l in 0..spec.n_layers {
        for (name, gk) in &ops_per_layer {
            let wt = w.get_l(l, name)?;
            let (rows_w, n) = wt.dims2();
            let gram = gram_of(&stats.layers[l], *gk);
            let xnorm: Vec<f32> =
                (0..n).map(|i| gram.at2(i, i).max(0.0).sqrt()).collect();
            let scores = metric.wanda_scores(&wt, &xnorm)?;
            let k = ((n as f64) * opts.sparsity).floor() as usize;
            let pruned = lowest_k(&scores, k);
            sw.split("metric");
            let mut kept = vec![true; n];
            for &j in &pruned {
                kept[j] = false;
            }
            let new_w = if opts.restore {
                restore_columns(&wt, gram, &kept, opts.delta)?
            } else {
                let mut t = wt.clone();
                zero_cols(&mut t, &pruned);
                t
            };
            w.set_l(l, name, &new_w)?;
            removed += pruned.len() * rows_w;
            sw.split("restore");
        }
    }

    // No coupled structure → the structural mask stays full; report the
    // achieved sparsity from the raw zeroed-column count.
    let mask = PruneMask::full(&spec);
    let pool = crate::model::mask::prunable_params(&spec);
    let report = PruneReport {
        method: opts.method,
        target_sparsity: opts.sparsity,
        achieved_sparsity: removed as f64 / pool as f64,
        params_removed: removed,
        phase_s: sw
            .splits
            .iter()
            .map(|(n, d)| (n.clone(), d.as_secs_f64()))
            .collect(),
        total_s: sw.total().as_secs_f64(),
    };
    Ok((w, mask, report))
}

#[derive(Clone, Copy)]
enum GramKind {
    Ln1,
    Ln2,
    Attn,
    Ffn,
}

fn gram_of(stats: &crate::runtime::session::LayerStats, k: GramKind) -> &Tensor {
    match k {
        GramKind::Ln1 => &stats.g_ln1,
        GramKind::Ln2 => &stats.g_ln2,
        GramKind::Attn => &stats.g_attn,
        GramKind::Ffn => &stats.g_ffn,
    }
}
