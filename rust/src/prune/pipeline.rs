//! The pruning coordinator: capture → metric → select → restore/apply,
//! with per-phase wall-time accounting (Table 4). One entry point serves
//! FASP and all structure-sharing baselines; SliceGPT-like dispatches to
//! its own rotation-based path in [`super::baselines`].

use super::metric::{
    flap_scores, global_lowest, lowest_k, magnitude_scores, KernelMetric,
};
use super::restore::{bias_compensation, restore_columns};
use super::structure::{plan, rope_pairs, units};
use super::types::{Method, PruneOpts, PruneReport};
use crate::data::Dataset;
use crate::model::mask::{LayerMask, PruneMask};
use crate::model::{Weights};
use crate::runtime::session::CalibStats;
use crate::runtime::Session;
use crate::tensor::ops::{zero_cols, zero_elems, zero_rows};
use crate::util::timer::Stopwatch;
use anyhow::Result;

/// Prune `weights` in place (on a clone) and return the pruned weights,
/// the structural mask and the phase report.
pub fn prune(
    session: &Session,
    weights: &Weights,
    dataset: &Dataset,
    opts: &PruneOpts,
) -> Result<(Weights, PruneMask, PruneReport)> {
    if opts.method == Method::SliceGptLike {
        return super::baselines::slicegpt::prune_slicegpt(session, weights, dataset, opts);
    }
    if opts.method == Method::WandaStruct {
        return super::baselines::wanda_struct::prune_wanda_struct(
            session, weights, dataset, opts,
        );
    }

    let spec = session.spec.clone();
    let mut w = weights.clone();
    let mut mask = PruneMask::full(&spec);
    let mut sw = Stopwatch::start();

    let calib = dataset.calib_batches(opts.calib_batches);
    let calib_tokens: Vec<_> = calib.iter().map(|b| b.tokens.clone()).collect();

    // Pack the dense params once; gradcol and the first capture both see
    // the same unmodified weights. (Sequential mode re-packs per layer
    // below because `w` mutates between captures.)
    let dense_packed = session.pack(&w.packed)?;

    // LLM-Pruner-like needs gradients once (dense model).
    let grad_scores = if opts.method == Method::LlmPrunerLike {
        let batches: Vec<_> = calib
            .iter()
            .map(|b| (b.tokens.clone(), b.targets.clone()))
            .collect();
        let g = session.gradcol(&dense_packed, &batches)?;
        sw.split("gradcol");
        Some(g)
    } else {
        None
    };

    let group_plan = plan(&spec, opts.sparsity, opts.prune_qk);
    let layer_order: Vec<usize> = (0..spec.n_layers).collect();

    // Either one dense capture, or re-capture per layer (sequential).
    let mut stats = session.capture(&dense_packed, &calib_tokens)?;
    sw.split("capture");

    // FLAP selects globally: gather scores for all layers first.
    if opts.method == Method::Flap {
        let (ffn_pruned, ov_pruned) = flap_select(&spec, &w, &stats, &group_plan)?;
        sw.split("select");
        for l in 0..spec.n_layers {
            apply_ffn(&mut w, &stats, l, &ffn_pruned[l], opts, &mut mask.layers[l], &mut sw)?;
            apply_ov(&mut w, &stats, l, &ov_pruned[l], opts, &mut mask.layers[l], &mut sw)?;
        }
        return finish(&spec, w, mask, opts, sw);
    }

    let kernel_metric = KernelMetric::new(session.manifest);

    // Adaptive mode (paper §5 future work): gather Wanda scores for every
    // layer, z-normalize, select pruned units globally, then apply with
    // restoration as usual.
    if opts.adaptive && matches!(opts.method, Method::Fasp | Method::Magnitude) {
        let later = if spec.family == "opt" { "fc2" } else { "w_down" };
        let mut ffn_scores = Vec::with_capacity(spec.n_layers);
        let mut ov_scores = Vec::with_capacity(spec.n_layers);
        for l in 0..spec.n_layers {
            let w_later = w.get_l(l, later)?;
            let w_o = w.get_l(l, "wo")?;
            if opts.method == Method::Magnitude {
                ffn_scores.push(magnitude_scores(&w_later));
                ov_scores.push(magnitude_scores(&w_o));
            } else {
                ffn_scores.push(kernel_metric.wanda_scores(&w_later, &stats.ffn_xnorm(l))?);
                ov_scores.push(kernel_metric.wanda_scores(&w_o, &stats.attn_xnorm(l))?);
            }
        }
        let ffn_total: usize = (0..spec.n_layers)
            .map(|l| units(spec.d_ff_l(l), group_plan.ffn_ratio))
            .sum();
        let ov_total: usize = (0..spec.n_layers)
            .map(|l| units(spec.d_ov_l(l), group_plan.ov_ratio))
            .sum();
        let ffn_pruned = global_lowest(&ffn_scores, ffn_total);
        let ov_pruned = global_lowest(&ov_scores, ov_total);
        sw.split("metric");
        for l in 0..spec.n_layers {
            apply_ffn(&mut w, &stats, l, &ffn_pruned[l], opts, &mut mask.layers[l], &mut sw)?;
            apply_ov(&mut w, &stats, l, &ov_pruned[l], opts, &mut mask.layers[l], &mut sw)?;
        }
        return finish(&spec, w, mask, opts, sw);
    }

    for &l in &layer_order {
        if opts.sequential && l > 0 {
            // propagate pruning effects into the calibration activations.
            // The repack per iteration is required (earlier layers' weights
            // changed) and is dwarfed by the capture forward; the one known
            // redundancy is the untouched tok_emb head panel, ~1/L of the
            // plan per iteration.
            stats = session.capture(&session.pack(&w.packed)?, &calib_tokens)?;
            sw.split("capture");
        }
        // ---- FFN group ---------------------------------------------------
        let later = if spec.family == "opt" { "fc2" } else { "w_down" };
        let w_later = w.get_l(l, later)?;
        let ffn_scores: Vec<f32> = match (&opts.method, &grad_scores) {
            (Method::LlmPrunerLike, Some(g)) => g[l].ffn.clone(),
            (Method::Magnitude, _) => magnitude_scores(&w_later),
            _ => kernel_metric.wanda_scores(&w_later, &stats.ffn_xnorm(l))?,
        };
        let k_ffn = units(spec.d_ff_l(l), group_plan.ffn_ratio);
        let ffn_pruned = lowest_k(&ffn_scores, k_ffn);
        sw.split("metric");
        apply_ffn(&mut w, &stats, l, &ffn_pruned, opts, &mut mask.layers[l], &mut sw)?;

        // ---- OV group ----------------------------------------------------
        let w_o = w.get_l(l, "wo")?;
        let ov_scores: Vec<f32> = match (&opts.method, &grad_scores) {
            (Method::LlmPrunerLike, Some(g)) => g[l].ov.clone(),
            (Method::Magnitude, _) => magnitude_scores(&w_o),
            _ => kernel_metric.wanda_scores(&w_o, &stats.attn_xnorm(l))?,
        };
        let k_ov = units(spec.d_ov_l(l), group_plan.ov_ratio);
        let ov_pruned = lowest_k(&ov_scores, k_ov);
        sw.split("metric");
        apply_ov(&mut w, &stats, l, &ov_pruned, opts, &mut mask.layers[l], &mut sw)?;

        // ---- QK group (Table 6 ablation) ----------------------------------
        if opts.prune_qk && group_plan.qk_ratio > 0.0 {
            let qk_pruned = select_qk(&spec, &w, &stats, l, group_plan.qk_ratio)?;
            sw.split("metric");
            apply_qk(&mut w, l, &qk_pruned, &mut mask.layers[l])?;
            sw.split("apply");
        }
    }

    finish(&spec, w, mask, opts, sw)
}

fn finish(
    spec: &crate::runtime::manifest::ModelSpec,
    w: Weights,
    mask: PruneMask,
    opts: &PruneOpts,
    sw: Stopwatch,
) -> Result<(Weights, PruneMask, PruneReport)> {
    mask.validate(spec)?;
    let report = PruneReport {
        method: opts.method,
        target_sparsity: opts.sparsity,
        achieved_sparsity: mask.sparsity(spec),
        params_removed: mask.params_removed(spec),
        phase_s: sw
            .splits
            .iter()
            .map(|(n, d)| (n.clone(), d.as_secs_f64()))
            .collect(),
        total_s: sw.total().as_secs_f64(),
    };
    Ok((w, mask, report))
}

/// Zero/restore the FFN coupled group of layer `l`.
fn apply_ffn(
    w: &mut Weights,
    stats: &CalibStats,
    l: usize,
    pruned: &[usize],
    opts: &PruneOpts,
    lmask: &mut LayerMask,
    sw: &mut Stopwatch,
) -> Result<()> {
    if pruned.is_empty() {
        return Ok(());
    }
    let is_opt = w.spec.family == "opt";
    let later = if is_opt { "fc2" } else { "w_down" };
    let bias = if is_opt { "bfc2" } else { "b_down" };
    let mut kept = vec![true; w.spec.d_ff_l(l)];
    for &j in pruned {
        kept[j] = false;
    }

    let w_later = w.get_l(l, later)?;
    if opts.method == Method::Flap {
        // bias-only compensation, then plain zeroing
        let delta =
            bias_compensation(&w_later, &stats.layers[l].m_ffn.data, stats.rows, &kept);
        let mut b = w.get_l(l, bias)?;
        for (bv, dv) in b.data.iter_mut().zip(&delta) {
            *bv += dv;
        }
        w.set_l(l, bias, &b)?;
    }
    let new_later = if opts.restore {
        match opts.method {
            Method::NasllmAdmm => {
                let g64: Vec<f64> =
                    stats.layers[l].g_ffn.data.iter().map(|&x| x as f64).collect();
                let mut greg = g64;
                let n = w.spec.d_ff_l(l);
                let mean_diag: f64 =
                    (0..n).map(|i| greg[i * n + i]).sum::<f64>() / n as f64;
                for i in 0..n {
                    greg[i * n + i] += opts.delta * mean_diag.max(1e-30);
                }
                let (t, _iters) = crate::linalg::admm_restore(
                    &w_later,
                    &greg,
                    &kept,
                    mean_diag.max(1e-6),
                    opts.admm_iters,
                )?;
                t
            }
            _ => restore_columns(&w_later, &stats.layers[l].g_ffn, &kept, opts.delta)?,
        }
    } else {
        let mut t = w_later.clone();
        zero_cols(&mut t, pruned);
        t
    };
    w.set_l(l, later, &new_later)?;
    sw.split("restore");

    // coupled rows are free removals (§3.1)
    if is_opt {
        let mut fc1 = w.get_l(l, "fc1")?;
        zero_rows(&mut fc1, pruned);
        w.set_l(l, "fc1", &fc1)?;
        let mut b1 = w.get_l(l, "bfc1")?;
        zero_elems(&mut b1, pruned);
        w.set_l(l, "bfc1", &b1)?;
    } else {
        for name in ["w_gate", "w_up"] {
            let mut m = w.get_l(l, name)?;
            zero_rows(&mut m, pruned);
            w.set_l(l, name, &m)?;
        }
    }
    for &j in pruned {
        lmask.ffn[j] = false;
    }
    sw.split("apply");
    Ok(())
}

/// Zero/restore the OV coupled group of layer `l`.
fn apply_ov(
    w: &mut Weights,
    stats: &CalibStats,
    l: usize,
    pruned: &[usize],
    opts: &PruneOpts,
    lmask: &mut LayerMask,
    sw: &mut Stopwatch,
) -> Result<()> {
    if pruned.is_empty() {
        return Ok(());
    }
    let is_opt = w.spec.family == "opt";
    let mut kept = vec![true; w.spec.d_ov_l(l)];
    for &j in pruned {
        kept[j] = false;
    }
    let w_o = w.get_l(l, "wo")?;
    if opts.method == Method::Flap {
        let delta =
            bias_compensation(&w_o, &stats.layers[l].m_attn.data, stats.rows, &kept);
        let mut b = w.get_l(l, "bo")?;
        for (bv, dv) in b.data.iter_mut().zip(&delta) {
            *bv += dv;
        }
        w.set_l(l, "bo", &b)?;
    }
    let new_wo = if opts.restore {
        match opts.method {
            Method::NasllmAdmm => {
                let n = w.spec.d_ov_l(l);
                let mut g64: Vec<f64> =
                    stats.layers[l].g_attn.data.iter().map(|&x| x as f64).collect();
                let mean_diag: f64 =
                    (0..n).map(|i| g64[i * n + i]).sum::<f64>() / n as f64;
                for i in 0..n {
                    g64[i * n + i] += opts.delta * mean_diag.max(1e-30);
                }
                let (t, _) = crate::linalg::admm_restore(
                    &w_o,
                    &g64,
                    &kept,
                    mean_diag.max(1e-6),
                    opts.admm_iters,
                )?;
                t
            }
            _ => restore_columns(&w_o, &stats.layers[l].g_attn, &kept, opts.delta)?,
        }
    } else {
        let mut t = w_o.clone();
        zero_cols(&mut t, pruned);
        t
    };
    w.set_l(l, "wo", &new_wo)?;
    sw.split("restore");

    let mut wv = w.get_l(l, "wv")?;
    zero_rows(&mut wv, pruned);
    w.set_l(l, "wv", &wv)?;
    if is_opt {
        let mut bv = w.get_l(l, "bv")?;
        zero_elems(&mut bv, pruned);
        w.set_l(l, "bv", &bv)?;
    }
    for &j in pruned {
        lmask.ov[j] = false;
    }
    sw.split("apply");
    Ok(())
}

/// Score Q/K rows (Wanda on rows of both matrices against the ln1 input
/// norms); LLaMA selects whole RoPE pairs.
fn select_qk(
    spec: &crate::runtime::manifest::ModelSpec,
    w: &Weights,
    stats: &CalibStats,
    l: usize,
    ratio: f64,
) -> Result<Vec<usize>> {
    let xnorm = stats.ln1_xnorm(l);
    let wq = w.get_l(l, "wq")?;
    let wk = w.get_l(l, "wk")?;
    let d = spec.d_model;
    let mut row_score = vec![0.0f32; d];
    for j in 0..d {
        let mut s = 0.0f32;
        for (i, &xn) in xnorm.iter().enumerate() {
            s += (wq.at2(j, i).abs() + wk.at2(j, i).abs()) * xn;
        }
        row_score[j] = s;
    }
    if spec.family == "llama" {
        // prune whole RoPE pairs
        let pairs = rope_pairs(d, spec.n_heads);
        let pair_scores: Vec<f32> =
            pairs.iter().map(|&(a, b)| row_score[a] + row_score[b]).collect();
        let k_pairs = units(pairs.len(), ratio);
        let mut pruned = Vec::with_capacity(2 * k_pairs);
        for pi in lowest_k(&pair_scores, k_pairs) {
            pruned.push(pairs[pi].0);
            pruned.push(pairs[pi].1);
        }
        Ok(pruned)
    } else {
        Ok(lowest_k(&row_score, units(d, ratio)))
    }
}

fn apply_qk(
    w: &mut Weights,
    l: usize,
    pruned: &[usize],
    lmask: &mut LayerMask,
) -> Result<()> {
    if pruned.is_empty() {
        return Ok(());
    }
    for name in ["wq", "wk"] {
        let mut m = w.get_l(l, name)?;
        zero_rows(&mut m, pruned);
        w.set_l(l, name, &m)?;
    }
    if w.spec.family == "opt" {
        for name in ["bq", "bk"] {
            let mut b = w.get_l(l, name)?;
            zero_elems(&mut b, pruned);
            w.set_l(l, name, &b)?;
        }
    }
    for &j in pruned {
        lmask.qk[j] = false;
    }
    Ok(())
}

/// FLAP's global adaptive selection over both groups.
fn flap_select(
    spec: &crate::runtime::manifest::ModelSpec,
    w: &Weights,
    stats: &CalibStats,
    plan: &super::structure::GroupPlan,
) -> Result<(Vec<Vec<usize>>, Vec<Vec<usize>>)> {
    let later = if spec.family == "opt" { "fc2" } else { "w_down" };
    let mut ffn_scores = Vec::with_capacity(spec.n_layers);
    let mut ov_scores = Vec::with_capacity(spec.n_layers);
    for l in 0..spec.n_layers {
        let wl = w.get_l(l, later)?;
        let gd: Vec<f32> =
            (0..spec.d_ff_l(l)).map(|i| stats.layers[l].g_ffn.at2(i, i)).collect();
        ffn_scores.push(flap_scores(&wl, &gd, &stats.layers[l].m_ffn.data, stats.rows));
        let wo = w.get_l(l, "wo")?;
        let gd: Vec<f32> =
            (0..spec.d_ov_l(l)).map(|i| stats.layers[l].g_attn.at2(i, i)).collect();
        ov_scores.push(flap_scores(&wo, &gd, &stats.layers[l].m_attn.data, stats.rows));
    }
    let ffn_total: usize = (0..spec.n_layers)
        .map(|l| units(spec.d_ff_l(l), plan.ffn_ratio))
        .sum();
    let ov_total: usize = (0..spec.n_layers)
        .map(|l| units(spec.d_ov_l(l), plan.ov_ratio))
        .sum();
    Ok((
        global_lowest(&ffn_scores, ffn_total),
        global_lowest(&ov_scores, ov_total),
    ))
}

/// Outcome of [`prune_compact`]: the masked weights, the structural
/// mask, the phase report (with the extra `repack` stage), and the
/// physically sliced compact model ready to save/run.
pub struct CompactOutcome {
    pub pruned: Weights,
    pub mask: PruneMask,
    pub report: PruneReport,
    pub compact: crate::model::compact::CompactModel,
}

/// Prune, then physically repack the result into a compact model named
/// `name`. The repack wall-time lands in the report as a `repack` phase
/// (Table-4-style accounting), so the export cost is visible next to
/// capture/metric/restore. The repack runs on the session's backend
/// pool, so it parallelizes exactly like the entries do.
pub fn prune_compact(
    session: &Session,
    weights: &Weights,
    dataset: &Dataset,
    opts: &PruneOpts,
    name: &str,
) -> Result<CompactOutcome> {
    let (pruned, mask, mut report) = prune(session, weights, dataset, opts)?;
    let t0 = std::time::Instant::now();
    let compact = {
        let _exec = session.exec_scope();
        crate::model::compact::compact_from_mask(&pruned, &mask, name)?
    };
    let repack_s = t0.elapsed().as_secs_f64();
    report.phase_s.push(("repack".to_string(), repack_s));
    report.total_s += repack_s;
    Ok(CompactOutcome { pruned, mask, report, compact })
}
