//! Pruning-report persistence: serialize `PruneReport`s (plus eval
//! results) to JSON under `results/reports/` so experiment runs are
//! auditable and EXPERIMENTS.md can cite concrete files.

use super::types::PruneReport;
use crate::util::json::Json;
use crate::Result;
use std::path::PathBuf;

/// A report enriched with evaluation outcomes.
pub struct RunRecord {
    pub model: String,
    pub report: PruneReport,
    pub dense_ppl: Option<f64>,
    pub pruned_ppl: Option<f64>,
    pub zero_shot_mean: Option<f64>,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", Json::Str(self.model.clone())),
            ("method", Json::Str(self.report.method.label().to_string())),
            ("target_sparsity", Json::Num(self.report.target_sparsity)),
            ("achieved_sparsity", Json::Num(self.report.achieved_sparsity)),
            ("params_removed", Json::Num(self.report.params_removed as f64)),
            ("total_s", Json::Num(self.report.total_s)),
            (
                "phases",
                Json::Obj(
                    self.report
                        .phase_s
                        .iter()
                        .map(|(n, s)| (n.clone(), Json::Num(*s)))
                        .collect(),
                ),
            ),
        ];
        if let Some(p) = self.dense_ppl {
            fields.push(("dense_ppl", Json::Num(p)));
        }
        if let Some(p) = self.pruned_ppl {
            fields.push(("pruned_ppl", Json::Num(p)));
        }
        if let Some(z) = self.zero_shot_mean {
            fields.push(("zero_shot_mean", Json::Num(z)));
        }
        Json::obj(fields)
    }

    /// Persist under results/reports/<model>_<method>_<sparsity>.json.
    pub fn save(&self) -> Result<PathBuf> {
        let dir = crate::repo_root().join("results").join("reports");
        std::fs::create_dir_all(&dir)?;
        let name = format!(
            "{}_{}_{:02.0}.json",
            self.model,
            format!("{:?}", self.report.method).to_lowercase(),
            self.report.target_sparsity * 100.0
        );
        let path = dir.join(name);
        std::fs::write(&path, self.to_json().pretty())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::Method;

    #[test]
    fn json_roundtrip() {
        let rec = RunRecord {
            model: "llama_tiny".into(),
            report: PruneReport {
                method: Method::Fasp,
                target_sparsity: 0.2,
                achieved_sparsity: 0.197,
                params_removed: 25856,
                phase_s: vec![("capture".into(), 1.2), ("restore".into(), 0.1)],
                total_s: 1.4,
            },
            dense_ppl: Some(9.76),
            pruned_ppl: Some(9.80),
            zero_shot_mean: None,
        };
        let j = rec.to_json();
        let re = Json::parse(&j.pretty()).unwrap();
        assert_eq!(re.get("method").as_str().unwrap(), "FASP (ours)");
        assert_eq!(re.get("phases").get("capture").as_f64().unwrap(), 1.2);
        assert_eq!(re.get("pruned_ppl").as_f64().unwrap(), 9.80);
    }
}
