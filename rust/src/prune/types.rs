//! Pruning method taxonomy and option/report types.

use std::fmt;

/// Pruning methods — FASP plus every baseline in the paper's tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// The paper's method: coupled structure + Wanda-column metric +
    /// closed-form restoration, Q/K skipped.
    Fasp,
    /// Table 5 ablation row "Wanda": per-operator column pruning with
    /// evenly distributed sparsity + restoration, no coupling.
    WandaStruct,
    /// Weight-magnitude column metric on the FASP structure, no
    /// restoration.
    Magnitude,
    /// FLAP: fluctuation metric, global adaptive selection, bias-only
    /// compensation (no weight restoration).
    Flap,
    /// SliceGPT-like: PCA rotation + slicing (exact on the OV pair,
    /// energy-metric on FFN), no restoration.
    SliceGptLike,
    /// LLM-Pruner-like: first-order Taylor column importance from
    /// calibration gradients, no restoration (and no fine-tuning).
    LlmPrunerLike,
    /// NASLLM-like: FASP structure/metric but the ADMM restorer.
    NasllmAdmm,
}

impl Method {
    pub fn all() -> [Method; 7] {
        [
            Method::Fasp,
            Method::WandaStruct,
            Method::Magnitude,
            Method::Flap,
            Method::SliceGptLike,
            Method::LlmPrunerLike,
            Method::NasllmAdmm,
        ]
    }

    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "fasp" => Method::Fasp,
            "wanda" | "wanda_struct" => Method::WandaStruct,
            "magnitude" | "mag" => Method::Magnitude,
            "flap" => Method::Flap,
            "slicegpt" | "slicegpt_like" => Method::SliceGptLike,
            "llm_pruner" | "llm_pruner_like" => Method::LlmPrunerLike,
            "nasllm" | "nasllm_admm" | "admm" => Method::NasllmAdmm,
            _ => return None,
        })
    }

    /// Paper-table label.
    pub fn label(&self) -> &'static str {
        match self {
            Method::Fasp => "FASP (ours)",
            Method::WandaStruct => "Wanda-struct",
            Method::Magnitude => "Magnitude",
            Method::Flap => "FLAP*",
            Method::SliceGptLike => "SliceGPT*",
            Method::LlmPrunerLike => "LLM-Pruner*",
            Method::NasllmAdmm => "NASLLM*",
        }
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// Options for one pruning run.
#[derive(Clone, Debug)]
pub struct PruneOpts {
    pub method: Method,
    /// Target sparsity over the prunable pool (0.0–~0.6).
    pub sparsity: f64,
    /// Calibration batches to stream through capture.
    pub calib_batches: usize,
    /// FASP restoration on/off (structure ablation keeps selection but
    /// may disable the update).
    pub restore: bool,
    /// Table 6 ablation: also prune Q/K rows.
    pub prune_qk: bool,
    /// Ridge δ (relative to mean Gram diagonal) in Eq. 8.
    pub delta: f64,
    /// Re-capture activations after each pruned layer (SparseGPT-style
    /// propagation) instead of one dense pass.
    pub sequential: bool,
    /// Adaptive per-layer sparsity (paper §5 future work): select pruned
    /// units globally across layers by z-normalized score instead of a
    /// uniform per-layer ratio. FASP/magnitude only.
    pub adaptive: bool,
    /// ADMM iterations (NasllmAdmm only).
    pub admm_iters: usize,
    pub seed: u64,
}

impl PruneOpts {
    pub fn new(method: Method, sparsity: f64) -> PruneOpts {
        PruneOpts {
            method,
            sparsity,
            calib_batches: 8,
            restore: matches!(
                method,
                Method::Fasp | Method::WandaStruct | Method::NasllmAdmm
            ),
            prune_qk: false,
            delta: 1e-2,
            sequential: false,
            adaptive: false,
            admm_iters: 48,
            seed: 42,
        }
    }
}

/// Outcome of one pruning run.
#[derive(Clone, Debug)]
pub struct PruneReport {
    pub method: Method,
    pub target_sparsity: f64,
    pub achieved_sparsity: f64,
    pub params_removed: usize,
    /// (phase name, seconds)
    pub phase_s: Vec<(String, f64)>,
    pub total_s: f64,
}

impl PruneReport {
    pub fn phase(&self, name: &str) -> f64 {
        self.phase_s
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }
}
