//! FASP structured pruning — the paper's contribution (§3) plus every
//! baseline the evaluation compares against.
//!
//! * [`structure`] — the coupled pruning structure (§3.1): later-layer
//!   columns ↔ earlier-layer rows, Q/K skipping, sparsity rebalancing.
//! * [`metric`]    — the Wanda-inspired column metric (§3.2) and the
//!   baseline metrics (magnitude, FLAP fluctuation, Taylor).
//! * [`restore`]   — the closed-form least-squares restoration (§3.3,
//!   Eq. 8) via the host Cholesky, plus FLAP bias compensation.
//! * [`pipeline`]  — the coordinator: calibration capture → scores →
//!   selection → apply/restore, with per-phase wall-time accounting,
//!   plus the `repack` stage that exports a compact (physically sliced)
//!   model artifact.
//! * [`baselines`] — SliceGPT-like PCA slicing (rotation on the OV pair,
//!   energy metric on FFN), and method plumbing for LLM-Pruner-like /
//!   NASLLM-ADMM variants.

pub mod types;
pub mod structure;
pub mod metric;
pub mod restore;
pub mod pipeline;
pub mod baselines;
pub mod report;

pub use pipeline::{prune, prune_compact, CompactOutcome};
pub use types::{Method, PruneOpts, PruneReport};
