//! `fasp lint` — a dependency-free determinism & robustness
//! static-analysis pass over the crate's own sources.
//!
//! Every receipt this reproduction ships (packed≡unpacked kernels,
//! batched-serve ≡ sequential-generate, bit-identical outputs at any
//! thread width / backend / storage mode) rests on invariants that a
//! single stray `HashMap` iteration, unordered float `sum()`, or
//! panic-in-serve-path can silently break. The dynamic suites catch
//! those only when a test hits the right interleaving; this pass
//! checks the contract *statically on every build*:
//!
//! | rule | contract |
//! |------|----------|
//! | D1   | no `HashMap`/`HashSet` in library code (iteration order) |
//! | D2   | no unordered float reductions outside `lane_accum`'s home |
//! | D3   | no wall-clock / pointer-derived values in library code |
//! | U1   | every `unsafe` carries an adjacent `// SAFETY:` comment |
//! | R1   | no `unwrap`/`expect`/`panic!` in request paths |
//! | P1   | no hand-rolled threads/channels outside `util/pool.rs` |
//!
//! Suppressions live in `rust/lint_allow.toml`; every entry carries a
//! written justification and an entry that matches nothing fails the
//! lint (see [`allow`]). The pass runs as a tier-1 gate in
//! `verify.sh` (before the test matrix) and inside
//! `bench_hot_paths --check`, emitting `LINT_REPORT.json` next to the
//! other receipts.

pub mod allow;
pub mod lexer;
pub mod report;
pub mod rules;
pub mod source;

pub use report::LintRun;
pub use rules::Violation;

use crate::Result;
use std::path::Path;

/// Lint the crate rooted at `root` (the repo root — the directory
/// holding `Cargo.toml` and `rust/`). Scans `rust/src/**/*.rs`
/// against `rust/lint_allow.toml` (an absent allowlist means zero
/// suppressions).
pub fn lint_repo(root: &Path) -> Result<LintRun> {
    let rust_dir = root.join("rust");
    let src_dir = rust_dir.join("src");
    anyhow::ensure!(
        src_dir.is_dir(),
        "fasp lint: {} is not a directory (run from the repo, or set FASP_ROOT)",
        src_dir.display()
    );
    let files = source::scan_crate(&src_dir)?;
    let allow_path = rust_dir.join("lint_allow.toml");
    let entries = if allow_path.is_file() {
        let text = std::fs::read_to_string(&allow_path)
            .map_err(|e| anyhow::anyhow!("fasp lint: read {}: {e}", allow_path.display()))?;
        allow::parse(&text)?
    } else {
        Vec::new()
    };
    let mut findings = Vec::new();
    for f in &files {
        findings.extend(rules::check_file(f));
    }
    Ok(report::evaluate(files.len(), findings, entries))
}
