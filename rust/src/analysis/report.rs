//! Allowlist application + report rendering for `fasp lint`: the
//! human table and the machine-readable `LINT_REPORT.json` receipt.

use crate::analysis::allow::AllowEntry;
use crate::analysis::rules::{Violation, CATALOG};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// The outcome of one lint pass over the crate.
pub struct LintRun {
    pub files_scanned: usize,
    /// Raw findings not absorbed by the allowlist — each one fails
    /// the lint.
    pub violations: Vec<Violation>,
    /// Findings absorbed by an allowlist entry (index into `entries`).
    pub allowed: Vec<(Violation, usize)>,
    /// The parsed allowlist.
    pub entries: Vec<AllowEntry>,
    /// Indices of entries that absorbed zero findings — stale entries
    /// also fail the lint (the allowlist can never rot ahead of code).
    pub stale: Vec<usize>,
}

/// Apply the allowlist to raw findings. Entries are tried in file
/// order; each absorbs up to its cap. Deterministic: findings arrive
/// sorted (files scanned in sorted order, tokens in source order).
pub fn evaluate(
    files_scanned: usize,
    findings: Vec<Violation>,
    entries: Vec<AllowEntry>,
) -> LintRun {
    let mut used = vec![0usize; entries.len()];
    let mut violations = Vec::new();
    let mut allowed = Vec::new();
    for v in findings {
        let hit = entries.iter().enumerate().find(|(i, e)| {
            used[*i] < e.cap() && e.covers(v.rule, &v.rel, &v.snippet)
        });
        match hit {
            Some((i, _)) => {
                used[i] += 1;
                allowed.push((v, i));
            }
            None => violations.push(v),
        }
    }
    let stale = (0..entries.len()).filter(|&i| used[i] == 0).collect();
    LintRun {
        files_scanned,
        violations,
        allowed,
        entries,
        stale,
    }
}

impl LintRun {
    /// Clean = zero violations and zero stale allowlist entries.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty() && self.stale.is_empty()
    }

    fn count(&self, rule: &str) -> (usize, usize) {
        (
            self.violations.iter().filter(|v| v.rule == rule).count(),
            self.allowed.iter().filter(|(v, _)| v.rule == rule).count(),
        )
    }

    /// Render the human-readable report table.
    pub fn render_table(&self) -> String {
        let mut s = String::new();
        s.push_str("fasp lint — determinism & robustness static analysis\n");
        s.push_str(&format!(
            "  {} files scanned, {} allowlist entr{}\n\n",
            self.files_scanned,
            self.entries.len(),
            if self.entries.len() == 1 { "y" } else { "ies" }
        ));
        s.push_str("  rule  viol  allowed  description\n");
        for (id, desc) in CATALOG {
            let (v, a) = self.count(id);
            s.push_str(&format!("  {id:<4}  {v:>4}  {a:>7}  {desc}\n"));
        }
        if !self.violations.is_empty() {
            s.push_str("\nviolations:\n");
            for v in &self.violations {
                s.push_str(&format!(
                    "  {}:{} [{}] {}\n",
                    v.rel, v.line, v.rule, v.snippet
                ));
            }
        }
        if !self.stale.is_empty() {
            s.push_str("\nstale allowlist entries (matched nothing — remove them):\n");
            for &i in &self.stale {
                let e = &self.entries[i];
                s.push_str(&format!(
                    "  lint_allow.toml:{} [{}] {} {}\n",
                    e.line,
                    e.rule,
                    e.file,
                    e.pattern.as_deref().unwrap_or("(whole file)")
                ));
            }
        }
        let status = if self.is_clean() {
            format!(
                "\nOK: 0 violations, {} allowed suppression{}\n",
                self.allowed.len(),
                if self.allowed.len() == 1 { "" } else { "s" }
            )
        } else {
            format!(
                "\nFAIL: {} violation{}, {} stale allowlist entr{}\n",
                self.violations.len(),
                if self.violations.len() == 1 { "" } else { "s" },
                self.stale.len(),
                if self.stale.len() == 1 { "y" } else { "ies" }
            )
        };
        s.push_str(&status);
        s
    }

    /// The `LINT_REPORT.json` payload: per-rule counts and per-file
    /// breakdowns, plus totals and stale-entry diagnostics.
    pub fn report_json(&self) -> Json {
        let mut rules = Vec::new();
        for (id, desc) in CATALOG {
            let (v, a) = self.count(id);
            let mut files: BTreeMap<String, i64> = BTreeMap::new();
            for viol in self.violations.iter().filter(|x| x.rule == *id) {
                *files.entry(viol.rel.clone()).or_insert(0) += 1;
            }
            let files_json = Json::Obj(
                files
                    .into_iter()
                    .map(|(k, n)| (k, Json::Num(n as f64)))
                    .collect(),
            );
            rules.push(Json::obj(vec![
                ("id", Json::Str(id.to_string())),
                ("description", Json::Str(desc.to_string())),
                ("violations", Json::Num(v as f64)),
                ("allowed", Json::Num(a as f64)),
                ("files", files_json),
            ]));
        }
        let stale = self
            .stale
            .iter()
            .map(|&i| {
                let e = &self.entries[i];
                Json::obj(vec![
                    ("rule", Json::Str(e.rule.clone())),
                    ("file", Json::Str(e.file.clone())),
                    (
                        "pattern",
                        match &e.pattern {
                            Some(p) => Json::Str(p.clone()),
                            None => Json::Null,
                        },
                    ),
                    ("line", Json::Num(e.line as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            ("rules", Json::Arr(rules)),
            (
                "total_violations",
                Json::Num(self.violations.len() as f64),
            ),
            ("total_allowed", Json::Num(self.allowed.len() as f64)),
            ("allow_entries", Json::Num(self.entries.len() as f64)),
            ("stale_allow_entries", Json::Arr(stale)),
            ("clean", Json::Bool(self.is_clean())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{allow, rules, source::SourceFile};

    fn findings(rel: &str, src: &str) -> Vec<Violation> {
        rules::check_file(&SourceFile::synthetic(rel, src))
    }

    #[test]
    fn allowlist_absorbs_up_to_cap_and_flags_stale() {
        let src = "fn a(v: &[f32]) -> f32 { v.iter().sum::<f32>() }\nfn b(v: &[f32]) -> f32 { v.iter().sum::<f32>() }\n";
        let toml = "[[allow]]\nrule = \"D2\"\nfile = \"src/x.rs\"\npattern = \".sum::<f32>()\"\nmax = 1\nwhy = \"only the first one is a known-safe scalar site\"\n";
        let entries = allow::parse(toml).unwrap();
        let run = evaluate(1, findings("src/x.rs", src), entries);
        assert_eq!(run.allowed.len(), 1);
        assert_eq!(run.violations.len(), 1); // cap exceeded → second stays
        assert!(run.stale.is_empty());
        assert!(!run.is_clean());

        // stale entry: nothing to absorb
        let toml2 = "[[allow]]\nrule = \"D1\"\nfile = \"src/x.rs\"\nwhy = \"there is no HashMap here any more at all\"\n";
        let run2 = evaluate(1, Vec::new(), allow::parse(toml2).unwrap());
        assert_eq!(run2.stale, vec![0]);
        assert!(!run2.is_clean());
    }

    #[test]
    fn file_scope_entry_absorbs_everything_in_that_file() {
        let src = "fn f() { let a = std::time::Instant::now(); let _ = a; }\nfn g() { let b = std::time::Instant::now(); let _ = b; }\n";
        let toml = "[[allow]]\nrule = \"D3\"\nfile = \"src/util/timer.rs\"\nwhy = \"the timer module measures wall time by design\"\n";
        let run = evaluate(
            1,
            findings("src/util/timer.rs", src),
            allow::parse(toml).unwrap(),
        );
        assert!(run.is_clean());
        assert_eq!(run.allowed.len(), 2);
    }

    #[test]
    fn report_json_shape() {
        let src = "use std::collections::HashMap;\n";
        let run = evaluate(3, findings("src/x.rs", src), Vec::new());
        let j = run.report_json();
        let txt = j.pretty();
        assert!(txt.contains("\"total_violations\""));
        assert!(txt.contains("\"files_scanned\""));
        assert!(txt.contains("src/x.rs"));
        assert!(!run.is_clean());
        let table = run.render_table();
        assert!(table.contains("FAIL"));
        assert!(table.contains("src/x.rs:1 [D1]"));
    }
}
