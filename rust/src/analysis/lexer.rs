//! A lightweight, dependency-free Rust lexer for the static-analysis
//! pass (`fasp lint`). It is *not* a full Rust grammar — it produces
//! exactly what the lint rules need and nothing more:
//!
//! - identifiers, numeric literals (with a float/integer flag) and
//!   single-character punctuation, each tagged with a 1-based line;
//! - comments, recorded separately per line (so the U1 rule can look
//!   for `// SAFETY:` text adjacent to an `unsafe` token);
//! - string / raw-string / byte-string / char literals are consumed
//!   and *dropped*, so rule matchers never fire on text inside quotes
//!   (this is what lets the linter's own fixtures live in string
//!   literals without tripping the rules on themselves).
//!
//! Keywords are ordinary identifiers here (`unsafe`, `as`, `mod`, ...);
//! `::` arrives as two `:` puncts. Lifetimes (`'a`) are distinguished
//! from char literals (`'x'`) by lookahead and dropped entirely.

/// One meaningful token of a source file.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Identifier or keyword.
    Ident(String),
    /// Numeric literal; `float` is true for `1.0`, `1e9`, `2.5f32`, ...
    Num { text: String, float: bool },
    /// Single punctuation character (`::` is two `:` tokens).
    Punct(char),
}

/// A token plus its 1-based source line.
#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
}

/// One line's worth of comment text (block comments spanning N lines
/// produce N entries, so "comment directly above line L" is a simple
/// line-number check).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// The lexed view of one source file.
#[derive(Debug, Default)]
pub struct LexedFile {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

impl LexedFile {
    /// Ident text at token index `i`, or `""`.
    pub fn ident(&self, i: usize) -> &str {
        match self.tokens.get(i).map(|t| &t.tok) {
            Some(Tok::Ident(s)) => s,
            _ => "",
        }
    }

    /// True if token `i` is the punct `c`.
    pub fn punct(&self, i: usize, c: char) -> bool {
        matches!(self.tokens.get(i).map(|t| &t.tok), Some(Tok::Punct(p)) if *p == c)
    }
}

/// Lex `src` into tokens + comments. Never fails: unterminated
/// constructs simply consume to end of input (good enough for a
/// linter that only runs over code the compiler already accepted).
pub fn lex(src: &str) -> LexedFile {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = LexedFile::default();
    let mut i = 0usize;
    let mut line = 1usize;

    macro_rules! bump_line {
        ($c:expr) => {
            if $c == '\n' {
                line += 1;
            }
        };
    }

    while i < n {
        let c = b[i];
        // -- whitespace ------------------------------------------------
        if c.is_whitespace() {
            bump_line!(c);
            i += 1;
            continue;
        }
        // -- line comment ---------------------------------------------
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            out.comments.push(Comment {
                line,
                text: b[start..i].iter().collect(),
            });
            continue; // newline handled by whitespace branch
        }
        // -- block comment (nesting, per Rust) ------------------------
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start_line = line;
            let mut depth = 1usize;
            let start = i;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    bump_line!(b[i]);
                    i += 1;
                }
            }
            let text: String = b[start..i.min(n)].iter().collect();
            for (k, part) in text.split('\n').enumerate() {
                out.comments.push(Comment {
                    line: start_line + k,
                    text: part.to_string(),
                });
            }
            continue;
        }
        // -- raw strings: r"...", r#"..."#, br#"..."# ------------------
        if (c == 'r' || c == 'b') && is_raw_string_start(&b, i) {
            let mut j = i;
            if b[j] == 'b' {
                j += 1;
            }
            j += 1; // past 'r'
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            j += 1; // past opening quote
            // scan for closing quote followed by `hashes` #'s
            while j < n {
                bump_line!(b[j]);
                if b[j] == '"' {
                    let mut k = j + 1;
                    let mut seen = 0usize;
                    while k < n && b[k] == '#' && seen < hashes {
                        seen += 1;
                        k += 1;
                    }
                    if seen == hashes {
                        j = k;
                        break;
                    }
                }
                j += 1;
            }
            i = j;
            continue;
        }
        // -- plain / byte strings -------------------------------------
        if c == '"' || (c == 'b' && i + 1 < n && b[i + 1] == '"') {
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            while j < n {
                if b[j] == '\\' {
                    // `\<newline>` is a line-continuation escape: the
                    // skipped newline still advances the line counter
                    if j + 1 < n && b[j + 1] == '\n' {
                        line += 1;
                    }
                    j += 2;
                    continue;
                }
                bump_line!(b[j]);
                if b[j] == '"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            i = j;
            continue;
        }
        // -- char literal vs lifetime ---------------------------------
        if c == '\'' || (c == 'b' && i + 1 < n && b[i + 1] == '\'') {
            let q = if c == 'b' { i + 1 } else { i };
            // lifetime: 'ident NOT followed by a closing quote
            if b[q] == '\''
                && q + 1 < n
                && (b[q + 1].is_alphabetic() || b[q + 1] == '_')
                && !(q + 2 < n && b[q + 2] == '\'')
            {
                let mut j = q + 1;
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                i = j;
                continue;
            }
            // char literal: consume to closing quote, honoring escapes
            let mut j = q + 1;
            while j < n {
                if b[j] == '\\' {
                    j += 2;
                    continue;
                }
                if b[j] == '\'' {
                    j += 1;
                    break;
                }
                bump_line!(b[j]);
                j += 1;
            }
            i = j;
            continue;
        }
        // -- identifier / keyword -------------------------------------
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            out.tokens.push(Token {
                tok: Tok::Ident(b[start..i].iter().collect()),
                line,
            });
            continue;
        }
        // -- numeric literal ------------------------------------------
        if c.is_ascii_digit() {
            let start = i;
            let mut float = false;
            if c == '0' && i + 1 < n && matches!(b[i + 1], 'x' | 'o' | 'b') {
                i += 2;
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
            } else {
                while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                    i += 1;
                }
                // fraction: '.' only if followed by a digit (so `0..n`
                // and `1.max(2)` stay integers)
                if i + 1 < n && b[i] == '.' && b[i + 1].is_ascii_digit() {
                    float = true;
                    i += 1;
                    while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                        i += 1;
                    }
                } else if i + 1 < n && b[i] == '.' && !(b[i + 1].is_alphabetic() || b[i + 1] == '.' || b[i + 1] == '_')
                {
                    // trailing-dot float like `1.` (rare; not followed
                    // by ident/range)
                    float = true;
                    i += 1;
                }
                // exponent
                if i < n
                    && (b[i] == 'e' || b[i] == 'E')
                    && (i + 1 < n && (b[i + 1].is_ascii_digit() || b[i + 1] == '+' || b[i + 1] == '-'))
                {
                    float = true;
                    i += 1;
                    if b[i] == '+' || b[i] == '-' {
                        i += 1;
                    }
                    while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                        i += 1;
                    }
                }
                // suffix (f32/f64 force float; u32 etc. keep integer)
                if i < n && b[i].is_alphabetic() {
                    let s = i;
                    while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                        i += 1;
                    }
                    let suffix: String = b[s..i].iter().collect();
                    if suffix.starts_with('f') {
                        float = true;
                    }
                }
            }
            out.tokens.push(Token {
                tok: Tok::Num {
                    text: b[start..i].iter().collect(),
                    float,
                },
                line,
            });
            continue;
        }
        // -- punctuation ----------------------------------------------
        out.tokens.push(Token {
            tok: Tok::Punct(c),
            line,
        });
        i += 1;
    }
    out
}

/// True when position `i` starts a raw (byte) string: `r"`, `r#`,
/// `br"`, `br#` — and is not just an identifier beginning with r/b.
fn is_raw_string_start(b: &[char], i: usize) -> bool {
    let mut j = i;
    if b[j] == 'b' {
        j += 1;
        if j >= b.len() || b[j] != 'r' {
            return false;
        }
    }
    if b[j] != 'r' {
        return false;
    }
    j += 1;
    while j < b.len() && b[j] == '#' {
        j += 1;
    }
    j < b.len() && b[j] == '"'
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Ident(s) => Some(s.clone()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn strings_and_chars_are_dropped() {
        let src = "let s = \"HashMap inside a string\"; let c = 'x'; let l: &'static str = r#\"Instant::now\"#;";
        let ids = idents(src);
        assert!(ids.contains(&"let".to_string()));
        assert!(!ids.contains(&"HashMap".to_string()));
        assert!(!ids.contains(&"Instant".to_string()));
        // lifetime consumed without swallowing following tokens
        assert!(ids.contains(&"str".to_string()));
    }

    #[test]
    fn comments_recorded_with_lines() {
        let src = "// SAFETY: fine\nlet x = 1;\n/* multi\nline */\nlet y = 2;";
        let f = lex(src);
        assert_eq!(f.comments.len(), 3); // line comment + 2 block lines
        assert_eq!(f.comments[0].line, 1);
        assert!(f.comments[0].text.contains("SAFETY"));
        assert_eq!(f.comments[1].line, 3);
        assert_eq!(f.comments[2].line, 4);
        // tokens keep correct lines across the block comment
        let y = f
            .tokens
            .iter()
            .find(|t| matches!(&t.tok, Tok::Ident(s) if s == "y"))
            .unwrap();
        assert_eq!(y.line, 5);
    }

    #[test]
    fn string_line_continuation_keeps_line_numbers() {
        // `\<newline>` inside a string is an escape, but the physical
        // line still advances — later tokens must not drift
        let src = "let s = \"one \\\n two\";\nlet after = 1;";
        let f = lex(src);
        let after = f
            .tokens
            .iter()
            .find(|t| matches!(&t.tok, Tok::Ident(s) if s == "after"))
            .unwrap();
        assert_eq!(after.line, 3);
    }

    #[test]
    fn float_detection() {
        let cases = [
            ("1.0", true),
            ("1e9", true),
            ("2.5f32", true),
            ("3f64", true),
            ("42", false),
            ("0xff", false),
            ("1_000", false),
            ("7usize", false),
        ];
        for (src, want) in cases {
            let f = lex(src);
            match &f.tokens[0].tok {
                Tok::Num { float, .. } => assert_eq!(*float, want, "{src}"),
                t => panic!("{src}: {t:?}"),
            }
        }
    }

    #[test]
    fn range_is_not_a_float() {
        let f = lex("for i in 0..10 {}");
        let nums: Vec<bool> = f
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Num { float, .. } => Some(*float),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec![false, false]);
    }

    #[test]
    fn double_colon_is_two_puncts() {
        let f = lex("Instant::now()");
        assert_eq!(f.ident(0), "Instant");
        assert!(f.punct(1, ':') && f.punct(2, ':'));
        assert_eq!(f.ident(3), "now");
    }
}
