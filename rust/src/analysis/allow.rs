//! The `rust/lint_allow.toml` allowlist: every suppression is an
//! explicit, justified entry. The parser is a tiny line-based TOML
//! subset (the vendored-only build has no toml crate) accepting
//! exactly the shape the allowlist uses:
//!
//! ```toml
//! # full-line comments only
//! [[allow]]
//! rule = "D2"                      # required, must be a known rule id
//! file = "src/model/host.rs"       # required, path relative to rust/
//! pattern = ".sum::<f32>()"        # optional substring of the flagged line
//! max = 4                          # optional cap (pattern entries only)
//! why = "a written justification"  # required, >= 20 chars
//! ```
//!
//! Matching semantics (see [`crate::analysis::report`]):
//! - a pattern entry absorbs up to `max` (default 1) violations whose
//!   source line contains the substring;
//! - a file entry (no pattern) absorbs every violation of that rule in
//!   that file — for whole-module exemptions like `util/timer.rs`;
//! - an entry that absorbs *zero* violations is **stale** and fails
//!   the lint, so the allowlist can never rot ahead of the code.

use crate::analysis::rules::CATALOG;
use crate::Result;

/// One parsed `[[allow]]` entry.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: String,
    pub file: String,
    pub pattern: Option<String>,
    /// Max violations this entry may absorb; `None` = unlimited
    /// (file-scope entries). Pattern entries default to 1.
    pub max: Option<usize>,
    pub why: String,
    /// 1-based line of the `[[allow]]` header (for diagnostics).
    pub line: usize,
}

impl AllowEntry {
    /// Does this entry cover the given violation?
    pub fn covers(&self, rule: &str, rel: &str, snippet: &str) -> bool {
        self.rule == rule
            && self.file == rel
            && match &self.pattern {
                Some(p) => snippet.contains(p.as_str()),
                None => true,
            }
    }

    /// Absorption cap (usize::MAX for file-scope entries).
    pub fn cap(&self) -> usize {
        match (&self.pattern, self.max) {
            (_, Some(m)) => m,
            (Some(_), None) => 1,
            (None, None) => usize::MAX,
        }
    }
}

/// Parse the allowlist text. Errors carry the offending line number.
pub fn parse(text: &str) -> Result<Vec<AllowEntry>> {
    let mut entries: Vec<AllowEntry> = Vec::new();
    let mut cur: Option<AllowEntry> = None;

    for (i, raw) in text.lines().enumerate() {
        let lno = i + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[allow]]" {
            if let Some(e) = cur.take() {
                finish(&mut entries, e)?;
            }
            cur = Some(AllowEntry {
                rule: String::new(),
                file: String::new(),
                pattern: None,
                max: None,
                why: String::new(),
                line: lno,
            });
            continue;
        }
        let (key, val) = match line.split_once('=') {
            Some((k, v)) => (k.trim(), v.trim()),
            None => anyhow::bail!("lint_allow.toml:{lno}: expected `key = value`, got `{line}`"),
        };
        let e = match cur.as_mut() {
            Some(e) => e,
            None => anyhow::bail!("lint_allow.toml:{lno}: `{key}` before any [[allow]] header"),
        };
        match key {
            "rule" => e.rule = unquote(val, lno)?,
            "file" => e.file = unquote(val, lno)?,
            "pattern" => e.pattern = Some(unquote(val, lno)?),
            "why" => e.why = unquote(val, lno)?,
            "max" => {
                e.max = Some(val.parse().map_err(|_| {
                    anyhow::anyhow!("lint_allow.toml:{lno}: max must be an integer, got `{val}`")
                })?)
            }
            other => anyhow::bail!("lint_allow.toml:{lno}: unknown key `{other}`"),
        }
    }
    if let Some(e) = cur.take() {
        finish(&mut entries, e)?;
    }
    Ok(entries)
}

fn finish(entries: &mut Vec<AllowEntry>, e: AllowEntry) -> Result<()> {
    let lno = e.line;
    if !CATALOG.iter().any(|(id, _)| *id == e.rule) {
        anyhow::bail!(
            "lint_allow.toml:{lno}: unknown rule `{}` (known: {})",
            e.rule,
            CATALOG
                .iter()
                .map(|(id, _)| *id)
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    if e.file.is_empty() {
        anyhow::bail!("lint_allow.toml:{lno}: entry is missing `file`");
    }
    if e.why.trim().len() < 20 {
        anyhow::bail!(
            "lint_allow.toml:{lno}: `why` must be a real justification (>= 20 chars), got `{}`",
            e.why
        );
    }
    if e.max.is_some() && e.pattern.is_none() {
        anyhow::bail!("lint_allow.toml:{lno}: `max` requires a `pattern`");
    }
    entries.push(e);
    Ok(())
}

/// Strip a double-quoted TOML string (supports `\"` and `\\` escapes).
fn unquote(v: &str, lno: usize) -> Result<String> {
    let inner = v
        .strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .ok_or_else(|| anyhow::anyhow!("lint_allow.toml:{lno}: expected a quoted string, got `{v}`"))?;
    let mut out = String::with_capacity(inner.len());
    let mut chars = inner.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_pattern_and_file_entries() {
        let text = r#"
# comment
[[allow]]
rule = "D2"
file = "src/model/host.rs"
pattern = ".sum::<f32>()"
max = 4
why = "sequential scalar reductions over a fixed iterator order"

[[allow]]
rule = "D3"
file = "src/util/timer.rs"
why = "the timer module exists to measure wall time; it never feeds tokens"
"#;
        let es = parse(text).unwrap();
        assert_eq!(es.len(), 2);
        assert_eq!(es[0].rule, "D2");
        assert_eq!(es[0].cap(), 4);
        assert!(es[0].covers("D2", "src/model/host.rs", "let s = x.iter().sum::<f32>();"));
        assert!(!es[0].covers("D2", "src/model/host.rs", "x.iter().sum::<f64>()"));
        assert!(!es[0].covers("D2", "src/other.rs", ".sum::<f32>()"));
        assert_eq!(es[1].cap(), usize::MAX);
        assert!(es[1].covers("D3", "src/util/timer.rs", "anything at all"));
    }

    #[test]
    fn rejects_unknown_rule_missing_why_and_bare_max() {
        let bad_rule = "[[allow]]\nrule = \"Z9\"\nfile = \"src/x.rs\"\nwhy = \"a long enough justification here\"\n";
        assert!(parse(bad_rule).is_err());

        let short_why = "[[allow]]\nrule = \"D1\"\nfile = \"src/x.rs\"\nwhy = \"because\"\n";
        assert!(parse(short_why).is_err());

        let bare_max = "[[allow]]\nrule = \"D1\"\nfile = \"src/x.rs\"\nmax = 2\nwhy = \"a long enough justification here\"\n";
        assert!(parse(bare_max).is_err());

        let no_header = "rule = \"D1\"\n";
        assert!(parse(no_header).is_err());
    }

    #[test]
    fn pattern_default_cap_is_one() {
        let text = "[[allow]]\nrule = \"R1\"\nfile = \"src/serve/engine.rs\"\npattern = \".expect(\"\nwhy = \"documented loud-panic contract with tests\"\n";
        let es = parse(text).unwrap();
        assert_eq!(es[0].cap(), 1);
    }
}
