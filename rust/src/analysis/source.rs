//! Crate-walking and per-file source model for `fasp lint`.
//!
//! A [`SourceFile`] bundles the raw lines (for span-accurate snippets
//! and allowlist pattern matching), the lexed token stream, and a
//! per-line "inside `#[cfg(test)]`" mask. The determinism/robustness
//! rules (D1/D2/D3/R1/P1) skip test regions — tests deliberately
//! assert panics and use whatever containers are convenient — while
//! U1 (`// SAFETY:` on `unsafe`) applies everywhere.

use crate::analysis::lexer::{self, LexedFile, Tok};
use crate::Result;
use std::path::Path;

/// One analyzed source file.
pub struct SourceFile {
    /// Path relative to `rust/`, forward slashes: `"src/model/host.rs"`.
    pub rel: String,
    /// Raw source lines (index 0 = line 1).
    pub lines: Vec<String>,
    /// Token stream + comments.
    pub lexed: LexedFile,
    /// `test_lines[l]` (1-based; index 0 unused) — line is inside a
    /// `#[cfg(test)]` item.
    pub test_lines: Vec<bool>,
}

impl SourceFile {
    /// Build from in-memory source — the constructor the fixture
    /// self-tests use (`rel` controls path-scoped rules like R1).
    pub fn synthetic(rel: &str, src: &str) -> SourceFile {
        let lines: Vec<String> = src.lines().map(|l| l.to_string()).collect();
        let lexed = lexer::lex(src);
        let test_lines = mark_test_regions(&lexed, lines.len());
        SourceFile {
            rel: rel.to_string(),
            lines,
            lexed,
            test_lines,
        }
    }

    /// Trimmed text of 1-based line `l` (empty if out of range).
    pub fn line(&self, l: usize) -> &str {
        self.lines
            .get(l.wrapping_sub(1))
            .map(|s| s.trim())
            .unwrap_or("")
    }

    /// Is 1-based line `l` inside a `#[cfg(test)]` region?
    pub fn in_test(&self, l: usize) -> bool {
        *self.test_lines.get(l).unwrap_or(&false)
    }
}

/// Recursively collect every `.rs` file under `src_dir` (sorted by
/// path, so diagnostics and reports are stable run to run).
pub fn scan_crate(src_dir: &Path) -> Result<Vec<SourceFile>> {
    let mut paths = Vec::new();
    collect_rs(src_dir, &mut paths)?;
    paths.sort();
    let mut out = Vec::with_capacity(paths.len());
    for p in &paths {
        let src = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("lint: read {}: {e}", p.display()))?;
        let rel = match p.strip_prefix(src_dir.parent().unwrap_or(src_dir)) {
            Ok(r) => r.to_string_lossy().replace('\\', "/"),
            Err(_) => p.to_string_lossy().replace('\\', "/"),
        };
        out.push(SourceFile::synthetic(&rel, &src));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    let rd = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("lint: read_dir {}: {e}", dir.display()))?;
    for entry in rd {
        let entry = entry.map_err(|e| anyhow::anyhow!("lint: read_dir entry: {e}"))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// Walk the token stream looking for `#[cfg(test)]` attributes and
/// mark the lines of the item they gate (through its matching closing
/// brace, or the terminating `;` for brace-less items).
fn mark_test_regions(lexed: &LexedFile, n_lines: usize) -> Vec<bool> {
    let toks = &lexed.tokens;
    let mut mask = vec![false; n_lines + 2];
    let mut i = 0usize;
    while i < toks.len() {
        if is_cfg_test_attr(lexed, i) {
            let start_line = toks[i].line;
            // skip this attribute and any stacked ones after it
            let mut j = skip_attr(lexed, i);
            while lexed.punct(j, '#') {
                j = skip_attr(lexed, j);
            }
            // find the item body: first `{` before a top-level `;`
            let mut end_line = start_line;
            let mut k = j;
            let mut found = false;
            while k < toks.len() {
                match &toks[k].tok {
                    Tok::Punct('{') => {
                        let close = match_brace(lexed, k);
                        end_line = toks.get(close).map(|t| t.line).unwrap_or(n_lines);
                        i = close + 1;
                        found = true;
                        break;
                    }
                    Tok::Punct(';') => {
                        end_line = toks[k].line;
                        i = k + 1;
                        found = true;
                        break;
                    }
                    _ => k += 1,
                }
            }
            if !found {
                i = toks.len();
                end_line = n_lines;
            }
            for l in start_line..=end_line.min(n_lines) {
                mask[l] = true;
            }
            continue;
        }
        i += 1;
    }
    mask
}

/// Token `i` starts `#[cfg(test)]` (or `#[cfg(all(test, ...))]` —
/// any attribute whose text contains the `cfg` + `test` idents).
fn is_cfg_test_attr(lexed: &LexedFile, i: usize) -> bool {
    if !lexed.punct(i, '#') || !lexed.punct(i + 1, '[') {
        return false;
    }
    if lexed.ident(i + 2) != "cfg" {
        return false;
    }
    let end = skip_attr(lexed, i);
    (i + 3..end).any(|k| lexed.ident(k) == "test")
}

/// Given token index `i` at the `#` of an attribute, return the index
/// just past its closing `]`.
fn skip_attr(lexed: &LexedFile, i: usize) -> usize {
    let toks = &lexed.tokens;
    let mut k = i + 1; // at '['
    if !lexed.punct(k, '[') {
        return i + 1;
    }
    let mut depth = 0usize;
    while k < toks.len() {
        match &toks[k].tok {
            Tok::Punct('[') => depth += 1,
            Tok::Punct(']') => {
                depth -= 1;
                if depth == 0 {
                    return k + 1;
                }
            }
            _ => {}
        }
        k += 1;
    }
    toks.len()
}

/// Given token index `open` at a `{`, return the index of its
/// matching `}` (or the last token when unbalanced).
fn match_brace(lexed: &LexedFile, open: usize) -> usize {
    let toks = &lexed.tokens;
    let mut depth = 0usize;
    let mut k = open;
    while k < toks.len() {
        match &toks[k].tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth -= 1;
                if depth == 0 {
                    return k;
                }
            }
            _ => {}
        }
        k += 1;
    }
    toks.len().saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_mod_region_is_masked() {
        let src = "\
pub fn live() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t() {
        let m = std::collections::HashMap::<u32, u32>::new();
        assert!(m.is_empty());
    }
}

pub fn also_live() {}
";
        let f = SourceFile::synthetic("src/x.rs", src);
        assert!(!f.in_test(1));
        assert!(f.in_test(3)); // the attribute line itself
        assert!(f.in_test(9)); // HashMap inside the test mod
        assert!(f.in_test(12)); // closing brace
        assert!(!f.in_test(14)); // code after the mod
    }

    #[test]
    fn braceless_cfg_test_item() {
        let src = "#[cfg(test)]\nuse std::collections::HashMap;\npub fn live() {}\n";
        let f = SourceFile::synthetic("src/x.rs", src);
        assert!(f.in_test(1));
        assert!(f.in_test(2));
        assert!(!f.in_test(3));
    }

    #[test]
    fn stacked_attributes() {
        let src = "#[cfg(test)]\n#[allow(dead_code)]\nfn helper() {\n    let x = 1;\n}\nfn live() {}\n";
        let f = SourceFile::synthetic("src/x.rs", src);
        assert!(f.in_test(4));
        assert!(!f.in_test(6));
    }
}
