//! The `fasp lint` rule catalog. Every rule has a stable ID, a
//! one-line description (shown in the report table), and a token-level
//! matcher over [`SourceFile`]s.
//!
//! Scope policy: rules scan `rust/src/**` only. Tests assert panics
//! and use ad-hoc containers by design, and benches are timers by
//! definition — the determinism contract is on shipped library code.
//! Within a scanned file, `#[cfg(test)]` regions are skipped by every
//! rule except U1 (`unsafe` needs a SAFETY comment even in tests).

use crate::analysis::lexer::Tok;
use crate::analysis::source::SourceFile;

/// (id, description) — the order here is the report order.
pub const CATALOG: &[(&str, &str)] = &[
    (
        "D1",
        "HashMap/HashSet in library code: iteration order is nondeterministic; use BTreeMap/BTreeSet",
    ),
    (
        "D2",
        "unordered float reduction (.sum::<f32/f64>(), fold over floats) outside tensor/matmul.rs lane_accum",
    ),
    (
        "D3",
        "wall-clock / address-derived value (Instant::now, SystemTime, ptr-as-int) in library code",
    ),
    (
        "U1",
        "unsafe block without a // SAFETY: comment on the preceding line(s)",
    ),
    (
        "R1",
        "unwrap/expect/panic in a request path (serve/, fault/, model/kv_arena.rs, model/decode.rs, model/spec_decode.rs, runtime/store.rs)",
    ),
    (
        "P1",
        "hand-rolled threads/channels outside util/pool.rs: fan-out must use Pool::{map,run_rows1,run_rows2}",
    ),
];

/// One diagnostic: rule, file, 1-based line, the offending source line.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    pub rel: String,
    pub line: usize,
    pub snippet: String,
}

impl Violation {
    fn new(rule: &'static str, f: &SourceFile, line: usize) -> Violation {
        Violation {
            rule,
            rel: f.rel.clone(),
            line,
            snippet: f.line(line).to_string(),
        }
    }
}

/// Files where R1 (no panics in request paths) applies.
fn r1_scope(rel: &str) -> bool {
    rel.starts_with("src/serve/")
        || rel.starts_with("src/fault/")
        || rel == "src/model/kv_arena.rs"
        || rel == "src/model/decode.rs"
        || rel == "src/model/spec_decode.rs"
        || rel == "src/runtime/store.rs"
}

/// The canonical reduction home: D2 never fires here.
const D2_HOME: &str = "src/tensor/matmul.rs";
/// The pool implementation itself: P1 never fires here.
const P1_HOME: &str = "src/util/pool.rs";

/// Run every rule over one file.
pub fn check_file(f: &SourceFile) -> Vec<Violation> {
    let mut out = Vec::new();
    let toks = &f.lexed.tokens;

    // Dedup guard: at most one violation per (rule, line) so a line
    // like `a.sum::<f32>() + b.sum::<f32>()` reads as one finding.
    let mut push = {
        let mut seen: Vec<(&'static str, usize)> = Vec::new();
        move |out: &mut Vec<Violation>, rule: &'static str, line: usize, f: &SourceFile| {
            if !seen.contains(&(rule, line)) {
                seen.push((rule, line));
                out.push(Violation::new(rule, f, line));
            }
        }
    };

    for i in 0..toks.len() {
        let line = toks[i].line;
        let in_test = f.in_test(line);

        // ---- U1: unsafe needs an adjacent SAFETY comment (everywhere).
        // Accepted: a comment on the `unsafe` line itself, or anywhere
        // in the contiguous comment block ending on the line above
        // (multi-line SAFETY explanations put the keyword first).
        if f.lexed.ident(i) == "unsafe" {
            let at = |l: usize, needle: &str| {
                f.lexed
                    .comments
                    .iter()
                    .any(|c| c.line == l && c.text.contains(needle))
            };
            let has_comment = |l: usize| f.lexed.comments.iter().any(|c| c.line == l);
            let mut ok = at(line, "SAFETY");
            let mut l = line;
            while !ok && l > 1 && has_comment(l - 1) {
                l -= 1;
                ok = at(l, "SAFETY");
            }
            if !ok {
                push(&mut out, "U1", line, f);
            }
        }

        if in_test {
            continue;
        }

        // ---- D1: HashMap / HashSet --------------------------------
        match f.lexed.ident(i) {
            "HashMap" | "HashSet" => push(&mut out, "D1", line, f),
            _ => {}
        }

        // ---- D2: unordered float reductions -----------------------
        if f.rel != D2_HOME {
            // `.sum::<f32>()` / `.sum::<f64>()`
            if f.lexed.punct(i, '.')
                && f.lexed.ident(i + 1) == "sum"
                && f.lexed.punct(i + 2, ':')
                && f.lexed.punct(i + 3, ':')
                && f.lexed.punct(i + 4, '<')
                && matches!(f.lexed.ident(i + 5), "f32" | "f64")
            {
                push(&mut out, "D2", line, f);
            }
            // `.fold(<first arg mentioning floats>, ...)`
            if f.lexed.punct(i, '.') && f.lexed.ident(i + 1) == "fold" && f.lexed.punct(i + 2, '(')
            {
                if fold_init_is_float(f, i + 2) {
                    push(&mut out, "D2", line, f);
                }
            }
        }

        // ---- D3: wall clock / address-derived ---------------------
        if f.lexed.ident(i) == "Instant"
            && f.lexed.punct(i + 1, ':')
            && f.lexed.punct(i + 2, ':')
            && f.lexed.ident(i + 3) == "now"
        {
            push(&mut out, "D3", line, f);
        }
        if f.lexed.ident(i) == "SystemTime" {
            push(&mut out, "D3", line, f);
        }
        // `x.as_ptr() as usize/u64/...` — a pointer laundered into a value
        if f.lexed.ident(i) == "as_ptr"
            && f.lexed.punct(i + 1, '(')
            && f.lexed.punct(i + 2, ')')
            && f.lexed.ident(i + 3) == "as"
            && matches!(f.lexed.ident(i + 4), "usize" | "u64" | "u32" | "i64" | "isize")
        {
            push(&mut out, "D3", line, f);
        }

        // ---- R1: panics in request paths --------------------------
        if r1_scope(&f.rel) {
            if f.lexed.punct(i, '.')
                && matches!(f.lexed.ident(i + 1), "unwrap" | "expect")
                && f.lexed.punct(i + 2, '(')
            {
                push(&mut out, "R1", line, f);
            }
            if matches!(
                f.lexed.ident(i),
                "panic" | "unreachable" | "todo" | "unimplemented"
            ) && f.lexed.punct(i + 1, '!')
            {
                push(&mut out, "R1", line, f);
            }
        }

        // ---- P1: hand-rolled threading ----------------------------
        if f.rel != P1_HOME {
            if f.lexed.ident(i) == "thread"
                && f.lexed.punct(i + 1, ':')
                && f.lexed.punct(i + 2, ':')
                && matches!(f.lexed.ident(i + 3), "spawn" | "scope")
            {
                push(&mut out, "P1", line, f);
            }
            if f.lexed.ident(i) == "mpsc" {
                push(&mut out, "P1", line, f);
            }
        }
    }
    out
}

/// For a `.fold(` at token index `open` (the `(`): does the *first
/// argument* (tokens up to the matching top-level `,` or `)`) mention
/// a float — a float literal, or an `f32`/`f64` path? Catches
/// `fold(0.0, ...)`, `fold(f32::NEG_INFINITY, ...)` and
/// `fold((f64::INFINITY, f64::NEG_INFINITY), ...)` while ignoring
/// integer/`Vec` folds.
fn fold_init_is_float(f: &SourceFile, open: usize) -> bool {
    let toks = &f.lexed.tokens;
    let mut depth = 0usize;
    let mut k = open;
    while k < toks.len() {
        match &toks[k].tok {
            Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => depth += 1,
            Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => {
                if depth <= 1 {
                    return false; // end of args before any float
                }
                depth -= 1;
            }
            Tok::Punct(',') if depth == 1 => return false, // first arg done
            Tok::Num { float: true, .. } => return true,
            Tok::Ident(s) if s == "f32" || s == "f64" => return true,
            _ => {}
        }
        k += 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(rel: &str, src: &str) -> Vec<Violation> {
        check_file(&SourceFile::synthetic(rel, src))
    }

    fn rules(v: &[Violation]) -> Vec<&'static str> {
        v.iter().map(|x| x.rule).collect()
    }

    // ---- D1 -------------------------------------------------------
    #[test]
    fn d1_fires_on_hashmap_and_not_on_btreemap() {
        let bad = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, u32> = HashMap::new(); }\n";
        let got = lint("src/x.rs", bad);
        assert!(got.iter().all(|v| v.rule == "D1"));
        assert_eq!(got.len(), 2); // the use line + the fn line (deduped per line)
        assert_eq!(got[0].line, 1);

        let clean = "use std::collections::BTreeMap;\nfn f() { let m: BTreeMap<u32, u32> = BTreeMap::new(); }\n";
        assert!(lint("src/x.rs", clean).is_empty());
    }

    #[test]
    fn d1_skips_test_regions_and_strings() {
        let src = "fn f() { let s = \"HashMap\"; }\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn t() { let _m: HashMap<u8, u8> = HashMap::new(); }\n}\n";
        assert!(lint("src/x.rs", src).is_empty());
    }

    // ---- D2 -------------------------------------------------------
    #[test]
    fn d2_fires_on_float_sum_and_float_fold() {
        let bad = "fn f(v: &[f32]) -> f32 { v.iter().sum::<f32>() }\n";
        assert_eq!(rules(&lint("src/x.rs", bad)), vec!["D2"]);

        let bad64 = "fn f(v: &[f64]) -> f64 { v.iter().copied().sum::<f64>() }\n";
        assert_eq!(rules(&lint("src/x.rs", bad64)), vec!["D2"]);

        let fold = "fn f(v: &[f32]) -> f32 { v.iter().fold(0.0f32, |a, &b| a + b) }\n";
        assert_eq!(rules(&lint("src/x.rs", fold)), vec!["D2"]);

        let fold_inf = "fn f(v: &[f32]) -> f32 { v.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) }\n";
        assert_eq!(rules(&lint("src/x.rs", fold_inf)), vec!["D2"]);
    }

    #[test]
    fn d2_silent_on_int_reductions_and_in_matmul_home() {
        let ints = "fn f(v: &[usize]) -> usize { v.iter().sum::<usize>() + v.iter().fold(0, |a, &b| a + b) }\n";
        assert!(lint("src/x.rs", ints).is_empty());

        let vec_fold = "fn f(v: &[u32]) -> Vec<u32> { v.iter().fold(Vec::new(), |mut a, &b| { a.push(b); a }) }\n";
        assert!(lint("src/x.rs", vec_fold).is_empty());

        let home = "fn lane_accum(v: &[f32]) -> f32 { v.iter().sum::<f32>() }\n";
        assert!(lint("src/tensor/matmul.rs", home).is_empty());
    }

    // ---- D3 -------------------------------------------------------
    #[test]
    fn d3_fires_on_wall_clock_and_ptr_as_int() {
        let t = "fn f() { let t0 = std::time::Instant::now(); let _ = t0; }\n";
        assert_eq!(rules(&lint("src/x.rs", t)), vec!["D3"]);

        let st = "fn f() -> std::time::SystemTime { std::time::SystemTime::now() }\n";
        assert_eq!(rules(&lint("src/x.rs", st)), vec!["D3"]);

        let ptr = "fn f(v: &[u8]) -> usize { v.as_ptr() as usize }\n";
        assert_eq!(rules(&lint("src/x.rs", ptr)), vec!["D3"]);
    }

    #[test]
    fn d3_silent_on_duration_math_and_tests() {
        let clean = "fn f(d: std::time::Duration) -> f64 { d.as_secs_f64() }\n";
        assert!(lint("src/x.rs", clean).is_empty());
        let test = "#[cfg(test)]\nmod tests {\n    fn t() { let _ = std::time::Instant::now(); }\n}\n";
        assert!(lint("src/x.rs", test).is_empty());
    }

    // ---- U1 -------------------------------------------------------
    #[test]
    fn u1_fires_without_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert_eq!(rules(&lint("src/x.rs", bad)), vec!["U1"]);
    }

    #[test]
    fn u1_accepts_line_and_block_safety_comments() {
        let line = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid\n    unsafe { *p }\n}\n";
        assert!(lint("src/x.rs", line).is_empty());
        let wrapped = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p points into a live\n    // allocation of at least one byte\n    unsafe { *p }\n}\n";
        assert!(lint("src/x.rs", wrapped).is_empty());
        let block = "fn f(p: *const u8) -> u8 {\n    /* SAFETY: caller guarantees p is valid */\n    unsafe { *p }\n}\n";
        assert!(lint("src/x.rs", block).is_empty());
    }

    #[test]
    fn u1_accepts_long_contiguous_block_and_rejects_detached_comment() {
        let long = "fn f(p: *const u8) -> u8 {\n    // SAFETY: a long explanation whose\n    // keyword sits on the first of\n    // five contiguous comment lines\n    // well above the three-line\n    // window a naive rule would use\n    unsafe { *p }\n}\n";
        assert!(lint("src/x.rs", long).is_empty());
        // a blank line detaches the comment block — no longer adjacent
        let detached = "fn f(p: *const u8) -> u8 {\n    // SAFETY: stale note\n\n    unsafe { *p }\n}\n";
        assert_eq!(rules(&lint("src/x.rs", detached)), vec!["U1"]);
    }

    #[test]
    fn u1_applies_inside_test_code_too() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t(p: *const u8) -> u8 {\n        unsafe { *p }\n    }\n}\n";
        assert_eq!(rules(&lint("src/x.rs", src)), vec!["U1"]);
    }

    // ---- R1 -------------------------------------------------------
    #[test]
    fn r1_fires_only_in_request_paths() {
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert_eq!(rules(&lint("src/serve/engine.rs", bad)), vec!["R1"]);
        assert_eq!(rules(&lint("src/fault/mod.rs", bad)), vec!["R1"]);
        assert_eq!(rules(&lint("src/runtime/store.rs", bad)), vec!["R1"]);
        assert_eq!(rules(&lint("src/model/decode.rs", bad)), vec!["R1"]);
        assert!(lint("src/prune/metric.rs", bad).is_empty()); // out of scope

        let exp = "fn f(x: Option<u32>) -> u32 { x.expect(\"msg\") }\n";
        assert_eq!(rules(&lint("src/model/kv_arena.rs", exp)), vec!["R1"]);

        let pan = "fn f() { panic!(\"boom\"); }\n";
        assert_eq!(rules(&lint("src/serve/prefix.rs", pan)), vec!["R1"]);

        let unr = "fn f() { unreachable!(); }\n";
        assert_eq!(rules(&lint("src/serve/engine.rs", unr)), vec!["R1"]);
    }

    #[test]
    fn r1_silent_on_unwrap_or_and_test_code() {
        let clean = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0).max(x.unwrap_or_default()) }\n";
        assert!(lint("src/serve/engine.rs", clean).is_empty());
        let test = "#[cfg(test)]\nmod tests {\n    fn t(x: Option<u32>) -> u32 { x.unwrap() }\n}\n";
        assert!(lint("src/serve/engine.rs", test).is_empty());
    }

    // ---- P1 -------------------------------------------------------
    #[test]
    fn p1_fires_on_spawn_scope_and_mpsc() {
        let sp = "fn f() { std::thread::spawn(|| {}); }\n";
        assert_eq!(rules(&lint("src/x.rs", sp)), vec!["P1"]);
        let sc = "fn f() { std::thread::scope(|_| {}); }\n";
        assert_eq!(rules(&lint("src/x.rs", sc)), vec!["P1"]);
        let ch = "use std::sync::mpsc;\nfn f() { let (_tx, _rx) = mpsc::channel::<u32>(); }\n";
        assert_eq!(rules(&lint("src/x.rs", ch)), vec!["P1", "P1"]);
    }

    #[test]
    fn p1_silent_in_pool_home_and_on_sleep() {
        let home = "fn f() { std::thread::spawn(|| {}); }\n";
        assert!(lint("src/util/pool.rs", home).is_empty());
        let sleep = "fn f() { std::thread::sleep(std::time::Duration::from_micros(1)); }\n";
        assert!(lint("src/x.rs", sleep).is_empty());
    }
}
