//! Zero-shot likelihood-ranking evaluation (Table 3 substitution).
//!
//! Every (prompt, candidate) pair becomes one row of a [B, T] batch for
//! the `fwd_loss` artifact; the candidate span's summed NLL (extracted
//! from the per-token NLL output with the standard shift: position i
//! predicts token i+1) ranks the choices, lm-eval-harness style.

use crate::data::tasks::TaskSuite;
use crate::model::Weights;
use crate::runtime::Session;
use crate::tensor::IntTensor;
use anyhow::Result;

/// One scored row: which task, which choice, candidate span in the row.
struct RowRef {
    task: usize,
    choice: usize,
    span: (usize, usize), // [start, end) in tok_nll position space
}

pub struct SuiteResult {
    pub kind: &'static str,
    pub accuracy: f64,
    pub n: usize,
}

/// Evaluate one suite. Packs rows densely into fixed [B, T] batches.
pub fn eval_suite(
    session: &Session,
    weights: &Weights,
    suite: &TaskSuite,
) -> Result<SuiteResult> {
    let b = session.spec.batch;
    let t = session.spec.seq;

    // Build all rows.
    let mut rows: Vec<(Vec<i32>, RowRef)> = Vec::new();
    for (ti, task) in suite.tasks.iter().enumerate() {
        for (ci, choice) in task.choices.iter().enumerate() {
            let mut toks = task.prompt.clone();
            let plen = toks.len();
            toks.extend_from_slice(choice);
            let clen = choice.len();
            anyhow::ensure!(toks.len() < t, "row longer than artifact seq");
            toks.resize(t, 0);
            rows.push((
                toks,
                RowRef { task: ti, choice: ci, span: (plen - 1, plen - 1 + clen) },
            ));
        }
    }

    // Score rows batch by batch; tail batch padded with row 0.
    let params = session.pack(&weights.packed)?; // pack once
    let mut nll_per_row: Vec<f64> = vec![0.0; rows.len()];
    let mut idx = 0usize;
    while idx < rows.len() {
        let mut tokens = Vec::with_capacity(b * t);
        let mut targets = Vec::with_capacity(b * t);
        let mut live = Vec::with_capacity(b);
        for r in 0..b {
            let row = if idx + r < rows.len() {
                live.push(idx + r);
                &rows[idx + r].0
            } else {
                &rows[0].0
            };
            tokens.extend_from_slice(row);
            // shifted targets within the row; last target is a dummy 0
            targets.extend_from_slice(&row[1..]);
            targets.push(0);
        }
        let toks = IntTensor::new(vec![b, t], tokens);
        let tgts = IntTensor::new(vec![b, t], targets);
        let out = session.fwd_loss(&params, &toks, &tgts)?;
        for (r, &row_idx) in live.iter().enumerate() {
            let (s, e) = rows[row_idx].1.span;
            let mut sum = 0.0f64;
            for p in s..e {
                sum += out.tok_nll.data[r * t + p] as f64;
            }
            nll_per_row[row_idx] = sum;
        }
        idx += b;
    }

    // Rank per task.
    let mut correct = 0usize;
    for (ti, task) in suite.tasks.iter().enumerate() {
        let mut best = (f64::INFINITY, 0usize);
        for (row, rf) in rows.iter().map(|(_, rf)| rf).enumerate() {
            if rf.task == ti && nll_per_row[row] < best.0 {
                best = (nll_per_row[row], rf.choice);
            }
        }
        if best.1 == task.answer {
            correct += 1;
        }
    }
    Ok(SuiteResult {
        kind: suite.kind.label(),
        accuracy: 100.0 * correct as f64 / suite.tasks.len() as f64,
        n: suite.tasks.len(),
    })
}
