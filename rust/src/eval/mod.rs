//! Evaluation harness: teacher-forced perplexity (Tables 1–2, Figures
//! 3–4), zero-shot likelihood ranking (Table 3), and sliced-layer
//! latency (the structured-speedup claim).

pub mod perplexity;
pub mod zeroshot;
pub mod speed;

pub use perplexity::{perplexity, perplexity_as, perplexity_streamed};
pub use zeroshot::eval_suite;
