//! Teacher-forced perplexity over held-out batches via the `fwd_loss`
//! entry: PPL = exp(mean over all target tokens of NLL).

use crate::data::Batch;
use crate::model::Weights;
use crate::runtime::Session;
use crate::tensor::pack::Quant;
use anyhow::Result;

/// Perplexity of `weights` on the given batches (exact f32 panels).
pub fn perplexity(
    session: &Session,
    weights: &Weights,
    batches: &[Batch],
) -> Result<f64> {
    perplexity_as(session, weights, batches, Quant::F32)
}

/// [`perplexity`] with an explicit packed-panel dtype — `Quant::Int8`
/// evaluates the model through quantized panels (what a deployed int8
/// plan actually computes), so the int8-vs-f32 ppl delta the quant
/// experiment reports is measured on the real inference path.
pub fn perplexity_as(
    session: &Session,
    weights: &Weights,
    batches: &[Batch],
    quant: Quant,
) -> Result<f64> {
    anyhow::ensure!(!batches.is_empty(), "need at least one eval batch");
    let params = session.pack_as(&weights.packed, quant)?; // pack once
    let mut total = 0.0f64;
    let mut count = 0usize;
    for b in batches {
        let out = session.fwd_loss(&params, &b.tokens, &b.targets)?;
        total += out.mean_nll as f64 * b.tokens.numel() as f64;
        count += b.tokens.numel();
    }
    Ok((total / count as f64).exp())
}

/// Perplexity of a *sharded* compact model, streaming its weights layer
/// by layer (peak resident weights: embed/head shard + one layer shard
/// + the backend's prefetch buffer). The per-batch arithmetic is shared
/// with [`perplexity`], so the result is bit-identical to evaluating
/// the assembled monolithic weights.
pub fn perplexity_streamed(
    session: &Session,
    store: &crate::runtime::ShardedWeights,
    batches: &[Batch],
) -> Result<f64> {
    anyhow::ensure!(!batches.is_empty(), "need at least one eval batch");
    let mut total = 0.0f64;
    let mut count = 0usize;
    for b in batches {
        let out = session.fwd_loss_streamed(store, &b.tokens, &b.targets)?;
        total += out.mean_nll as f64 * b.tokens.numel() as f64;
        count += b.tokens.numel();
    }
    Ok((total / count as f64).exp())
}

/// Host-side fallback perplexity (no artifacts needed) — used by tests
/// as an independent cross-check of the session path.
pub fn perplexity_host(weights: &Weights, batches: &[Batch]) -> Result<f64> {
    use crate::model::host::forward_nll;
    let mut total = 0.0f64;
    let mut count = 0usize;
    for b in batches {
        let (nll, _) = forward_nll(weights, &b.tokens, &b.targets, false)?;
        total += nll.data.iter().map(|&x| x as f64).sum::<f64>();
        count += nll.numel();
    }
    Ok((total / count as f64).exp())
}
