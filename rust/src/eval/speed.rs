//! Structured-speedup measurements (the paper §1–2: structured pruning
//! yields hardware-agnostic inference speedups):
//!
//! * [`layer_latency_sweep`] — the physically sliced
//!   `latency_llama_small_s{pct}` single-layer artifacts, latency vs
//!   sparsity.
//! * [`compare_dense_compact`] — end-to-end model latency of a dense
//!   model vs its compact (physically repacked) export, through the same
//!   `fwd_loss` path perplexity uses. This is the receipt the compact
//!   artifact must produce: a genuinely smaller model that runs faster
//!   with no masks.
//! * [`compare_backends`] — the same forward on [`HostBackend`] vs
//!   [`ThreadedHostBackend`]: the threaded backend must be faster on
//!   multi-core while producing bit-identical outputs (the receipt the
//!   backend redesign must produce).
//! * [`compare_stream_eval`] — monolithic (assembled) vs shard-streaming
//!   `fwd_loss` of a sharded compact export: bit-identical NLL with peak
//!   resident weights of O(one layer + prefetch) instead of O(model)
//!   (the receipt the sharded store must produce).
//! * [`compare_decode`] — KV-cached autoregressive decode, dense vs
//!   compact on the same prompts, plus the naive O(prefix²) re-forward
//!   baseline: the compact model must decode faster per token with a
//!   strictly smaller resident KV cache (the receipt the OV slicing
//!   must produce at inference; `BENCH_decode.json`).
//! * [`compare_packed`] — the packed-operator-plan receipt
//!   (`BENCH_pack.json`): forward, prefill and per-token decode over
//!   `Session::pack`'s persistent pack cache vs the legacy per-call
//!   copy + transpose path, bit-identical outputs, and the
//!   pack/transpose counters proving the decode loop performs **zero**
//!   pack work after the session is built.
//! * [`compare_serve`] — the continuous-batching receipt
//!   (`BENCH_serve.json`): the serve engine driving N concurrent
//!   sessions over one shared plan vs N sequential `generate` calls —
//!   strictly higher throughput with **bit-identical** per-session
//!   tokens, plus p50/p99 per-token latency and arena page residency.
//! * [`compare_chaos`] / [`chaos_shard_probe`] — the
//!   graceful-degradation receipt (`BENCH_chaos.json`): the same serve
//!   load fault-free vs under a seeded `fault::FaultPlan` — survivors
//!   bit-identical, faulted sessions per-session errors, zero leaked
//!   pages, bit-identical replay — plus the shard-path probe (one-shot
//!   corruption absorbed by bounded re-reads, persistent truncation a
//!   proper `Err`).
//! * [`compare_speculative`] — the speculative-decoding receipt
//!   (`BENCH_spec.json`): target-only greedy `generate` vs
//!   draft-propose/target-verify with compact exports at several
//!   sparsities as drafts — tokens/sec, acceptance rate per draft
//!   sparsity, draft+target resident KV bytes, and per-point greedy
//!   bit-identity. Timing wraps whole calls out here because
//!   `model/spec_decode.rs` is wall-clock-free by contract (D3).

use crate::data::{Batch, Corpus, Dataset};
use crate::model::decode::{self, full_logits, sample_row, GenerateOpts, Sampler};
use crate::model::spec_decode::SpecOpts;
use crate::model::host;
use crate::model::weights::DenseParams;
use crate::model::Weights;
use crate::runtime::executable::{Artifact, In};
use crate::runtime::{HostBackend, Manifest, Session, ThreadedHostBackend};
use crate::tensor::{matmul, pack};
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

pub struct LatencyPoint {
    pub sparsity: f64,
    pub f_s: usize,
    pub dk_s: usize,
    pub mean_ms: f64,
    pub speedup: f64,
}

/// Measure each sliced-layer artifact; `reps` timed runs after 2 warmups.
pub fn layer_latency_sweep(manifest: &Manifest, reps: usize) -> Result<Vec<LatencyPoint>> {
    let mut names: Vec<&String> = manifest.latency.keys().collect();
    names.sort();
    let mut points = Vec::new();
    let mut base_ms = None;
    let mut rng = Rng::new(123);
    for name in names {
        let meta = &manifest.latency[name];
        let art = Artifact::load(manifest, name)?;
        // random inputs with the right sliced shapes
        let inputs: Vec<Tensor> = art
            .spec
            .inputs
            .iter()
            .map(|io| Tensor::randn(&io.shape, 0.05, &mut rng))
            .collect();
        let ins: Vec<In> = inputs.iter().map(In::F).collect();
        for _ in 0..2 {
            art.call(&ins)?;
        }
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            art.call(&ins)?;
        }
        let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let base = *base_ms.get_or_insert(mean_ms);
        points.push(LatencyPoint {
            sparsity: meta.sparsity,
            f_s: meta.f_s,
            dk_s: meta.dk_s,
            mean_ms,
            speedup: base / mean_ms,
        });
    }
    Ok(points)
}

/// Dense-vs-compact end-to-end latency comparison.
pub struct CompactCompare {
    pub dense_ms: f64,
    pub compact_ms: f64,
    pub speedup: f64,
}

/// Best-of-`reps` wall-clock of one `fwd_loss` call (params packed once,
/// like the perplexity loop). Min-of-reps is robust to scheduler noise
/// on small testbeds.
fn time_fwd(session: &Session, w: &Weights, batch: &Batch, reps: usize) -> Result<f64> {
    let params = session.pack(&w.packed)?;
    session.fwd_loss(&params, &batch.tokens, &batch.targets)?; // warmup
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        session.fwd_loss(&params, &batch.tokens, &batch.targets)?;
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(best)
}

/// Measure a dense model against its compact export on identical token
/// batches. Both models must be registered in the manifest (the compact
/// one via its `compact/` artifact or `Manifest::register_compact`).
pub fn compare_dense_compact(
    manifest: &Manifest,
    dense_model: &str,
    dense_w: &Weights,
    compact_model: &str,
    compact_w: &Weights,
    reps: usize,
) -> Result<CompactCompare> {
    let ds_sess = Session::new(manifest, dense_model)?;
    let cs_sess = Session::new(manifest, compact_model)?;
    let spec = ds_sess.spec.clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 0x5eed), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);
    let dense_ms = time_fwd(&ds_sess, dense_w, &b, reps)?;
    let compact_ms = time_fwd(&cs_sess, compact_w, &b, reps)?;
    Ok(CompactCompare { dense_ms, compact_ms, speedup: dense_ms / compact_ms })
}

/// Monolithic-load vs shard-streaming comparison of one *sharded*
/// compact model: the receipt the sharded store must produce — identical
/// numerics with peak resident weights of O(one layer + prefetch)
/// instead of O(model).
pub struct StreamCompare {
    /// Wall-time to assemble the full monolithic weights from shards.
    pub assemble_ms: f64,
    /// Best-of-reps `fwd_loss` over the assembled (resident) weights.
    pub mono_ms: f64,
    /// Best-of-reps `fwd_loss_streamed` over the shard store.
    pub stream_ms: f64,
    /// Peak resident weight bytes observed while streaming.
    pub peak_resident_bytes: usize,
    /// Full model weight bytes (the monolithic path's residency).
    pub model_bytes: usize,
    /// Mean per-shard load time during the streamed runs, ms.
    pub shard_load_ms: f64,
    /// Number of shards in the store (1 embed + n_layers).
    pub shards: usize,
    /// Bitwise equality of mean/seq/token NLL between the two paths.
    pub identical: bool,
}

/// Run `fwd_loss` monolithically (assembled weights) and streamed (layer
/// shards) on the same batch; verify bit-identity, time both, and report
/// the residency ratio. `model` must be the store's registered compact
/// model name.
pub fn compare_stream_eval(
    manifest: &Manifest,
    model: &str,
    store: &crate::runtime::ShardedWeights,
    reps: usize,
) -> Result<StreamCompare> {
    let session = Session::new(manifest, model)?;
    let spec = session.spec.clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 0x5a4d), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);

    let t0 = std::time::Instant::now();
    let w = store.assemble()?;
    let assemble_ms = t0.elapsed().as_secs_f64() * 1e3;

    let o1 = session.fwd_loss(&session.pack(&w.packed)?, &b.tokens, &b.targets)?;
    store.reset_stats();
    let o2 = session.fwd_loss_streamed(store, &b.tokens, &b.targets)?;
    let identical = o1.mean_nll.to_bits() == o2.mean_nll.to_bits()
        && o1.seq_nll.len() == o2.seq_nll.len()
        && o1
            .seq_nll
            .iter()
            .zip(&o2.seq_nll)
            .all(|(x, y)| x.to_bits() == y.to_bits())
        && o1
            .tok_nll
            .data
            .iter()
            .zip(&o2.tok_nll.data)
            .all(|(x, y)| x.to_bits() == y.to_bits());

    let mono_ms = time_fwd(&session, &w, &b, reps)?;
    let mut stream_ms = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = std::time::Instant::now();
        session.fwd_loss_streamed(store, &b.tokens, &b.targets)?;
        stream_ms = stream_ms.min(t.elapsed().as_secs_f64() * 1e3);
    }
    let snap = store.stats();
    Ok(StreamCompare {
        assemble_ms,
        mono_ms,
        stream_ms,
        peak_resident_bytes: snap.peak_resident_bytes,
        model_bytes: store.total_param_bytes(),
        shard_load_ms: snap.load_s * 1e3 / snap.loads.max(1) as f64,
        shards: store.n_shards(),
        identical,
    })
}

/// Dense-vs-compact autoregressive decode comparison on one prompt set
/// — the receipt FASP's OV slicing must produce at inference: smaller
/// per-token matvecs *and* a smaller resident KV cache.
pub struct DecodeCompare {
    pub prompt_len: usize,
    /// Cached decode steps timed per generation (`max_new - 1`).
    pub steps: usize,
    pub dense_prefill_ms: f64,
    pub compact_prefill_ms: f64,
    /// Mean cached-decode wall-time per token, best generation of reps.
    pub dense_per_token_ms: f64,
    pub compact_per_token_ms: f64,
    /// Mean per-token wall-time of naive generation (full-prefix
    /// re-forward per token) on the dense model — the O(prefix²)
    /// baseline the KV cache replaces.
    pub dense_reforward_per_token_ms: f64,
    /// dense / compact cached per-token latency.
    pub per_token_speedup: f64,
    /// reforward / cached per-token latency on the dense model.
    pub cache_speedup: f64,
    /// Allocated K/V cache bytes per model (same batch + capacity; the
    /// compact figure is strictly smaller whenever OV dims were sliced).
    pub dense_kv_bytes: usize,
    pub compact_kv_bytes: usize,
    /// Cached greedy tokens bitwise equal to naive-reforward greedy
    /// tokens on the dense model (the decode correctness receipt).
    pub identical: bool,
}

/// Greedy generation by full-prefix re-forward — no cache, O(prefix²):
/// re-runs the whole growing sequence for every new token. Returns the
/// generated tokens and the mean per-token seconds.
fn naive_generate(
    w: &Weights,
    prompt: &IntTensor,
    max_new: usize,
) -> Result<(IntTensor, f64)> {
    let (b, t0) = (prompt.shape[0], prompt.shape[1]);
    let mut seq = prompt.data.clone(); // [b, t] row-major, grows per step
    let mut t = t0;
    let mut steps = 0usize;
    let t_start = std::time::Instant::now();
    let mut rng = Rng::new(0); // greedy consumes no randomness
    for _ in 0..max_new {
        let toks = IntTensor::new(vec![b, t], seq.clone());
        let logits = full_logits(&mut DenseParams(w), &toks)?;
        let mut grown = Vec::with_capacity(b * (t + 1));
        for bi in 0..b {
            grown.extend_from_slice(&seq[bi * t..(bi + 1) * t]);
            grown.push(sample_row(logits.row(bi), Sampler::Greedy, &mut rng) as i32);
        }
        seq = grown;
        t += 1;
        steps += 1;
    }
    let per_token = t_start.elapsed().as_secs_f64() / steps.max(1) as f64;
    Ok((IntTensor::new(vec![b, t], seq), per_token))
}

/// Best-of-`reps` greedy generation over the session's packed operator
/// plan (packed once, outside the timed loop — exactly how a serving
/// loop amortizes it); returns (tokens, prefill_ms, per_token_ms,
/// kv_bytes).
fn time_generate(
    session: &Session,
    w: &Weights,
    prompt: &IntTensor,
    max_new: usize,
    reps: usize,
) -> Result<(IntTensor, f64, f64, usize)> {
    let opts = GenerateOpts { max_new, sampler: Sampler::Greedy, seed: 0 };
    let params = session.pack(&w.packed)?;
    // untimed warmup OUTSIDE the recorded loop: the first generation
    // after a pack pays one-time effects (page faults on the fresh
    // panels, RoPE table build) that a per-token number must exclude
    session.generate(&params, prompt, &opts)?;
    let mut best_pre = f64::INFINITY;
    let mut best_tok = f64::INFINITY;
    let mut out = None;
    for _ in 0..reps.max(1) {
        let gen = session.generate(&params, prompt, &opts)?;
        best_pre = best_pre.min(gen.prefill_s * 1e3);
        best_tok = best_tok.min(gen.per_token_s() * 1e3);
        out = Some((gen.tokens, gen.kv_bytes));
    }
    let (tokens, kv) = out.expect("reps >= 1");
    Ok((tokens, best_pre, best_tok, kv))
}

/// Measure KV-cached decode on a dense model vs its compact export on
/// the same prompt set (same token batch, same generation length), plus
/// the naive re-forward baseline on the dense model. Greedy throughout,
/// so the cached-vs-naive token identity doubles as the correctness
/// receipt.
pub fn compare_decode(
    manifest: &Manifest,
    dense_model: &str,
    dense_w: &Weights,
    compact_model: &str,
    compact_w: &Weights,
    prompt_len: usize,
    max_new: usize,
    reps: usize,
) -> Result<DecodeCompare> {
    anyhow::ensure!(max_new >= 2, "compare_decode wants max_new >= 2");
    let ds_sess = Session::new(manifest, dense_model)?;
    let cs_sess = Session::new(manifest, compact_model)?;
    let spec = ds_sess.spec.clone();
    anyhow::ensure!(
        cs_sess.spec.vocab == spec.vocab,
        "dense and compact models must share a vocab"
    );
    let ds = Dataset::new(Corpus::new(spec.vocab, 0xdec0de), spec.batch, prompt_len, 2);
    let prompt = ds.train_batch(0).tokens;

    let (dense_toks, dense_prefill_ms, dense_per_token_ms, dense_kv_bytes) =
        time_generate(&ds_sess, dense_w, &prompt, max_new, reps)?;
    let (_, compact_prefill_ms, compact_per_token_ms, compact_kv_bytes) =
        time_generate(&cs_sess, compact_w, &prompt, max_new, reps)?;
    let (naive_toks, dense_reforward_per_token_ms) = {
        let _exec = ds_sess.exec_scope();
        let (toks, per_s) = naive_generate(dense_w, &prompt, max_new)?;
        (toks, per_s * 1e3)
    };
    let identical = dense_toks.data == naive_toks.data;

    Ok(DecodeCompare {
        prompt_len,
        steps: max_new - 1,
        dense_prefill_ms,
        compact_prefill_ms,
        dense_per_token_ms,
        compact_per_token_ms,
        dense_reforward_per_token_ms,
        per_token_speedup: dense_per_token_ms / compact_per_token_ms,
        cache_speedup: dense_reforward_per_token_ms / dense_per_token_ms,
        dense_kv_bytes,
        compact_kv_bytes,
        identical,
    })
}

/// Continuous-batching serve vs N sequential generates — the receipt
/// the serve engine must produce (`BENCH_serve.json`).
pub struct ServeCompare {
    pub sessions: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    /// Sampled tokens per second through the batched engine.
    pub batched_tokens_per_s: f64,
    /// Same requests, one `generate` call per session, back to back.
    pub sequential_tokens_per_s: f64,
    /// batched / sequential throughput — must be > 1: a batched tick
    /// reads each packed weight panel once for all lanes instead of
    /// once per session per token.
    pub throughput_speedup: f64,
    pub p50_token_ms: f64,
    pub p99_token_ms: f64,
    /// Batched steps the engine ran.
    pub ticks: usize,
    pub max_batch_seen: usize,
    pub prefix_hits: u64,
    /// Arena residency high-water mark, pages.
    pub peak_pages: usize,
    /// Allocated bytes of the arena pool.
    pub kv_bytes: usize,
    /// Every session's serve tokens bitwise equal to its sequential
    /// `generate` run (same prompt, sampler and seed).
    pub identical: bool,
}

/// Drive `sessions` concurrent requests through the serve engine and
/// through per-session sequential `generate` on the same packed plan;
/// verify bit-identity and compare throughput. The second half of the
/// sessions repeat the first half's prompts so the prefix cache gets
/// exercised; every session samples from its own seed.
pub fn compare_serve(
    manifest: &Manifest,
    model: &str,
    w: &Weights,
    sessions: usize,
    prompt_len: usize,
    max_new: usize,
    cfg: &crate::serve::ServeConfig,
) -> Result<ServeCompare> {
    anyhow::ensure!(sessions >= 1, "compare_serve wants sessions >= 1");
    let session = Session::new(manifest, model)?;
    let spec = session.spec.clone();
    let uniq = sessions / 2 + sessions % 2;
    let toks = Dataset::new(Corpus::new(spec.vocab, 0x5e57e), uniq, prompt_len, 2)
        .train_batch(0)
        .tokens;
    let requests: Vec<crate::serve::ServeRequest> = (0..sessions)
        .map(|i| {
            let row = i % uniq;
            crate::serve::ServeRequest {
                prompt: toks.data[row * prompt_len..(row + 1) * prompt_len].to_vec(),
                max_new,
                sampler: Sampler::Greedy,
                seed: 0x5eed ^ i as u64,
                ..Default::default()
            }
        })
        .collect();
    let params = session.pack(&w.packed)?;

    // sequential baseline: one generate per session over the same plan
    // (first call doubles as the warmup for both paths — every packed
    // panel is touched)
    let opts0 = GenerateOpts { max_new, sampler: Sampler::Greedy, seed: 0 };
    let warm = IntTensor::new(vec![1, prompt_len], requests[0].prompt.clone());
    session.generate(&params, &warm, &opts0)?;
    let mut seq_tokens: Vec<Vec<i32>> = Vec::with_capacity(sessions);
    let t0 = std::time::Instant::now();
    for r in &requests {
        let prompt = IntTensor::new(vec![1, prompt_len], r.prompt.clone());
        let opts = GenerateOpts { max_new, sampler: r.sampler, seed: r.seed };
        seq_tokens.push(session.generate(&params, &prompt, &opts)?.tokens.data);
    }
    let seq_wall = t0.elapsed().as_secs_f64();
    let sequential_tokens_per_s =
        (sessions * max_new) as f64 / seq_wall.max(1e-12);

    let report = session.serve(&params, &requests, cfg)?;
    let identical = report.outputs.len() == seq_tokens.len()
        && report
            .outputs
            .iter()
            .zip(&seq_tokens)
            .all(|(o, s)| o.error.is_none() && &o.tokens == s);

    Ok(ServeCompare {
        sessions,
        prompt_len,
        max_new,
        batched_tokens_per_s: report.tokens_per_s,
        sequential_tokens_per_s,
        throughput_speedup: report.tokens_per_s / sequential_tokens_per_s,
        p50_token_ms: report.p50_token_s * 1e3,
        p99_token_ms: report.p99_token_s * 1e3,
        ticks: report.ticks,
        max_batch_seen: report.max_batch_seen,
        prefix_hits: report.prefix_hits,
        peak_pages: report.peak_pages,
        kv_bytes: report.kv_bytes,
        identical,
    })
}

/// The graceful-degradation receipt (`BENCH_chaos.json`): a serve load
/// under a seeded fault plan vs the same load fault-free.
pub struct ChaosCompare {
    pub sessions: usize,
    /// Canonical rendering of the plan the chaos runs used.
    pub plan: String,
    /// Pool fan-out / allocating arena-grow events of the clean run —
    /// the event space faults were placed in.
    pub pool_events: u64,
    pub arena_events: u64,
    pub injected_pool: u64,
    pub injected_arena: u64,
    pub clean_tokens_per_s: f64,
    pub chaos_tokens_per_s: f64,
    /// chaos / clean throughput (absorbed faults cost retries, so < 1
    /// is expected; the receipt is that it is finite and nonzero, i.e.
    /// the engine kept serving).
    pub throughput_ratio: f64,
    pub tick_retries: usize,
    pub failed_sessions: usize,
    pub shed_sessions: usize,
    pub deadline_failures: usize,
    /// Sessions that finished without error under faults.
    pub survivors: usize,
    /// Every survivor's tokens bitwise equal to its fault-free run.
    pub survivors_identical: bool,
    pub leaked_pages: usize,
    /// Re-running the identical plan reproduced the identical fault
    /// trace, counters and outputs.
    pub replay_identical: bool,
    /// `site@event=kind` fire log of the chaos run.
    pub trace: Vec<String>,
}

/// Drive `sessions` requests through the serve engine three times over
/// one packed plan: fault-free under a *counting* scope (the baseline
/// and the event census), then twice under the same seeded fault plan
/// (chaos + replay). Verifies the tentpole contract: survivors
/// bit-identical to fault-free, faulted sessions per-session errors,
/// clean drain, and bit-identical replay of the whole fault run.
#[allow(clippy::too_many_arguments)]
pub fn compare_chaos(
    manifest: &Manifest,
    model: &str,
    w: &Weights,
    sessions: usize,
    prompt_len: usize,
    max_new: usize,
    cfg: &crate::serve::ServeConfig,
    plan_override: Option<&crate::fault::FaultPlan>,
    n_pool: usize,
    seed: u64,
) -> Result<ChaosCompare> {
    use crate::fault::{self, FaultPlan, Site};
    anyhow::ensure!(sessions >= 1, "compare_chaos wants sessions >= 1");
    let session = Session::new(manifest, model)?;
    let spec = session.spec.clone();
    let uniq = sessions / 2 + sessions % 2;
    let toks = Dataset::new(Corpus::new(spec.vocab, 0x5e57e), uniq, prompt_len, 2)
        .train_batch(0)
        .tokens;
    let requests: Vec<crate::serve::ServeRequest> = (0..sessions)
        .map(|i| {
            let row = i % uniq;
            crate::serve::ServeRequest {
                prompt: toks.data[row * prompt_len..(row + 1) * prompt_len].to_vec(),
                max_new,
                sampler: Sampler::Greedy,
                seed: 0x5eed ^ i as u64,
                ..Default::default()
            }
        })
        .collect();
    let params = session.pack(&w.packed)?;

    // warmup: touch every packed panel before anything is timed
    let opts0 = GenerateOpts { max_new, sampler: Sampler::Greedy, seed: 0 };
    let warm = IntTensor::new(vec![1, prompt_len], requests[0].prompt.clone());
    session.generate(&params, &warm, &opts0)?;

    // 1. fault-free baseline under a counting scope: same config (so a
    // bounded queue sheds identically), zero faults, event census
    let (clean, pool_events, arena_events) = {
        let scope = fault::install(&FaultPlan::default());
        let rep = session.serve(&params, &requests, cfg)?;
        let r = scope.report();
        (rep, r.events_at(Site::Pool), r.events_at(Site::Arena))
    };
    anyhow::ensure!(
        clean.failed_sessions == clean.shed_sessions,
        "chaos baseline: {} session(s) failed with no faults armed",
        clean.failed_sessions - clean.shed_sessions
    );

    // 2. the plan: explicit override, else synthesized from the census
    let plan = match plan_override {
        Some(p) => p.clone(),
        None => fault::synth_serve_plan(seed, pool_events, arena_events, n_pool),
    };

    // 3 + 4. chaos run and its replay, identical plan
    let mut run = || {
        let scope = fault::install(&plan);
        let rep = session.serve(&params, &requests, cfg)?;
        let fr = scope.report();
        Ok::<_, anyhow::Error>((rep, fr))
    };
    let (chaos, fr1) = run()?;
    let (replay, fr2) = run()?;

    let replay_identical = fr1 == fr2
        && chaos.outputs.len() == replay.outputs.len()
        && chaos
            .outputs
            .iter()
            .zip(&replay.outputs)
            .all(|(a, b)| a.id == b.id && a.tokens == b.tokens && a.error == b.error)
        && chaos.failed_sessions == replay.failed_sessions
        && chaos.shed_sessions == replay.shed_sessions
        && chaos.deadline_failures == replay.deadline_failures
        && chaos.tick_retries == replay.tick_retries
        && chaos.leaked_pages == replay.leaked_pages;

    // survivors must be bitwise the fault-free run (outputs are ordered
    // by request id in both reports)
    let survivors = chaos.outputs.iter().filter(|o| o.error.is_none()).count();
    let survivors_identical = chaos.outputs.len() == clean.outputs.len()
        && chaos.outputs.iter().zip(&clean.outputs).all(|(c, cl)| {
            c.error.is_some() || (cl.error.is_none() && c.tokens == cl.tokens)
        });

    Ok(ChaosCompare {
        sessions,
        plan: plan.render(),
        pool_events,
        arena_events,
        injected_pool: fr1.injected_at(Site::Pool),
        injected_arena: fr1.injected_at(Site::Arena),
        clean_tokens_per_s: clean.tokens_per_s,
        chaos_tokens_per_s: chaos.tokens_per_s,
        throughput_ratio: chaos.tokens_per_s / clean.tokens_per_s.max(1e-12),
        tick_retries: chaos.tick_retries,
        failed_sessions: chaos.failed_sessions,
        shed_sessions: chaos.shed_sessions,
        deadline_failures: chaos.deadline_failures,
        survivors,
        survivors_identical,
        leaked_pages: chaos.leaked_pages,
        replay_identical,
        trace: fr1.trace,
    })
}

/// The shard half of the chaos receipt: write a sharded export of `w`
/// under `dir`, then prove (a) a one-shot checksum corruption is
/// *absorbed* by the bounded re-read (the pass still succeeds, the
/// retry counter shows it happened) and (b) a persistent truncation
/// surfaces as a per-call `Err` — never an abort.
pub struct ShardProbe {
    /// Shard-read events of one clean full pass (embed + all layers).
    pub shard_events: u64,
    /// Retries the absorbed pass took (>= 1: the fault was seen).
    pub retries_absorbed: u64,
    /// The one-shot-corrupt pass succeeded end to end.
    pub absorbed_ok: bool,
    /// The persistent-truncate load came back as `Err`.
    pub fatal_is_err: bool,
}

pub fn chaos_shard_probe(w: &Weights, dir: &std::path::Path) -> Result<ShardProbe> {
    use crate::fault::{self, FaultPlan, Site};
    use crate::model::compact::compact_from_mask;
    use crate::model::mask::PruneMask;
    use crate::runtime::store::{write_shards, ShardedWeights};

    // a sparsity-0 compact of `w`: same numerics, shard-store layout
    let mask = PruneMask::full(&w.spec);
    let cm = compact_from_mask(w, &mask, &format!("{}_chaos_probe", w.spec.name))?;
    std::fs::create_dir_all(dir)?;
    let index = write_shards(dir, &cm)?;
    let sw = ShardedWeights::open(cm.spec.clone(), dir.to_path_buf(), index)?;
    let n_layers = sw.spec().n_layers;
    let full_pass = |sw: &ShardedWeights| -> Result<()> {
        let _embed = sw.load_embed()?;
        for l in 0..n_layers {
            let _shard = sw.load_layer(l)?;
        }
        Ok(())
    };

    let shard_events = {
        let scope = fault::install(&FaultPlan::default());
        full_pass(&sw)?;
        scope.report().events_at(Site::Shard)
    };

    // (a) one-shot corruption on the second read: absorbed by a re-read
    sw.reset_stats();
    let absorbed_ok = {
        let _scope = fault::install(&FaultPlan::parse("shard@2=corrupt")?);
        full_pass(&sw).is_ok()
    };
    let retries_absorbed = sw.stats().shard_retries;

    // (b) persistent truncation: every re-read sees bad bytes — the
    // bounded retry gives up with a proper Err
    let fatal_is_err = {
        let _scope = fault::install(&FaultPlan::parse("shard@1=truncate*always")?);
        sw.load_embed().is_err()
    };

    Ok(ShardProbe { shard_events, retries_absorbed, absorbed_ok, fatal_is_err })
}

/// One draft sparsity point of the speculative receipt.
pub struct SpecPoint {
    /// Draft sparsity fraction (0.3 = 30% of FFN/OV units pruned).
    pub sparsity: f64,
    /// Registered model name of the compact draft.
    pub draft_model: String,
    /// accepted / proposed across the whole generation.
    pub acceptance: f64,
    pub proposed: usize,
    pub accepted: usize,
    /// Chunked target verification forwards.
    pub chunks: usize,
    /// Single-token draft decode steps.
    pub draft_steps: usize,
    /// Generated tokens per second, best-of-reps whole-call wall time.
    pub spec_tokens_per_s: f64,
    /// spec / target-only tokens per second.
    pub speedup: f64,
    /// Allocated K/V bytes of the draft's cache (strictly smaller than
    /// the target's whenever OV dims were sliced).
    pub draft_kv_bytes: usize,
    /// Speculative greedy tokens bitwise equal to target-only
    /// `generate` — the losslessness receipt, per point.
    pub greedy_identical: bool,
}

/// Target-only vs speculative greedy decode — the receipt the
/// speculative engine must produce (`BENCH_spec.json`).
pub struct SpecCompare {
    pub prompt_len: usize,
    pub max_new: usize,
    pub draft_k: usize,
    /// Generated tokens per second of target-only `generate`,
    /// best-of-reps whole-call wall time.
    pub target_tokens_per_s: f64,
    /// Allocated K/V bytes of the target's cache.
    pub target_kv_bytes: usize,
    pub points: Vec<SpecPoint>,
}

/// Measure target-only greedy `generate` against speculative decoding
/// with each supplied compact draft `(sparsity, model_name, weights)`.
/// Both paths run over packed plans; the whole call (prefill + decode)
/// is timed externally, best of `reps` after one untimed warmup, and
/// greedy bit-identity is checked per draft point. Drafts must be
/// registered in `manifest` (e.g. via `Manifest::register_compact`).
pub fn compare_speculative(
    manifest: &Manifest,
    target_model: &str,
    target_w: &Weights,
    drafts: &[(f64, &str, &Weights)],
    prompt_len: usize,
    max_new: usize,
    draft_k: usize,
    reps: usize,
) -> Result<SpecCompare> {
    anyhow::ensure!(max_new >= 2, "compare_speculative wants max_new >= 2");
    let session = Session::new(manifest, target_model)?;
    let spec = session.spec.clone();
    // speculative decode is single-sequence: one [1, prompt_len] prompt
    let prompt = Dataset::new(Corpus::new(spec.vocab, 0x5bec), 1, prompt_len, 2)
        .train_batch(0)
        .tokens;
    let params = session.pack(&target_w.packed)?;

    // ---- target-only baseline -----------------------------------------
    let gopts = GenerateOpts { max_new, sampler: Sampler::Greedy, seed: 0 };
    session.generate(&params, &prompt, &gopts)?; // warmup
    let mut target_s = f64::INFINITY;
    let mut target_toks = None;
    let mut target_kv_bytes = 0usize;
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        let gen = session.generate(&params, &prompt, &gopts)?;
        target_s = target_s.min(t0.elapsed().as_secs_f64());
        target_kv_bytes = gen.kv_bytes;
        target_toks = Some(gen.tokens);
    }
    let target_toks = target_toks.expect("reps >= 1");
    let target_tokens_per_s = max_new as f64 / target_s.max(1e-12);

    // ---- one point per draft ------------------------------------------
    let sopts = SpecOpts { max_new, draft_k, sampler: Sampler::Greedy, seed: 0 };
    let mut points = Vec::with_capacity(drafts.len());
    for &(sparsity, draft_model, draft_w) in drafts {
        let draft_sess = Session::new(manifest, draft_model)?;
        let draft_params = draft_sess.pack(&draft_w.packed)?;
        session.generate_speculative(&params, &draft_params, &prompt, &sopts)?; // warmup
        let mut spec_s = f64::INFINITY;
        let mut last = None;
        for _ in 0..reps.max(1) {
            let t0 = std::time::Instant::now();
            let g = session.generate_speculative(&params, &draft_params, &prompt, &sopts)?;
            spec_s = spec_s.min(t0.elapsed().as_secs_f64());
            last = Some(g);
        }
        let g = last.expect("reps >= 1");
        let spec_tokens_per_s = max_new as f64 / spec_s.max(1e-12);
        points.push(SpecPoint {
            sparsity,
            draft_model: draft_model.to_string(),
            acceptance: g.acceptance_rate(),
            proposed: g.proposed,
            accepted: g.accepted,
            chunks: g.chunks,
            draft_steps: g.draft_steps,
            spec_tokens_per_s,
            speedup: spec_tokens_per_s / target_tokens_per_s,
            draft_kv_bytes: g.draft_kv_bytes,
            greedy_identical: g.tokens.data == target_toks.data,
        });
    }

    Ok(SpecCompare {
        prompt_len,
        max_new,
        draft_k,
        target_tokens_per_s,
        target_kv_bytes,
        points,
    })
}

/// Single-threaded vs thread-pooled host execution of the same forward.
pub struct BackendCompare {
    /// Worker count of the threaded backend measured.
    pub threads: usize,
    pub single_ms: f64,
    pub threaded_ms: f64,
    pub speedup: f64,
    /// Bitwise equality of mean/seq/token NLL between the two backends.
    pub identical: bool,
}

/// Time `fwd_loss` on `model` under [`HostBackend`] and under
/// [`ThreadedHostBackend`] with `threads` workers, and verify the outputs
/// are bit-identical. The determinism receipt plus the latency receipt
/// in one measurement (used by `bench_hot_paths` and `test_backend`).
pub fn compare_backends(
    manifest: &Manifest,
    model: &str,
    w: &Weights,
    reps: usize,
    threads: usize,
) -> Result<BackendCompare> {
    let single = Session::with_backend(manifest, model, Arc::new(HostBackend::new()))?;
    let threaded =
        Session::with_backend(manifest, model, Arc::new(ThreadedHostBackend::new(threads)))?;
    let spec = single.spec.clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 0xbac), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);

    let o1 = single.fwd_loss(&single.pack(&w.packed)?, &b.tokens, &b.targets)?;
    let o2 = threaded.fwd_loss(&threaded.pack(&w.packed)?, &b.tokens, &b.targets)?;
    let identical = o1.mean_nll.to_bits() == o2.mean_nll.to_bits()
        && o1.seq_nll.len() == o2.seq_nll.len()
        && o1
            .seq_nll
            .iter()
            .zip(&o2.seq_nll)
            .all(|(x, y)| x.to_bits() == y.to_bits())
        && o1
            .tok_nll
            .data
            .iter()
            .zip(&o2.tok_nll.data)
            .all(|(x, y)| x.to_bits() == y.to_bits());

    let single_ms = time_fwd(&single, w, &b, reps)?;
    let threaded_ms = time_fwd(&threaded, w, &b, reps)?;
    Ok(BackendCompare {
        threads,
        single_ms,
        threaded_ms,
        speedup: single_ms / threaded_ms,
        identical,
    })
}

/// Packed-operator-plan vs legacy per-call-transpose measurement — the
/// receipt the pack cache must produce (`BENCH_pack.json`).
pub struct PackCompare {
    /// Worker count of the backend measured (the process default).
    pub threads: usize,
    /// One-time cost of building the plan (`Session::pack`), ms.
    pub pack_build_ms: f64,
    /// Resident bytes of the pre-packed panels.
    pub pack_bytes: usize,
    /// Number of weights the plan holds packed.
    pub packed_weights: usize,
    /// Best-of-reps full forward, legacy path (per-call weight copy +
    /// transpose inside `matmul_bt`).
    pub unpacked_fwd_ms: f64,
    /// Best-of-reps full forward over the plan (`Session::fwd_loss`).
    pub packed_fwd_ms: f64,
    pub fwd_speedup: f64,
    pub unpacked_prefill_ms: f64,
    pub packed_prefill_ms: f64,
    /// Mean cached-decode wall-time per token, best generation of reps.
    pub unpacked_per_token_ms: f64,
    pub packed_per_token_ms: f64,
    pub per_token_speedup: f64,
    /// Best-of-reps streamed `fwd_loss` over a sharded store (packing
    /// rides the prefetch thread); 0 when no store was supplied.
    pub streamed_fwd_ms: f64,
    /// Pack constructions observed during the packed generations — must
    /// be 0: all packing happened at `Session::pack`.
    pub decode_pack_ops: u64,
    /// `matmul_bt` transpose copies observed during the packed
    /// generations — must be 0: no hidden per-token transposes.
    pub decode_bt_transposes: u64,
    /// Packed ≡ unpacked, bitwise: token NLL of the forward AND the
    /// greedy decode token streams.
    pub identical: bool,
    /// One-time cost of building the int8 plan (`Session::pack_as`), ms.
    pub int8_pack_build_ms: f64,
    /// Resident bytes of the int8 plan's panels (q codes + per-group
    /// scale tables) — the ≤0.55× receipt vs `pack_bytes`.
    pub int8_pack_bytes: usize,
    /// Best-of-reps full forward over the int8 plan.
    pub int8_fwd_ms: f64,
    pub int8_prefill_ms: f64,
    /// Mean cached-decode wall-time per token over the int8 plan — must
    /// not regress past the f32 packed path (dequant rides in-register).
    pub int8_per_token_ms: f64,
    /// Greedy int8 decode determinism: token streams bit-identical
    /// across a replay on the same backend AND across `HostBackend` vs
    /// `ThreadedHostBackend` (pool-width independence). Int8 is *not*
    /// bit-matched against f32 — its contract is self-consistency.
    pub int8_deterministic: bool,
    /// Mean-NLL delta, int8 forward minus exact-f32 forward (bounded
    /// quantization error; reported, never bit-asserted).
    pub int8_nll_delta: f64,
}

/// Measure the packed operator plan against the legacy unpacked path on
/// one model: full forward (entry path vs per-call-transpose host
/// forward), greedy decode (plan vs `DenseParams`), optionally the
/// streamed forward over `store` (which must hold the same-shape model,
/// e.g. an s=0 sharded export). Everything runs on the process-default
/// backend; outputs must be bit-identical, the win is wall-time only.
pub fn compare_packed(
    manifest: &Manifest,
    model: &str,
    w: &Weights,
    store: Option<&crate::runtime::ShardedWeights>,
    prompt_len: usize,
    max_new: usize,
    reps: usize,
) -> Result<PackCompare> {
    anyhow::ensure!(max_new >= 2, "compare_packed wants max_new >= 2");
    let session = Session::new(manifest, model)?;
    let spec = session.spec.clone();
    let threads = session.backend().threads();
    let ds = Dataset::new(Corpus::new(spec.vocab, 0x9acc), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);

    // ---- the plan: built exactly once, timed ---------------------------
    let t0 = std::time::Instant::now();
    let params = session.pack(&w.packed)?;
    let pack_build_ms = t0.elapsed().as_secs_f64() * 1e3;

    // ---- full forward: packed entry path vs legacy host forward --------
    let o_packed = session.fwd_loss(&params, &b.tokens, &b.targets)?; // warmup
    let mut packed_fwd_ms = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = std::time::Instant::now();
        session.fwd_loss(&params, &b.tokens, &b.targets)?;
        packed_fwd_ms = packed_fwd_ms.min(t.elapsed().as_secs_f64() * 1e3);
    }
    let (nll_unpacked, unpacked_fwd_ms) = {
        let _exec = session.exec_scope();
        let (nll, _) = host::forward_nll(w, &b.tokens, &b.targets, false)?; // warmup
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t = std::time::Instant::now();
            host::forward_nll(w, &b.tokens, &b.targets, false)?;
            best = best.min(t.elapsed().as_secs_f64() * 1e3);
        }
        (nll, best)
    };
    let mut identical = o_packed
        .tok_nll
        .data
        .iter()
        .zip(&nll_unpacked.data)
        .all(|(x, y)| x.to_bits() == y.to_bits());

    // ---- decode: plan vs DenseParams, counters around the packed loop --
    let prompt =
        Dataset::new(Corpus::new(spec.vocab, 0xdeca), spec.batch, prompt_len, 2)
            .train_batch(0)
            .tokens;
    let opts = GenerateOpts { max_new, sampler: Sampler::Greedy, seed: 0 };
    session.generate(&params, &prompt, &opts)?; // warmup
    let packs0 = pack::pack_ops();
    let bt0 = matmul::bt_transposes();
    let mut packed_prefill_ms = f64::INFINITY;
    let mut packed_per_token_ms = f64::INFINITY;
    let mut packed_toks = None;
    for _ in 0..reps.max(1) {
        let gen = session.generate(&params, &prompt, &opts)?;
        packed_prefill_ms = packed_prefill_ms.min(gen.prefill_s * 1e3);
        packed_per_token_ms = packed_per_token_ms.min(gen.per_token_s() * 1e3);
        packed_toks = Some(gen.tokens);
    }
    let decode_pack_ops = pack::pack_ops() - packs0;
    let decode_bt_transposes = matmul::bt_transposes() - bt0;

    let (unpacked_toks, unpacked_prefill_ms, unpacked_per_token_ms) = {
        let _exec = session.exec_scope();
        decode::generate_src(&mut DenseParams(w), &prompt, &opts)?; // warmup
        let mut pre = f64::INFINITY;
        let mut tok = f64::INFINITY;
        let mut toks = None;
        for _ in 0..reps.max(1) {
            let gen = decode::generate_src(&mut DenseParams(w), &prompt, &opts)?;
            pre = pre.min(gen.prefill_s * 1e3);
            tok = tok.min(gen.per_token_s() * 1e3);
            toks = Some(gen.tokens);
        }
        (toks.expect("reps >= 1"), pre, tok)
    };
    identical = identical
        && packed_toks.expect("reps >= 1").data == unpacked_toks.data;

    // ---- streamed forward over the sharded store (prefetch packing) ----
    let streamed_fwd_ms = match store {
        Some(st) => {
            let sname = st.spec().name.clone();
            let ssess = Session::new(manifest, &sname)?;
            ssess.fwd_loss_streamed(st, &b.tokens, &b.targets)?; // warmup
            let mut best = f64::INFINITY;
            for _ in 0..reps.max(1) {
                let t = std::time::Instant::now();
                ssess.fwd_loss_streamed(st, &b.tokens, &b.targets)?;
                best = best.min(t.elapsed().as_secs_f64() * 1e3);
            }
            best
        }
        None => 0.0,
    };

    // ---- int8 plan: bytes, latency, determinism, nll delta -------------
    let t8 = std::time::Instant::now();
    let params8 = session.pack_as(&w.packed, pack::Quant::Int8)?;
    let int8_pack_build_ms = t8.elapsed().as_secs_f64() * 1e3;
    let o8 = session.fwd_loss(&params8, &b.tokens, &b.targets)?; // warmup
    let int8_nll_delta = o8.mean_nll as f64 - o_packed.mean_nll as f64;
    let mut int8_fwd_ms = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = std::time::Instant::now();
        session.fwd_loss(&params8, &b.tokens, &b.targets)?;
        int8_fwd_ms = int8_fwd_ms.min(t.elapsed().as_secs_f64() * 1e3);
    }
    session.generate(&params8, &prompt, &opts)?; // warmup
    let mut int8_prefill_ms = f64::INFINITY;
    let mut int8_per_token_ms = f64::INFINITY;
    let mut toks8: Option<crate::tensor::IntTensor> = None;
    let mut replay_eq = true;
    for _ in 0..reps.max(1) {
        let gen = session.generate(&params8, &prompt, &opts)?;
        int8_prefill_ms = int8_prefill_ms.min(gen.prefill_s * 1e3);
        int8_per_token_ms = int8_per_token_ms.min(gen.per_token_s() * 1e3);
        if let Some(prev) = &toks8 {
            replay_eq = replay_eq && gen.tokens.data == prev.data;
        }
        toks8 = Some(gen.tokens);
    }
    // pool-width independence: the same weights quantized + decoded on a
    // serial and a threaded backend must emit one token stream (and match
    // the process-default backend's stream above)
    let single = Session::with_backend(manifest, model, Arc::new(HostBackend::new()))?;
    let threaded =
        Session::with_backend(manifest, model, Arc::new(ThreadedHostBackend::new(4)))?;
    let g1 = single.generate(&single.pack_as(&w.packed, pack::Quant::Int8)?, &prompt, &opts)?;
    let g2 =
        threaded.generate(&threaded.pack_as(&w.packed, pack::Quant::Int8)?, &prompt, &opts)?;
    let int8_deterministic = replay_eq
        && toks8.map(|t| t.data == g1.tokens.data).unwrap_or(false)
        && g1.tokens.data == g2.tokens.data;

    Ok(PackCompare {
        threads,
        pack_build_ms,
        pack_bytes: params.pack_bytes(),
        packed_weights: params.pack_count(),
        unpacked_fwd_ms,
        packed_fwd_ms,
        fwd_speedup: unpacked_fwd_ms / packed_fwd_ms,
        unpacked_prefill_ms,
        packed_prefill_ms,
        unpacked_per_token_ms,
        packed_per_token_ms,
        per_token_speedup: unpacked_per_token_ms / packed_per_token_ms,
        streamed_fwd_ms,
        decode_pack_ops,
        decode_bt_transposes,
        identical,
        int8_pack_build_ms,
        int8_pack_bytes: params8.pack_bytes(),
        int8_fwd_ms,
        int8_prefill_ms,
        int8_per_token_ms,
        int8_deterministic,
        int8_nll_delta,
    })
}
