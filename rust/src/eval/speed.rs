//! Structured-speedup measurements (the paper §1–2: structured pruning
//! yields hardware-agnostic inference speedups):
//!
//! * [`layer_latency_sweep`] — the physically sliced
//!   `latency_llama_small_s{pct}` single-layer artifacts, latency vs
//!   sparsity.
//! * [`compare_dense_compact`] — end-to-end model latency of a dense
//!   model vs its compact (physically repacked) export, through the same
//!   `fwd_loss` path perplexity uses. This is the receipt the compact
//!   artifact must produce: a genuinely smaller model that runs faster
//!   with no masks.
//! * [`compare_backends`] — the same forward on [`HostBackend`] vs
//!   [`ThreadedHostBackend`]: the threaded backend must be faster on
//!   multi-core while producing bit-identical outputs (the receipt the
//!   backend redesign must produce).
//! * [`compare_stream_eval`] — monolithic (assembled) vs shard-streaming
//!   `fwd_loss` of a sharded compact export: bit-identical NLL with peak
//!   resident weights of O(one layer + prefetch) instead of O(model)
//!   (the receipt the sharded store must produce).

use crate::data::{Batch, Corpus, Dataset};
use crate::model::Weights;
use crate::runtime::executable::{Artifact, In};
use crate::runtime::{HostBackend, Manifest, Session, ThreadedHostBackend};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

pub struct LatencyPoint {
    pub sparsity: f64,
    pub f_s: usize,
    pub dk_s: usize,
    pub mean_ms: f64,
    pub speedup: f64,
}

/// Measure each sliced-layer artifact; `reps` timed runs after 2 warmups.
pub fn layer_latency_sweep(manifest: &Manifest, reps: usize) -> Result<Vec<LatencyPoint>> {
    let mut names: Vec<&String> = manifest.latency.keys().collect();
    names.sort();
    let mut points = Vec::new();
    let mut base_ms = None;
    let mut rng = Rng::new(123);
    for name in names {
        let meta = &manifest.latency[name];
        let art = Artifact::load(manifest, name)?;
        // random inputs with the right sliced shapes
        let inputs: Vec<Tensor> = art
            .spec
            .inputs
            .iter()
            .map(|io| Tensor::randn(&io.shape, 0.05, &mut rng))
            .collect();
        let ins: Vec<In> = inputs.iter().map(In::F).collect();
        for _ in 0..2 {
            art.call(&ins)?;
        }
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            art.call(&ins)?;
        }
        let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let base = *base_ms.get_or_insert(mean_ms);
        points.push(LatencyPoint {
            sparsity: meta.sparsity,
            f_s: meta.f_s,
            dk_s: meta.dk_s,
            mean_ms,
            speedup: base / mean_ms,
        });
    }
    Ok(points)
}

/// Dense-vs-compact end-to-end latency comparison.
pub struct CompactCompare {
    pub dense_ms: f64,
    pub compact_ms: f64,
    pub speedup: f64,
}

/// Best-of-`reps` wall-clock of one `fwd_loss` call (params packed once,
/// like the perplexity loop). Min-of-reps is robust to scheduler noise
/// on small testbeds.
fn time_fwd(session: &Session, w: &Weights, batch: &Batch, reps: usize) -> Result<f64> {
    let params = session.pack(&w.packed)?;
    session.fwd_loss(&params, &batch.tokens, &batch.targets)?; // warmup
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        session.fwd_loss(&params, &batch.tokens, &batch.targets)?;
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(best)
}

/// Measure a dense model against its compact export on identical token
/// batches. Both models must be registered in the manifest (the compact
/// one via its `compact/` artifact or `Manifest::register_compact`).
pub fn compare_dense_compact(
    manifest: &Manifest,
    dense_model: &str,
    dense_w: &Weights,
    compact_model: &str,
    compact_w: &Weights,
    reps: usize,
) -> Result<CompactCompare> {
    let ds_sess = Session::new(manifest, dense_model)?;
    let cs_sess = Session::new(manifest, compact_model)?;
    let spec = ds_sess.spec.clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 0x5eed), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);
    let dense_ms = time_fwd(&ds_sess, dense_w, &b, reps)?;
    let compact_ms = time_fwd(&cs_sess, compact_w, &b, reps)?;
    Ok(CompactCompare { dense_ms, compact_ms, speedup: dense_ms / compact_ms })
}

/// Monolithic-load vs shard-streaming comparison of one *sharded*
/// compact model: the receipt the sharded store must produce — identical
/// numerics with peak resident weights of O(one layer + prefetch)
/// instead of O(model).
pub struct StreamCompare {
    /// Wall-time to assemble the full monolithic weights from shards.
    pub assemble_ms: f64,
    /// Best-of-reps `fwd_loss` over the assembled (resident) weights.
    pub mono_ms: f64,
    /// Best-of-reps `fwd_loss_streamed` over the shard store.
    pub stream_ms: f64,
    /// Peak resident weight bytes observed while streaming.
    pub peak_resident_bytes: usize,
    /// Full model weight bytes (the monolithic path's residency).
    pub model_bytes: usize,
    /// Mean per-shard load time during the streamed runs, ms.
    pub shard_load_ms: f64,
    /// Number of shards in the store (1 embed + n_layers).
    pub shards: usize,
    /// Bitwise equality of mean/seq/token NLL between the two paths.
    pub identical: bool,
}

/// Run `fwd_loss` monolithically (assembled weights) and streamed (layer
/// shards) on the same batch; verify bit-identity, time both, and report
/// the residency ratio. `model` must be the store's registered compact
/// model name.
pub fn compare_stream_eval(
    manifest: &Manifest,
    model: &str,
    store: &crate::runtime::ShardedWeights,
    reps: usize,
) -> Result<StreamCompare> {
    let session = Session::new(manifest, model)?;
    let spec = session.spec.clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 0x5a4d), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);

    let t0 = std::time::Instant::now();
    let w = store.assemble()?;
    let assemble_ms = t0.elapsed().as_secs_f64() * 1e3;

    let o1 = session.fwd_loss(&session.pack(&w.packed)?, &b.tokens, &b.targets)?;
    store.reset_stats();
    let o2 = session.fwd_loss_streamed(store, &b.tokens, &b.targets)?;
    let identical = o1.mean_nll.to_bits() == o2.mean_nll.to_bits()
        && o1.seq_nll.len() == o2.seq_nll.len()
        && o1
            .seq_nll
            .iter()
            .zip(&o2.seq_nll)
            .all(|(x, y)| x.to_bits() == y.to_bits())
        && o1
            .tok_nll
            .data
            .iter()
            .zip(&o2.tok_nll.data)
            .all(|(x, y)| x.to_bits() == y.to_bits());

    let mono_ms = time_fwd(&session, &w, &b, reps)?;
    let mut stream_ms = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = std::time::Instant::now();
        session.fwd_loss_streamed(store, &b.tokens, &b.targets)?;
        stream_ms = stream_ms.min(t.elapsed().as_secs_f64() * 1e3);
    }
    let snap = store.stats();
    Ok(StreamCompare {
        assemble_ms,
        mono_ms,
        stream_ms,
        peak_resident_bytes: snap.peak_resident_bytes,
        model_bytes: store.total_param_bytes(),
        shard_load_ms: snap.load_s * 1e3 / snap.loads.max(1) as f64,
        shards: store.n_shards(),
        identical,
    })
}

/// Single-threaded vs thread-pooled host execution of the same forward.
pub struct BackendCompare {
    /// Worker count of the threaded backend measured.
    pub threads: usize,
    pub single_ms: f64,
    pub threaded_ms: f64,
    pub speedup: f64,
    /// Bitwise equality of mean/seq/token NLL between the two backends.
    pub identical: bool,
}

/// Time `fwd_loss` on `model` under [`HostBackend`] and under
/// [`ThreadedHostBackend`] with `threads` workers, and verify the outputs
/// are bit-identical. The determinism receipt plus the latency receipt
/// in one measurement (used by `bench_hot_paths` and `test_backend`).
pub fn compare_backends(
    manifest: &Manifest,
    model: &str,
    w: &Weights,
    reps: usize,
    threads: usize,
) -> Result<BackendCompare> {
    let single = Session::with_backend(manifest, model, Arc::new(HostBackend::new()))?;
    let threaded =
        Session::with_backend(manifest, model, Arc::new(ThreadedHostBackend::new(threads)))?;
    let spec = single.spec.clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 0xbac), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);

    let o1 = single.fwd_loss(&single.pack(&w.packed)?, &b.tokens, &b.targets)?;
    let o2 = threaded.fwd_loss(&threaded.pack(&w.packed)?, &b.tokens, &b.targets)?;
    let identical = o1.mean_nll.to_bits() == o2.mean_nll.to_bits()
        && o1.seq_nll.len() == o2.seq_nll.len()
        && o1
            .seq_nll
            .iter()
            .zip(&o2.seq_nll)
            .all(|(x, y)| x.to_bits() == y.to_bits())
        && o1
            .tok_nll
            .data
            .iter()
            .zip(&o2.tok_nll.data)
            .all(|(x, y)| x.to_bits() == y.to_bits());

    let single_ms = time_fwd(&single, w, &b, reps)?;
    let threaded_ms = time_fwd(&threaded, w, &b, reps)?;
    Ok(BackendCompare {
        threads,
        single_ms,
        threaded_ms,
        speedup: single_ms / threaded_ms,
        identical,
    })
}
