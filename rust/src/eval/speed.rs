//! Sliced decoder-layer latency: the structured-speedup claim (the paper
//! §1–2: structured pruning yields hardware-agnostic inference
//! speedups). Runs the physically sliced `latency_llama_small_s{pct}`
//! artifacts and reports latency vs sparsity.

use crate::runtime::executable::{Artifact, In};
use crate::runtime::Manifest;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::Result;

pub struct LatencyPoint {
    pub sparsity: f64,
    pub f_s: usize,
    pub dk_s: usize,
    pub mean_ms: f64,
    pub speedup: f64,
}

/// Measure each sliced-layer artifact; `reps` timed runs after 2 warmups.
pub fn layer_latency_sweep(manifest: &Manifest, reps: usize) -> Result<Vec<LatencyPoint>> {
    let mut names: Vec<&String> = manifest.latency.keys().collect();
    names.sort();
    let mut points = Vec::new();
    let mut base_ms = None;
    let mut rng = Rng::new(123);
    for name in names {
        let meta = &manifest.latency[name];
        let art = Artifact::load(manifest, name)?;
        // random inputs with the right sliced shapes
        let inputs: Vec<Tensor> = art
            .spec
            .inputs
            .iter()
            .map(|io| Tensor::randn(&io.shape, 0.05, &mut rng))
            .collect();
        let ins: Vec<In> = inputs.iter().map(In::F).collect();
        for _ in 0..2 {
            art.call(&ins)?;
        }
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            art.call(&ins)?;
        }
        let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let base = *base_ms.get_or_insert(mean_ms);
        points.push(LatencyPoint {
            sparsity: meta.sparsity,
            f_s: meta.f_s,
            dk_s: meta.dk_s,
            mean_ms,
            speedup: base / mean_ms,
        });
    }
    Ok(points)
}
