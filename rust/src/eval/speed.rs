//! Structured-speedup measurements (the paper §1–2: structured pruning
//! yields hardware-agnostic inference speedups):
//!
//! * [`layer_latency_sweep`] — the physically sliced
//!   `latency_llama_small_s{pct}` single-layer artifacts, latency vs
//!   sparsity.
//! * [`compare_dense_compact`] — end-to-end model latency of a dense
//!   model vs its compact (physically repacked) export, through the same
//!   `fwd_loss` path perplexity uses. This is the receipt the compact
//!   artifact must produce: a genuinely smaller model that runs faster
//!   with no masks.

use crate::data::{Batch, Corpus, Dataset};
use crate::model::Weights;
use crate::runtime::executable::{Artifact, In};
use crate::runtime::{Manifest, ModelEngine};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::Result;

pub struct LatencyPoint {
    pub sparsity: f64,
    pub f_s: usize,
    pub dk_s: usize,
    pub mean_ms: f64,
    pub speedup: f64,
}

/// Measure each sliced-layer artifact; `reps` timed runs after 2 warmups.
pub fn layer_latency_sweep(manifest: &Manifest, reps: usize) -> Result<Vec<LatencyPoint>> {
    let mut names: Vec<&String> = manifest.latency.keys().collect();
    names.sort();
    let mut points = Vec::new();
    let mut base_ms = None;
    let mut rng = Rng::new(123);
    for name in names {
        let meta = &manifest.latency[name];
        let art = Artifact::load(manifest, name)?;
        // random inputs with the right sliced shapes
        let inputs: Vec<Tensor> = art
            .spec
            .inputs
            .iter()
            .map(|io| Tensor::randn(&io.shape, 0.05, &mut rng))
            .collect();
        let ins: Vec<In> = inputs.iter().map(In::F).collect();
        for _ in 0..2 {
            art.call(&ins)?;
        }
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            art.call(&ins)?;
        }
        let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let base = *base_ms.get_or_insert(mean_ms);
        points.push(LatencyPoint {
            sparsity: meta.sparsity,
            f_s: meta.f_s,
            dk_s: meta.dk_s,
            mean_ms,
            speedup: base / mean_ms,
        });
    }
    Ok(points)
}

/// Dense-vs-compact end-to-end latency comparison.
pub struct CompactCompare {
    pub dense_ms: f64,
    pub compact_ms: f64,
    pub speedup: f64,
}

/// Best-of-`reps` wall-clock of one `fwd_loss` call (params uploaded
/// once, like the perplexity loop). Min-of-reps is robust to scheduler
/// noise on the 1-core testbed.
fn time_fwd(engine: &ModelEngine, w: &Weights, batch: &Batch, reps: usize) -> Result<f64> {
    let lit = engine.params_literal(&w.packed)?;
    engine.fwd_loss_lit(&lit, &batch.tokens, &batch.targets)?; // warmup
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        engine.fwd_loss_lit(&lit, &batch.tokens, &batch.targets)?;
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(best)
}

/// Measure a dense model against its compact export on identical token
/// batches. Both models must be registered in the manifest (the compact
/// one via its `compact/` artifact or `Manifest::register_compact`).
pub fn compare_dense_compact(
    manifest: &Manifest,
    dense_model: &str,
    dense_w: &Weights,
    compact_model: &str,
    compact_w: &Weights,
    reps: usize,
) -> Result<CompactCompare> {
    let de = ModelEngine::new(manifest, dense_model)?;
    let ce = ModelEngine::new(manifest, compact_model)?;
    let spec = de.spec.clone();
    let ds = Dataset::new(Corpus::new(spec.vocab, 0x5eed), spec.batch, spec.seq, 2);
    let b = ds.train_batch(0);
    let dense_ms = time_fwd(&de, dense_w, &b, reps)?;
    let compact_ms = time_fwd(&ce, compact_w, &b, reps)?;
    Ok(CompactCompare { dense_ms, compact_ms, speedup: dense_ms / compact_ms })
}
