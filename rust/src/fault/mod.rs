//! # Deterministic fault injection — `fasp chaos`'s substrate
//!
//! A seeded, replayable harness for proving the serving stack degrades
//! instead of dying. A [`FaultPlan`] arms faults at *event counters* —
//! the Nth shard read ([`shard_read`]), the Mth top-level pool fan-out
//! ([`pool_fanout_bomb`]), the Kth allocating KV-arena grow
//! ([`arena_grow`]) — never at wall-clock instants, so a given plan
//! fires at exactly the same operations on every run (D3-clean by
//! construction) and `fasp chaos` can assert that replaying the same
//! plan reproduces the same fault trace, counters and outputs bitwise.
//!
//! ## Wiring
//!
//! The plan installs into a thread-local scope ([`install`]); the three
//! hook functions are called from `runtime/store.rs`, `util/pool.rs`
//! and `model/kv_arena.rs` and are no-ops without a scope (production
//! never pays more than one thread-local read). Threads the runtime
//! itself spawns on a faulted path (the store's shard prefetch thread)
//! inherit the scope explicitly via [`handle`]/[`adopt`] — ambient
//! threads never see someone else's plan, so parallel `cargo test`
//! cannot cross-pollute.
//!
//! ## Event determinism contract
//!
//! * **shard** — one event per shard-file read *attempt* (a checksum
//!   retry is a new event). Deterministic for sequential readers and
//!   prefetch depth ≤ 1, the only shapes the runtime uses.
//! * **pool** — one event per top-level `Pool::map`/`run_rows*` entry
//!   on a thread holding the scope; nested fan-out work never counts.
//!   Call sites gate their pool entry on `workers() > 1` and a flop
//!   threshold, so the event count is a function of pool width and
//!   model scale — plans are synthesized per width from a clean
//!   counting run ([`synth_serve_plan`]).
//! * **arena** — one event per `KvArena::grow` call that actually
//!   allocates pages. Width-independent.

use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Environment variable holding a fault plan (`fasp chaos` also takes
/// `--plan`): comma-separated `site@nth=kind[:arg][*count]` entries.
pub const ENV_FAULTS: &str = "FASP_FAULTS";

/// Where a fault injects.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Site {
    /// A shard-file read in `runtime/store.rs`.
    Shard,
    /// A top-level worker-pool fan-out in `util/pool.rs`.
    Pool,
    /// An allocating page grow in `model/kv_arena.rs`.
    Arena,
}

impl Site {
    pub const ALL: [Site; 3] = [Site::Shard, Site::Pool, Site::Arena];

    fn idx(self) -> usize {
        match self {
            Site::Shard => 0,
            Site::Pool => 1,
            Site::Arena => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Site::Shard => "shard",
            Site::Pool => "pool",
            Site::Arena => "arena",
        }
    }
}

/// What happens at an armed event.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FaultKind {
    /// Flip one payload byte of the read (trips the shard checksum).
    ShardCorrupt,
    /// Drop the tail half of the read's bytes (trips the checksum).
    ShardTruncate,
    /// Stall the read for the given milliseconds. Scheduling noise
    /// only: no byte changes, so outputs cannot change either.
    ShardSlow(u64),
    /// One worker of the fan-out raises an injected panic (the pool
    /// itself raises it; the serve engine must catch and absorb it).
    PoolPanic,
    /// The grow reports pool exhaustion (`Err`) without allocating.
    ArenaExhaust,
}

impl FaultKind {
    fn label(self) -> String {
        match self {
            FaultKind::ShardCorrupt => "corrupt".to_string(),
            FaultKind::ShardTruncate => "truncate".to_string(),
            FaultKind::ShardSlow(ms) => format!("slow:{ms}"),
            FaultKind::PoolPanic => "panic".to_string(),
            FaultKind::ArenaExhaust => "exhaust".to_string(),
        }
    }

    fn site(self) -> Site {
        match self {
            FaultKind::ShardCorrupt | FaultKind::ShardTruncate | FaultKind::ShardSlow(_) => {
                Site::Shard
            }
            FaultKind::PoolPanic => Site::Pool,
            FaultKind::ArenaExhaust => Site::Arena,
        }
    }
}

/// One armed fault: fire at events `nth .. nth + count` of `site`
/// (1-based window; `count == u64::MAX` means "from `nth` on, forever",
/// rendered `*always` — the persistent-failure shape).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub site: Site,
    pub nth: u64,
    pub count: u64,
    pub kind: FaultKind,
}

/// A full injection plan — an ordered set of [`FaultSpec`]s.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse comma-separated `site@nth=kind[:arg][*count]` entries, e.g.
    /// `shard@2=corrupt, pool@7=panic, shard@4=slow:10,
    /// arena@5=exhaust*always`.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let mut specs = Vec::new();
        for raw in text.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            let (head, kind_part) = entry
                .split_once('=')
                .with_context(|| format!("fault entry '{entry}': missing '=<kind>'"))?;
            let (site_s, nth_s) = head
                .split_once('@')
                .with_context(|| format!("fault entry '{entry}': missing '<site>@<nth>'"))?;
            let site = match site_s.trim() {
                "shard" => Site::Shard,
                "pool" => Site::Pool,
                "arena" => Site::Arena,
                other => bail!("fault entry '{entry}': unknown site '{other}'"),
            };
            let nth: u64 = nth_s
                .trim()
                .parse()
                .with_context(|| format!("fault entry '{entry}': bad event number"))?;
            anyhow::ensure!(nth >= 1, "fault entry '{entry}': events are 1-based");
            let (kind_s, count) = match kind_part.split_once('*') {
                Some((k, c)) if c.trim() == "always" => (k, u64::MAX),
                Some((k, c)) => (
                    k,
                    c.trim()
                        .parse::<u64>()
                        .with_context(|| format!("fault entry '{entry}': bad count"))?,
                ),
                None => (kind_part, 1),
            };
            anyhow::ensure!(count >= 1, "fault entry '{entry}': count must be >= 1");
            let kind = match kind_s.trim().split_once(':') {
                None => match kind_s.trim() {
                    "corrupt" => FaultKind::ShardCorrupt,
                    "truncate" => FaultKind::ShardTruncate,
                    "panic" => FaultKind::PoolPanic,
                    "exhaust" => FaultKind::ArenaExhaust,
                    other => bail!("fault entry '{entry}': unknown kind '{other}'"),
                },
                Some(("slow", ms)) => FaultKind::ShardSlow(
                    ms.trim()
                        .parse()
                        .with_context(|| format!("fault entry '{entry}': bad slow milliseconds"))?,
                ),
                Some((other, _)) => bail!("fault entry '{entry}': unknown kind '{other}'"),
            };
            anyhow::ensure!(
                kind.site() == site,
                "fault entry '{entry}': kind '{}' belongs to site '{}', not '{}'",
                kind.label(),
                kind.site().name(),
                site.name()
            );
            specs.push(FaultSpec { site, nth, count, kind });
        }
        Ok(FaultPlan { specs })
    }

    /// The plan from `FASP_FAULTS`, if set (absent/blank → `None`).
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var(ENV_FAULTS) {
            Ok(s) if !s.trim().is_empty() => Ok(Some(FaultPlan::parse(&s)?)),
            _ => Ok(None),
        }
    }

    /// Canonical textual form — `parse(render(p)) == p`.
    pub fn render(&self) -> String {
        let parts: Vec<String> = self
            .specs
            .iter()
            .map(|s| {
                let tail = match s.count {
                    1 => String::new(),
                    u64::MAX => "*always".to_string(),
                    c => format!("*{c}"),
                };
                format!("{}@{}={}{}", s.site.name(), s.nth, s.kind.label(), tail)
            })
            .collect();
        parts.join(",")
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }
}

/// Synthesize a structured serve-drive plan from a clean run's event
/// counts: one single-shot arena exhaustion (exactly one session fails
/// deterministically) plus up to `n_pool` single-shot pool worker
/// panics (each absorbed by the engine's tick retry). Placement is
/// pseudorandom but a pure function of `seed` and the counts — the
/// replay-identity receipt `fasp chaos` asserts.
pub fn synth_serve_plan(seed: u64, pool_events: u64, arena_events: u64, n_pool: usize) -> FaultPlan {
    let mut rng = Rng::new(seed ^ 0xfa57_c405);
    let mut specs = Vec::new();
    if arena_events > 0 {
        let nth = 1 + rng.below(arena_events as usize) as u64;
        specs.push(FaultSpec { site: Site::Arena, nth, count: 1, kind: FaultKind::ArenaExhaust });
    }
    for _ in 0..n_pool {
        if pool_events == 0 {
            break;
        }
        let nth = 1 + rng.below(pool_events as usize) as u64;
        specs.push(FaultSpec { site: Site::Pool, nth, count: 1, kind: FaultKind::PoolPanic });
    }
    FaultPlan { specs }
}

// ----------------------------------------------------------- live state

struct SiteState {
    events: AtomicU64,
    injected: AtomicU64,
}

impl SiteState {
    fn new() -> SiteState {
        SiteState { events: AtomicU64::new(0), injected: AtomicU64::new(0) }
    }
}

struct PlanState {
    specs: Vec<FaultSpec>,
    sites: [SiteState; 3],
    /// `site@event=kind` lines in fire order — the replayable trace.
    trace: Mutex<Vec<String>>,
}

impl PlanState {
    fn new(plan: &FaultPlan) -> PlanState {
        PlanState {
            specs: plan.specs.clone(),
            sites: [SiteState::new(), SiteState::new(), SiteState::new()],
            trace: Mutex::new(Vec::new()),
        }
    }

    /// Count one `site` event; return the armed kind if a spec's window
    /// covers it.
    fn fire(&self, site: Site) -> Option<FaultKind> {
        let e = self.sites[site.idx()].events.fetch_add(1, Ordering::Relaxed) + 1;
        for s in &self.specs {
            if s.site == site && e >= s.nth && e - s.nth < s.count {
                self.sites[site.idx()].injected.fetch_add(1, Ordering::Relaxed);
                self.trace
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .push(format!("{}@{}={}", site.name(), e, s.kind.label()));
                return Some(s.kind);
            }
        }
        None
    }
}

thread_local! {
    static ACTIVE: RefCell<Option<Arc<PlanState>>> = RefCell::new(None);
}

/// Counters + trace of one scope — the receipts `fasp chaos` compares
/// across replays.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultReport {
    /// Events observed per site, [`Site::ALL`] order.
    pub events: [u64; 3],
    /// Faults injected per site, [`Site::ALL`] order.
    pub injected: [u64; 3],
    /// `site@event=kind` lines in fire order.
    pub trace: Vec<String>,
}

impl FaultReport {
    pub fn events_at(&self, site: Site) -> u64 {
        self.events[site.idx()]
    }

    pub fn injected_at(&self, site: Site) -> u64 {
        self.injected[site.idx()]
    }

    pub fn total_injected(&self) -> u64 {
        self.injected.iter().sum()
    }
}

/// RAII scope: the plan is live on the installing thread (and on any
/// thread that [`adopt`]s its [`handle`]) until drop. Scopes nest; drop
/// restores the previous scope.
pub struct FaultScope {
    state: Arc<PlanState>,
    prev: Option<Arc<PlanState>>,
}

/// Make `plan` the active fault plan on this thread. An empty plan is
/// the *counting* scope: no faults fire, but events still tally — the
/// input [`synth_serve_plan`] needs.
pub fn install(plan: &FaultPlan) -> FaultScope {
    let state = Arc::new(PlanState::new(plan));
    let prev = ACTIVE.with(|a| a.borrow_mut().replace(state.clone()));
    FaultScope { state, prev }
}

impl FaultScope {
    pub fn report(&self) -> FaultReport {
        let s = &self.state;
        FaultReport {
            events: [0, 1, 2].map(|i| s.sites[i].events.load(Ordering::Relaxed)),
            injected: [0, 1, 2].map(|i| s.sites[i].injected.load(Ordering::Relaxed)),
            trace: s.trace.lock().unwrap_or_else(|p| p.into_inner()).clone(),
        }
    }
}

impl Drop for FaultScope {
    fn drop(&mut self) {
        ACTIVE.with(|a| *a.borrow_mut() = self.prev.take());
    }
}

/// Opaque carrier of this thread's active scope, for threads the
/// runtime itself spawns on a faulted path (shard prefetch). Cheap to
/// clone; empty when no scope is active.
#[derive(Clone, Default)]
pub struct FaultHandle(Option<Arc<PlanState>>);

/// Capture the calling thread's scope (empty handle when none).
pub fn handle() -> FaultHandle {
    FaultHandle(ACTIVE.with(|a| a.borrow().clone()))
}

/// Guard making a captured [`handle`] active on this thread until drop.
/// An empty handle is a no-op guard.
pub struct AdoptGuard {
    prev: Option<Arc<PlanState>>,
    installed: bool,
}

pub fn adopt(h: FaultHandle) -> AdoptGuard {
    match h.0 {
        Some(state) => {
            let prev = ACTIVE.with(|a| a.borrow_mut().replace(state));
            AdoptGuard { prev, installed: true }
        }
        None => AdoptGuard { prev: None, installed: false },
    }
}

impl Drop for AdoptGuard {
    fn drop(&mut self) {
        if self.installed {
            let prev = self.prev.take();
            ACTIVE.with(|a| *a.borrow_mut() = prev);
        }
    }
}

fn fire_active(site: Site) -> Option<FaultKind> {
    ACTIVE
        .with(|a| a.borrow().as_ref().map(|st| st.fire(site)))
        .flatten()
}

// ----------------------------------------------------------- hook points

/// `runtime/store.rs` hook: one event per shard-read attempt; an armed
/// fault mutates the just-read bytes in place (corrupt/truncate trip
/// the caller's checksum verification; slow stalls without touching a
/// byte).
pub fn shard_read(bytes: &mut Vec<u8>) {
    match fire_active(Site::Shard) {
        Some(FaultKind::ShardCorrupt) => {
            if !bytes.is_empty() {
                let mid = bytes.len() / 2;
                bytes[mid] ^= 0xff;
            }
        }
        Some(FaultKind::ShardTruncate) => {
            let keep = bytes.len() / 2;
            bytes.truncate(keep);
        }
        Some(FaultKind::ShardSlow(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        _ => {}
    }
}

/// `util/pool.rs` hook: one event per top-level fan-out entry on the
/// issuing thread. `true` = this fan-out must raise an injected worker
/// panic (the pool itself raises it, so the injection lives outside the
/// R1-scoped request paths).
pub fn pool_fanout_bomb() -> bool {
    matches!(fire_active(Site::Pool), Some(FaultKind::PoolPanic))
}

/// `model/kv_arena.rs` hook: one event per allocating grow; an armed
/// exhaustion surfaces as the `Err` a genuinely empty free list would
/// produce, before any page moves.
pub fn arena_grow() -> Result<()> {
    if matches!(fire_active(Site::Arena), Some(FaultKind::ArenaExhaust)) {
        bail!("kv arena exhausted (injected fault)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_render_round_trip() {
        let text = "shard@2=corrupt, pool@7=panic*3, shard@4=slow:10, \
                    arena@5=exhaust*always,shard@1=truncate";
        let plan = FaultPlan::parse(text).unwrap();
        assert_eq!(plan.specs.len(), 5);
        assert_eq!(plan.specs[1].count, 3);
        assert_eq!(plan.specs[2].kind, FaultKind::ShardSlow(10));
        assert_eq!(plan.specs[3].count, u64::MAX);
        let rendered = plan.render();
        assert_eq!(FaultPlan::parse(&rendered).unwrap(), plan);
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        for bad in [
            "shard@0=corrupt",      // events are 1-based
            "shard@2",              // missing kind
            "disk@1=corrupt",       // unknown site
            "shard@1=explode",      // unknown kind
            "pool@1=corrupt",       // kind/site mismatch
            "shard@1=corrupt*0",    // zero count
            "shard@x=corrupt",      // bad event number
            "shard@1=slow:abc",     // bad slow arg
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn fire_window_covers_nth_through_count() {
        let plan = FaultPlan::parse("arena@3=exhaust*2").unwrap();
        let scope = install(&plan);
        let fired: Vec<bool> = (0..6).map(|_| arena_grow().is_err()).collect();
        assert_eq!(fired, [false, false, true, true, false, false]);
        let r = scope.report();
        assert_eq!(r.events_at(Site::Arena), 6);
        assert_eq!(r.injected_at(Site::Arena), 2);
        assert_eq!(r.trace, vec!["arena@3=exhaust", "arena@4=exhaust"]);
    }

    #[test]
    fn persistent_fault_never_stops() {
        let plan = FaultPlan::parse("pool@2=panic*always").unwrap();
        let scope = install(&plan);
        let fired: Vec<bool> = (0..5).map(|_| pool_fanout_bomb()).collect();
        assert_eq!(fired, [false, true, true, true, true]);
        assert_eq!(scope.report().total_injected(), 4);
    }

    #[test]
    fn hooks_are_inert_without_a_scope() {
        assert!(!pool_fanout_bomb());
        assert!(arena_grow().is_ok());
        let mut bytes = vec![1u8, 2, 3, 4];
        shard_read(&mut bytes);
        assert_eq!(bytes, [1, 2, 3, 4]);
    }

    #[test]
    fn scope_is_thread_local_unless_adopted() {
        let plan = FaultPlan::parse("arena@1=exhaust*always").unwrap();
        let scope = install(&plan);
        assert!(arena_grow().is_err());

        // a plain thread sees no scope...
        let bare = std::thread::spawn(|| arena_grow().is_ok()).join().unwrap();
        assert!(bare, "foreign thread saw someone else's fault plan");

        // ...but an adopting thread shares the counters
        let h = handle();
        let adopted = std::thread::spawn(move || {
            let _g = adopt(h);
            arena_grow().is_err()
        })
        .join()
        .unwrap();
        assert!(adopted, "adopted thread missed the plan");
        assert_eq!(scope.report().events_at(Site::Arena), 3);
        assert_eq!(scope.report().injected_at(Site::Arena), 2);
    }

    #[test]
    fn scopes_nest_and_restore() {
        let outer = install(&FaultPlan::parse("arena@1=exhaust").unwrap());
        assert!(arena_grow().is_err());
        {
            let inner = install(&FaultPlan::default());
            assert!(arena_grow().is_ok(), "inner counting scope must not fire");
            assert_eq!(inner.report().events_at(Site::Arena), 1);
        }
        // outer scope restored; its one-shot already spent
        assert!(arena_grow().is_ok());
        assert_eq!(outer.report().events_at(Site::Arena), 2);
    }

    #[test]
    fn shard_faults_mutate_bytes_deterministically() {
        let scope = install(&FaultPlan::parse("shard@1=corrupt,shard@2=truncate").unwrap());
        let mut a = vec![0u8; 8];
        shard_read(&mut a);
        assert_eq!(a[4], 0xff, "corrupt flips the middle byte");
        let mut b = vec![0u8; 8];
        shard_read(&mut b);
        assert_eq!(b.len(), 4, "truncate halves the payload");
        assert_eq!(scope.report().injected, [2, 0, 0]);
    }

    #[test]
    fn synth_plan_is_seed_deterministic() {
        let a = synth_serve_plan(42, 100, 20, 2);
        let b = synth_serve_plan(42, 100, 20, 2);
        assert_eq!(a, b);
        assert_eq!(a.specs.len(), 3);
        assert!(a.specs.iter().all(|s| s.count == 1));
        let c = synth_serve_plan(43, 100, 20, 2);
        assert_ne!(a, c, "different seeds should move the fault points");
        // no pool events → no pool faults, arena fault still placed
        let d = synth_serve_plan(42, 0, 20, 2);
        assert_eq!(d.specs.len(), 1);
        assert_eq!(d.specs[0].site, Site::Arena);
    }
}
