//! Model zoo: weight storage (packed, manifest-ordered), deterministic
//! initialization, checkpoints, the host forward/backward (the runtime's
//! execution engine and the numerics baseline), the KV-cached
//! autoregressive decode engine (per-session ring caches and the serve
//! engine's paged KV arena), the pruning mask bookkeeping, and the
//! compact (physically sliced) export path.

pub mod weights;
pub mod host;
pub mod host_grad;
pub mod decode;
pub mod kv_arena;
pub mod mask;
pub mod compact;
pub mod spec_decode;
pub mod zoo;

pub use compact::CompactModel;
pub use decode::{GenerateOpts, Generation, KvCache, Sampler};
pub use spec_decode::{SpecGeneration, SpecOpts};
pub use kv_arena::{KvArena, PagedKv};
pub use mask::PruneMask;
pub use weights::{
    DenseParams, PackCache, PackedDenseParams, PackedWeights, ParamSource, Weights,
};
