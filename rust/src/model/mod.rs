//! Model zoo: weight storage (packed, manifest-ordered), deterministic
//! initialization, checkpoints, a host-side reference forward (numerics
//! cross-check for the PJRT path + offline fallback), and the pruning
//! mask bookkeeping.

pub mod weights;
pub mod host;
pub mod mask;
pub mod zoo;

pub use mask::PruneMask;
pub use weights::Weights;
