//! Zoo registry: maps the paper's model axis onto the in-repo configs and
//! owns checkpoint paths. The paper's sizes and our analogs
//! (DESIGN.md substitution table):
//!
//! | paper        | zoo           |
//! |--------------|---------------|
//! | OPT-125M     | `opt_tiny`    |
//! | OPT-1.3B     | `opt_small`   |
//! | OPT-2.7B     | `opt_medium`  |
//! | LLaMA-7B     | `llama_tiny`* |
//! | LLaMA-13B    | `llama_small` |
//! | LLaMA-30B    | `llama_medium`|
//!
//! *size ordering is what matters: each family spans three sizes.

use std::path::PathBuf;

pub const OPT_MODELS: [&str; 3] = ["opt_tiny", "opt_small", "opt_medium"];
pub const LLAMA_MODELS: [&str; 3] = ["llama_tiny", "llama_small", "llama_medium"];

pub fn all_models() -> Vec<&'static str> {
    OPT_MODELS.iter().chain(LLAMA_MODELS.iter()).copied().collect()
}

/// Paper-size label for table headers.
pub fn paper_label(model: &str) -> &'static str {
    match model {
        "opt_tiny" => "OPT-125M*",
        "opt_small" => "OPT-1.3B*",
        "opt_medium" => "OPT-2.7B*",
        "llama_tiny" => "LLaMA-7B*",
        "llama_small" => "LLaMA-13B*",
        "llama_medium" => "LLaMA-30B*",
        _ => "?",
    }
}

/// Default training budget per model (steps, lr) — sized for the 1-core
/// CPU testbed; enough for the corpus structure to be learned so pruning
/// damage is measurable.
pub fn train_budget(model: &str) -> (usize, f32) {
    match model {
        m if m.ends_with("tiny") => (260, 3e-3),
        m if m.ends_with("small") => (220, 1.5e-3),
        _ => (140, 1e-3),
    }
}

pub fn checkpoint_path(model: &str) -> PathBuf {
    crate::checkpoints_dir().join(format!("{model}.ftns"))
}
