//! Speculative decoding with a FASP-pruned draft model — the paper's
//! *compression* artifact turned into a *lossless speedup* of the
//! uncompressed model.
//!
//! FASP pruning manufactures the draft for free: a compact export
//! shares the vocab, tokenizer and provenance of its dense parent, runs
//! strictly cheaper per token (sliced FFN/OV matvecs), and keeps a
//! strictly smaller KV cache (sliced `d_ov`). The loop here is the
//! standard draft-then-verify scheme:
//!
//! 1. the **draft** proposes up to `draft_k` tokens autoregressively
//!    against its own [`KvCache`] ([`super::decode::decode_step_src`]);
//! 2. the **target** scores the committed tail plus every proposal in
//!    ONE chunked forward ([`super::decode::decode_chunk_src`]) — k+1
//!    positions per weight-panel stream instead of one;
//! 3. acceptance is **exact**:
//!    * greedy — the longest proposal prefix matching the target's
//!      argmaxes is accepted, then the target's own argmax is committed
//!      (correction on reject, bonus on full accept). Every committed
//!      token is a target argmax conditioned on target argmaxes, so the
//!      output is **bit-identical to target-only `generate` by
//!      construction** (the chunk≡steps bitwise contract closes the
//!      loop — `rust/tests/test_spec_decode.rs` locks it);
//!    * sampled (top-k) — standard rejection sampling: accept proposal
//!      `x` with `min(1, p_target(x)/p_draft(x))`, on reject resample
//!      from the normalized residual `max(0, p_target - p_draft)`, on
//!      full accept draw the bonus token from `p_target`. The committed
//!      sequence is distributed exactly as target-only sampling (the
//!      Leviathan et al. identity) and is seed-reproducible over the
//!      per-session [`Rng`] streams;
//! 4. both caches [`KvCache::truncate`] back to the committed prefix —
//!    rejected positions are forgotten, never re-read.
//!
//! This module is a request path: every failure mode (mismatched vocab,
//! empty prompt, cache overflow, all-non-finite logits in the sampled
//! path) is a proper `Err`, and it performs no wall-clock reads — the
//! perf receipts live in `eval::speed::compare_speculative`
//! (`BENCH_spec.json`), which times whole calls from outside.

use super::decode::{
    check_generate_prompt, decode_chunk_src, decode_step_src, prefill_src, sample_row, KvCache,
    Sampler,
};
use super::weights::ParamSource;
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;
use anyhow::Result;

/// Speculative generation settings.
#[derive(Clone, Copy, Debug)]
pub struct SpecOpts {
    /// Tokens to generate (>= 1).
    pub max_new: usize,
    /// Max tokens the draft proposes per verification round (>= 1).
    pub draft_k: usize,
    /// The *target* selection rule — greedy reproduces target-only
    /// `generate` bitwise; top-k samples the target distribution
    /// exactly. The draft proposes under the same rule.
    pub sampler: Sampler,
    /// Seed of the sampling [`Rng`] streams (unused by greedy).
    pub seed: u64,
}

impl Default for SpecOpts {
    fn default() -> Self {
        SpecOpts { max_new: 16, draft_k: 4, sampler: Sampler::Greedy, seed: 0 }
    }
}

/// One finished speculative generation: the tokens plus the
/// acceptance/work counters the perf receipt reports. No wall-times
/// here by design (this module is wall-clock-free); timing wraps the
/// whole call in `eval::speed`.
pub struct SpecGeneration {
    /// [1, prompt_len + generated] token ids (prompt included).
    pub tokens: IntTensor,
    pub prompt_len: usize,
    pub generated: usize,
    /// Draft tokens proposed across all rounds.
    pub proposed: usize,
    /// Proposals accepted by the target.
    pub accepted: usize,
    /// Chunked target verification forwards executed.
    pub chunks: usize,
    /// Single-token draft decode steps executed.
    pub draft_steps: usize,
    /// Allocated K/V bytes of the target's cache.
    pub target_kv_bytes: usize,
    /// Allocated K/V bytes of the draft's (OV-sliced, strictly smaller
    /// at equal capacity) cache.
    pub draft_kv_bytes: usize,
}

impl SpecGeneration {
    /// Fraction of draft proposals the target accepted (1.0 when
    /// nothing was proposed — `max_new` 1 never needs a draft).
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            1.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

/// The sampling distribution behind [`sample_row`]'s top-k draw, made
/// explicit: candidate token ids in (logit desc, index asc) order with
/// normalized probabilities. Mirrors `sample_row`'s candidate
/// construction exactly — non-finite logits sort last and are dropped
/// — so "the target distribution" below means precisely what
/// target-only `generate` samples from. All-non-finite logits are a
/// proper `Err` here (request path — R1), not a panic.
fn topk_dist(logits: &[f32], k: usize, temperature: f32) -> Result<(Vec<usize>, Vec<f64>)> {
    anyhow::ensure!(!logits.is_empty(), "topk_dist: empty logits");
    let k = k.clamp(1, logits.len());
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_unstable_by(|&a, &b| {
        use std::cmp::Ordering;
        match (logits[a].is_finite(), logits[b].is_finite()) {
            (true, true) => logits[b].total_cmp(&logits[a]).then(a.cmp(&b)),
            (true, false) => Ordering::Less,
            (false, true) => Ordering::Greater,
            (false, false) => a.cmp(&b),
        }
    });
    idx.truncate(k);
    while idx.len() > 1 && !logits[idx[idx.len() - 1]].is_finite() {
        idx.pop();
    }
    anyhow::ensure!(
        logits[idx[0]].is_finite(),
        "topk_dist: no finite logit to sample (all NaN/inf)"
    );
    let temp = temperature.max(1e-6) as f64;
    let m = logits[idx[0]] as f64;
    let mut w: Vec<f64> = Vec::with_capacity(idx.len());
    let mut total = 0.0f64;
    for &i in &idx {
        let e = ((logits[i] as f64 - m) / temp).exp();
        total += e;
        w.push(e);
    }
    // total >= 1 always (the max-logit candidate contributes exp(0))
    for e in w.iter_mut() {
        *e /= total;
    }
    Ok((idx, w))
}

/// Probability of `token` under an explicit candidate distribution
/// (0 outside the candidate set). Candidate sets are at most k long,
/// so a linear scan is the right tool (and keeps iteration order
/// deterministic — D1 bans hashing anyway).
fn prob_of(idx: &[usize], p: &[f64], token: usize) -> f64 {
    for (i, &c) in idx.iter().enumerate() {
        if c == token {
            return p[i];
        }
    }
    0.0
}

/// The speculative generation loop over any pair of [`ParamSource`]s
/// (dense, compact, packed or streamed — draft and target are
/// independent sources). Single sequence (b = 1): acceptance lengths
/// differ per sequence, so batching would serialize on the slowest
/// lane anyway.
///
/// Invariants the loop maintains between rounds (`committed` = prompt
/// plus generated-so-far, length N):
/// * the target cache holds exactly N-1 positions — everything
///   committed except the newest token, which the next verification
///   chunk feeds first (mirroring `generate`, which never feeds its
///   final sampled token);
/// * the draft cache holds a prefix of the committed tokens (it can
///   trail by up to two after a fully-accepted round: the last
///   proposal plus the bonus token), caught up by single steps before
///   the next proposal;
/// * rejected proposals' cache rows are rolled back with
///   [`KvCache::truncate`] on both sides and never read again.
pub fn generate_speculative_src<T: ParamSource, D: ParamSource>(
    target: &mut T,
    draft: &mut D,
    prompt: &IntTensor,
    opts: &SpecOpts,
) -> Result<SpecGeneration> {
    check_generate_prompt(prompt)?;
    anyhow::ensure!(
        prompt.shape[0] == 1,
        "speculative decode runs one sequence at a time, got batch {}",
        prompt.shape[0]
    );
    anyhow::ensure!(opts.max_new >= 1, "speculative decode wants max_new >= 1");
    anyhow::ensure!(opts.draft_k >= 1, "speculative decode wants draft_k >= 1");
    let t_vocab = target.spec().vocab;
    anyhow::ensure!(
        draft.spec().vocab == t_vocab && t_vocab >= 1,
        "draft model '{}' (vocab {}) cannot draft for target '{}' (vocab {}) \
         — speculative decode needs a draft sharing the target's token space",
        draft.spec().name,
        draft.spec().vocab,
        target.spec().name,
        t_vocab
    );

    let t0 = prompt.shape[1];
    // same exact sizing as `generate`: the final sampled token is never
    // fed back, and the draft never proposes past max_new - 1
    let cap = t0 + opts.max_new - 1;
    let mut tcache = KvCache::for_spec(target.spec(), 1, cap)?;
    let mut dcache = KvCache::for_spec(draft.spec(), 1, cap)?;

    let mut rng = Rng::new(opts.seed);
    let mut draft_rng = rng.fork(0xd4a57);

    let tlogits = prefill_src(target, prompt, &mut tcache)?;
    let _ = prefill_src(draft, prompt, &mut dcache)?;

    let mut committed: Vec<i32> = prompt.data.clone();
    // the first token is sampled from the target's prefill logits —
    // exactly `generate`'s first draw
    committed.push(sample_row(tlogits.row(0), opts.sampler, &mut rng) as i32);

    let mut proposed = 0usize;
    let mut accepted = 0usize;
    let mut chunks = 0usize;
    let mut draft_steps = 0usize;

    while committed.len() < t0 + opts.max_new {
        let n = committed.len();
        let remaining = t0 + opts.max_new - n;
        let kp = opts.draft_k.min(remaining - 1);

        // ---- draft proposes kp tokens against its own smaller cache
        let mut proposals: Vec<i32> = Vec::with_capacity(kp);
        let mut draft_dists: Vec<(Vec<usize>, Vec<f64>)> = Vec::new();
        if kp > 0 {
            // catch up on committed tokens the draft has not seen yet
            let mut dlogits: Option<Tensor> = None;
            for j in dcache.len()..n {
                draft.rewind()?;
                let tok = IntTensor::new(vec![1, 1], vec![committed[j]]);
                dlogits = Some(decode_step_src(draft, &tok, &mut dcache)?);
                draft_steps += 1;
            }
            let mut dl = dlogits.ok_or_else(|| {
                anyhow::anyhow!(
                    "speculative decode: draft cache ({} positions) ran ahead \
                     of the committed tokens ({n}) — loop invariant broken",
                    dcache.len()
                )
            })?;
            for i in 0..kp {
                let d = match opts.sampler {
                    Sampler::Greedy => {
                        sample_row(dl.row(0), Sampler::Greedy, &mut draft_rng) as i32
                    }
                    Sampler::TopK { k, temperature } => {
                        let (idx, p) = topk_dist(dl.row(0), k, temperature)?;
                        let d = idx[draft_rng.categorical(&p)] as i32;
                        draft_dists.push((idx, p));
                        d
                    }
                };
                proposals.push(d);
                if i + 1 < kp {
                    draft.rewind()?;
                    let tok = IntTensor::new(vec![1, 1], vec![d]);
                    dl = decode_step_src(draft, &tok, &mut dcache)?;
                    draft_steps += 1;
                }
            }
        }
        proposed += kp;

        // ---- target verifies tail + all proposals in ONE chunk: row i
        // holds the target's next-token logits after chunk token i
        target.rewind()?;
        let mut chunk_toks: Vec<i32> = Vec::with_capacity(kp + 1);
        chunk_toks.push(committed[n - 1]);
        chunk_toks.extend_from_slice(&proposals);
        let chunk = IntTensor::new(vec![1, kp + 1], chunk_toks);
        let logits = decode_chunk_src(target, &chunk, &mut tcache)?;
        chunks += 1;

        // ---- exact acceptance + one committed token per round
        let mut a = 0usize;
        let mut rejected = false;
        match opts.sampler {
            Sampler::Greedy => {
                // longest prefix of proposals matching the target's own
                // argmaxes; first mismatch commits the target's choice
                while a < kp {
                    let want = sample_row(logits.row(a), Sampler::Greedy, &mut rng) as i32;
                    committed.push(want);
                    if want == proposals[a] {
                        accepted += 1;
                        a += 1;
                    } else {
                        rejected = true;
                        break;
                    }
                }
                if !rejected {
                    // full accept: the bonus token is free — the chunk
                    // already scored the position after the last proposal
                    committed.push(sample_row(logits.row(kp), Sampler::Greedy, &mut rng) as i32);
                }
            }
            Sampler::TopK { k, temperature } => {
                while a < kp {
                    let (tidx, tp) = topk_dist(logits.row(a), k, temperature)?;
                    let (didx, dp) = (&draft_dists[a].0, &draft_dists[a].1);
                    let x = proposals[a] as usize;
                    let pt = prob_of(&tidx, &tp, x);
                    let pd = prob_of(didx, dp, x);
                    let accept_p = if pd > 0.0 {
                        (pt / pd).min(1.0)
                    } else if pt > 0.0 {
                        1.0
                    } else {
                        0.0
                    };
                    if rng.f64() < accept_p {
                        committed.push(proposals[a]);
                        accepted += 1;
                        a += 1;
                    } else {
                        // resample from the normalized residual
                        // max(0, p_target - p_draft) over the target's
                        // candidate set (sanitized: clamped at 0, with
                        // a p_target fallback if the residual vanishes)
                        let mut w: Vec<f64> = Vec::with_capacity(tidx.len());
                        let mut total = 0.0f64;
                        for (ci, &cand) in tidx.iter().enumerate() {
                            let mut r = (tp[ci] - prob_of(didx, dp, cand)).max(0.0);
                            if !r.is_finite() {
                                r = 0.0;
                            }
                            total += r;
                            w.push(r);
                        }
                        let pick = if total > 0.0 && total.is_finite() {
                            tidx[rng.categorical(&w)]
                        } else {
                            tidx[rng.categorical(&tp)]
                        };
                        committed.push(pick as i32);
                        rejected = true;
                        break;
                    }
                }
                if !rejected {
                    let (tidx, tp) = topk_dist(logits.row(kp), k, temperature)?;
                    committed.push(tidx[rng.categorical(&tp)] as i32);
                }
            }
        }

        // ---- roll both caches back to the committed prefix (the
        // target may keep every chunk position on a full accept; the
        // draft may legitimately trail and is clamped, never extended)
        let n_new = committed.len();
        tcache.truncate(n_new - 1)?;
        dcache.truncate((n_new - 1).min(dcache.len()))?;
    }

    let total = t0 + opts.max_new;
    Ok(SpecGeneration {
        tokens: IntTensor::new(vec![1, total], committed),
        prompt_len: t0,
        generated: opts.max_new,
        proposed,
        accepted,
        chunks,
        draft_steps,
        target_kv_bytes: tcache.kv_bytes(),
        draft_kv_bytes: dcache.kv_bytes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_dist_matches_sample_row_candidates() {
        let logits = [5.0f32, 4.0, 3.0, -10.0, f32::NAN, -30.0];
        let (idx, p) = topk_dist(&logits, 3, 1.0).unwrap();
        assert_eq!(idx, vec![0, 1, 2]);
        let total: f64 = p.iter().fold(0.0, |acc, &x| acc + x);
        assert!((total - 1.0).abs() < 1e-12, "probs normalize, got {total}");
        assert!(p[0] > p[1] && p[1] > p[2]);
    }

    #[test]
    fn topk_dist_drops_nonfinite_tail_and_errs_on_all_nonfinite() {
        let logits = [1.0f32, f32::NAN, f32::INFINITY];
        let (idx, _) = topk_dist(&logits, 3, 1.0).unwrap();
        assert_eq!(idx, vec![0], "non-finite candidates dropped");
        let bad = [f32::NAN, f32::NEG_INFINITY];
        assert!(topk_dist(&bad, 2, 1.0).is_err());
    }

    #[test]
    fn prob_of_is_zero_outside_candidates() {
        let idx = vec![4usize, 9];
        let p = vec![0.75, 0.25];
        assert_eq!(prob_of(&idx, &p, 4), 0.75);
        assert_eq!(prob_of(&idx, &p, 9), 0.25);
        assert_eq!(prob_of(&idx, &p, 1), 0.0);
    }
}
