//! Weight store: a single packed f32 vector in manifest parameter order
//! (the runtime currency), with named 2-D/1-D views for the pruning math.

use crate::runtime::manifest::ModelSpec;
use crate::tensor::io::TensorFile;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Where a forward pass gets its parameters from. The host forward
/// ([`super::host::forward_nll_src`]) pulls globals (`tok_emb`,
/// `lnf_*`, …) via [`ParamSource::get`] and per-layer tensors via
/// [`ParamSource::get_l`], calling [`ParamSource::layer_done`] once it
/// has consumed a layer — layers are always visited in order 0..L.
///
/// Two sources exist: [`DenseParams`] (a fully resident [`Weights`],
/// the classic path) and `runtime::store::StreamingParams` (per-layer
/// shards loaded lazily with background prefetch, peak-resident weights
/// of O(one layer)). Both hand back the same bytes, so outputs are
/// bit-identical by construction.
pub trait ParamSource {
    fn spec(&self) -> &ModelSpec;

    /// A non-layer (global) parameter by name.
    fn get(&mut self, name: &str) -> Result<Tensor>;

    /// A layer-scoped parameter, e.g. `get_l(2, "wq")`.
    fn get_l(&mut self, l: usize, short: &str) -> Result<Tensor>;

    /// The forward is done reading layer `l` (streaming sources release
    /// the shard here; dense sources ignore it).
    fn layer_done(&mut self, _l: usize) -> Result<()> {
        Ok(())
    }

    /// Reset to layer 0 for another in-order pass — autoregressive
    /// decode runs one pass per generated token over the same source.
    /// Dense sources are stateless (no-op); streaming sources restart
    /// their prefetch pipeline while keeping the embed shard resident.
    fn rewind(&mut self) -> Result<()> {
        Ok(())
    }
}

/// The trivial [`ParamSource`]: every parameter is already resident.
pub struct DenseParams<'a>(pub &'a Weights);

impl ParamSource for DenseParams<'_> {
    fn spec(&self) -> &ModelSpec {
        &self.0.spec
    }
    fn get(&mut self, name: &str) -> Result<Tensor> {
        self.0.get(name)
    }
    fn get_l(&mut self, l: usize, short: &str) -> Result<Tensor> {
        self.0.get_l(l, short)
    }
}

#[derive(Clone)]
pub struct Weights {
    pub spec: ModelSpec,
    /// Packed parameters, `spec.params` order.
    pub packed: Tensor,
    offsets: BTreeMap<String, (usize, Vec<usize>)>,
}

impl Weights {
    fn build_offsets(spec: &ModelSpec) -> BTreeMap<String, (usize, Vec<usize>)> {
        let mut map = BTreeMap::new();
        let mut off = 0usize;
        for (name, shape) in &spec.params {
            let n: usize = shape.iter().product();
            map.insert(name.clone(), (off, shape.clone()));
            off += n;
        }
        map
    }

    /// All-zero weights (useful for tests).
    pub fn zeros(spec: &ModelSpec) -> Weights {
        Weights {
            spec: spec.clone(),
            packed: Tensor::zeros(&[spec.n_params_elems()]),
            offsets: Self::build_offsets(spec),
        }
    }

    /// Wrap an existing packed parameter vector (length-checked).
    pub fn from_packed(spec: &ModelSpec, data: Vec<f32>) -> Result<Weights> {
        anyhow::ensure!(
            data.len() == spec.n_params_elems(),
            "packed length {} != model {} ({})",
            data.len(),
            spec.n_params_elems(),
            spec.name,
        );
        Ok(Weights {
            spec: spec.clone(),
            packed: Tensor::new(vec![data.len()], data),
            offsets: Self::build_offsets(spec),
        })
    }

    /// Deterministic initialization: N(0, 0.02) for embeddings and linear
    /// weights (GPT-style), ones for norm gains, zeros for biases.
    pub fn init(spec: &ModelSpec, seed: u64) -> Weights {
        let mut w = Weights::zeros(spec);
        let mut rng = Rng::new(seed);
        for (name, shape) in spec.params.clone() {
            let n: usize = shape.iter().product();
            let is_gain = name.ends_with("ln1_g")
                || name.ends_with("ln2_g")
                || name.ends_with("lnf_g");
            let is_bias = shape.len() == 1 && !is_gain;
            let data = if is_gain {
                vec![1.0f32; n]
            } else if is_bias {
                vec![0.0f32; n]
            } else {
                // scale residual-path projections down by depth (GPT-2 trick)
                let base = 0.02f32;
                let std = if name.ends_with("wo") || name.ends_with("fc2") || name.ends_with("w_down") {
                    base / (2.0 * spec.n_layers as f32).sqrt()
                } else {
                    base
                };
                rng.normal_vec(n, std)
            };
            w.set_raw(&name, &data);
        }
        w
    }

    pub fn offset(&self, name: &str) -> Result<(usize, Vec<usize>)> {
        self.offsets
            .get(name)
            .cloned()
            .with_context(|| format!("param '{name}' not found"))
    }

    /// Copy a parameter out as a Tensor.
    pub fn get(&self, name: &str) -> Result<Tensor> {
        let (off, shape) = self.offset(name)?;
        let n: usize = shape.iter().product();
        Ok(Tensor::new(shape, self.packed.data[off..off + n].to_vec()))
    }

    /// Write a parameter back (shape-checked).
    pub fn set(&mut self, name: &str, t: &Tensor) -> Result<()> {
        let (off, shape) = self.offset(name)?;
        anyhow::ensure!(
            t.shape == shape,
            "set {name}: shape {:?} != {:?}",
            t.shape,
            shape
        );
        self.packed.data[off..off + t.numel()].copy_from_slice(&t.data);
        Ok(())
    }

    fn set_raw(&mut self, name: &str, data: &[f32]) {
        let (off, _) = self.offsets[name].clone();
        self.packed.data[off..off + data.len()].copy_from_slice(data);
    }

    /// Layer-scoped param name, e.g. `pname(2, "wq") == "layers.2.wq"`.
    pub fn pname(layer: usize, short: &str) -> String {
        format!("layers.{layer}.{short}")
    }

    pub fn get_l(&self, layer: usize, short: &str) -> Result<Tensor> {
        self.get(&Self::pname(layer, short))
    }

    pub fn set_l(&mut self, layer: usize, short: &str, t: &Tensor) -> Result<()> {
        self.set(&Self::pname(layer, short), t)
    }

    pub fn has(&self, name: &str) -> bool {
        self.offsets.contains_key(name)
    }

    /// Fraction of exactly-zero parameter entries (mask-sparsity probe).
    pub fn zero_fraction(&self) -> f64 {
        let z = self.packed.data.iter().filter(|&&x| x == 0.0).count();
        z as f64 / self.packed.numel().max(1) as f64
    }

    // ---- checkpoints -----------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut tf = TensorFile::new();
        tf.insert("packed", self.packed.clone());
        tf.insert("version", Tensor::scalar(1.0));
        tf.save(path)
    }

    pub fn load(spec: &ModelSpec, path: &Path) -> Result<Weights> {
        let tf = TensorFile::load(path)?;
        let packed = tf.get("packed")?.clone();
        anyhow::ensure!(
            packed.numel() == spec.n_params_elems(),
            "checkpoint size {} != model {} ({})",
            packed.numel(),
            spec.n_params_elems(),
            spec.name,
        );
        Ok(Weights {
            spec: spec.clone(),
            packed: Tensor::new(vec![spec.n_params_elems()], packed.data),
            offsets: Self::build_offsets(spec),
        })
    }
}
