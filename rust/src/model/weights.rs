//! Weight store: a single packed f32 vector in manifest parameter order
//! (the runtime currency), with named 2-D/1-D views for the pruning math
//! — plus the persistent pack cache ([`PackCache`] / [`PackedWeights`])
//! that holds every linear weight pre-packed in the kernel layout, built
//! exactly once per weight set and consumed by every forward, prefill
//! and decode step.

use crate::runtime::manifest::ModelSpec;
use crate::tensor::io::TensorFile;
use crate::tensor::pack::{PackedMat, Quant};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

/// Where a forward pass gets its parameters from. The host forward
/// ([`super::host::forward_nll_src`]) pulls globals (`tok_emb`,
/// `lnf_*`, …) via [`ParamSource::get`] and per-layer tensors via
/// [`ParamSource::get_l`], calling [`ParamSource::layer_done`] once it
/// has consumed a layer — layers are always visited in order 0..L.
///
/// Three sources exist: [`DenseParams`] (a fully resident [`Weights`],
/// the classic unpacked path), [`PackedDenseParams`] (resident weights
/// plus a [`PackCache`] of pre-packed linear weights — what
/// `Session::pack` builds once per weight set) and
/// `runtime::store::StreamingParams` (per-layer shards loaded lazily
/// with background prefetch that also packs the next layer while the
/// current one executes). All hand back the same bytes and the packed
/// and unpacked kernels share one reduction order, so outputs are
/// bit-identical across sources by construction.
pub trait ParamSource {
    fn spec(&self) -> &ModelSpec;

    /// A non-layer (global) parameter by name.
    fn get(&mut self, name: &str) -> Result<Tensor>;

    /// A layer-scoped parameter, e.g. `get_l(2, "wq")`.
    fn get_l(&mut self, l: usize, short: &str) -> Result<Tensor>;

    /// A pre-packed (transpose-free) view of the 2-D global weight
    /// `name` for the `x·Wᵀ` hot path, if this source holds one.
    /// `Ok(None)` sends the caller down the unpacked [`ParamSource::get`]
    /// path — packed and unpacked products are bit-identical by the
    /// kernel contract (`crate::tensor::pack`), so this is purely a
    /// latency decision, never a numerics one.
    fn get_packed(&mut self, _name: &str) -> Result<Option<Arc<PackedMat>>> {
        Ok(None)
    }

    /// Layer-scoped [`ParamSource::get_packed`].
    fn get_l_packed(&mut self, _l: usize, _short: &str) -> Result<Option<Arc<PackedMat>>> {
        Ok(None)
    }

    /// Gather embedding rows `ids` (one per output row) into a fresh
    /// [ids.len(), d] tensor. The default copies the whole table via
    /// [`ParamSource::get`]; resident sources override to gather
    /// straight from their backing store, so the per-forward (and
    /// per-decode-token) table copy disappears.
    fn embed_rows(&mut self, ids: &[i32]) -> Result<Tensor> {
        let te = self.get("tok_emb")?;
        gather_rows(&te.data, te.shape[0], te.shape[1], ids)
    }

    /// Visit rows [row0, row0+count) of the 2-D param `name` without
    /// copying the rest of the table (the OPT positional-embedding add).
    /// Default copies via [`ParamSource::get`]; resident sources
    /// override to borrow the rows in place.
    fn with_rows(
        &mut self,
        name: &str,
        row0: usize,
        count: usize,
        f: &mut dyn FnMut(&[f32]),
    ) -> Result<()> {
        let t = self.get(name)?;
        let (rows, c) = t.dims2();
        anyhow::ensure!(
            row0 + count <= rows,
            "rows [{row0}, {}) outside '{name}' [{rows}, {c}]",
            row0 + count
        );
        f(&t.data[row0 * c..(row0 + count) * c]);
        Ok(())
    }

    /// The forward is done reading layer `l` (streaming sources release
    /// the shard here; dense sources ignore it).
    fn layer_done(&mut self, _l: usize) -> Result<()> {
        Ok(())
    }

    /// Reset to layer 0 for another in-order pass — autoregressive
    /// decode runs one pass per generated token over the same source.
    /// Dense sources are stateless (no-op); streaming sources restart
    /// their prefetch pipeline while keeping the embed shard resident.
    fn rewind(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Row gather shared by every `embed_rows` implementation: table is a
/// row-major [rows, d] slice; ids are validated loudly (the callers
/// validate against the vocab first, this guards the table itself).
pub(crate) fn gather_rows(table: &[f32], rows: usize, d: usize, ids: &[i32]) -> Result<Tensor> {
    debug_assert_eq!(table.len(), rows * d);
    let mut x = Tensor::zeros(&[ids.len(), d]);
    for (r, &id) in ids.iter().enumerate() {
        let id = id as usize;
        anyhow::ensure!(id < rows, "embedding row {id} outside table [{rows}, {d}]");
        x.row_mut(r).copy_from_slice(&table[id * d..(id + 1) * d]);
    }
    Ok(x)
}

/// The trivial [`ParamSource`]: every parameter is already resident
/// (unpacked — linears pay the per-call `matmul_bt` path; the baseline
/// the packed benches compare against).
pub struct DenseParams<'a>(pub &'a Weights);

impl ParamSource for DenseParams<'_> {
    fn spec(&self) -> &ModelSpec {
        &self.0.spec
    }
    fn get(&mut self, name: &str) -> Result<Tensor> {
        self.0.get(name)
    }
    fn get_l(&mut self, l: usize, short: &str) -> Result<Tensor> {
        self.0.get_l(l, short)
    }
    fn embed_rows(&mut self, ids: &[i32]) -> Result<Tensor> {
        let (table, shape) = self.0.view("tok_emb")?;
        gather_rows(table, shape[0], shape[1], ids)
    }
    fn with_rows(
        &mut self,
        name: &str,
        row0: usize,
        count: usize,
        f: &mut dyn FnMut(&[f32]),
    ) -> Result<()> {
        dense_with_rows(self.0, name, row0, count, f)
    }
}

fn dense_with_rows(
    w: &Weights,
    name: &str,
    row0: usize,
    count: usize,
    f: &mut dyn FnMut(&[f32]),
) -> Result<()> {
    let (data, shape) = w.view(name)?;
    anyhow::ensure!(shape.len() == 2, "'{name}' is not 2-D: {shape:?}");
    let (rows, c) = (shape[0], shape[1]);
    anyhow::ensure!(
        row0 + count <= rows,
        "rows [{row0}, {}) outside '{name}' [{rows}, {c}]",
        row0 + count
    );
    f(&data[row0 * c..(row0 + count) * c]);
    Ok(())
}

// ------------------------------------------------------------ pack cache

/// The per-layer weights that feed `linear` (and therefore pack) for a
/// family — everything else (norm gains, biases, embeddings) stays raw.
pub fn linear_shorts(family: &str) -> &'static [&'static str] {
    if family == "opt" {
        &["wq", "wk", "wv", "wo", "fc1", "fc2"]
    } else {
        &["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"]
    }
}

/// Every linear weight of a model pre-packed in the kernel layout
/// ([`PackedMat`], A·Bᵀ orientation) plus the tied logits head
/// (`tok_emb`, the largest per-forward transpose of all). Built once
/// per weight set on the ambient pool ([`PackCache::build`]) — pack
/// bytes are pool-width-independent — and shared via `Arc` so decode
/// loops clone handles, never panels.
pub struct PackCache {
    global: BTreeMap<String, Arc<PackedMat>>,
    layers: Vec<BTreeMap<String, Arc<PackedMat>>>,
    quant: Quant,
}

impl PackCache {
    /// Pack every linear weight (per [`linear_shorts`]) and the tied
    /// head of `w`, fanning the per-weight packs out on the ambient
    /// worker pool. Each pack is a pure relayout, so the cache holds
    /// identical bytes at any pool width. Exact f32 panels — the
    /// reference every bit-identity contract measures against.
    pub fn build(w: &Weights) -> PackCache {
        Self::build_q(w, Quant::F32)
    }

    /// [`PackCache::build`] with an explicit panel dtype: `Int8`
    /// quantizes each panel at pack time (~0.27× resident bytes,
    /// bounded error — see `crate::tensor::pack`). Quantized bytes are
    /// pool-width-independent just like the f32 relayout
    /// (`test_backend.rs`).
    pub fn build_q(w: &Weights, quant: Quant) -> PackCache {
        let shorts = linear_shorts(&w.spec.family);
        // job list: (layer/global target, packed-vector offset, rows, cols)
        struct Job {
            layer: Option<(usize, String)>,
            name: String,
            off: usize,
            rows: usize,
            cols: usize,
        }
        let mut jobs: Vec<Job> = Vec::new();
        for (name, shape) in &w.spec.params {
            if shape.len() != 2 {
                continue;
            }
            let layer = if name == "tok_emb" {
                None
            } else if let Some(rest) = name.strip_prefix("layers.") {
                let mut it = rest.splitn(2, '.');
                match (it.next().and_then(|s| s.parse::<usize>().ok()), it.next()) {
                    (Some(l), Some(short)) if shorts.iter().any(|s| *s == short) => {
                        Some((l, short.to_string()))
                    }
                    _ => continue,
                }
            } else {
                continue;
            };
            let (off, _) = w.offset(name).expect("spec param has an offset");
            jobs.push(Job { layer, name: name.clone(), off, rows: shape[0], cols: shape[1] });
        }
        let pool = crate::util::pool::current();
        let packed: Vec<Arc<PackedMat>> = pool.map(jobs.len(), |i| {
            let j = &jobs[i];
            Arc::new(PackedMat::pack_bt_raw_q(
                &w.packed.data[j.off..j.off + j.rows * j.cols],
                j.rows,
                j.cols,
                quant,
            ))
        });
        let mut cache = PackCache {
            global: BTreeMap::new(),
            layers: (0..w.spec.n_layers).map(|_| BTreeMap::new()).collect(),
            quant,
        };
        for (job, pm) in jobs.into_iter().zip(packed) {
            match job.layer {
                Some((l, short)) => {
                    cache.layers[l].insert(short, pm);
                }
                None => {
                    cache.global.insert(job.name, pm);
                }
            }
        }
        cache
    }

    pub fn get(&self, name: &str) -> Option<Arc<PackedMat>> {
        self.global.get(name).cloned()
    }

    pub fn get_l(&self, l: usize, short: &str) -> Option<Arc<PackedMat>> {
        self.layers.get(l).and_then(|m| m.get(short).cloned())
    }

    /// Panel dtype every pack in this cache was built with.
    pub fn quant(&self) -> Quant {
        self.quant
    }

    /// Number of packed weights held.
    pub fn count(&self) -> usize {
        self.global.len() + self.layers.iter().map(|m| m.len()).sum::<usize>()
    }

    /// Resident bytes of all packed panels (the pack-cache receipt).
    pub fn bytes(&self) -> usize {
        self.global.values().map(|p| p.bytes()).sum::<usize>()
            + self
                .layers
                .iter()
                .flat_map(|m| m.values())
                .map(|p| p.bytes())
                .sum::<usize>()
    }
}

/// A weight set bundled with its pack cache — the operator plan
/// `Session::pack` builds once and every entry, prefill and decode step
/// consumes. The raw [`Weights`] stay resident for the paths that need
/// original layouts (embedding gathers, backward, restoration).
pub struct PackedWeights {
    pub w: Weights,
    pub packs: PackCache,
}

impl PackedWeights {
    /// Build the (exact f32) pack cache for `w` on the ambient pool.
    pub fn new(w: Weights) -> PackedWeights {
        Self::new_q(w, Quant::F32)
    }

    /// [`PackedWeights::new`] with an explicit panel dtype.
    pub fn new_q(w: Weights, quant: Quant) -> PackedWeights {
        let packs = PackCache::build_q(&w, quant);
        PackedWeights { w, packs }
    }

    /// A [`ParamSource`] over this plan (cheap; borrows both parts).
    pub fn source(&self) -> PackedDenseParams<'_> {
        PackedDenseParams { w: &self.w, packs: &self.packs }
    }
}

/// [`DenseParams`] plus a [`PackCache`]: resident weights whose linears
/// resolve to pre-packed panels — zero per-call transpose/pack/copy work
/// on every hot path, bit-identical outputs to the unpacked source.
pub struct PackedDenseParams<'a> {
    pub w: &'a Weights,
    pub packs: &'a PackCache,
}

impl ParamSource for PackedDenseParams<'_> {
    fn spec(&self) -> &ModelSpec {
        &self.w.spec
    }
    fn get(&mut self, name: &str) -> Result<Tensor> {
        self.w.get(name)
    }
    fn get_l(&mut self, l: usize, short: &str) -> Result<Tensor> {
        self.w.get_l(l, short)
    }
    fn get_packed(&mut self, name: &str) -> Result<Option<Arc<PackedMat>>> {
        Ok(self.packs.get(name))
    }
    fn get_l_packed(&mut self, l: usize, short: &str) -> Result<Option<Arc<PackedMat>>> {
        Ok(self.packs.get_l(l, short))
    }
    fn embed_rows(&mut self, ids: &[i32]) -> Result<Tensor> {
        let (table, shape) = self.w.view("tok_emb")?;
        gather_rows(table, shape[0], shape[1], ids)
    }
    fn with_rows(
        &mut self,
        name: &str,
        row0: usize,
        count: usize,
        f: &mut dyn FnMut(&[f32]),
    ) -> Result<()> {
        dense_with_rows(self.w, name, row0, count, f)
    }
}

#[derive(Clone)]
pub struct Weights {
    pub spec: ModelSpec,
    /// Packed parameters, `spec.params` order.
    pub packed: Tensor,
    offsets: BTreeMap<String, (usize, Vec<usize>)>,
}

impl Weights {
    fn build_offsets(spec: &ModelSpec) -> BTreeMap<String, (usize, Vec<usize>)> {
        let mut map = BTreeMap::new();
        let mut off = 0usize;
        for (name, shape) in &spec.params {
            let n: usize = shape.iter().product();
            map.insert(name.clone(), (off, shape.clone()));
            off += n;
        }
        map
    }

    /// All-zero weights (useful for tests).
    pub fn zeros(spec: &ModelSpec) -> Weights {
        Weights {
            spec: spec.clone(),
            packed: Tensor::zeros(&[spec.n_params_elems()]),
            offsets: Self::build_offsets(spec),
        }
    }

    /// Wrap an existing packed parameter vector (length-checked).
    pub fn from_packed(spec: &ModelSpec, data: Vec<f32>) -> Result<Weights> {
        anyhow::ensure!(
            data.len() == spec.n_params_elems(),
            "packed length {} != model {} ({})",
            data.len(),
            spec.n_params_elems(),
            spec.name,
        );
        Ok(Weights {
            spec: spec.clone(),
            packed: Tensor::new(vec![data.len()], data),
            offsets: Self::build_offsets(spec),
        })
    }

    /// Deterministic initialization: N(0, 0.02) for embeddings and linear
    /// weights (GPT-style), ones for norm gains, zeros for biases.
    pub fn init(spec: &ModelSpec, seed: u64) -> Weights {
        let mut w = Weights::zeros(spec);
        let mut rng = Rng::new(seed);
        for (name, shape) in spec.params.clone() {
            let n: usize = shape.iter().product();
            let is_gain = name.ends_with("ln1_g")
                || name.ends_with("ln2_g")
                || name.ends_with("lnf_g");
            let is_bias = shape.len() == 1 && !is_gain;
            let data = if is_gain {
                vec![1.0f32; n]
            } else if is_bias {
                vec![0.0f32; n]
            } else {
                // scale residual-path projections down by depth (GPT-2 trick)
                let base = 0.02f32;
                let std = if name.ends_with("wo") || name.ends_with("fc2") || name.ends_with("w_down") {
                    base / (2.0 * spec.n_layers as f32).sqrt()
                } else {
                    base
                };
                rng.normal_vec(n, std)
            };
            w.set_raw(&name, &data);
        }
        w
    }

    pub fn offset(&self, name: &str) -> Result<(usize, Vec<usize>)> {
        self.offsets
            .get(name)
            .cloned()
            .with_context(|| format!("param '{name}' not found"))
    }

    /// Borrow a parameter's backing slice + shape without copying.
    pub fn view(&self, name: &str) -> Result<(&[f32], Vec<usize>)> {
        let (off, shape) = self.offset(name)?;
        let n: usize = shape.iter().product();
        Ok((&self.packed.data[off..off + n], shape))
    }

    /// Copy a parameter out as a Tensor.
    pub fn get(&self, name: &str) -> Result<Tensor> {
        let (off, shape) = self.offset(name)?;
        let n: usize = shape.iter().product();
        Ok(Tensor::new(shape, self.packed.data[off..off + n].to_vec()))
    }

    /// Write a parameter back (shape-checked).
    pub fn set(&mut self, name: &str, t: &Tensor) -> Result<()> {
        let (off, shape) = self.offset(name)?;
        anyhow::ensure!(
            t.shape == shape,
            "set {name}: shape {:?} != {:?}",
            t.shape,
            shape
        );
        self.packed.data[off..off + t.numel()].copy_from_slice(&t.data);
        Ok(())
    }

    fn set_raw(&mut self, name: &str, data: &[f32]) {
        let (off, _) = self.offsets[name].clone();
        self.packed.data[off..off + data.len()].copy_from_slice(data);
    }

    /// Layer-scoped param name, e.g. `pname(2, "wq") == "layers.2.wq"`.
    pub fn pname(layer: usize, short: &str) -> String {
        format!("layers.{layer}.{short}")
    }

    pub fn get_l(&self, layer: usize, short: &str) -> Result<Tensor> {
        self.get(&Self::pname(layer, short))
    }

    pub fn set_l(&mut self, layer: usize, short: &str, t: &Tensor) -> Result<()> {
        self.set(&Self::pname(layer, short), t)
    }

    pub fn has(&self, name: &str) -> bool {
        self.offsets.contains_key(name)
    }

    /// Fraction of exactly-zero parameter entries (mask-sparsity probe).
    pub fn zero_fraction(&self) -> f64 {
        let z = self.packed.data.iter().filter(|&&x| x == 0.0).count();
        z as f64 / self.packed.numel().max(1) as f64
    }

    // ---- checkpoints -----------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut tf = TensorFile::new();
        tf.insert("packed", self.packed.clone());
        tf.insert("version", Tensor::scalar(1.0));
        tf.save(path)
    }

    pub fn load(spec: &ModelSpec, path: &Path) -> Result<Weights> {
        let tf = TensorFile::load(path)?;
        let packed = tf.get("packed")?.clone();
        anyhow::ensure!(
            packed.numel() == spec.n_params_elems(),
            "checkpoint size {} != model {} ({})",
            packed.numel(),
            spec.n_params_elems(),
            spec.name,
        );
        Ok(Weights {
            spec: spec.clone(),
            packed: Tensor::new(vec![spec.n_params_elems()], packed.data),
            offsets: Self::build_offsets(spec),
        })
    }
}
