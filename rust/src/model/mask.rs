//! Pruning mask bookkeeping.
//!
//! Evaluation of pruned models uses **exact masking** (DESIGN.md §5):
//! zeroing the i-th column of the down/out/fc2 projection makes the
//! coupled row's contribution exactly zero (paper Eq. 3), so dense masked
//! evaluation is numerically identical to physically sliced evaluation
//! while keeping artifact shapes static. The mask tracks which structures
//! were removed so (a) coupled rows/biases are zeroed too (the actual
//! sparsity win), (b) parameter accounting matches the paper's notion of
//! sparsity, (c) invariants are property-testable, and (d) the compact
//! exporter (`model::compact`) knows exactly which rows/columns to slice
//! out physically.
//!
//! Mask vector lengths follow the model's *per-layer* dims
//! (`ModelSpec::layer_dims`), so compact models can be re-masked and
//! re-pruned through the same machinery.

use crate::runtime::manifest::ModelSpec;
use anyhow::Result;

/// Per-layer kept masks. `true` = kept.
#[derive(Clone, Debug)]
pub struct LayerMask {
    /// FFN hidden units (columns of fc2/down ↔ rows of fc1/gate/up),
    /// len `spec.d_ff_l(l)`.
    pub ffn: Vec<bool>,
    /// Attention context dims (columns of W_out ↔ rows of W_V),
    /// len `spec.d_ov_l(l)`.
    pub ov: Vec<bool>,
    /// Q/K rows (ablation only; FASP default keeps all), len d_model.
    pub qk: Vec<bool>,
}

impl LayerMask {
    pub fn full(spec: &ModelSpec, l: usize) -> LayerMask {
        LayerMask {
            ffn: vec![true; spec.d_ff_l(l)],
            ov: vec![true; spec.d_ov_l(l)],
            qk: vec![true; spec.d_model],
        }
    }
}

/// Whole-model pruning mask.
#[derive(Clone, Debug)]
pub struct PruneMask {
    pub layers: Vec<LayerMask>,
}

pub fn kept_indices(mask: &[bool]) -> Vec<usize> {
    mask.iter().enumerate().filter(|(_, &k)| k).map(|(i, _)| i).collect()
}

pub fn pruned_indices(mask: &[bool]) -> Vec<usize> {
    mask.iter().enumerate().filter(|(_, &k)| !k).map(|(i, _)| i).collect()
}

impl PruneMask {
    pub fn full(spec: &ModelSpec) -> PruneMask {
        PruneMask {
            layers: (0..spec.n_layers).map(|l| LayerMask::full(spec, l)).collect(),
        }
    }

    /// Parameters removed by this mask under FASP's coupled structure
    /// (counting both the column and its coupled row(s)/bias element).
    pub fn params_removed(&self, spec: &ModelSpec) -> usize {
        let d = spec.d_model;
        let is_opt = spec.family == "opt";
        let mut removed = 0usize;
        for lm in &self.layers {
            let ffn_pruned = lm.ffn.iter().filter(|&&k| !k).count();
            let ov_pruned = lm.ov.iter().filter(|&&k| !k).count();
            let qk_pruned = lm.qk.iter().filter(|&&k| !k).count();
            if is_opt {
                // fc2 column (d) + fc1 row (d) + fc1 bias (1)
                removed += ffn_pruned * (2 * d + 1);
                // wo column (d) + wv row (d) + wv bias (1)
                removed += ov_pruned * (2 * d + 1);
                // wq row + bias + wk row + bias
                removed += qk_pruned * (2 * d + 2);
            } else {
                // down column (d) + up row (d) + gate row (d)
                removed += ffn_pruned * (3 * d);
                removed += ov_pruned * (2 * d);
                removed += qk_pruned * (2 * d);
            }
        }
        removed
    }

    /// Achieved sparsity over the *prunable* parameter pool (decoder
    /// linears; embeddings/norms are not prunable, matching the paper's
    /// per-operator sparsity accounting).
    pub fn sparsity(&self, spec: &ModelSpec) -> f64 {
        self.params_removed(spec) as f64 / prunable_params(spec) as f64
    }

    /// Structural consistency checks (property-tested):
    /// mask vector lengths match the model's per-layer dims.
    pub fn validate(&self, spec: &ModelSpec) -> Result<()> {
        anyhow::ensure!(self.layers.len() == spec.n_layers, "layer count");
        for (l, lm) in self.layers.iter().enumerate() {
            anyhow::ensure!(
                lm.ffn.len() == spec.d_ff_l(l),
                "layer {l} ffn mask len {} != {}",
                lm.ffn.len(),
                spec.d_ff_l(l)
            );
            anyhow::ensure!(
                lm.ov.len() == spec.d_ov_l(l),
                "layer {l} ov mask len {} != {}",
                lm.ov.len(),
                spec.d_ov_l(l)
            );
            anyhow::ensure!(lm.qk.len() == spec.d_model, "layer {l} qk mask len");
        }
        Ok(())
    }
}

/// Total parameters in the prunable pool (all decoder-block linears,
/// counted with their biases where present), summed over the per-layer
/// dims so compact models account honestly.
pub fn prunable_params(spec: &ModelSpec) -> usize {
    let d = spec.d_model;
    let mut total = 0usize;
    for l in 0..spec.n_layers {
        let f = spec.d_ff_l(l);
        let ov = spec.d_ov_l(l);
        total += if spec.family == "opt" {
            // wq,wk: 2(d² + d); wv: ov·d + ov; wo: d·ov + d;
            // fc1: f·d + f; fc2: d·f + d
            2 * (d * d + d) + (ov * d + ov) + (d * ov + d) + (2 * d * f + f + d)
        } else {
            2 * d * d + 2 * ov * d + 3 * d * f
        };
    }
    total
}
