//! KV-cached autoregressive decode: the inference path where FASP's OV
//! slicing actually pays off (smaller per-token matvecs *and* a smaller
//! resident value cache — SlimGPT/FLAP's motivation for structured
//! pruning).
//!
//! Pieces:
//! * [`KvCache`] — per-layer K/V ring buffers sized by each layer's
//!   **sliced** dims: keys keep the full `n_heads·head_dim` width (FASP
//!   leaves Q/K dense), values are `d_ov_l` wide with the per-head
//!   column blocks given by `head_splits_l`. Buffers are preallocated at
//!   a fixed capacity with resident-byte accounting ([`KvCache::kv_bytes`],
//!   the decode-memory receipt); writing past capacity is a loud error,
//!   never a silent wrap.
//! * [`prefill_src`] — one full-prompt forward that populates the cache
//!   (keys stored post-RoPE at their absolute positions) and returns the
//!   last-position logits.
//! * [`decode_step_src`] — one token per sequence against the cache:
//!   O(prefix) work per token (single-row linears + one attention row
//!   per head) instead of the O(prefix²) full re-forward.
//! * [`decode_chunk_src`] — t tokens per sequence in one forward,
//!   causal within the chunk, logits for **every** chunk position: the
//!   speculative-decode verification kernel (`model::spec_decode`),
//!   paired with [`KvCache::truncate`] rollback for rejected
//!   proposals. [`decode_chunk_paged`] is its logits-free paged
//!   sibling — the serve engine's chunked prompt prefill.
//! * [`generate_src`] / [`Sampler`] — the batched generation loop with
//!   greedy and seeded top-k sampling.
//! * [`decode_step_paged`] — the serve engine's batched step: one token
//!   per *lane* against a paged KV arena (`model::kv_arena`), lanes at
//!   independent positions so prompt prefill and mid-generation decode
//!   interleave in one batch (continuous batching, see `crate::serve`).
//!
//! Determinism contract (locked by `rust/tests/test_decode.rs`): the
//! cached step shares every kernel with the full forward — `attn_row`
//! for the attention row, the packed/unpacked linear forms (one
//! canonical lane reduction order, see `tensor::{matmul,pack}`) for the
//! matvecs, `rope_row` on the same cached tables — so `decode_step_src`
//! logits are **bit-identical** to a full-prefix re-forward at every
//! position, on every backend pool width, from every [`ParamSource`]
//! (dense weights packed or unpacked, compact weights, sharded
//! [`crate::runtime::store::StreamingParams`]).
//!
//! Latency contract (locked by the `bench_hot_paths` packing section):
//! a source with a pack cache performs **zero** transpose/pack/
//! table-copy allocations per decode step — the per-token hot loop is
//! matvecs over persistent packed panels plus the cache attention rows.

use super::host::{
    attention, attn_out_residual, attn_row, attn_row_by, embed_tokens, ffn_sublayer,
    head_logits, norm_input, qkv_proj, rope_cached, rope_row,
};
use super::kv_arena::{KvArena, PagedKv};
use super::weights::ParamSource;
use crate::runtime::manifest::ModelSpec;
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;
use anyhow::Result;

/// One layer's K/V buffers.
struct LayerKv {
    /// Post-RoPE keys, [batch, cap, n_heads·head_dim] row-major (Q/K
    /// stay dense under FASP, so this width never shrinks).
    k: Vec<f32>,
    /// Values, [batch, cap, d_ov_l] — the layer's sliced width; this is
    /// where OV pruning shrinks the resident cache.
    v: Vec<f32>,
    /// Kept V dims per head (prefix sums give each head's column block).
    splits: Vec<usize>,
    /// Σ splits — the layer's value width.
    dv: usize,
}

/// Preallocated per-layer K/V ring buffers for one decode session.
/// Geometry is pinned to one model spec at construction; every
/// prefill/step re-checks it, so a cache built for one model can never
/// silently serve another (mismatched layer dims are a hard error).
pub struct KvCache {
    model: String,
    family: String,
    d_model: usize,
    n_heads: usize,
    head_dim: usize,
    kdim: usize,
    batch: usize,
    cap: usize,
    len: usize,
    layers: Vec<LayerKv>,
}

impl KvCache {
    /// Allocate buffers for `batch` sequences of up to `capacity`
    /// positions under `spec`'s (per-layer, possibly sliced) dims.
    pub fn for_spec(spec: &ModelSpec, batch: usize, capacity: usize) -> Result<KvCache> {
        anyhow::ensure!(batch >= 1, "kv cache wants batch >= 1");
        anyhow::ensure!(capacity >= 1, "kv cache wants capacity >= 1");
        if spec.family == "opt" {
            anyhow::ensure!(
                capacity <= spec.seq,
                "kv cache capacity {capacity} exceeds the {} learned \
                 positions of OPT model '{}' (pos_emb covers seq={})",
                spec.seq,
                spec.name,
                spec.seq
            );
        }
        let head_dim = spec.head_dim();
        let kdim = spec.n_heads * head_dim;
        let layers = (0..spec.n_layers)
            .map(|l| {
                let splits = spec.head_splits_l(l);
                let dv: usize = splits.iter().sum();
                LayerKv {
                    k: vec![0.0; batch * capacity * kdim],
                    v: vec![0.0; batch * capacity * dv],
                    splits,
                    dv,
                }
            })
            .collect();
        Ok(KvCache {
            model: spec.name.clone(),
            family: spec.family.clone(),
            d_model: spec.d_model,
            n_heads: spec.n_heads,
            head_dim,
            kdim,
            batch,
            cap: capacity,
            len: 0,
            layers,
        })
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Cached positions so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Forget all cached positions (buffers stay allocated).
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Roll the cache back to `pos` cached positions — the speculative
    /// decode rejection path: positions written for proposals past the
    /// accepted prefix are forgotten. Rows beyond `len` are never read
    /// (every attention row is bounded by its position), so no zeroing
    /// is needed; a later write at the same position simply overwrites.
    /// Truncate can only roll *back*: a `pos` beyond the cached length
    /// (or the capacity) is a proper `Err`, never a silent extension of
    /// the cache over stale rows.
    pub fn truncate(&mut self, pos: usize) -> Result<()> {
        anyhow::ensure!(
            pos <= self.len,
            "kv truncate to {pos} exceeds cached length {} (capacity {}) — \
             truncate can only roll back, never extend",
            self.len,
            self.cap
        );
        self.len = pos;
        Ok(())
    }

    /// Allocated resident bytes of the K/V buffers — the decode-memory
    /// receipt: V buffers are sized by each layer's sliced `d_ov`, so an
    /// OV-pruned compact model's cache is strictly smaller than its
    /// dense base at the same capacity.
    pub fn kv_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| (l.k.len() + l.v.len()) * std::mem::size_of::<f32>())
            .sum()
    }

    /// Bytes of `kv_bytes` actually holding live positions.
    pub fn used_bytes(&self) -> usize {
        if self.cap == 0 {
            return 0;
        }
        self.kv_bytes() / self.cap * self.len
    }

    /// The cache only ever serves the exact spec it was built for.
    fn check_spec(&self, spec: &ModelSpec, batch: usize) -> Result<()> {
        anyhow::ensure!(
            self.model == spec.name,
            "kv cache was built for model '{}' but the forward is running \
             '{}' — cache/model mismatch",
            self.model,
            spec.name
        );
        anyhow::ensure!(
            self.family == spec.family
                && self.d_model == spec.d_model
                && self.n_heads == spec.n_heads
                && self.layers.len() == spec.n_layers,
            "kv cache geometry (d={}, heads={}, layers={}) does not match \
             model '{}' — mismatched layer dims",
            self.d_model,
            self.n_heads,
            self.layers.len(),
            spec.name
        );
        for (l, lay) in self.layers.iter().enumerate() {
            let want = spec.head_splits_l(l);
            anyhow::ensure!(
                lay.splits == want,
                "kv cache layer {l}: head splits {:?} != model '{}' splits \
                 {:?} — mismatched layer dims",
                lay.splits,
                spec.name,
                want
            );
        }
        anyhow::ensure!(
            self.batch == batch,
            "kv cache batch {} != input batch {batch}",
            self.batch
        );
        Ok(())
    }

    /// Store one position's K/V rows ([batch, kdim] / [batch, dv]) for
    /// layer `l`. Keys must already be RoPE-rotated at `pos`.
    fn write_pos(&mut self, l: usize, pos: usize, k_rows: &Tensor, v_rows: &Tensor) {
        let (kdim, cap, batch) = (self.kdim, self.cap, self.batch);
        let lay = &mut self.layers[l];
        let dv = lay.dv;
        for bi in 0..batch {
            let ko = (bi * cap + pos) * kdim;
            lay.k[ko..ko + kdim].copy_from_slice(k_rows.row(bi));
            let vo = (bi * cap + pos) * dv;
            lay.v[vo..vo + dv].copy_from_slice(v_rows.row(bi));
        }
    }

    /// Store a whole prompt's K/V rows ([batch·t, kdim] / [batch·t, dv])
    /// for layer `l`, position `ti` of row `bi` landing at slot
    /// `bi·cap + ti`. Keys must already be RoPE-rotated per position.
    fn write_prefill(&mut self, l: usize, t: usize, k_rows: &Tensor, v_rows: &Tensor) {
        self.write_chunk(l, 0, t, k_rows, v_rows)
    }

    /// Store a chunk's K/V rows ([batch·t, kdim] / [batch·t, dv]) for
    /// layer `l`: chunk position `ti` of sequence `bi` (input row
    /// `bi·t + ti`) lands at slot `bi·cap + pos0 + ti` — the same copy
    /// `write_pos` performs per position, batched over the chunk.
    fn write_chunk(&mut self, l: usize, pos0: usize, t: usize, k_rows: &Tensor, v_rows: &Tensor) {
        let (kdim, cap, batch) = (self.kdim, self.cap, self.batch);
        let lay = &mut self.layers[l];
        let dv = lay.dv;
        for bi in 0..batch {
            for ti in 0..t {
                let r = bi * t + ti;
                let ko = (bi * cap + pos0 + ti) * kdim;
                lay.k[ko..ko + kdim].copy_from_slice(k_rows.row(r));
                let vo = (bi * cap + pos0 + ti) * dv;
                lay.v[vo..vo + dv].copy_from_slice(v_rows.row(r));
            }
        }
    }
}

fn validate_ids(tokens: &IntTensor, vocab: usize) -> Result<()> {
    for &id in &tokens.data {
        anyhow::ensure!(
            id >= 0 && (id as usize) < vocab,
            "token id {id} outside vocab {vocab}"
        );
    }
    Ok(())
}

/// Scalar geometry pulled out of a spec up front (the source hands out
/// tensors through `&mut self` afterwards).
struct Geom {
    d: usize,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    vocab: usize,
    seq: usize,
    is_opt: bool,
    head_splits: Vec<Vec<usize>>,
}

impl Geom {
    fn of(spec: &ModelSpec) -> Geom {
        Geom {
            d: spec.d_model,
            n_layers: spec.n_layers,
            n_heads: spec.n_heads,
            head_dim: spec.head_dim(),
            vocab: spec.vocab,
            seq: spec.seq,
            is_opt: spec.family == "opt",
            head_splits: (0..spec.n_layers).map(|l| spec.head_splits_l(l)).collect(),
        }
    }
}

/// Full-prompt forward shared by [`prefill_src`] (cache = Some) and
/// [`full_logits`] (cache = None): embeds `tokens`, runs every layer
/// through the same building blocks `forward_nll_src` executes
/// (`norm_input`/`qkv_proj`/`attention`/`attn_out_residual`/
/// `ffn_sublayer` — shared code, nothing mirrored), and returns the
/// **last-position logits** [b, vocab]. With a cache, each layer's
/// post-RoPE keys and values are stored at their absolute positions.
fn forward_last_logits<S: ParamSource>(
    src: &mut S,
    tokens: &IntTensor,
    mut cache: Option<&mut KvCache>,
) -> Result<Tensor> {
    let g = Geom::of(src.spec());
    let (b, t) = (tokens.shape[0], tokens.shape[1]);
    let rows = b * t;
    validate_ids(tokens, g.vocab)?;

    let mut x = embed_tokens(src, tokens, g.d, g.is_opt, 0)?;
    let rope = rope_cached(t, g.head_dim);
    let (cos, sin): (&[f32], &[f32]) = (&rope.0, &rope.1);

    for l in 0..g.n_layers {
        // ---- attention
        let x_ln = norm_input(src, l, "ln1", &x, g.d, g.is_opt)?;
        let (q, k, v) = qkv_proj(src, l, &x_ln, g.is_opt)?;
        if let Some(c) = cache.as_deref_mut() {
            // keys cache post-RoPE at their absolute positions — the
            // same per-row rotation `attention` applies to its gathered
            // buffers, so cached rows are bitwise the rows a re-forward
            // would rebuild
            let mut kc = k.clone();
            if !g.is_opt {
                for r in 0..rows {
                    let ti = r % t;
                    for hi in 0..g.n_heads {
                        rope_row(
                            &mut kc.row_mut(r)[hi * g.head_dim..(hi + 1) * g.head_dim],
                            g.head_dim,
                            ti,
                            cos,
                            sin,
                        );
                    }
                }
            }
            c.write_prefill(l, t, &kc, &v);
        }
        let ctx = attention(
            b,
            t,
            g.n_heads,
            g.head_dim,
            &g.head_splits[l],
            &q,
            &k,
            &v,
            cos,
            sin,
            !g.is_opt,
        );
        attn_out_residual(src, l, &ctx, &mut x)?;
        ffn_sublayer(src, l, &mut x, g.d, g.is_opt)?;
        src.layer_done(l)?;
    }
    if let Some(c) = cache {
        c.len = t;
    }

    // last position of each sequence → final norm → logits
    let mut last = Tensor::zeros(&[b, g.d]);
    for bi in 0..b {
        last.row_mut(bi).copy_from_slice(x.row(bi * t + t - 1));
    }
    head_logits(src, last, g.d, g.is_opt)
}

/// Run the whole prompt through the model once, populating `cache`
/// (which must be empty and match the source's spec), and return the
/// last-position logits [b, vocab].
pub fn prefill_src<S: ParamSource>(
    src: &mut S,
    tokens: &IntTensor,
    cache: &mut KvCache,
) -> Result<Tensor> {
    anyhow::ensure!(
        tokens.shape.len() == 2 && tokens.shape[1] >= 1,
        "prefill wants [b, t] tokens with t >= 1, got shape {:?}",
        tokens.shape
    );
    let (b, t) = (tokens.shape[0], tokens.shape[1]);
    cache.check_spec(src.spec(), b)?;
    anyhow::ensure!(
        cache.len == 0,
        "prefill wants an empty cache (len {}); clear() it first",
        cache.len
    );
    anyhow::ensure!(
        t <= cache.cap,
        "kv cache overflow: prompt length {t} exceeds capacity {}",
        cache.cap
    );
    forward_last_logits(src, tokens, Some(cache))
}

/// Full-prefix logits at the last position via the plain (cache-free)
/// forward machinery — the O(prefix²) re-forward baseline the decode
/// tests pin [`decode_step_src`] against, and the naive-generation
/// reference `eval::speed::compare_decode` times.
pub fn full_logits<S: ParamSource>(src: &mut S, tokens: &IntTensor) -> Result<Tensor> {
    anyhow::ensure!(
        tokens.shape.len() == 2 && tokens.shape[1] >= 1,
        "full_logits wants [b, t] tokens with t >= 1, got shape {:?}",
        tokens.shape
    );
    forward_last_logits(src, tokens, None)
}

/// Process one token per sequence (position `cache.len()`) against the
/// cache: O(prefix) per token — single-row linears plus one attention
/// row per (sequence, head) — instead of re-running the whole prefix.
/// Appends the new position's K/V and returns the logits [b, vocab].
///
/// The per-(sequence, head) cache attention fans out on the ambient
/// worker pool (the session backend's) with the fixed block order
/// `attention` uses, so outputs are bit-identical at every pool width.
pub fn decode_step_src<S: ParamSource>(
    src: &mut S,
    tokens: &IntTensor,
    cache: &mut KvCache,
) -> Result<Tensor> {
    let g = Geom::of(src.spec());
    let b = cache.batch;
    anyhow::ensure!(
        tokens.numel() == b,
        "decode_step wants one token per sequence ({} tokens for batch {b})",
        tokens.numel()
    );
    cache.check_spec(src.spec(), b)?;
    let pos = cache.len;
    anyhow::ensure!(
        pos < cache.cap,
        "kv cache overflow: capacity {} exhausted at position {pos}",
        cache.cap
    );
    validate_ids(tokens, g.vocab)?;
    let (dh, kdim, cap) = (g.head_dim, cache.kdim, cache.cap);
    let scale = 1.0 / (dh as f32).sqrt();

    // reshape to the [b, 1] layout the shared embed helper wants; the
    // OPT position row is `pos`
    let toks = IntTensor::new(vec![b, 1], tokens.data.clone());
    let mut x = embed_tokens(src, &toks, g.d, g.is_opt, pos)?;
    let rope = rope_cached(pos + 1, dh);
    let (cos, sin): (&[f32], &[f32]) = (&rope.0, &rope.1);

    for l in 0..g.n_layers {
        // ---- attention (one row per sequence, against the cache)
        let x_ln = norm_input(src, l, "ln1", &x, g.d, g.is_opt)?;
        let (mut q, mut k, v) = qkv_proj(src, l, &x_ln, g.is_opt)?;
        if !g.is_opt {
            for bi in 0..b {
                for hi in 0..g.n_heads {
                    rope_row(&mut q.row_mut(bi)[hi * dh..(hi + 1) * dh], dh, pos, cos, sin);
                    rope_row(&mut k.row_mut(bi)[hi * dh..(hi + 1) * dh], dh, pos, cos, sin);
                }
            }
        }
        cache.write_pos(l, pos, &k, &v);

        let lay = &cache.layers[l];
        let splits = &lay.splits;
        let dv = lay.dv;
        let mut offs = Vec::with_capacity(g.n_heads + 1);
        let mut acc = 0usize;
        offs.push(0);
        for &s in splits {
            acc += s;
            offs.push(acc);
        }
        let block = |bi: usize, hi: usize| -> Vec<f32> {
            let dv_h = splits[hi];
            if dv_h == 0 {
                return Vec::new(); // fully sliced head: nothing reads it
            }
            let qrow = &q.row(bi)[hi * dh..(hi + 1) * dh];
            let kbuf = &lay.k[bi * cap * kdim..(bi + 1) * cap * kdim];
            let vbuf = &lay.v[bi * cap * dv..(bi + 1) * cap * dv];
            let mut out = vec![0.0f32; dv_h];
            attn_row(qrow, kbuf, kdim, hi * dh, vbuf, dv, offs[hi], pos, dh, dv_h, scale, &mut out);
            out
        };
        let n_blocks = b * g.n_heads;
        let mut ctx = Tensor::zeros(&[b, dv]);
        let mut place = |i: usize, blk: Vec<f32>| {
            let (bi, hi) = (i / g.n_heads, i % g.n_heads);
            let dv_h = splits[hi];
            if dv_h == 0 {
                return;
            }
            ctx.row_mut(bi)[offs[hi]..offs[hi] + dv_h].copy_from_slice(&blk);
        };
        let pool = crate::util::pool::current();
        let work = n_blocks * (pos + 1) * (dh + dv / g.n_heads.max(1));
        if pool.workers() > 1 && n_blocks > 1 && work >= crate::util::pool::PAR_THRESHOLD {
            let blocks = pool.map(n_blocks, |i| block(i / g.n_heads, i % g.n_heads));
            for (i, blk) in blocks.into_iter().enumerate() {
                place(i, blk);
            }
        } else {
            for i in 0..n_blocks {
                place(i, block(i / g.n_heads, i % g.n_heads));
            }
        }
        attn_out_residual(src, l, &ctx, &mut x)?;
        // ---- ffn (the shared sublayer, just b rows)
        ffn_sublayer(src, l, &mut x, g.d, g.is_opt)?;
        src.layer_done(l)?;
    }
    cache.len = pos + 1;

    head_logits(src, x, g.d, g.is_opt)
}

/// Process `t` consecutive tokens per sequence — positions
/// `cache.len() .. cache.len() + t` — against the cache in **one**
/// forward, causal *within* the chunk (chunk position `ti` attends to
/// the whole cached prefix plus chunk positions `..= ti`), and return
/// the logits of every chunk position: [b·t, vocab], row `bi·t + ti`
/// holding the next-token logits after feeding token `(bi, ti)`.
///
/// This is the speculative-decode verification kernel: the target
/// model scores a draft's k proposals (plus the committed token before
/// them) in one chunked pass instead of k+1 sequential steps — the
/// cache attention stays O(prefix) per row, but every linear streams
/// its packed weight panel once for all t rows instead of once per
/// token, which is where the verification win comes from on a
/// weight-bandwidth-bound host.
///
/// Bit-identity contract (locked by `rust/tests/test_spec_decode.rs`):
/// a chunk of 1 executes the exact calls [`decode_step_src`] executes
/// (same embed row, same RoPE rows, same `attn_row` reduction over the
/// same cache strides), and a chunk of t leaves the cache and produces
/// per-position logits bitwise equal to t single steps — so chunked
/// verification can never diverge from sequential decode.
pub fn decode_chunk_src<S: ParamSource>(
    src: &mut S,
    tokens: &IntTensor,
    cache: &mut KvCache,
) -> Result<Tensor> {
    let g = Geom::of(src.spec());
    let b = cache.batch;
    anyhow::ensure!(
        tokens.shape.len() == 2 && tokens.shape[0] == b && tokens.shape[1] >= 1,
        "decode_chunk wants [b={b}, t >= 1] tokens, got shape {:?}",
        tokens.shape
    );
    let t = tokens.shape[1];
    cache.check_spec(src.spec(), b)?;
    let pos0 = cache.len;
    anyhow::ensure!(
        pos0 + t <= cache.cap,
        "kv cache overflow: chunk of {t} at position {pos0} exceeds capacity {}",
        cache.cap
    );
    validate_ids(tokens, g.vocab)?;
    let (dh, kdim, cap) = (g.head_dim, cache.kdim, cache.cap);
    let scale = 1.0 / (dh as f32).sqrt();

    let mut x = embed_tokens(src, tokens, g.d, g.is_opt, pos0)?;
    let rope = rope_cached(pos0 + t, dh);
    let (cos, sin): (&[f32], &[f32]) = (&rope.0, &rope.1);

    for l in 0..g.n_layers {
        // ---- attention (t rows per sequence, against cache + chunk)
        let x_ln = norm_input(src, l, "ln1", &x, g.d, g.is_opt)?;
        let (mut q, mut k, v) = qkv_proj(src, l, &x_ln, g.is_opt)?;
        if !g.is_opt {
            for r in 0..b * t {
                let pos = pos0 + r % t;
                for hi in 0..g.n_heads {
                    rope_row(&mut q.row_mut(r)[hi * dh..(hi + 1) * dh], dh, pos, cos, sin);
                    rope_row(&mut k.row_mut(r)[hi * dh..(hi + 1) * dh], dh, pos, cos, sin);
                }
            }
        }
        // chunk K/V land in the cache first, so row ti's attention reads
        // chunk positions <= ti straight from the cache buffers (its
        // bound pos0 + ti keeps later chunk rows invisible — causal)
        cache.write_chunk(l, pos0, t, &k, &v);

        let lay = &cache.layers[l];
        let splits = &lay.splits;
        let dv = lay.dv;
        let mut offs = Vec::with_capacity(g.n_heads + 1);
        let mut acc = 0usize;
        offs.push(0);
        for &s in splits {
            acc += s;
            offs.push(acc);
        }
        let block = |r: usize, hi: usize| -> Vec<f32> {
            let dv_h = splits[hi];
            if dv_h == 0 {
                return Vec::new(); // fully sliced head: nothing reads it
            }
            let (bi, ti) = (r / t, r % t);
            let qrow = &q.row(r)[hi * dh..(hi + 1) * dh];
            let kbuf = &lay.k[bi * cap * kdim..(bi + 1) * cap * kdim];
            let vbuf = &lay.v[bi * cap * dv..(bi + 1) * cap * dv];
            let mut out = vec![0.0f32; dv_h];
            attn_row(
                qrow,
                kbuf,
                kdim,
                hi * dh,
                vbuf,
                dv,
                offs[hi],
                pos0 + ti,
                dh,
                dv_h,
                scale,
                &mut out,
            );
            out
        };
        let n_blocks = b * t * g.n_heads;
        let mut ctx = Tensor::zeros(&[b * t, dv]);
        let mut place = |i: usize, blk: Vec<f32>| {
            let (r, hi) = (i / g.n_heads, i % g.n_heads);
            let dv_h = splits[hi];
            if dv_h == 0 {
                return;
            }
            ctx.row_mut(r)[offs[hi]..offs[hi] + dv_h].copy_from_slice(&blk);
        };
        let pool = crate::util::pool::current();
        let work = n_blocks * (pos0 + t) * (dh + dv / g.n_heads.max(1));
        if pool.workers() > 1 && n_blocks > 1 && work >= crate::util::pool::PAR_THRESHOLD {
            let blocks = pool.map(n_blocks, |i| block(i / g.n_heads, i % g.n_heads));
            for (i, blk) in blocks.into_iter().enumerate() {
                place(i, blk);
            }
        } else {
            for i in 0..n_blocks {
                place(i, block(i / g.n_heads, i % g.n_heads));
            }
        }
        attn_out_residual(src, l, &ctx, &mut x)?;
        // ---- ffn (the shared sublayer, b·t rows)
        ffn_sublayer(src, l, &mut x, g.d, g.is_opt)?;
        src.layer_done(l)?;
    }
    cache.len = pos0 + t;

    head_logits(src, x, g.d, g.is_opt)
}

// ------------------------------------------------------------ paged decode

/// One lane of a batched paged decode step: a session's page table plus
/// the token it feeds at its next position.
pub struct PagedLane<'a> {
    pub kv: &'a mut PagedKv,
    pub token: i32,
}

/// Batched one-token-per-lane decode step against a paged KV arena —
/// the serve engine's inner loop. Each lane advances its own sequence
/// by exactly one position; lanes may sit at *different* positions, so
/// prompt prefill (fed one token per tick) and mid-generation decode
/// interleave freely inside one batch — that is what lets sessions
/// join/leave the running batch at token granularity.
///
/// Bit-identity contract (locked by `rust/tests/test_serve.rs`): row
/// `i` of the returned logits is bitwise what [`decode_step_src`]
/// produces for lane `i` alone, at any batch composition and pool
/// width. Two properties make that true by construction: every linear
/// sub-kernel (`norm_input`/`qkv_proj`/`attn_out_residual`/
/// `ffn_sublayer`/`head_logits`) computes each output row from its own
/// input row with serial per-row arithmetic, and the cache attention
/// row runs through the same [`attn_row_by`] reduction the contiguous
/// [`KvCache`] path uses — only the row *addressing* differs (page
/// table indirection vs ring-buffer stride).
pub fn decode_step_paged<S: ParamSource>(
    src: &mut S,
    arena: &mut KvArena,
    lanes: &mut [PagedLane<'_>],
) -> Result<Tensor> {
    let g = Geom::of(src.spec());
    let n = lanes.len();
    anyhow::ensure!(n >= 1, "decode_step_paged wants at least one lane");
    arena.check_spec(src.spec())?;
    let dh = g.head_dim;
    let scale = 1.0 / (dh as f32).sqrt();

    let mut positions = Vec::with_capacity(n);
    for lane in lanes.iter() {
        anyhow::ensure!(
            lane.token >= 0 && (lane.token as usize) < g.vocab,
            "token id {} outside vocab {}",
            lane.token,
            g.vocab
        );
        let pos = lane.kv.len();
        if g.is_opt {
            anyhow::ensure!(
                pos < g.seq,
                "position {pos} exceeds the {} learned positions of OPT \
                 model (pos_emb covers seq={})",
                g.seq,
                g.seq
            );
        }
        positions.push(pos);
    }
    // reserve this tick's page for every lane before any forward work
    for lane in lanes.iter_mut() {
        arena.grow(lane.kv, lane.kv.len() + 1)?;
    }
    let max_pos = positions
        .iter()
        .copied()
        .max()
        .ok_or_else(|| anyhow::anyhow!("decode_step_paged: no lanes"))?;

    // per-lane embeds: lanes carry their own absolute position (the OPT
    // learned-position row differs per lane, so this cannot be one
    // batched call) — bitwise the row a b=1 `decode_step_src` embeds
    let mut x = Tensor::zeros(&[n, g.d]);
    for (i, lane) in lanes.iter().enumerate() {
        let toks = IntTensor::new(vec![1, 1], vec![lane.token]);
        let e = embed_tokens(src, &toks, g.d, g.is_opt, positions[i])?;
        x.row_mut(i).copy_from_slice(e.row(0));
    }
    let rope = rope_cached(max_pos + 1, dh);
    let (cos, sin): (&[f32], &[f32]) = (&rope.0, &rope.1);

    for l in 0..g.n_layers {
        // ---- attention (one row per lane, against the paged arena)
        let x_ln = norm_input(src, l, "ln1", &x, g.d, g.is_opt)?;
        let (mut q, mut k, v) = qkv_proj(src, l, &x_ln, g.is_opt)?;
        if !g.is_opt {
            for (i, &pos) in positions.iter().enumerate() {
                for hi in 0..g.n_heads {
                    rope_row(&mut q.row_mut(i)[hi * dh..(hi + 1) * dh], dh, pos, cos, sin);
                    rope_row(&mut k.row_mut(i)[hi * dh..(hi + 1) * dh], dh, pos, cos, sin);
                }
            }
        }
        for (i, lane) in lanes.iter().enumerate() {
            arena.write_pos(lane.kv, l, positions[i], k.row(i), v.row(i));
        }

        let splits = &g.head_splits[l];
        let dv: usize = splits.iter().sum();
        let mut offs = Vec::with_capacity(g.n_heads + 1);
        let mut acc = 0usize;
        offs.push(0);
        for &s in splits {
            acc += s;
            offs.push(acc);
        }
        let tables: Vec<&[usize]> = lanes.iter().map(|lane| lane.kv.pages()).collect();
        let arena_ref = &*arena;
        let block = |i: usize, hi: usize| -> Vec<f32> {
            let dv_h = splits[hi];
            if dv_h == 0 {
                return Vec::new(); // fully sliced head: nothing reads it
            }
            let qrow = &q.row(i)[hi * dh..(hi + 1) * dh];
            let pt = tables[i];
            let mut out = vec![0.0f32; dv_h];
            attn_row_by(
                qrow,
                |tj| &arena_ref.k_row(l, pt, tj)[hi * dh..(hi + 1) * dh],
                |tj| &arena_ref.v_row(l, pt, tj)[offs[hi]..offs[hi] + dv_h],
                positions[i],
                scale,
                &mut out,
            );
            out
        };
        let n_blocks = n * g.n_heads;
        let mut ctx = Tensor::zeros(&[n, dv]);
        let mut place = |i: usize, blk: Vec<f32>| {
            let (bi, hi) = (i / g.n_heads, i % g.n_heads);
            let dv_h = splits[hi];
            if dv_h == 0 {
                return;
            }
            ctx.row_mut(bi)[offs[hi]..offs[hi] + dv_h].copy_from_slice(&blk);
        };
        let pool = crate::util::pool::current();
        let work = n_blocks * (max_pos + 1) * (dh + dv / g.n_heads.max(1));
        if pool.workers() > 1 && n_blocks > 1 && work >= crate::util::pool::PAR_THRESHOLD {
            let blocks = pool.map(n_blocks, |i| block(i / g.n_heads, i % g.n_heads));
            for (i, blk) in blocks.into_iter().enumerate() {
                place(i, blk);
            }
        } else {
            for i in 0..n_blocks {
                place(i, block(i / g.n_heads, i % g.n_heads));
            }
        }
        attn_out_residual(src, l, &ctx, &mut x)?;
        // ---- ffn (the shared sublayer, just n rows)
        ffn_sublayer(src, l, &mut x, g.d, g.is_opt)?;
        src.layer_done(l)?;
    }
    for lane in lanes.iter_mut() {
        lane.kv.advance();
    }

    head_logits(src, x, g.d, g.is_opt)
}

/// Feed `tokens` — `t` consecutive positions of ONE session — against
/// the paged arena in a single causal chunk, populating the session's
/// K/V pages without computing any logits: the serve engine's chunked
/// prompt prefill ([`crate::serve`], `ServeConfig::prefill_chunk`).
///
/// The pages end bitwise as `t` single-token [`decode_step_paged`]
/// feeds would leave them (same embed/RoPE/write kernels on the same
/// rows), so every later sampled logit is unchanged — and the engine
/// always discarded non-final prompt-position logits anyway, so
/// skipping the [t, vocab] head product here is pure savings on top of
/// the one-weight-stream-per-chunk linears.
pub fn decode_chunk_paged<S: ParamSource>(
    src: &mut S,
    arena: &mut KvArena,
    kv: &mut PagedKv,
    tokens: &[i32],
) -> Result<()> {
    let g = Geom::of(src.spec());
    let t = tokens.len();
    anyhow::ensure!(t >= 1, "decode_chunk_paged wants at least one token");
    arena.check_spec(src.spec())?;
    for &id in tokens {
        anyhow::ensure!(
            id >= 0 && (id as usize) < g.vocab,
            "token id {id} outside vocab {}",
            g.vocab
        );
    }
    let pos0 = kv.len();
    if g.is_opt {
        anyhow::ensure!(
            pos0 + t <= g.seq,
            "positions {pos0}..{} exceed the {} learned positions of OPT \
             model (pos_emb covers seq={})",
            pos0 + t,
            g.seq,
            g.seq
        );
    }
    arena.grow(kv, pos0 + t)?;
    let dh = g.head_dim;
    let scale = 1.0 / (dh as f32).sqrt();

    let toks = IntTensor::new(vec![1, t], tokens.to_vec());
    let mut x = embed_tokens(src, &toks, g.d, g.is_opt, pos0)?;
    let rope = rope_cached(pos0 + t, dh);
    let (cos, sin): (&[f32], &[f32]) = (&rope.0, &rope.1);

    for l in 0..g.n_layers {
        // ---- attention (t rows of one session, against the arena)
        let x_ln = norm_input(src, l, "ln1", &x, g.d, g.is_opt)?;
        let (mut q, mut k, v) = qkv_proj(src, l, &x_ln, g.is_opt)?;
        if !g.is_opt {
            for ti in 0..t {
                for hi in 0..g.n_heads {
                    rope_row(&mut q.row_mut(ti)[hi * dh..(hi + 1) * dh], dh, pos0 + ti, cos, sin);
                    rope_row(&mut k.row_mut(ti)[hi * dh..(hi + 1) * dh], dh, pos0 + ti, cos, sin);
                }
            }
        }
        for ti in 0..t {
            arena.write_pos(kv, l, pos0 + ti, k.row(ti), v.row(ti));
        }

        let splits = &g.head_splits[l];
        let dv: usize = splits.iter().sum();
        let mut offs = Vec::with_capacity(g.n_heads + 1);
        let mut acc = 0usize;
        offs.push(0);
        for &s in splits {
            acc += s;
            offs.push(acc);
        }
        let pt = kv.pages();
        let arena_ref = &*arena;
        let block = |ti: usize, hi: usize| -> Vec<f32> {
            let dv_h = splits[hi];
            if dv_h == 0 {
                return Vec::new(); // fully sliced head: nothing reads it
            }
            let qrow = &q.row(ti)[hi * dh..(hi + 1) * dh];
            let mut out = vec![0.0f32; dv_h];
            attn_row_by(
                qrow,
                |tj| &arena_ref.k_row(l, pt, tj)[hi * dh..(hi + 1) * dh],
                |tj| &arena_ref.v_row(l, pt, tj)[offs[hi]..offs[hi] + dv_h],
                pos0 + ti,
                scale,
                &mut out,
            );
            out
        };
        let n_blocks = t * g.n_heads;
        let mut ctx = Tensor::zeros(&[t, dv]);
        let mut place = |i: usize, blk: Vec<f32>| {
            let (ti, hi) = (i / g.n_heads, i % g.n_heads);
            let dv_h = splits[hi];
            if dv_h == 0 {
                return;
            }
            ctx.row_mut(ti)[offs[hi]..offs[hi] + dv_h].copy_from_slice(&blk);
        };
        let pool = crate::util::pool::current();
        let work = n_blocks * (pos0 + t) * (dh + dv / g.n_heads.max(1));
        if pool.workers() > 1 && n_blocks > 1 && work >= crate::util::pool::PAR_THRESHOLD {
            let blocks = pool.map(n_blocks, |i| block(i / g.n_heads, i % g.n_heads));
            for (i, blk) in blocks.into_iter().enumerate() {
                place(i, blk);
            }
        } else {
            for i in 0..n_blocks {
                place(i, block(i / g.n_heads, i % g.n_heads));
            }
        }
        attn_out_residual(src, l, &ctx, &mut x)?;
        // ---- ffn (the shared sublayer, t rows)
        ffn_sublayer(src, l, &mut x, g.d, g.is_opt)?;
        src.layer_done(l)?;
    }
    for _ in 0..t {
        kv.advance();
    }
    Ok(())
}

// ---------------------------------------------------------------- sampling

/// Next-token selection strategy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampler {
    /// Argmax, lowest index wins ties — fully deterministic.
    Greedy,
    /// Sample from the `k` highest logits under a temperature-scaled
    /// softmax, driven by the caller's seeded [`Rng`]. `k = 1`
    /// degenerates to greedy (and consumes no randomness... almost: it
    /// draws once, but over a single candidate).
    TopK { k: usize, temperature: f32 },
}

/// Pick a token id from one row of logits. Deterministic given the
/// sampler and the Rng state: ties order by index, candidate order is
/// (logit desc, index asc).
///
/// Non-finite logits (NaN/±inf) are never sampled: they sort strictly
/// last (deterministically, by index) and are dropped from the top-k
/// candidate set. The old comparator's `partial_cmp(..).unwrap_or(Equal)`
/// let NaN land anywhere in the sort; a NaN inside the top-k then made
/// `exp(NaN)` poison every softmax weight, so `Rng::categorical`'s
/// running subtraction never fired and it silently returned the *last*
/// (worst) candidate. If every logit is non-finite there is nothing
/// valid to sample and we panic loudly instead of emitting garbage.
pub fn sample_row(logits: &[f32], sampler: Sampler, rng: &mut Rng) -> usize {
    assert!(!logits.is_empty(), "sample_row: empty logits");
    match sampler {
        Sampler::Greedy => {
            let mut best: Option<usize> = None;
            for (i, &v) in logits.iter().enumerate() {
                if !v.is_finite() {
                    continue;
                }
                match best {
                    Some(b) if logits[b] >= v => {}
                    _ => best = Some(i),
                }
            }
            best.expect("sample_row: no finite logit to sample (all NaN/inf)")
        }
        Sampler::TopK { k, temperature } => {
            let k = k.clamp(1, logits.len());
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            idx.sort_unstable_by(|&a, &b| {
                use std::cmp::Ordering;
                match (logits[a].is_finite(), logits[b].is_finite()) {
                    // both finite: total_cmp agrees with partial_cmp
                    // (and, unlike it, has no panic path for R1)
                    (true, true) => logits[b].total_cmp(&logits[a]).then(a.cmp(&b)),
                    (true, false) => Ordering::Less,
                    (false, true) => Ordering::Greater,
                    (false, false) => a.cmp(&b),
                }
            });
            idx.truncate(k);
            // k may exceed the finite candidate count; drop the
            // non-finite tail so the softmax only ever sees real logits
            while idx.len() > 1 && !logits[idx[idx.len() - 1]].is_finite() {
                idx.pop();
            }
            assert!(
                logits[idx[0]].is_finite(),
                "sample_row: no finite logit to sample (all NaN/inf)"
            );
            let temp = temperature.max(1e-6) as f64;
            let m = logits[idx[0]] as f64;
            let weights: Vec<f64> = idx
                .iter()
                .map(|&i| ((logits[i] as f64 - m) / temp).exp())
                .collect();
            idx[rng.categorical(&weights)]
        }
    }
}

// --------------------------------------------------------------- generation

/// Batched generation settings.
#[derive(Clone, Copy, Debug)]
pub struct GenerateOpts {
    /// Tokens to generate per sequence (>= 1).
    pub max_new: usize,
    pub sampler: Sampler,
    /// Seed of the sampling [`Rng`] (unused by greedy).
    pub seed: u64,
}

impl Default for GenerateOpts {
    fn default() -> Self {
        GenerateOpts { max_new: 16, sampler: Sampler::Greedy, seed: 0 }
    }
}

/// One finished generation: the prompt plus sampled continuations, with
/// the per-phase wall-times and the cache-residency receipt.
pub struct Generation {
    /// [b, prompt_len + generated] token ids (prompt included).
    pub tokens: IntTensor,
    pub prompt_len: usize,
    pub generated: usize,
    /// Wall-time of the prompt prefill.
    pub prefill_s: f64,
    /// Wall-time of all decode steps (sampling included).
    pub decode_s: f64,
    /// Cached decode steps executed (`generated - 1`; the final sampled
    /// token needs no forward).
    pub steps: usize,
    /// Allocated K/V bytes of the cache that served this generation.
    pub kv_bytes: usize,
}

impl Generation {
    /// Mean wall-time per cached decode step, seconds.
    pub fn per_token_s(&self) -> f64 {
        self.decode_s / self.steps.max(1) as f64
    }
}

/// Shared up-front prompt validation of every generation entry: an
/// empty prompt — whether `[b, 0]` (no tokens) or `[0, t]` (no
/// sequences) — is a proper `Err` **before any forward work**, with the
/// same "rejected before prefill" wording the oversized-generation
/// guard uses, instead of surfacing later as a confusing cache-geometry
/// error mid-setup.
pub(crate) fn check_generate_prompt(prompt: &IntTensor) -> Result<()> {
    anyhow::ensure!(
        prompt.shape.len() == 2,
        "generate wants [b, t] prompt tokens, got {:?}",
        prompt.shape
    );
    anyhow::ensure!(
        prompt.shape[0] >= 1 && prompt.shape[1] >= 1,
        "generate wants a non-empty prompt ([b, t] with b, t >= 1), got \
         {:?} — rejected before prefill",
        prompt.shape
    );
    Ok(())
}

/// The generation loop over any [`ParamSource`]: prefill the prompt,
/// then sample + decode one token at a time. The cache is sized exactly
/// (`prompt + max_new - 1` positions — the last sampled token is never
/// fed back). Streaming sources are rewound between passes so their
/// prefetch pipeline stays live for every step.
pub fn generate_src<S: ParamSource>(
    src: &mut S,
    prompt: &IntTensor,
    opts: &GenerateOpts,
) -> Result<Generation> {
    check_generate_prompt(prompt)?;
    anyhow::ensure!(opts.max_new >= 1, "generate wants max_new >= 1");
    let (b, t0) = (prompt.shape[0], prompt.shape[1]);
    let cap = t0 + opts.max_new - 1;
    let mut cache = KvCache::for_spec(src.spec(), b, cap)?;
    generate_with_cache_src(src, prompt, opts, &mut cache)
}

/// [`generate_src`] over a caller-supplied (reusable) cache — the
/// serving-style entry where the cache outlives one generation. The
/// whole request is validated against [`KvCache::capacity`] **up
/// front**: a prompt + `max_new` that cannot fit returns a proper
/// `Err` before any forward work, instead of burning a full prefill
/// and N decode steps only to die on `decode_step_src`'s
/// "kv cache overflow" assert mid-generation (that `ensure!` stays as
/// the last-resort invariant). The cache is cleared before prefill.
pub fn generate_with_cache_src<S: ParamSource>(
    src: &mut S,
    prompt: &IntTensor,
    opts: &GenerateOpts,
    cache: &mut KvCache,
) -> Result<Generation> {
    check_generate_prompt(prompt)?;
    anyhow::ensure!(opts.max_new >= 1, "generate wants max_new >= 1");
    let (b, t0) = (prompt.shape[0], prompt.shape[1]);
    cache.check_spec(src.spec(), b)?;
    let need = t0 + opts.max_new - 1;
    anyhow::ensure!(
        need <= cache.capacity(),
        "kv cache overflow: prompt {t0} + max_new {} needs {need} cached \
         positions but capacity is {} — rejected before prefill",
        opts.max_new,
        cache.capacity()
    );
    cache.clear();
    let mut rng = Rng::new(opts.seed);

    let t_pre = std::time::Instant::now();
    let mut logits = prefill_src(src, prompt, &mut cache)?;
    let prefill_s = t_pre.elapsed().as_secs_f64();

    let t_dec = std::time::Instant::now();
    let mut new_tokens: Vec<i32> = Vec::with_capacity(opts.max_new * b);
    let mut steps = 0usize;
    for step in 0..opts.max_new {
        let mut next = Vec::with_capacity(b);
        for bi in 0..b {
            next.push(sample_row(logits.row(bi), opts.sampler, &mut rng) as i32);
        }
        new_tokens.extend_from_slice(&next);
        if step + 1 < opts.max_new {
            src.rewind()?;
            let nt = IntTensor::new(vec![b, 1], next);
            logits = decode_step_src(src, &nt, &mut cache)?;
            steps += 1;
        }
    }
    let decode_s = t_dec.elapsed().as_secs_f64();

    let total = t0 + opts.max_new;
    let mut out = Vec::with_capacity(b * total);
    for bi in 0..b {
        out.extend_from_slice(&prompt.data[bi * t0..(bi + 1) * t0]);
        for step in 0..opts.max_new {
            out.push(new_tokens[step * b + bi]);
        }
    }
    Ok(Generation {
        tokens: IntTensor::new(vec![b, total], out),
        prompt_len: t0,
        generated: opts.max_new,
        prefill_s,
        decode_s,
        steps,
        kv_bytes: cache.kv_bytes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_first_argmax() {
        let mut rng = Rng::new(0);
        assert_eq!(sample_row(&[0.1, 0.9, 0.9, 0.2], Sampler::Greedy, &mut rng), 1);
        assert_eq!(sample_row(&[3.0], Sampler::Greedy, &mut rng), 0);
    }

    #[test]
    fn top1_equals_greedy() {
        let mut rng = Rng::new(7);
        let logits = [0.3f32, -1.0, 2.5, 2.5, 0.0];
        let g = sample_row(&logits, Sampler::Greedy, &mut rng);
        for seed in 0..20u64 {
            let mut r = Rng::new(seed);
            let s = sample_row(
                &logits,
                Sampler::TopK { k: 1, temperature: 0.7 },
                &mut r,
            );
            assert_eq!(s, g, "seed {seed}");
        }
    }

    #[test]
    fn topk_stays_inside_the_top_k() {
        let logits = [5.0f32, 4.0, 3.0, -10.0, -20.0, -30.0];
        let mut r = Rng::new(11);
        for _ in 0..200 {
            let s = sample_row(&logits, Sampler::TopK { k: 3, temperature: 1.0 }, &mut r);
            assert!(s < 3, "sampled {s} outside top-3");
        }
        // same seed → same draws
        let a: Vec<usize> = {
            let mut r = Rng::new(5);
            (0..32)
                .map(|_| sample_row(&logits, Sampler::TopK { k: 3, temperature: 1.0 }, &mut r))
                .collect()
        };
        let b: Vec<usize> = {
            let mut r = Rng::new(5);
            (0..32)
                .map(|_| sample_row(&logits, Sampler::TopK { k: 3, temperature: 1.0 }, &mut r))
                .collect()
        };
        assert_eq!(a, b);
    }
}
