//! Compact repacking (the deployable-artifact half of FASP §3): given a
//! `(Weights, PruneMask)` pair, physically slice out the pruned FFN
//! columns and OV head dims — the interlinked row/column removals the
//! coupled structure makes free — and emit shrunken dense tensors plus a
//! per-layer [`ModelSpec`] that the runtime executes with no masks.
//!
//! Exactness: pruned fc2/w_down columns pair with zeroed fc1/gate/up rows
//! (so the removed hidden units are exactly dead), and pruned wo columns
//! pair with zeroed wv rows (dead context dims). Removing dead terms from
//! a sum does not change it, so the compact forward equals the masked
//! dense forward up to matmul re-blocking (≤ 1e-5 on tiny models), and a
//! sparsity-0 export is bit-identical.
//!
//! On-disk artifact (`<artifacts>/compact/`), two storage formats:
//! * `<name>.compact.json` — self-describing spec: base model, family,
//!   per-layer dims (`d_ff`, `d_ov`, `head_splits`), sparsity, and the
//!   storage descriptor — either a `weights` file name (monolithic) or a
//!   `shards` index (sharded). Parameter shapes are reconstructed from
//!   the dims via [`build_params`], so spec/weights mismatches fail
//!   loudly.
//! * monolithic ([`save_compact`]): `<name>.ftns` — one packed weights
//!   file (same container as checkpoints).
//! * sharded ([`save_compact_sharded`]): `<name>.embed.ftns` plus one
//!   `<name>.layerNNN.ftns` per layer, each checksummed in the spec's
//!   shard index (`runtime::store`), so multi-GB compact models can
//!   stream-load with peak resident weights of O(one layer).
//!
//! All files are written via temp-file + rename so a concurrent
//! `Manifest::load` never observes a half-written artifact.
//!
//! The per-layer tensor slicing (and the per-shard serialization) fans
//! out on the shared worker pool (`util::pool`), so the `repack` phase
//! of `PruneReport` shrinks on multi-core hosts; gathers and
//! serialization are pure, so the exported bytes are identical for any
//! pool width.

use super::mask::{kept_indices, PruneMask};
use super::weights::Weights;
use crate::runtime::manifest::{CompactInfo, CompactStorage, LayerDims, ModelSpec};
use crate::runtime::store::{write_shards, ShardIndex, ShardLayout};
use crate::tensor::ops::{gather_cols, gather_elems, gather_rows};
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// A physically sliced model ready to save or run.
pub struct CompactModel {
    pub spec: ModelSpec,
    pub weights: Weights,
    pub base_model: String,
    pub sparsity: f64,
}

/// `layers.<l>.<short>` → `(l, short)`.
fn split_layer_param(name: &str) -> Option<(usize, &str)> {
    let rest = name.strip_prefix("layers.")?;
    let dot = rest.find('.')?;
    let l: usize = rest[..dot].parse().ok()?;
    Some((l, &rest[dot + 1..]))
}

/// The packed parameter order for a (possibly per-layer-sliced) model —
/// mirrors `python/compile/configs.py::param_spec` with per-layer dims.
pub fn build_params(
    family: &str,
    d_model: usize,
    n_layers: usize,
    vocab: usize,
    seq: usize,
    layer_dims: &[LayerDims],
) -> Vec<(String, Vec<usize>)> {
    let d = d_model;
    let mut params: Vec<(String, Vec<usize>)> = vec![("tok_emb".into(), vec![vocab, d])];
    if family == "opt" {
        params.push(("pos_emb".into(), vec![seq, d]));
    }
    for (i, ld) in layer_dims.iter().enumerate().take(n_layers) {
        let p = format!("layers.{i}.");
        let f = ld.d_ff;
        let ov = ld.d_ov;
        if family == "opt" {
            for (n, s) in [
                ("ln1_g", vec![d]),
                ("ln1_b", vec![d]),
                ("wq", vec![d, d]),
                ("bq", vec![d]),
                ("wk", vec![d, d]),
                ("bk", vec![d]),
                ("wv", vec![ov, d]),
                ("bv", vec![ov]),
                ("wo", vec![d, ov]),
                ("bo", vec![d]),
                ("ln2_g", vec![d]),
                ("ln2_b", vec![d]),
                ("fc1", vec![f, d]),
                ("bfc1", vec![f]),
                ("fc2", vec![d, f]),
                ("bfc2", vec![d]),
            ] {
                params.push((format!("{p}{n}"), s));
            }
        } else {
            for (n, s) in [
                ("ln1_g", vec![d]),
                ("wq", vec![d, d]),
                ("wk", vec![d, d]),
                ("wv", vec![ov, d]),
                ("wo", vec![d, ov]),
                ("bo", vec![d]),
                ("ln2_g", vec![d]),
                ("w_gate", vec![f, d]),
                ("w_up", vec![f, d]),
                ("w_down", vec![d, f]),
                ("b_down", vec![d]),
            ] {
                params.push((format!("{p}{n}"), s));
            }
        }
    }
    params.push(("lnf_g".into(), vec![d]));
    if family == "opt" {
        params.push(("lnf_b".into(), vec![d]));
    }
    params
}

/// Physically repack `base` under `mask` into a compact model named
/// `name`. The mask must keep Q/K dense (FASP's default) and at least one
/// unit per group per layer.
pub fn compact_from_mask(
    base: &Weights,
    mask: &PruneMask,
    name: &str,
) -> Result<CompactModel> {
    let spec = &base.spec;
    mask.validate(spec)
        .context("compact export: mask does not fit the model spec")?;

    let mut kept_ffn: Vec<Vec<usize>> = Vec::with_capacity(spec.n_layers);
    let mut kept_ov: Vec<Vec<usize>> = Vec::with_capacity(spec.n_layers);
    let mut layer_dims: Vec<LayerDims> = Vec::with_capacity(spec.n_layers);
    for (l, lm) in mask.layers.iter().enumerate() {
        anyhow::ensure!(
            lm.qk.iter().all(|&k| k),
            "layer {l}: compact export does not support Q/K-pruned masks \
             (FASP §3.1 keeps Q/K dense); re-run without --prune-qk"
        );
        let kf = kept_indices(&lm.ffn);
        let ko = kept_indices(&lm.ov);
        anyhow::ensure!(
            !kf.is_empty() && !ko.is_empty(),
            "layer {l}: compact export needs at least one kept unit per \
             group (ffn kept {}, ov kept {})",
            kf.len(),
            ko.len()
        );
        // map kept OV dims onto the base model's per-head blocks
        let base_splits = spec.head_splits_l(l);
        let mut offs = vec![0usize; base_splits.len() + 1];
        for (hi, &s) in base_splits.iter().enumerate() {
            offs[hi + 1] = offs[hi] + s;
        }
        let head_splits: Vec<usize> = (0..spec.n_heads)
            .map(|hi| {
                ko.iter()
                    .filter(|&&j| j >= offs[hi] && j < offs[hi + 1])
                    .count()
            })
            .collect();
        layer_dims.push(LayerDims {
            d_ff: kf.len(),
            d_ov: ko.len(),
            head_splits,
        });
        kept_ffn.push(kf);
        kept_ov.push(ko);
    }

    let params = build_params(
        &spec.family,
        spec.d_model,
        spec.n_layers,
        spec.vocab,
        spec.seq,
        &layer_dims,
    );
    let new_spec = ModelSpec {
        name: name.to_string(),
        family: spec.family.clone(),
        d_model: spec.d_model,
        n_heads: spec.n_heads,
        n_layers: spec.n_layers,
        d_ff: spec.d_ff,
        vocab: spec.vocab,
        seq: spec.seq,
        batch: spec.batch,
        params,
        layer_dims,
    };

    // Per-parameter slicing is embarrassingly parallel (disjoint source
    // reads, disjoint destination tensors): fan out on the ambient worker
    // pool — the session's backend pool when called from `prune_compact`
    // — then write the slices back in parameter order. Gathers are pure
    // copies, so the result is pool-width-independent.
    let mut out = Weights::zeros(&new_spec);
    let names: Vec<String> = new_spec.params.iter().map(|(n, _)| n.clone()).collect();
    let pool = crate::util::pool::current();
    let sliced: Vec<Result<Tensor>> = pool.map(names.len(), |i| {
        let pname = &names[i];
        let src = base.get(pname)?;
        Ok(match split_layer_param(pname) {
            Some((l, short)) => match short {
                "fc1" | "w_gate" | "w_up" => gather_rows(&src, &kept_ffn[l]),
                "bfc1" => gather_elems(&src, &kept_ffn[l]),
                "fc2" | "w_down" => gather_cols(&src, &kept_ffn[l]),
                "wv" => gather_rows(&src, &kept_ov[l]),
                "bv" => gather_elems(&src, &kept_ov[l]),
                "wo" => gather_cols(&src, &kept_ov[l]),
                _ => src,
            },
            None => src,
        })
    });
    for (pname, dst) in names.iter().zip(sliced) {
        out.set(pname, &dst?)?;
    }

    Ok(CompactModel {
        spec: new_spec,
        weights: out,
        base_model: spec.name.clone(),
        sparsity: mask.sparsity(spec),
    })
}

// ---------------------------------------------------------------- disk io

/// How a compact export lays its weights on disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExportMode {
    /// One packed `.ftns` file (the classic format).
    Monolithic,
    /// One `.ftns` shard per layer plus an embed/head shard, with a
    /// checksummed shard index in the spec (stream-loadable).
    Sharded,
}

impl ExportMode {
    pub fn parse(s: &str) -> Option<ExportMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "monolithic" | "mono" | "packed" => Some(ExportMode::Monolithic),
            "sharded" | "shard" | "shards" => Some(ExportMode::Sharded),
            _ => None,
        }
    }

    /// The process-default export mode: `FASP_EXPORT` if set and valid
    /// (`monolithic` | `sharded`), else monolithic. `verify.sh` runs the
    /// tier-1 suite under both values.
    pub fn from_env() -> ExportMode {
        match std::env::var("FASP_EXPORT") {
            Ok(v) => ExportMode::parse(&v).unwrap_or_else(|| {
                crate::warn!(
                    "FASP_EXPORT='{v}' not recognized (want 'monolithic' or \
                     'sharded'); defaulting to monolithic"
                );
                ExportMode::Monolithic
            }),
            Err(_) => ExportMode::Monolithic,
        }
    }
}

fn spec_to_json(cm: &CompactModel, storage: (&str, Json)) -> Json {
    let s = &cm.spec;
    let dims = Json::Arr(
        s.layer_dims
            .iter()
            .map(|ld| {
                Json::obj(vec![
                    ("d_ff", Json::Num(ld.d_ff as f64)),
                    ("d_ov", Json::Num(ld.d_ov as f64)),
                    (
                        "head_splits",
                        Json::Arr(
                            ld.head_splits.iter().map(|&x| Json::Num(x as f64)).collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    Json::obj(vec![
        ("format", Json::Num(1.0)),
        ("kind", Json::Str("compact".into())),
        ("name", Json::Str(s.name.clone())),
        ("base_model", Json::Str(cm.base_model.clone())),
        ("family", Json::Str(s.family.clone())),
        ("sparsity", Json::Num(cm.sparsity)),
        ("d_model", Json::Num(s.d_model as f64)),
        ("n_heads", Json::Num(s.n_heads as f64)),
        ("n_layers", Json::Num(s.n_layers as f64)),
        ("d_ff", Json::Num(s.d_ff as f64)),
        ("vocab", Json::Num(s.vocab as f64)),
        ("seq", Json::Num(s.seq as f64)),
        ("batch", Json::Num(s.batch as f64)),
        ("layer_dims", dims),
        storage,
    ])
}

fn write_spec_json(dir: &Path, cm: &CompactModel, storage: (&str, Json)) -> Result<PathBuf> {
    let jname = format!("{}.compact.json", cm.spec.name);
    let jtmp = dir.join(format!("{jname}.tmp"));
    std::fs::write(&jtmp, spec_to_json(cm, storage).pretty())
        .with_context(|| format!("write {}", jtmp.display()))?;
    let jpath = dir.join(&jname);
    std::fs::rename(&jtmp, &jpath)
        .with_context(|| format!("publish {}", jpath.display()))?;
    Ok(jpath)
}

/// Write `<name>.ftns` + `<name>.compact.json` under `dir` (created on
/// demand), atomically. Returns the json path.
pub fn save_compact(dir: &Path, cm: &CompactModel) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create {}", dir.display()))?;
    let wname = format!("{}.ftns", cm.spec.name);
    let wtmp = dir.join(format!("{wname}.tmp"));
    cm.weights.save(&wtmp)?;
    std::fs::rename(&wtmp, dir.join(&wname))
        .with_context(|| format!("publish {}", wname))?;
    write_spec_json(dir, cm, ("weights", Json::Str(wname)))
}

/// Write a sharded export under `dir`: one `.ftns` shard per layer plus
/// the embed/head shard (`runtime::store::write_shards`, pool-parallel,
/// per-shard checksums) and a `<name>.compact.json` carrying the shard
/// index. Returns the json path.
pub fn save_compact_sharded(dir: &Path, cm: &CompactModel) -> Result<PathBuf> {
    let index = write_shards(dir, cm)?;
    write_spec_json(dir, cm, ("shards", index.to_json()))
}

/// [`save_compact_sharded`] with an explicit layer-shard payload dtype:
/// `Quant::Int8` writes quantized layer shards (~0.27× the f32 stream
/// bytes; the embed/head shard stays f32). The shard index records the
/// dtype, so `ShardedWeights::open` serves the store transparently.
pub fn save_compact_sharded_q(
    dir: &Path,
    cm: &CompactModel,
    quant: crate::tensor::pack::Quant,
) -> Result<PathBuf> {
    let index = crate::runtime::store::write_shards_q(dir, cm, quant)?;
    write_spec_json(dir, cm, ("shards", index.to_json()))
}

/// Save in the process-default [`ExportMode`] (`FASP_EXPORT`).
pub fn save_compact_auto(dir: &Path, cm: &CompactModel) -> Result<PathBuf> {
    match ExportMode::from_env() {
        ExportMode::Monolithic => save_compact(dir, cm),
        ExportMode::Sharded => save_compact_sharded(dir, cm),
    }
}

/// Parse and validate a `*.compact.json` descriptor (no weights read).
/// Dimension inconsistencies (head splits not summing to `d_ov`, wrong
/// layer counts, bad fields) fail loudly here.
pub fn load_compact_spec(path: &Path) -> Result<(ModelSpec, CompactInfo)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read compact spec {}", path.display()))?;
    let j = Json::parse(&text)
        .with_context(|| format!("parse compact spec {}", path.display()))?;
    match j.get("kind").as_str() {
        Some("compact") => {}
        other => bail!(
            "{}: not a compact artifact (kind = {:?})",
            path.display(),
            other
        ),
    }
    let name = j.get("name").as_str().context("compact field 'name'")?.to_string();
    let family = j.get("family").as_str().context("compact field 'family'")?.to_string();
    anyhow::ensure!(
        family == "opt" || family == "llama",
        "compact '{name}': unknown family '{family}'"
    );
    let base_model = j
        .get("base_model")
        .as_str()
        .context("compact field 'base_model'")?
        .to_string();
    let sparsity = j.get("sparsity").as_f64().context("compact field 'sparsity'")?;
    let get = |k: &str| -> Result<usize> {
        j.get(k).as_usize().with_context(|| format!("compact field '{k}'"))
    };
    let d_model = get("d_model")?;
    let n_heads = get("n_heads")?;
    let n_layers = get("n_layers")?;
    let d_ff = get("d_ff")?;
    let vocab = get("vocab")?;
    let seq = get("seq")?;
    let batch = get("batch")?;
    anyhow::ensure!(n_heads > 0 && d_model % n_heads == 0, "compact '{name}': d_model {d_model} not divisible by {n_heads} heads");

    let dims_json = j.get("layer_dims").as_arr().context("compact field 'layer_dims'")?;
    anyhow::ensure!(
        dims_json.len() == n_layers,
        "compact '{name}': {} layer_dims entries for {} layers",
        dims_json.len(),
        n_layers
    );
    let mut layer_dims = Vec::with_capacity(n_layers);
    for (l, ld) in dims_json.iter().enumerate() {
        let lf = ld.get("d_ff").as_usize().with_context(|| format!("layer {l} d_ff"))?;
        let lov = ld.get("d_ov").as_usize().with_context(|| format!("layer {l} d_ov"))?;
        let splits: Vec<usize> = ld
            .get("head_splits")
            .as_arr()
            .with_context(|| format!("layer {l} head_splits"))?
            .iter()
            .map(|x| {
                x.as_f64()
                    .filter(|v| *v >= 0.0 && v.fract() == 0.0)
                    .map(|v| v as usize)
                    .with_context(|| {
                        format!("layer {l} head_splits: entry is not a non-negative integer")
                    })
            })
            .collect::<Result<_>>()?;
        anyhow::ensure!(
            splits.len() == n_heads,
            "compact '{name}' layer {l}: {} head splits for {} heads — \
             spec/mask dimension mismatch",
            splits.len(),
            n_heads
        );
        let sum: usize = splits.iter().sum();
        anyhow::ensure!(
            sum == lov,
            "compact '{name}' layer {l}: head_splits sum {sum} != d_ov {lov} — \
             spec/mask dimension mismatch"
        );
        anyhow::ensure!(
            lf >= 1 && lov >= 1,
            "compact '{name}' layer {l}: degenerate dims (d_ff {lf}, d_ov {lov})"
        );
        layer_dims.push(LayerDims { d_ff: lf, d_ov: lov, head_splits: splits });
    }

    let params = build_params(&family, d_model, n_layers, vocab, seq, &layer_dims);
    let spec = ModelSpec {
        name,
        family,
        d_model,
        n_heads,
        n_layers,
        d_ff,
        vocab,
        seq,
        batch,
        params,
        layer_dims,
    };

    let dir = path.parent().unwrap_or_else(|| Path::new(".")).to_path_buf();
    let storage = match (j.get("weights").as_str(), j.get("shards").as_arr()) {
        (Some(wfile), None) => CompactStorage::Monolithic {
            weights_path: dir.join(wfile),
        },
        (None, Some(_)) => {
            let index = ShardIndex::from_json(j.get("shards"))
                .with_context(|| format!("compact '{}': shard index", spec.name))?;
            let layout = ShardLayout::of(&spec)?;
            index.validate(&spec.name, &layout)?;
            CompactStorage::Sharded { dir, index }
        }
        (Some(_), Some(_)) => bail!(
            "compact '{}': both 'weights' and 'shards' declared — pick one",
            spec.name
        ),
        (None, None) => bail!(
            "compact '{}': neither 'weights' nor 'shards' declared",
            spec.name
        ),
    };
    let info = CompactInfo { base_model, sparsity, storage };
    Ok((spec, info))
}

/// Load a full compact model (spec + weights) from its descriptor —
/// either storage format; sharded artifacts are assembled shard by
/// shard.
pub fn load_compact(path: &Path) -> Result<CompactModel> {
    let (spec, info) = load_compact_spec(path)?;
    let weights = info.storage.load_weights(&spec)?;
    Ok(CompactModel {
        spec,
        weights,
        base_model: info.base_model,
        sparsity: info.sparsity,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mask::PruneMask;

    fn toy_spec() -> ModelSpec {
        let layer_dims = vec![
            LayerDims { d_ff: 16, d_ov: 8, head_splits: vec![4, 4] },
            LayerDims { d_ff: 16, d_ov: 8, head_splits: vec![4, 4] },
        ];
        let params = build_params("llama", 8, 2, 32, 16, &layer_dims);
        ModelSpec {
            name: "toy".into(),
            family: "llama".into(),
            d_model: 8,
            n_heads: 2,
            n_layers: 2,
            d_ff: 16,
            vocab: 32,
            seq: 16,
            batch: 2,
            params,
            layer_dims,
        }
    }

    #[test]
    fn zero_sparsity_export_is_identity() {
        let spec = toy_spec();
        let w = Weights::init(&spec, 5);
        let mask = PruneMask::full(&spec);
        let cm = compact_from_mask(&w, &mask, "toy_c").unwrap();
        assert_eq!(cm.spec.params, spec.params);
        assert_eq!(cm.weights.packed, w.packed); // bit-identical
        assert!(cm.spec.is_uniform());
    }

    #[test]
    fn export_shrinks_declared_dims() {
        let spec = toy_spec();
        let w = Weights::init(&spec, 6);
        let mut mask = PruneMask::full(&spec);
        mask.layers[0].ffn[3] = false;
        mask.layers[0].ffn[7] = false;
        mask.layers[1].ov[5] = false; // head 1 loses a dim
        let cm = compact_from_mask(&w, &mask, "toy_c").unwrap();
        assert_eq!(cm.spec.d_ff_l(0), 14);
        assert_eq!(cm.spec.d_ff_l(1), 16);
        assert_eq!(cm.spec.d_ov_l(1), 7);
        assert_eq!(cm.spec.head_splits_l(1), vec![4, 3]);
        assert!(!cm.spec.is_uniform());
        assert!(cm.spec.n_params_elems() < spec.n_params_elems());
        // sliced tensors have the declared shapes
        assert_eq!(cm.weights.get_l(0, "w_down").unwrap().shape, vec![8, 14]);
        assert_eq!(cm.weights.get_l(1, "wv").unwrap().shape, vec![7, 8]);
        assert_eq!(cm.weights.get_l(1, "wo").unwrap().shape, vec![8, 7]);
    }

    #[test]
    fn qk_pruned_mask_rejected() {
        let spec = toy_spec();
        let w = Weights::init(&spec, 7);
        let mut mask = PruneMask::full(&spec);
        mask.layers[0].qk[2] = false;
        let err = compact_from_mask(&w, &mask, "x").unwrap_err();
        assert!(format!("{err:#}").contains("Q/K"), "{err:#}");
    }

    #[test]
    fn save_load_roundtrip() {
        let spec = toy_spec();
        let w = Weights::init(&spec, 8);
        let mut mask = PruneMask::full(&spec);
        mask.layers[0].ffn[0] = false;
        mask.layers[1].ov[1] = false;
        let cm = compact_from_mask(&w, &mask, "toy_rt").unwrap();
        let dir = std::env::temp_dir().join("fasp_compact_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let jpath = save_compact(&dir, &cm).unwrap();
        let re = load_compact(&jpath).unwrap();
        assert_eq!(re.spec, cm.spec);
        assert_eq!(re.weights.packed, cm.weights.packed);
        assert_eq!(re.base_model, "toy");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_save_load_roundtrip() {
        let spec = toy_spec();
        let w = Weights::init(&spec, 12);
        let mut mask = PruneMask::full(&spec);
        mask.layers[0].ffn[2] = false;
        mask.layers[1].ov[3] = false;
        let cm = compact_from_mask(&w, &mask, "toy_sh").unwrap();
        let dir = std::env::temp_dir().join("fasp_compact_sharded_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let jpath = save_compact_sharded(&dir, &cm).unwrap();
        let re = load_compact(&jpath).unwrap();
        assert_eq!(re.spec, cm.spec);
        assert_eq!(re.weights.packed, cm.weights.packed, "sharded round trip must be bit-identical");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn export_mode_parses() {
        assert_eq!(ExportMode::parse("sharded"), Some(ExportMode::Sharded));
        assert_eq!(ExportMode::parse("Shard"), Some(ExportMode::Sharded));
        assert_eq!(ExportMode::parse("MONO"), Some(ExportMode::Monolithic));
        assert_eq!(ExportMode::parse("monolithic"), Some(ExportMode::Monolithic));
        assert_eq!(ExportMode::parse("bogus"), None);
    }
}
