//! Host-side reference forward pass for both families. This is the
//! independent implementation used to cross-check the PJRT artifacts
//! (test_runtime) and as an offline fallback when artifacts are absent.
//! Mirrors `python/compile/model.py` exactly — any drift is a test
//! failure, not a silent divergence.

use crate::runtime::manifest::ModelSpec;
use crate::tensor::matmul::{matmul_bt, matmul};
use crate::tensor::ops::logsumexp;
use crate::tensor::{IntTensor, Tensor};
use super::weights::Weights;
use anyhow::Result;

const LN_EPS: f32 = 1e-5;

fn layer_norm(x: &mut [f32], d: usize, g: &[f32], b: &[f32]) {
    for row in x.chunks_exact_mut(d) {
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - mu) * inv * g[i] + b[i];
        }
    }
}

fn rms_norm(x: &mut [f32], d: usize, g: &[f32]) {
    for row in x.chunks_exact_mut(d) {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + LN_EPS).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = *v * inv * g[i];
        }
    }
}

/// cos/sin tables [t, dh/2] matching python rope_tables.
fn rope_tables(t: usize, dh: usize) -> (Vec<f32>, Vec<f32>) {
    let half = dh / 2;
    let mut cos = vec![0.0f32; t * half];
    let mut sin = vec![0.0f32; t * half];
    for ti in 0..t {
        for k in 0..half {
            let inv_freq = 1.0f64 / 10000f64.powf(k as f64 / half as f64);
            let ang = ti as f64 * inv_freq;
            cos[ti * half + k] = ang.cos() as f32;
            sin[ti * half + k] = ang.sin() as f32;
        }
    }
    (cos, sin)
}

/// Rotate-half RoPE applied in place to [t, dh] rows of one head.
fn apply_rope(x: &mut [f32], t: usize, dh: usize, cos: &[f32], sin: &[f32]) {
    let half = dh / 2;
    for ti in 0..t {
        let row = &mut x[ti * dh..(ti + 1) * dh];
        for k in 0..half {
            let c = cos[ti * half + k];
            let s = sin[ti * half + k];
            let x1 = row[k];
            let x2 = row[half + k];
            row[k] = x1 * c - x2 * s;
            row[half + k] = x1 * s + x2 * c;
        }
    }
}

/// Linear y = x·Wᵀ (+ b). x is [rows, in], w is [out, in].
fn linear(x: &Tensor, w: &Tensor, b: Option<&Tensor>) -> Tensor {
    let mut y = matmul_bt(x, w);
    if let Some(b) = b {
        let (rows, out) = y.dims2();
        for r in 0..rows {
            let row = &mut y.data[r * out..(r + 1) * out];
            for (v, bv) in row.iter_mut().zip(&b.data) {
                *v += bv;
            }
        }
    }
    y
}

/// Per-layer calibration activations (host mirror of capture.py), used by
/// tests to validate the capture artifact's Gram matrices.
pub struct HostCaptures {
    pub ln1: Tensor,
    pub ln2: Tensor,
    pub attn_ctx: Tensor,
    pub ffn_h: Tensor,
}

/// Full host forward: per-token NLL [b, t] of `targets` under the model
/// given `tokens` (teacher forcing, same contract as the fwd_loss
/// artifact), plus optionally the per-layer capture activations.
pub fn forward_nll(
    w: &Weights,
    tokens: &IntTensor,
    targets: &IntTensor,
    collect: bool,
) -> Result<(Tensor, Vec<HostCaptures>)> {
    let spec = &w.spec;
    let (b, t) = (tokens.shape[0], tokens.shape[1]);
    let d = spec.d_model;
    let rows = b * t;

    let tok_emb = w.get("tok_emb")?;
    // x [rows, d]
    let mut x = Tensor::zeros(&[rows, d]);
    for (r, &tokid) in tokens.data.iter().enumerate() {
        x.row_mut(r).copy_from_slice(tok_emb.row(tokid as usize));
    }
    let is_opt = spec.family == "opt";
    if is_opt {
        let pos = w.get("pos_emb")?;
        for bi in 0..b {
            for ti in 0..t {
                let r = bi * t + ti;
                for (v, p) in x.row_mut(r).iter_mut().zip(pos.row(ti)) {
                    *v += p;
                }
            }
        }
    }
    let (cos, sin) = rope_tables(t, spec.head_dim());

    let mut captures = Vec::new();
    for l in 0..spec.n_layers {
        // ---- attention
        let mut x_ln = x.clone();
        if is_opt {
            layer_norm(
                &mut x_ln.data,
                d,
                &w.get_l(l, "ln1_g")?.data,
                &w.get_l(l, "ln1_b")?.data,
            );
        } else {
            rms_norm(&mut x_ln.data, d, &w.get_l(l, "ln1_g")?.data);
        }
        let (q, k, v) = if is_opt {
            (
                linear(&x_ln, &w.get_l(l, "wq")?, Some(&w.get_l(l, "bq")?)),
                linear(&x_ln, &w.get_l(l, "wk")?, Some(&w.get_l(l, "bk")?)),
                linear(&x_ln, &w.get_l(l, "wv")?, Some(&w.get_l(l, "bv")?)),
            )
        } else {
            (
                linear(&x_ln, &w.get_l(l, "wq")?, None),
                linear(&x_ln, &w.get_l(l, "wk")?, None),
                linear(&x_ln, &w.get_l(l, "wv")?, None),
            )
        };
        let ctx = attention(spec, b, t, &q, &k, &v, &cos, &sin, !is_opt);
        // both families carry an out-proj bias (llama's is the zero-init
        // FLAP-compensation slot, see configs.py)
        let attn_out = linear(&ctx, &w.get_l(l, "wo")?, Some(&w.get_l(l, "bo")?));
        for (xv, av) in x.data.iter_mut().zip(&attn_out.data) {
            *xv += av;
        }

        // ---- ffn
        let mut x_ln2 = x.clone();
        if is_opt {
            layer_norm(
                &mut x_ln2.data,
                d,
                &w.get_l(l, "ln2_g")?.data,
                &w.get_l(l, "ln2_b")?.data,
            );
        } else {
            rms_norm(&mut x_ln2.data, d, &w.get_l(l, "ln2_g")?.data);
        }
        let h = if is_opt {
            let mut h = linear(&x_ln2, &w.get_l(l, "fc1")?, Some(&w.get_l(l, "bfc1")?));
            for v in h.data.iter_mut() {
                *v = v.max(0.0); // relu
            }
            h
        } else {
            let g = linear(&x_ln2, &w.get_l(l, "w_gate")?, None);
            let u = linear(&x_ln2, &w.get_l(l, "w_up")?, None);
            let mut h = u;
            for (hv, gv) in h.data.iter_mut().zip(&g.data) {
                let silu = gv / (1.0 + (-gv).exp());
                *hv *= silu;
            }
            h
        };
        let ffn_out = if is_opt {
            linear(&h, &w.get_l(l, "fc2")?, Some(&w.get_l(l, "bfc2")?))
        } else {
            linear(&h, &w.get_l(l, "w_down")?, Some(&w.get_l(l, "b_down")?))
        };
        for (xv, fv) in x.data.iter_mut().zip(&ffn_out.data) {
            *xv += fv;
        }
        if collect {
            captures.push(HostCaptures { ln1: x_ln, ln2: x_ln2, attn_ctx: ctx, ffn_h: h });
        }
    }

    if is_opt {
        layer_norm(&mut x.data, d, &w.get("lnf_g")?.data, &w.get("lnf_b")?.data);
    } else {
        rms_norm(&mut x.data, d, &w.get("lnf_g")?.data);
    }

    // logits = x · tok_embᵀ; per-token NLL without materializing softmax
    let logits = matmul_bt(&x, &tok_emb); // [rows, V]
    let mut nll = Tensor::zeros(&[b, t]);
    for r in 0..rows {
        let row = logits.row(r);
        let z = logsumexp(row);
        let tgt = targets.data[r] as usize;
        nll.data[r] = z - row[tgt];
    }
    Ok((nll, captures))
}

#[allow(clippy::too_many_arguments)]
fn attention(
    spec: &ModelSpec,
    b: usize,
    t: usize,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cos: &[f32],
    sin: &[f32],
    rope: bool,
) -> Tensor {
    let d = spec.d_model;
    let h = spec.n_heads;
    let dh = spec.head_dim();
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = Tensor::zeros(&[b * t, d]);
    // per (batch, head): gather [t, dh] slices, optional rope, attention
    let mut qh = vec![0.0f32; t * dh];
    let mut kh = vec![0.0f32; t * dh];
    let mut vh = vec![0.0f32; t * dh];
    for bi in 0..b {
        for hi in 0..h {
            for ti in 0..t {
                let r = bi * t + ti;
                let src = hi * dh..(hi + 1) * dh;
                qh[ti * dh..(ti + 1) * dh].copy_from_slice(&q.row(r)[src.clone()]);
                kh[ti * dh..(ti + 1) * dh].copy_from_slice(&k.row(r)[src.clone()]);
                vh[ti * dh..(ti + 1) * dh].copy_from_slice(&v.row(r)[src]);
            }
            if rope {
                apply_rope(&mut qh, t, dh, cos, sin);
                apply_rope(&mut kh, t, dh, cos, sin);
            }
            // causal attention rows
            for ti in 0..t {
                let qrow = &qh[ti * dh..(ti + 1) * dh];
                // scores over [0..=ti]
                let mut scores = Vec::with_capacity(ti + 1);
                for tj in 0..=ti {
                    let krow = &kh[tj * dh..(tj + 1) * dh];
                    scores.push(
                        crate::tensor::matmul::dot(qrow, krow) * scale,
                    );
                }
                let m = scores.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
                let mut z = 0.0f32;
                for s in scores.iter_mut() {
                    *s = (*s - m).exp();
                    z += *s;
                }
                let out = &mut ctx.row_mut(bi * t + ti)[hi * dh..(hi + 1) * dh];
                for (tj, w) in scores.iter().enumerate() {
                    let vrow = &vh[tj * dh..(tj + 1) * dh];
                    let wz = w / z;
                    for (o, vv) in out.iter_mut().zip(vrow) {
                        *o += wz * vv;
                    }
                }
            }
        }
    }
    ctx
}

/// Host Gram accumulation X^T X (cross-check against the capture artifact).
pub fn host_gram(x: &Tensor) -> Tensor {
    matmul(&x.t(), x)
}

/// Mean NLL over a batch.
pub fn mean_nll(w: &Weights, tokens: &IntTensor, targets: &IntTensor) -> Result<f32> {
    let (nll, _) = forward_nll(w, tokens, targets, false)?;
    Ok(nll.data.iter().sum::<f32>() / nll.numel() as f32)
}
