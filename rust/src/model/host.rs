//! Host-side reference forward pass for both families — the execution
//! engine of the host runtime backend (see `runtime::host_exec`) and the
//! independent numerics baseline every test pins down.
//! Mirrors `python/compile/model.py` exactly — any drift is a test
//! failure, not a silent divergence.
//!
//! Per-layer dims: a compact (physically sliced) model keeps a different
//! number of FFN hidden units and attention V/out dims in every layer
//! (`ModelSpec::layer_dims`), with the V/out dims split unevenly across
//! heads. The forward reads those dims per layer, so masked-dense and
//! compact models run through the same code path.
//!
//! Parallelism: attention (batch, head) blocks and per-token NLL rows fan
//! out on the ambient worker pool (`util::pool`), installed by the
//! session's backend. Every fan-out keeps the serial reduction order, so
//! outputs are bit-identical for any pool width.

use crate::runtime::manifest::ModelSpec;
use crate::tensor::matmul::{matmul_bt, matmul};
use crate::tensor::ops::logsumexp;
use crate::tensor::{IntTensor, Tensor};
use super::weights::Weights;
use anyhow::Result;

pub(crate) const LN_EPS: f32 = 1e-5;

pub(crate) fn layer_norm(x: &mut [f32], d: usize, g: &[f32], b: &[f32]) {
    for row in x.chunks_exact_mut(d) {
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - mu) * inv * g[i] + b[i];
        }
    }
}

pub(crate) fn rms_norm(x: &mut [f32], d: usize, g: &[f32]) {
    for row in x.chunks_exact_mut(d) {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + LN_EPS).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = *v * inv * g[i];
        }
    }
}

/// cos/sin tables [t, dh/2] matching python rope_tables.
pub(crate) fn rope_tables(t: usize, dh: usize) -> (Vec<f32>, Vec<f32>) {
    let half = dh / 2;
    let mut cos = vec![0.0f32; t * half];
    let mut sin = vec![0.0f32; t * half];
    for ti in 0..t {
        for k in 0..half {
            let inv_freq = 1.0f64 / 10000f64.powf(k as f64 / half as f64);
            let ang = ti as f64 * inv_freq;
            cos[ti * half + k] = ang.cos() as f32;
            sin[ti * half + k] = ang.sin() as f32;
        }
    }
    (cos, sin)
}

/// Rotate-half RoPE applied in place to [t, dh] rows of one head.
pub(crate) fn apply_rope(x: &mut [f32], t: usize, dh: usize, cos: &[f32], sin: &[f32]) {
    let half = dh / 2;
    for ti in 0..t {
        let row = &mut x[ti * dh..(ti + 1) * dh];
        for k in 0..half {
            let c = cos[ti * half + k];
            let s = sin[ti * half + k];
            let x1 = row[k];
            let x2 = row[half + k];
            row[k] = x1 * c - x2 * s;
            row[half + k] = x1 * s + x2 * c;
        }
    }
}

/// Linear y = x·Wᵀ (+ b). x is [rows, in], w is [out, in].
pub(crate) fn linear(x: &Tensor, w: &Tensor, b: Option<&Tensor>) -> Tensor {
    let mut y = matmul_bt(x, w);
    if let Some(b) = b {
        let (rows, out) = y.dims2();
        for r in 0..rows {
            let row = &mut y.data[r * out..(r + 1) * out];
            for (v, bv) in row.iter_mut().zip(&b.data) {
                *v += bv;
            }
        }
    }
    y
}

/// Per-layer calibration activations (host mirror of capture.py), used by
/// the capture entry and by tests to validate the Gram matrices.
pub struct HostCaptures {
    pub ln1: Tensor,
    pub ln2: Tensor,
    pub attn_ctx: Tensor,
    pub ffn_h: Tensor,
}

/// Full host forward: per-token NLL [b, t] of `targets` under the model
/// given `tokens` (teacher forcing, same contract as the fwd_loss
/// artifact), plus optionally the per-layer capture activations.
pub fn forward_nll(
    w: &Weights,
    tokens: &IntTensor,
    targets: &IntTensor,
    collect: bool,
) -> Result<(Tensor, Vec<HostCaptures>)> {
    forward_nll_src(&mut super::weights::DenseParams(w), tokens, targets, collect)
}

/// [`forward_nll`] over an arbitrary [`ParamSource`]. Layers are visited
/// strictly in order and each is released (`layer_done`) before the next
/// is requested, so a streaming source holds at most one layer's shard
/// (plus its prefetch buffer) at a time. The embedding/head parameters
/// (`tok_emb`, the final norm) stay resident for the whole pass — the
/// tied head reuses `tok_emb` for the logits.
pub fn forward_nll_src<S: super::weights::ParamSource>(
    src: &mut S,
    tokens: &IntTensor,
    targets: &IntTensor,
    collect: bool,
) -> Result<(Tensor, Vec<HostCaptures>)> {
    // Pull the scalar geometry out up front: `src` hands out tensors
    // through &mut below, and cloning the whole spec (params table
    // included) per forward would tax the hot path.
    let spec = src.spec();
    let d = spec.d_model;
    let n_layers = spec.n_layers;
    let n_heads = spec.n_heads;
    let head_dim = spec.head_dim();
    let vocab = spec.vocab;
    let is_opt = spec.family == "opt";
    let head_splits: Vec<Vec<usize>> =
        (0..n_layers).map(|l| spec.head_splits_l(l)).collect();
    let (b, t) = (tokens.shape[0], tokens.shape[1]);
    let rows = b * t;

    let tok_emb = src.get("tok_emb")?;
    // x [rows, d]
    let mut x = Tensor::zeros(&[rows, d]);
    for (r, &tokid) in tokens.data.iter().enumerate() {
        x.row_mut(r).copy_from_slice(tok_emb.row(tokid as usize));
    }
    if is_opt {
        let pos = src.get("pos_emb")?;
        for bi in 0..b {
            for ti in 0..t {
                let r = bi * t + ti;
                for (v, p) in x.row_mut(r).iter_mut().zip(pos.row(ti)) {
                    *v += p;
                }
            }
        }
    }
    let (cos, sin) = rope_tables(t, head_dim);

    let mut captures = Vec::new();
    for l in 0..n_layers {
        // ---- attention
        let mut x_ln = x.clone();
        if is_opt {
            layer_norm(
                &mut x_ln.data,
                d,
                &src.get_l(l, "ln1_g")?.data,
                &src.get_l(l, "ln1_b")?.data,
            );
        } else {
            rms_norm(&mut x_ln.data, d, &src.get_l(l, "ln1_g")?.data);
        }
        let (q, k, v) = if is_opt {
            (
                linear(&x_ln, &src.get_l(l, "wq")?, Some(&src.get_l(l, "bq")?)),
                linear(&x_ln, &src.get_l(l, "wk")?, Some(&src.get_l(l, "bk")?)),
                linear(&x_ln, &src.get_l(l, "wv")?, Some(&src.get_l(l, "bv")?)),
            )
        } else {
            (
                linear(&x_ln, &src.get_l(l, "wq")?, None),
                linear(&x_ln, &src.get_l(l, "wk")?, None),
                linear(&x_ln, &src.get_l(l, "wv")?, None),
            )
        };
        let ctx = attention(
            b,
            t,
            n_heads,
            head_dim,
            &head_splits[l],
            &q,
            &k,
            &v,
            &cos,
            &sin,
            !is_opt,
        );
        // both families carry an out-proj bias (llama's is the zero-init
        // FLAP-compensation slot, see configs.py)
        let attn_out = linear(&ctx, &src.get_l(l, "wo")?, Some(&src.get_l(l, "bo")?));
        for (xv, av) in x.data.iter_mut().zip(&attn_out.data) {
            *xv += av;
        }

        // ---- ffn
        let mut x_ln2 = x.clone();
        if is_opt {
            layer_norm(
                &mut x_ln2.data,
                d,
                &src.get_l(l, "ln2_g")?.data,
                &src.get_l(l, "ln2_b")?.data,
            );
        } else {
            rms_norm(&mut x_ln2.data, d, &src.get_l(l, "ln2_g")?.data);
        }
        let h = if is_opt {
            let mut h = linear(&x_ln2, &src.get_l(l, "fc1")?, Some(&src.get_l(l, "bfc1")?));
            for v in h.data.iter_mut() {
                *v = v.max(0.0); // relu
            }
            h
        } else {
            let g = linear(&x_ln2, &src.get_l(l, "w_gate")?, None);
            let u = linear(&x_ln2, &src.get_l(l, "w_up")?, None);
            let mut h = u;
            for (hv, gv) in h.data.iter_mut().zip(&g.data) {
                let silu = gv / (1.0 + (-gv).exp());
                *hv *= silu;
            }
            h
        };
        let ffn_out = if is_opt {
            linear(&h, &src.get_l(l, "fc2")?, Some(&src.get_l(l, "bfc2")?))
        } else {
            linear(&h, &src.get_l(l, "w_down")?, Some(&src.get_l(l, "b_down")?))
        };
        for (xv, fv) in x.data.iter_mut().zip(&ffn_out.data) {
            *xv += fv;
        }
        if collect {
            captures.push(HostCaptures { ln1: x_ln, ln2: x_ln2, attn_ctx: ctx, ffn_h: h });
        }
        src.layer_done(l)?;
    }

    if is_opt {
        layer_norm(&mut x.data, d, &src.get("lnf_g")?.data, &src.get("lnf_b")?.data);
    } else {
        rms_norm(&mut x.data, d, &src.get("lnf_g")?.data);
    }

    // logits = x · tok_embᵀ; per-token NLL without materializing softmax.
    // Rows are independent: fan out over row chunks of the NLL buffer.
    let logits = matmul_bt(&x, &tok_emb); // [rows, V]
    let mut nll = Tensor::zeros(&[b, t]);
    let nll_rows = |r0: usize, chunk: &mut [f32]| {
        for (i, nv) in chunk.iter_mut().enumerate() {
            let r = r0 + i;
            let row = logits.row(r);
            let z = logsumexp(row);
            let tgt = targets.data[r] as usize;
            *nv = z - row[tgt];
        }
    };
    let pool = crate::util::pool::current();
    if pool.workers() > 1 && rows * vocab >= crate::util::pool::PAR_THRESHOLD {
        pool.run_rows1(&mut nll.data, 1, nll_rows);
    } else {
        nll_rows(0, &mut nll.data);
    }
    Ok((nll, captures))
}

/// Causal multi-head attention with per-head V widths.
///
/// `q`/`k` are [b·t, n_heads·dh] (full Q/K head dim); `v` is
/// [b·t, Σ splits] with head `h`'s value dims occupying the contiguous
/// column block given by the prefix sums of `splits`. Returns the context
/// [b·t, Σ splits] in the same column layout (the input layout of the
/// sliced `wo`).
///
/// The (batch, head) blocks are independent; large inputs fan out on the
/// ambient worker pool, each block computing its own [t, dv] context
/// slice with the serial loop order — outputs are bit-identical across
/// pool widths.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention(
    b: usize,
    t: usize,
    n_heads: usize,
    dh: usize,
    splits: &[usize],
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cos: &[f32],
    sin: &[f32],
    rope: bool,
) -> Tensor {
    assert_eq!(splits.len(), n_heads);
    let dov: usize = splits.iter().sum();
    let mut offs = Vec::with_capacity(n_heads + 1);
    let mut acc = 0usize;
    offs.push(0);
    for &s in splits {
        acc += s;
        offs.push(acc);
    }
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = Tensor::zeros(&[b * t, dov]);

    // per (batch, head): gather [t, dh]/[t, dv] slices, optional rope,
    // causal attention into a local [t, dv] block. The serial path pays
    // a per-block scratch allocation + one [t, dv] copy vs the old
    // buffer-reusing loop — accepted so both backends execute this one
    // closure and the bitwise-identity contract holds by construction.
    let block = |bi: usize, hi: usize| -> Vec<f32> {
        let dv = splits[hi];
        if dv == 0 {
            return Vec::new(); // head fully sliced away: nothing reads its scores
        }
        let vo = offs[hi];
        let mut qh = vec![0.0f32; t * dh];
        let mut kh = vec![0.0f32; t * dh];
        let mut vh = vec![0.0f32; t * dv];
        for ti in 0..t {
            let r = bi * t + ti;
            let src = hi * dh..(hi + 1) * dh;
            qh[ti * dh..(ti + 1) * dh].copy_from_slice(&q.row(r)[src.clone()]);
            kh[ti * dh..(ti + 1) * dh].copy_from_slice(&k.row(r)[src]);
            vh[ti * dv..(ti + 1) * dv].copy_from_slice(&v.row(r)[vo..vo + dv]);
        }
        if rope {
            apply_rope(&mut qh, t, dh, cos, sin);
            apply_rope(&mut kh, t, dh, cos, sin);
        }
        let mut out = vec![0.0f32; t * dv];
        // causal attention rows
        for ti in 0..t {
            let qrow = &qh[ti * dh..(ti + 1) * dh];
            // scores over [0..=ti]
            let mut scores = Vec::with_capacity(ti + 1);
            for tj in 0..=ti {
                let krow = &kh[tj * dh..(tj + 1) * dh];
                scores.push(crate::tensor::matmul::dot(qrow, krow) * scale);
            }
            let m = scores.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let mut z = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - m).exp();
                z += *s;
            }
            let orow = &mut out[ti * dv..(ti + 1) * dv];
            for (tj, w) in scores.iter().enumerate() {
                let vrow = &vh[tj * dv..(tj + 1) * dv];
                let wz = w / z;
                for (o, vv) in orow.iter_mut().zip(vrow) {
                    *o += wz * vv;
                }
            }
        }
        out
    };

    let n_blocks = b * n_heads;
    let pool = crate::util::pool::current();
    let work = n_blocks * t * t * (dh + dov / n_heads.max(1));
    let mut place = |i: usize, blk: Vec<f32>| {
        let (bi, hi) = (i / n_heads, i % n_heads);
        let dv = splits[hi];
        if dv == 0 {
            return;
        }
        let vo = offs[hi];
        for ti in 0..t {
            ctx.row_mut(bi * t + ti)[vo..vo + dv]
                .copy_from_slice(&blk[ti * dv..(ti + 1) * dv]);
        }
    };
    if pool.workers() > 1 && n_blocks > 1 && work >= crate::util::pool::PAR_THRESHOLD {
        let blocks = pool.map(n_blocks, |i| block(i / n_heads, i % n_heads));
        for (i, blk) in blocks.into_iter().enumerate() {
            place(i, blk);
        }
    } else {
        // serial: compute and place one block at a time (no block list)
        for i in 0..n_blocks {
            place(i, block(i / n_heads, i % n_heads));
        }
    }
    ctx
}

/// Host Gram accumulation X^T X (cross-check against the capture artifact).
pub fn host_gram(x: &Tensor) -> Tensor {
    matmul(&x.t(), x)
}

/// Column sums of a [rows, c] activation matrix — the capture mean leaves.
/// Serial accumulation order (row-major), shared by the capture entry and
/// the streaming capture path so both produce bit-identical sums.
pub fn col_sums(x: &Tensor) -> Tensor {
    let (r, c) = x.dims2();
    let mut sums = vec![0.0f32; c];
    for i in 0..r {
        for (s, v) in sums.iter_mut().zip(x.row(i)) {
            *s += v;
        }
    }
    Tensor::new(vec![c], sums)
}

/// Mean NLL over a batch.
pub fn mean_nll(w: &Weights, tokens: &IntTensor, targets: &IntTensor) -> Result<f32> {
    let (nll, _) = forward_nll(w, tokens, targets, false)?;
    Ok(nll.data.iter().sum::<f32>() / nll.numel() as f32)
}

/// One physically sliced LLaMA-style decoder layer (the latency artifact
/// entry, mirroring `python/compile/latency.py::layer_fwd_sliced`).
/// Inputs, in order: x [b,t,d], ln1_g [d], wq [d,d], wk [d,d],
/// wv [dk_s,d], wo [d,dk_s], ln2_g [d], w_gate [f_s,d], w_up [f_s,d],
/// w_down [d,f_s]. Returns y [b,t,d].
pub fn sliced_layer_fwd(
    b: usize,
    t: usize,
    n_heads: usize,
    inputs: &[Tensor],
) -> Result<Tensor> {
    anyhow::ensure!(inputs.len() == 10, "sliced layer wants 10 inputs");
    let x3 = &inputs[0];
    let (bb, tt, d) = x3.dims3();
    anyhow::ensure!(bb == b && tt == t, "sliced layer batch/seq mismatch");
    let ln1_g = &inputs[1];
    let wq = &inputs[2];
    let wk = &inputs[3];
    let wv = &inputs[4];
    let wo = &inputs[5];
    let ln2_g = &inputs[6];
    let w_gate = &inputs[7];
    let w_up = &inputs[8];
    let w_down = &inputs[9];
    let dk_s = wv.shape[0];
    anyhow::ensure!(dk_s % n_heads == 0, "dk_s {} not divisible by heads", dk_s);
    let dh = d / n_heads;
    let rows = b * t;

    let mut x = Tensor::new(vec![rows, d], x3.data.clone());
    let mut x_ln = x.clone();
    rms_norm(&mut x_ln.data, d, &ln1_g.data);
    let q = linear(&x_ln, wq, None);
    let k = linear(&x_ln, wk, None);
    let v = linear(&x_ln, wv, None);
    let (cos, sin) = rope_tables(t, dh);
    let splits = vec![dk_s / n_heads; n_heads];
    let ctx = attention(b, t, n_heads, dh, &splits, &q, &k, &v, &cos, &sin, true);
    let attn_out = linear(&ctx, wo, None);
    for (xv, av) in x.data.iter_mut().zip(&attn_out.data) {
        *xv += av;
    }
    let mut x_ln2 = x.clone();
    rms_norm(&mut x_ln2.data, d, &ln2_g.data);
    let g = linear(&x_ln2, w_gate, None);
    let u = linear(&x_ln2, w_up, None);
    let mut h = u;
    for (hv, gv) in h.data.iter_mut().zip(&g.data) {
        let silu = gv / (1.0 + (-gv).exp());
        *hv *= silu;
    }
    let y = linear(&h, w_down, None);
    for (xv, yv) in x.data.iter_mut().zip(&y.data) {
        *xv += yv;
    }
    Ok(Tensor::new(vec![b, t, d], x.data))
}
