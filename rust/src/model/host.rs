//! Host-side reference forward pass for both families — the execution
//! engine of the host runtime backend (see `runtime::host_exec`) and the
//! independent numerics baseline every test pins down.
//! Mirrors `python/compile/model.py` exactly — any drift is a test
//! failure, not a silent divergence.
//!
//! Per-layer dims: a compact (physically sliced) model keeps a different
//! number of FFN hidden units and attention V/out dims in every layer
//! (`ModelSpec::layer_dims`), with the V/out dims split unevenly across
//! heads. The forward reads those dims per layer, so masked-dense and
//! compact models run through the same code path.
//!
//! Parallelism: attention (batch, head) blocks and per-token NLL rows fan
//! out on the ambient worker pool (`util::pool`), installed by the
//! session's backend. Every fan-out keeps the serial reduction order, so
//! outputs are bit-identical for any pool width.

use crate::runtime::manifest::ModelSpec;
use crate::tensor::matmul::{matmul_at, matmul_bt};
use crate::tensor::ops::logsumexp;
use crate::tensor::pack::matmul_packed;
use crate::tensor::{IntTensor, Tensor};
use super::weights::Weights;
use anyhow::Result;

pub(crate) const LN_EPS: f32 = 1e-5;

pub(crate) fn layer_norm(x: &mut [f32], d: usize, g: &[f32], b: &[f32]) {
    for row in x.chunks_exact_mut(d) {
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - mu) * inv * g[i] + b[i];
        }
    }
}

pub(crate) fn rms_norm(x: &mut [f32], d: usize, g: &[f32]) {
    for row in x.chunks_exact_mut(d) {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + LN_EPS).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = *v * inv * g[i];
        }
    }
}

/// Fill rows [t0, t1) of cos/sin tables laid out [t, dh/2]. Each row
/// depends only on its own position, never on the table length, so
/// tables extend append-only with the old prefix untouched — the
/// invariant the process-wide cache and incremental decode rely on.
fn fill_rope_rows(cos: &mut Vec<f32>, sin: &mut Vec<f32>, t0: usize, t1: usize, dh: usize) {
    let half = dh / 2;
    cos.resize(t1 * half, 0.0);
    sin.resize(t1 * half, 0.0);
    for ti in t0..t1 {
        for k in 0..half {
            let inv_freq = 1.0f64 / 10000f64.powf(k as f64 / half as f64);
            let ang = ti as f64 * inv_freq;
            cos[ti * half + k] = ang.cos() as f32;
            sin[ti * half + k] = ang.sin() as f32;
        }
    }
}

/// cos/sin tables [t, dh/2] matching python rope_tables — the uncached
/// reference builder ([`rope_cached`] is what the forward paths use;
/// tests pin the cache's prefix invariance against this).
pub fn rope_tables(t: usize, dh: usize) -> (Vec<f32>, Vec<f32>) {
    let mut cos = Vec::new();
    let mut sin = Vec::new();
    fill_rope_rows(&mut cos, &mut sin, 0, t, dh);
    (cos, sin)
}

/// Process-wide RoPE table cache: one monotonically growing table per
/// head dim, shared by every forward pass and every decode session.
/// Returns tables with **at least** `t` rows — row-indexed consumers
/// ([`apply_rope`], [`rope_row`]) never read past the rows they need,
/// so a longer table is always valid. Replaces the per-`forward_nll`
/// rebuild (the tables were recomputed on every call) and extends
/// incrementally (with doubling slack) as decode positions grow.
pub fn rope_cached(t: usize, dh: usize) -> std::sync::Arc<(Vec<f32>, Vec<f32>)> {
    use std::collections::BTreeMap;
    use std::sync::{Arc, Mutex};
    static CACHE: once_cell::sync::OnceCell<
        Mutex<BTreeMap<usize, Arc<(Vec<f32>, Vec<f32>)>>>,
    > = once_cell::sync::OnceCell::new();
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    let mut map = cache.lock().expect("rope cache poisoned");
    let entry = map
        .entry(dh)
        .or_insert_with(|| Arc::new((Vec::new(), Vec::new())));
    let half = (dh / 2).max(1);
    let have = entry.0.len() / half;
    if have < t {
        // grow with slack so per-token decode extensions amortize; the
        // values of existing rows are position-only, so the new table's
        // prefix is bit-identical to the old one
        let grow_to = t.next_power_of_two().max(64);
        let (mut cos, mut sin) = (entry.0.clone(), entry.1.clone());
        fill_rope_rows(&mut cos, &mut sin, have, grow_to, dh);
        *entry = Arc::new((cos, sin));
    }
    entry.clone()
}

/// Rotate-half RoPE on one [dh] row at table row `pos` — the shared
/// primitive of the batched [`apply_rope`] and the per-position cache
/// writes in the decode path (identical arithmetic by construction).
pub(crate) fn rope_row(row: &mut [f32], dh: usize, pos: usize, cos: &[f32], sin: &[f32]) {
    let half = dh / 2;
    for k in 0..half {
        let c = cos[pos * half + k];
        let s = sin[pos * half + k];
        let x1 = row[k];
        let x2 = row[half + k];
        row[k] = x1 * c - x2 * s;
        row[half + k] = x1 * s + x2 * c;
    }
}

/// Rotate-half RoPE applied in place to [t, dh] rows of one head.
pub(crate) fn apply_rope(x: &mut [f32], t: usize, dh: usize, cos: &[f32], sin: &[f32]) {
    for ti in 0..t {
        rope_row(&mut x[ti * dh..(ti + 1) * dh], dh, ti, cos, sin);
    }
}

/// Row-broadcast bias add (shared by every linear form).
pub(crate) fn add_bias(y: &mut Tensor, b: &Tensor) {
    let (rows, out) = y.dims2();
    debug_assert_eq!(b.numel(), out);
    for r in 0..rows {
        let row = &mut y.data[r * out..(r + 1) * out];
        for (v, bv) in row.iter_mut().zip(&b.data) {
            *v += bv;
        }
    }
}

/// Linear y = x·Wᵀ (+ b) over raw tensors. x is [rows, in], w is
/// [out, in]. The unpacked form — sources with a pack cache go through
/// [`linear_l`] instead (same bits, no per-call transpose).
pub(crate) fn linear(x: &Tensor, w: &Tensor, b: Option<&Tensor>) -> Tensor {
    let mut y = matmul_bt(x, w);
    if let Some(b) = b {
        add_bias(&mut y, b);
    }
    y
}

/// One weight-stationary linear `y = x·Wᵀ (+ b)` over a [`ParamSource`]:
/// consumes the source's pre-packed weight when it holds one (zero
/// per-call transpose/pack/copy work — the tentpole of the packed
/// operator plan) and falls back to the unpacked copy + [`matmul_bt`]
/// otherwise. Both paths run the canonical lane-kernel reduction order,
/// so the output bits are identical either way.
pub(crate) fn linear_l<S: super::weights::ParamSource>(
    src: &mut S,
    l: usize,
    wname: &str,
    bname: Option<&str>,
    x: &Tensor,
) -> Result<Tensor> {
    let mut y = match src.get_l_packed(l, wname)? {
        Some(p) => matmul_packed(x, &p),
        None => matmul_bt(x, &src.get_l(l, wname)?),
    };
    if let Some(bn) = bname {
        let b = src.get_l(l, bn)?;
        add_bias(&mut y, &b);
    }
    Ok(y)
}

// --- shared per-layer building blocks ---------------------------------
// One implementation of the family-conditional layer math, called by the
// teacher-forced forward (`forward_nll_src`) AND both decode forms
// (`model::decode::{prefill,decode_step}`): the decode≡re-forward
// bitwise contract holds because there is nothing to mirror — all three
// paths execute these same functions.

/// Clone-and-normalize a sublayer input: LayerNorm (gain + bias) for
/// OPT, RMSNorm for llama. `ln` is the parameter stem ("ln1" / "ln2").
pub(crate) fn norm_input<S: super::weights::ParamSource>(
    src: &mut S,
    l: usize,
    ln: &str,
    x: &Tensor,
    d: usize,
    is_opt: bool,
) -> Result<Tensor> {
    let mut x_ln = x.clone();
    if is_opt {
        layer_norm(
            &mut x_ln.data,
            d,
            &src.get_l(l, &format!("{ln}_g"))?.data,
            &src.get_l(l, &format!("{ln}_b"))?.data,
        );
    } else {
        rms_norm(&mut x_ln.data, d, &src.get_l(l, &format!("{ln}_g"))?.data);
    }
    Ok(x_ln)
}

/// Q/K/V projections of one layer (biased for OPT). Weight-stationary:
/// packed panels when the source holds them, unpacked fallback else.
pub(crate) fn qkv_proj<S: super::weights::ParamSource>(
    src: &mut S,
    l: usize,
    x_ln: &Tensor,
    is_opt: bool,
) -> Result<(Tensor, Tensor, Tensor)> {
    Ok(if is_opt {
        (
            linear_l(src, l, "wq", Some("bq"), x_ln)?,
            linear_l(src, l, "wk", Some("bk"), x_ln)?,
            linear_l(src, l, "wv", Some("bv"), x_ln)?,
        )
    } else {
        (
            linear_l(src, l, "wq", None, x_ln)?,
            linear_l(src, l, "wk", None, x_ln)?,
            linear_l(src, l, "wv", None, x_ln)?,
        )
    })
}

/// Attention output projection + residual add into `x`. Both families
/// carry an out-proj bias (llama's is the zero-init FLAP-compensation
/// slot, see configs.py).
pub(crate) fn attn_out_residual<S: super::weights::ParamSource>(
    src: &mut S,
    l: usize,
    ctx: &Tensor,
    x: &mut Tensor,
) -> Result<()> {
    let attn_out = linear_l(src, l, "wo", Some("bo"), ctx)?;
    for (xv, av) in x.data.iter_mut().zip(&attn_out.data) {
        *xv += av;
    }
    Ok(())
}

/// The whole FFN sublayer: ln2-normalized input, ReLU fc1→fc2 (OPT) or
/// SiLU gate·up→down (llama), residual add into `x`. Returns the normed
/// input and hidden activations (the capture leaves).
pub(crate) fn ffn_sublayer<S: super::weights::ParamSource>(
    src: &mut S,
    l: usize,
    x: &mut Tensor,
    d: usize,
    is_opt: bool,
) -> Result<(Tensor, Tensor)> {
    let x_ln2 = norm_input(src, l, "ln2", x, d, is_opt)?;
    let h = if is_opt {
        let mut h = linear_l(src, l, "fc1", Some("bfc1"), &x_ln2)?;
        for v in h.data.iter_mut() {
            *v = v.max(0.0); // relu
        }
        h
    } else {
        let g = linear_l(src, l, "w_gate", None, &x_ln2)?;
        let u = linear_l(src, l, "w_up", None, &x_ln2)?;
        let mut h = u;
        for (hv, gv) in h.data.iter_mut().zip(&g.data) {
            let silu = gv / (1.0 + (-gv).exp());
            *hv *= silu;
        }
        h
    };
    let ffn_out = if is_opt {
        linear_l(src, l, "fc2", Some("bfc2"), &h)?
    } else {
        linear_l(src, l, "w_down", Some("b_down"), &h)?
    };
    for (xv, fv) in x.data.iter_mut().zip(&ffn_out.data) {
        *xv += fv;
    }
    Ok((x_ln2, h))
}

/// Token embedding (+ learned positions for OPT, starting at absolute
/// position `pos0` — 0 for a full forward, the cache length for a
/// decode step). Returns x [b·t, d]. Rows gather straight from the
/// source's backing store ([`super::weights::ParamSource::embed_rows`])
/// — no per-call copy of the whole table, which on the decode path used
/// to cost an O(vocab·d) allocation *per token*.
pub(crate) fn embed_tokens<S: super::weights::ParamSource>(
    src: &mut S,
    tokens: &IntTensor,
    d: usize,
    is_opt: bool,
    pos0: usize,
) -> Result<Tensor> {
    let (b, t) = (tokens.shape[0], tokens.shape[1]);
    let mut x = src.embed_rows(&tokens.data)?;
    anyhow::ensure!(
        x.shape == vec![b * t, d],
        "embedding width {:?} != model d_model {d}",
        x.shape
    );
    if is_opt {
        src.with_rows("pos_emb", pos0, t, &mut |pos| {
            for bi in 0..b {
                for ti in 0..t {
                    let r = bi * t + ti;
                    for (v, p) in
                        x.row_mut(r).iter_mut().zip(&pos[ti * d..(ti + 1) * d])
                    {
                        *v += p;
                    }
                }
            }
        })?;
    }
    Ok(x)
}

/// Final norm + tied-head logits (consumes `x`). The logits product
/// `x · tok_embᵀ` — the single largest per-forward transpose in the
/// model — runs over the source's packed head panel when it holds one.
pub(crate) fn head_logits<S: super::weights::ParamSource>(
    src: &mut S,
    mut x: Tensor,
    d: usize,
    is_opt: bool,
) -> Result<Tensor> {
    if is_opt {
        layer_norm(&mut x.data, d, &src.get("lnf_g")?.data, &src.get("lnf_b")?.data);
    } else {
        rms_norm(&mut x.data, d, &src.get("lnf_g")?.data);
    }
    Ok(match src.get_packed("tok_emb")? {
        Some(p) => matmul_packed(&x, &p),
        None => matmul_bt(&x, &src.get("tok_emb")?),
    })
}

/// Per-layer calibration activations (host mirror of capture.py), used by
/// the capture entry and by tests to validate the Gram matrices.
pub struct HostCaptures {
    pub ln1: Tensor,
    pub ln2: Tensor,
    pub attn_ctx: Tensor,
    pub ffn_h: Tensor,
}

/// Full host forward: per-token NLL [b, t] of `targets` under the model
/// given `tokens` (teacher forcing, same contract as the fwd_loss
/// artifact), plus optionally the per-layer capture activations.
pub fn forward_nll(
    w: &Weights,
    tokens: &IntTensor,
    targets: &IntTensor,
    collect: bool,
) -> Result<(Tensor, Vec<HostCaptures>)> {
    forward_nll_src(&mut super::weights::DenseParams(w), tokens, targets, collect)
}

/// [`forward_nll`] over an arbitrary [`ParamSource`]. Layers are visited
/// strictly in order and each is released (`layer_done`) before the next
/// is requested, so a streaming source holds at most one layer's shard
/// (plus its prefetch buffer) at a time. The embedding/head parameters
/// (`tok_emb`, the final norm) stay resident for the whole pass — the
/// tied head reuses `tok_emb` for the logits.
pub fn forward_nll_src<S: super::weights::ParamSource>(
    src: &mut S,
    tokens: &IntTensor,
    targets: &IntTensor,
    collect: bool,
) -> Result<(Tensor, Vec<HostCaptures>)> {
    // Pull the scalar geometry out up front: `src` hands out tensors
    // through &mut below, and cloning the whole spec (params table
    // included) per forward would tax the hot path.
    let spec = src.spec();
    let d = spec.d_model;
    let n_layers = spec.n_layers;
    let n_heads = spec.n_heads;
    let head_dim = spec.head_dim();
    let vocab = spec.vocab;
    let is_opt = spec.family == "opt";
    let head_splits: Vec<Vec<usize>> =
        (0..n_layers).map(|l| spec.head_splits_l(l)).collect();
    let (b, t) = (tokens.shape[0], tokens.shape[1]);
    let rows = b * t;

    let mut x = embed_tokens(src, tokens, d, is_opt, 0)?;
    // cached once per process per head dim (rows beyond `t` are ignored
    // by the row-indexed consumers, so a longer cached table is fine)
    let rope = rope_cached(t, head_dim);
    let (cos, sin): (&[f32], &[f32]) = (&rope.0, &rope.1);

    let mut captures = Vec::new();
    for l in 0..n_layers {
        // ---- attention
        let x_ln = norm_input(src, l, "ln1", &x, d, is_opt)?;
        let (q, k, v) = qkv_proj(src, l, &x_ln, is_opt)?;
        let ctx = attention(
            b,
            t,
            n_heads,
            head_dim,
            &head_splits[l],
            &q,
            &k,
            &v,
            cos,
            sin,
            !is_opt,
        );
        attn_out_residual(src, l, &ctx, &mut x)?;

        // ---- ffn
        let (x_ln2, h) = ffn_sublayer(src, l, &mut x, d, is_opt)?;
        if collect {
            captures.push(HostCaptures { ln1: x_ln, ln2: x_ln2, attn_ctx: ctx, ffn_h: h });
        }
        src.layer_done(l)?;
    }

    // logits = x · tok_embᵀ; per-token NLL without materializing softmax.
    // Rows are independent: fan out over row chunks of the NLL buffer.
    let logits = head_logits(src, x, d, is_opt)?; // [rows, V]
    let mut nll = Tensor::zeros(&[b, t]);
    let nll_rows = |r0: usize, chunk: &mut [f32]| {
        for (i, nv) in chunk.iter_mut().enumerate() {
            let r = r0 + i;
            let row = logits.row(r);
            let z = logsumexp(row);
            let tgt = targets.data[r] as usize;
            *nv = z - row[tgt];
        }
    };
    let pool = crate::util::pool::current();
    if pool.workers() > 1 && rows * vocab >= crate::util::pool::PAR_THRESHOLD {
        pool.run_rows1(&mut nll.data, 1, nll_rows);
    } else {
        nll_rows(0, &mut nll.data);
    }
    Ok((nll, captures))
}

/// One causal attention row: query `qrow` [dh] at absolute position
/// `ti`, attending over key/value rows `0..=ti` read from strided
/// buffers (`k[tj·k_stride + k_off ..][..dh]`, `v[tj·v_stride + v_off
/// ..][..dv]`). Accumulates into `out` [dv] (caller-zeroed) with the
/// exact serial order the original `attention()` loop used — scores in
/// ascending tj, running max, exp/sum, then the weighted-V axpy in
/// ascending tj — so the prefill path (contiguous gathered buffers,
/// stride `dh`/`dv`, offset 0) and the decode path (KV-cache rows,
/// stride `n_heads·dh` / layer `d_ov`, per-head offsets) produce
/// bit-identical contexts.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attn_row(
    qrow: &[f32],
    k: &[f32],
    k_stride: usize,
    k_off: usize,
    v: &[f32],
    v_stride: usize,
    v_off: usize,
    ti: usize,
    dh: usize,
    dv: usize,
    scale: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(qrow.len(), dh, "attn_row: qrow width != dh");
    debug_assert_eq!(out.len(), dv, "attn_row: out width != dv");
    attn_row_by(
        qrow,
        |tj| &k[tj * k_stride + k_off..tj * k_stride + k_off + dh],
        |tj| &v[tj * v_stride + v_off..tj * v_stride + v_off + dv],
        ti,
        scale,
        out,
    )
}

/// The attention-row kernel behind [`attn_row`], generalized over row
/// *addressing*: `k_at(tj)`/`v_at(tj)` hand back key/value rows [dh] /
/// [dv] for positions `0..=ti` from wherever they live — a contiguous
/// gathered buffer, a strided KV-cache slab, or a paged arena's block
/// table (`model::kv_arena`). The arithmetic is the one serial order
/// every caller shares (scores in ascending tj, running max, exp/sum,
/// weighted-V axpy in ascending tj), so all addressing schemes produce
/// bit-identical contexts by construction.
pub(crate) fn attn_row_by<'a>(
    qrow: &[f32],
    k_at: impl Fn(usize) -> &'a [f32],
    v_at: impl Fn(usize) -> &'a [f32],
    ti: usize,
    scale: f32,
    out: &mut [f32],
) {
    let mut scores = Vec::with_capacity(ti + 1);
    for tj in 0..=ti {
        let krow = k_at(tj);
        debug_assert_eq!(krow.len(), qrow.len(), "attn_row_by: krow width != dh");
        scores.push(crate::tensor::matmul::dot(qrow, krow) * scale);
    }
    let m = scores.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
    let mut z = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - m).exp();
        z += *s;
    }
    for (tj, w) in scores.iter().enumerate() {
        let vrow = v_at(tj);
        debug_assert_eq!(vrow.len(), out.len(), "attn_row_by: vrow width != dv");
        let wz = w / z;
        for (o, vv) in out.iter_mut().zip(vrow) {
            *o += wz * vv;
        }
    }
}

/// Causal multi-head attention with per-head V widths.
///
/// `q`/`k` are [b·t, n_heads·dh] (full Q/K head dim); `v` is
/// [b·t, Σ splits] with head `h`'s value dims occupying the contiguous
/// column block given by the prefix sums of `splits`. Returns the context
/// [b·t, Σ splits] in the same column layout (the input layout of the
/// sliced `wo`).
///
/// The (batch, head) blocks are independent; large inputs fan out on the
/// ambient worker pool, each block computing its own [t, dv] context
/// slice with the serial loop order — outputs are bit-identical across
/// pool widths.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention(
    b: usize,
    t: usize,
    n_heads: usize,
    dh: usize,
    splits: &[usize],
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cos: &[f32],
    sin: &[f32],
    rope: bool,
) -> Tensor {
    assert_eq!(splits.len(), n_heads);
    let dov: usize = splits.iter().sum();
    let mut offs = Vec::with_capacity(n_heads + 1);
    let mut acc = 0usize;
    offs.push(0);
    for &s in splits {
        acc += s;
        offs.push(acc);
    }
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = Tensor::zeros(&[b * t, dov]);

    // per (batch, head): gather [t, dh]/[t, dv] slices, optional rope,
    // causal attention into a local [t, dv] block. The serial path pays
    // a per-block scratch allocation + one [t, dv] copy vs the old
    // buffer-reusing loop — accepted so both backends execute this one
    // closure and the bitwise-identity contract holds by construction.
    let block = |bi: usize, hi: usize| -> Vec<f32> {
        let dv = splits[hi];
        if dv == 0 {
            return Vec::new(); // head fully sliced away: nothing reads its scores
        }
        let vo = offs[hi];
        let mut qh = vec![0.0f32; t * dh];
        let mut kh = vec![0.0f32; t * dh];
        let mut vh = vec![0.0f32; t * dv];
        for ti in 0..t {
            let r = bi * t + ti;
            let src = hi * dh..(hi + 1) * dh;
            qh[ti * dh..(ti + 1) * dh].copy_from_slice(&q.row(r)[src.clone()]);
            kh[ti * dh..(ti + 1) * dh].copy_from_slice(&k.row(r)[src]);
            vh[ti * dv..(ti + 1) * dv].copy_from_slice(&v.row(r)[vo..vo + dv]);
        }
        if rope {
            apply_rope(&mut qh, t, dh, cos, sin);
            apply_rope(&mut kh, t, dh, cos, sin);
        }
        let mut out = vec![0.0f32; t * dv];
        // causal attention rows (shared with the KV-cached decode step)
        for ti in 0..t {
            let qrow = &qh[ti * dh..(ti + 1) * dh];
            attn_row(
                qrow,
                &kh,
                dh,
                0,
                &vh,
                dv,
                0,
                ti,
                dh,
                dv,
                scale,
                &mut out[ti * dv..(ti + 1) * dv],
            );
        }
        out
    };

    let n_blocks = b * n_heads;
    let pool = crate::util::pool::current();
    let work = n_blocks * t * t * (dh + dov / n_heads.max(1));
    let mut place = |i: usize, blk: Vec<f32>| {
        let (bi, hi) = (i / n_heads, i % n_heads);
        let dv = splits[hi];
        if dv == 0 {
            return;
        }
        let vo = offs[hi];
        for ti in 0..t {
            ctx.row_mut(bi * t + ti)[vo..vo + dv]
                .copy_from_slice(&blk[ti * dv..(ti + 1) * dv]);
        }
    };
    if pool.workers() > 1 && n_blocks > 1 && work >= crate::util::pool::PAR_THRESHOLD {
        let blocks = pool.map(n_blocks, |i| block(i / n_heads, i % n_heads));
        for (i, blk) in blocks.into_iter().enumerate() {
            place(i, blk);
        }
    } else {
        // serial: compute and place one block at a time (no block list)
        for i in 0..n_blocks {
            place(i, block(i / n_heads, i % n_heads));
        }
    }
    ctx
}

/// Host Gram accumulation XᵀX (cross-check against the capture
/// artifact) — the transpose-free [`matmul_at`] kernel, bit-identical
/// to the old `matmul(&x.t(), x)` without the [rows·c] transpose copy
/// per capture leaf.
pub fn host_gram(x: &Tensor) -> Tensor {
    matmul_at(x, x)
}

/// Column sums of a [rows, c] activation matrix — the capture mean leaves.
/// Serial accumulation order (row-major), shared by the capture entry and
/// the streaming capture path so both produce bit-identical sums.
pub fn col_sums(x: &Tensor) -> Tensor {
    let (r, c) = x.dims2();
    let mut sums = vec![0.0f32; c];
    for i in 0..r {
        for (s, v) in sums.iter_mut().zip(x.row(i)) {
            *s += v;
        }
    }
    Tensor::new(vec![c], sums)
}

/// Mean NLL over a batch.
pub fn mean_nll(w: &Weights, tokens: &IntTensor, targets: &IntTensor) -> Result<f32> {
    let (nll, _) = forward_nll(w, tokens, targets, false)?;
    Ok(nll.data.iter().sum::<f32>() / nll.numel() as f32)
}

/// One physically sliced LLaMA-style decoder layer (the latency artifact
/// entry, mirroring `python/compile/latency.py::layer_fwd_sliced`).
/// Inputs, in order: x [b,t,d], ln1_g [d], wq [d,d], wk [d,d],
/// wv [dk_s,d], wo [d,dk_s], ln2_g [d], w_gate [f_s,d], w_up [f_s,d],
/// w_down [d,f_s]. Returns y [b,t,d].
pub fn sliced_layer_fwd(
    b: usize,
    t: usize,
    n_heads: usize,
    inputs: &[Tensor],
) -> Result<Tensor> {
    anyhow::ensure!(inputs.len() == 10, "sliced layer wants 10 inputs");
    let x3 = &inputs[0];
    let (bb, tt, d) = x3.dims3();
    anyhow::ensure!(bb == b && tt == t, "sliced layer batch/seq mismatch");
    let ln1_g = &inputs[1];
    let wq = &inputs[2];
    let wk = &inputs[3];
    let wv = &inputs[4];
    let wo = &inputs[5];
    let ln2_g = &inputs[6];
    let w_gate = &inputs[7];
    let w_up = &inputs[8];
    let w_down = &inputs[9];
    let dk_s = wv.shape[0];
    anyhow::ensure!(dk_s % n_heads == 0, "dk_s {} not divisible by heads", dk_s);
    let dh = d / n_heads;
    let rows = b * t;

    let mut x = Tensor::new(vec![rows, d], x3.data.clone());
    let mut x_ln = x.clone();
    rms_norm(&mut x_ln.data, d, &ln1_g.data);
    let q = linear(&x_ln, wq, None);
    let k = linear(&x_ln, wk, None);
    let v = linear(&x_ln, wv, None);
    let rope = rope_cached(t, dh);
    let splits = vec![dk_s / n_heads; n_heads];
    let ctx = attention(b, t, n_heads, dh, &splits, &q, &k, &v, &rope.0, &rope.1, true);
    let attn_out = linear(&ctx, wo, None);
    for (xv, av) in x.data.iter_mut().zip(&attn_out.data) {
        *xv += av;
    }
    let mut x_ln2 = x.clone();
    rms_norm(&mut x_ln2.data, d, &ln2_g.data);
    let g = linear(&x_ln2, w_gate, None);
    let u = linear(&x_ln2, w_up, None);
    let mut h = u;
    for (hv, gv) in h.data.iter_mut().zip(&g.data) {
        let silu = gv / (1.0 + (-gv).exp());
        *hv *= silu;
    }
    let y = linear(&h, w_down, None);
    for (xv, yv) in x.data.iter_mut().zip(&y.data) {
        *xv += yv;
    }
    Ok(Tensor::new(vec![b, t, d], x.data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rope_cache_extends_with_bit_identical_prefix() {
        let dh = 8;
        let small = rope_cached(4, dh);
        assert!(small.0.len() >= 4 * dh / 2);
        let big = rope_cached(200, dh);
        assert!(big.0.len() >= 200 * dh / 2);
        let (cos_ref, sin_ref) = rope_tables(200, dh);
        for (i, (c, r)) in big.0.iter().zip(&cos_ref).enumerate() {
            assert_eq!(c.to_bits(), r.to_bits(), "cos[{i}] drifted on extension");
        }
        for (i, (s, r)) in big.1.iter().zip(&sin_ref).enumerate() {
            assert_eq!(s.to_bits(), r.to_bits(), "sin[{i}] drifted on extension");
        }
        // the earlier (smaller) fetch shares the same values
        for (i, (c, r)) in small.0.iter().take(4 * dh / 2).zip(&cos_ref).enumerate() {
            assert_eq!(c.to_bits(), r.to_bits(), "cached prefix cos[{i}]");
        }
    }

    #[test]
    fn attn_row_matches_strided_reads() {
        // the same K/V served contiguously and strided must attend
        // identically (the cache layout contract)
        let t = 5;
        let (dh, dv) = (4, 3);
        let mut rng = crate::util::rng::Rng::new(3);
        let q: Vec<f32> = rng.normal_vec(dh, 1.0);
        let k: Vec<f32> = rng.normal_vec(t * dh, 1.0);
        let v: Vec<f32> = rng.normal_vec(t * dv, 1.0);
        // strided copies: rows padded into wider buffers at an offset
        let (ks, ko, vs, vo) = (dh + 3, 2, dv + 5, 4);
        let mut k_wide = vec![0.0f32; t * ks];
        let mut v_wide = vec![0.0f32; t * vs];
        for ti in 0..t {
            k_wide[ti * ks + ko..ti * ks + ko + dh]
                .copy_from_slice(&k[ti * dh..(ti + 1) * dh]);
            v_wide[ti * vs + vo..ti * vs + vo + dv]
                .copy_from_slice(&v[ti * dv..(ti + 1) * dv]);
        }
        for ti in 0..t {
            let mut a = vec![0.0f32; dv];
            let mut b = vec![0.0f32; dv];
            let scale = 0.5;
            attn_row(&q, &k, dh, 0, &v, dv, 0, ti, dh, dv, scale, &mut a);
            attn_row(&q, &k_wide, ks, ko, &v_wide, vs, vo, ti, dh, dv, scale, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "ti={ti}");
            }
        }
    }
}
