//! Paged KV arena: the serve engine's shared decode cache.
//!
//! A per-session [`super::decode::KvCache`] preallocates `capacity`
//! contiguous positions per sequence. That is the right shape for one
//! generation at a time, but a serve engine running many short sessions
//! over one model would fragment memory badly: each arrival allocates
//! (and each retirement frees) multi-megabyte slabs sized to its own
//! worst case. The arena replaces per-session ring buffers with one
//! fixed pool of **pages** — blocks of `page` consecutive positions,
//! with K/V storage for *every* layer — and gives each served session a
//! small **page table** ([`PagedKv`]) mapping its logical positions to
//! arena pages. Allocation/free is O(1) off a LIFO free list, sessions
//! of any length pack into the same pool, and pages are refcounted so
//! the prefix cache (`crate::serve::prefix`) can pin a finished
//! prompt's full pages and later share them with new sessions that
//! start with the same tokens — zero-copy prefill reuse.
//!
//! Layout: page `p`, layer `l`, slot `s` (position `pos` lives at page
//! `table[pos / page]`, slot `pos % page`):
//!   * keys   `layers[l].k[(p·page + s)·kdim ..][..kdim]` (post-RoPE,
//!     full `n_heads·head_dim` width — FASP leaves Q/K dense),
//!   * values `layers[l].v[(p·page + s)·dv_l ..][..dv_l]` (the layer's
//!     sliced `d_ov_l` width, where OV pruning shrinks residency).
//!
//! Determinism: the arena stores exactly the rows [`super::decode`]'s
//! contiguous cache stores (same kernels write them), and readers go
//! through `host::attn_row_by` with page-table addressing — so paged
//! decode is bit-identical to ring-buffer decode by construction
//! (locked by `rust/tests/test_serve.rs`).

use crate::runtime::manifest::ModelSpec;
use anyhow::Result;

/// One layer's pooled K/V storage.
struct ArenaLayer {
    /// [n_pages · page, kdim] post-RoPE keys.
    k: Vec<f32>,
    /// [n_pages · page, dv] values (sliced width).
    v: Vec<f32>,
    /// Kept V dims per head (prefix sums give each head's column block).
    splits: Vec<usize>,
    /// Σ splits — the layer's value width.
    dv: usize,
}

/// A session's page table: logical position `pos` lives in arena page
/// `pages[pos / page_size]`. `len` counts written positions, exactly
/// like `KvCache::len`.
#[derive(Clone, Debug, Default)]
pub struct PagedKv {
    pages: Vec<usize>,
    len: usize,
}

impl PagedKv {
    pub fn new() -> PagedKv {
        PagedKv { pages: Vec::new(), len: 0 }
    }

    /// Cached positions so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The page table (arena page ids, one per block of positions).
    pub fn pages(&self) -> &[usize] {
        &self.pages
    }

    /// One position has been written for this sequence.
    pub(crate) fn advance(&mut self) {
        self.len += 1;
    }

    /// Roll the write cursor back to `len0` (a snapshot taken before a
    /// batched step). Pages stay owned — a retried step rewrites the
    /// same slots with the same deterministic kernels, so rollback is
    /// all the undo a mid-step fault needs.
    pub(crate) fn rollback(&mut self, len0: usize) {
        self.len = self.len.min(len0);
    }
}

/// Fixed pool of KV pages shared by every served session of one model.
/// Geometry is pinned to a spec at construction and re-checked by every
/// batched step, exactly like `KvCache`.
pub struct KvArena {
    model: String,
    family: String,
    d_model: usize,
    n_heads: usize,
    head_dim: usize,
    kdim: usize,
    /// Positions per page.
    page: usize,
    /// Total pages in the pool.
    n_pages: usize,
    layers: Vec<ArenaLayer>,
    /// Per-page refcount: 0 = free, 1 = one owner, >1 = shared (prefix
    /// cache pin and/or sessions reusing a common prompt head).
    refs: Vec<u32>,
    /// LIFO free list — retiring a short session hands its hot pages
    /// straight to the next arrival.
    free: Vec<usize>,
    peak_pages: usize,
}

impl KvArena {
    /// Allocate a pool of `n_pages` pages of `page` positions each
    /// under `spec`'s (per-layer, possibly sliced) dims.
    pub fn for_spec(spec: &ModelSpec, n_pages: usize, page: usize) -> Result<KvArena> {
        anyhow::ensure!(page >= 1, "kv arena wants page size >= 1");
        anyhow::ensure!(n_pages >= 1, "kv arena wants n_pages >= 1");
        let head_dim = spec.head_dim();
        let kdim = spec.n_heads * head_dim;
        let slots = n_pages * page;
        let layers = (0..spec.n_layers)
            .map(|l| {
                let splits = spec.head_splits_l(l);
                let dv: usize = splits.iter().sum();
                ArenaLayer {
                    k: vec![0.0; slots * kdim],
                    v: vec![0.0; slots * dv],
                    splits,
                    dv,
                }
            })
            .collect();
        Ok(KvArena {
            model: spec.name.clone(),
            family: spec.family.clone(),
            d_model: spec.d_model,
            n_heads: spec.n_heads,
            head_dim,
            kdim,
            page,
            n_pages,
            layers,
            refs: vec![0; n_pages],
            free: (0..n_pages).rev().collect(),
            peak_pages: 0,
        })
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Positions per page.
    pub fn page_size(&self) -> usize {
        self.page
    }

    pub fn n_pages(&self) -> usize {
        self.n_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.n_pages - self.free.len()
    }

    /// High-water mark of simultaneously resident pages — the serve
    /// residency receipt.
    pub fn peak_pages(&self) -> usize {
        self.peak_pages
    }

    /// Pages needed to hold `positions` cached positions.
    pub fn pages_for(&self, positions: usize) -> usize {
        (positions + self.page - 1) / self.page
    }

    /// Allocated bytes of the whole pool (all pages, used or free).
    pub fn kv_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| (l.k.len() + l.v.len()) * std::mem::size_of::<f32>())
            .sum()
    }

    /// Bytes of one page across every layer.
    pub fn page_bytes(&self) -> usize {
        self.kv_bytes() / self.n_pages
    }

    /// The arena only ever serves the exact spec it was built for.
    pub fn check_spec(&self, spec: &ModelSpec) -> Result<()> {
        anyhow::ensure!(
            self.model == spec.name,
            "kv arena was built for model '{}' but the forward is running \
             '{}' — arena/model mismatch",
            self.model,
            spec.name
        );
        anyhow::ensure!(
            self.family == spec.family
                && self.d_model == spec.d_model
                && self.n_heads == spec.n_heads
                && self.layers.len() == spec.n_layers,
            "kv arena geometry (d={}, heads={}, layers={}) does not match \
             model '{}' — mismatched layer dims",
            self.d_model,
            self.n_heads,
            self.layers.len(),
            spec.name
        );
        for (l, lay) in self.layers.iter().enumerate() {
            let want = spec.head_splits_l(l);
            anyhow::ensure!(
                lay.splits == want,
                "kv arena layer {l}: head splits {:?} != model '{}' splits \
                 {:?} — mismatched layer dims",
                lay.splits,
                spec.name,
                want
            );
        }
        Ok(())
    }

    /// Extend `kv`'s page table until it covers `upto` positions. New
    /// pages come off the free list with refcount 1. Errs when the pool
    /// is exhausted — the serve engine's admission reservation exists
    /// precisely so this can never fire mid-generation.
    pub fn grow(&mut self, kv: &mut PagedKv, upto: usize) -> Result<()> {
        if kv.pages.len() * self.page < upto {
            // one fault event per *allocating* grow; an armed exhaustion
            // errs here, before any page moves
            crate::fault::arena_grow()?;
        }
        while kv.pages.len() * self.page < upto {
            let p = match self.free.pop() {
                Some(p) => p,
                None => {
                    anyhow::bail!(
                        "kv arena exhausted: {} pages of {} positions all \
                         resident while growing a sequence to {upto}",
                        self.n_pages,
                        self.page
                    )
                }
            };
            debug_assert_eq!(self.refs[p], 0, "free page with live refs");
            self.refs[p] = 1;
            kv.pages.push(p);
        }
        self.peak_pages = self.peak_pages.max(self.used_pages());
        Ok(())
    }

    /// Drop `kv`'s hold on all its pages (pages with no other owner
    /// return to the free list) and reset it to an empty sequence.
    pub fn release(&mut self, kv: &mut PagedKv) {
        for p in std::mem::take(&mut kv.pages) {
            self.dec_ref(p);
        }
        kv.len = 0;
    }

    /// Take an extra hold on `pages` (the prefix cache pinning a
    /// finished prompt's full pages).
    pub(crate) fn retain_pages(&mut self, pages: &[usize]) {
        for &p in pages {
            debug_assert!(self.refs[p] > 0, "retain of a free page");
            self.refs[p] += 1;
        }
    }

    /// Drop one hold on `pages` (prefix-cache eviction).
    pub(crate) fn release_pages(&mut self, pages: &[usize]) {
        for &p in pages {
            self.dec_ref(p);
        }
    }

    /// A new sequence whose first `positions` positions are served by
    /// the shared `pages` (refcounts bumped): the prefix-cache hit
    /// path. The shared prefix must consist of *full* pages only.
    pub(crate) fn share(&mut self, pages: &[usize], positions: usize) -> PagedKv {
        debug_assert_eq!(
            positions,
            pages.len() * self.page,
            "shared prefix must cover exactly its full pages"
        );
        self.retain_pages(pages);
        PagedKv { pages: pages.to_vec(), len: positions }
    }

    fn dec_ref(&mut self, p: usize) {
        debug_assert!(self.refs[p] > 0, "double free of arena page {p}");
        self.refs[p] -= 1;
        if self.refs[p] == 0 {
            self.free.push(p);
        }
    }

    /// Store one position's K/V rows for layer `l`. Keys must already
    /// be RoPE-rotated at `pos`. Only exclusively-owned pages may be
    /// written: shared (prefix) pages are immutable by construction —
    /// a session's fresh positions always land past its shared full
    /// pages.
    pub(crate) fn write_pos(&mut self, kv: &PagedKv, l: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        let p = kv.pages[pos / self.page];
        debug_assert_eq!(self.refs[p], 1, "write into shared arena page {p}");
        let slot = p * self.page + pos % self.page;
        let kdim = self.kdim;
        let lay = &mut self.layers[l];
        debug_assert_eq!(krow.len(), kdim, "write_pos: krow width != kdim");
        debug_assert_eq!(vrow.len(), lay.dv, "write_pos: vrow width != dv");
        lay.k[slot * kdim..(slot + 1) * kdim].copy_from_slice(krow);
        lay.v[slot * lay.dv..(slot + 1) * lay.dv].copy_from_slice(vrow);
    }

    /// Layer `l`'s key row [kdim] at logical position `tj` of the
    /// sequence whose page table is `pages`.
    pub(crate) fn k_row(&self, l: usize, pages: &[usize], tj: usize) -> &[f32] {
        let slot = pages[tj / self.page] * self.page + tj % self.page;
        let kdim = self.kdim;
        &self.layers[l].k[slot * kdim..(slot + 1) * kdim]
    }

    /// Layer `l`'s value row [dv_l] at logical position `tj`.
    pub(crate) fn v_row(&self, l: usize, pages: &[usize], tj: usize) -> &[f32] {
        let slot = pages[tj / self.page] * self.page + tj % self.page;
        let dv = self.layers[l].dv;
        &self.layers[l].v[slot * dv..(slot + 1) * dv]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::compact::build_params;
    use crate::runtime::manifest::LayerDims;

    fn toy_spec() -> ModelSpec {
        let layer_dims = vec![
            LayerDims { d_ff: 20, d_ov: 10, head_splits: vec![6, 4] },
            LayerDims { d_ff: 12, d_ov: 5, head_splits: vec![5, 0] },
        ];
        let params = build_params("llama", 16, 2, 48, 24, &layer_dims);
        ModelSpec {
            name: "arena_toy".into(),
            family: "llama".into(),
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 20,
            vocab: 48,
            seq: 24,
            batch: 2,
            params,
            layer_dims,
        }
    }

    #[test]
    fn grow_release_reuse_is_lifo_and_accounted() {
        let spec = toy_spec();
        let mut arena = KvArena::for_spec(&spec, 6, 4).unwrap();
        assert_eq!(arena.free_pages(), 6);
        assert_eq!(arena.pages_for(9), 3);

        let mut a = PagedKv::new();
        arena.grow(&mut a, 5).unwrap(); // 2 pages
        assert_eq!(a.pages(), &[0, 1]);
        assert_eq!(arena.used_pages(), 2);

        let mut b = PagedKv::new();
        arena.grow(&mut b, 4).unwrap(); // 1 page
        assert_eq!(b.pages(), &[2]);
        assert_eq!(arena.peak_pages(), 3);

        arena.release(&mut a);
        assert_eq!(arena.used_pages(), 1);
        assert!(a.pages().is_empty() && a.is_empty());

        // LIFO: the pages a freed come right back, hottest first
        let mut c = PagedKv::new();
        arena.grow(&mut c, 8).unwrap();
        assert_eq!(c.pages(), &[1, 0]);
        assert_eq!(arena.peak_pages(), 3);

        arena.release(&mut b);
        arena.release(&mut c);
        assert_eq!(arena.free_pages(), 6);
    }

    #[test]
    fn exhaustion_is_a_proper_error() {
        let spec = toy_spec();
        let mut arena = KvArena::for_spec(&spec, 2, 4).unwrap();
        let mut a = PagedKv::new();
        arena.grow(&mut a, 8).unwrap();
        let mut b = PagedKv::new();
        let err = arena.grow(&mut b, 1).unwrap_err();
        assert!(err.to_string().contains("kv arena exhausted"), "{err}");
        arena.release(&mut a);
        assert_eq!(arena.free_pages(), 2);
    }

    #[test]
    fn injected_exhaustion_errs_and_leaves_accounting_clean() {
        use crate::fault::{install, FaultPlan, Site};
        let spec = toy_spec();
        let mut arena = KvArena::for_spec(&spec, 6, 4).unwrap();
        let scope = install(&FaultPlan::parse("arena@2=exhaust").unwrap());
        let mut a = PagedKv::new();
        arena.grow(&mut a, 4).unwrap(); // event 1: clean
        let mut b = PagedKv::new();
        let err = arena.grow(&mut b, 4).unwrap_err(); // event 2: armed
        assert!(err.to_string().contains("injected fault"), "{err}");
        assert!(b.pages().is_empty(), "failed grow must not hand out pages");
        // a non-allocating grow (already covered) is not an event
        arena.grow(&mut a, 3).unwrap();
        assert_eq!(scope.report().events_at(Site::Arena), 2);
        assert_eq!(scope.report().injected_at(Site::Arena), 1);
        arena.release(&mut a);
        assert_eq!(arena.free_pages(), 6);
    }

    #[test]
    fn rollback_rewinds_len_but_keeps_pages() {
        let spec = toy_spec();
        let mut arena = KvArena::for_spec(&spec, 3, 2).unwrap();
        let mut kv = PagedKv::new();
        arena.grow(&mut kv, 3).unwrap();
        kv.advance();
        kv.advance();
        kv.rollback(1);
        assert_eq!(kv.len(), 1);
        assert_eq!(kv.pages().len(), 2, "rollback never releases pages");
        kv.rollback(5); // rollback never advances
        assert_eq!(kv.len(), 1);
        arena.release(&mut kv);
    }

    #[test]
    fn shared_pages_survive_owner_release() {
        let spec = toy_spec();
        let mut arena = KvArena::for_spec(&spec, 4, 4).unwrap();
        let mut a = PagedKv::new();
        arena.grow(&mut a, 8).unwrap(); // pages [0, 1], both full at len 8
        let head: Vec<usize> = a.pages().to_vec();
        arena.retain_pages(&head); // prefix-cache pin
        arena.release(&mut a);
        assert_eq!(arena.used_pages(), 2, "pinned pages stay resident");

        let kv = arena.share(&head, 8);
        assert_eq!(kv.len(), 8);
        assert_eq!(kv.pages(), &head[..]);
        let mut kv = kv;
        arena.release(&mut kv);
        arena.release_pages(&head); // eviction
        assert_eq!(arena.free_pages(), 4);
    }

    #[test]
    fn write_then_read_roundtrips_rows() {
        let spec = toy_spec();
        let mut arena = KvArena::for_spec(&spec, 3, 2).unwrap();
        let mut kv = PagedKv::new();
        arena.grow(&mut kv, 3).unwrap();
        let kdim = spec.n_heads * spec.head_dim();
        for pos in 0..3 {
            let krow: Vec<f32> = (0..kdim).map(|j| (pos * 100 + j) as f32).collect();
            let vrow: Vec<f32> = (0..10).map(|j| (pos * 1000 + j) as f32).collect();
            arena.write_pos(&kv, 0, pos, &krow, &vrow);
            kv.advance();
        }
        for pos in 0..3 {
            assert_eq!(arena.k_row(0, kv.pages(), pos)[0], (pos * 100) as f32);
            assert_eq!(arena.v_row(0, kv.pages(), pos)[9], (pos * 1000 + 9) as f32);
        }
        arena.release(&mut kv);
    }
}
