//! Host backward pass: full manual backprop through both model families,
//! powering the `train_step` and `gradcol` host entries (the math the
//! original AOT artifacts obtained from `jax.value_and_grad`).
//!
//! The derivations are the standard transformer chain rules; they were
//! cross-validated against f64 central finite differences for both
//! families before landing (see tests at the bottom: the directional
//! derivative along the gradient direction must match a finite
//! difference of the loss).
//!
//! Supports per-layer dims (`ModelSpec::layer_dims`) — compact models
//! train and produce Taylor scores through the same code path.
//!
//! Parallelism: attention (batch, head) blocks — forward and backward —
//! and the softmax/NLL row loops fan out on the ambient worker pool
//! (`util::pool`). Every reduction keeps a fixed, pool-width-independent
//! order (per-block local accumulators, serial f64 loss sum over the
//! per-row NLL buffer), so gradients and losses are bit-identical across
//! backends.

use super::host::LN_EPS;
use super::weights::{PackCache, Weights};
use crate::runtime::manifest::ModelSpec;
use crate::tensor::matmul::{matmul, matmul_at, matmul_bt};
use crate::tensor::pack::matmul_packed;
use crate::tensor::{IntTensor, Tensor};
use anyhow::Result;

pub const BETA1: f32 = 0.9;
pub const BETA2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;
pub const GRAD_CLIP: f32 = 1.0;

// ---------------------------------------------------------------- norms

enum NormCache {
    /// LayerNorm: normalized activations + per-row 1/σ.
    Ln { xh: Tensor, inv: Vec<f32> },
    /// RMSNorm: per-row 1/rms (input x cached by the caller).
    Rms { inv: Vec<f32> },
}

fn layer_norm_fwd(x: &Tensor, g: &[f32], b: &[f32]) -> (Tensor, NormCache) {
    let (rows, d) = x.dims2();
    let mut y = Tensor::zeros(&[rows, d]);
    let mut xh = Tensor::zeros(&[rows, d]);
    let mut inv = vec![0.0f32; rows];
    for r in 0..rows {
        let row = x.row(r);
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        inv[r] = iv;
        let xh_row = xh.row_mut(r);
        for j in 0..d {
            xh_row[j] = (row[j] - mu) * iv;
        }
        let y_row = y.row_mut(r);
        for j in 0..d {
            y_row[j] = xh.at2(r, j) * g[j] + b[j];
        }
    }
    (y, NormCache::Ln { xh, inv })
}

/// Returns dx; accumulates dg/db.
fn layer_norm_bwd(
    dy: &Tensor,
    cache: &NormCache,
    g: &[f32],
    dg: &mut [f32],
    db: &mut [f32],
) -> Tensor {
    let (xh, inv) = match cache {
        NormCache::Ln { xh, inv } => (xh, inv),
        _ => unreachable!("layer_norm_bwd on rms cache"),
    };
    let (rows, d) = dy.dims2();
    let mut dx = Tensor::zeros(&[rows, d]);
    for r in 0..rows {
        let dy_row = dy.row(r);
        let xh_row = xh.row(r);
        let mut m1 = 0.0f32; // mean(dxh)
        let mut m2 = 0.0f32; // mean(dxh * xh)
        for j in 0..d {
            let dxh = dy_row[j] * g[j];
            m1 += dxh;
            m2 += dxh * xh_row[j];
            dg[j] += dy_row[j] * xh_row[j];
            db[j] += dy_row[j];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        let iv = inv[r];
        let dx_row = dx.row_mut(r);
        for j in 0..d {
            let dxh = dy_row[j] * g[j];
            dx_row[j] = iv * (dxh - m1 - xh_row[j] * m2);
        }
    }
    dx
}

fn rms_norm_fwd(x: &Tensor, g: &[f32]) -> (Tensor, NormCache) {
    let (rows, d) = x.dims2();
    let mut y = Tensor::zeros(&[rows, d]);
    let mut inv = vec![0.0f32; rows];
    for r in 0..rows {
        let row = x.row(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let iv = 1.0 / (ms + LN_EPS).sqrt();
        inv[r] = iv;
        let y_row = y.row_mut(r);
        for j in 0..d {
            y_row[j] = row[j] * iv * g[j];
        }
    }
    (y, NormCache::Rms { inv })
}

/// Returns dx; accumulates dg. `x` is the norm's input (cached upstream).
fn rms_norm_bwd(
    dy: &Tensor,
    x: &Tensor,
    cache: &NormCache,
    g: &[f32],
    dg: &mut [f32],
) -> Tensor {
    let inv = match cache {
        NormCache::Rms { inv } => inv,
        _ => unreachable!("rms_norm_bwd on ln cache"),
    };
    let (rows, d) = dy.dims2();
    let mut dx = Tensor::zeros(&[rows, d]);
    for r in 0..rows {
        let dy_row = dy.row(r);
        let x_row = x.row(r);
        let iv = inv[r];
        let mut s = 0.0f32; // Σ_j dy_j g_j x_j
        for j in 0..d {
            s += dy_row[j] * g[j] * x_row[j];
            dg[j] += dy_row[j] * x_row[j] * iv;
        }
        let c = iv * iv * iv * s / d as f32;
        let dx_row = dx.row_mut(r);
        for j in 0..d {
            dx_row[j] = g[j] * dy_row[j] * iv - x_row[j] * c;
        }
    }
    dx
}

// ---------------------------------------------------------------- rope

/// Apply rotate-half RoPE in place to every head block of [b·t, h·dh].
fn rope_rows(x: &mut Tensor, b: usize, t: usize, n_heads: usize, dh: usize, cos: &[f32], sin: &[f32]) {
    let half = dh / 2;
    for r in 0..b * t {
        let ti = r % t;
        let row = x.row_mut(r);
        for hi in 0..n_heads {
            let base = hi * dh;
            for k in 0..half {
                let c = cos[ti * half + k];
                let s = sin[ti * half + k];
                let x1 = row[base + k];
                let x2 = row[base + half + k];
                row[base + k] = x1 * c - x2 * s;
                row[base + half + k] = x1 * s + x2 * c;
            }
        }
    }
}

/// Backward of [`rope_rows`]: the inverse (transpose) rotation, in place.
fn rope_rows_bwd(x: &mut Tensor, b: usize, t: usize, n_heads: usize, dh: usize, cos: &[f32], sin: &[f32]) {
    let half = dh / 2;
    for r in 0..b * t {
        let ti = r % t;
        let row = x.row_mut(r);
        for hi in 0..n_heads {
            let base = hi * dh;
            for k in 0..half {
                let c = cos[ti * half + k];
                let s = sin[ti * half + k];
                let d1 = row[base + k];
                let d2 = row[base + half + k];
                row[base + k] = d1 * c + d2 * s;
                row[base + half + k] = -d1 * s + d2 * c;
            }
        }
    }
}

// ---------------------------------------------------------------- linear

/// y = x·Wᵀ (+ b) through the pack cache when one is supplied (the
/// gradcol entry runs over `Session::pack`'s plan), unpacked fallback
/// otherwise — bit-identical either way by the lane-kernel contract.
fn lin_fwd_p(
    w: &Weights,
    packs: Option<&PackCache>,
    l: usize,
    name: &str,
    b: Option<&Tensor>,
    x: &Tensor,
) -> Result<Tensor> {
    let mut y = match packs.and_then(|p| p.get_l(l, name)) {
        Some(pm) => matmul_packed(x, &pm),
        None => matmul_bt(x, &w.get_l(l, name)?),
    };
    if let Some(b) = b {
        super::host::add_bias(&mut y, b);
    }
    Ok(y)
}

/// dW += dyᵀ·x, db += Σ_rows dy; returns dx = dy·W. The weight gradient
/// runs through the transpose-free [`matmul_at`] kernel — bit-identical
/// to the old `matmul(&dy.t(), x)` without the per-train-step [R·out]
/// transpose copy.
fn linear_bwd(
    dy: &Tensor,
    x: &Tensor,
    w: &Tensor,
    dw: &mut Tensor,
    db: Option<&mut Vec<f32>>,
) -> Tensor {
    let dwt = matmul_at(dy, x);
    for (a, v) in dw.data.iter_mut().zip(&dwt.data) {
        *a += v;
    }
    if let Some(db) = db {
        let (rows, out) = dy.dims2();
        for r in 0..rows {
            let row = dy.row(r);
            for j in 0..out {
                db[j] += row[j];
            }
        }
    }
    matmul(dy, w)
}

// ---------------------------------------------------------------- caches

struct LayerCache {
    x_in: Tensor,
    x_ln1: Tensor,
    ln1: NormCache,
    /// q/k post-rope [R, h·dh]; v [R, dov].
    q: Tensor,
    k: Tensor,
    v: Tensor,
    /// Attention probs, [b, h, t, t] flattened (upper triangle zero).
    probs: Vec<f32>,
    ctx: Tensor,
    x_mid: Tensor,
    x_ln2: Tensor,
    ln2: NormCache,
    /// opt: pre-relu fc1 out; llama: gate pre-activation.
    ffn_a: Tensor,
    /// llama only: up-projection output.
    ffn_u: Option<Tensor>,
    /// post-activation hidden [R, f_l].
    h: Tensor,
}

/// Per-parameter gradient accumulator addressed through the weight
/// offsets (so per-layer shapes come along for free).
struct GradAcc {
    data: Vec<f32>,
}

impl GradAcc {
    fn add(&mut self, w: &Weights, name: &str, t: &Tensor) -> Result<()> {
        let (off, shape) = w.offset(name)?;
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == t.numel(), "grad shape for '{name}'");
        for (g, v) in self.data[off..off + n].iter_mut().zip(&t.data) {
            *g += v;
        }
        Ok(())
    }

    fn add_vec(&mut self, w: &Weights, name: &str, v: &[f32]) -> Result<()> {
        let (off, shape) = w.offset(name)?;
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == v.len(), "grad len for '{name}'");
        for (g, x) in self.data[off..off + n].iter_mut().zip(v) {
            *g += x;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- fwd+bwd

/// Mean teacher-forced NLL and its gradient w.r.t. every packed
/// parameter (unclipped — clipping is the trainer's concern).
pub fn loss_and_grad(
    w: &Weights,
    tokens: &IntTensor,
    targets: &IntTensor,
) -> Result<(f32, Tensor)> {
    loss_and_grad_packed(w, None, tokens, targets)
}

/// [`loss_and_grad`] with an optional pack cache: the forward linears
/// (and the logits head) consume pre-packed panels, the backward works
/// off the resident raw weights — outputs are bit-identical with and
/// without the cache. The train step passes `None` (its weights change
/// every step); the gradcol entry passes `Session::pack`'s cache.
pub fn loss_and_grad_packed(
    w: &Weights,
    packs: Option<&PackCache>,
    tokens: &IntTensor,
    targets: &IntTensor,
) -> Result<(f32, Tensor)> {
    let spec = &w.spec;
    let (b, t) = (tokens.shape[0], tokens.shape[1]);
    let d = spec.d_model;
    let n_heads = spec.n_heads;
    let dh = spec.head_dim();
    let rows = b * t;
    let is_opt = spec.family == "opt";
    // process-cached tables (rows beyond `t` are simply unused)
    let rope = super::host::rope_cached(t, dh);
    let scale = 1.0 / (dh as f32).sqrt();

    let tok_emb = w.get("tok_emb")?;

    // ---- forward with caches ------------------------------------------
    let mut x = Tensor::zeros(&[rows, d]);
    for (r, &tokid) in tokens.data.iter().enumerate() {
        x.row_mut(r).copy_from_slice(tok_emb.row(tokid as usize));
    }
    if is_opt {
        let pos = w.get("pos_emb")?;
        for bi in 0..b {
            for ti in 0..t {
                let r = bi * t + ti;
                for (v, p) in x.row_mut(r).iter_mut().zip(pos.row(ti)) {
                    *v += p;
                }
            }
        }
    }

    let mut caches: Vec<LayerCache> = Vec::with_capacity(spec.n_layers);
    for l in 0..spec.n_layers {
        let x_in = x.clone();
        let (x_ln1, ln1) = if is_opt {
            layer_norm_fwd(&x, &w.get_l(l, "ln1_g")?.data, &w.get_l(l, "ln1_b")?.data)
        } else {
            rms_norm_fwd(&x, &w.get_l(l, "ln1_g")?.data)
        };
        let bq = if is_opt { Some(w.get_l(l, "bq")?) } else { None };
        let bk = if is_opt { Some(w.get_l(l, "bk")?) } else { None };
        let bv = if is_opt { Some(w.get_l(l, "bv")?) } else { None };
        let mut q = lin_fwd_p(w, packs, l, "wq", bq.as_ref(), &x_ln1)?;
        let mut k = lin_fwd_p(w, packs, l, "wk", bk.as_ref(), &x_ln1)?;
        let v = lin_fwd_p(w, packs, l, "wv", bv.as_ref(), &x_ln1)?;
        if !is_opt {
            rope_rows(&mut q, b, t, n_heads, dh, &rope.0, &rope.1);
            rope_rows(&mut k, b, t, n_heads, dh, &rope.0, &rope.1);
        }
        let splits = spec.head_splits_l(l);
        let dov: usize = splits.iter().sum();
        let mut offs = vec![0usize; n_heads + 1];
        for hi in 0..n_heads {
            offs[hi + 1] = offs[hi] + splits[hi];
        }
        let mut ctx = Tensor::zeros(&[rows, dov]);
        let mut probs = vec![0.0f32; b * n_heads * t * t];
        // independent (batch, head) blocks, fanned out on the ambient
        // pool; each returns its contiguous probs block [t,t] and its
        // context slice [t, dv]
        let fwd_block = |bi: usize, hi: usize| -> (Vec<f32>, Vec<f32>) {
            let dv = splits[hi];
            let vo = offs[hi];
            let qb = hi * dh;
            let mut pb = vec![0.0f32; t * t];
            let mut cb = vec![0.0f32; t * dv];
            for ti in 0..t {
                let rq = bi * t + ti;
                let qrow = &q.row(rq)[qb..qb + dh];
                let mut scores = Vec::with_capacity(ti + 1);
                for tj in 0..=ti {
                    let krow = &k.row(bi * t + tj)[qb..qb + dh];
                    scores.push(crate::tensor::matmul::dot(qrow, krow) * scale);
                }
                let m = scores.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
                let mut z = 0.0f32;
                for s in scores.iter_mut() {
                    *s = (*s - m).exp();
                    z += *s;
                }
                for (tj, s) in scores.iter().enumerate() {
                    pb[ti * t + tj] = s / z;
                }
                if dv > 0 {
                    let out = &mut cb[ti * dv..(ti + 1) * dv];
                    for (tj, s) in scores.iter().enumerate() {
                        let wz = s / z;
                        let vrow = &v.row(bi * t + tj)[vo..vo + dv];
                        for (o, vv) in out.iter_mut().zip(vrow) {
                            *o += wz * vv;
                        }
                    }
                }
            }
            (pb, cb)
        };
        let n_blocks = b * n_heads;
        let pool = crate::util::pool::current();
        let attn_work = n_blocks * t * t * (dh + dov / n_heads.max(1));
        let mut place = |i: usize, (pb, cb): (Vec<f32>, Vec<f32>)| {
            let (bi, hi) = (i / n_heads, i % n_heads);
            let base = (bi * n_heads + hi) * t * t;
            probs[base..base + t * t].copy_from_slice(&pb);
            let dv = splits[hi];
            if dv == 0 {
                return;
            }
            let vo = offs[hi];
            for ti in 0..t {
                ctx.row_mut(bi * t + ti)[vo..vo + dv]
                    .copy_from_slice(&cb[ti * dv..(ti + 1) * dv]);
            }
        };
        if pool.workers() > 1 && n_blocks > 1 && attn_work >= crate::util::pool::PAR_THRESHOLD
        {
            let blocks = pool.map(n_blocks, |i| fwd_block(i / n_heads, i % n_heads));
            for (i, blk) in blocks.into_iter().enumerate() {
                place(i, blk);
            }
        } else {
            // serial: stream each block straight into probs/ctx
            for i in 0..n_blocks {
                place(i, fwd_block(i / n_heads, i % n_heads));
            }
        }
        let attn_out = lin_fwd_p(w, packs, l, "wo", Some(&w.get_l(l, "bo")?), &ctx)?;
        for (xv, av) in x.data.iter_mut().zip(&attn_out.data) {
            *xv += av;
        }
        let x_mid = x.clone();
        let (x_ln2, ln2) = if is_opt {
            layer_norm_fwd(&x, &w.get_l(l, "ln2_g")?.data, &w.get_l(l, "ln2_b")?.data)
        } else {
            rms_norm_fwd(&x, &w.get_l(l, "ln2_g")?.data)
        };
        let (ffn_a, ffn_u, h) = if is_opt {
            let a = lin_fwd_p(w, packs, l, "fc1", Some(&w.get_l(l, "bfc1")?), &x_ln2)?;
            let mut h = a.clone();
            for v in h.data.iter_mut() {
                *v = v.max(0.0);
            }
            (a, None, h)
        } else {
            let g = lin_fwd_p(w, packs, l, "w_gate", None, &x_ln2)?;
            let u = lin_fwd_p(w, packs, l, "w_up", None, &x_ln2)?;
            let mut h = u.clone();
            for (hv, gv) in h.data.iter_mut().zip(&g.data) {
                let sg = 1.0 / (1.0 + (-gv).exp());
                *hv *= gv * sg;
            }
            (g, Some(u), h)
        };
        let ffn_out = if is_opt {
            lin_fwd_p(w, packs, l, "fc2", Some(&w.get_l(l, "bfc2")?), &h)?
        } else {
            lin_fwd_p(w, packs, l, "w_down", Some(&w.get_l(l, "b_down")?), &h)?
        };
        for (xv, fv) in x.data.iter_mut().zip(&ffn_out.data) {
            *xv += fv;
        }
        caches.push(LayerCache {
            x_in,
            x_ln1,
            ln1,
            q,
            k,
            v,
            probs,
            ctx,
            x_mid,
            x_ln2,
            ln2,
            ffn_a,
            ffn_u,
            h,
        });
    }

    let x_f = x.clone();
    let (x_n, lnf) = if is_opt {
        layer_norm_fwd(&x, &w.get("lnf_g")?.data, &w.get("lnf_b")?.data)
    } else {
        rms_norm_fwd(&x, &w.get("lnf_g")?.data)
    };

    // logits → loss → dlogits (probs materialized in place of logits).
    // Rows are independent; the per-row NLLs land in a buffer and the
    // f64 loss reduction stays serial in row order, so the loss is
    // bit-identical for any pool width.
    let mut logits = match packs.and_then(|p| p.get("tok_emb")) {
        Some(pm) => matmul_packed(&x_n, &pm), // packed head panel, same bits
        None => matmul_bt(&x_n, &tok_emb),
    }; // [R, V]
    let vocab = spec.vocab;
    let mut row_nll = vec![0.0f32; rows];
    let softmax_rows = |r0: usize, lrows: &mut [f32], nrows: &mut [f32]| {
        for (i, nv) in nrows.iter_mut().enumerate() {
            let r = r0 + i;
            let row = &mut lrows[i * vocab..(i + 1) * vocab];
            let tgt = targets.data[r] as usize;
            let tgt_logit = row[tgt];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let mut z = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                z += *v;
            }
            // nll = logsumexp - logit[tgt] (stable: exp is shifted by m)
            *nv = m + z.ln() - tgt_logit;
            // row becomes softmax probs
            for v in row.iter_mut() {
                *v /= z;
            }
        }
    };
    let pool = crate::util::pool::current();
    let logits_par = pool.workers() > 1 && rows * vocab >= crate::util::pool::PAR_THRESHOLD;
    if logits_par {
        pool.run_rows2(&mut logits.data, vocab, &mut row_nll, 1, softmax_rows);
    } else {
        softmax_rows(0, &mut logits.data, &mut row_nll);
    }
    let loss_sum: f64 = row_nll.iter().map(|&x| x as f64).sum();
    let loss = (loss_sum / rows as f64) as f32;

    // ---- backward ------------------------------------------------------
    let mut grad = GradAcc { data: vec![0.0f32; spec.n_params_elems()] };

    // dlogits = (probs − onehot)/R, reusing the probs buffer
    let inv_r = 1.0 / rows as f32;
    let dlogit_rows = |r0: usize, lrows: &mut [f32]| {
        for (i, row) in lrows.chunks_exact_mut(vocab).enumerate() {
            let tgt = targets.data[r0 + i] as usize;
            row[tgt] -= 1.0;
            for v in row.iter_mut() {
                *v *= inv_r;
            }
        }
    };
    if logits_par {
        pool.run_rows1(&mut logits.data, vocab, dlogit_rows);
    } else {
        dlogit_rows(0, &mut logits.data);
    }
    let dlogits = logits;

    let dx_n = matmul(&dlogits, &tok_emb); // [R, d]
    grad.add(w, "tok_emb", &matmul_at(&dlogits, &x_n))?;

    let mut dx = if is_opt {
        let mut dg = vec![0.0f32; d];
        let mut db = vec![0.0f32; d];
        let dx = layer_norm_bwd(&dx_n, &lnf, &w.get("lnf_g")?.data, &mut dg, &mut db);
        grad.add_vec(w, "lnf_g", &dg)?;
        grad.add_vec(w, "lnf_b", &db)?;
        dx
    } else {
        let mut dg = vec![0.0f32; d];
        let dx = rms_norm_bwd(&dx_n, &x_f, &lnf, &w.get("lnf_g")?.data, &mut dg);
        grad.add_vec(w, "lnf_g", &dg)?;
        dx
    };

    for l in (0..spec.n_layers).rev() {
        let c = &caches[l];
        let f_l = c.h.shape[1];
        let splits = spec.head_splits_l(l);
        let dov: usize = splits.iter().sum();
        let mut offs = vec![0usize; n_heads + 1];
        for hi in 0..n_heads {
            offs[hi + 1] = offs[hi] + splits[hi];
        }

        // ---- FFN backward (x = x_mid + ffn_out) ------------------------
        let dffn_out = &dx; // residual pass-through handled by adding dxm below
        let dx_ln2 = if is_opt {
            let fc2 = w.get_l(l, "fc2")?;
            let mut dfc2 = Tensor::zeros(&[d, f_l]);
            let mut dbfc2 = vec![0.0f32; d];
            let dh_post = linear_bwd(dffn_out, &c.h, &fc2, &mut dfc2, Some(&mut dbfc2));
            grad.add(w, &Weights::pname(l, "fc2"), &dfc2)?;
            grad.add_vec(w, &Weights::pname(l, "bfc2"), &dbfc2)?;
            // relu
            let mut da = dh_post;
            for (dv, av) in da.data.iter_mut().zip(&c.ffn_a.data) {
                if *av <= 0.0 {
                    *dv = 0.0;
                }
            }
            let fc1 = w.get_l(l, "fc1")?;
            let mut dfc1 = Tensor::zeros(&[f_l, d]);
            let mut dbfc1 = vec![0.0f32; f_l];
            let dx_ln2 = linear_bwd(&da, &c.x_ln2, &fc1, &mut dfc1, Some(&mut dbfc1));
            grad.add(w, &Weights::pname(l, "fc1"), &dfc1)?;
            grad.add_vec(w, &Weights::pname(l, "bfc1"), &dbfc1)?;
            dx_ln2
        } else {
            let w_down = w.get_l(l, "w_down")?;
            let mut dwd = Tensor::zeros(&[d, f_l]);
            let mut dbd = vec![0.0f32; d];
            let dh_post = linear_bwd(dffn_out, &c.h, &w_down, &mut dwd, Some(&mut dbd));
            grad.add(w, &Weights::pname(l, "w_down"), &dwd)?;
            grad.add_vec(w, &Weights::pname(l, "b_down"), &dbd)?;
            // swiglu: h = u · silu(g)
            let u = c.ffn_u.as_ref().unwrap();
            let gg = &c.ffn_a;
            let mut du = Tensor::zeros(&[rows, f_l]);
            let mut dgg = Tensor::zeros(&[rows, f_l]);
            for i in 0..rows * f_l {
                let g_v = gg.data[i];
                let sg = 1.0 / (1.0 + (-g_v).exp());
                let silu = g_v * sg;
                du.data[i] = dh_post.data[i] * silu;
                dgg.data[i] = dh_post.data[i] * u.data[i] * (sg + g_v * sg * (1.0 - sg));
            }
            let w_up = w.get_l(l, "w_up")?;
            let w_gate = w.get_l(l, "w_gate")?;
            let mut dwu = Tensor::zeros(&[f_l, d]);
            let mut dwg = Tensor::zeros(&[f_l, d]);
            let dx1 = linear_bwd(&du, &c.x_ln2, &w_up, &mut dwu, None);
            let dx2 = linear_bwd(&dgg, &c.x_ln2, &w_gate, &mut dwg, None);
            grad.add(w, &Weights::pname(l, "w_up"), &dwu)?;
            grad.add(w, &Weights::pname(l, "w_gate"), &dwg)?;
            crate::tensor::ops::add(&dx1, &dx2)
        };
        let dxm = if is_opt {
            let mut dg2 = vec![0.0f32; d];
            let mut db2 = vec![0.0f32; d];
            let r = layer_norm_bwd(&dx_ln2, &c.ln2, &w.get_l(l, "ln2_g")?.data, &mut dg2, &mut db2);
            grad.add_vec(w, &Weights::pname(l, "ln2_g"), &dg2)?;
            grad.add_vec(w, &Weights::pname(l, "ln2_b"), &db2)?;
            r
        } else {
            let mut dg2 = vec![0.0f32; d];
            let r = rms_norm_bwd(&dx_ln2, &c.x_mid, &c.ln2, &w.get_l(l, "ln2_g")?.data, &mut dg2);
            grad.add_vec(w, &Weights::pname(l, "ln2_g"), &dg2)?;
            r
        };
        // residual: d(x_mid) = dx (straight-through) + norm path
        let mut dxmid = dx;
        for (a, v) in dxmid.data.iter_mut().zip(&dxm.data) {
            *a += v;
        }

        // ---- attention backward (x_mid = x_in + ctx·woᵀ + bo) ----------
        let wo = w.get_l(l, "wo")?;
        let mut dwo = Tensor::zeros(&[d, dov]);
        let mut dbo = vec![0.0f32; d];
        let dctx = linear_bwd(&dxmid, &c.ctx, &wo, &mut dwo, Some(&mut dbo));
        grad.add(w, &Weights::pname(l, "wo"), &dwo)?;
        grad.add_vec(w, &Weights::pname(l, "bo"), &dbo)?;

        let mut dq = Tensor::zeros(&[rows, d]);
        let mut dk = Tensor::zeros(&[rows, d]);
        let mut dv = Tensor::zeros(&[rows, dov]);
        // independent (batch, head) blocks again: each accumulates its own
        // [t, dh]/[t, dvw] gradient slices with the serial inner order
        let bwd_block = |bi: usize, hi: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let dvw = splits[hi];
            let vo = offs[hi];
            let qb = hi * dh;
            let mut dqb = vec![0.0f32; t * dh];
            let mut dkb = vec![0.0f32; t * dh];
            let mut dvb = vec![0.0f32; t * dvw];
            // dP and softmax backward, row ti at a time
            for ti in 0..t {
                let rq = bi * t + ti;
                let pbase = ((bi * n_heads + hi) * t + ti) * t;
                // dP[ti][tj] = dctx_row · v_row ; also dv += P * dctx
                let dch = &dctx.row(rq)[vo..vo + dvw];
                let mut dp = vec![0.0f32; ti + 1];
                for tj in 0..=ti {
                    let p = c.probs[pbase + tj];
                    if dvw > 0 {
                        let vrow = &c.v.row(bi * t + tj)[vo..vo + dvw];
                        let mut s = 0.0f32;
                        let dvrow = &mut dvb[tj * dvw..(tj + 1) * dvw];
                        for ((dvv, &vv), &dc) in
                            dvrow.iter_mut().zip(vrow).zip(dch.iter())
                        {
                            *dvv += p * dc;
                            s += dc * vv;
                        }
                        dp[tj] = s;
                    }
                }
                // softmax backward: ds = P ⊙ (dP − Σ dP·P)
                let mut dot_pp = 0.0f32;
                for tj in 0..=ti {
                    dot_pp += dp[tj] * c.probs[pbase + tj];
                }
                for tj in 0..=ti {
                    let p = c.probs[pbase + tj];
                    let ds = p * (dp[tj] - dot_pp) * scale;
                    if ds == 0.0 {
                        continue;
                    }
                    let krow = &c.k.row(bi * t + tj)[qb..qb + dh];
                    let qrow = &c.q.row(rq)[qb..qb + dh];
                    {
                        let dq_row = &mut dqb[ti * dh..(ti + 1) * dh];
                        for (o, &kv) in dq_row.iter_mut().zip(krow) {
                            *o += ds * kv;
                        }
                    }
                    let dk_row = &mut dkb[tj * dh..(tj + 1) * dh];
                    for (o, &qv) in dk_row.iter_mut().zip(qrow) {
                        *o += ds * qv;
                    }
                }
            }
            (dqb, dkb, dvb)
        };
        let n_blocks = b * n_heads;
        let attn_work = n_blocks * t * t * (dh + dov / n_heads.max(1));
        let mut place = |i: usize, (dqb, dkb, dvb): (Vec<f32>, Vec<f32>, Vec<f32>)| {
            let (bi, hi) = (i / n_heads, i % n_heads);
            let dvw = splits[hi];
            let vo = offs[hi];
            let qb = hi * dh;
            for ti in 0..t {
                let r = bi * t + ti;
                dq.row_mut(r)[qb..qb + dh].copy_from_slice(&dqb[ti * dh..(ti + 1) * dh]);
                dk.row_mut(r)[qb..qb + dh].copy_from_slice(&dkb[ti * dh..(ti + 1) * dh]);
                if dvw > 0 {
                    dv.row_mut(r)[vo..vo + dvw]
                        .copy_from_slice(&dvb[ti * dvw..(ti + 1) * dvw]);
                }
            }
        };
        if pool.workers() > 1 && n_blocks > 1 && attn_work >= crate::util::pool::PAR_THRESHOLD
        {
            let blocks = pool.map(n_blocks, |i| bwd_block(i / n_heads, i % n_heads));
            for (i, blk) in blocks.into_iter().enumerate() {
                place(i, blk);
            }
        } else {
            // serial: stream each block straight into dq/dk/dv
            for i in 0..n_blocks {
                place(i, bwd_block(i / n_heads, i % n_heads));
            }
        }
        if !is_opt {
            rope_rows_bwd(&mut dq, b, t, n_heads, dh, &rope.0, &rope.1);
            rope_rows_bwd(&mut dk, b, t, n_heads, dh, &rope.0, &rope.1);
        }
        let wq = w.get_l(l, "wq")?;
        let wk = w.get_l(l, "wk")?;
        let wv = w.get_l(l, "wv")?;
        let mut dwq = Tensor::zeros(&[d, d]);
        let mut dwk = Tensor::zeros(&[d, d]);
        let mut dwv = Tensor::zeros(&[dov, d]);
        let (dx1, dx2, dx3);
        if is_opt {
            let mut dbq = vec![0.0f32; d];
            let mut dbk = vec![0.0f32; d];
            let mut dbv = vec![0.0f32; dov];
            dx1 = linear_bwd(&dq, &c.x_ln1, &wq, &mut dwq, Some(&mut dbq));
            dx2 = linear_bwd(&dk, &c.x_ln1, &wk, &mut dwk, Some(&mut dbk));
            dx3 = linear_bwd(&dv, &c.x_ln1, &wv, &mut dwv, Some(&mut dbv));
            grad.add_vec(w, &Weights::pname(l, "bq"), &dbq)?;
            grad.add_vec(w, &Weights::pname(l, "bk"), &dbk)?;
            grad.add_vec(w, &Weights::pname(l, "bv"), &dbv)?;
        } else {
            dx1 = linear_bwd(&dq, &c.x_ln1, &wq, &mut dwq, None);
            dx2 = linear_bwd(&dk, &c.x_ln1, &wk, &mut dwk, None);
            dx3 = linear_bwd(&dv, &c.x_ln1, &wv, &mut dwv, None);
        }
        grad.add(w, &Weights::pname(l, "wq"), &dwq)?;
        grad.add(w, &Weights::pname(l, "wk"), &dwk)?;
        grad.add(w, &Weights::pname(l, "wv"), &dwv)?;
        let mut dx_ln1 = dx1;
        for (a, v) in dx_ln1.data.iter_mut().zip(&dx2.data) {
            *a += v;
        }
        for (a, v) in dx_ln1.data.iter_mut().zip(&dx3.data) {
            *a += v;
        }
        let dxi = if is_opt {
            let mut dg1 = vec![0.0f32; d];
            let mut db1 = vec![0.0f32; d];
            let r = layer_norm_bwd(&dx_ln1, &c.ln1, &w.get_l(l, "ln1_g")?.data, &mut dg1, &mut db1);
            grad.add_vec(w, &Weights::pname(l, "ln1_g"), &dg1)?;
            grad.add_vec(w, &Weights::pname(l, "ln1_b"), &db1)?;
            r
        } else {
            let mut dg1 = vec![0.0f32; d];
            let r = rms_norm_bwd(&dx_ln1, &c.x_in, &c.ln1, &w.get_l(l, "ln1_g")?.data, &mut dg1);
            grad.add_vec(w, &Weights::pname(l, "ln1_g"), &dg1)?;
            r
        };
        // residual into the layer input
        for (a, v) in dxmid.data.iter_mut().zip(&dxi.data) {
            *a += v;
        }
        dx = dxmid;
    }

    // embedding backward: scatter-add token rows (+ positional for opt)
    {
        let (off, _) = w.offset("tok_emb")?;
        for (r, &tokid) in tokens.data.iter().enumerate() {
            let base = off + tokid as usize * d;
            let row = dx.row(r);
            for (g, v) in grad.data[base..base + d].iter_mut().zip(row) {
                *g += v;
            }
        }
    }
    if is_opt {
        let (off, _) = w.offset("pos_emb")?;
        for bi in 0..b {
            for ti in 0..t {
                let row = dx.row(bi * t + ti);
                let base = off + ti * d;
                for (g, v) in grad.data[base..base + d].iter_mut().zip(row) {
                    *g += v;
                }
            }
        }
    }

    let n = grad.data.len();
    Ok((loss, Tensor::new(vec![n], grad.data)))
}

// ---------------------------------------------------------------- adam

/// One fused Adam step over the packed [3P] train state — the host mirror
/// of `python/compile/train.py::train_step` (global-norm clip 1.0, β₁ 0.9,
/// β₂ 0.999, ε 1e-8, bias correction with 1-based step `t`). Returns the
/// loss at the incoming params and the updated state.
pub fn train_step_host(
    spec: &ModelSpec,
    state: &[f32],
    tokens: &IntTensor,
    targets: &IntTensor,
    t: f32,
    lr: f32,
) -> Result<(f32, Vec<f32>)> {
    let p = spec.n_params_elems();
    anyhow::ensure!(state.len() == 3 * p, "train state length {} != 3·{p}", state.len());
    let weights = Weights::from_packed(spec, state[..p].to_vec())?;
    let (loss, grad) = loss_and_grad(&weights, tokens, targets)?;

    let gnorm = (grad.data.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>() + 1e-12)
        .sqrt();
    let clip = (GRAD_CLIP as f64 / gnorm).min(1.0) as f32;

    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);
    let mut new = state.to_vec();
    for i in 0..p {
        let g = grad.data[i] * clip;
        let m2 = BETA1 * state[p + i] + (1.0 - BETA1) * g;
        let v2 = BETA2 * state[2 * p + i] + (1.0 - BETA2) * g * g;
        let mhat = m2 / bc1;
        let vhat = v2 / bc2;
        new[i] = state[i] - lr * mhat / (vhat.sqrt() + ADAM_EPS);
        new[p + i] = m2;
        new[2 * p + i] = v2;
    }
    Ok((loss, new))
}

// ---------------------------------------------------------------- taylor

/// First-order Taylor column scores per layer (the `gradcol` entry,
/// mirroring `python/compile/gradcol.py`): per-layer `(ffn[f_l], ov[dov_l])`
/// built from |W ⊙ ∂L/∂W| column/row sums over the coupled structures.
pub fn taylor_scores(
    w: &Weights,
    grad_packed: &Tensor,
) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
    let spec = &w.spec;
    let gw = Weights::from_packed(spec, grad_packed.data.clone())?;
    let is_opt = spec.family == "opt";
    let mut out = Vec::with_capacity(spec.n_layers);
    for l in 0..spec.n_layers {
        let mut ffn = if is_opt {
            col_abs_prod(&w.get_l(l, "fc2")?, &gw.get_l(l, "fc2")?)
        } else {
            col_abs_prod(&w.get_l(l, "w_down")?, &gw.get_l(l, "w_down")?)
        };
        if is_opt {
            add_into(&mut ffn, &row_abs_prod(&w.get_l(l, "fc1")?, &gw.get_l(l, "fc1")?));
        } else {
            add_into(&mut ffn, &row_abs_prod(&w.get_l(l, "w_up")?, &gw.get_l(l, "w_up")?));
            add_into(&mut ffn, &row_abs_prod(&w.get_l(l, "w_gate")?, &gw.get_l(l, "w_gate")?));
        }
        let mut ov = col_abs_prod(&w.get_l(l, "wo")?, &gw.get_l(l, "wo")?);
        add_into(&mut ov, &row_abs_prod(&w.get_l(l, "wv")?, &gw.get_l(l, "wv")?));
        out.push((ffn, ov));
    }
    Ok(out)
}

fn col_abs_prod(a: &Tensor, b: &Tensor) -> Vec<f32> {
    let (m, n) = a.dims2();
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        let ar = a.row(i);
        let br = b.row(i);
        for j in 0..n {
            out[j] += (ar[j] * br[j]).abs();
        }
    }
    out
}

fn row_abs_prod(a: &Tensor, b: &Tensor) -> Vec<f32> {
    let (m, _) = a.dims2();
    (0..m)
        .map(|i| {
            a.row(i)
                .iter()
                .zip(b.row(i))
                .map(|(x, y)| (x * y).abs())
                .sum()
        })
        .collect()
}

fn add_into(a: &mut [f32], b: &[f32]) {
    for (x, y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ModelSpec;
    use crate::util::rng::Rng;

    fn tiny_spec(family: &str) -> ModelSpec {
        let (d, f, v, t) = (8usize, 12usize, 16usize, 5usize);
        let mut params = vec![("tok_emb".to_string(), vec![v, d])];
        if family == "opt" {
            params.push(("pos_emb".into(), vec![t, d]));
        }
        for i in 0..2 {
            let p = format!("layers.{i}.");
            if family == "opt" {
                for (n, s) in [
                    ("ln1_g", vec![d]),
                    ("ln1_b", vec![d]),
                    ("wq", vec![d, d]),
                    ("bq", vec![d]),
                    ("wk", vec![d, d]),
                    ("bk", vec![d]),
                    ("wv", vec![d, d]),
                    ("bv", vec![d]),
                    ("wo", vec![d, d]),
                    ("bo", vec![d]),
                    ("ln2_g", vec![d]),
                    ("ln2_b", vec![d]),
                    ("fc1", vec![f, d]),
                    ("bfc1", vec![f]),
                    ("fc2", vec![d, f]),
                    ("bfc2", vec![d]),
                ] {
                    params.push((format!("{p}{n}"), s));
                }
            } else {
                for (n, s) in [
                    ("ln1_g", vec![d]),
                    ("wq", vec![d, d]),
                    ("wk", vec![d, d]),
                    ("wv", vec![d, d]),
                    ("wo", vec![d, d]),
                    ("bo", vec![d]),
                    ("ln2_g", vec![d]),
                    ("w_gate", vec![f, d]),
                    ("w_up", vec![f, d]),
                    ("w_down", vec![d, f]),
                    ("b_down", vec![d]),
                ] {
                    params.push((format!("{p}{n}"), s));
                }
            }
        }
        params.push(("lnf_g".into(), vec![d]));
        if family == "opt" {
            params.push(("lnf_b".into(), vec![d]));
        }
        ModelSpec {
            name: format!("grad_{family}"),
            family: family.into(),
            d_model: d,
            n_heads: 2,
            n_layers: 2,
            d_ff: f,
            vocab: v,
            seq: t,
            batch: 2,
            params,
            layer_dims: Vec::new(),
        }
    }

    /// Directional-derivative check: a central finite difference of the
    /// loss along the (normalized) gradient direction must equal the
    /// gradient norm. Catches sign/structure errors in any sub-gradient.
    #[test]
    fn gradient_matches_finite_difference() {
        for fam in ["opt", "llama"] {
            let spec = tiny_spec(fam);
            let mut rng = Rng::new(11);
            let n = spec.n_params_elems();
            let packed: Vec<f32> = rng.normal_vec(n, 0.3);
            let w = Weights::from_packed(&spec, packed.clone()).unwrap();
            let toks = crate::tensor::IntTensor::new(
                vec![2, 5],
                (0..10).map(|_| rng.below(spec.vocab) as i32).collect(),
            );
            let tgts = crate::tensor::IntTensor::new(
                vec![2, 5],
                (0..10).map(|_| rng.below(spec.vocab) as i32).collect(),
            );
            let (loss, g) = loss_and_grad(&w, &toks, &tgts).unwrap();
            assert!(loss.is_finite() && loss > 0.0, "{fam}: loss {loss}");
            let gnorm = g.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            assert!(gnorm > 1e-6, "{fam}: zero gradient");

            // φ(ε) = loss(p + ε·g/|g|); φ'(0) must equal |g|
            let h = 1e-2f64;
            let eval = |eps: f64| -> f64 {
                let pp: Vec<f32> = packed
                    .iter()
                    .zip(&g.data)
                    .map(|(&p, &gv)| p + (eps * gv as f64 / gnorm) as f32)
                    .collect();
                let wp = Weights::from_packed(&spec, pp).unwrap();
                let (lp, _) = loss_and_grad(&wp, &toks, &tgts).unwrap();
                lp as f64
            };
            let fd = (eval(h) - eval(-h)) / (2.0 * h);
            let rel = (fd - gnorm).abs() / gnorm;
            assert!(
                rel < 0.05,
                "{fam}: directional fd {fd:.6} vs |g| {gnorm:.6} (rel {rel:.3})"
            );
        }
    }

    #[test]
    fn adam_step_reduces_loss_on_repeat() {
        let spec = tiny_spec("llama");
        let mut rng = Rng::new(3);
        let p = spec.n_params_elems();
        let mut state = vec![0.0f32; 3 * p];
        let init = rng.normal_vec(p, 0.2);
        state[..p].copy_from_slice(&init);
        let toks = crate::tensor::IntTensor::new(
            vec![2, 5],
            (0..10).map(|_| rng.below(spec.vocab) as i32).collect(),
        );
        let tgts = toks.clone();
        let mut first = None;
        let mut last = 0.0;
        for step in 0..30 {
            let (loss, ns) =
                train_step_host(&spec, &state, &toks, &tgts, (step + 1) as f32, 5e-2).unwrap();
            state = ns;
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(
            last < first.unwrap() - 0.2,
            "no learning: {} → {last}",
            first.unwrap()
        );
    }

    #[test]
    fn taylor_scores_shapes_and_signs() {
        let spec = tiny_spec("opt");
        let mut rng = Rng::new(9);
        let w = Weights::from_packed(&spec, rng.normal_vec(spec.n_params_elems(), 0.3)).unwrap();
        let toks = crate::tensor::IntTensor::new(
            vec![2, 5],
            (0..10).map(|_| rng.below(spec.vocab) as i32).collect(),
        );
        let (_, g) = loss_and_grad(&w, &toks, &toks).unwrap();
        let scores = taylor_scores(&w, &g).unwrap();
        assert_eq!(scores.len(), 2);
        for (ffn, ov) in &scores {
            assert_eq!(ffn.len(), spec.d_ff);
            assert_eq!(ov.len(), spec.d_model);
            assert!(ffn.iter().all(|x| x.is_finite() && *x >= 0.0));
            assert!(ov.iter().all(|x| x.is_finite() && *x >= 0.0));
        }
    }
}
