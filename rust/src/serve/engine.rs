//! The continuous-batching scheduler: admission queue → batched ticks
//! → retirement, all over ONE shared packed plan.
//!
//! Every scheduler **tick** runs one [`decode_step_paged`] over all
//! active sessions: each contributes exactly one token — the next
//! prompt token while it is still prefilling, its last sampled token
//! afterwards. Prefill is just decode fed one token per tick (the
//! repo's decode≡re-forward bit-identity contract makes the two
//! paths interchangeable), which is what makes the batching truly
//! *continuous*: a fresh session starts prefilling in the same batch
//! where older sessions are mid-generation, and a finished session
//! leaves the batch on the tick it completes — no tail-of-batch
//! stragglers, no prefill stalls.
//!
//! **Chunked prefill** ([`ServeConfig::prefill_chunk`]): a session
//! still deep in its prompt additionally feeds up to `prefill_chunk-1`
//! prompt tokens per tick through one logits-free chunked forward
//! ([`decode_chunk_paged`]) before its lane token — each weight panel
//! streams once for the whole chunk instead of once per token, so long
//! prompts prefill up to `prefill_chunk`× faster. The chunk writes
//! bitwise the same K/V as single steps (the chunk≡steps contract in
//! `model/decode.rs`), and prompt-position logits were always
//! discarded, so scheduler output is unchanged bit-for-bit —
//! `prefill_chunk = 1` *is* the old engine.
//!
//! Determinism receipt (locked by `rust/tests/test_serve.rs`): each
//! session's output is **bit-identical** to a per-session sequential
//! `generate` with the same prompt/sampler/seed, at every batch
//! composition, admission order, page size and pool width. Forward
//! rows are lane-independent (see [`decode_step_paged`]), and each
//! session samples from its own [`Rng::new(seed)`] stream, so batch
//! neighbors can never perturb a session's randomness.
//!
//! Memory safety-by-accounting: admission reserves the *worst-case*
//! page count of every active session (`prompt + max_new - 1`
//! positions), so the arena can never run out mid-generation — a
//! request that could never fit is rejected up front, and one that
//! merely has to wait stays queued (FIFO, head-of-line) until
//! retirements or prefix-cache evictions free enough pages.
//!
//! **Graceful degradation** (locked by `rust/tests/test_chaos.rs` and
//! the `fasp chaos` CLI): the engine degrades per session instead of
//! dying. A bounded admission queue ([`ServeConfig::queue_cap`]) sheds
//! excess requests deterministically from the back; per-request
//! deadlines count scheduler *ticks*, never wall clock
//! ([`ServeRequest::deadline_ticks`]), so expiry replays
//! bit-identically; a mid-step fault — a panicking pool worker, an
//! arena exhaustion, a failed shard load — is caught at the engine's
//! fault boundary ([`run_caught`]), rolled back
//! ([`PagedKv::rollback`]), retried up to [`ServeConfig::tick_retries`]
//! times, and finally turned into a per-session failed [`ServeOutput`]
//! (`error: Some(..)`). Surviving lanes finish **bit-identical** to the
//! fault-free run — forward rows are lane-independent and sampling is
//! per-session seeded, so a neighbor's death can't perturb anyone —
//! and the drain stays clean: zero leaked arena pages
//! ([`ServeReport::leaked_pages`]).

use super::prefix::PrefixCache;
use crate::model::decode::{decode_chunk_paged, decode_step_paged, sample_row, PagedLane, Sampler};
use crate::model::kv_arena::{KvArena, PagedKv};
use crate::model::weights::{PackedWeights, ParamSource};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::VecDeque;

/// One decode session submitted to the engine.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub prompt: Vec<i32>,
    /// Tokens to generate (>= 1).
    pub max_new: usize,
    pub sampler: Sampler,
    /// Seed of this session's own sampling [`Rng`] stream.
    pub seed: u64,
    /// Scheduler-tick budget: a session still unfinished after
    /// participating in this many batched ticks retires with a
    /// per-session deadline error (never wall clock — tick deadlines
    /// replay bit-identically). `usize::MAX` = no deadline.
    pub deadline_ticks: usize,
}

impl Default for ServeRequest {
    fn default() -> Self {
        ServeRequest {
            prompt: Vec::new(),
            max_new: 1,
            sampler: Sampler::Greedy,
            seed: 0,
            deadline_ticks: usize::MAX,
        }
    }
}

/// Engine shape knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Positions per KV arena page.
    pub page: usize,
    /// Total pages in the arena pool.
    pub n_pages: usize,
    /// Max sessions decoding in one batched tick.
    pub max_batch: usize,
    /// Share common prompt heads across sessions.
    pub prefix_cache: bool,
    /// Max prompt tokens a prefilling session consumes per tick (>= 1):
    /// `prefill_chunk - 1` via one chunked forward plus its lane token.
    /// 1 disables chunking and reproduces the token-per-tick engine
    /// exactly; any value yields bit-identical outputs.
    pub prefill_chunk: usize,
    /// Bound on the admission queue: excess requests shed
    /// deterministically from the back (newest first) with per-session
    /// shed errors before any forward work. `usize::MAX` = unbounded.
    pub queue_cap: usize,
    /// Retries a batched step gets after an absorbed mid-step fault
    /// (pool worker panic) before the step's sessions retire with
    /// per-session errors.
    pub tick_retries: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            page: 16,
            n_pages: 256,
            max_batch: 8,
            prefix_cache: true,
            prefill_chunk: 4,
            queue_cap: usize::MAX,
            tick_retries: 2,
        }
    }
}

/// One finished session.
#[derive(Clone, Debug)]
pub struct ServeOutput {
    /// Index of the originating request.
    pub id: usize,
    /// Prompt + sampled continuation — the exact layout one row of
    /// `generate`'s output uses. For a failed session: the prompt plus
    /// whatever was generated before the fault.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub generated: usize,
    /// Prompt positions adopted from the prefix cache (0 on a miss).
    pub prefix_hit_positions: usize,
    /// `Some(reason)` when the session failed (shed, deadline, or an
    /// unabsorbed fault) instead of completing. A failed session never
    /// fails the batch: surviving lanes finish bit-identically to a
    /// fault-free run.
    pub error: Option<String>,
}

/// What a full drive of the engine produced, with the throughput /
/// latency / residency receipts `BENCH_serve.json` records.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Outputs ordered by request id.
    pub outputs: Vec<ServeOutput>,
    /// Batched steps executed.
    pub ticks: usize,
    pub wall_s: f64,
    /// Sampled (non-prompt) tokens across all sessions.
    pub generated_tokens: usize,
    pub tokens_per_s: f64,
    /// Per-token latency percentiles: each sampled token is attributed
    /// the wall-time of the tick that produced it.
    pub p50_token_s: f64,
    pub p99_token_s: f64,
    /// Largest batch any tick ran.
    pub max_batch_seen: usize,
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    pub prefix_insertions: u64,
    pub prefix_evictions: u64,
    /// Arena residency high-water mark, pages.
    pub peak_pages: usize,
    /// Bytes of one arena page (all layers).
    pub page_bytes: usize,
    /// Allocated bytes of the whole arena pool.
    pub kv_bytes: usize,
    /// Sessions that retired with an error (shed + deadline + faulted).
    pub failed_sessions: usize,
    /// Sessions shed by the bounded admission queue.
    pub shed_sessions: usize,
    /// Sessions that hit their tick deadline.
    pub deadline_failures: usize,
    /// Step retries taken after absorbed mid-step faults.
    pub tick_retries: usize,
    /// Arena pages still resident after drain — always 0 unless the
    /// engine leaked (the chaos receipt).
    pub leaked_pages: usize,
}

/// A session resident in the running batch.
struct Active {
    id: usize,
    prompt: Vec<i32>,
    max_new: usize,
    sampler: Sampler,
    rng: Rng,
    kv: PagedKv,
    /// Prompt tokens consumed so far (starts past a prefix-cache hit).
    fed: usize,
    /// Last sampled token, waiting to be fed next tick.
    pending: Option<i32>,
    out: Vec<i32>,
    /// Worst-case page table length — the admission reservation.
    pages_total: usize,
    prefix_hit_positions: usize,
    inserted: bool,
    /// Batched ticks this session has participated in.
    age_ticks: usize,
    deadline_ticks: usize,
}

/// Drive every request to completion over `model`'s shared packed plan
/// and return the outputs plus throughput/latency/residency receipts.
/// Self-contained (builds its own arena + prefix cache); enter a
/// backend scope first to pick the worker pool — `Session::serve`
/// does exactly that.
pub fn serve(
    model: &PackedWeights,
    requests: &[ServeRequest],
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let spec = &model.w.spec;
    anyhow::ensure!(cfg.max_batch >= 1, "serve wants max_batch >= 1");
    anyhow::ensure!(cfg.prefill_chunk >= 1, "serve wants prefill_chunk >= 1");
    let mut arena = KvArena::for_spec(spec, cfg.n_pages, cfg.page)?;
    let mut prefix = PrefixCache::new(cfg.page);
    let is_opt = spec.family == "opt";

    // ---- submit-time validation: reject unservable requests before
    // any forward work (the mid-flight arena/KV asserts stay as
    // last-resort invariants)
    for (id, r) in requests.iter().enumerate() {
        anyhow::ensure!(!r.prompt.is_empty(), "serve request {id}: empty prompt");
        anyhow::ensure!(r.max_new >= 1, "serve request {id}: max_new must be >= 1");
        for &t in &r.prompt {
            anyhow::ensure!(
                t >= 0 && (t as usize) < spec.vocab,
                "serve request {id}: token id {t} outside vocab {}",
                spec.vocab
            );
        }
        let need = r.prompt.len() + r.max_new - 1;
        let pages_total = arena.pages_for(need);
        anyhow::ensure!(
            pages_total <= cfg.n_pages,
            "serve request {id}: prompt {} + max_new {} needs {pages_total} \
             pages but the arena only has {} — rejected before any forward work",
            r.prompt.len(),
            r.max_new,
            cfg.n_pages
        );
        if is_opt {
            anyhow::ensure!(
                need <= spec.seq,
                "serve request {id}: prompt {} + max_new {} exceeds the {} \
                 learned positions of OPT model '{}'",
                r.prompt.len(),
                r.max_new,
                spec.seq,
                spec.name
            );
        }
    }

    let mut queue: VecDeque<usize> = (0..requests.len()).collect();
    let mut active: Vec<Active> = Vec::new();
    let mut outputs: Vec<Option<ServeOutput>> = (0..requests.len()).map(|_| None).collect();
    let mut token_s: Vec<f64> = Vec::new();
    let mut ticks = 0usize;
    let mut max_batch_seen = 0usize;
    let mut failed_sessions = 0usize;
    let mut shed_sessions = 0usize;
    let mut deadline_failures = 0usize;
    let mut tick_retries_total = 0usize;
    let mut src = model.source();

    // ---- bounded admission: shed the newest requests over the queue
    // cap deterministically, before any forward work
    while queue.len() > cfg.queue_cap {
        let Some(rid) = queue.pop_back() else { break };
        shed_sessions += 1;
        failed_sessions += 1;
        let r = &requests[rid];
        outputs[rid] = Some(ServeOutput {
            id: rid,
            tokens: r.prompt.clone(),
            prompt_len: r.prompt.len(),
            generated: 0,
            prefix_hit_positions: 0,
            error: Some(format!(
                "shed: admission queue over capacity {}",
                cfg.queue_cap
            )),
        });
    }

    let wall = std::time::Instant::now();
    'sched: loop {
        // ---- admission (FIFO, every tick — token-granularity joins)
        while active.len() < cfg.max_batch && !queue.is_empty() {
            let rid = queue[0];
            let r = &requests[rid];
            let t_prompt = r.prompt.len();
            let pages_total = arena.pages_for(t_prompt + r.max_new - 1);
            // Share full prompt-head pages, but never the final prompt
            // position: its forward produces the first sampling logits,
            // so every session runs at least one tick.
            let hit = if cfg.prefix_cache {
                prefix.lookup(&r.prompt, t_prompt - 1)
            } else {
                None
            };
            let have_pages = hit.as_ref().map(|(_, pages)| pages.len()).unwrap_or(0);
            let reserved: usize = active
                .iter()
                .map(|s| s.pages_total - s.kv.pages().len())
                .sum();
            if arena.free_pages() < reserved + (pages_total - have_pages) {
                // Starved: shed cold prefix pins, else wait for a
                // retirement. Head-of-line blocking keeps admission
                // deterministic.
                if cfg.prefix_cache && prefix.evict_one(&mut arena) {
                    continue;
                }
                break;
            }
            queue.pop_front();
            let (fed, kv) = match hit {
                Some((positions, pages)) => (positions, arena.share(&pages, positions)),
                None => (0, PagedKv::new()),
            };
            active.push(Active {
                id: rid,
                prompt: r.prompt.clone(),
                max_new: r.max_new,
                sampler: r.sampler,
                rng: Rng::new(r.seed),
                kv,
                fed,
                pending: None,
                out: Vec::new(),
                pages_total,
                prefix_hit_positions: fed,
                inserted: false,
                age_ticks: 0,
                deadline_ticks: r.deadline_ticks,
            });
        }
        if active.is_empty() {
            if queue.is_empty() {
                break;
            }
            // unreachable: an empty batch frees every session page, and
            // draining the prefix cache frees the rest, so a validated
            // request always admits eventually
            anyhow::bail!(
                "serve admission wedged with {} queued requests and an empty batch",
                queue.len()
            );
        }

        // ---- tick deadlines: a session over its budget retires with a
        // per-session error; its pages free immediately for the queue
        let mut i = 0;
        while i < active.len() {
            if active[i].age_ticks >= active[i].deadline_ticks {
                let s = active.remove(i);
                deadline_failures += 1;
                failed_sessions += 1;
                let reason = format!(
                    "deadline exceeded: {} ticks (limit {})",
                    s.age_ticks, s.deadline_ticks
                );
                fail_active(&mut arena, &mut outputs, s, reason);
            } else {
                i += 1;
            }
        }
        if active.is_empty() {
            continue 'sched; // freed slots go back through admission
        }
        max_batch_seen = max_batch_seen.max(active.len());

        // ---- chunked prefill: sessions still >= 2 tokens from the end
        // of their prompt bulk-feed prompt tokens (never the final one —
        // its forward produces the first sampling logits and stays on
        // the lane path) before contributing their lane token below.
        // Admission already reserved every page this can grow into.
        // The chunk runs one session at a time, so a chunk fault is
        // per-session by construction: an `Err` (e.g. injected arena
        // exhaustion) retires that session; a caught panic rolls its
        // cache back and retries before retiring it. Neighbors never
        // notice either way.
        if cfg.prefill_chunk > 1 {
            let mut i = 0;
            while i < active.len() {
                let t_prompt = active[i].prompt.len();
                if active[i].fed + 1 >= t_prompt {
                    i += 1;
                    continue;
                }
                let c = (cfg.prefill_chunk - 1).min(t_prompt - 1 - active[i].fed);
                let len0 = active[i].kv.len();
                let mut attempt = 0usize;
                let fate: Option<String> = loop {
                    src.rewind()?;
                    let s = &mut active[i];
                    let (kv, prompt, fed) = (&mut s.kv, &s.prompt, s.fed);
                    match run_caught(|| {
                        decode_chunk_paged(&mut src, &mut arena, kv, &prompt[fed..fed + c])
                    }) {
                        TickFate::Done(()) => break None,
                        TickFate::Failed(e) => break Some(format!("prefill fault: {e:#}")),
                        TickFate::Panicked(m) => {
                            active[i].kv.rollback(len0);
                            if attempt < cfg.tick_retries {
                                attempt += 1;
                                tick_retries_total += 1;
                                continue;
                            }
                            break Some(format!(
                                "prefill fault after {attempt} retries: {m}"
                            ));
                        }
                    }
                };
                match fate {
                    None => {
                        active[i].fed += c;
                        i += 1;
                    }
                    Some(reason) => {
                        let s = active.remove(i);
                        failed_sessions += 1;
                        fail_active(&mut arena, &mut outputs, s, reason);
                    }
                }
            }
            if active.is_empty() {
                continue 'sched;
            }
        }

        // ---- per-lane pre-grow: allocate this tick's page (if any)
        // lane by lane, so arena exhaustion — real or injected — is
        // attributable to exactly one session and retires only it.
        // After this, every grow inside the step is covered and cannot
        // allocate, so no mid-step fan-out can see an arena fault.
        let mut i = 0;
        while i < active.len() {
            let need = active[i].kv.len() + 1;
            match arena.grow(&mut active[i].kv, need) {
                Ok(()) => i += 1,
                Err(e) => {
                    let s = active.remove(i);
                    failed_sessions += 1;
                    fail_active(&mut arena, &mut outputs, s, format!("kv page fault: {e:#}"));
                }
            }
        }
        if active.is_empty() {
            continue 'sched;
        }

        // ---- one batched step: every active session advances one token
        ticks += 1;
        let t_tick = std::time::Instant::now();
        {
            // Snapshot every lane's write cursor: a caught mid-step
            // fault (pool worker panic) rolls all lanes back to it and
            // the step retries — the retried step rewrites the same
            // slots with the same deterministic kernels, so an absorbed
            // fault leaves outputs bit-identical to a fault-free run.
            let len0: Vec<usize> = active.iter().map(|s| s.kv.len()).collect();
            let mut attempt = 0usize;
            let logits = loop {
                src.rewind()?;
                let msg: String;
                {
                    let mut lanes: Vec<PagedLane<'_>> = Vec::with_capacity(active.len());
                    for s in active.iter_mut() {
                        let token = next_token(s.fed, &s.prompt, s.pending, s.id)?;
                        lanes.push(PagedLane { kv: &mut s.kv, token });
                    }
                    match run_caught(|| decode_step_paged(&mut src, &mut arena, &mut lanes)) {
                        TickFate::Done(l) => break l,
                        TickFate::Failed(e) => msg = format!("{e:#}"),
                        TickFate::Panicked(m) => msg = m,
                    }
                }
                for (s, &l0) in active.iter_mut().zip(&len0) {
                    s.kv.rollback(l0);
                }
                if attempt < cfg.tick_retries {
                    attempt += 1;
                    tick_retries_total += 1;
                    continue;
                }
                // retries exhausted: the step's sessions retire with
                // per-session errors — the engine itself keeps running
                failed_sessions += active.len();
                for s in active.drain(..) {
                    fail_active(
                        &mut arena,
                        &mut outputs,
                        s,
                        format!("tick fault after {attempt} retries: {msg}"),
                    );
                }
                continue 'sched;
            };
            let dt = t_tick.elapsed().as_secs_f64();

            // ---- per-session bookkeeping + sampling
            let mut sampled = 0usize;
            let mut retired: Vec<usize> = Vec::new();
            for (i, s) in active.iter_mut().enumerate() {
                s.age_ticks += 1;
                let t_prompt = s.prompt.len();
                let pos = s.kv.len() - 1; // the position this tick processed
                if s.fed < t_prompt {
                    s.fed += 1;
                    if s.fed == t_prompt && cfg.prefix_cache && !s.inserted {
                        // prompt fully resident: pin its full pages for
                        // future sessions with the same head
                        s.inserted = true;
                        prefix.insert(&mut arena, &s.prompt, s.kv.pages());
                    }
                } else {
                    s.pending = None;
                }
                if pos + 1 >= t_prompt {
                    let tok = sample_row(logits.row(i), s.sampler, &mut s.rng) as i32;
                    s.out.push(tok);
                    sampled += 1;
                    if s.out.len() == s.max_new {
                        retired.push(i); // final token is never fed back
                    } else {
                        s.pending = Some(tok);
                    }
                }
            }
            for _ in 0..sampled {
                token_s.push(dt);
            }
            // ---- retirement: leave the batch on the completing tick
            for &i in retired.iter().rev() {
                let mut s = active.remove(i);
                arena.release(&mut s.kv);
                let mut tokens = s.prompt.clone();
                tokens.extend_from_slice(&s.out);
                outputs[s.id] = Some(ServeOutput {
                    id: s.id,
                    tokens,
                    prompt_len: s.prompt.len(),
                    generated: s.out.len(),
                    prefix_hit_positions: s.prefix_hit_positions,
                    error: None,
                });
            }
        }
    }
    let wall_s = wall.elapsed().as_secs_f64();

    // teardown: drop the prefix pins; every page must come home — even
    // after shed/deadline/faulted retirements (the chaos receipt)
    prefix.clear(&mut arena);
    let leaked_pages = arena.used_pages();
    debug_assert_eq!(leaked_pages, 0, "serve leaked arena pages");

    // total_cmp: no panic path even if a tick duration came out NaN
    // (it can't — but R1 bans the expect, and total order is free).
    token_s.sort_by(|a, b| a.total_cmp(b));
    let pct = |q: f64| -> f64 {
        if token_s.is_empty() {
            return 0.0;
        }
        token_s[((token_s.len() - 1) as f64 * q).round() as usize]
    };
    let generated_tokens = token_s.len();
    Ok(ServeReport {
        outputs: collect_outputs(outputs)?,
        ticks,
        wall_s,
        generated_tokens,
        tokens_per_s: generated_tokens as f64 / wall_s.max(1e-12),
        p50_token_s: pct(0.50),
        p99_token_s: pct(0.99),
        max_batch_seen,
        prefix_hits: prefix.hits,
        prefix_misses: prefix.misses,
        prefix_insertions: prefix.insertions,
        prefix_evictions: prefix.evictions,
        peak_pages: arena.peak_pages(),
        page_bytes: arena.page_bytes(),
        kv_bytes: arena.kv_bytes(),
        failed_sessions,
        shed_sessions,
        deadline_failures,
        tick_retries: tick_retries_total,
        leaked_pages,
    })
}

/// What one guarded engine step came to: a value, a proper `Err`, or a
/// panic caught at the engine's fault boundary.
enum TickFate<T> {
    Done(T),
    Failed(anyhow::Error),
    Panicked(String),
}

/// Run one engine step with both failure channels absorbed: `Err`s pass
/// through as [`TickFate::Failed`], and a panic a pool worker re-raised
/// (see `util/pool.rs::join_all`) is caught as [`TickFate::Panicked`]
/// instead of killing the process. `AssertUnwindSafe` is sound here
/// because every caller either rolls the touched lanes back to a
/// pre-step snapshot (retry) or retires them (release + error output) —
/// no state survives a caught panic unreconciled.
fn run_caught<T>(f: impl FnOnce() -> Result<T>) -> TickFate<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(Ok(v)) => TickFate::Done(v),
        Ok(Err(e)) => TickFate::Failed(e),
        Err(p) => TickFate::Panicked(panic_text(&p)),
    }
}

/// Best-effort text of a caught panic payload.
fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Retire a faulted/shed/expired session: release its pages back to the
/// arena and record a failed [`ServeOutput`] (prompt + whatever was
/// generated pre-fault, `error: Some(reason)`) in its slot. The batch
/// and its surviving lanes never see the fault.
fn fail_active(
    arena: &mut KvArena,
    outputs: &mut [Option<ServeOutput>],
    mut s: Active,
    reason: String,
) {
    arena.release(&mut s.kv);
    let prompt_len = s.prompt.len();
    let mut tokens = std::mem::take(&mut s.prompt);
    tokens.extend_from_slice(&s.out);
    outputs[s.id] = Some(ServeOutput {
        id: s.id,
        tokens,
        prompt_len,
        generated: s.out.len(),
        prefix_hit_positions: s.prefix_hit_positions,
        error: Some(reason),
    });
}

/// The token a session contributes to this tick: the next unfed
/// prompt token while prefilling, its pending sampled token after.
/// An active session with neither is a scheduler invariant violation
/// — surfaced as an `Err` (one bad session must never panic the
/// engine; R1).
fn next_token(fed: usize, prompt: &[i32], pending: Option<i32>, id: usize) -> Result<i32> {
    if fed < prompt.len() {
        return Ok(prompt[fed]);
    }
    pending.ok_or_else(|| {
        anyhow::anyhow!(
            "serve tick: active session {id} has neither unfed prompt \
             tokens (fed {fed} of {}) nor a pending sampled token",
            prompt.len()
        )
    })
}

/// Final assembly of the per-request output slots. Every slot must be
/// filled by retirement before the loop exits; a hole means the
/// scheduler dropped a session — reported as an `Err` with the
/// offending request ids instead of a panic (R1).
fn collect_outputs(outputs: Vec<Option<ServeOutput>>) -> Result<Vec<ServeOutput>> {
    let missing: Vec<usize> = outputs
        .iter()
        .enumerate()
        .filter(|(_, o)| o.is_none())
        .map(|(i, _)| i)
        .collect();
    anyhow::ensure!(
        missing.is_empty(),
        "serve finished with incomplete session(s) {missing:?} — scheduler bug"
    );
    Ok(outputs.into_iter().flatten().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Regression tests for the R1 conversions: the request-path
    // invariant violations that used to be `expect(...)` panics must
    // now surface as proper `Err`s.

    #[test]
    fn next_token_prefers_prompt_then_pending() {
        assert_eq!(next_token(0, &[7, 8], None, 0).unwrap(), 7);
        assert_eq!(next_token(1, &[7, 8], Some(99), 0).unwrap(), 8);
        assert_eq!(next_token(2, &[7, 8], Some(99), 0).unwrap(), 99);
    }

    #[test]
    fn next_token_without_prompt_or_pending_is_err_not_panic() {
        let err = next_token(2, &[7, 8], None, 5).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("session 5"), "{msg}");
        assert!(msg.contains("pending"), "{msg}");
    }

    #[test]
    fn collect_outputs_reports_missing_slots_as_err_not_panic() {
        let full = ServeOutput {
            id: 0,
            tokens: vec![1, 2],
            prompt_len: 1,
            generated: 1,
            prefix_hit_positions: 0,
            error: None,
        };
        let ok = collect_outputs(vec![Some(full.clone())]).unwrap();
        assert_eq!(ok.len(), 1);

        let err = collect_outputs(vec![Some(full), None]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("[1]"), "{msg}");
        assert!(msg.contains("incomplete"), "{msg}");
    }
}
