//! The serve subsystem: continuous-batching decode over one shared
//! packed plan — FASP's deployment payoff made concrete. Many
//! independent decode sessions (own prompt, sampler, seed) are driven
//! through an admission queue, a paged KV arena
//! (`crate::model::kv_arena`) and a batched scheduler
//! ([`engine::serve`]) that interleaves prompt prefill with
//! mid-generation decode at token granularity, plus a token-hash
//! prefix cache ([`prefix`]) sharing common prompt heads zero-copy.
//!
//! The hard receipt (locked by `rust/tests/test_serve.rs`, recorded by
//! `BENCH_serve.json`): every session's output is **bit-identical** to
//! a per-session sequential `generate`, while batched throughput beats
//! N sequential calls — the batch reads each packed weight panel once
//! per tick for all lanes instead of once per session per token.

pub mod engine;
pub mod prefix;

pub use engine::{serve, ServeConfig, ServeOutput, ServeReport, ServeRequest};
