//! Prefix cache: token-hash-keyed sharing of common prompt heads.
//!
//! When a served session finishes feeding its prompt, the K/V rows of
//! the prompt's *full* pages are immutable forever (causal attention
//! only ever reads them). The cache pins those pages (one extra
//! refcount in the [`KvArena`]) under an FNV-1a hash of the exact
//! token prefix; a later session whose prompt starts with the same
//! tokens adopts the pages zero-copy and skips that much prefill.
//!
//! Correctness:
//! * only **full** pages are shared — a partially written page could
//!   still be appended to by its owner;
//! * a hit never covers the final prompt position — that position's
//!   forward produces the first sampling logits, so it always
//!   recomputes (the adopted rows are bitwise what a cold prefill
//!   would write, locked by `rust/tests/test_serve.rs`);
//! * entries store their exact tokens, so a hash collision degrades to
//!   a miss instead of serving the wrong prefix;
//! * eviction (when admission is starved for pages) is deterministic:
//!   fewest hits first, ties by key. Evicting only drops the cache's
//!   refcount — sessions still reading the pages keep them resident.

use crate::model::kv_arena::KvArena;
use std::collections::BTreeMap;

/// FNV-1a over the little-endian bytes of the token ids.
fn token_hash(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for b in (t as u32).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

struct Entry {
    /// Exact prefix tokens (collision guard).
    tokens: Vec<i32>,
    /// The full pages holding positions `0..tokens.len()`.
    pages: Vec<usize>,
    hits: u64,
}

pub(crate) struct PrefixCache {
    /// Positions per arena page.
    page: usize,
    entries: BTreeMap<u64, Entry>,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl PrefixCache {
    pub fn new(page: usize) -> PrefixCache {
        PrefixCache {
            page,
            entries: BTreeMap::new(),
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Longest cached full-page head of `prompt` covering at most
    /// `max_positions` positions: `(positions, pages)`. Counts one hit
    /// or one miss per call.
    pub fn lookup(&mut self, prompt: &[i32], max_positions: usize) -> Option<(usize, Vec<usize>)> {
        let max_pages = max_positions.min(prompt.len()) / self.page;
        for j in (1..=max_pages).rev() {
            let pfx = &prompt[..j * self.page];
            if let Some(e) = self.entries.get_mut(&token_hash(pfx)) {
                if e.tokens == pfx {
                    e.hits += 1;
                    self.hits += 1;
                    return Some((pfx.len(), e.pages.clone()));
                }
            }
        }
        self.misses += 1;
        None
    }

    /// Pin `prompt`'s full-page head, already resident as the leading
    /// pages of `pages` (a session that just finished its prefill).
    /// No-op when the head is shorter than one page, already cached, or
    /// hash-collides with a different cached prefix.
    pub fn insert(&mut self, arena: &mut KvArena, prompt: &[i32], pages: &[usize]) {
        let j = prompt.len() / self.page;
        if j == 0 {
            return;
        }
        let pfx = &prompt[..j * self.page];
        let h = token_hash(pfx);
        if self.entries.contains_key(&h) {
            return; // cached already (or a collision: keep the incumbent)
        }
        arena.retain_pages(&pages[..j]);
        self.entries.insert(
            h,
            Entry { tokens: pfx.to_vec(), pages: pages[..j].to_vec(), hits: 0 },
        );
        self.insertions += 1;
    }

    /// Evict the coldest entry (fewest hits, ties by ascending key).
    /// Returns false when the cache is empty.
    pub fn evict_one(&mut self, arena: &mut KvArena) -> bool {
        let victim = self
            .entries
            .iter()
            .min_by_key(|&(k, e)| (e.hits, *k))
            .map(|(&k, _)| k);
        // R1: no panic paths in serve code — a victim key that has
        // somehow vanished (impossible: it was just read from this
        // map under &mut self) degrades to "nothing evicted" instead
        // of killing the engine.
        match victim.and_then(|k| self.entries.remove(&k)) {
            Some(e) => {
                arena.release_pages(&e.pages);
                self.evictions += 1;
                true
            }
            None => false,
        }
    }

    /// Drop every pin (serve teardown — the arena must end fully free).
    pub fn clear(&mut self, arena: &mut KvArena) {
        for (_, e) in std::mem::take(&mut self.entries) {
            arena.release_pages(&e.pages);
        }
    }
}
