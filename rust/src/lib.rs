//! # FASP — Fast and Accurate Structured Pruning of Large Language Models
//!
//! Full-system reproduction of the FASP paper on a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: pruning pipeline, calibration
//!   batching, restoration solver, model zoo, trainer, evaluation harness
//!   and experiment registry. Python is never on this path.
//! * **L2** — JAX model definitions (`python/compile/`), AOT-lowered once
//!   to HLO-text artifacts consumed through [`runtime`].
//! * **L1** — Pallas kernels (Gram accumulation, Wanda column metric,
//!   tiled matmul) embedded in the L2 entries.
//!
//! See `DESIGN.md` for the system inventory and the experiment index
//! mapping every paper table/figure to a module, and `EXPERIMENTS.md` for
//! measured results.

pub mod util;
pub mod tensor;
pub mod linalg;
pub mod runtime;
pub mod model;
pub mod data;
pub mod train;
pub mod prune;
pub mod fault;
pub mod serve;
pub mod eval;
pub mod bench_support;
pub mod experiments;
pub mod analysis;
pub mod cli;

/// Crate-wide result alias (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;

/// Repository root discovery: honors `FASP_ROOT`, else walks up from the
/// current directory looking for `Cargo.toml`/`artifacts`.
pub fn repo_root() -> std::path::PathBuf {
    if let Ok(r) = std::env::var("FASP_ROOT") {
        return std::path::PathBuf::from(r);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        if dir.join("Cargo.toml").exists() || dir.join("artifacts").exists() {
            return dir;
        }
        if !dir.pop() {
            return ".".into();
        }
    }
}

/// Default artifacts directory.
pub fn artifacts_dir() -> std::path::PathBuf {
    repo_root().join("artifacts")
}

/// Default checkpoints directory (created on demand).
pub fn checkpoints_dir() -> std::path::PathBuf {
    let d = repo_root().join("checkpoints");
    let _ = std::fs::create_dir_all(&d);
    d
}
