//! Cyclic Jacobi eigendecomposition for symmetric matrices.
//!
//! Powers the SliceGPT-like baseline: PCA of activation covariances
//! (Gram matrices from calibration capture) yields the rotation whose
//! trailing principal directions are sliced. The paper criticizes
//! SliceGPT for needing 64-bit PCA on large calibration sets — running it
//! here on the same Gram matrices makes the cost comparison direct
//! (Table 4 analog).

/// Eigendecomposition A = V · diag(w) · Vᵀ of a symmetric matrix
/// (row-major n×n, f64). Returns (eigenvalues ascending, V column-major
/// by eigenvector: v[k*n..][..n] is the k-th eigenvector).
pub fn jacobi_eigh(a_in: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a_in.len(), n * n);
    let mut a = a_in.to_vec();
    // v starts as identity; rows are eigenvectors at the end
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius mass
        let mut off = 0.0f64;
        for i in 0..n {
            for j in i + 1..n {
                off += a[i * n + j] * a[i * n + j];
            }
        }
        if off.sqrt() < 1e-11 * (1.0 + frob(&a, n)) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q of A
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                // accumulate rotations into V (rows = eigenvectors)
                for k in 0..n {
                    let vpk = v[p * n + k];
                    let vqk = v[q * n + k];
                    v[p * n + k] = c * vpk - s * vqk;
                    v[q * n + k] = s * vpk + c * vqk;
                }
            }
        }
    }
    let mut w: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    // sort ascending, permuting eigenvectors accordingly
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&x, &y| w[x].partial_cmp(&w[y]).unwrap());
    let w_sorted: Vec<f64> = idx.iter().map(|&i| w[i]).collect();
    let mut v_sorted = vec![0.0f64; n * n];
    for (k, &i) in idx.iter().enumerate() {
        v_sorted[k * n..(k + 1) * n].copy_from_slice(&v[i * n..(i + 1) * n]);
    }
    w = w_sorted;
    (w, v_sorted)
}

fn frob(a: &[f64], n: usize) -> f64 {
    a.iter().take(n * n).map(|x| x * x).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_matrix() {
        let a = vec![3.0, 0.0, 0.0, 1.0];
        let (w, _v) = jacobi_eigh(&a, 2);
        assert!((w[0] - 1.0).abs() < 1e-10);
        assert!((w[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn reconstructs_random_symmetric() {
        let mut rng = Rng::new(0);
        let n = 24;
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let x = rng.normal();
                a[i * n + j] = x;
                a[j * n + i] = x;
            }
        }
        let (w, v) = jacobi_eigh(&a, n);
        // check A ≈ Σ_k w_k v_k v_kᵀ and orthonormality
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                let mut dot = 0.0;
                for k in 0..n {
                    s += w[k] * v[k * n + i] * v[k * n + j];
                    dot += v[i * n + k] * v[j * n + k];
                }
                assert!((s - a[i * n + j]).abs() < 1e-7, "recon ({i},{j})");
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-8, "ortho ({i},{j})");
            }
        }
        // ascending order
        for k in 1..n {
            assert!(w[k] >= w[k - 1]);
        }
    }
}
