//! Cholesky factorization and positive-definite solves.
//!
//! The FASP restoration (paper Eq. 8) is
//! `W*_{:,M} = W·G·Π_Mᵀ (Π_M G Π_Mᵀ + δI)⁻¹` with `G = X Xᵀ` — one
//! factorization of the kept-index Gram block per pruned operator, then a
//! triangular solve per output row. This module does both in f64 for
//! numerical headroom (the Gram matrices are sums of many rank-1 terms and
//! can be ill-conditioned at high sparsity).

use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor L with A = L·Lᵀ, stored row-major n×n
/// (strict upper triangle zeroed).
pub struct CholeskyFactor {
    pub n: usize,
    pub l: Vec<f64>,
}

/// Factor a symmetric positive-definite matrix (row-major, f64).
/// Fails if a pivot drops below `1e-12`.
pub fn cholesky(a: &[f64], n: usize) -> Result<CholeskyFactor> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        // split_at_mut so row i and earlier rows coexist; the inner
        // accumulation is a contiguous f64 dot (vectorizes — §Perf iter 2)
        let (head, tail) = l.split_at_mut(i * n);
        let li = &mut tail[..n];
        for j in 0..i {
            let lj = &head[j * n..j * n + j];
            let s = a[i * n + j] - dot64(&li[..j], lj);
            li[j] = s / head[j * n + j];
        }
        let s = a[i * n + i] - dot64(&li[..i], &li[..i]);
        if s <= 1e-12 {
            bail!("cholesky: non-positive pivot {s:.3e} at {i}");
        }
        li[i] = s.sqrt();
    }
    Ok(CholeskyFactor { n, l })
}

/// Unrolled f64 dot product (4 independent accumulators → SIMD lanes).
#[inline]
fn dot64(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        for l in 0..4 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

impl CholeskyFactor {
    /// Solve A x = b in place (forward then backward substitution).
    /// Forward pass uses contiguous row dots; the backward pass is
    /// reformulated column-wise (axpy) so it also streams contiguous
    /// memory (§Perf iter 2).
    pub fn solve_in_place(&self, b: &mut [f64]) {
        let n = self.n;
        assert_eq!(b.len(), n);
        // L y = b  — row dot, contiguous
        for i in 0..n {
            let s = b[i] - dot64(&self.l[i * n..i * n + i], &b[..i]);
            b[i] = s / self.l[i * n + i];
        }
        // Lᵀ x = y — column access on L == row access with axpy:
        // for i from n-1 down: x_i = y_i / l_ii, then subtract x_i·L[i, :i]
        // from the remaining prefix of y.
        for i in (0..n).rev() {
            let xi = b[i] / self.l[i * n + i];
            b[i] = xi;
            let row = &self.l[i * n..i * n + i];
            for (bk, lk) in b[..i].iter_mut().zip(row) {
                *bk -= xi * lk;
            }
        }
    }
}

/// Solve A X = B for m right-hand sides given row-major B (m×n, each ROW
/// is a right-hand side — i.e. solves Xᵀ A = B row-wise, which is the
/// restoration orientation: each output row of W* is an independent RHS).
/// Returns X with the same layout.
pub fn solve_posdef_many(a: &[f64], n: usize, b_rows: &mut [f64]) -> Result<()> {
    let f = cholesky(a, n)?;
    assert_eq!(b_rows.len() % n, 0);
    for row in b_rows.chunks_exact_mut(n) {
        f.solve_in_place(row);
    }
    Ok(())
}

/// Solve A x = b for a single RHS.
pub fn solve_posdef(a: &[f64], n: usize, b: &[f64]) -> Result<Vec<f64>> {
    let f = cholesky(a, n)?;
    let mut x = b.to_vec();
    f.solve_in_place(&mut x);
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Vec<f64> {
        // A = Mᵀ M + n·I
        let m: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += m[k * n + i] * m[k * n + j];
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn solve_recovers_known_x() {
        let mut rng = Rng::new(0);
        for &n in &[1usize, 2, 5, 16, 64] {
            let a = random_spd(n, &mut rng);
            let x_true: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5 - 1.0).collect();
            // b = A x
            let b: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| a[i * n + j] * x_true[j]).sum())
                .collect();
            let x = solve_posdef(&a, n, &b).unwrap();
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-8, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Rng::new(1);
        let n = 12;
        let a = random_spd(n, &mut rng);
        let f = cholesky(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += f.l[i * n + k] * f.l[j * n + k];
                }
                assert!((s - a[i * n + j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        // [-1] is not PD
        assert!(cholesky(&[-1.0], 1).is_err());
        // saddle
        let a = vec![1.0, 2.0, 2.0, 1.0];
        assert!(cholesky(&a, 2).is_err());
    }
}
