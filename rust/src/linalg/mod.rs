//! Dense linear algebra substrate. The xla_extension 0.5.1 runtime cannot
//! execute jax's LAPACK FFI custom calls, so every dense solve lives here
//! on the host (DESIGN.md §7-L2):
//!
//! * [`cholesky`] / [`solve_posdef`] — the FASP restoration normal
//!   equation (paper Eq. 8).
//! * [`jacobi_eigh`] — symmetric eigendecomposition for the
//!   SliceGPT-like PCA baseline.
//! * [`admm`] — the NASLLM-style ADMM restorer baseline (paper §3.3
//!   discussion), kept to measure the efficiency/accuracy trade-off
//!   the paper argues about.

pub mod cholesky;
pub mod eigh;
pub mod admm;

pub use admm::admm_restore;
pub use cholesky::{cholesky, solve_posdef, solve_posdef_many, CholeskyFactor};
pub use eigh::jacobi_eigh;
