//! ADMM restoration baseline (the NASLLM approach the paper argues
//! against in §3.3).
//!
//! Solves the same masked least-squares problem as FASP's closed form —
//! `min ‖W' X − W X‖²  s.t.  W'[:, pruned] = 0` — but by ADMM splitting
//! `W' = Z` with the column-support constraint on `Z`:
//!
//! ```text
//! W_{k+1} = (W G + ρ (Z_k − U_k)) (G + ρI)⁻¹
//! Z_{k+1} = Π_M (W_{k+1} + U_k)        (project: zero pruned columns)
//! U_{k+1} = U_k + W_{k+1} − Z_{k+1}
//! ```
//!
//! As the paper notes, the `(G + ρI)⁻¹` factorization already costs as
//! much as FASP's single solve, and the iterations converge slowly near
//! the optimum — `experiments/table4.rs` measures exactly that trade-off.

use super::cholesky::cholesky;
use crate::tensor::Tensor;
use anyhow::Result;

/// ADMM solve. `w` is the dense [m,n] weight, `g` the n×n Gram (f64
/// row-major), `kept` the kept-column mask. Returns the restored [m,n]
/// weight with pruned columns exactly zero, plus the iteration count run.
pub fn admm_restore(
    w: &Tensor,
    g: &[f64],
    kept: &[bool],
    rho: f64,
    iters: usize,
) -> Result<(Tensor, usize)> {
    let (m, n) = w.dims2();
    assert_eq!(g.len(), n * n);
    assert_eq!(kept.len(), n);

    // factor (G + ρI) once
    let mut greg = g.to_vec();
    for i in 0..n {
        greg[i * n + i] += rho;
    }
    let factor = cholesky(&greg, n)?;

    // B = W·G, rows in f64
    let mut b = vec![0.0f64; m * n];
    for i in 0..m {
        let wrow = w.row(i);
        for k in 0..n {
            let wik = wrow[k] as f64;
            if wik == 0.0 {
                continue;
            }
            let grow = &g[k * n..(k + 1) * n];
            let brow = &mut b[i * n..(i + 1) * n];
            for j in 0..n {
                brow[j] += wik * grow[j];
            }
        }
    }

    let mut wk = vec![0.0f64; m * n]; // W iterate
    let mut z = vec![0.0f64; m * n]; // projected iterate
    let mut u = vec![0.0f64; m * n]; // scaled dual
    let mut rhs = vec![0.0f64; n];
    let mut done = iters;
    for it in 0..iters {
        let mut primal_res = 0.0f64;
        for i in 0..m {
            let brow = &b[i * n..(i + 1) * n];
            for j in 0..n {
                rhs[j] = brow[j] + rho * (z[i * n + j] - u[i * n + j]);
            }
            factor.solve_in_place(&mut rhs);
            wk[i * n..(i + 1) * n].copy_from_slice(&rhs);
        }
        for i in 0..m {
            for j in 0..n {
                let idx = i * n + j;
                let zn = if kept[j] { wk[idx] + u[idx] } else { 0.0 };
                primal_res += (wk[idx] - zn) * (wk[idx] - zn);
                u[idx] += wk[idx] - zn;
                z[idx] = zn;
            }
        }
        if primal_res.sqrt() < 1e-9 * (m as f64).sqrt() {
            done = it + 1;
            break;
        }
    }

    let out: Vec<f32> = z.iter().map(|&x| x as f32).collect();
    Ok((Tensor::new(vec![m, n], out), done))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// ADMM must converge towards the closed-form restoration.
    #[test]
    fn approaches_closed_form() {
        let mut rng = Rng::new(0);
        let (m, n, s) = (6usize, 10usize, 40usize);
        let w = Tensor::randn(&[m, n], 1.0, &mut rng);
        // G from random activations X [s, n]
        let x = Tensor::randn(&[s, n], 1.0, &mut rng);
        let mut g = vec![0.0f64; n * n];
        for r in 0..s {
            for i in 0..n {
                for j in 0..n {
                    g[i * n + j] += (x.at2(r, i) * x.at2(r, j)) as f64;
                }
            }
        }
        for i in 0..n {
            g[i * n + i] += 1e-3;
        }
        let kept: Vec<bool> = (0..n).map(|j| j % 3 != 0).collect();

        let (w_admm, iters) = admm_restore(&w, &g, &kept, 1.0, 400).unwrap();
        assert!(iters <= 400);
        // closed form via kept-block solve
        let kept_idx: Vec<usize> = (0..n).filter(|&j| kept[j]).collect();
        let kn = kept_idx.len();
        let mut gk = vec![0.0f64; kn * kn];
        for (a, &ia) in kept_idx.iter().enumerate() {
            for (b2, &ib) in kept_idx.iter().enumerate() {
                gk[a * kn + b2] = g[ia * n + ib];
            }
        }
        let f = cholesky(&gk, kn).unwrap();
        for i in 0..m {
            // rhs = (W G)[i, kept]
            let mut rhs = vec![0.0f64; kn];
            for (a, &ja) in kept_idx.iter().enumerate() {
                let mut sum = 0.0;
                for k in 0..n {
                    sum += w.at2(i, k) as f64 * g[k * n + ja];
                }
                rhs[a] = sum;
            }
            f.solve_in_place(&mut rhs);
            for (a, &ja) in kept_idx.iter().enumerate() {
                assert!(
                    (w_admm.at2(i, ja) as f64 - rhs[a]).abs() < 1e-3,
                    "row {i} col {ja}"
                );
            }
        }
        // pruned columns exactly zero
        for i in 0..m {
            for j in 0..n {
                if !kept[j] {
                    assert_eq!(w_admm.at2(i, j), 0.0);
                }
            }
        }
    }
}
