//! Blocked host matmul — the hot path of both the host runtime backend
//! (every linear layer and the logits product) and the pruning math
//! (restoration assembles `B = W·G` per pruned operator).
//!
//! ## The canonical reduction order
//!
//! Every matmul-family product in this crate reduces each output element
//! with **one** discipline, implemented once in [`lane_accum`] (the
//! unified lane microkernel): contributions accumulate in ascending-k
//! order into a single accumulator per output lane, skipping exact zeros
//! of the left operand. The callers differ only in how they address the
//! operands:
//!
//! * [`matmul_into`] — the blocked multi-row kernel (k-major right
//!   operand, one `lane_accum` call per (row, k-block));
//! * [`crate::tensor::pack::matmul_packed`] — the same kernel over a
//!   pre-packed ([`crate::tensor::pack::PackedMat`]) weight, including
//!   its single-row decode path;
//! * [`matvec_bt_into`] — the strided-B instance (B stored [n, k] as a
//!   linear weight), unrolled over output-column lanes so the serial
//!   accumulator chain of one column no longer bounds throughput;
//! * [`matmul_at`] — the Aᵀ-indexed instance (transpose-free Gram /
//!   backward products);
//! * [`lane_accum_q8`] — the int8-panel instance (dequant-in-register:
//!   elementwise `q·scale` before the same ascending-k accumulation),
//!   so quantized products keep the determinism contract while storing
//!   one byte per weight.
//!
//! Because the per-element order is shared, all of these are
//! **bit-identical** to each other on the same logical product — across
//! backends, pool widths, and packed/unpacked weight sources. That
//! identity is the decode↔re-forward and packed↔unpacked contract
//! (`rust/tests/{test_decode,test_pack}.rs`).
//!
//! [`dot`] is the one deliberate exception: a fixed 8-lane k-striped
//! reduction used for *single vector-vector* products (attention scores,
//! metric math), where the canonical single-accumulator chain cannot be
//! vectorized. Its outputs never cross paths with a matmul-family
//! product, so no bit contract spans the two orders.
//!
//! Large products fan out on the ambient worker pool
//! (`util::pool::current`): multi-row over output-row chunks, single-row
//! over output-column chunks. Each output element is computed by exactly
//! one worker with the serial order, so results are bit-identical for
//! every pool width. The `bench_hot_paths` bench tracks these paths
//! (EXPERIMENTS.md §Perf).

use crate::util::pool;
use super::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};

const BLOCK: usize = 64;

/// Process-wide count of weight-transpose copies taken by [`matmul_bt`]'s
/// multi-row fallback. The packed-operator benches assert this stays
/// flat across a decode loop (no hidden per-token transposes).
static BT_TRANSPOSES: AtomicU64 = AtomicU64::new(0);

/// Current [`matmul_bt`] transpose-copy count (monotonic; diff two
/// snapshots around a region to count its transposes).
pub fn bt_transposes() -> u64 {
    BT_TRANSPOSES.load(Ordering::Relaxed)
}

/// The unified lane microkernel — the canonical reduction order of every
/// matmul-family product:
///
/// ```text
/// out[j] += Σ_{kk = k0..k1, ascending} a[kk] · b[kk·ldb + col0 + j]
/// ```
///
/// with `a[kk] == 0.0` skipped (the masked-model fast path: pruned
/// activations contribute nothing and pay nothing). Each output lane `j`
/// keeps a single accumulator, so the per-element order is ascending-k
/// regardless of how callers tile `k0..k1` — k-blocks visited in
/// ascending order compose to the same bits as one unblocked sweep.
/// The lane loop is an axpy over contiguous memory and auto-vectorizes.
#[inline]
pub fn lane_accum(
    a: &[f32],
    k0: usize,
    k1: usize,
    b: &[f32],
    ldb: usize,
    col0: usize,
    out: &mut [f32],
) {
    for kk in k0..k1 {
        let av = a[kk];
        if av == 0.0 {
            continue;
        }
        let br = &b[kk * ldb + col0..kk * ldb + col0 + out.len()];
        for (o, bv) in out.iter_mut().zip(br) {
            *o += av * bv;
        }
    }
}

/// The int8 instance of [`lane_accum`]: the panel stores quantized
/// bytes `q[kk·ldb + j]` with one f32 scale per (k-group, lane) —
/// `scales[(kk / group)·ldb + j]` — and each contribution dequantizes
/// **in register** before accumulating:
///
/// ```text
/// out[j] += Σ_{kk = k0..k1, ascending} a[kk] · (q[kk·ldb + col0 + j] as f32 · s[(kk/group)·ldb + col0 + j])
/// ```
///
/// Dequantization is elementwise (no reduction of its own), so the
/// accumulation order is exactly [`lane_accum`]'s: ascending-k, one
/// accumulator per lane, zero-skip on the activation. Int8 products are
/// therefore bit-identical to themselves across pool widths and jitter
/// — the same partition-disjointness argument as f32. They are *not*
/// bit-matched to f32 (quantization error is bounded, not zero); f32
/// mode stays the exact reference.
#[inline]
pub fn lane_accum_q8(
    a: &[f32],
    k0: usize,
    k1: usize,
    q: &[i8],
    scales: &[f32],
    group: usize,
    ldb: usize,
    col0: usize,
    out: &mut [f32],
) {
    for kk in k0..k1 {
        let av = a[kk];
        if av == 0.0 {
            continue;
        }
        let qr = &q[kk * ldb + col0..kk * ldb + col0 + out.len()];
        let g = kk / group;
        let sr = &scales[g * ldb + col0..g * ldb + col0 + out.len()];
        for ((o, qv), sv) in out.iter_mut().zip(qr).zip(sr) {
            *o += av * ((*qv as f32) * *sv);
        }
    }
}

/// C = A·B for 2-D tensors [m,k]·[k,n].
///
/// Multi-row products fan out over output-row chunks; single-row
/// products (restoration's per-operator `matmul(&diff, &g)` rows,
/// gradcol probes, any [1,k]·[k,n]) fan out over output-*column* chunks
/// through [`lane_accum`] — each column is one lane of the canonical
/// kernel, so the pooled result is bit-identical to the serial blocked
/// path at every width.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner dim: {:?} x {:?}", a.shape, b.shape);
    let mut c = vec![0.0f32; m * n];
    let p = pool::current();
    let flops = m.saturating_mul(k).saturating_mul(n);
    if p.workers() > 1 && m >= 2 && flops >= pool::PAR_THRESHOLD {
        p.run_rows1(&mut c, n, |r0, chunk| {
            let rows = chunk.len() / n;
            matmul_into(&a.data[r0 * k..(r0 + rows) * k], &b.data, chunk, rows, k, n);
        });
    } else if p.workers() > 1 && m == 1 && n >= 2 && flops >= pool::PAR_THRESHOLD {
        p.run_rows1(&mut c, 1, |j0, chunk| {
            lane_accum(&a.data, 0, k, &b.data, n, j0, chunk);
        });
    } else {
        matmul_into(&a.data, &b.data, &mut c, m, k, n);
    }
    Tensor::new(vec![m, n], c)
}

/// C = A·Bᵀ ("linear" orientation: B is [n,k] like a PyTorch weight).
///
/// Perf note (EXPERIMENTS.md §Perf iter 1): the original row-dot
/// microkernel ran at ~3.4 GF/s — the per-element dot defeats
/// vectorization across output columns. Transposing B once (a [k·n]
/// copy, amortized over the k-deep matmul) and reusing the blocked axpy
/// kernel runs at matmul speed (~13 GF/s), a ~3.5× win on the linear
/// layers of the host reference model.
///
/// This is now the *fallback* path: weight-stationary callers hold a
/// [`crate::tensor::pack::PackedMat`] (the transpose taken once, at
/// build) and call `matmul_packed`, which skips the per-call copy while
/// producing the same bits. Single-row products (`m == 1`, the decode
/// fallback) skip both the transpose and the row-chunk tiling and go
/// through [`matvec_bt_into`], which keeps the canonical reduction
/// order — so a one-token decode linear is bit-identical to the same
/// row inside a full-prefix [b·t, k] product. Large single rows fan out
/// over output-column chunks on the ambient pool.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (n, k2) = b.dims2();
    assert_eq!(k, k2, "matmul_bt inner dim: {:?} x {:?}", a.shape, b.shape);
    if m == 1 {
        let mut c = vec![0.0f32; n];
        let p = pool::current();
        if p.workers() > 1 && n >= 2 && k * n >= pool::PAR_THRESHOLD {
            p.run_rows1(&mut c, 1, |j0, chunk| {
                matvec_bt_into(&a.data, &b.data, chunk, j0, k);
            });
        } else {
            matvec_bt_into(&a.data, &b.data, &mut c, 0, k);
        }
        return Tensor::new(vec![1, n], c);
    }
    BT_TRANSPOSES.fetch_add(1, Ordering::Relaxed);
    matmul(a, &b.t())
}

/// out[j] = Σ_kk a[kk]·b[(j0+j)·k + kk] — one A·Bᵀ output row segment
/// over the *unpacked* [n, k] weight layout. Each output keeps the
/// canonical order (ascending k, single accumulator, zero-skip on `a`),
/// so the bits match the blocked multi-row path and the packed kernel
/// exactly (the decode↔re-forward identity depends on this). Four
/// output columns advance in lockstep — four independent accumulators
/// walking four contiguous B rows — so the serial dependency chain of
/// one column no longer bounds throughput.
pub fn matvec_bt_into(a: &[f32], b: &[f32], out: &mut [f32], j0: usize, k: usize) {
    debug_assert!((j0 + out.len()) * k <= b.len());
    const LANES: usize = 4;
    let mut j = 0usize;
    while j + LANES <= out.len() {
        let base = (j0 + j) * k;
        let r0 = &b[base..base + k];
        let r1 = &b[base + k..base + 2 * k];
        let r2 = &b[base + 2 * k..base + 3 * k];
        let r3 = &b[base + 3 * k..base + 4 * k];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for ((((&av, &b0), &b1), &b2), &b3) in
            a.iter().zip(r0).zip(r1).zip(r2).zip(r3)
        {
            if av == 0.0 {
                continue;
            }
            s0 += av * b0;
            s1 += av * b1;
            s2 += av * b2;
            s3 += av * b3;
        }
        out[j] = s0;
        out[j + 1] = s1;
        out[j + 2] = s2;
        out[j + 3] = s3;
        j += LANES;
    }
    for (jj, o) in out.iter_mut().enumerate().skip(j) {
        let row = &b[(j0 + jj) * k..(j0 + jj + 1) * k];
        let mut s = 0.0f32;
        for (&av, &bv) in a.iter().zip(row) {
            if av == 0.0 {
                continue;
            }
            s += av * bv;
        }
        *o = s;
    }
}

/// Blocked C += A·B on raw slices (row-major): [`lane_accum`] per
/// (row, k-block), k-blocks ascending — the canonical order, cache-tiled.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                lane_accum(
                    &a[i * k..(i + 1) * k],
                    k0,
                    k1,
                    b,
                    n,
                    0,
                    &mut c[i * n..(i + 1) * n],
                );
            }
        }
    }
}

/// Blocked C += A·(int8 panel) on raw slices: [`lane_accum_q8`] per
/// (row, k-block), k-blocks ascending — so each output row accumulates
/// in exactly the order the single-row decode path
/// (`matvec_packed_into` → one unblocked `lane_accum_q8` sweep) uses,
/// and prefill rows are bit-identical to decode steps under int8 just
/// as [`matmul_into`] rows are under f32.
pub fn matmul_q8_into(
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    group: usize,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(q.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                lane_accum_q8(
                    &a[i * k..(i + 1) * k],
                    k0,
                    k1,
                    q,
                    scales,
                    group,
                    n,
                    0,
                    &mut c[i * n..(i + 1) * n],
                );
            }
        }
    }
}

/// C = Aᵀ·B for A [r,m], B [r,n] — the transpose-free Gram/backward
/// kernel (`dW = dyᵀ·x`, `G = xᵀ·x`). Bit-identical to
/// `matmul(&a.t(), b)` by construction: each output element accumulates
/// in ascending-r order with the same zero-skip on the (logically
/// transposed) left operand — only the [r·m] transpose copy disappears.
/// Fans out over output-row chunks like [`matmul`].
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    let (r, m) = a.dims2();
    let (r2, n) = b.dims2();
    assert_eq!(r, r2, "matmul_at outer dim: {:?} x {:?}", a.shape, b.shape);
    let mut c = vec![0.0f32; m * n];
    let p = pool::current();
    let flops = r.saturating_mul(m).saturating_mul(n);
    if p.workers() > 1 && m >= 2 && flops >= pool::PAR_THRESHOLD {
        p.run_rows1(&mut c, n, |i0, chunk| {
            let rows = chunk.len() / n;
            matmul_at_into(&a.data, &b.data, chunk, i0, rows, r, m, n);
        });
    } else {
        matmul_at_into(&a.data, &b.data, &mut c, 0, m, r, m, n);
    }
    Tensor::new(vec![m, n], c)
}

/// C rows [i0, i0+rows) of Aᵀ·B on raw slices — the Aᵀ-indexed instance
/// of the canonical order: the left operand is read with stride `m`
/// (`a[rr·m + i]`), everything else is [`matmul_into`]'s loop shape.
fn matmul_at_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    i0: usize,
    rows: usize,
    r: usize,
    m: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), r * m);
    debug_assert_eq!(b.len(), r * n);
    debug_assert_eq!(c.len(), rows * n);
    for r0 in (0..r).step_by(BLOCK) {
        let r1 = (r0 + BLOCK).min(r);
        for i in 0..rows {
            let cr = &mut c[i * n..(i + 1) * n];
            for rr in r0..r1 {
                let av = a[rr * m + i0 + i];
                if av == 0.0 {
                    continue;
                }
                let br = &b[rr * n..(rr + 1) * n];
                for (o, bv) in cr.iter_mut().zip(br) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// Unrolled dot product — the fixed 8-lane k-striped reduction for
/// single vector-vector products (attention scores, metric math). See
/// the module docs: this order never crosses a matmul-family bit
/// contract; a one-output canonical chain cannot be vectorized, so the
/// lanes stripe over k instead of over outputs.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// y = A·x for 2-D [m,k] and vector [k].
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let (m, k) = a.dims2();
    assert_eq!(x.len(), k);
    (0..m).map(|i| dot(&a.data[i * k..(i + 1) * k], x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (_, n) = b.dims2();
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(kk, j);
                }
                *c.at2_mut(i, j) = s;
            }
        }
        c
    }

    fn bits_eq(a: &Tensor, b: &Tensor) -> bool {
        a.shape == b.shape
            && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(3, 5, 7), (64, 64, 64), (65, 130, 33), (1, 100, 1)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul(&a, &b);
            let cn = naive(&a, &b);
            assert!(c.max_abs_diff(&cn) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn bt_matches_transpose() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[17, 31], 1.0, &mut rng);
        let b = Tensor::randn(&[13, 31], 1.0, &mut rng);
        let c1 = matmul_bt(&a, &b);
        let c2 = matmul(&a, &b.t());
        assert!(c1.max_abs_diff(&c2) < 1e-4);
    }

    #[test]
    fn single_row_bt_bit_identical_to_blocked() {
        use crate::util::pool;
        let mut rng = Rng::new(5);
        // a single row must produce the exact bits the blocked transpose
        // path produces for the same row (decode ≡ re-forward contract),
        // including in the presence of exact zeros (the skip path) and
        // at output widths off the 4-lane unroll (n % 4 != 0)
        for &(k, n) in &[(64usize, 48usize), (130, 33), (8, 1), (16, 6)] {
            let mut a = Tensor::randn(&[1, k], 1.0, &mut rng);
            a.data[k / 2] = 0.0;
            a.data[0] = 0.0;
            let b = Tensor::randn(&[n, k], 1.0, &mut rng);
            let fast = matmul_bt(&a, &b);
            let blocked = {
                let mut c = vec![0.0f32; n];
                matmul_into(&a.data, &b.t().data, &mut c, 1, k, n);
                Tensor::new(vec![1, n], c)
            };
            assert!(
                bits_eq(&fast, &blocked),
                "({k},{n}): single-row path diverged from blocked"
            );
        }
        // and the pooled fan-out never changes the bits
        let a = Tensor::randn(&[1, 1100], 1.0, &mut rng);
        let b = Tensor::randn(&[1024, 1100], 1.0, &mut rng);
        let serial = {
            let _g = pool::enter(pool::serial());
            matmul_bt(&a, &b)
        };
        for workers in [2usize, 5] {
            let par = {
                let _g = pool::enter(std::sync::Arc::new(pool::Pool::new(workers)));
                matmul_bt(&a, &b)
            };
            assert!(
                bits_eq(&serial, &par),
                "matvec fan-out not bit-identical at {workers} workers"
            );
        }
    }

    #[test]
    fn parallel_rows_bit_identical_to_serial() {
        use crate::util::pool;
        let mut rng = Rng::new(7);
        // 97·120·110 ≈ 1.28M flops — above PAR_THRESHOLD, so the pooled
        // path actually engages
        let a = Tensor::randn(&[97, 120], 1.0, &mut rng);
        let b = Tensor::randn(&[120, 110], 1.0, &mut rng);
        let serial = {
            let _g = pool::enter(pool::serial());
            matmul(&a, &b)
        };
        for workers in [2usize, 3, 8] {
            let par = {
                let _g = pool::enter(std::sync::Arc::new(pool::Pool::new(workers)));
                matmul(&a, &b)
            };
            assert!(bits_eq(&serial, &par), "matmul not bit-identical with {workers} workers");
        }
    }

    #[test]
    fn single_row_ab_fans_out_bit_identically() {
        use crate::util::pool;
        let mut rng = Rng::new(19);
        // [1, k]·[k, n] above PAR_THRESHOLD: the column fan-out must
        // match the serial blocked path bit for bit (zero-skip included)
        let mut a = Tensor::randn(&[1, 1100], 1.0, &mut rng);
        a.data[3] = 0.0;
        a.data[700] = 0.0;
        let b = Tensor::randn(&[1100, 1024], 1.0, &mut rng);
        let serial = {
            let _g = pool::enter(pool::serial());
            matmul(&a, &b)
        };
        for workers in [2usize, 5, 8] {
            let par = {
                let _g = pool::enter(std::sync::Arc::new(pool::Pool::new(workers)));
                matmul(&a, &b)
            };
            assert!(
                bits_eq(&serial, &par),
                "single-row matmul fan-out not bit-identical at {workers} workers"
            );
        }
    }

    #[test]
    fn matmul_at_bit_identical_to_explicit_transpose() {
        use crate::util::pool;
        let mut rng = Rng::new(23);
        for &(r, m, n) in &[(5usize, 3usize, 7usize), (64, 64, 64), (130, 65, 33), (80, 1, 9)] {
            let mut a = Tensor::randn(&[r, m], 1.0, &mut rng);
            a.data[0] = 0.0; // exercise the zero-skip parity
            let b = Tensor::randn(&[r, n], 1.0, &mut rng);
            let fast = matmul_at(&a, &b);
            let reference = matmul(&a.t(), &b);
            assert!(
                bits_eq(&fast, &reference),
                "({r},{m},{n}): matmul_at diverged from matmul(a.t(), b)"
            );
        }
        // pooled fan-out: same bits at every width
        let a = Tensor::randn(&[220, 130], 1.0, &mut rng);
        let b = Tensor::randn(&[220, 120], 1.0, &mut rng);
        let serial = {
            let _g = pool::enter(pool::serial());
            matmul_at(&a, &b)
        };
        for workers in [2usize, 4, 8] {
            let par = {
                let _g = pool::enter(std::sync::Arc::new(pool::Pool::new(workers)));
                matmul_at(&a, &b)
            };
            assert!(
                bits_eq(&serial, &par),
                "matmul_at fan-out not bit-identical at {workers} workers"
            );
        }
    }

    #[test]
    fn matvec_matches() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[9, 21], 1.0, &mut rng);
        let x: Vec<f32> = (0..21).map(|i| i as f32 * 0.1).collect();
        let y = matvec(&a, &x);
        let xm = Tensor::new(vec![21, 1], x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..9 {
            assert!((y[i] - ym.data[i]).abs() < 1e-4);
        }
    }
}
