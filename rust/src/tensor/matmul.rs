//! Blocked host matmul — the hot path of both the host runtime backend
//! (every linear layer and the logits product) and the pruning math
//! (restoration assembles `B = W·G` per pruned operator). Cache-blocked
//! with a k-innermost microkernel; large products fan out over output-row
//! chunks on the ambient worker pool (`util::pool::current`). Each output
//! row is computed by exactly one worker with the serial loop order, so
//! results are bit-identical for every pool width. The `bench_hot_paths`
//! bench tracks both paths (EXPERIMENTS.md §Perf).

use crate::util::pool;
use super::Tensor;

const BLOCK: usize = 64;

/// C = A·B for 2-D tensors [m,k]·[k,n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner dim: {:?} x {:?}", a.shape, b.shape);
    let mut c = vec![0.0f32; m * n];
    let p = pool::current();
    let flops = m.saturating_mul(k).saturating_mul(n);
    if p.workers() > 1 && m >= 2 && flops >= pool::PAR_THRESHOLD {
        p.run_rows1(&mut c, n, |r0, chunk| {
            let rows = chunk.len() / n;
            matmul_into(&a.data[r0 * k..(r0 + rows) * k], &b.data, chunk, rows, k, n);
        });
    } else {
        matmul_into(&a.data, &b.data, &mut c, m, k, n);
    }
    Tensor::new(vec![m, n], c)
}

/// C = A·Bᵀ ("linear" orientation: B is [n,k] like a PyTorch weight).
///
/// Perf note (EXPERIMENTS.md §Perf iter 1): the original row-dot
/// microkernel ran at ~3.4 GF/s — the per-element dot defeats
/// vectorization across output columns. Transposing B once (a [k·n]
/// copy, amortized over the k-deep matmul) and reusing the blocked axpy
/// kernel runs at matmul speed (~13 GF/s), a ~3.5× win on the linear
/// layers of the host reference model.
///
/// Single-row products (`m == 1`, the decode-step hot path) skip both
/// the transpose and the row-chunk tiling and go through
/// [`matvec_bt_into`], which keeps `matmul_into`'s exact reduction
/// order — so a one-token decode linear is bit-identical to the same
/// row inside a full-prefix [b·t, k] product. Large single rows (the
/// logits head) fan out over output-column chunks on the ambient pool;
/// each output element is computed by exactly one worker with the
/// serial order, so the result is pool-width-independent.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (n, k2) = b.dims2();
    assert_eq!(k, k2, "matmul_bt inner dim: {:?} x {:?}", a.shape, b.shape);
    if m == 1 {
        let mut c = vec![0.0f32; n];
        let p = pool::current();
        if p.workers() > 1 && n >= 2 && k * n >= pool::PAR_THRESHOLD {
            p.run_rows1(&mut c, 1, |j0, chunk| {
                matvec_bt_into(&a.data, &b.data, chunk, j0, k);
            });
        } else {
            matvec_bt_into(&a.data, &b.data, &mut c, 0, k);
        }
        return Tensor::new(vec![1, n], c);
    }
    matmul(a, &b.t())
}

/// out[j] = Σ_kk a[kk]·b[(j0+j)·k + kk] — one A·Bᵀ output row segment,
/// accumulated in ascending-k order with the same zero-skip
/// `matmul_into` applies, so the bits match the blocked multi-row path
/// exactly (the decode↔re-forward identity depends on this). A single
/// serial accumulator is slower than the 8-lane `dot`, but the blocked
/// path's reduction order is the determinism contract.
pub fn matvec_bt_into(a: &[f32], b: &[f32], out: &mut [f32], j0: usize, k: usize) {
    debug_assert!((j0 + out.len()) * k <= b.len());
    for (jj, o) in out.iter_mut().enumerate() {
        let row = &b[(j0 + jj) * k..(j0 + jj + 1) * k];
        let mut s = 0.0f32;
        for (av, bv) in a.iter().zip(row) {
            if *av == 0.0 {
                continue;
            }
            s += av * bv;
        }
        *o = s;
    }
}

/// Blocked C += A·B on raw slices (row-major).
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let ar = &a[i * k..(i + 1) * k];
                let cr = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = ar[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let br = &b[kk * n..(kk + 1) * n];
                    // axpy over the full row — auto-vectorizes
                    for (cv, bv) in cr.iter_mut().zip(br) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
}

/// Unrolled dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// y = A·x for 2-D [m,k] and vector [k].
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let (m, k) = a.dims2();
    assert_eq!(x.len(), k);
    (0..m).map(|i| dot(&a.data[i * k..(i + 1) * k], x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (_, n) = b.dims2();
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(kk, j);
                }
                *c.at2_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(3, 5, 7), (64, 64, 64), (65, 130, 33), (1, 100, 1)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul(&a, &b);
            let cn = naive(&a, &b);
            assert!(c.max_abs_diff(&cn) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn bt_matches_transpose() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[17, 31], 1.0, &mut rng);
        let b = Tensor::randn(&[13, 31], 1.0, &mut rng);
        let c1 = matmul_bt(&a, &b);
        let c2 = matmul(&a, &b.t());
        assert!(c1.max_abs_diff(&c2) < 1e-4);
    }

    #[test]
    fn single_row_bt_bit_identical_to_blocked() {
        use crate::util::pool;
        let mut rng = Rng::new(5);
        // a single row must produce the exact bits the blocked transpose
        // path produces for the same row (decode ≡ re-forward contract),
        // including in the presence of exact zeros (the skip path)
        for &(k, n) in &[(64usize, 48usize), (130, 33), (8, 1)] {
            let mut a = Tensor::randn(&[1, k], 1.0, &mut rng);
            a.data[k / 2] = 0.0;
            a.data[0] = 0.0;
            let b = Tensor::randn(&[n, k], 1.0, &mut rng);
            let fast = matmul_bt(&a, &b);
            let blocked = {
                let mut c = vec![0.0f32; n];
                matmul_into(&a.data, &b.t().data, &mut c, 1, k, n);
                Tensor::new(vec![1, n], c)
            };
            let same = fast
                .data
                .iter()
                .zip(&blocked.data)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "({k},{n}): single-row path diverged from blocked");
        }
        // and the pooled fan-out never changes the bits
        let a = Tensor::randn(&[1, 1100], 1.0, &mut rng);
        let b = Tensor::randn(&[1024, 1100], 1.0, &mut rng);
        let serial = {
            let _g = pool::enter(pool::serial());
            matmul_bt(&a, &b)
        };
        for workers in [2usize, 5] {
            let par = {
                let _g = pool::enter(std::sync::Arc::new(pool::Pool::new(workers)));
                matmul_bt(&a, &b)
            };
            let same = serial
                .data
                .iter()
                .zip(&par.data)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "matvec fan-out not bit-identical at {workers} workers");
        }
    }

    #[test]
    fn parallel_rows_bit_identical_to_serial() {
        use crate::util::pool;
        let mut rng = Rng::new(7);
        // 97·120·110 ≈ 1.28M flops — above PAR_THRESHOLD, so the pooled
        // path actually engages
        let a = Tensor::randn(&[97, 120], 1.0, &mut rng);
        let b = Tensor::randn(&[120, 110], 1.0, &mut rng);
        let serial = {
            let _g = pool::enter(pool::serial());
            matmul(&a, &b)
        };
        for workers in [2usize, 3, 8] {
            let par = {
                let _g = pool::enter(std::sync::Arc::new(pool::Pool::new(workers)));
                matmul(&a, &b)
            };
            let same = serial
                .data
                .iter()
                .zip(&par.data)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "matmul not bit-identical with {workers} workers");
        }
    }

    #[test]
    fn matvec_matches() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[9, 21], 1.0, &mut rng);
        let x: Vec<f32> = (0..21).map(|i| i as f32 * 0.1).collect();
        let y = matvec(&a, &x);
        let xm = Tensor::new(vec![21, 1], x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..9 {
            assert!((y[i] - ym.data[i]).abs() < 1e-4);
        }
    }
}
