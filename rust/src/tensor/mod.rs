//! Host tensor substrate: owned, contiguous, row-major f32/i32 tensors
//! with the operations the coordinator needs on the host side (metric
//! math, restoration assembly, reference model forward). The runtime hot
//! path stays on PJRT device buffers; these tensors are the host-side
//! currency.

mod core;
pub mod ops;
pub mod matmul;
pub mod pack;
pub mod io;

pub use core::{IntTensor, Tensor};
pub use pack::{PackedMat, Quant};
