//! Binary tensor container ("FTNS"): a minimal named-tensor archive used
//! for model checkpoints and cached calibration stats. Little-endian,
//! single file, no compression:
//!
//! ```text
//! magic "FTNS" | u32 version | u32 count
//! per entry: u32 name_len | name bytes | u8 dtype (0=f32,1=i32)
//!            | u32 ndim | u64 dims... | payload
//! ```

use super::{IntTensor, Tensor};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"FTNS";
const VERSION: u32 = 1;

/// An ordered collection of named tensors.
#[derive(Default, Clone)]
pub struct TensorFile {
    pub tensors: BTreeMap<String, Tensor>,
    pub ints: BTreeMap<String, IntTensor>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn insert_int(&mut self, name: &str, t: IntTensor) {
        self.ints.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| format!("tensor '{name}' missing"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("create {}", path.display()))?,
        );
        self.write_to(&mut w)
    }

    /// Serialize into an in-memory buffer (the shard writer checksums the
    /// exact bytes before they hit disk). Byte-for-byte identical to what
    /// [`TensorFile::save`] writes.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.write_to(&mut buf)?;
        Ok(buf)
    }

    fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        let count = (self.tensors.len() + self.ints.len()) as u32;
        w.write_all(&count.to_le_bytes())?;
        for (name, t) in &self.tensors {
            write_header(&mut w, name, 0, &t.shape)?;
            // SAFETY: `t.data` is a live `Vec<f32>` borrowed for this
            // statement, so the pointer is non-null, aligned (u8 needs
            // align 1) and covers exactly `len * 4` initialized bytes
            // of one allocation; f32 has no padding or invalid bit
            // patterns, and the slice is dropped before `w` can
            // observe the Vec again (no aliasing writes).
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
            };
            w.write_all(bytes)?;
        }
        for (name, t) in &self.ints {
            write_header(&mut w, name, 1, &t.shape)?;
            // SAFETY: same argument as above for `Vec<i32>` — 4-byte
            // elements viewed as `len * 4` initialized bytes at align
            // 1, lifetime confined to this statement.
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
            };
            w.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("open {}", path.display()))?,
        );
        Self::read_from(&mut r).with_context(|| format!("read FTNS {}", path.display()))
    }

    /// Deserialize from an in-memory buffer (shard payloads are checksummed
    /// as raw bytes first, then parsed through this).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = bytes;
        Self::read_from(&mut r)
    }

    fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("read FTNS magic")?;
        if &magic != MAGIC {
            bail!("not a FTNS file");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("unsupported FTNS version {version}");
        }
        let count = read_u32(&mut r)?;
        let mut out = TensorFile::new();
        for _ in 0..count {
            let name_len = read_u32(&mut r)? as usize;
            if name_len > 4096 {
                bail!("corrupt FTNS: name_len {name_len}");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name not utf-8")?;
            let mut dt = [0u8; 1];
            r.read_exact(&mut dt)?;
            let ndim = read_u32(&mut r)? as usize;
            if ndim > 8 {
                bail!("corrupt FTNS: ndim {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut b = [0u8; 8];
                r.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            let n: usize = shape.iter().product();
            let mut payload = vec![0u8; n * 4];
            r.read_exact(&mut payload).with_context(|| {
                format!(
                    "read {}-byte payload of tensor '{name}' — file truncated?",
                    n * 4
                )
            })?;
            match dt[0] {
                0 => {
                    let data = payload
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    out.tensors.insert(name, Tensor::new(shape, data));
                }
                1 => {
                    let data = payload
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    out.ints.insert(name, IntTensor::new(shape, data));
                }
                d => bail!("unknown dtype tag {d}"),
            }
        }
        Ok(out)
    }
}

fn write_header<W: Write>(w: &mut W, name: &str, dtype: u8, shape: &[usize]) -> Result<()> {
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name.as_bytes())?;
    w.write_all(&[dtype])?;
    w.write_all(&(shape.len() as u32).to_le_bytes())?;
    for &d in shape {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(0);
        let mut tf = TensorFile::new();
        tf.insert("w", Tensor::randn(&[3, 4], 1.0, &mut rng));
        tf.insert("b", Tensor::randn(&[7], 1.0, &mut rng));
        tf.insert_int("toks", IntTensor::new(vec![2, 2], vec![1, 2, 3, 4]));
        let path = std::env::temp_dir().join("fasp_io_test.ftns");
        tf.save(&path).unwrap();
        let re = TensorFile::load(&path).unwrap();
        assert_eq!(re.tensors["w"], tf.tensors["w"]);
        assert_eq!(re.tensors["b"], tf.tensors["b"]);
        assert_eq!(re.ints["toks"], tf.ints["toks"]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bytes_roundtrip_matches_file_bytes() {
        let mut rng = Rng::new(1);
        let mut tf = TensorFile::new();
        tf.insert("w", Tensor::randn(&[2, 5], 1.0, &mut rng));
        let bytes = tf.to_bytes().unwrap();
        let path = std::env::temp_dir().join("fasp_io_bytes.ftns");
        tf.save(&path).unwrap();
        assert_eq!(bytes, std::fs::read(&path).unwrap());
        let re = TensorFile::from_bytes(&bytes).unwrap();
        assert_eq!(re.tensors["w"], tf.tensors["w"]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_corrupt() {
        let path = std::env::temp_dir().join("fasp_io_bad.ftns");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(TensorFile::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
