//! Core tensor types.

use crate::util::rng::Rng;

/// Owned, contiguous, row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Owned i32 tensor (token ids).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape {:?} vs len {}", shape, data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; numel(shape)] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// N(0, std) initialization.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(numel(shape), std) }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected 2-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn dims3(&self) -> (usize, usize, usize) {
        assert_eq!(self.shape.len(), 3, "expected 3-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2])
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at2_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.shape[1] + j]
    }

    /// Row slice of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let (_, c) = self.dims2();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.shape[1];
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(numel(shape), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Transposed copy of a 2-D tensor.
    pub fn t(&self) -> Tensor {
        let (r, c) = self.dims2();
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(vec![c, r], out)
    }

    /// Maximum |a - b| between same-shape tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative Frobenius error ||a-b|| / max(||b||, eps).
    pub fn rel_err(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let num: f32 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let den: f32 = other.data.iter().map(|b| b * b).sum();
        (num.sqrt()) / den.sqrt().max(1e-12)
    }
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(numel(&shape), data.len());
        IntTensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        IntTensor { shape: shape.to_vec(), data: vec![0; numel(shape)] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.t();
        assert_eq!(tt.shape, vec![3, 2]);
        assert_eq!(tt.at2(2, 1), 6.0);
        assert_eq!(tt.t(), t);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn randn_scale() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn(&[100, 100], 0.02, &mut rng);
        let var: f32 =
            t.data.iter().map(|x| x * x).sum::<f32>() / t.numel() as f32;
        assert!((var.sqrt() - 0.02).abs() < 0.002);
    }
}
