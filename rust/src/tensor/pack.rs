//! Persistent packed-weight operators: a weight panel-packed **once**
//! into the cache-blocked k-major layout [`super::matmul::matmul_into`]
//! consumes, then reused by every forward, prefill, decode step and
//! streamed eval — killing the per-call transpose copy `matmul_bt` pays
//! and the per-call weight copy `ParamSource::get_l` pays.
//!
//! A [`PackedMat`] holds one of two payloads ([`Quant`]):
//!
//! * **F32** — a pure relayout: the product kernels ([`matmul_packed`],
//!   [`matvec_packed_into`]) run the same canonical lane reduction order
//!   (`lane_accum`: ascending-k, one accumulator per output lane,
//!   zero-skip on the activation) the unpacked paths run, so packed and
//!   unpacked products are **bit-identical** — packing is purely a
//!   latency decision, never a numerics one (`rust/tests/test_pack.rs`).
//! * **Int8** — the f32 panel symmetrically quantized at pack time to
//!   one byte per weight plus an f32 scale per ([`Q8_GROUP`]-deep
//!   k-group, output lane), rounding to nearest-even. Products
//!   dequantize **in register** (`lane_accum_q8`: the elementwise
//!   `q·scale` feeds the same ascending-k single-accumulator order), so
//!   int8 results are bit-identical *to themselves* across pool widths,
//!   jitter and packed sources — while int8-vs-f32 deltas are bounded by
//!   the quantization step (asserted as a bound, never bit-matched; f32
//!   stays the exact reference). Resident bytes drop to
//!   `k·n + 4·⌈k/64⌉·n` ≈ 0.27× the f32 panel.
//!
//! Packing is pool-parallel (scatter over disjoint k-rows, quantization
//! over disjoint k-groups → bytes are pool-width-independent, locked in
//! by `test_backend.rs`) and counted process-wide ([`pack_ops`]): the
//! `bench_hot_paths` packing section asserts a decode loop performs
//! **zero** pack work after its session is built.

use crate::util::pool;
use super::matmul::{lane_accum, lane_accum_q8, matmul_into, matmul_q8_into};
use super::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};

static PACK_OPS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of pack constructions (monotonic; diff two
/// snapshots around a region to count its packs). The receipt that the
/// per-token decode hot loop does no packing after session build.
pub fn pack_ops() -> u64 {
    PACK_OPS.load(Ordering::Relaxed)
}

/// k-rows per quantization scale group: each [`Q8_GROUP`]-deep slab of a
/// panel's reduction axis shares one f32 scale per output lane. Matches
/// the matmul cache block, so blocked products never straddle a group
/// mid-block.
pub const Q8_GROUP: usize = 64;

/// Payload dtype of a [`PackedMat`] (and of a shard store built from
/// one). `F32` is the exact reference; `Int8` trades bounded error for
/// ~0.27× the bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Quant {
    F32,
    Int8,
}

impl Quant {
    /// Parse a dtype name ("f32" / "int8", few aliases); `None` when
    /// unrecognized so callers can surface a proper error.
    pub fn parse(s: &str) -> Option<Quant> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(Quant::F32),
            "int8" | "i8" | "q8" => Some(Quant::Int8),
            _ => None,
        }
    }

    /// Canonical short name (index JSON, CLI tables, bench rows).
    pub fn label(self) -> &'static str {
        match self {
            Quant::F32 => "f32",
            Quant::Int8 => "int8",
        }
    }

    /// The `FASP_QUANT` env knob, read at CLI boundaries **only**
    /// (`fasp generate/serve/chaos/shard`): library entry points take
    /// the dtype explicitly (`Session::pack_as`, `write_shards_q`), and
    /// `Session::pack` is pinned to `F32` so every packed≡unpacked bit
    /// contract stays env-insensitive. Unset/unknown → `F32`.
    pub fn from_env() -> Quant {
        std::env::var("FASP_QUANT")
            .ok()
            .and_then(|s| Quant::parse(&s))
            .unwrap_or(Quant::F32)
    }
}

/// Which operand layout a [`PackedMat`] was packed from (the pack is a
/// pure relayout, so this is all [`PackedMat::unpack`] needs to invert).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Orient {
    /// From a [n, k] linear weight (`y = x·Wᵀ`, the A·Bᵀ orientation).
    Bt,
    /// From a [k, n] right operand (the A·B orientation; already
    /// k-major, so packing is a plain copy).
    Ab,
}

/// The dtype-specific panel storage. Both variants are k-major [k, n]:
/// element (kk, j) multiplies activation kk into lane j.
enum Payload {
    F32(Vec<f32>),
    Int8 {
        /// `q[kk·n + j]`, one byte per weight.
        q: Vec<i8>,
        /// `scales[(kk / Q8_GROUP)·n + j]`, ⌈k/64⌉·n entries.
        scales: Vec<f32>,
    },
}

/// A weight packed once into the k-major [k, n] panel layout the blocked
/// kernel consumes (f32 exact, or int8 + per-group scales).
pub struct PackedMat {
    payload: Payload,
    k: usize,
    n: usize,
    orient: Orient,
}

/// Round half to even — the quantizer's tie-break, implemented manually
/// so it cannot drift with toolchain intrinsics. Exact for the
/// magnitudes the quantizer produces (|x| ≤ 127 + ε, far below 2²³
/// where `floor`/subtract stay exact in f32).
fn rne(x: f32) -> f32 {
    let fl = x.floor();
    let frac = x - fl;
    if frac > 0.5 {
        fl + 1.0
    } else if frac < 0.5 {
        fl
    } else if (fl as i64) % 2 == 0 {
        fl
    } else {
        fl + 1.0
    }
}

/// Quantize k-rows [kk0, kk1) of a k-major panel: per-lane amax over the
/// group, symmetric scale `amax/127`, round-to-nearest-even, clamp to
/// [-127, 127]. An all-zero lane keeps scale 0 and quantizes to 0
/// (exact zeros survive quantization, preserving the kernels' zero-skip
/// semantics on the activation side and sparsity in the panel).
fn quantize_group(panel: &[f32], n: usize, kk0: usize, kk1: usize) -> (Vec<i8>, Vec<f32>) {
    let mut amax = vec![0.0f32; n];
    for kk in kk0..kk1 {
        let row = &panel[kk * n..(kk + 1) * n];
        for (m, &v) in amax.iter_mut().zip(row) {
            let a = v.abs();
            if a > *m {
                *m = a;
            }
        }
    }
    let mut scales = vec![0.0f32; n];
    for (s, &m) in scales.iter_mut().zip(&amax) {
        *s = m / 127.0;
    }
    let mut q = vec![0i8; (kk1 - kk0) * n];
    for kk in kk0..kk1 {
        let row = &panel[kk * n..(kk + 1) * n];
        let qrow = &mut q[(kk - kk0) * n..(kk - kk0 + 1) * n];
        for ((qv, &v), &s) in qrow.iter_mut().zip(row).zip(&scales) {
            if s > 0.0 {
                *qv = rne(v / s).clamp(-127.0, 127.0) as i8;
            }
        }
    }
    (q, scales)
}

/// Quantize a whole k-major [k, n] panel into (q, scales). Groups are
/// independent (disjoint k-slabs, each computed with identical serial
/// arithmetic), so the pooled fan-out returns the exact bytes of the
/// serial loop at any width ([`pool::Pool::map`] slots by index).
fn quantize_panel(panel: &[f32], k: usize, n: usize) -> (Vec<i8>, Vec<f32>) {
    let groups = (k + Q8_GROUP - 1) / Q8_GROUP;
    let part = |g: usize| {
        let kk0 = g * Q8_GROUP;
        let kk1 = (kk0 + Q8_GROUP).min(k);
        quantize_group(panel, n, kk0, kk1)
    };
    let p = pool::current();
    let parts: Vec<(Vec<i8>, Vec<f32>)> =
        if p.workers() > 1 && groups >= 2 && k * n >= pool::PAR_THRESHOLD {
            p.map(groups, part)
        } else {
            (0..groups).map(part).collect()
        };
    let mut q = Vec::with_capacity(k * n);
    let mut scales = Vec::with_capacity(groups * n);
    for (qg, sg) in parts {
        q.extend_from_slice(&qg);
        scales.extend_from_slice(&sg);
    }
    (q, scales)
}

/// Symmetric int8 quantization of a flat vector in groups of `group`
/// consecutive elements, one f32 scale per group — the shard-payload
/// quantizer (`runtime/store.rs` int8 shards). Same round-to-nearest-
/// even + clamp discipline as the panel quantizer, so
/// `|v[i] - q[i]·scales[i/group]| ≤ scales[i/group]/2` per element and
/// exact zeros stay exact.
pub fn quantize_flat(v: &[f32], group: usize) -> (Vec<i8>, Vec<f32>) {
    let groups = (v.len() + group - 1) / group;
    let mut q = vec![0i8; v.len()];
    let mut scales = vec![0.0f32; groups];
    for g in 0..groups {
        let a = g * group;
        let b = (a + group).min(v.len());
        let mut amax = 0.0f32;
        for &x in &v[a..b] {
            let ax = x.abs();
            if ax > amax {
                amax = ax;
            }
        }
        let s = amax / 127.0;
        scales[g] = s;
        if s > 0.0 {
            for (qv, &x) in q[a..b].iter_mut().zip(&v[a..b]) {
                *qv = rne(x / s).clamp(-127.0, 127.0) as i8;
            }
        }
    }
    (q, scales)
}

/// Dequantize the sub-range [off, off+n) of a [`quantize_flat`] payload:
/// `q[i]·scales[i/group]`. Callers bounds-check `off + n ≤ q.len()`.
pub fn dequantize_flat_range(
    q: &[i8],
    scales: &[f32],
    group: usize,
    off: usize,
    n: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for (i, o) in out.iter_mut().enumerate() {
        *o = (q[off + i] as f32) * scales[(off + i) / group];
    }
    out
}

impl PackedMat {
    /// Pack a [n, k] linear weight (A·Bᵀ orientation), exact f32.
    pub fn pack_bt(w: &Tensor) -> PackedMat {
        Self::pack_bt_q(w, Quant::F32)
    }

    /// [`PackedMat::pack_bt`] with an explicit payload dtype.
    pub fn pack_bt_q(w: &Tensor, quant: Quant) -> PackedMat {
        let (n, k) = w.dims2();
        Self::pack_bt_raw_q(&w.data, n, k, quant)
    }

    /// [`PackedMat::pack_bt`] over a raw row-major [n, k] slice — lets
    /// weight stores pack straight out of their packed parameter vector
    /// or shard payload without an intermediate tensor copy. The scatter
    /// fans out over disjoint k-rows of the packed buffer on the ambient
    /// pool; every output element is written exactly once with no
    /// arithmetic, so the bytes are identical at any pool width.
    pub fn pack_bt_raw(w: &[f32], n: usize, k: usize) -> PackedMat {
        Self::pack_bt_raw_q(w, n, k, Quant::F32)
    }

    /// [`PackedMat::pack_bt_raw`] with an explicit payload dtype:
    /// `Int8` builds the f32 panel first (same scatter), then quantizes
    /// it group-by-group ([`Q8_GROUP`] k-rows per scale) and drops the
    /// f32 copy. Quantization is round-to-nearest-even against a
    /// symmetric per-(group, lane) scale, so `|w - q·s| ≤ s/2` per
    /// element — the bound `test_pack.rs` propertizes.
    pub fn pack_bt_raw_q(w: &[f32], n: usize, k: usize, quant: Quant) -> PackedMat {
        assert_eq!(w.len(), n * k, "pack_bt_raw: {} elems for [{n}, {k}]", w.len());
        PACK_OPS.fetch_add(1, Ordering::Relaxed);
        let mut data = vec![0.0f32; k * n];
        let fill = |kk0: usize, chunk: &mut [f32]| {
            for (i, prow) in chunk.chunks_exact_mut(n).enumerate() {
                let kk = kk0 + i;
                for (j, v) in prow.iter_mut().enumerate() {
                    *v = w[j * k + kk];
                }
            }
        };
        let p = pool::current();
        if p.workers() > 1 && k >= 2 && k * n >= pool::PAR_THRESHOLD {
            p.run_rows1(&mut data, n, fill);
        } else {
            fill(0, &mut data);
        }
        let payload = match quant {
            Quant::F32 => Payload::F32(data),
            Quant::Int8 => {
                let (q, scales) = quantize_panel(&data, k, n);
                Payload::Int8 { q, scales }
            }
        };
        PackedMat { payload, k, n, orient: Orient::Bt }
    }

    /// Pack a [k, n] right operand (A·B orientation) — already k-major,
    /// so this is a plain copy into the persistent layout (f32 only:
    /// the A·B orientation packs activations and graph intermediates,
    /// which stay exact).
    pub fn pack_ab(b: &Tensor) -> PackedMat {
        let (k, n) = b.dims2();
        PACK_OPS.fetch_add(1, Ordering::Relaxed);
        PackedMat { payload: Payload::F32(b.data.clone()), k, n, orient: Orient::Ab }
    }

    /// Output width n (lanes per activation row).
    pub fn out_dim(&self) -> usize {
        self.n
    }

    /// Reduction depth k (activation width).
    pub fn k_dim(&self) -> usize {
        self.k
    }

    pub fn orient(&self) -> Orient {
        self.orient
    }

    /// Payload dtype.
    pub fn quant(&self) -> Quant {
        match self.payload {
            Payload::F32(_) => Quant::F32,
            Payload::Int8 { .. } => Quant::Int8,
        }
    }

    /// Resident bytes of the packed panel (int8: quantized bytes plus
    /// the f32 scale table).
    pub fn bytes(&self) -> usize {
        match &self.payload {
            Payload::F32(d) => d.len() * std::mem::size_of::<f32>(),
            Payload::Int8 { q, scales } => {
                q.len() + scales.len() * std::mem::size_of::<f32>()
            }
        }
    }

    /// The k-major f32 panel data (tests and kernels). Panics on an
    /// int8 payload — quantized panels expose [`PackedMat::q_data`]
    /// instead (pack.rs is not a request path; a wrong-dtype access is
    /// a programming error, not a runtime condition).
    pub fn data(&self) -> &[f32] {
        match &self.payload {
            Payload::F32(d) => d,
            Payload::Int8 { .. } => {
                panic!("PackedMat::data on an int8 payload; use q_data()")
            }
        }
    }

    /// The quantized panel (q bytes, scale table), `None` for f32.
    pub fn q_data(&self) -> Option<(&[i8], &[f32])> {
        match &self.payload {
            Payload::F32(_) => None,
            Payload::Int8 { q, scales } => Some((q, scales)),
        }
    }

    /// The k-major panel as f32 values: borrowed data for `F32`,
    /// dequantized (`q·scale`) for `Int8`.
    fn panel_f32(&self) -> std::borrow::Cow<'_, [f32]> {
        match &self.payload {
            Payload::F32(d) => std::borrow::Cow::Borrowed(d),
            Payload::Int8 { q, scales } => {
                let mut out = vec![0.0f32; self.k * self.n];
                for kk in 0..self.k {
                    let g = kk / Q8_GROUP;
                    for j in 0..self.n {
                        out[kk * self.n + j] =
                            (q[kk * self.n + j] as f32) * scales[g * self.n + j];
                    }
                }
                std::borrow::Cow::Owned(out)
            }
        }
    }

    /// Invert the pack: returns the tensor in its original layout
    /// ([n, k] for [`Orient::Bt`], [k, n] for [`Orient::Ab`]). For f32 a
    /// pure relayout, so the roundtrip is bit-exact (proptested); for
    /// int8 the values are the dequantized `q·scale` — exactly what the
    /// product kernels multiply by, so an unpacked-reference product
    /// over `unpack()` reproduces the packed int8 product bits.
    pub fn unpack(&self) -> Tensor {
        let panel = self.panel_f32();
        match self.orient {
            Orient::Ab => Tensor::new(vec![self.k, self.n], panel.into_owned()),
            Orient::Bt => {
                let mut out = vec![0.0f32; self.n * self.k];
                for kk in 0..self.k {
                    for j in 0..self.n {
                        out[j * self.k + kk] = panel[kk * self.n + j];
                    }
                }
                Tensor::new(vec![self.n, self.k], out)
            }
        }
    }
}

/// C = A·(packed) for A [m, k]: the packed replacement for both
/// `matmul_bt(a, w)` (when packed from `w` via [`PackedMat::pack_bt`])
/// and `matmul(a, b)` (via [`PackedMat::pack_ab`]) — bit-identical to
/// either for f32 payloads, dequant-in-register with the same reduction
/// order for int8 — with zero per-call transpose or pack work.
///
/// Multi-row products fan out over output-row chunks; single-row
/// products (the per-token decode hot path) fan out over output-column
/// chunks through the lane kernel. Same gates as the unpacked paths;
/// each output element is computed by one worker with the canonical
/// order, so results are pool-width-independent for both dtypes.
pub fn matmul_packed(a: &Tensor, p: &PackedMat) -> Tensor {
    let (m, k) = a.dims2();
    assert_eq!(
        k, p.k,
        "matmul_packed inner dim: {:?} x packed [{}, {}]",
        a.shape, p.k, p.n
    );
    let n = p.n;
    let mut c = vec![0.0f32; m * n];
    let pl = pool::current();
    let flops = m.saturating_mul(k).saturating_mul(n);
    if m == 1 {
        if pl.workers() > 1 && n >= 2 && flops >= pool::PAR_THRESHOLD {
            pl.run_rows1(&mut c, 1, |j0, chunk| {
                matvec_packed_into(&a.data, p, chunk, j0);
            });
        } else {
            matvec_packed_into(&a.data, p, &mut c, 0);
        }
    } else {
        let rows_into = |r0: usize, chunk: &mut [f32]| {
            let rows = chunk.len() / n;
            let ar = &a.data[r0 * k..(r0 + rows) * k];
            match &p.payload {
                Payload::F32(d) => matmul_into(ar, d, chunk, rows, k, n),
                Payload::Int8 { q, scales } => {
                    matmul_q8_into(ar, q, scales, Q8_GROUP, chunk, rows, k, n)
                }
            }
        };
        if pl.workers() > 1 && flops >= pool::PAR_THRESHOLD {
            pl.run_rows1(&mut c, n, rows_into);
        } else {
            rows_into(0, &mut c);
        }
    }
    Tensor::new(vec![m, n], c)
}

/// Single-row packed product into a caller buffer: columns
/// [j0, j0+out.len()) of `a · packed` — the kernel [`matmul_packed`]'s
/// m == 1 (decode) path runs, exposed for callers with preallocated
/// output segments (canonical lane order for either dtype, zero
/// allocations).
pub fn matvec_packed_into(a: &[f32], p: &PackedMat, out: &mut [f32], j0: usize) {
    debug_assert_eq!(a.len(), p.k);
    debug_assert!(j0 + out.len() <= p.n);
    match &p.payload {
        Payload::F32(d) => lane_accum(a, 0, p.k, d, p.n, j0, out),
        Payload::Int8 { q, scales } => {
            lane_accum_q8(a, 0, p.k, q, scales, Q8_GROUP, p.n, j0, out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::{matmul, matmul_bt};
    use crate::util::pool;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn bits_eq(a: &Tensor, b: &Tensor) -> bool {
        a.shape == b.shape
            && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn pack_roundtrips_both_orientations() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[7, 13], 1.0, &mut rng);
        assert!(bits_eq(&PackedMat::pack_bt(&w).unpack(), &w));
        let b = Tensor::randn(&[13, 7], 1.0, &mut rng);
        assert!(bits_eq(&PackedMat::pack_ab(&b).unpack(), &b));
    }

    #[test]
    fn packed_product_bit_identical_to_unpacked() {
        let mut rng = Rng::new(9);
        for &(m, k, n) in &[(1usize, 16usize, 9usize), (1, 130, 33), (6, 64, 48), (65, 130, 33)] {
            let mut a = Tensor::randn(&[m, k], 1.0, &mut rng);
            a.data[0] = 0.0; // the zero-skip path must agree too
            a.data[(m * k) / 2] = 0.0;
            let w = Tensor::randn(&[n, k], 1.0, &mut rng);
            let packed = matmul_packed(&a, &PackedMat::pack_bt(&w));
            let unpacked = matmul_bt(&a, &w);
            assert!(bits_eq(&packed, &unpacked), "bt ({m},{k},{n}) diverged");
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let packed = matmul_packed(&a, &PackedMat::pack_ab(&b));
            let unpacked = matmul(&a, &b);
            assert!(bits_eq(&packed, &unpacked), "ab ({m},{k},{n}) diverged");
        }
    }

    #[test]
    fn packed_product_pool_width_independent() {
        let mut rng = Rng::new(11);
        let w = Tensor::randn(&[1024, 1100], 1.0, &mut rng);
        let pm = {
            let _g = pool::enter(pool::serial());
            PackedMat::pack_bt(&w)
        };
        for &m in &[1usize, 5] {
            let a = Tensor::randn(&[m, 1100], 1.0, &mut rng);
            let serial = {
                let _g = pool::enter(pool::serial());
                matmul_packed(&a, &pm)
            };
            for workers in [2usize, 4, 8] {
                let par = {
                    let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
                    matmul_packed(&a, &pm)
                };
                assert!(
                    bits_eq(&serial, &par),
                    "m={m}: packed product diverged at {workers} workers"
                );
            }
        }
    }

    #[test]
    fn pack_bytes_pool_width_independent() {
        let mut rng = Rng::new(13);
        let w = Tensor::randn(&[1024, 1100], 1.0, &mut rng);
        let serial = {
            let _g = pool::enter(pool::serial());
            PackedMat::pack_bt(&w)
        };
        for workers in [2usize, 8] {
            let par = {
                let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
                PackedMat::pack_bt(&w)
            };
            assert_eq!(serial.bytes(), par.bytes());
            assert!(
                serial
                    .data()
                    .iter()
                    .zip(par.data())
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "pack bytes diverged at {workers} workers"
            );
        }
    }

    #[test]
    fn int8_pack_bytes_pool_width_independent() {
        let mut rng = Rng::new(29);
        // 1100 k-rows → 18 scale groups, k·n ≥ PAR_THRESHOLD so the
        // pooled quantization path actually engages
        let w = Tensor::randn(&[1024, 1100], 1.0, &mut rng);
        let serial = {
            let _g = pool::enter(pool::serial());
            PackedMat::pack_bt_q(&w, Quant::Int8)
        };
        let (sq, ss) = serial.q_data().unwrap();
        for workers in [2usize, 8] {
            let par = {
                let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
                PackedMat::pack_bt_q(&w, Quant::Int8)
            };
            assert_eq!(serial.bytes(), par.bytes());
            let (pq, ps) = par.q_data().unwrap();
            assert!(sq == pq, "int8 q bytes diverged at {workers} workers");
            assert!(
                ss.iter().zip(ps).all(|(x, y)| x.to_bits() == y.to_bits()),
                "int8 scales diverged at {workers} workers"
            );
        }
    }

    #[test]
    fn int8_product_pool_width_independent_and_matches_dequant_reference() {
        let mut rng = Rng::new(31);
        let w = Tensor::randn(&[1024, 1100], 1.0, &mut rng);
        let pm = {
            let _g = pool::enter(pool::serial());
            PackedMat::pack_bt_q(&w, Quant::Int8)
        };
        // the dequantized weights: an unpacked product over them must
        // reproduce the packed int8 bits (dequant is elementwise, the
        // reduction order is shared)
        let wd = pm.unpack();
        for &m in &[1usize, 5] {
            let mut a = Tensor::randn(&[m, 1100], 1.0, &mut rng);
            a.data[0] = 0.0; // zero-skip parity under int8 too
            let serial = {
                let _g = pool::enter(pool::serial());
                matmul_packed(&a, &pm)
            };
            let reference = {
                let _g = pool::enter(pool::serial());
                matmul_bt(&a, &wd)
            };
            assert!(
                bits_eq(&serial, &reference),
                "m={m}: int8 product != product over dequantized weights"
            );
            for workers in [2usize, 4, 8] {
                let par = {
                    let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
                    matmul_packed(&a, &pm)
                };
                assert!(
                    bits_eq(&serial, &par),
                    "m={m}: int8 product diverged at {workers} workers"
                );
            }
        }
    }

    #[test]
    fn int8_matvec_segments_compose() {
        let mut rng = Rng::new(37);
        let (k, n) = (150usize, 21usize); // spans 3 scale groups
        let a = Tensor::randn(&[1, k], 1.0, &mut rng);
        let w = Tensor::randn(&[n, k], 1.0, &mut rng);
        let pm = PackedMat::pack_bt_q(&w, Quant::Int8);
        let whole = matmul_packed(&a, &pm);
        let mut seg = vec![0.0f32; n];
        matvec_packed_into(&a.data, &pm, &mut seg[..8], 0);
        matvec_packed_into(&a.data, &pm, &mut seg[8..15], 8);
        matvec_packed_into(&a.data, &pm, &mut seg[15..], 15);
        assert!(
            whole.data.iter().zip(&seg).all(|(x, y)| x.to_bits() == y.to_bits()),
            "segmented int8 matvec diverged from the whole row"
        );
    }

    #[test]
    fn int8_bytes_ratio_and_error_bound() {
        let mut rng = Rng::new(41);
        let w = Tensor::randn(&[96, 200], 1.0, &mut rng);
        let f = PackedMat::pack_bt(&w);
        let q = PackedMat::pack_bt_q(&w, Quant::Int8);
        // 1 byte + scales (4·⌈k/64⌉/k per weight) ≪ 0.55×4 bytes
        assert!(
            (q.bytes() as f64) <= 0.55 * (f.bytes() as f64),
            "int8 bytes {} not ≤ 0.55× f32 bytes {}",
            q.bytes(),
            f.bytes()
        );
        // per-element: |w - q·s| ≤ s/2 (+ tiny float slack)
        let (qd, scales) = q.q_data().unwrap();
        let (n, k) = w.dims2();
        for kk in 0..k {
            let g = kk / Q8_GROUP;
            for j in 0..n {
                let orig = w.data[j * k + kk];
                let s = scales[g * n + j];
                let deq = (qd[kk * n + j] as f32) * s;
                assert!(
                    (orig - deq).abs() <= 0.5 * s + 1e-6,
                    "({kk},{j}): |{orig} - {deq}| > s/2 (s={s})"
                );
            }
        }
    }

    #[test]
    fn rne_rounds_half_to_even() {
        for (x, want) in [
            (2.5f32, 2.0f32),
            (3.5, 4.0),
            (-2.5, -2.0),
            (-3.5, -4.0),
            (0.5, 0.0),
            (-0.5, 0.0),
            (1.49, 1.0),
            (1.51, 2.0),
            (-1.49, -1.0),
            (126.5, 126.0),
            (0.0, 0.0),
        ] {
            assert_eq!(rne(x).to_bits(), want.to_bits(), "rne({x})");
        }
    }

    #[test]
    fn int8_zero_lanes_quantize_exactly() {
        // an all-zero output lane keeps scale 0 and dequantizes to exact
        // zeros; exact-zero weights inside a live lane stay exactly zero
        let (n, k) = (3usize, 70usize);
        let mut w = vec![0.0f32; n * k];
        for kk in 0..k {
            w[kk] = 0.25 * ((kk % 7) as f32 - 3.0); // lane 0 live (has zeros at kk%7==3)
        }
        let pm = PackedMat::pack_bt_raw_q(&w, n, k, Quant::Int8);
        let deq = pm.unpack();
        for kk in 0..k {
            if w[kk] == 0.0 {
                assert_eq!(deq.data[kk].to_bits(), 0.0f32.to_bits());
            }
            assert_eq!(deq.data[k + kk].to_bits(), 0.0f32.to_bits(), "zero lane 1");
            assert_eq!(deq.data[2 * k + kk].to_bits(), 0.0f32.to_bits(), "zero lane 2");
        }
    }

    #[test]
    fn matvec_packed_into_segments_compose() {
        let mut rng = Rng::new(17);
        let (k, n) = (40usize, 21usize);
        let a = Tensor::randn(&[1, k], 1.0, &mut rng);
        let w = Tensor::randn(&[n, k], 1.0, &mut rng);
        let pm = PackedMat::pack_bt(&w);
        let whole = matmul_packed(&a, &pm);
        let mut seg = vec![0.0f32; n];
        matvec_packed_into(&a.data, &pm, &mut seg[..8], 0);
        matvec_packed_into(&a.data, &pm, &mut seg[8..15], 8);
        matvec_packed_into(&a.data, &pm, &mut seg[15..], 15);
        assert!(
            whole.data.iter().zip(&seg).all(|(x, y)| x.to_bits() == y.to_bits()),
            "segmented matvec diverged from the whole row"
        );
    }

    #[test]
    fn pack_ops_counts_constructions() {
        let before = pack_ops();
        let mut rng = Rng::new(23);
        let w = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let pm = PackedMat::pack_bt(&w);
        let _ = matmul_packed(&Tensor::randn(&[1, 6], 1.0, &mut rng), &pm);
        let _ = matmul_packed(&Tensor::randn(&[3, 6], 1.0, &mut rng), &pm);
        // products never pack; only constructions count (other tests may
        // run concurrently, so the delta is a lower bound ≥ 1 here)
        assert!(pack_ops() >= before + 1);
    }
}
