//! Persistent packed-weight operators: a weight panel-packed **once**
//! into the cache-blocked k-major layout [`super::matmul::matmul_into`]
//! consumes, then reused by every forward, prefill, decode step and
//! streamed eval — killing the per-call transpose copy `matmul_bt` pays
//! and the per-call weight copy `ParamSource::get_l` pays.
//!
//! A [`PackedMat`] is a pure relayout: the product kernels
//! ([`matmul_packed`], [`matvec_packed_into`]) run the same canonical
//! lane reduction order (`lane_accum`: ascending-k, one accumulator per
//! output lane, zero-skip on the activation) the unpacked paths run, so
//! packed and unpacked products are **bit-identical** — packing is
//! purely a latency decision, never a numerics one
//! (`rust/tests/test_pack.rs`).
//!
//! Packing is pool-parallel (scatter over disjoint k-rows → bytes are
//! pool-width-independent, locked in by `test_backend.rs`) and counted
//! process-wide ([`pack_ops`]): the `bench_hot_paths` packing section
//! asserts a decode loop performs **zero** pack work after its session
//! is built.

use crate::util::pool;
use super::matmul::{lane_accum, matmul_into};
use super::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};

static PACK_OPS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of pack constructions (monotonic; diff two
/// snapshots around a region to count its packs). The receipt that the
/// per-token decode hot loop does no packing after session build.
pub fn pack_ops() -> u64 {
    PACK_OPS.load(Ordering::Relaxed)
}

/// Which operand layout a [`PackedMat`] was packed from (the pack is a
/// pure relayout, so this is all [`PackedMat::unpack`] needs to invert).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Orient {
    /// From a [n, k] linear weight (`y = x·Wᵀ`, the A·Bᵀ orientation).
    Bt,
    /// From a [k, n] right operand (the A·B orientation; already
    /// k-major, so packing is a plain copy).
    Ab,
}

/// A weight packed once into the k-major [k, n] panel layout the blocked
/// kernel consumes: `data[kk·n + j]` multiplies activation element `kk`
/// into output lane `j`.
pub struct PackedMat {
    data: Vec<f32>,
    k: usize,
    n: usize,
    orient: Orient,
}

impl PackedMat {
    /// Pack a [n, k] linear weight (A·Bᵀ orientation).
    pub fn pack_bt(w: &Tensor) -> PackedMat {
        let (n, k) = w.dims2();
        Self::pack_bt_raw(&w.data, n, k)
    }

    /// [`PackedMat::pack_bt`] over a raw row-major [n, k] slice — lets
    /// weight stores pack straight out of their packed parameter vector
    /// or shard payload without an intermediate tensor copy. The scatter
    /// fans out over disjoint k-rows of the packed buffer on the ambient
    /// pool; every output element is written exactly once with no
    /// arithmetic, so the bytes are identical at any pool width.
    pub fn pack_bt_raw(w: &[f32], n: usize, k: usize) -> PackedMat {
        assert_eq!(w.len(), n * k, "pack_bt_raw: {} elems for [{n}, {k}]", w.len());
        PACK_OPS.fetch_add(1, Ordering::Relaxed);
        let mut data = vec![0.0f32; k * n];
        let fill = |kk0: usize, chunk: &mut [f32]| {
            for (i, prow) in chunk.chunks_exact_mut(n).enumerate() {
                let kk = kk0 + i;
                for (j, v) in prow.iter_mut().enumerate() {
                    *v = w[j * k + kk];
                }
            }
        };
        let p = pool::current();
        if p.workers() > 1 && n >= 1 && k >= 2 && k * n >= pool::PAR_THRESHOLD {
            p.run_rows1(&mut data, n, fill);
        } else {
            fill(0, &mut data);
        }
        PackedMat { data, k, n, orient: Orient::Bt }
    }

    /// Pack a [k, n] right operand (A·B orientation) — already k-major,
    /// so this is a plain copy into the persistent layout.
    pub fn pack_ab(b: &Tensor) -> PackedMat {
        let (k, n) = b.dims2();
        PACK_OPS.fetch_add(1, Ordering::Relaxed);
        PackedMat { data: b.data.clone(), k, n, orient: Orient::Ab }
    }

    /// Output width n (lanes per activation row).
    pub fn out_dim(&self) -> usize {
        self.n
    }

    /// Reduction depth k (activation width).
    pub fn k_dim(&self) -> usize {
        self.k
    }

    pub fn orient(&self) -> Orient {
        self.orient
    }

    /// Resident bytes of the packed panel.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// The k-major panel data (tests and kernels).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Invert the pack: returns the tensor in its original layout
    /// ([n, k] for [`Orient::Bt`], [k, n] for [`Orient::Ab`]) — a pure
    /// relayout, so the roundtrip is bit-exact (proptested).
    pub fn unpack(&self) -> Tensor {
        match self.orient {
            Orient::Ab => Tensor::new(vec![self.k, self.n], self.data.clone()),
            Orient::Bt => {
                let mut out = vec![0.0f32; self.n * self.k];
                for kk in 0..self.k {
                    for j in 0..self.n {
                        out[j * self.k + kk] = self.data[kk * self.n + j];
                    }
                }
                Tensor::new(vec![self.n, self.k], out)
            }
        }
    }
}

/// C = A·(packed) for A [m, k]: the packed replacement for both
/// `matmul_bt(a, w)` (when packed from `w` via [`PackedMat::pack_bt`])
/// and `matmul(a, b)` (via [`PackedMat::pack_ab`]), bit-identical to
/// either, with zero per-call transpose or pack work.
///
/// Multi-row products fan out over output-row chunks; single-row
/// products (the per-token decode hot path) fan out over output-column
/// chunks through the lane kernel. Same gates as the unpacked paths;
/// each output element is computed by one worker with the canonical
/// order, so results are pool-width-independent.
pub fn matmul_packed(a: &Tensor, p: &PackedMat) -> Tensor {
    let (m, k) = a.dims2();
    assert_eq!(
        k, p.k,
        "matmul_packed inner dim: {:?} x packed [{}, {}]",
        a.shape, p.k, p.n
    );
    let n = p.n;
    let mut c = vec![0.0f32; m * n];
    let pl = pool::current();
    let flops = m.saturating_mul(k).saturating_mul(n);
    if m == 1 {
        if pl.workers() > 1 && n >= 2 && flops >= pool::PAR_THRESHOLD {
            pl.run_rows1(&mut c, 1, |j0, chunk| {
                matvec_packed_into(&a.data, p, chunk, j0);
            });
        } else {
            matvec_packed_into(&a.data, p, &mut c, 0);
        }
    } else if pl.workers() > 1 && flops >= pool::PAR_THRESHOLD {
        pl.run_rows1(&mut c, n, |r0, chunk| {
            let rows = chunk.len() / n;
            matmul_into(&a.data[r0 * k..(r0 + rows) * k], &p.data, chunk, rows, k, n);
        });
    } else {
        matmul_into(&a.data, &p.data, &mut c, m, k, n);
    }
    Tensor::new(vec![m, n], c)
}

/// Single-row packed product into a caller buffer: columns
/// [j0, j0+out.len()) of `a · packed` — the kernel [`matmul_packed`]'s
/// m == 1 (decode) path runs, exposed for callers with preallocated
/// output segments (canonical lane order, zero allocations).
pub fn matvec_packed_into(a: &[f32], p: &PackedMat, out: &mut [f32], j0: usize) {
    debug_assert_eq!(a.len(), p.k);
    debug_assert!(j0 + out.len() <= p.n);
    lane_accum(a, 0, p.k, &p.data, p.n, j0, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::{matmul, matmul_bt};
    use crate::util::pool;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn bits_eq(a: &Tensor, b: &Tensor) -> bool {
        a.shape == b.shape
            && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn pack_roundtrips_both_orientations() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[7, 13], 1.0, &mut rng);
        assert!(bits_eq(&PackedMat::pack_bt(&w).unpack(), &w));
        let b = Tensor::randn(&[13, 7], 1.0, &mut rng);
        assert!(bits_eq(&PackedMat::pack_ab(&b).unpack(), &b));
    }

    #[test]
    fn packed_product_bit_identical_to_unpacked() {
        let mut rng = Rng::new(9);
        for &(m, k, n) in &[(1usize, 16usize, 9usize), (1, 130, 33), (6, 64, 48), (65, 130, 33)] {
            let mut a = Tensor::randn(&[m, k], 1.0, &mut rng);
            a.data[0] = 0.0; // the zero-skip path must agree too
            a.data[(m * k) / 2] = 0.0;
            let w = Tensor::randn(&[n, k], 1.0, &mut rng);
            let packed = matmul_packed(&a, &PackedMat::pack_bt(&w));
            let unpacked = matmul_bt(&a, &w);
            assert!(bits_eq(&packed, &unpacked), "bt ({m},{k},{n}) diverged");
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let packed = matmul_packed(&a, &PackedMat::pack_ab(&b));
            let unpacked = matmul(&a, &b);
            assert!(bits_eq(&packed, &unpacked), "ab ({m},{k},{n}) diverged");
        }
    }

    #[test]
    fn packed_product_pool_width_independent() {
        let mut rng = Rng::new(11);
        let w = Tensor::randn(&[1024, 1100], 1.0, &mut rng);
        let pm = {
            let _g = pool::enter(pool::serial());
            PackedMat::pack_bt(&w)
        };
        for &m in &[1usize, 5] {
            let a = Tensor::randn(&[m, 1100], 1.0, &mut rng);
            let serial = {
                let _g = pool::enter(pool::serial());
                matmul_packed(&a, &pm)
            };
            for workers in [2usize, 4, 8] {
                let par = {
                    let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
                    matmul_packed(&a, &pm)
                };
                assert!(
                    bits_eq(&serial, &par),
                    "m={m}: packed product diverged at {workers} workers"
                );
            }
        }
    }

    #[test]
    fn pack_bytes_pool_width_independent() {
        let mut rng = Rng::new(13);
        let w = Tensor::randn(&[1024, 1100], 1.0, &mut rng);
        let serial = {
            let _g = pool::enter(pool::serial());
            PackedMat::pack_bt(&w)
        };
        for workers in [2usize, 8] {
            let par = {
                let _g = pool::enter(Arc::new(pool::Pool::new(workers)));
                PackedMat::pack_bt(&w)
            };
            assert_eq!(serial.bytes(), par.bytes());
            assert!(
                serial
                    .data()
                    .iter()
                    .zip(par.data())
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "pack bytes diverged at {workers} workers"
            );
        }
    }

    #[test]
    fn matvec_packed_into_segments_compose() {
        let mut rng = Rng::new(17);
        let (k, n) = (40usize, 21usize);
        let a = Tensor::randn(&[1, k], 1.0, &mut rng);
        let w = Tensor::randn(&[n, k], 1.0, &mut rng);
        let pm = PackedMat::pack_bt(&w);
        let whole = matmul_packed(&a, &pm);
        let mut seg = vec![0.0f32; n];
        matvec_packed_into(&a.data, &pm, &mut seg[..8], 0);
        matvec_packed_into(&a.data, &pm, &mut seg[8..15], 8);
        matvec_packed_into(&a.data, &pm, &mut seg[15..], 15);
        assert!(
            whole.data.iter().zip(&seg).all(|(x, y)| x.to_bits() == y.to_bits()),
            "segmented matvec diverged from the whole row"
        );
    }

    #[test]
    fn pack_ops_counts_constructions() {
        let before = pack_ops();
        let mut rng = Rng::new(23);
        let w = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let pm = PackedMat::pack_bt(&w);
        let _ = matmul_packed(&Tensor::randn(&[1, 6], 1.0, &mut rng), &pm);
        let _ = matmul_packed(&Tensor::randn(&[3, 6], 1.0, &mut rng), &pm);
        // products never pack; only constructions count (other tests may
        // run concurrently, so the delta is a lower bound ≥ 1 here)
        assert!(pack_ops() >= before + 1);
    }
}
