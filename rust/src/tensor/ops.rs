//! Elementwise / reduction / selection operations on host tensors.
//! Everything the pruning math needs: column norms/sums, masked zeroing,
//! gathers by index set, softmax and friends for the host reference model.

use super::Tensor;

/// Column-wise L1 norm of |W| for a 2-D tensor: out[j] = Σ_i |W_ij|.
pub fn col_abs_sum(w: &Tensor) -> Vec<f32> {
    let (r, c) = w.dims2();
    let mut out = vec![0.0f32; c];
    for i in 0..r {
        let row = &w.data[i * c..(i + 1) * c];
        for (o, x) in out.iter_mut().zip(row) {
            *o += x.abs();
        }
    }
    out
}

/// Row-wise L1 norm: out[i] = Σ_j |W_ij|.
pub fn row_abs_sum(w: &Tensor) -> Vec<f32> {
    let (r, c) = w.dims2();
    (0..r)
        .map(|i| w.data[i * c..(i + 1) * c].iter().map(|x| x.abs()).sum())
        .collect()
}

/// Column-wise squared L2 norm.
pub fn col_sq_sum(w: &Tensor) -> Vec<f32> {
    let (r, c) = w.dims2();
    let mut out = vec![0.0f32; c];
    for i in 0..r {
        let row = &w.data[i * c..(i + 1) * c];
        for (o, x) in out.iter_mut().zip(row) {
            *o += x * x;
        }
    }
    out
}

/// Zero the given columns of a 2-D tensor in place.
pub fn zero_cols(w: &mut Tensor, cols: &[usize]) {
    let (r, c) = w.dims2();
    for i in 0..r {
        let row = &mut w.data[i * c..(i + 1) * c];
        for &j in cols {
            row[j] = 0.0;
        }
    }
}

/// Zero the given rows of a 2-D tensor in place.
pub fn zero_rows(w: &mut Tensor, rows: &[usize]) {
    let c = w.shape[1];
    for &i in rows {
        w.data[i * c..(i + 1) * c].fill(0.0);
    }
}

/// Zero entries of a 1-D tensor in place.
pub fn zero_elems(b: &mut Tensor, idx: &[usize]) {
    for &i in idx {
        b.data[i] = 0.0;
    }
}

/// Gather columns: out[:, k] = w[:, cols[k]].
pub fn gather_cols(w: &Tensor, cols: &[usize]) -> Tensor {
    let (r, c) = w.dims2();
    let mut out = vec![0.0f32; r * cols.len()];
    for i in 0..r {
        let row = &w.data[i * c..(i + 1) * c];
        for (k, &j) in cols.iter().enumerate() {
            out[i * cols.len() + k] = row[j];
        }
    }
    Tensor::new(vec![r, cols.len()], out)
}

/// Gather rows: out[k, :] = w[rows[k], :].
pub fn gather_rows(w: &Tensor, rows: &[usize]) -> Tensor {
    let (_, c) = w.dims2();
    let mut out = Vec::with_capacity(rows.len() * c);
    for &i in rows {
        out.extend_from_slice(&w.data[i * c..(i + 1) * c]);
    }
    Tensor::new(vec![rows.len(), c], out)
}

/// Gather elements of a 1-D tensor: out[k] = b[idx[k]].
pub fn gather_elems(b: &Tensor, idx: &[usize]) -> Tensor {
    assert_eq!(b.ndim(), 1, "gather_elems wants 1-D, got {:?}", b.shape);
    Tensor::new(vec![idx.len()], idx.iter().map(|&i| b.data[i]).collect())
}

/// Scatter rows back: w[rows[k], :] = src[k, :] (inverse of gather_rows).
pub fn scatter_rows(w: &mut Tensor, rows: &[usize], src: &Tensor) {
    let (_, c) = w.dims2();
    let (sr, sc) = src.dims2();
    assert_eq!(sc, c);
    assert_eq!(sr, rows.len());
    for (k, &i) in rows.iter().enumerate() {
        w.data[i * c..(i + 1) * c].copy_from_slice(src.row(k));
    }
}

/// Scatter columns back: w[:, cols[k]] = src[:, k].
pub fn scatter_cols(w: &mut Tensor, cols: &[usize], src: &Tensor) {
    let (r, c) = w.dims2();
    let (sr, sc) = src.dims2();
    assert_eq!(sr, r);
    assert_eq!(sc, cols.len());
    for i in 0..r {
        for (k, &j) in cols.iter().enumerate() {
            w.data[i * c + j] = src.data[i * sc + k];
        }
    }
}

/// out = a + b (same shape).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    Tensor::new(
        a.shape.clone(),
        a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
    )
}

/// a += b in place.
pub fn add_assign(a: &mut Tensor, b: &Tensor) {
    assert_eq!(a.shape, b.shape);
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

/// a *= s in place.
pub fn scale(a: &mut Tensor, s: f32) {
    for x in a.data.iter_mut() {
        *x *= s;
    }
}

/// In-place stable softmax over the last axis of a 2-D tensor.
pub fn softmax_rows(x: &mut Tensor) {
    let (r, c) = x.dims2();
    for i in 0..r {
        let row = &mut x.data[i * c..(i + 1) * c];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
}

/// log-sum-exp over a slice.
pub fn logsumexp(row: &[f32]) -> f32 {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    m + row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln()
}

/// Frobenius norm.
pub fn fro_norm(a: &Tensor) -> f32 {
    a.data.iter().map(|x| x * x).sum::<f32>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t23() -> Tensor {
        Tensor::new(vec![2, 3], vec![1., -2., 3., -4., 5., -6.])
    }

    #[test]
    fn col_row_sums() {
        let w = t23();
        assert_eq!(col_abs_sum(&w), vec![5., 7., 9.]);
        assert_eq!(row_abs_sum(&w), vec![6., 15.]);
        assert_eq!(col_sq_sum(&w), vec![17., 29., 45.]);
    }

    #[test]
    fn zero_and_gather() {
        let mut w = t23();
        zero_cols(&mut w, &[1]);
        assert_eq!(w.data, vec![1., 0., 3., -4., 0., -6.]);
        let g = gather_cols(&w, &[0, 2]);
        assert_eq!(g.shape, vec![2, 2]);
        assert_eq!(g.data, vec![1., 3., -4., -6.]);
        let r = gather_rows(&w, &[1]);
        assert_eq!(r.data, vec![-4., 0., -6.]);
    }

    #[test]
    fn scatter_inverts_gather() {
        let w = t23();
        let cols = vec![0usize, 2];
        let g = gather_cols(&w, &cols);
        let mut w2 = Tensor::zeros(&[2, 3]);
        scatter_cols(&mut w2, &cols, &g);
        assert_eq!(w2.data, vec![1., 0., 3., -4., 0., -6.]);
    }

    #[test]
    fn softmax_normalizes() {
        let mut x = t23();
        softmax_rows(&mut x);
        for i in 0..2 {
            let s: f32 = x.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn logsumexp_stable() {
        let v = logsumexp(&[1000.0, 1000.0]);
        assert!((v - (1000.0 + (2.0f32).ln())).abs() < 1e-3);
    }
}
