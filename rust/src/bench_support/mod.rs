//! Criterion-lite bench harness (criterion is not in the offline vendor
//! set): warmup + adaptive sampling + robust stats + markdown tables.
//! Used by every target in `rust/benches/`.

pub mod table;

use crate::util::{mean, stddev};
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        mean(&self.samples)
    }
    pub fn std_s(&self) -> f64 {
        stddev(&self.samples)
    }
    pub fn min_s(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }
    pub fn summary(&self) -> String {
        format!(
            "{:<40} mean {:>10} ± {:>9}  min {:>10}  (n={})",
            self.name,
            fmt_s(self.mean_s()),
            fmt_s(self.std_s()),
            fmt_s(self.min_s()),
            self.samples.len()
        )
    }
}

pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3}s", s)
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Bench runner: time-budgeted adaptive sampling.
pub struct Bencher {
    /// minimum samples per case
    pub min_samples: usize,
    /// soft time budget per case (seconds)
    pub budget_s: f64,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        // honor a CLI-ish env knob so `make bench FAST=1` can shrink runs
        let fast = std::env::var("FASP_BENCH_FAST").is_ok();
        Bencher {
            min_samples: if fast { 3 } else { 5 },
            budget_s: if fast { 1.0 } else { 3.0 },
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Run `f` repeatedly; each invocation is one sample.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // one warmup
        f();
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_samples
            || (start.elapsed().as_secs_f64() < self.budget_s && samples.len() < 200)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let r = BenchResult { name: name.to_string(), samples };
        println!("{}", r.summary());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Throughput helper: items/sec for the most recent result.
    pub fn last_throughput(&self, items: usize) -> f64 {
        self.results
            .last()
            .map(|r| items as f64 / r.mean_s())
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples() {
        let mut b = Bencher { min_samples: 3, budget_s: 0.01, results: vec![] };
        b.bench("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(b.results[0].samples.len() >= 3);
        assert!(b.results[0].mean_s() >= 0.0);
    }
}
