//! Markdown/ASCII table printer for the experiment outputs (every paper
//! table is regenerated through this).

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut width = vec![0usize; cols];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &width));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Render an ASCII line chart (for the figure reproductions): one series
/// per (label, points) with shared x.
pub fn ascii_chart(
    title: &str,
    xs: &[f64],
    series: &[(String, Vec<f64>)],
    height: usize,
) -> String {
    let mut out = format!("\n## {title}\n\n");
    let all: Vec<f64> = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .filter(|y| y.is_finite())
        .collect();
    if all.is_empty() {
        return out;
    }
    let (ymin, ymax) = all
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &y| {
            (lo.min(y), hi.max(y))
        });
    let span = (ymax - ymin).max(1e-9);
    let width = xs.len();
    let marks = ['*', 'o', '+', 'x', '#', '@', '%'];
    let mut grid = vec![vec![' '; width * 3]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (xi, &y) in ys.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let row = ((ymax - y) / span * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][xi * 3 + 1] = marks[si % marks.len()];
        }
    }
    for (i, row) in grid.iter().enumerate() {
        let y = ymax - span * i as f64 / (height - 1) as f64;
        out.push_str(&format!("{:>9.2} |{}\n", y, row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "{:>9} +{}\n", "", "-".repeat(width * 3)
    ));
    out.push_str(&format!(
        "{:>10} {}\n",
        "x:",
        xs.iter().map(|x| format!("{:<3.0}", x * 100.0)).collect::<String>()
    ));
    for (si, (label, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", marks[si % marks.len()], label));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("Test", &["a", "b"]);
        t.row(vec!["1".into(), "xx".into()]);
        let s = t.render();
        assert!(s.contains("| a | b  |"));
        assert!(s.contains("| 1 | xx |"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn chart_contains_series() {
        let s = ascii_chart(
            "C",
            &[0.0, 0.1, 0.2],
            &[("m".into(), vec![1.0, 2.0, 3.0])],
            5,
        );
        assert!(s.contains('*'));
        assert!(s.contains("m"));
    }
}
