//! Wall-clock timing helpers used by the pruning pipeline phase breakdown
//! (Table 4) and the bench harness.

use std::time::{Duration, Instant};

/// Simple stopwatch with named splits.
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    pub splits: Vec<(String, Duration)>,
}

impl Stopwatch {
    pub fn start() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now, splits: Vec::new() }
    }

    /// Record time since the previous split under `name`.
    pub fn split(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        // accumulate into an existing split of the same name
        if let Some(e) = self.splits.iter_mut().find(|(n, _)| n == name) {
            e.1 += d;
        } else {
            self.splits.push((name.to_string(), d));
        }
        d
    }

    pub fn total(&self) -> Duration {
        Instant::now() - self.start
    }

    /// "phase1 1.2s | phase2 300ms | total 1.5s"
    pub fn report(&self) -> String {
        let mut parts: Vec<String> = self
            .splits
            .iter()
            .map(|(n, d)| format!("{} {}", n, fmt_duration(*d)))
            .collect();
        parts.push(format!("total {}", fmt_duration(self.total())));
        parts.join(" | ")
    }
}

/// Human-friendly duration.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 60.0 {
        format!("{:.0}m{:02.0}s", (s / 60.0).floor(), s % 60.0)
    } else if s >= 1.0 {
        format!("{:.2}s", s)
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_accumulate() {
        let mut sw = Stopwatch::start();
        sw.split("a");
        sw.split("b");
        sw.split("a");
        assert_eq!(sw.splits.len(), 2);
        assert!(sw.report().contains("total"));
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_duration(Duration::from_secs(90)), "1m30s");
        assert_eq!(fmt_duration(Duration::from_millis(1500)), "1.50s");
        assert!(fmt_duration(Duration::from_micros(12)).ends_with("µs"));
    }
}
