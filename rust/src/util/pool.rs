//! Dependency-free scoped worker pool (std::thread only) — the execution
//! substrate of the runtime backends (`runtime::backend`).
//!
//! Design rules, in priority order:
//!
//! 1. **Determinism**: every helper partitions work into disjoint output
//!    regions computed with exactly the arithmetic (and reduction order)
//!    the serial code uses. No atomic accumulation, no worker-count-
//!    dependent reductions — a `Pool` with 1 worker and a `Pool` with 16
//!    produce bit-identical results.
//! 2. **Zero dependencies**: scoped `std::thread` fan-out per call. For
//!    the coarse tasks this repo parallelizes (batch rows, attention
//!    heads, layer repacks) the spawn cost is noise next to the work.
//! 3. **No nesting**: worker threads run with a serial pool installed, so
//!    a parallel matmul inside a parallel attention block never explodes
//!    into threads².
//!
//! Sizing comes from `FASP_THREADS` (see [`default_threads`]). The
//! process-wide default pool is what ambient code (outside any backend
//! scope) sees via [`current`]; `runtime::backend` installs its own pool
//! for the duration of an entry execution.

use once_cell::sync::OnceCell;
use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Below this many scalar operations a parallel fan-out is not worth the
/// scoped-spawn overhead; call sites compare their work estimate to it.
pub const PAR_THRESHOLD: usize = 1 << 20;

/// Hard cap on the default sizing (explicit `FASP_THREADS` may exceed it).
const DEFAULT_MAX_THREADS: usize = 8;

/// A fixed-width scoped worker pool. Cheap to clone behind an [`Arc`];
/// holds no threads between calls.
pub struct Pool {
    workers: usize,
}

// ---------------------------------------------------------------- jitter
//
// `FASP_POOL_JITTER=<max_us>` is a *debug* knob: every spawned worker
// sleeps a pseudorandom 0..=max_us microseconds before touching its
// work, shuffling the interleaving of every fan-out. The determinism
// contract says results are a function of the partition arithmetic
// alone, so outputs must stay bit-identical under any jitter —
// `test_backend.rs` asserts exactly that. The delays derive from a
// process-local counter hashed with the worker index (splitmix64),
// not from wall clock or thread ids, so the knob itself introduces no
// D3-style nondeterministic *values* — only scheduling noise.

/// Fan-out counter feeding the jitter hash (which delays arise is
/// scheduling-dependent; which results arise must not be).
static JITTER_SEQ: AtomicU64 = AtomicU64::new(0);

/// Read `FASP_POOL_JITTER` (max delay in microseconds; 0/absent =
/// disabled). Re-read on every fan-out so tests can toggle it live.
fn jitter_max_us() -> u64 {
    std::env::var("FASP_POOL_JITTER")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0)
}

/// Sleep the pseudorandom per-worker start delay (no-op when disabled).
fn jitter_start(max_us: u64, worker: usize) {
    if max_us == 0 {
        return;
    }
    let seq = JITTER_SEQ.fetch_add(1, Ordering::Relaxed);
    // splitmix64 over (seq, worker): cheap, stateless, well-mixed
    let mut z = seq
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add((worker as u64).wrapping_mul(0xd1b5_4a32_d192_ed03));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    std::thread::sleep(std::time::Duration::from_micros(z % (max_us + 1)));
}

// ------------------------------------------------------- fault injection
//
// Each *top-level* entry into `map`/`run_rows1`/`run_rows2` on a thread
// holding a fault scope counts one `crate::fault` pool event; nested
// entries (a parallel matmul inside a fan-out's work section, which the
// serial-nested-pool rule routes through the shortcut paths) are
// suppressed by the IN_FANOUT flag, so event numbering is a function of
// the call graph, not of how the work happens to be partitioned. An
// armed event detonates an injected panic *inside the pool* — on a
// spawned worker for parallel fan-outs (re-raised on the caller by
// `join_all`), on the calling thread for serial shortcuts — which is
// exactly the failure shape a real worker bug produces and what the
// serve engine must catch and absorb.

thread_local! {
    static IN_FANOUT: Cell<bool> = Cell::new(false);
}

/// RAII flag marking this thread as inside a fan-out's work section.
struct FanoutScope {
    was: bool,
}

impl FanoutScope {
    fn begin() -> FanoutScope {
        FanoutScope { was: IN_FANOUT.with(|c| c.replace(true)) }
    }
}

impl Drop for FanoutScope {
    fn drop(&mut self) {
        let was = self.was;
        IN_FANOUT.with(|c| c.set(was));
    }
}

/// Count one pool fault event (top-level entries only); `true` = this
/// fan-out must raise the injected worker panic.
fn fanout_bomb() -> bool {
    if IN_FANOUT.with(|c| c.get()) {
        return false;
    }
    crate::fault::pool_fanout_bomb()
}

/// The injected worker panic (P1-home: panics may originate in the pool,
/// never in request paths — request paths must *absorb* this one).
fn detonate() -> ! {
    panic!("injected fault: pool worker panic");
}

impl Pool {
    pub fn new(workers: usize) -> Pool {
        Pool { workers: workers.max(1) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Deterministic parallel map: returns `[f(0), f(1), …, f(n-1)]` in
    /// index order. Tasks are work-stolen off a shared counter; each
    /// worker collects `(index, value)` pairs locally and the results are
    /// slotted by index, so scheduling never reorders anything.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let bomb = fanout_bomb();
        let _fan = FanoutScope::begin();
        if self.workers == 1 || n <= 1 {
            if bomb {
                detonate();
            }
            return (0..n).map(f).collect();
        }
        let w = self.workers.min(n);
        let next = AtomicUsize::new(0);
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let jit = jitter_max_us();
        let f = &f;
        let next = &next;
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(w - 1);
            for wi in 0..w - 1 {
                handles.push(s.spawn(move || {
                    if bomb && wi == 0 {
                        detonate();
                    }
                    jitter_start(jit, wi);
                    let _serial = enter(serial());
                    let mut got: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        got.push((i, f(i)));
                    }
                    got
                }));
            }
            {
                let _serial = enter(serial());
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    slots[i] = Some(f(i));
                }
            }
            for got in join_all(handles) {
                for (i, v) in got {
                    slots[i] = Some(v);
                }
            }
        });
        slots
            .into_iter()
            .map(|o| o.expect("pool map: missing slot"))
            .collect()
    }

    /// Split `data` (logically rows of `row_len` elements) into one
    /// contiguous row-range per worker and run `f(first_row, chunk)` on
    /// each in parallel. Each row is written by exactly one worker with
    /// the serial arithmetic, so the result is chunking-independent.
    pub fn run_rows1<F>(&self, data: &mut [f32], row_len: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let bomb = fanout_bomb();
        let _fan = FanoutScope::begin();
        let rows = if row_len == 0 { 0 } else { data.len() / row_len };
        debug_assert_eq!(rows * row_len, data.len(), "run_rows1: ragged data");
        let w = self.workers.min(rows.max(1));
        if w <= 1 {
            if bomb {
                detonate();
            }
            f(0, data);
            return;
        }
        let jit = jitter_max_us();
        let f = &f;
        std::thread::scope(|s| {
            let base = rows / w;
            let extra = rows % w;
            let mut rest = data;
            let mut row0 = 0usize;
            let mut handles = Vec::with_capacity(w - 1);
            for wi in 0..w {
                let take_rows = base + usize::from(wi < extra);
                let (chunk, tail) = rest.split_at_mut(take_rows * row_len);
                rest = tail;
                let r0 = row0;
                row0 += take_rows;
                if wi + 1 == w {
                    // last chunk runs on the calling thread
                    let _serial = enter(serial());
                    f(r0, chunk);
                } else {
                    handles.push(s.spawn(move || {
                        if bomb && wi == 0 {
                            detonate();
                        }
                        jitter_start(jit, wi);
                        let _serial = enter(serial());
                        f(r0, chunk);
                    }));
                }
            }
            join_all(handles);
        });
    }

    /// Two-buffer variant of [`run_rows1`]: both slices are split at the
    /// same row boundaries (`a` has `a_len` elements per row, `b` has
    /// `b_len`), so `f` sees matching disjoint row ranges of each. Used
    /// where a row transformation also emits a per-row scalar (e.g. the
    /// softmax/NLL loop writing probabilities and per-row loss).
    pub fn run_rows2<F>(
        &self,
        a: &mut [f32],
        a_len: usize,
        b: &mut [f32],
        b_len: usize,
        f: F,
    ) where
        F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
    {
        let bomb = fanout_bomb();
        let _fan = FanoutScope::begin();
        let rows = if a_len == 0 { 0 } else { a.len() / a_len };
        debug_assert_eq!(rows * a_len, a.len(), "run_rows2: ragged a");
        debug_assert_eq!(rows * b_len, b.len(), "run_rows2: b rows mismatch");
        let w = self.workers.min(rows.max(1));
        if w <= 1 {
            if bomb {
                detonate();
            }
            f(0, a, b);
            return;
        }
        let jit = jitter_max_us();
        let f = &f;
        std::thread::scope(|s| {
            let base = rows / w;
            let extra = rows % w;
            let mut rest_a = a;
            let mut rest_b = b;
            let mut row0 = 0usize;
            let mut handles = Vec::with_capacity(w - 1);
            for wi in 0..w {
                let take_rows = base + usize::from(wi < extra);
                let (ca, ta) = rest_a.split_at_mut(take_rows * a_len);
                let (cb, tb) = rest_b.split_at_mut(take_rows * b_len);
                rest_a = ta;
                rest_b = tb;
                let r0 = row0;
                row0 += take_rows;
                if wi + 1 == w {
                    let _serial = enter(serial());
                    f(r0, ca, cb);
                } else {
                    handles.push(s.spawn(move || {
                        if bomb && wi == 0 {
                            detonate();
                        }
                        jitter_start(jit, wi);
                        let _serial = enter(serial());
                        f(r0, ca, cb);
                    }));
                }
            }
            join_all(handles);
        });
    }
}

/// Join every worker handle, collecting results in spawn order. If any
/// worker panicked, the FIRST panic payload is re-raised on the calling
/// thread via [`std::panic::resume_unwind`] — but only after all
/// handles have been joined, so no worker is left running against
/// borrowed data. Relying on `std::thread::scope`'s implicit join would
/// discard the payload and re-panic with a generic "a scoped thread
/// panicked", which makes assertion failures inside pool tasks
/// undebuggable at `FASP_THREADS>1`.
fn join_all<T>(handles: Vec<std::thread::ScopedJoinHandle<'_, T>>) -> Vec<T> {
    let mut out = Vec::with_capacity(handles.len());
    let mut payload: Option<Box<dyn std::any::Any + Send>> = None;
    for h in handles {
        match h.join() {
            Ok(v) => out.push(v),
            Err(p) => {
                if payload.is_none() {
                    payload = Some(p);
                }
            }
        }
    }
    if let Some(p) = payload {
        std::panic::resume_unwind(p);
    }
    out
}

// ------------------------------------------------------------- sizing

/// Explicit `FASP_THREADS` setting, if present and valid (≥ 1).
pub fn threads_from_env() -> Option<usize> {
    std::env::var("FASP_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
}

/// Pool width used when nothing installs a backend: `FASP_THREADS` if
/// set, else the machine's parallelism capped at 8 (the fan-outs here
/// are memory-bandwidth-bound well before that).
pub fn default_threads() -> usize {
    threads_from_env().unwrap_or_else(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(DEFAULT_MAX_THREADS)
    })
}

// ------------------------------------------------------------- ambient pool

static SERIAL: OnceCell<Arc<Pool>> = OnceCell::new();
static DEFAULT: OnceCell<Arc<Pool>> = OnceCell::new();

thread_local! {
    static CURRENT: RefCell<Option<Arc<Pool>>> = RefCell::new(None);
}

/// The shared 1-worker pool (the determinism reference and the pool
/// installed inside workers to forbid nested fan-out).
pub fn serial() -> Arc<Pool> {
    SERIAL.get_or_init(|| Arc::new(Pool::new(1))).clone()
}

/// The process-default pool, sized by [`default_threads`] once.
pub fn default_pool() -> Arc<Pool> {
    DEFAULT
        .get_or_init(|| Arc::new(Pool::new(default_threads())))
        .clone()
}

/// The pool ambient on this thread: the innermost [`enter`] scope, else
/// the process default.
pub fn current() -> Arc<Pool> {
    CURRENT
        .with(|c| c.borrow().clone())
        .unwrap_or_else(default_pool)
}

/// RAII scope installing a pool as this thread's [`current`]; restores
/// the previous pool on drop. Returned by `Backend::enter`.
pub struct PoolScope {
    prev: Option<Arc<Pool>>,
}

pub fn enter(pool: Arc<Pool>) -> PoolScope {
    let prev = CURRENT.with(|c| c.borrow_mut().replace(pool));
    PoolScope { prev }
}

impl Drop for PoolScope {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_index_order() {
        for workers in [1usize, 2, 4, 7] {
            let pool = Pool::new(workers);
            let out = pool.map(23, |i| i * i);
            assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn run_rows1_covers_every_row_once() {
        for workers in [1usize, 2, 3, 5] {
            let pool = Pool::new(workers);
            let rows = 11;
            let row_len = 4;
            let mut data = vec![0.0f32; rows * row_len];
            pool.run_rows1(&mut data, row_len, |r0, chunk| {
                for (i, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (r0 + i) as f32 + 1.0;
                    }
                }
            });
            for r in 0..rows {
                for j in 0..row_len {
                    assert_eq!(data[r * row_len + j], (r + 1) as f32, "w={workers} r={r}");
                }
            }
        }
    }

    #[test]
    fn run_rows2_splits_both_buffers_consistently() {
        let pool = Pool::new(3);
        let rows = 9;
        let mut a = vec![1.0f32; rows * 2];
        let mut b = vec![0.0f32; rows];
        pool.run_rows2(&mut a, 2, &mut b, 1, |r0, ca, cb| {
            for i in 0..cb.len() {
                ca[i * 2] += (r0 + i) as f32;
                cb[i] = ca[i * 2] + ca[i * 2 + 1];
            }
        });
        for r in 0..rows {
            assert_eq!(b[r], r as f32 + 2.0);
        }
    }

    #[test]
    fn workers_run_with_serial_pool_installed() {
        let pool = Pool::new(4);
        let nested = pool.map(8, |_| current().workers());
        assert!(nested.iter().all(|&w| w == 1), "nested pools must be serial");
    }

    #[test]
    fn injected_pool_fault_panics_and_is_catchable() {
        use crate::fault::{install, FaultPlan, Site};
        let scope = install(&FaultPlan::parse("pool@2=panic").unwrap());
        let pool = Pool::new(3);
        assert_eq!(pool.map(4, |i| i), vec![0, 1, 2, 3]); // event 1: clean
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let caught =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.map(4, |i| i)));
        std::panic::set_hook(prev);
        assert!(caught.is_err(), "armed fan-out must raise the injected panic");
        assert_eq!(scope.report().injected_at(Site::Pool), 1);
        // one-shot fault is spent; later fan-outs run clean
        assert_eq!(pool.map(2, |i| i), vec![0, 1]);
        assert_eq!(scope.report().events_at(Site::Pool), 3);
    }

    #[test]
    fn nested_fanouts_do_not_count_pool_events() {
        use crate::fault::{install, FaultPlan, Site};
        let scope = install(&FaultPlan::default());
        let pool = Pool::new(1);
        pool.map(3, |_| {
            // nested entry through the serial shortcut on this thread —
            // must not count as a top-level pool event
            serial().map(2, |j| j);
            0usize
        });
        assert_eq!(scope.report().events_at(Site::Pool), 1);
    }

    #[test]
    fn enter_scopes_nest_and_restore() {
        let outer = current().workers();
        {
            let _g = enter(Arc::new(Pool::new(5)));
            assert_eq!(current().workers(), 5);
            {
                let _g2 = enter(serial());
                assert_eq!(current().workers(), 1);
            }
            assert_eq!(current().workers(), 5);
        }
        assert_eq!(current().workers(), outer);
    }
}
