//! Tiny leveled logger (the `log` crate facade is vendored but a full
//! env_logger is not; this is all we need). Level comes from `FASP_LOG`
//! (error|warn|info|debug, default info).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

pub fn level() -> Level {
    let v = LEVEL.load(Ordering::Relaxed);
    if v == 255 {
        let lv = match std::env::var("FASP_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            _ => Level::Info,
        };
        LEVEL.store(lv as u8, Ordering::Relaxed);
        lv
    } else {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            3 => Level::Debug,
            _ => Level::Info,
        }
    }
}

pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

pub fn enabled(lv: Level) -> bool {
    (lv as u8) <= (level() as u8)
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            eprintln!("[warn] {}", format!($($arg)*));
        }
    };
}
