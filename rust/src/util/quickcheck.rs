//! Mini property-testing framework (proptest is not in the offline vendor
//! set): random case generation with linear shrinking for sized inputs.
//!
//! Usage:
//! ```no_run
//! use fasp::util::quickcheck::{Gen, forall};
//! forall(100, 42, |g: &mut Gen| {
//!     let xs = g.vec_f32(1..64, -10.0..10.0);
//!     let sum: f32 = xs.iter().sum();
//!     let sum2: f32 = xs.iter().rev().sum();
//!     ((sum - sum2).abs() < 1e-3, format!("sum mismatch {sum} {sum2}"))
//! });
//! ```

use super::rng::Rng;
use std::ops::Range;

/// Case generator: a seeded RNG with convenience draws that record the
/// "size" choices so failures can be replayed/shrunk.
pub struct Gen {
    pub rng: Rng,
    /// current size multiplier in (0, 1]; shrink passes lower it.
    pub scale: f64,
}

impl Gen {
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        let span = (r.end - r.start).max(1);
        let scaled = ((span as f64 * self.scale).ceil() as usize).max(1);
        r.start + self.rng.below(scaled.min(span))
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        r.start + self.rng.f32() * (r.end - r.start)
    }

    pub fn vec_f32(&mut self, len: Range<usize>, range: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(range.clone())).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `cases` random cases of `prop`. On failure, retries the same seed
/// at smaller scales (shrink-lite) and panics with the smallest failing
/// report.
pub fn forall<F>(cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> (bool, String),
{
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64 * 0x9E37);
        let mut g = Gen { rng: Rng::new(case_seed), scale: 1.0 };
        let (ok, msg) = prop(&mut g);
        if ok {
            continue;
        }
        // shrink: replay the same stream with smaller size scales
        let mut smallest = (1.0f64, msg);
        for &scale in &[0.5, 0.25, 0.1, 0.05] {
            let mut g = Gen { rng: Rng::new(case_seed), scale };
            let (ok, msg) = prop(&mut g);
            if !ok {
                smallest = (scale, msg);
            }
        }
        panic!(
            "property failed (case {case}, seed {case_seed}, scale {}): {}",
            smallest.0, smallest.1
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(50, 1, |g| {
            let xs = g.vec_f32(1..32, -1.0..1.0);
            (xs.iter().all(|x| x.abs() <= 1.0), "bounds".into())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn catches_violation() {
        forall(50, 2, |g| {
            let n = g.usize_in(1..100);
            (n < 50, format!("n={n}"))
        });
    }
}
