//! Cross-cutting substrates: JSON, PRNG, timing, logging, property
//! testing. These exist because the offline vendor set has no
//! serde/rand/criterion/proptest — each is a small, tested, in-repo
//! equivalent (see DESIGN.md §3).

pub mod json;
pub mod pool;
pub mod rng;
pub mod timer;
pub mod quickcheck;
pub mod log;

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Argsort descending by key.
pub fn argsort_desc(keys: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..keys.len()).collect();
    idx.sort_by(|&a, &b| keys[b].partial_cmp(&keys[a]).unwrap_or(std::cmp::Ordering::Equal));
    idx
}
