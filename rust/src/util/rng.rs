//! Deterministic PRNG substrate (the vendor set only carries `rand_core`
//! without `rand`): SplitMix64 seeding + xoshiro256** core, plus the
//! sampling helpers the corpus generator, weight init and property tests
//! need. All experiment randomness flows through this so results are
//! reproducible from a single seed.

/// xoshiro256** with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for parallel/per-purpose rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vec of N(0, std) f32 values.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights. Weights must be
    /// finite, non-negative and sum to a positive total — a NaN or
    /// infinite weight poisons the running subtraction so `u <= 0.0`
    /// never fires and the walk silently falls through to the *last*
    /// index (the worst candidate under a sorted top-k). Callers are
    /// expected to sanitize first (see `model::decode::sample_row`);
    /// these debug asserts make a poisoned call loud in test builds.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        debug_assert!(!weights.is_empty(), "categorical: empty weights");
        debug_assert!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "categorical: weights must be finite and non-negative, got {weights:?}"
        );
        let total: f64 = weights.iter().sum();
        debug_assert!(
            total > 0.0,
            "categorical: weights must have a positive total, got {total}"
        );
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf(s) weights over [0, n): w_k = 1/(k+1)^s.
    pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
        (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).collect()
    }

    /// k distinct indices from [0, n) (reservoir-free; k << n assumed ok).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
