//! Minimal JSON parser/writer (serde is not in the offline vendor set).
//!
//! Supports the full JSON grammar we emit/consume: objects, arrays,
//! strings (with escapes), numbers, booleans, null. Used for
//! `artifacts/manifest.json`, experiment reports and config files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic
/// round-trips.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    // ---- construction helpers --------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    /// Serialize with 1-space indentation (matches python json.dump(indent=1)).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }
    /// Compact serialization.
    pub fn compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        let pad = |out: &mut String, d: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..d {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    v.write(out, depth + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, depth + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}, "d": true, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        let re = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.compact()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }
}
