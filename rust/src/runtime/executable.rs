//! One loaded artifact: manifest entry → resolved host implementation,
//! plus typed input construction and output validation.
//!
//! Conventions (inherited from the original AOT pipeline):
//! * outputs form an ordered tuple of leaves, returned as [`Literal`]s;
//! * inputs are passed positionally in manifest order;
//! * shapes/dtypes are validated against the manifest before execution so
//!   a drifted artifact fails loudly, not with garbage numerics.
//!
//! Host artifacts carry a small on-disk stamp file (written by
//! `gen_host_artifacts.py`); loading validates it so a corrupt or
//! garbage artifact file is rejected up front. Entries synthesized
//! in-memory (compact models) have no file and skip that check.

use super::host_exec::HostEntry;
use super::literal::Literal;
use super::manifest::{ArtifactKind, ArtifactSpec, DType, Manifest};
use crate::model::PackedWeights;
use crate::tensor::{IntTensor, Tensor};
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// First line of every host artifact stamp file.
pub const HOST_ARTIFACT_MAGIC: &str = "FASP-HOST-ARTIFACT v1";

/// Borrowed host value for artifact inputs.
#[derive(Clone, Copy)]
pub enum In<'a> {
    F(&'a Tensor),
    I(&'a IntTensor),
    /// An opaque literal already in artifact form (fed back, e.g. the
    /// packed train state). Shape-checked against the input spec.
    Lit(&'a Literal),
    /// A count-only placeholder for an input whose bytes the entry never
    /// reads because they arrive via the packed operator plan (the
    /// params input of `call_packed`). Validated against the manifest
    /// spec exactly like a literal of that many elements — entries that
    /// *would* read it (the plan-less fallback) fail loudly on the empty
    /// placeholder rather than computing on garbage.
    Elems(usize),
}

/// The shared empty literal standing in for [`In::Elems`] positions.
fn empty_literal() -> &'static Literal {
    static EMPTY: once_cell::sync::OnceCell<Literal> = once_cell::sync::OnceCell::new();
    EMPTY.get_or_init(|| Literal::from_f32(&[0], Vec::new()))
}

/// Running counters for the perf breakdown (EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct ExecStats {
    pub calls: AtomicU64,
    pub upload_ns: AtomicU64,
    pub exec_ns: AtomicU64,
    pub download_ns: AtomicU64,
}

pub struct Artifact {
    pub spec: ArtifactSpec,
    entry: HostEntry,
    pub stats: ExecStats,
}

/// Validate a host artifact stamp file: magic line + matching entry name.
fn validate_stamp(path: &std::path::Path, name: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read artifact file {}", path.display()))?;
    let mut lines = text.lines();
    match lines.next() {
        Some(l) if l.trim() == HOST_ARTIFACT_MAGIC => {}
        _ => bail!(
            "{}: not a host artifact (bad magic; expected '{HOST_ARTIFACT_MAGIC}')",
            path.display()
        ),
    }
    let entry_line = format!("entry: {name}");
    if !lines.any(|l| l.trim() == entry_line) {
        bail!("{}: artifact stamp does not declare '{entry_line}'", path.display());
    }
    Ok(())
}

impl Artifact {
    /// Load `name` from the manifest: validate its stamp file (when it
    /// has one) and resolve the host implementation.
    pub fn load(manifest: &Manifest, name: &str) -> Result<Artifact> {
        let spec = manifest.artifact(name)?.clone();
        if spec.kind == ArtifactKind::Hlo {
            bail!(
                "artifact '{name}' is an AOT HLO entry; this build executes \
                 host artifacts only — regenerate with gen_host_artifacts.py"
            );
        }
        let t0 = std::time::Instant::now();
        if !spec.file.is_empty() {
            let path = manifest.artifact_path(&spec);
            validate_stamp(&path, name)
                .with_context(|| format!("load artifact '{name}'"))?;
        }
        let entry = HostEntry::resolve(manifest, name)?;
        crate::debug!("loaded {name} in {:.2?}", t0.elapsed());
        Ok(Artifact { spec, entry, stats: ExecStats::default() })
    }

    /// Execute with typed host inputs; returns output leaves as literals.
    pub fn call(&self, inputs: &[In]) -> Result<Vec<Literal>> {
        self.call_packed(inputs, None)
    }

    /// [`Artifact::call`] with the session's packed operator plan: model
    /// entries run over the plan's resident weights and pre-packed
    /// linear panels (zero per-call weight copies/transposes) instead of
    /// rebuilding weights from the params literal each call. Outputs are
    /// bit-identical with or without the plan.
    pub fn call_packed(
        &self,
        inputs: &[In],
        model: Option<&PackedWeights>,
    ) -> Result<Vec<Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, artifact wants {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let t0 = std::time::Instant::now();
        // owned literals for tensor inputs; borrowed passed through
        let mut owned: Vec<Literal> = Vec::with_capacity(inputs.len());
        for (i, (inp, spec)) in inputs.iter().copied().zip(&self.spec.inputs).enumerate() {
            match inp {
                In::F(t) => {
                    if t.shape != spec.shape || spec.dtype != DType::F32 {
                        bail!(
                            "{} input {} ('{}'): got f32{:?}, want {:?}{:?}",
                            self.spec.name, i, spec.name, t.shape, spec.dtype, spec.shape
                        );
                    }
                    owned.push(Literal::from_tensor(t));
                }
                In::I(t) => {
                    if t.shape != spec.shape || spec.dtype != DType::I32 {
                        bail!(
                            "{} input {} ('{}'): got i32{:?}, want {:?}{:?}",
                            self.spec.name, i, spec.name, t.shape, spec.dtype, spec.shape
                        );
                    }
                    owned.push(Literal::from_int_tensor(t));
                }
                In::Lit(l) => {
                    let n = l.element_count();
                    if n != spec.numel() {
                        bail!(
                            "{} input {} ('{}'): literal has {} elems, want {:?}",
                            self.spec.name, i, spec.name, n, spec.shape
                        );
                    }
                }
                In::Elems(n) => {
                    if n != spec.numel() {
                        bail!(
                            "{} input {} ('{}'): {} elems declared, want {:?}",
                            self.spec.name, i, spec.name, n, spec.shape
                        );
                    }
                }
            }
        }
        // positional argument list preserving order
        let mut all: Vec<&Literal> = Vec::with_capacity(inputs.len());
        let mut oi = 0usize;
        for inp in inputs.iter().copied() {
            match inp {
                In::Lit(l) => all.push(l),
                In::Elems(_) => all.push(empty_literal()),
                In::F(_) | In::I(_) => {
                    all.push(&owned[oi]);
                    oi += 1;
                }
            }
        }
        let upload = t0.elapsed();

        let t1 = std::time::Instant::now();
        let leaves = self
            .entry
            .execute(&all, model)
            .with_context(|| format!("execute {}", self.spec.name))?;
        let exec = t1.elapsed();

        let t2 = std::time::Instant::now();
        if leaves.len() != self.spec.outputs.len() {
            bail!(
                "{}: {} output leaves, manifest says {}",
                self.spec.name,
                leaves.len(),
                self.spec.outputs.len()
            );
        }
        for (i, (leaf, spec)) in leaves.iter().zip(&self.spec.outputs).enumerate() {
            if leaf.element_count() != spec.numel() || leaf.dtype() != spec.dtype {
                bail!(
                    "{} out{}: {} {:?} elems, manifest wants {:?}{:?}",
                    self.spec.name,
                    i,
                    leaf.element_count(),
                    leaf.dtype(),
                    spec.dtype,
                    spec.shape
                );
            }
        }
        let download = t2.elapsed();

        self.stats.calls.fetch_add(1, Ordering::Relaxed);
        self.stats.upload_ns.fetch_add(upload.as_nanos() as u64, Ordering::Relaxed);
        self.stats.exec_ns.fetch_add(exec.as_nanos() as u64, Ordering::Relaxed);
        self.stats
            .download_ns
            .fetch_add(download.as_nanos() as u64, Ordering::Relaxed);
        Ok(leaves)
    }

    /// Convert an output leaf literal to a host Tensor (f32), shaped per
    /// the manifest.
    pub fn to_tensor(&self, leaf_idx: usize, lit: &Literal) -> Result<Tensor> {
        let spec = &self.spec.outputs[leaf_idx];
        if spec.dtype != DType::F32 {
            bail!("{} out{} is not f32", self.spec.name, leaf_idx);
        }
        let v = lit.as_f32()?;
        if v.len() != spec.numel() {
            bail!(
                "{} out{}: {} elems, want {:?}",
                self.spec.name, leaf_idx, v.len(), spec.shape
            );
        }
        Ok(Tensor::new(spec.shape.clone(), v.to_vec()))
    }

    /// Convenience: execute and convert every f32 leaf to a Tensor.
    pub fn call_tensors(&self, inputs: &[In]) -> Result<Vec<Tensor>> {
        self.call_tensors_packed(inputs, None)
    }

    /// [`Artifact::call_tensors`] over a packed operator plan.
    pub fn call_tensors_packed(
        &self,
        inputs: &[In],
        model: Option<&PackedWeights>,
    ) -> Result<Vec<Tensor>> {
        let leaves = self.call_packed(inputs, model)?;
        leaves
            .iter()
            .enumerate()
            .map(|(i, l)| self.to_tensor(i, l))
            .collect()
    }

    /// Mean wall-clock per call of the pure execute phase.
    pub fn mean_exec_ms(&self) -> f64 {
        let calls = self.stats.calls.load(Ordering::Relaxed).max(1);
        self.stats.exec_ns.load(Ordering::Relaxed) as f64 / calls as f64 / 1e6
    }
}
