//! One compiled artifact: HLO text → `XlaComputation` → PJRT executable,
//! plus typed input construction and output unpacking.
//!
//! Conventions (set by `python/compile/aot_util.py`):
//! * the computation root is a tuple (`return_tuple=True`) — PJRT hands
//!   back ONE tuple buffer, which we decompose on the host;
//! * inputs are passed positionally in manifest order;
//! * shapes/dtypes are validated against the manifest before execution so
//!   a drifted artifact fails loudly, not with garbage numerics.

use super::manifest::{ArtifactSpec, DType, Manifest};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Borrowed host value for artifact inputs.
#[derive(Clone, Copy)]
pub enum In<'a> {
    F(&'a Tensor),
    I(&'a IntTensor),
    /// An opaque literal already in artifact-output form (fed back, e.g.
    /// the packed train state). Shape-checked against the input spec.
    Lit(&'a xla::Literal),
}

/// Running counters for the perf breakdown (EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct ExecStats {
    pub calls: AtomicU64,
    pub upload_ns: AtomicU64,
    pub exec_ns: AtomicU64,
    pub download_ns: AtomicU64,
}

pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    pub stats: ExecStats,
}

pub(crate) fn f32_literal(shape: &[usize], data: &[f32]) -> xla::Literal {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )
    .expect("f32 literal")
}

pub(crate) fn i32_literal(shape: &[usize], data: &[i32]) -> xla::Literal {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes,
    )
    .expect("i32 literal")
}

impl Artifact {
    /// Load and compile `name` from the manifest's artifact directory.
    pub fn load(manifest: &Manifest, name: &str) -> Result<Artifact> {
        let spec = manifest.artifact(name)?.clone();
        let path = manifest.artifact_path(&spec);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = super::client::with_client(|c| {
            c.compile(&comp)
                .with_context(|| format!("XLA compile of '{name}'"))
        })?;
        crate::debug!("compiled {name} in {:.2?}", t0.elapsed());
        Ok(Artifact { spec, exe, stats: ExecStats::default() })
    }

    /// Execute with typed host inputs; returns output leaves as literals.
    pub fn call(&self, inputs: &[In]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, artifact wants {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let t0 = std::time::Instant::now();
        let mut lits: Vec<xla::Literal> = Vec::with_capacity(inputs.len());
        // borrowed literals are referenced via index into `inputs`
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
        for (i, (inp, spec)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            match inp {
                In::F(t) => {
                    if t.shape != spec.shape || spec.dtype != DType::F32 {
                        bail!(
                            "{} input {} ('{}'): got f32{:?}, want {:?}{:?}",
                            self.spec.name, i, spec.name, t.shape, spec.dtype, spec.shape
                        );
                    }
                    lits.push(f32_literal(&t.shape, &t.data));
                }
                In::I(t) => {
                    if t.shape != spec.shape || spec.dtype != DType::I32 {
                        bail!(
                            "{} input {} ('{}'): got i32{:?}, want {:?}{:?}",
                            self.spec.name, i, spec.name, t.shape, spec.dtype, spec.shape
                        );
                    }
                    lits.push(i32_literal(&t.shape, &t.data));
                }
                In::Lit(l) => {
                    let n = l.element_count();
                    if n != spec.numel() {
                        bail!(
                            "{} input {} ('{}'): literal has {} elems, want {:?}",
                            self.spec.name, i, spec.name, n, spec.shape
                        );
                    }
                    refs.push(l);
                }
            }
        }
        // Build the positional argument list preserving order.
        let mut all: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
        let mut li = 0;
        let mut ri = 0;
        for inp in inputs {
            match inp {
                In::Lit(_) => {
                    all.push(refs[ri]);
                    ri += 1;
                }
                _ => {
                    all.push(&lits[li]);
                    li += 1;
                }
            }
        }
        let upload = t0.elapsed();

        let t1 = std::time::Instant::now();
        let result = self
            .exe
            .execute::<&xla::Literal>(&all)
            .with_context(|| format!("execute {}", self.spec.name))?;
        let exec = t1.elapsed();

        let t2 = std::time::Instant::now();
        let buf = &result[0][0];
        let root = buf
            .to_literal_sync()
            .with_context(|| format!("fetch result of {}", self.spec.name))?;
        let leaves = root.to_tuple().context("decompose output tuple")?;
        if leaves.len() != self.spec.outputs.len() {
            bail!(
                "{}: {} output leaves, manifest says {}",
                self.spec.name,
                leaves.len(),
                self.spec.outputs.len()
            );
        }
        let download = t2.elapsed();

        self.stats.calls.fetch_add(1, Ordering::Relaxed);
        self.stats.upload_ns.fetch_add(upload.as_nanos() as u64, Ordering::Relaxed);
        self.stats.exec_ns.fetch_add(exec.as_nanos() as u64, Ordering::Relaxed);
        self.stats
            .download_ns
            .fetch_add(download.as_nanos() as u64, Ordering::Relaxed);
        Ok(leaves)
    }

    /// Convert an output leaf literal to a host Tensor (f32).
    pub fn to_tensor(&self, leaf_idx: usize, lit: &xla::Literal) -> Result<Tensor> {
        let spec = &self.spec.outputs[leaf_idx];
        if spec.dtype != DType::F32 {
            bail!("{} out{} is not f32", self.spec.name, leaf_idx);
        }
        let v: Vec<f32> = lit.to_vec().context("literal to_vec")?;
        if v.len() != spec.numel() {
            bail!(
                "{} out{}: {} elems, want {:?}",
                self.spec.name, leaf_idx, v.len(), spec.shape
            );
        }
        Ok(Tensor::new(spec.shape.clone(), v))
    }

    /// Convenience: execute and convert every f32 leaf to a Tensor.
    pub fn call_tensors(&self, inputs: &[In]) -> Result<Vec<Tensor>> {
        let leaves = self.call(inputs)?;
        leaves
            .iter()
            .enumerate()
            .map(|(i, l)| self.to_tensor(i, l))
            .collect()
    }

    /// Mean wall-clock per call of the pure execute phase.
    pub fn mean_exec_ms(&self) -> f64 {
        let calls = self.stats.calls.load(Ordering::Relaxed).max(1);
        self.stats.exec_ns.load(Ordering::Relaxed) as f64 / calls as f64 / 1e6
    }
}
