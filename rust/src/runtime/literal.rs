//! Host literal: the typed value currency of the runtime boundary.
//!
//! Historically this was `xla::Literal` (a PJRT device-adjacent buffer).
//! The runtime now executes entries through the in-process host backends
//! ([`super::host_exec`]), so a literal is a plain owned array — but the
//! contract keeps the same shape: params upload once (wrapped as
//! `session::PackedParams`), multi-batch loops reuse them, and the packed
//! train state round-trips opaquely without per-tensor decomposition.
//! Literals never cross out of `runtime/`.

use crate::tensor::{IntTensor, Tensor};
use anyhow::{bail, Result};

use super::manifest::DType;

/// An owned, shaped, typed host value.
#[derive(Clone, Debug, PartialEq)]
pub enum Literal {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Literal {
    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Literal {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Literal::F32 { shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Literal {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Literal::I32 { shape: shape.to_vec(), data }
    }

    pub fn from_tensor(t: &Tensor) -> Literal {
        Literal::F32 { shape: t.shape.clone(), data: t.data.clone() }
    }

    pub fn from_int_tensor(t: &IntTensor) -> Literal {
        Literal::I32 { shape: t.shape.clone(), data: t.data.clone() }
    }

    pub fn scalar_f32(v: f32) -> Literal {
        Literal::F32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Literal::F32 { shape, .. } | Literal::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Literal::F32 { .. } => DType::F32,
            Literal::I32 { .. } => DType::I32,
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
        }
    }

    /// Borrow the f32 payload (errors on dtype mismatch).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Literal::F32 { data, .. } => Ok(data),
            Literal::I32 { .. } => bail!("literal is i32, expected f32"),
        }
    }

    /// Borrow the i32 payload (errors on dtype mismatch).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Literal::I32 { data, .. } => Ok(data),
            Literal::F32 { .. } => bail!("literal is f32, expected i32"),
        }
    }

    /// Convert an f32 literal to a host tensor.
    pub fn to_tensor(&self) -> Result<Tensor> {
        match self {
            Literal::F32 { shape, data } => Ok(Tensor::new(shape.clone(), data.clone())),
            Literal::I32 { .. } => bail!("literal is i32, expected f32"),
        }
    }

    /// Convert an i32 literal to a host int tensor.
    pub fn to_int_tensor(&self) -> Result<IntTensor> {
        match self {
            Literal::I32 { shape, data } => {
                Ok(IntTensor::new(shape.clone(), data.clone()))
            }
            Literal::F32 { .. } => bail!("literal is f32, expected i32"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_and_type_checks() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let l = Literal::from_tensor(&t);
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.shape(), &[2, 2]);
        assert_eq!(l.to_tensor().unwrap(), t);
        assert!(l.as_i32().is_err());

        let it = IntTensor::new(vec![3], vec![1, 2, 3]);
        let li = Literal::from_int_tensor(&it);
        assert_eq!(li.as_i32().unwrap(), &[1, 2, 3]);
        assert!(li.to_tensor().is_err());

        let s = Literal::scalar_f32(7.0);
        assert_eq!(s.element_count(), 1);
        assert!(s.shape().is_empty());
    }
}
