//! Model-level runtime facade. One `ModelEngine` per zoo model binds the
//! four AOT entries (`fwd_loss`, `capture`, `gradcol`, `train_step`) and
//! exposes typed, batched operations to the coordinator. Artifacts
//! compile lazily (first use) and are cached for the engine's lifetime.

use super::executable::{Artifact, In};
use super::literal::Literal;
use super::manifest::{Manifest, ModelSpec};
use crate::tensor::{IntTensor, Tensor};
use crate::tensor::ops::add_assign;
use anyhow::{Context, Result};
use once_cell::sync::OnceCell;

/// Per-layer calibration statistics (sums over sample rows; additive
/// across batches). Mirrors `python/compile/capture.py::CAPTURE_LEAVES`.
#[derive(Clone)]
pub struct LayerStats {
    /// Gram of the qkv input (post-ln1), d×d.
    pub g_ln1: Tensor,
    /// Gram of the fc1/gate/up input (post-ln2), d×d.
    pub g_ln2: Tensor,
    /// Gram of the W_out input (attention context), d×d.
    pub g_attn: Tensor,
    /// Gram of the W_fc2/W_down input (FFN hidden), f×f.
    pub g_ffn: Tensor,
    pub m_ln1: Tensor,
    pub m_ln2: Tensor,
    pub m_attn: Tensor,
    pub m_ffn: Tensor,
}

/// Accumulated calibration statistics for a whole model.
pub struct CalibStats {
    pub layers: Vec<LayerStats>,
    /// Number of sample rows accumulated (batches × B × T).
    pub rows: usize,
}

impl CalibStats {
    /// ‖X_j‖₂ per FFN hidden unit of layer `l` (from diag of the Gram).
    pub fn ffn_xnorm(&self, l: usize) -> Vec<f32> {
        diag_sqrt(&self.layers[l].g_ffn)
    }
    /// ‖X_j‖₂ per attention-context dim of layer `l`.
    pub fn attn_xnorm(&self, l: usize) -> Vec<f32> {
        diag_sqrt(&self.layers[l].g_attn)
    }
    /// ‖X_j‖₂ per qkv-input dim (used by the Q/K ablation).
    pub fn ln1_xnorm(&self, l: usize) -> Vec<f32> {
        diag_sqrt(&self.layers[l].g_ln1)
    }
}

fn diag_sqrt(g: &Tensor) -> Vec<f32> {
    let (n, _) = g.dims2();
    (0..n).map(|i| g.at2(i, i).max(0.0).sqrt()).collect()
}

/// Per-layer Taylor scores for the LLM-Pruner-like baseline.
#[derive(Clone)]
pub struct GradScores {
    pub ffn: Vec<f32>,
    pub ov: Vec<f32>,
}

pub struct FwdOut {
    pub mean_nll: f32,
    pub seq_nll: Vec<f32>,
    pub tok_nll: Tensor,
}

pub struct ModelEngine<'m> {
    pub manifest: &'m Manifest,
    pub spec: ModelSpec,
    fwd: OnceCell<Artifact>,
    capture: OnceCell<Artifact>,
    gradcol: OnceCell<Artifact>,
    train: OnceCell<Artifact>,
}

impl<'m> ModelEngine<'m> {
    pub fn new(manifest: &'m Manifest, model: &str) -> Result<Self> {
        let spec = manifest.model(model)?.clone();
        Ok(ModelEngine {
            manifest,
            spec,
            fwd: OnceCell::new(),
            capture: OnceCell::new(),
            gradcol: OnceCell::new(),
            train: OnceCell::new(),
        })
    }

    fn art<'a>(&self, cell: &'a OnceCell<Artifact>, entry: &str) -> Result<&'a Artifact> {
        // OnceCell::get_or_try_init would move; emulate with get/set.
        if cell.get().is_none() {
            let a = Artifact::load(self.manifest, &format!("{}_{entry}", self.spec.name))?;
            let _ = cell.set(a);
        }
        Ok(cell.get().unwrap())
    }

    pub fn fwd_artifact(&self) -> Result<&Artifact> {
        self.art(&self.fwd, "fwd_loss")
    }

    /// Teacher-forced loss on one batch.
    pub fn fwd_loss(
        &self,
        params: &Tensor,
        tokens: &IntTensor,
        targets: &IntTensor,
    ) -> Result<FwdOut> {
        let a = self.fwd_artifact()?;
        let leaves = a.call(&[In::F(params), In::I(tokens), In::I(targets)])?;
        Self::unpack_fwd(a, leaves)
    }

    /// Pre-built packed-params literal for multi-batch loops: building
    /// the [P] literal once skips the per-call tensor→literal copy and
    /// shape re-validation at the artifact boundary (the host backend
    /// still takes its own working copy per call, which is small next to
    /// the forward compute).
    pub fn params_literal(&self, params: &Tensor) -> Result<Literal> {
        anyhow::ensure!(
            params.numel() == self.spec.n_params_elems(),
            "param length {} != {}",
            params.numel(),
            self.spec.n_params_elems()
        );
        Ok(Literal::from_f32(&[params.numel()], params.data.clone()))
    }

    /// `fwd_loss` with a cached params literal.
    pub fn fwd_loss_lit(
        &self,
        params: &Literal,
        tokens: &IntTensor,
        targets: &IntTensor,
    ) -> Result<FwdOut> {
        let a = self.fwd_artifact()?;
        let leaves = a.call(&[In::Lit(params), In::I(tokens), In::I(targets)])?;
        Self::unpack_fwd(a, leaves)
    }

    fn unpack_fwd(a: &Artifact, leaves: Vec<Literal>) -> Result<FwdOut> {
        let mean = leaves[0].as_f32()?[0];
        let seq = leaves[1].as_f32()?.to_vec();
        let tok = a.to_tensor(2, &leaves[2])?;
        Ok(FwdOut { mean_nll: mean, seq_nll: seq, tok_nll: tok })
    }

    /// Run capture over `batches` and accumulate the per-layer stats.
    pub fn capture(
        &self,
        params: &Tensor,
        batches: &[IntTensor],
    ) -> Result<CalibStats> {
        let a = self.art(&self.capture, "capture")?;
        let leaves_per_layer = self.manifest.capture_leaves.len();
        let n_layers = self.spec.n_layers;
        let params_lit = self.params_literal(params)?; // upload once
        let mut acc: Option<Vec<LayerStats>> = None;
        let mut rows = 0usize;
        for toks in batches {
            let outs = a.call_tensors(&[In::Lit(&params_lit), In::I(toks)])?;
            anyhow::ensure!(
                outs.len() == leaves_per_layer * n_layers,
                "capture output arity"
            );
            rows += toks.numel();
            let mut layers = Vec::with_capacity(n_layers);
            for l in 0..n_layers {
                let b = l * leaves_per_layer;
                layers.push(LayerStats {
                    g_ln1: outs[b].clone(),
                    g_ln2: outs[b + 1].clone(),
                    g_attn: outs[b + 2].clone(),
                    g_ffn: outs[b + 3].clone(),
                    m_ln1: outs[b + 4].clone(),
                    m_ln2: outs[b + 5].clone(),
                    m_attn: outs[b + 6].clone(),
                    m_ffn: outs[b + 7].clone(),
                });
            }
            match &mut acc {
                None => acc = Some(layers),
                Some(acc) => {
                    for (a_l, n_l) in acc.iter_mut().zip(&layers) {
                        add_assign(&mut a_l.g_ln1, &n_l.g_ln1);
                        add_assign(&mut a_l.g_ln2, &n_l.g_ln2);
                        add_assign(&mut a_l.g_attn, &n_l.g_attn);
                        add_assign(&mut a_l.g_ffn, &n_l.g_ffn);
                        add_assign(&mut a_l.m_ln1, &n_l.m_ln1);
                        add_assign(&mut a_l.m_ln2, &n_l.m_ln2);
                        add_assign(&mut a_l.m_attn, &n_l.m_attn);
                        add_assign(&mut a_l.m_ffn, &n_l.m_ffn);
                    }
                }
            }
        }
        Ok(CalibStats {
            layers: acc.context("capture needs at least one batch")?,
            rows,
        })
    }

    /// Taylor column scores accumulated over calibration batches.
    pub fn gradcol(
        &self,
        params: &Tensor,
        batches: &[(IntTensor, IntTensor)],
    ) -> Result<Vec<GradScores>> {
        let a = self.art(&self.gradcol, "gradcol")?;
        let n_layers = self.spec.n_layers;
        let mut acc: Vec<GradScores> = Vec::new();
        for (toks, tgts) in batches {
            let outs = a.call_tensors(&[In::F(params), In::I(toks), In::I(tgts)])?;
            anyhow::ensure!(outs.len() == 2 * n_layers, "gradcol output arity");
            if acc.is_empty() {
                for l in 0..n_layers {
                    acc.push(GradScores {
                        ffn: outs[2 * l].data.clone(),
                        ov: outs[2 * l + 1].data.clone(),
                    });
                }
            } else {
                for l in 0..n_layers {
                    for (x, y) in acc[l].ffn.iter_mut().zip(&outs[2 * l].data) {
                        *x += y;
                    }
                    for (x, y) in acc[l].ov.iter_mut().zip(&outs[2 * l + 1].data) {
                        *x += y;
                    }
                }
            }
        }
        anyhow::ensure!(!acc.is_empty(), "gradcol needs at least one batch");
        Ok(acc)
    }

    pub fn train_artifact(&self) -> Result<&Artifact> {
        self.art(&self.train, "train_step")
    }

    /// One Adam step. `state` is the packed [3P] literal; returns
    /// (loss, new state literal) — the state never unpacks on the host.
    pub fn train_step(
        &self,
        state: &Literal,
        tokens: &IntTensor,
        targets: &IntTensor,
        t: f32,
        lr: f32,
    ) -> Result<(f32, Literal)> {
        let a = self.train_artifact()?;
        let t_s = Tensor::scalar(t);
        let lr_s = Tensor::scalar(lr);
        let mut leaves = a.call(&[
            In::Lit(state),
            In::I(tokens),
            In::I(targets),
            In::F(&t_s),
            In::F(&lr_s),
        ])?;
        let loss = leaves[0].as_f32()?[0];
        Ok((loss, leaves.remove(1)))
    }

    /// Build a fresh packed train state [3P] from packed params [P].
    pub fn init_train_state(&self, params: &Tensor) -> Result<Literal> {
        let p = params.numel();
        anyhow::ensure!(p == self.spec.n_params_elems(), "param length");
        let mut state = vec![0.0f32; 3 * p];
        state[..p].copy_from_slice(&params.data);
        Ok(Literal::from_f32(&[3 * p], state))
    }

    /// Extract packed params [P] from a packed train-state literal [3P].
    pub fn params_from_state(&self, state: &Literal) -> Result<Tensor> {
        let all = state.as_f32()?;
        let p = self.spec.n_params_elems();
        anyhow::ensure!(all.len() == 3 * p, "state length {}", all.len());
        Ok(Tensor::new(vec![p], all[..p].to_vec()))
    }
}
